//! `terapipe` — the launcher.
//!
//! Subcommands:
//!   configs                         list the Table 1 settings (+ --dump N)
//!   solve    --setting N            DP slicing scheme for one setting
//!   autotune --setting N            online planner service: replay a
//!                                   cluster-event trace, warm re-solves,
//!                                   drift detection, sim-validated plans
//!   simulate --setting N            w/o vs w/ TeraPipe iteration latency
//!   timeline --setting N            ASCII (or --chrome) schedule timeline
//!   fig3 | fig5 | fig6 | fig7 | appendix-a
//!                                   regenerate the paper's figures/tables
//!   train    […]                    real pipelined training — native CPU
//!                                   backend by default, AOT + PJRT with
//!                                   --artifacts (feature `pjrt`)
//!   measure  […]                    measure t(i,j) on the real backend and
//!                                   fit the Eq. 9 linear context model
//!
//! Flags use `--key value` / `--key=value` (see util::cli).

use std::path::PathBuf;

use terapipe::backend::{BackendSpec, NativeSpec};
use terapipe::config::{dump_setting, presets};
use terapipe::data::synthetic_corpus;
use terapipe::experiments as exp;
use terapipe::perfmodel::analytic::AnalyticModel;
#[cfg(feature = "pjrt")]
use terapipe::perfmodel::linear::LinearCtxModel;
use terapipe::perfmodel::measure::StageModels;
use terapipe::perfmodel::CostModel;
use terapipe::runtime::manifest::ModelDims;
use terapipe::sim::schedule::build_plan;
use terapipe::sim::{engine::simulate, trace};
use terapipe::solver::joint::{gpipe_plan, solve_joint_analytic, JointOpts};
use terapipe::solver::dp;
use terapipe::util::cli::Args;

fn main() {
    let args = Args::from_env();
    let cmd = args.positional.first().map(|s| s.as_str()).unwrap_or("help");
    let r = match cmd {
        "configs" => cmd_configs(&args),
        "solve" => cmd_solve(&args),
        "autotune" => cmd_autotune(&args),
        "simulate" => cmd_simulate(&args),
        "timeline" => cmd_timeline(&args),
        "fig3" => cmd_fig3(&args),
        "fig5" => cmd_fig5(&args),
        "fig6" => cmd_fig6(&args),
        "fig7" => cmd_fig7(&args),
        "appendix-a" => cmd_appendix_a(),
        "calibrate" => cmd_calibrate(&args),
        "train" => cmd_train(&args),
        "measure" => cmd_measure(&args),
        _ => {
            print!("{}", HELP);
            Ok(())
        }
    };
    if let Err(e) = r {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

const HELP: &str = "terapipe — token-level pipeline parallelism (TeraPipe, ICML 2021)

USAGE: terapipe <command> [--options]

  configs  [--dump N]                     Table 1 presets (JSON with --dump)
  solve    --setting N [--granularity 8] [--eps 0.1]
  autotune --setting N [--events trace.json] [--granularity 16] [--eps 0.1]
           [--hysteresis 0.02] [--tolerance 1e-9]
           [--trace-out trace.json] [--metrics-out metrics.prom]
  simulate --setting N [--granularity 16]
  timeline --setting N [--mode terapipe|gpipe] [--width 100] [--chrome]
  fig3     [--model gpt3-1b]
  fig5     [--granularity 16] [--settings 1,2,...,10]
  fig6     [--setting 8|9] [--max-slices N]
  fig7
  appendix-a
  train    [--slicing 32,32,32,32] [--steps 50] [--microbatches 1]
           [--lr 0.001] [--corpus FILE] [--auto] [--replan-every N]
           [--drift-threshold 0.35] [--drift-window 16]
           [--recv-timeout-ms 120000] (0 = wait forever)
           [--save-checkpoint DIR] [--resume DIR]
           [--trace-out trace.json] [--metrics-out metrics.prom]
           (Perfetto span trace + Prometheus-style metrics snapshot)
           [--postmortem-dir DIR] [--flight-steps 8] (black-box flight
           recorder: last-N-step bundle dumped on failure or at exit)
           [--heartbeat-ms N] (worker liveness beacons; defaults to 250
           when --postmortem-dir is set, off otherwise; 0 = off)
           [--report-every N] (print the worst exec<->sim differential
           cell every N steps; needs an obs output flag)
           native model: [--hidden 64] [--heads 4] [--layers 2] [--stages 2]
           [--seq-len 128] [--batch 4] [--vocab 256] [--granularity 16]
           [--seed 42]; or [--artifacts DIR] for the AOT/PJRT backend
           (requires a `--features pjrt` build)
  measure  [--repeats 5] [native model flags as for train | --artifacts DIR]
";

fn opts_from(args: &Args, default_gran: u32) -> JointOpts {
    JointOpts {
        granularity: args.u32("granularity", default_gran),
        eps_ms: args.f64("eps", 0.1),
        max_microbatch: args
            .get("max-microbatch")
            .map(|_| args.u32("max-microbatch", 4)),
    }
}

fn cmd_configs(args: &Args) -> anyhow::Result<()> {
    if args.get("dump").is_some() {
        let s = presets::setting(args.u32("dump", 1));
        println!("{}", dump_setting(&s));
        return Ok(());
    }
    println!("| id | model | N | H | L | #GPUs | B | #Data | #Pipe | #Op | params |");
    for s in presets::table1() {
        println!(
            "| {} | {} | {} | {} | {} | {} | {} | {} | {} | {} | {:.1}B |",
            s.id,
            s.model.name,
            s.model.num_layers,
            s.model.hidden,
            s.model.seq_len,
            s.parallel.total_gpus(),
            s.parallel.batch_size,
            s.parallel.data_parallel,
            s.parallel.pipeline_stages,
            s.parallel.op_parallel,
            s.model.num_params() as f64 / 1e9,
        );
    }
    Ok(())
}

fn cmd_solve(args: &Args) -> anyhow::Result<()> {
    let id = args.u32("setting", 5);
    let setting = presets::setting(id);
    let opts = opts_from(args, 8);
    let base = AnalyticModel::from_setting(&setting, 1);
    let k = setting.parallel.pipeline_stages;
    let l = setting.model.seq_len;

    let (scheme, stats) = dp::solve_tokens(&base, l, k, opts.granularity, opts.eps_ms);
    println!("setting ({id}) {}: K={k}, L={l}", setting.model.name);
    println!("single-sequence DP scheme: {}", scheme.notation());
    println!(
        "  t_max {:.3} ms, total {:.3} ms, Eq.5 latency {:.3} ms ({} slices)",
        scheme.t_max_ms,
        scheme.total_ms,
        scheme.latency_ms,
        scheme.num_slices()
    );
    println!(
        "  t_max candidates {}, inner DPs run {} (+{} feasibility probes)",
        stats.candidates, stats.dps_run, stats.probe_dps
    );

    let joint = solve_joint_analytic(&base, setting.batch_per_pipeline(), l, k, &opts);
    println!("joint batch+token scheme: {}", joint.notation());
    println!("  predicted iteration latency {:.1} ms", joint.latency_ms);
    Ok(())
}

/// The online planner service on a scripted cluster-event trace: warm
/// re-solves on topology/bandwidth deltas, drift detection from sampled
/// latencies, hysteresis-gated switches — every emitted plan replayed
/// through the discrete-event simulator and rejected if its predicted
/// Eq. 5 latency diverges beyond --tolerance.
fn cmd_autotune(args: &Args) -> anyhow::Result<()> {
    use terapipe::perfmodel::{CostModel, ScaledModel};
    use terapipe::planner::drift::LatencySample;
    use terapipe::planner::events::{demo_trace, parse_trace, EventKind};
    use terapipe::planner::{validate, Planner, PlannerConfig, ReplanDecision};

    let id = args.u32("setting", 8);
    let setting = presets::setting(id);
    let gran = args.u32("granularity", 16);
    let tol = args.f64("tolerance", 1e-9);
    let trace_out = args.get("trace-out").map(PathBuf::from);
    let metrics_out = args.get("metrics-out").map(PathBuf::from);
    if trace_out.is_some() || metrics_out.is_some() {
        terapipe::obs::set_enabled(true);
    }
    let k = setting.parallel.pipeline_stages;
    let l = setting.model.seq_len;
    let cfg = PlannerConfig {
        granularity: gran,
        eps_ms: args.f64("eps", 0.1),
        hysteresis_rel: args.f64("hysteresis", 0.02),
        ..Default::default()
    };
    let base = AnalyticModel::from_setting(&setting, 1);
    let mut planner = Planner::new(&format!("analytic/setting{id}"), base, l, k, cfg);

    let trace = match args.get("events") {
        Some(path) => parse_trace(&std::fs::read_to_string(path)?)
            .map_err(|e| anyhow::anyhow!("{path}: {e}"))?,
        None => {
            println!("(no --events file: replaying the built-in demo trace)");
            demo_trace(k)
        }
    };

    let clip = |s: &str| {
        if s.len() > 44 {
            format!("{}…", &s[..43])
        } else {
            s.to_string()
        }
    };
    let report = |p: &Planner<AnalyticModel>, step: u64, d: &ReplanDecision| -> anyhow::Result<()> {
        let sim = validate::validate_scheme(&p.current_model(), &d.scheme, d.stages, tol)
            .map_err(|e| anyhow::anyhow!("sim validation failed at step {step}: {e}"))?;
        let warm = d
            .warm
            .map(|w| format!("{} probes, window {}", w.probes, if w.hit { "hit" } else { "miss" }))
            .unwrap_or_else(|| "cold".into());
        println!(
            "step {:>5} {:<12} K={:<3} scale=({:.3}c,{:.3}m) Eq.5 {:.3} ms (sim {:.3}) gain {:+.2}% {:<6} [{warm}] {}",
            step,
            format!("{:?}", d.trigger),
            d.stages,
            d.compute_scale,
            d.comm_scale,
            d.scheme.latency_ms,
            sim,
            100.0 * d.gain_rel,
            if d.switched { "SWITCH" } else { "keep" },
            clip(&d.scheme.notation()),
        );
        Ok(())
    };

    println!(
        "autotune: setting ({id}) {} — K={k}, L={l}, g={gran}, {} events",
        setting.model.name,
        trace.len()
    );
    let first = planner.plan().clone();
    let sim = validate::validate_scheme(&planner.current_model(), &first, planner.stages(), tol)
        .map_err(|e| anyhow::anyhow!("sim validation failed on the initial plan: {e}"))?;
    println!(
        "step     0 Initial      K={k:<3} scale=(1.000c,1.000m) Eq.5 {:.3} ms (sim {sim:.3}) [cold] {}",
        first.latency_ms,
        clip(&first.notation()),
    );

    let mut rng = terapipe::util::Rng::new(0xA070);
    let max_units = l / gran;
    for ev in &trace {
        match ev.kind {
            EventKind::Stages(k2) => {
                let d = planner.on_stages_change(k2);
                report(&planner, ev.step, &d)?;
            }
            EventKind::Bandwidth(f) => {
                let d = planner.on_bandwidth_change(f);
                report(&planner, ev.step, &d)?;
            }
            EventKind::Slowdown(f) => {
                let d = planner.on_slowdown(f);
                report(&planner, ev.step, &d)?;
            }
            EventKind::Samples { true_factor, count } => {
                // undisclosed drift: observations come from the current
                // model with every stage time scaled by true_factor
                let (compute, comm) = planner.scales();
                let truth = ScaledModel {
                    inner: AnalyticModel::from_setting(&setting, 1),
                    compute,
                    comm,
                };
                let mut replans = 0usize;
                for _ in 0..count {
                    let iu = 1 + rng.below(max_units.min(8));
                    let ju = rng.below(max_units - iu + 1);
                    let (i, j) = (iu * gran, ju * gran);
                    let ms = true_factor * (truth.t(i, j) + truth.t_comm(i));
                    if let Some(d) = planner.on_sample(LatencySample { i, j, ms }) {
                        report(&planner, ev.step, &d)?;
                        replans += 1;
                    }
                }
                if replans == 0 {
                    println!(
                        "step {:>5} Samples      ×{true_factor} ({count} obs): within drift threshold, no replan",
                        ev.step
                    );
                }
            }
            EventKind::Straggler { stage, factor } => {
                // the single-dimension cost model has no per-stage term:
                // fold the named straggler into the compute scale (every
                // stage pays, so the plan is conservative for the rest)
                println!(
                    "step {:>5} Straggler    stage {stage} ×{factor:.2} -> folding into compute scale",
                    ev.step
                );
                let d = planner.on_slowdown(factor);
                report(&planner, ev.step, &d)?;
            }
            EventKind::LinkDegraded { link, factor } => {
                println!(
                    "step {:>5} LinkDegraded link {link} ×{factor:.2} -> effective bandwidth ×{:.3}",
                    ev.step,
                    1.0 / factor
                );
                let d = planner.on_bandwidth_change(1.0 / factor);
                report(&planner, ev.step, &d)?;
            }
        }
    }

    // Cache + drift telemetry goes through the metrics registry: the
    // stdout summary and --metrics-out render the same counters from the
    // same source (no bespoke print path to fall out of sync).
    let spans = terapipe::obs::flush();
    let mut reg = terapipe::obs::MetricsRegistry::new();
    terapipe::obs::metrics::cache_metrics(&mut reg, &planner.cache_stats());
    if !spans.spans.is_empty() || spans.dropped > 0 {
        terapipe::obs::metrics::span_metrics(&mut reg, &spans);
    }
    print!("{}", reg.render());
    if let Some(path) = &metrics_out {
        std::fs::write(path, reg.render())?;
        println!("metrics written to {}", path.display());
    }
    if let Some(path) = &trace_out {
        let bundle = terapipe::obs::export::TraceBundle {
            exec: spans.spans,
            predicted: Vec::new(),
            stages: k as usize,
            dropped: spans.dropped,
        };
        std::fs::write(path, terapipe::obs::export::perfetto_trace(&bundle).to_string())?;
        println!("trace written to {} (open at ui.perfetto.dev)", path.display());
    }
    Ok(())
}

fn cmd_simulate(args: &Args) -> anyhow::Result<()> {
    let id = args.u32("setting", 5);
    let opts = opts_from(args, 16);
    let row = exp::fig5_row(id, &opts);
    print!("{}", exp::render_fig5(&[row]));
    Ok(())
}

fn cmd_timeline(args: &Args) -> anyhow::Result<()> {
    let id = args.u32("setting", 8);
    let setting = presets::setting(id);
    let opts = opts_from(args, 64);
    let base = AnalyticModel::from_setting(&setting, 1);
    let k = setting.parallel.pipeline_stages;
    let l = setting.model.seq_len;
    let b = setting.batch_per_pipeline();
    let scheme = match args.get_or("mode", "terapipe") {
        "gpipe" => gpipe_plan(&|m| base.with_microbatch(m), b, l, k),
        _ => solve_joint_analytic(&base, b, l, k, &opts),
    };
    let cost = exp::AnalyticPhase { base: &base };
    let plan = build_plan(&cost, &scheme, k as usize, None, true);
    let r = simulate(&plan).map_err(|e| anyhow::anyhow!(e))?;
    if args.flag("chrome") {
        println!("{}", trace::chrome_json(&r.trace));
    } else {
        println!("scheme: {}", scheme.notation());
        println!(
            "makespan {:.1} ms, bubble fraction {:.1}%",
            r.makespan_ms,
            100.0 * r.bubble_fraction
        );
        print!(
            "{}",
            trace::ascii(&r.trace, k as usize, args.usize("width", 100))
        );
    }
    Ok(())
}

fn cmd_fig3(args: &Args) -> anyhow::Result<()> {
    let model = presets::model_by_name(args.get_or("model", "gpt3-1b"))
        .ok_or_else(|| anyhow::anyhow!("unknown model"))?;
    println!(
        "# Fig. 3 — single-layer fwd time/throughput vs tokens ({})",
        model.name
    );
    println!("| tokens | fwd ms | tokens/ms |");
    for (t, ms, tp) in exp::fig3_curve(&model, 2048) {
        println!("| {t} | {ms:.3} | {tp:.1} |");
    }
    Ok(())
}

fn cmd_fig5(args: &Args) -> anyhow::Result<()> {
    let opts = opts_from(args, 16);
    let ids = args.u32_list("settings", &[1, 2, 3, 4, 5, 6, 7, 8, 9, 10]);
    let rows: Vec<_> = ids.iter().map(|&i| exp::fig5_row(i, &opts)).collect();
    println!("# Fig. 5 / Table 2 — iteration latency w/o vs w/ TeraPipe (simulated testbed)");
    print!("{}", exp::render_fig5(&rows));
    Ok(())
}

fn cmd_fig6(args: &Args) -> anyhow::Result<()> {
    let id = args.u32("setting", 9);
    let max = args.u32("max-slices", if id == 9 { 128 } else { 16 });
    let opts = opts_from(args, 16);
    println!("# Fig. 6 / Table 3 — uniform slicing vs DP, setting ({id})");
    println!("| algorithm | scheme | latency (s) | TFLOPs/GPU |");
    for (label, scheme, lat, tf) in exp::fig6_rows(id, max, &opts) {
        let short = if scheme.len() > 42 {
            format!("{}…", &scheme[..41])
        } else {
            scheme
        };
        println!("| {label} | {short} | {lat:.3} | {tf:.4} |");
    }
    Ok(())
}

fn cmd_fig7(args: &Args) -> anyhow::Result<()> {
    let opts = opts_from(args, 16);
    println!("# Fig. 7 / Table 4 — GPT3-13B (setting 5) with longer sequences");
    println!("| L | w/o TeraPipe (s) | w/ TeraPipe (s) | speedup | paper speedup |");
    let paper = [1.40, 2.76, 4.97, 7.83];
    for ((l, g, t, sp, _), p) in exp::fig7_rows(&opts).into_iter().zip(paper) {
        println!("| {l} | {g:.3} | {t:.3} | {sp:.2}x | {p:.2}x |");
    }
    Ok(())
}

fn cmd_appendix_a() -> anyhow::Result<()> {
    println!("# Appendix A — gradient accumulation under per-stage memory caps");
    println!("| schedule | makespan (arb. units) |");
    for (label, ms) in exp::appendix_a_rows() {
        println!("| {label} | {ms:.1} |");
    }
    Ok(())
}

/// Grid-search the four V100 cost-model constants against the paper's
/// Table 2 latencies (geometric-mean log error over all 20 numbers).
/// Used once to pick the GpuSpec defaults — recorded in EXPERIMENTS.md.
fn cmd_calibrate(args: &Args) -> anyhow::Result<()> {
    let gran = args.u32("granularity", 64);
    let opts = JointOpts {
        granularity: gran,
        eps_ms: 0.2,
        max_microbatch: Some(4),
    };
    let mut best: Option<(f64, [f64; 4])> = None;
    for &eff in &[0.30, 0.35, 0.40, 0.45, 0.50, 0.55] {
        for &sat in &[128.0, 256.0, 384.0, 512.0] {
            for &launch in &[1.0, 2.0, 3.0, 4.0, 6.0] {
                for &p2p in &[0.5, 1.0, 2.0, 3.0] {
                    let mut err = 0.0;
                    for id in 1..=10u32 {
                        let mut s = presets::setting(id);
                        s.cluster.gpu.efficiency = eff;
                        s.cluster.gpu.saturation_tokens_h2048 = sat;
                        s.cluster.gpu.launch_overhead_ms = launch;
                        s.cluster.p2p_latency_ms = p2p;
                        let row = exp::fig5_row_for(&s, &opts);
                        err += (row.gpipe_latency_s / row.paper_gpipe_s).ln().powi(2);
                        err += (row.terapipe_latency_s / row.paper_terapipe_s).ln().powi(2);
                    }
                    let rms = (err / 20.0).sqrt();
                    if best.as_ref().map_or(true, |(b, _)| rms < *b) {
                        best = Some((rms, [eff, sat, launch, p2p]));
                        println!(
                            "new best rms-log-err {:.4}: eff={eff} sat={sat} launch={launch} p2p={p2p}",
                            rms
                        );
                    }
                }
            }
        }
    }
    let (rms, [eff, sat, launch, p2p]) = best.unwrap();
    println!(
        "\nbest: efficiency={eff} sat_tokens_h2048={sat} launch_ms={launch} p2p_ms={p2p} (rms log err {rms:.4}, i.e. typical ×{:.2} off)",
        rms.exp()
    );
    Ok(())
}

/// Native model geometry from CLI flags (defaults: a small byte-level GPT
/// the CPU backend trains comfortably).
fn native_spec(args: &Args) -> anyhow::Result<NativeSpec> {
    let granularity = args.usize("granularity", 16);
    let dims = ModelDims {
        vocab: args.usize("vocab", 256),
        hidden: args.usize("hidden", 64),
        num_heads: args.usize("heads", 4),
        layers_per_stage: args.usize("layers", 2),
        num_stages: args.usize("stages", 2),
        seq_len: args.usize("seq-len", 128),
        batch: args.usize("batch", 4),
        block_ctx: granularity,
        seed: args.u32("seed", 42) as u64,
    };
    anyhow::ensure!(dims.num_heads >= 1 && dims.hidden % dims.num_heads == 0, "--hidden must be a multiple of --heads");
    anyhow::ensure!(granularity >= 1 && dims.seq_len % granularity == 0, "--granularity must divide --seq-len");
    anyhow::ensure!(dims.num_stages >= 1 && dims.layers_per_stage >= 1, "--stages and --layers must be ≥ 1");
    Ok(NativeSpec::new(dims, granularity))
}

/// Bucket-restricted DP over a fitted cost model (solver::bucketed).
fn dp_bucketed<M: CostModel>(
    fitted: &M,
    seq_len: usize,
    stages: usize,
    buckets: &[usize],
) -> Vec<usize> {
    let bu: Vec<u32> = buckets.iter().map(|&b| b as u32).collect();
    let (scheme, _) = terapipe::solver::bucketed::solve_tokens_bucketed(
        fitted, seq_len as u32, stages as u32, &bu, 0.0,
    )
    .expect("buckets must compose the sequence length");
    scheme.lens.into_iter().map(|l| l as usize).collect()
}

/// Predicted (simulated) single-step trace: the per-role Eq. 9 fits
/// replayed through the wavefront over `slicing` — each stage track uses
/// its own role's model. Feeds the exec↔sim differential, the Perfetto
/// predicted tracks, and the flight recorder's postmortem report.
fn predicted_spans(
    models: &StageModels,
    slicing: &[usize],
    stages: usize,
) -> Vec<terapipe::sim::trace::Span> {
    let mut per_stage = Vec::with_capacity(stages);
    for stage in 0..stages {
        let fit = models.for_stage(stage, stages);
        let mut stage_durs = Vec::with_capacity(slicing.len());
        let mut off = 0u32;
        for &len in slicing {
            stage_durs.push(fit.t(len as u32, off));
            off += len as u32;
        }
        per_stage.push(stage_durs);
    }
    let plan = terapipe::sim::schedule::stream_plan_per_stage(&per_stage);
    terapipe::sim::wavefront::evaluate(&plan, true)
        .map(|r| r.trace)
        .unwrap_or_default()
}

/// Uniform 4-way split when it lands on buckets, else one full slice.
fn default_slicing(seq_len: usize, buckets: &[usize]) -> Vec<usize> {
    let quarter = seq_len / 4;
    if quarter > 0 && seq_len % 4 == 0 && buckets.contains(&quarter) {
        vec![quarter; 4]
    } else {
        vec![seq_len]
    }
}

fn step_printer(r: &terapipe::coordinator::StepReport) {
    if r.step % 10 == 0 || r.step < 5 {
        // per-stage utilization (busy / pipeline window) when timing
        // collection is on (cfg.trace or a replan cadence)
        let util = if !r.stage_busy_ms.is_empty() && r.pipe_ms > 0.0 {
            let per: Vec<String> = r
                .stage_busy_ms
                .iter()
                .map(|b| format!("{:.0}%", 100.0 * b / r.pipe_ms))
                .collect();
            let bubble = r
                .bubble_fraction
                .map(|b| format!(" bubble {:.0}%", 100.0 * b))
                .unwrap_or_default();
            format!("  util [{}]{}", per.join(" "), bubble)
        } else {
            String::new()
        };
        println!(
            "step {:>4}  loss {:.4}  {:>7.1} ms  {:.0} tok/s{util}",
            r.step,
            r.loss,
            r.wall_ms,
            r.tokens as f64 / (r.wall_ms / 1e3)
        );
    }
}

/// `--recv-timeout-ms N`: the driver's inactivity deadline (0 = wait
/// forever, the pre-deadline behavior).
fn recv_timeout(args: &Args) -> Option<u64> {
    let default = terapipe::coordinator::DEFAULT_RECV_TIMEOUT_MS as usize;
    match args.usize("recv-timeout-ms", default) {
        0 => None,
        ms => Some(ms as u64),
    }
}

fn cmd_train(args: &Args) -> anyhow::Result<()> {
    if args.get("artifacts").is_some() {
        return cmd_train_pjrt(args);
    }
    let spec = native_spec(args)?;
    let m = spec.model();
    let buckets = spec.buckets();

    // Observability: any output flag turns the global span recorder
    // on (before --auto's measure pass, so probe spans land in the
    // trace) and enables per-slice timing collection (cfg.trace).
    let trace_out = args.get("trace-out").map(PathBuf::from);
    let metrics_out = args.get("metrics-out").map(PathBuf::from);
    // Black-box flight recorder: ring of the last --flight-steps steps'
    // spans + health verdicts, dumped as a postmortem bundle into
    // --postmortem-dir when the run fails (or on exit, for drills).
    let postmortem = args.get("postmortem-dir").map(PathBuf::from);
    let flight_steps = args.usize("flight-steps", 8);
    // Worst exec↔sim differential cell printed every N steps (0 = off).
    let report_every = args.usize("report-every", 0);
    let obs_on = trace_out.is_some() || metrics_out.is_some() || postmortem.is_some();
    if obs_on {
        terapipe::obs::set_enabled(true);
    }
    // Worker liveness beacons default on when the flight recorder is
    // armed (a postmortem should tell idle from dead), off otherwise:
    // heartbeats add a second sender per driver link, which perturbs the
    // virtual transport's RNG stream in determinism-pinned tests.
    let heartbeat_ms =
        match args.usize("heartbeat-ms", if postmortem.is_some() { 250 } else { 0 }) {
            0 => None,
            ms => Some(ms as u64),
        };

    // One measured per-stage fit serves --auto slicing, the drift gate's
    // solved-against belief (when --replan-every is set), and the
    // predicted trace-out tracks.
    let mut auto_fit: Option<StageModels> = None;
    let slicing: Vec<usize> = if args.flag("auto") {
        // measure real native timings per stage role → fit Eq. 9 per
        // role → bottleneck DP over the buckets
        let models = terapipe::backend::measure_fit_per_stage(&spec, 3)?;
        let lens = dp_bucketed(
            &models.planning_model(m.num_stages),
            m.seq_len,
            m.num_stages,
            &buckets,
        );
        println!("auto slicing from per-stage measured models (bottleneck DP): {lens:?}");
        auto_fit = Some(models);
        lens
    } else if args.get("slicing").is_some() {
        args.u32_list("slicing", &[]).into_iter().map(|x| x as usize).collect()
    } else {
        default_slicing(m.seq_len, &buckets)
    };

    let cfg = terapipe::coordinator::TrainConfig {
        slicing,
        microbatches: args.usize("microbatches", 1),
        steps: args.usize("steps", 50),
        lr: args.f64("lr", 1e-3) as f32,
        seed: args.u32("seed", 42) as u64,
        replan_every: args.get("replan-every").map(|_| args.usize("replan-every", 0)),
        trace: obs_on,
        recv_timeout_ms: recv_timeout(args),
        heartbeat_ms,
    };
    let corpus = match args.get("corpus") {
        Some(path) => std::fs::read_to_string(path)?,
        None => synthetic_corpus(1 << 16, 7),
    };
    let resume = args.get("resume").map(PathBuf::from);
    let save = args.get("save-checkpoint").map(PathBuf::from);

    // The flight recorder and the per-step differential cell both need
    // the predicted (simulated) step up front; measure once if --auto
    // didn't already. The --trace-out predicted track is still built
    // after the run (over the final slicing, which a replan may change).
    let pre_predicted: Vec<terapipe::sim::trace::Span> =
        if obs_on && (postmortem.is_some() || report_every > 0) {
            let models = match &auto_fit {
                Some(models) => models.clone(),
                None => {
                    let models = terapipe::backend::measure_fit_per_stage(&spec, 1)?;
                    auto_fit = Some(models.clone());
                    models
                }
            };
            predicted_spans(&models, &cfg.slicing, m.num_stages)
        } else {
            Vec::new()
        };
    let mut flight = terapipe::obs::flight::FlightRecorder::new(flight_steps);
    flight.set_fingerprint(terapipe::obs::flight::plan_fingerprint(
        &cfg.slicing,
        &[m.num_stages as u64, cfg.seed],
    ));

    println!(
        "training {} params (native CPU backend), {} stages × {} layers, L={}, B={}, slicing {:?}",
        m.total_params(),
        m.num_stages,
        m.layers_per_stage,
        m.seq_len,
        m.batch,
        cfg.slicing
    );
    let replan = cfg.replan_every;
    let mut trainer =
        terapipe::coordinator::Trainer::with_spec_resume(spec.clone(), cfg, resume)?;
    let seed = trainer.config().seed;
    let mut batcher = terapipe::data::Batcher::new(&corpus, m.batch, m.seq_len, seed);

    // Per-step drains keep the fixed-capacity per-thread span buffers
    // from overflowing across a long run; each drained flush also feeds
    // the flight ring and (on the --report-every cadence) the worst
    // exec↔sim differential cell.
    let mut spans = terapipe::obs::Flush::default();
    let mut last_step = 0u64;
    let record_postmortem = postmortem.is_some();
    let on_step = |r: &terapipe::coordinator::StepReport,
                   spans: &mut terapipe::obs::Flush,
                   flight: &mut terapipe::obs::flight::FlightRecorder,
                   last_step: &mut u64| {
        step_printer(r);
        *last_step = r.step as u64;
        if !obs_on {
            return;
        }
        let f = terapipe::obs::flush();
        if record_postmortem {
            flight.record_step(
                r.step as u64,
                r.loss,
                r.wall_ms,
                &f.spans,
                f.dropped,
                &r.stage_health,
                &[],
            );
        }
        if report_every > 0 && r.step % report_every == 0 && !pre_predicted.is_empty() {
            let d = terapipe::obs::Differential::from_spans(&f.spans, &pre_predicted);
            if let Some(c) = d.worst() {
                println!(
                    "  worst exec<->sim cell: stage {} slice {}: exec {:.3} ms vs sim {:.3} ms ({:+.0}%)",
                    c.stage,
                    c.slice,
                    c.exec_ms,
                    c.pred_ms,
                    100.0 * c.rel_err
                );
            }
        }
        spans.absorb(f);
    };
    let result: anyhow::Result<Vec<terapipe::coordinator::StepReport>> = if replan.is_some() {
        // Solver-in-the-loop with the drift gate (ROADMAP "planner on the
        // real runtime"): live per-slice samples stream into the
        // DriftDetector; a re-measure + re-solve is paid only when the
        // window says the solved-against model drifted.
        let solved_against = match auto_fit.clone() {
            Some(models) => models,
            None => terapipe::backend::measure_fit_per_stage(&spec, 3)?,
        }
        .planning_model(m.num_stages);
        let dcfg = terapipe::planner::drift::DriftConfig {
            window: args.usize("drift-window", 16),
            rel_threshold: args.f64("drift-threshold", 0.35),
        };
        let respec = spec.clone();
        trainer
            .train_with_drift_replan(
                || batcher.next_batch(),
                |r| on_step(r, &mut spans, &mut flight, &mut last_step),
                solved_against,
                dcfg,
                |step, factor| {
                    println!("drift at step {step} (×{factor:.3}): re-measuring + re-solving");
                    match terapipe::backend::measure_fit_per_stage(&respec, 3) {
                        Ok(m2) => Some(dp_bucketed(
                            &m2.planning_model(m.num_stages),
                            m.seq_len,
                            m.num_stages,
                            &buckets,
                        )),
                        Err(e) => {
                            eprintln!("re-measure failed, keeping slicing: {e:#}");
                            None
                        }
                    }
                },
            )
            .map(|(reports, drift)| {
                println!(
                    "drift gate: {} re-solves, {} stable checks, {} warmups over {} samples, {} named causes",
                    drift.resolves, drift.stable_checks, drift.warmups, drift.samples_seen,
                    drift.named_causes
                );
                reports
            })
    } else {
        trainer.train(|| batcher.next_batch(), |r| on_step(r, &mut spans, &mut flight, &mut last_step))
    };

    // ---- postmortem bundle: on any Err out of the loop, or on demand ----
    if let Some(dir) = &postmortem {
        if result.is_err() && obs_on {
            // the failing step never reached on_step: capture its spans
            // and the post-failure health verdicts in one last frame
            let f = terapipe::obs::flush();
            let health = trainer.health().codes();
            flight.record_step(last_step + 1, f64::NAN, 0.0, &f.spans, f.dropped, &health, &[]);
            spans.absorb(f);
        }
        let reason = match &result {
            Ok(_) => "on-demand dump at end of run".to_string(),
            Err(e) => format!("training failed: {e:#}"),
        };
        let mut reg = terapipe::obs::MetricsRegistry::new();
        terapipe::obs::metrics::span_metrics(&mut reg, &spans);
        terapipe::obs::health::health_metrics(&mut reg, trainer.health());
        if let Ok(reports) = &result {
            terapipe::obs::metrics::step_metrics(&mut reg, reports);
        }
        let metrics_text = reg.render();
        let final_health = trainer.health().codes();
        let ctx = terapipe::obs::flight::DumpContext {
            reason: &reason,
            slicing: &trainer.config().slicing,
            stages: m.num_stages,
            metrics_text: &metrics_text,
            timeline: trainer.health_timeline(),
            final_health: &final_health,
            predicted: &pre_predicted,
        };
        match flight.dump(dir, &ctx) {
            Ok(files) => println!(
                "postmortem bundle written to {} ({})",
                dir.display(),
                files.join(", ")
            ),
            Err(e) => eprintln!("postmortem dump failed: {e}"),
        }
    }
    let reports = result?;
    if let Some(ckpt) = save {
        trainer.save_checkpoint(&ckpt)?;
        println!("checkpoint written to {}", ckpt.display());
    }
    if obs_on {
        // trailing spans: the final update acks, checkpoint traffic
        spans.absorb(terapipe::obs::flush());
    }
    if let Some(path) = &metrics_out {
        let mut reg = terapipe::obs::MetricsRegistry::new();
        terapipe::obs::metrics::span_metrics(&mut reg, &spans);
        terapipe::obs::metrics::step_metrics(&mut reg, &reports);
        terapipe::obs::health::health_metrics(&mut reg, trainer.health());
        std::fs::write(path, reg.render())?;
        println!("metrics written to {}", path.display());
    }
    if let Some(path) = &trace_out {
        // Predicted counterpart: the per-role Eq. 9 fits replayed through
        // the wavefront over the *current* slicing (a replan may have
        // switched it mid-run) — each stage track uses its own role's
        // model, stacked under the exec tracks in Perfetto and aligned
        // cell-by-cell in the differential.
        let models = match auto_fit {
            Some(models) => models,
            None => terapipe::backend::measure_fit_per_stage(&spec, 1)?,
        };
        let slicing = trainer.config().slicing.clone();
        let predicted = predicted_spans(&models, &slicing, m.num_stages);
        let diff = terapipe::obs::Differential::from_spans(&spans.spans, &predicted);
        let bundle = terapipe::obs::export::TraceBundle {
            exec: spans.spans,
            predicted,
            stages: m.num_stages,
            dropped: spans.dropped,
        };
        std::fs::write(path, terapipe::obs::export::perfetto_trace(&bundle).to_string())?;
        println!("trace written to {} (open at ui.perfetto.dev)", path.display());
        print!("exec↔sim differential: {}", diff.report());
        if let Some(bf) =
            terapipe::obs::differential::measured_bubble_fraction(&bundle.exec, m.num_stages)
        {
            println!("measured bubble fraction {:.1}%", 100.0 * bf);
        }
    }
    let first = reports.first().unwrap();
    let last = reports.last().unwrap();
    println!(
        "done: loss {:.4} -> {:.4} over {} steps",
        first.loss,
        last.loss,
        reports.len()
    );
    Ok(())
}

#[cfg(feature = "pjrt")]
fn cmd_train_pjrt(args: &Args) -> anyhow::Result<()> {
    let dir = PathBuf::from(args.get_or("artifacts", "artifacts"));
    let manifest = terapipe::runtime::manifest::Manifest::load(&dir)?;
    let m = manifest.model.clone();

    let slicing: Vec<usize> = if args.flag("auto") {
        // measure → fit → DP restricted to the AOT buckets
        let fitted = measured_model_pjrt(&dir, 3)?;
        let lens = dp_bucketed(&fitted, m.seq_len, m.num_stages, &manifest.buckets);
        println!("auto slicing from measured model: {lens:?}");
        lens
    } else {
        args.u32_list("slicing", &[64, 32, 16, 16])
            .into_iter()
            .map(|x| x as usize)
            .collect()
    };

    let cfg = terapipe::coordinator::TrainConfig {
        slicing,
        microbatches: args.usize("microbatches", 1),
        steps: args.usize("steps", 50),
        lr: args.f64("lr", 1e-3) as f32,
        seed: args.u32("seed", 42) as u64,
        replan_every: args.get("replan-every").map(|_| args.usize("replan-every", 0)),
        trace: false,
        recv_timeout_ms: recv_timeout(args),
        heartbeat_ms: None,
    };
    let corpus = match args.get("corpus") {
        Some(path) => std::fs::read_to_string(path)?,
        None => synthetic_corpus(1 << 16, 7),
    };
    let resume = args.get("resume").map(PathBuf::from);
    let save = args.get("save-checkpoint").map(PathBuf::from);

    println!(
        "training {} params (PJRT backend), {} stages × {} layers, L={}, B={}, slicing {:?}",
        m.total_params(),
        m.num_stages,
        m.layers_per_stage,
        m.seq_len,
        m.batch,
        cfg.slicing
    );
    let mut trainer = terapipe::coordinator::Trainer::new_with_resume(&dir, cfg, resume)?;
    let seed = trainer.config().seed;
    let mut batcher = terapipe::data::Batcher::new(&corpus, m.batch, m.seq_len, seed);
    // solver-in-the-loop: on the replan cadence, re-measure the real
    // stage latency, refit Eq. 9, and re-solve the bucketed DP
    let replan_dir = dir.clone();
    let reports = trainer.train_with_replan(
        || batcher.next_batch(),
        step_printer,
        |step| {
            println!("replan at step {step}: re-measuring stage latency");
            match measured_model_pjrt(&replan_dir, 3) {
                Ok(fitted) => {
                    let manifest =
                        terapipe::runtime::manifest::Manifest::load(&replan_dir).ok()?;
                    Some(dp_bucketed(
                        &fitted,
                        manifest.model.seq_len,
                        manifest.model.num_stages,
                        &manifest.buckets,
                    ))
                }
                Err(e) => {
                    eprintln!("replan measure failed, keeping slicing: {e:#}");
                    None
                }
            }
        },
    )?;
    if let Some(ckpt) = save {
        trainer.save_checkpoint(&ckpt)?;
        println!("checkpoint written to {}", ckpt.display());
    }
    let first = reports.first().unwrap();
    let last = reports.last().unwrap();
    println!(
        "done: loss {:.4} -> {:.4} over {} steps",
        first.loss,
        last.loss,
        reports.len()
    );
    Ok(())
}

#[cfg(not(feature = "pjrt"))]
fn cmd_train_pjrt(_args: &Args) -> anyhow::Result<()> {
    Err(anyhow::anyhow!(
        "--artifacts selects the PJRT backend, which this build omits; rebuild with `--features pjrt` or drop the flag to train on the native backend"
    ))
}

/// Measure the real per-slice fwd+bwd latency through the PJRT backend
/// and fit the paper's Eq. 9 model (shared harness with the native path).
#[cfg(feature = "pjrt")]
fn measured_model_pjrt(dir: &std::path::Path, repeats: u32) -> anyhow::Result<LinearCtxModel> {
    let spec = terapipe::backend::PjrtSpec::new(dir)?;
    terapipe::backend::measure_fit(&spec, repeats)
}

fn cmd_measure(args: &Args) -> anyhow::Result<()> {
    if args.get("artifacts").is_some() {
        return cmd_measure_pjrt(args);
    }
    let spec = native_spec(args)?;
    let m = spec.model();
    let buckets = spec.buckets();
    let models = terapipe::backend::measure_fit_per_stage(&spec, args.u32("repeats", 5))?;
    print_measure_per_stage(&models, &buckets, m.seq_len, m.num_stages, "native CPU");
    Ok(())
}

#[cfg(feature = "pjrt")]
fn cmd_measure_pjrt(args: &Args) -> anyhow::Result<()> {
    let dir = PathBuf::from(args.get_or("artifacts", "artifacts"));
    let fitted = measured_model_pjrt(&dir, args.u32("repeats", 5))?;
    let manifest = terapipe::runtime::manifest::Manifest::load(&dir)?;
    print_measure(
        &fitted,
        &manifest.buckets,
        manifest.model.seq_len,
        manifest.model.num_stages,
        "PJRT",
    );
    Ok(())
}

#[cfg(not(feature = "pjrt"))]
fn cmd_measure_pjrt(_args: &Args) -> anyhow::Result<()> {
    Err(anyhow::anyhow!(
        "--artifacts selects the PJRT backend, which this build omits; rebuild with `--features pjrt` or drop the flag to measure the native backend"
    ))
}

/// Per-role Eq. 9 coefficients + the bottleneck table the slicing DP
/// actually consumes (native path; the PJRT path keeps the single-model
/// printout in [`print_measure`]).
fn print_measure_per_stage(
    models: &StageModels,
    buckets: &[usize],
    seq_len: usize,
    stages: usize,
    label: &str,
) {
    println!("# measured per-stage fwd+bwd latency (real {label} backend) + Eq. 9 fit per role");
    for (role, fit) in [
        ("first", &models.first),
        ("middle", &models.middle),
        ("last", &models.last),
    ] {
        println!(
            "{role:>6}: t_ctx(i,j) = {:.4} + {:.6}·i + {:.6}·j + {:.8}·ij  (ms)",
            fit.coeffs.a0, fit.coeffs.a1, fit.coeffs.a2, fit.coeffs.a3
        );
    }
    let pm = models.planning_model(stages);
    println!("| i (slice) | j (ctx) | bottleneck ms |");
    let g = *buckets.iter().min().unwrap();
    for &i in buckets {
        for j in [0usize, seq_len / 2] {
            let jj = (j / g) * g;
            if i + jj <= seq_len {
                println!("| {i} | {jj} | {:.3} |", pm.t(i as u32, jj as u32));
            }
        }
    }
    let lens = dp_bucketed(&pm, seq_len, stages, buckets);
    println!("DP slicing over per-stage measured models (bottleneck, bucketed): {lens:?}");
}

#[cfg(feature = "pjrt")]
fn print_measure(fitted: &LinearCtxModel, buckets: &[usize], seq_len: usize, stages: usize, label: &str) {
    println!("# measured stage fwd+bwd latency (real {label} backend) + Eq. 9 fit");
    println!(
        "t_ctx(i,j) = {:.4} + {:.6}·i + {:.6}·j + {:.8}·ij  (ms)",
        fitted.coeffs.a0, fitted.coeffs.a1, fitted.coeffs.a2, fitted.coeffs.a3
    );
    println!("| i (slice) | j (ctx) | predicted ms |");
    let g = *buckets.iter().min().unwrap();
    for &i in buckets {
        for j in [0usize, seq_len / 2] {
            let jj = (j / g) * g;
            if i + jj <= seq_len {
                println!("| {i} | {jj} | {:.3} |", fitted.t(i as u32, jj as u32));
            }
        }
    }
    let lens = dp_bucketed(fitted, seq_len, stages, buckets);
    println!("DP slicing over measured model (bucketed): {lens:?}");
}
