//! `artifacts/manifest.json` — the contract between `python/compile/aot.py`
//! and the rust runtime: model geometry, slice buckets, per-executable
//! input/output specs (flat, in HLO parameter order), and the initial
//! parameter files.

use std::path::{Path, PathBuf};

use anyhow::{anyhow, bail, Context, Result};

use crate::util::json::Json;

/// Model geometry (mirror of python `ModelDims`).
#[derive(Debug, Clone, PartialEq)]
pub struct ModelDims {
    pub vocab: usize,
    pub hidden: usize,
    pub num_heads: usize,
    pub layers_per_stage: usize,
    pub num_stages: usize,
    pub seq_len: usize,
    pub batch: usize,
    pub block_ctx: usize,
    pub seed: u64,
}

impl ModelDims {
    pub fn head_dim(&self) -> usize {
        self.hidden / self.num_heads
    }

    /// KV context buffer shape: [NL, B, T, NH, HD].
    pub fn kv_shape(&self) -> Vec<usize> {
        vec![
            self.layers_per_stage,
            self.batch,
            self.seq_len,
            self.num_heads,
            self.head_dim(),
        ]
    }

    /// Per-slice KV shape for slice length `s`.
    pub fn kv_new_shape(&self, s: usize) -> Vec<usize> {
        vec![self.layers_per_stage, self.batch, s, self.num_heads, self.head_dim()]
    }

    pub fn total_params(&self) -> usize {
        let h = self.hidden;
        12 * h * h * self.layers_per_stage * self.num_stages
            + (self.vocab + self.seq_len) * h // embeddings
            + 2 * h // final LN
            + h * self.vocab + self.vocab // LM head
    }
}

/// One tensor in an executable's I/O list.
#[derive(Debug, Clone, PartialEq)]
pub struct TensorSpec {
    pub name: String,
    pub shape: Vec<usize>,
    pub dtype: String,
}

/// An executable's flat I/O signature.
#[derive(Debug, Clone)]
pub struct ExeSpec {
    pub name: String,
    pub inputs: Vec<TensorSpec>,
    pub outputs: Vec<TensorSpec>,
}

/// A parameter tensor with its init file.
#[derive(Debug, Clone)]
pub struct InitEntry {
    pub name: String,
    pub shape: Vec<usize>,
    pub file: String,
}

/// The parsed manifest.
#[derive(Debug, Clone)]
pub struct Manifest {
    pub dir: PathBuf,
    pub model: ModelDims,
    pub buckets: Vec<usize>,
    /// Parameter specs per group, in canonical flat order.
    pub embed_params: Vec<TensorSpec>,
    pub stage_params: Vec<TensorSpec>,
    pub head_params: Vec<TensorSpec>,
    pub executables: Vec<ExeSpec>,
    pub init_embed: Vec<InitEntry>,
    pub init_head: Vec<InitEntry>,
    pub init_stages: Vec<Vec<InitEntry>>,
}

/// `entry.req(key)` as a string, with the offending key in the error.
fn req_str(e: &Json, key: &str) -> Result<String> {
    Ok(e.req(key)
        .map_err(|m| anyhow!(m))?
        .as_str()
        .ok_or_else(|| anyhow!("'{key}' must be a string"))?
        .to_string())
}

/// `entry.req(key)` as an array of sizes, with the offending key (and
/// element index) in the error — malformed manifests must come back as
/// `Err`, never a panic (manifest.json is external input).
fn req_shape(e: &Json, key: &str) -> Result<Vec<usize>> {
    e.req(key)
        .map_err(|m| anyhow!(m))?
        .as_arr()
        .ok_or_else(|| anyhow!("'{key}' must be an array"))?
        .iter()
        .enumerate()
        .map(|(i, d)| {
            d.as_usize()
                .ok_or_else(|| anyhow!("'{key}[{i}]' must be a non-negative integer"))
        })
        .collect()
}

fn tensor_specs(v: &Json) -> Result<Vec<TensorSpec>> {
    v.as_arr()
        .ok_or_else(|| anyhow!("expected array of tensor specs"))?
        .iter()
        .map(|e| {
            Ok(TensorSpec {
                name: req_str(e, "name")?,
                shape: req_shape(e, "shape")?,
                dtype: e
                    .get("dtype")
                    .and_then(|d| d.as_str())
                    .unwrap_or("float32")
                    .to_string(),
            })
        })
        .collect()
}

fn init_entries(v: &Json) -> Result<Vec<InitEntry>> {
    v.as_arr()
        .ok_or_else(|| anyhow!("expected init array"))?
        .iter()
        .map(|e| {
            Ok(InitEntry {
                name: req_str(e, "name")?,
                shape: req_shape(e, "shape")?,
                file: req_str(e, "file")?,
            })
        })
        .collect()
}

impl Manifest {
    pub fn load(dir: &Path) -> Result<Manifest> {
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {} (run `make artifacts`)", path.display()))?;
        let v = Json::parse(&text).map_err(|e| anyhow!("parsing {}: {e}", path.display()))?;

        let m = v.req("model").map_err(|e| anyhow!(e))?;
        let u = |k: &str| -> Result<usize> {
            m.req(k)
                .map_err(|e| anyhow!(e))?
                .as_usize()
                .ok_or_else(|| anyhow!("model.{k} must be a number"))
        };
        let model = ModelDims {
            vocab: u("vocab")?,
            hidden: u("hidden")?,
            num_heads: u("num_heads")?,
            layers_per_stage: u("layers_per_stage")?,
            num_stages: u("num_stages")?,
            seq_len: u("seq_len")?,
            batch: u("batch")?,
            block_ctx: u("block_ctx")?,
            seed: u("seed")? as u64,
        };

        let buckets: Vec<usize> = v
            .req("buckets")
            .map_err(|e| anyhow!(e))?
            .as_arr()
            .ok_or_else(|| anyhow!("buckets must be an array"))?
            .iter()
            .enumerate()
            .map(|(i, b)| {
                b.as_usize()
                    .ok_or_else(|| anyhow!("'buckets[{i}]' must be a non-negative integer"))
            })
            .collect::<Result<_>>()?;

        let groups = v.req("param_groups").map_err(|e| anyhow!(e))?;
        let embed_params = tensor_specs(groups.req("embed").map_err(|e| anyhow!(e))?)?;
        let stage_params = tensor_specs(groups.req("stage").map_err(|e| anyhow!(e))?)?;
        let head_params = tensor_specs(groups.req("head").map_err(|e| anyhow!(e))?)?;

        let mut executables = Vec::new();
        for (name, spec) in v
            .req("executables")
            .map_err(|e| anyhow!(e))?
            .members()
            .ok_or_else(|| anyhow!("executables must be an object"))?
        {
            executables.push(ExeSpec {
                name: name.clone(),
                inputs: tensor_specs(spec.req("inputs").map_err(|e| anyhow!(e))?)?,
                outputs: tensor_specs(spec.req("outputs").map_err(|e| anyhow!(e))?)?,
            });
        }

        let init = v.req("init").map_err(|e| anyhow!(e))?;
        let init_embed = init_entries(init.req("embed").map_err(|e| anyhow!(e))?)?;
        let init_head = init_entries(init.req("head").map_err(|e| anyhow!(e))?)?;
        let init_stages = init
            .req("stages")
            .map_err(|e| anyhow!(e))?
            .as_arr()
            .ok_or_else(|| anyhow!("init.stages must be an array"))?
            .iter()
            .map(init_entries)
            .collect::<Result<Vec<_>>>()?;

        if init_stages.len() != model.num_stages {
            bail!(
                "manifest has {} stage inits for {} stages",
                init_stages.len(),
                model.num_stages
            );
        }

        Ok(Manifest {
            dir: dir.to_path_buf(),
            model,
            buckets,
            embed_params,
            stage_params,
            head_params,
            executables,
            init_embed,
            init_head,
            init_stages,
        })
    }

    pub fn exe(&self, name: &str) -> Result<&ExeSpec> {
        self.executables
            .iter()
            .find(|e| e.name == name)
            .ok_or_else(|| anyhow!("executable '{name}' not in manifest"))
    }

    pub fn hlo_path(&self, name: &str) -> PathBuf {
        self.dir.join(format!("{name}.hlo.txt"))
    }

    /// Load an init tensor group from its raw f32 files.
    pub fn load_init(
        &self,
        entries: &[InitEntry],
    ) -> Result<Vec<crate::runtime::tensor::HostTensor>> {
        entries
            .iter()
            .map(|e| {
                let path = self.dir.join(&e.file);
                let bytes = std::fs::read(&path)
                    .with_context(|| format!("reading init file {}", path.display()))?;
                let n: usize = e.shape.iter().product::<usize>().max(1);
                if bytes.len() != 4 * n {
                    bail!("{}: expected {} bytes, got {}", e.file, 4 * n, bytes.len());
                }
                let floats: Vec<f32> = bytes
                    .chunks_exact(4)
                    .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
                    .collect();
                Ok(crate::runtime::tensor::HostTensor::f32(&e.shape, floats))
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn art_dir() -> Option<PathBuf> {
        let d = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        d.join("manifest.json").exists().then_some(d)
    }

    #[test]
    fn loads_real_manifest_when_built() {
        let Some(dir) = art_dir() else {
            eprintln!("skipping: artifacts not built");
            return;
        };
        let m = Manifest::load(&dir).unwrap();
        assert!(m.buckets.len() >= 2);
        assert_eq!(m.stage_params.len(), 12 * m.model.layers_per_stage);
        assert_eq!(m.embed_params.len(), 2);
        assert_eq!(m.head_params.len(), 4);
        // every bucket has its six executables
        for &s in &m.buckets {
            for role in ["embed_fwd", "embed_bwd", "stage_fwd", "stage_bwd", "head_fwd", "head_bwd"] {
                let name = format!("{role}_s{s}");
                let e = m.exe(&name).unwrap();
                assert!(!e.inputs.is_empty(), "{name}");
                assert!(m.hlo_path(&name).exists(), "{name} hlo file");
            }
        }
        // init loads and matches shapes
        let embed = m.load_init(&m.init_embed).unwrap();
        assert_eq!(embed[0].shape, vec![m.model.vocab, m.model.hidden]);
        assert_eq!(m.init_stages.len(), m.model.num_stages);
    }

    #[test]
    fn kv_shapes_consistent() {
        let d = ModelDims {
            vocab: 256, hidden: 128, num_heads: 4, layers_per_stage: 2,
            num_stages: 2, seq_len: 128, batch: 4, block_ctx: 64, seed: 0,
        };
        assert_eq!(d.head_dim(), 32);
        assert_eq!(d.kv_shape(), vec![2, 4, 128, 4, 32]);
        assert_eq!(d.kv_new_shape(16), vec![2, 4, 16, 4, 32]);
        assert!(d.total_params() > 12 * 128 * 128 * 4);
    }

    #[test]
    fn missing_manifest_errors_helpfully() {
        let err = Manifest::load(Path::new("/nonexistent")).unwrap_err();
        assert!(format!("{err:#}").contains("make artifacts"));
    }

    /// Write `text` as manifest.json in a scratch dir and try to load it.
    fn load_text(tag: &str, text: &str) -> Result<Manifest> {
        let dir =
            std::env::temp_dir().join(format!("terapipe-manifest-{tag}-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(dir.join("manifest.json"), text).unwrap();
        let out = Manifest::load(&dir);
        let _ = std::fs::remove_dir_all(&dir);
        out
    }

    const MODEL: &str = r#""model": {"vocab": 8, "hidden": 4, "num_heads": 2,
        "layers_per_stage": 1, "num_stages": 1, "seq_len": 8, "batch": 1,
        "block_ctx": 4, "seed": 0}"#;

    #[test]
    fn malformed_bucket_is_an_error_not_a_panic() {
        let text = format!(r#"{{{MODEL}, "buckets": [4, "x"]}}"#);
        let err = load_text("bucket", &text).unwrap_err();
        assert!(format!("{err:#}").contains("buckets[1]"), "{err:#}");
    }

    #[test]
    fn malformed_shape_dim_names_the_offending_key() {
        let text = format!(
            r#"{{{MODEL}, "buckets": [4, 8],
                "param_groups": {{"embed": [{{"name": "w", "shape": [4, "oops"]}}],
                                  "stage": [], "head": []}},
                "executables": {{}},
                "init": {{"embed": [], "head": [], "stages": [[]]}}}}"#
        );
        let err = load_text("shape", &text).unwrap_err();
        assert!(format!("{err:#}").contains("shape[1]"), "{err:#}");
    }

    #[test]
    fn init_entry_missing_file_is_an_error_not_a_panic() {
        let text = format!(
            r#"{{{MODEL}, "buckets": [4, 8],
                "param_groups": {{"embed": [], "stage": [], "head": []}},
                "executables": {{}},
                "init": {{"embed": [{{"name": "w", "shape": [4]}}],
                          "head": [], "stages": [[]]}}}}"#
        );
        let err = load_text("initfile", &text).unwrap_err();
        assert!(format!("{err:#}").contains("file"), "{err:#}");
    }

    #[test]
    fn non_string_tensor_name_is_an_error_not_a_panic() {
        let text = format!(
            r#"{{{MODEL}, "buckets": [4, 8],
                "param_groups": {{"embed": [{{"name": 3, "shape": [4]}}],
                                  "stage": [], "head": []}},
                "executables": {{}},
                "init": {{"embed": [], "head": [], "stages": [[]]}}}}"#
        );
        let err = load_text("name", &text).unwrap_err();
        assert!(format!("{err:#}").contains("name"), "{err:#}");
    }
}
