//! Host-side runtime substrate: tensors, the artifact manifest, and (with
//! the `pjrt` feature) the PJRT execution layer.
//!
//! The always-available parts — [`tensor::HostTensor`] (the coordinator's
//! interchange format, with the strided KV-buffer copies) and
//! [`manifest::ModelDims`]/[`manifest::Manifest`] — carry no XLA
//! dependency and back both stage backends. The PJRT pieces below load
//! the AOT HLO-text artifacts, compile them once, and execute them on the
//! hot path; python never runs here.
//!
//! Each [`StageRuntime`] owns its own `PjRtClient` — one per stage worker
//! thread, mirroring one-process-per-GPU deployments and sidestepping the
//! (non-Send) PJRT handles: all cross-thread traffic is plain
//! [`tensor::HostTensor`] data.
//!
//! Interchange is HLO *text* (`HloModuleProto::from_text_file`), not
//! serialized protos: jax ≥ 0.5 emits 64-bit instruction ids that
//! xla_extension 0.5.1 rejects; the text parser reassigns ids.

pub mod manifest;
pub mod tensor;

#[cfg(feature = "pjrt")]
use std::collections::HashMap;
#[cfg(feature = "pjrt")]
use std::path::Path;

#[cfg(feature = "pjrt")]
use anyhow::{anyhow, bail, Context, Result};

#[cfg(feature = "pjrt")]
use manifest::{ExeSpec, Manifest};
#[cfg(feature = "pjrt")]
use tensor::HostTensor;

/// A compiled executable plus its manifest signature.
#[cfg(feature = "pjrt")]
pub struct Executable {
    pub spec: ExeSpec,
    exe: xla::PjRtLoadedExecutable,
}

#[cfg(feature = "pjrt")]
impl Executable {
    /// Execute with shape/dtype validation against the manifest spec.
    /// Inputs are uploaded, the tuple output is decomposed into host
    /// tensors in manifest order.
    pub fn run(&self, inputs: &[HostTensor]) -> Result<Vec<HostTensor>> {
        let refs: Vec<&HostTensor> = inputs.iter().collect();
        self.run_refs(&refs)
    }

    /// Borrow-based variant — the coordinator hot path: parameters and KV
    /// buffers are passed by reference instead of deep-cloned per slice
    /// (EXPERIMENTS.md §Perf L3 iteration 1).
    pub fn run_refs(&self, inputs: &[&HostTensor]) -> Result<Vec<HostTensor>> {
        if inputs.len() != self.spec.inputs.len() {
            bail!(
                "{}: expected {} inputs, got {}",
                self.spec.name,
                self.spec.inputs.len(),
                inputs.len()
            );
        }
        for (t, s) in inputs.iter().zip(&self.spec.inputs) {
            if t.shape != s.shape {
                bail!(
                    "{} input '{}': shape {:?} != manifest {:?}",
                    self.spec.name, s.name, t.shape, s.shape
                );
            }
            if t.dtype_name() != s.dtype {
                bail!(
                    "{} input '{}': dtype {} != manifest {}",
                    self.spec.name, s.name, t.dtype_name(), s.dtype
                );
            }
        }
        let literals: Vec<xla::Literal> = inputs
            .iter()
            .map(|t| t.to_literal())
            .collect::<Result<_>>()?;
        let refs: Vec<&xla::Literal> = literals.iter().collect();
        self.run_literal_refs(&refs)
    }

    /// Lowest-level entry: pre-converted literals (the coordinator caches
    /// parameter literals between optimizer steps — §Perf L3 iteration 2).
    /// Count is validated; shape validation happened when the literals
    /// were built.
    pub fn run_literal_refs(&self, args: &[&xla::Literal]) -> Result<Vec<HostTensor>> {
        if args.len() != self.spec.inputs.len() {
            bail!(
                "{}: expected {} inputs, got {}",
                self.spec.name,
                self.spec.inputs.len(),
                args.len()
            );
        }
        let result = self
            .exe
            .execute::<&xla::Literal>(args)
            .with_context(|| format!("executing {}", self.spec.name))?;
        let out_lit = result[0][0]
            .to_literal_sync()
            .with_context(|| format!("fetching {} output", self.spec.name))?;
        // aot.py lowers with return_tuple=True: always a tuple, even for
        // single outputs.
        let parts = out_lit.to_tuple()?;
        if parts.len() != self.spec.outputs.len() {
            bail!(
                "{}: expected {} outputs, got {}",
                self.spec.name,
                self.spec.outputs.len(),
                parts.len()
            );
        }
        let mut outs = Vec::with_capacity(parts.len());
        for (lit, s) in parts.iter().zip(&self.spec.outputs) {
            let t = HostTensor::from_literal(lit)
                .with_context(|| format!("{} output '{}'", self.spec.name, s.name))?;
            if t.shape != s.shape {
                bail!(
                    "{} output '{}': shape {:?} != manifest {:?}",
                    self.spec.name, s.name, t.shape, s.shape
                );
            }
            outs.push(t);
        }
        Ok(outs)
    }
}

/// One stage worker's runtime: a CPU PJRT client plus the compiled
/// executables that worker needs.
#[cfg(feature = "pjrt")]
pub struct StageRuntime {
    pub manifest: Manifest,
    client: xla::PjRtClient,
    exes: HashMap<String, Executable>,
}

#[cfg(feature = "pjrt")]
impl StageRuntime {
    /// Create a client and compile `names` from the artifact dir.
    pub fn load(artifacts: &Path, names: &[String]) -> Result<StageRuntime> {
        // Silence xla_extension's per-client INFO chatter (created/destroyed
        // lines) unless the user asked for it. Must be set before the first
        // client in the process — which is here.
        if std::env::var_os("TF_CPP_MIN_LOG_LEVEL").is_none() {
            std::env::set_var("TF_CPP_MIN_LOG_LEVEL", "1");
        }
        let manifest = Manifest::load(artifacts)?;
        let client = xla::PjRtClient::cpu().map_err(|e| anyhow!("PJRT cpu client: {e:?}"))?;
        let mut rt = StageRuntime {
            manifest,
            client,
            exes: HashMap::new(),
        };
        for n in names {
            rt.compile(n)?;
        }
        Ok(rt)
    }

    /// Compile (or re-use) an executable by manifest name.
    pub fn compile(&mut self, name: &str) -> Result<()> {
        if self.exes.contains_key(name) {
            return Ok(());
        }
        let spec = self.manifest.exe(name)?.clone();
        let path = self.manifest.hlo_path(name);
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().ok_or_else(|| anyhow!("non-utf8 path"))?,
        )
        .map_err(|e| anyhow!("parsing {}: {e:?}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .map_err(|e| anyhow!("compiling {name}: {e:?}"))?;
        self.exes.insert(name.to_string(), Executable { spec, exe });
        Ok(())
    }

    pub fn run(&self, name: &str, inputs: &[HostTensor]) -> Result<Vec<HostTensor>> {
        self.exes
            .get(name)
            .ok_or_else(|| anyhow!("executable '{name}' not compiled"))?
            .run(inputs)
    }

    /// Borrow-based hot-path variant (no input cloning).
    pub fn run_refs(&self, name: &str, inputs: &[&HostTensor]) -> Result<Vec<HostTensor>> {
        self.exes
            .get(name)
            .ok_or_else(|| anyhow!("executable '{name}' not compiled"))?
            .run_refs(inputs)
    }

    /// Pre-converted-literal hot path (cached parameter uploads).
    pub fn run_literal_refs(&self, name: &str, args: &[&xla::Literal]) -> Result<Vec<HostTensor>> {
        self.exes
            .get(name)
            .ok_or_else(|| anyhow!("executable '{name}' not compiled"))?
            .run_literal_refs(args)
    }

    pub fn compiled(&self) -> Vec<&str> {
        self.exes.keys().map(|s| s.as_str()).collect()
    }
}

/// Names of the executables a given stage worker needs, given the bucket
/// set: every stage runs stage_fwd/bwd; the first adds embed, the last
/// adds head; everyone gets its optimizer step(s).
pub fn stage_exe_names(stage: usize, num_stages: usize, buckets: &[usize]) -> Vec<String> {
    let mut names = Vec::new();
    for &s in buckets {
        names.push(format!("stage_fwd_s{s}"));
        names.push(format!("stage_bwd_s{s}"));
        if stage == 0 {
            names.push(format!("embed_fwd_s{s}"));
            names.push(format!("embed_bwd_s{s}"));
        }
        if stage == num_stages - 1 {
            names.push(format!("head_fwd_s{s}"));
            names.push(format!("head_bwd_s{s}"));
        }
    }
    names.push("adam_stage".into());
    if stage == 0 {
        names.push("adam_embed".into());
    }
    if stage == num_stages - 1 {
        names.push("adam_head".into());
    }
    names
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stage_exe_names_cover_roles() {
        let names = stage_exe_names(0, 2, &[16, 32]);
        assert!(names.contains(&"embed_fwd_s16".to_string()));
        assert!(names.contains(&"adam_embed".to_string()));
        assert!(!names.contains(&"head_fwd_s16".to_string()));
        let last = stage_exe_names(1, 2, &[16, 32]);
        assert!(last.contains(&"head_bwd_s32".to_string()));
        assert!(last.contains(&"adam_head".to_string()));
        assert!(!last.contains(&"embed_fwd_s16".to_string()));
        // single-stage pipelines get both roles
        let solo = stage_exe_names(0, 1, &[16]);
        assert!(solo.contains(&"embed_fwd_s16".to_string()));
        assert!(solo.contains(&"head_fwd_s16".to_string()));
    }
}
