//! Host-side tensors exchanged between stage workers (and, with the
//! `pjrt` feature, with PJRT).
//!
//! The coordinator moves activations/gradients between OS threads as plain
//! `Vec<f32>`/`Vec<i32>` with explicit shapes; [`HostTensor`] provides the
//! strided copies the KV-buffer bookkeeping needs (writing a slice's K/V
//! into the padded context buffer at `ctx_len`, reading a slice's
//! accumulated context gradients back out) and — behind `pjrt` — converts
//! to/from `xla::Literal` at the PJRT boundary.

#[cfg(feature = "pjrt")]
use anyhow::{bail, Context, Result};

/// Element payload.
#[derive(Debug, Clone, PartialEq)]
pub enum Data {
    F32(Vec<f32>),
    I32(Vec<i32>),
}

/// A dense row-major host tensor.
#[derive(Debug, Clone, PartialEq)]
pub struct HostTensor {
    pub shape: Vec<usize>,
    pub data: Data,
}

impl HostTensor {
    pub fn zeros_f32(shape: &[usize]) -> Self {
        HostTensor {
            shape: shape.to_vec(),
            data: Data::F32(vec![0.0; shape.iter().product()]),
        }
    }

    pub fn f32(shape: &[usize], data: Vec<f32>) -> Self {
        assert_eq!(shape.iter().product::<usize>(), data.len());
        HostTensor {
            shape: shape.to_vec(),
            data: Data::F32(data),
        }
    }

    pub fn i32(shape: &[usize], data: Vec<i32>) -> Self {
        assert_eq!(shape.iter().product::<usize>(), data.len());
        HostTensor {
            shape: shape.to_vec(),
            data: Data::I32(data),
        }
    }

    pub fn scalar_i32(v: i32) -> Self {
        HostTensor {
            shape: vec![],
            data: Data::I32(vec![v]),
        }
    }

    pub fn scalar_f32(v: f32) -> Self {
        HostTensor {
            shape: vec![],
            data: Data::F32(vec![v]),
        }
    }

    pub fn len(&self) -> usize {
        self.shape.iter().product()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn as_f32(&self) -> &[f32] {
        match &self.data {
            Data::F32(v) => v,
            _ => panic!("expected f32 tensor"),
        }
    }

    pub fn as_f32_mut(&mut self) -> &mut [f32] {
        match &mut self.data {
            Data::F32(v) => v,
            _ => panic!("expected f32 tensor"),
        }
    }

    pub fn as_i32(&self) -> &[i32] {
        match &self.data {
            Data::I32(v) => v,
            _ => panic!("expected i32 tensor"),
        }
    }

    pub fn dtype_name(&self) -> &'static str {
        match self.data {
            Data::F32(_) => "float32",
            Data::I32(_) => "int32",
        }
    }

    /// In-place elementwise add (gradient accumulation).
    pub fn add_assign(&mut self, other: &HostTensor) {
        assert_eq!(self.shape, other.shape, "add_assign shape mismatch");
        let dst = self.as_f32_mut();
        let src = other.as_f32();
        for (d, s) in dst.iter_mut().zip(src) {
            *d += s;
        }
    }

    pub fn fill_zero(&mut self) {
        match &mut self.data {
            Data::F32(v) => v.iter_mut().for_each(|x| *x = 0.0),
            Data::I32(v) => v.iter_mut().for_each(|x| *x = 0),
        }
    }

    /// Max |x| — used by tests and grad-norm telemetry.
    pub fn max_abs(&self) -> f32 {
        self.as_f32().iter().fold(0.0f32, |m, x| m.max(x.abs()))
    }

    // ---- PJRT boundary ----

    #[cfg(feature = "pjrt")]
    pub fn to_literal(&self) -> Result<xla::Literal> {
        let dims: Vec<i64> = self.shape.iter().map(|&d| d as i64).collect();
        let lit = match &self.data {
            Data::F32(v) => xla::Literal::vec1(v).reshape(&dims)?,
            Data::I32(v) => xla::Literal::vec1(v).reshape(&dims)?,
        };
        Ok(lit)
    }

    #[cfg(feature = "pjrt")]
    pub fn from_literal(lit: &xla::Literal) -> Result<HostTensor> {
        let shape = lit.array_shape().context("literal has no array shape")?;
        let dims: Vec<usize> = shape.dims().iter().map(|&d| d as usize).collect();
        let t = match shape.ty() {
            xla::ElementType::F32 => HostTensor {
                shape: dims,
                data: Data::F32(lit.to_vec::<f32>()?),
            },
            xla::ElementType::S32 => HostTensor {
                shape: dims,
                data: Data::I32(lit.to_vec::<i32>()?),
            },
            other => bail!("unsupported literal element type {other:?}"),
        };
        Ok(t)
    }

    // ---- KV-buffer strided copies ----
    //
    // KV tensors are [NL, B, T, NH, HD]; flattening (NL·B) = outer and
    // (NH·HD) = inner gives a canonical (outer, T, inner) view used below.

    /// View helper: split `shape` at `axis` into (outer, axis_len, inner).
    fn axis_view(shape: &[usize], axis: usize) -> (usize, usize, usize) {
        let outer: usize = shape[..axis].iter().product();
        let inner: usize = shape[axis + 1..].iter().product();
        (outer, shape[axis], inner)
    }

    /// Write `src` (same shape except `axis` where `src` is shorter) into
    /// `self` starting at `offset` along `axis` — the coordinator's
    /// "scatter this slice's K/V at ctx_len".
    pub fn write_at_axis(&mut self, axis: usize, offset: usize, src: &HostTensor) {
        assert_eq!(self.shape.len(), src.shape.len());
        for (d, (a, b)) in self.shape.iter().zip(&src.shape).enumerate() {
            if d != axis {
                assert_eq!(a, b, "dim {d} mismatch");
            }
        }
        let (outer, t_dst, inner) = Self::axis_view(&self.shape, axis);
        let (_, t_src, _) = Self::axis_view(&src.shape, axis);
        assert!(offset + t_src <= t_dst, "write past axis end");
        let dst = self.as_f32_mut();
        let s = src.as_f32();
        for o in 0..outer {
            let dst_base = (o * t_dst + offset) * inner;
            let src_base = o * t_src * inner;
            dst[dst_base..dst_base + t_src * inner]
                .copy_from_slice(&s[src_base..src_base + t_src * inner]);
        }
    }

    /// Read `len` entries along `axis` starting at `offset` — the
    /// coordinator's "gather this slice's accumulated context grads".
    pub fn read_at_axis(&self, axis: usize, offset: usize, len: usize) -> HostTensor {
        let (outer, t_src, inner) = Self::axis_view(&self.shape, axis);
        assert!(offset + len <= t_src, "read past axis end");
        let src = self.as_f32();
        let mut out = vec![0.0f32; outer * len * inner];
        for o in 0..outer {
            let src_base = (o * t_src + offset) * inner;
            let dst_base = o * len * inner;
            out[dst_base..dst_base + len * inner]
                .copy_from_slice(&src[src_base..src_base + len * inner]);
        }
        let mut shape = self.shape.clone();
        shape[axis] = len;
        HostTensor {
            shape,
            data: Data::F32(out),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn write_then_read_roundtrip_on_axis2() {
        // [NL=2, B=1, T=4, NH=1, HD=3] buffer; write a 2-long slice at 1
        let mut buf = HostTensor::zeros_f32(&[2, 1, 4, 1, 3]);
        let src = HostTensor::f32(&[2, 1, 2, 1, 3], (0..12).map(|x| x as f32).collect());
        buf.write_at_axis(2, 1, &src);
        let back = buf.read_at_axis(2, 1, 2);
        assert_eq!(back, src);
        // untouched positions stay zero
        let head = buf.read_at_axis(2, 0, 1);
        assert!(head.as_f32().iter().all(|&x| x == 0.0));
        let tail = buf.read_at_axis(2, 3, 1);
        assert!(tail.as_f32().iter().all(|&x| x == 0.0));
    }

    #[test]
    fn write_at_axis_places_rows_correctly() {
        let mut buf = HostTensor::zeros_f32(&[1, 1, 3, 1, 2]);
        let src = HostTensor::f32(&[1, 1, 1, 1, 2], vec![7.0, 8.0]);
        buf.write_at_axis(2, 2, &src);
        assert_eq!(buf.as_f32(), &[0., 0., 0., 0., 7., 8.]);
    }

    #[test]
    fn add_assign_accumulates() {
        let mut a = HostTensor::f32(&[2, 2], vec![1., 2., 3., 4.]);
        let b = HostTensor::f32(&[2, 2], vec![0.5; 4]);
        a.add_assign(&b);
        assert_eq!(a.as_f32(), &[1.5, 2.5, 3.5, 4.5]);
        a.fill_zero();
        assert_eq!(a.as_f32(), &[0.0; 4]);
    }

    #[test]
    #[should_panic(expected = "write past axis end")]
    fn write_past_end_panics() {
        let mut buf = HostTensor::zeros_f32(&[1, 1, 3, 1, 2]);
        let src = HostTensor::f32(&[1, 1, 2, 1, 2], vec![0.0; 4]);
        buf.write_at_axis(2, 2, &src);
    }

    #[test]
    fn scalar_shapes() {
        assert_eq!(HostTensor::scalar_i32(5).shape, Vec::<usize>::new());
        assert_eq!(HostTensor::scalar_f32(1.5).len(), 1);
    }

    #[test]
    fn max_abs_works() {
        let t = HostTensor::f32(&[3], vec![-2.5, 1.0, 2.0]);
        assert_eq!(t.max_abs(), 2.5);
    }
}
