//! The driver: spawns stage workers, streams token slices into the
//! pipeline, collects losses and timing samples, and coordinates
//! optimizer updates. Generic over the stage backend via
//! [`BackendSpec`], and over the message fabric via
//! [`transport::Transport`] — in-process channels by default, the
//! deterministic virtual network for fault injection.
//!
//! Every driver collect loop (step, update, checkpoint) is bounded by
//! `TrainConfig::recv_timeout_ms`, an *inactivity* deadline: any
//! arrival resets it, so slow-but-alive pipelines are never killed,
//! while a dead stage or a dropped message fails the step with a
//! progress diagnostic instead of hanging `recv()` forever.

use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::{anyhow, bail, Result};

use super::messages::{DriverMsg, FwdPayload, Msg, SliceTime, TimedPhase};
use super::transport::{DriverRecv, DriverRx, Fabric, InProcTransport, MsgTx, Transport};
use super::worker::{run_worker, WorkerCfg};
use super::TrainConfig;
use crate::backend::BackendSpec;
use crate::data::Batch;
use crate::obs::anomaly::{AnomalyDetector, Cause, Detection};
use crate::obs::health::{HealthMonitor, HealthTimeline};
use crate::perfmodel::{CostModel, ScaledModel};
use crate::planner::drift::{DriftConfig, DriftDetector, DriftVerdict, LatencySample};
use crate::runtime::manifest::ModelDims;

/// Per-step telemetry.
#[derive(Debug, Clone)]
pub struct StepReport {
    pub step: usize,
    /// Mean per-token cross-entropy (nats).
    pub loss: f64,
    pub wall_ms: f64,
    /// Wall time from step start until the last slice's loss arrived —
    /// the executed forward-sweep makespan the wavefront model predicts.
    pub fwd_ms: f64,
    /// Wall time from step start until the last backward ack — the full
    /// fwd+bwd pipeline makespan.
    pub pipe_ms: f64,
    /// Tokens processed this step (microbatches · batch · L).
    pub tokens: usize,
    /// Per-stage compute busy time this step (ms; empty unless timing
    /// collection is on — `cfg.trace` or a replan cadence).
    pub stage_busy_ms: Vec<f64>,
    /// Measured bubble fraction `1 - Σ busy / (stages · pipe_ms)`;
    /// `None` without timing collection.
    pub bubble_fraction: Option<f64>,
    /// Per-stage health verdict codes after this step
    /// ([`crate::obs::health::HealthState`]: 0 healthy, 1 suspect,
    /// 2 unhealthy) — the monitor runs every step, so this is always
    /// `num_stages` long.
    pub stage_health: Vec<u8>,
}

/// What one [`Trainer::step`] returns: the scalars a driver loop needs,
/// before they're folded into a [`StepReport`].
#[derive(Debug, Clone)]
pub struct StepStats {
    /// Mean per-token cross-entropy (nats).
    pub loss: f64,
    /// Tokens processed (microbatches · batch · L).
    pub tokens: usize,
    /// Forward-sweep makespan (ms).
    pub fwd_ms: f64,
    /// Full fwd+bwd pipeline makespan (ms).
    pub pipe_ms: f64,
    /// Per-stage busy time (ms; empty without timing collection).
    pub stage_busy_ms: Vec<f64>,
}

impl StepStats {
    /// Measured bubble fraction over the pipeline window, when per-stage
    /// busy time was collected.
    pub fn bubble_fraction(&self) -> Option<f64> {
        if self.stage_busy_ms.is_empty() || self.pipe_ms <= 0.0 {
            return None;
        }
        let busy: f64 = self.stage_busy_ms.iter().sum();
        Some((1.0 - busy / (self.stage_busy_ms.len() as f64 * self.pipe_ms)).clamp(0.0, 1.0))
    }
}

/// Outcome of the drift-gated replan loop ([`Trainer::train_with_drift_replan`]).
#[derive(Debug, Clone, Default)]
pub struct DriftReplanReport {
    /// Replan-cadence checks whose window verdict was `Drifted` (each
    /// triggers exactly one `resolve` call).
    pub resolves: usize,
    /// Replan-cadence checks whose window verdict was `Stable` (no
    /// re-solve paid — the point of routing samples through the detector).
    pub stable_checks: usize,
    /// Cadence checks skipped because the sample window wasn't full yet.
    pub warmups: usize,
    /// Latency samples fed to the detector.
    pub samples_seen: usize,
    /// Named-cause detections the anomaly attributor emitted during the
    /// run (compute straggler / comm degradation / global slowdown) —
    /// the typed evidence a planner can consume beyond the scalar drift
    /// verdict. The detections themselves stay buffered on the trainer
    /// ([`Trainer::take_anomalies`]).
    pub named_causes: usize,
}

/// A running pipeline: workers + transport endpoints.
pub struct Trainer<S: BackendSpec> {
    pub model: ModelDims,
    /// Slice lengths the backend supports (the planner's bucket set).
    pub buckets: Vec<usize>,
    cfg: TrainConfig,
    /// Global step counter (continues across checkpoint resume).
    steps_done: usize,
    /// Driver→stage senders, one per stage (stage 0 takes the slices).
    to_all: Vec<Box<dyn MsgTx>>,
    from_workers: Box<dyn DriverRx>,
    handles: Vec<JoinHandle<()>>,
    /// Per-slice timing samples collected during the most recent step.
    timings: Vec<SliceTime>,
    /// Per-stage liveness + latency state machines, fed by every driver
    /// arrival (including heartbeats) and by recv-probe silence.
    health: HealthMonitor,
    /// Rolling robust-statistics attributor over per-slice timings.
    anomaly: AnomalyDetector,
    /// Detections accumulated across steps; drained by
    /// [`Trainer::take_anomalies`].
    anomalies: Vec<Detection>,
}

/// How many health probes the driver schedules across one recv deadline:
/// a stage silent for a full `recv_timeout_ms / IDLE_PROBES` sub-interval
/// accrues one liveness miss, so with the default thresholds a dead
/// stage walks Healthy → Suspect → Unhealthy *before* the deadline
/// finally fails the step.
const IDLE_PROBES: u32 = 4;

impl<S: BackendSpec> Trainer<S> {
    /// Spawn one worker thread per stage, each building its own backend
    /// from `spec` on its own thread. In-process transport.
    pub fn with_spec(spec: S, cfg: TrainConfig) -> Result<Trainer<S>> {
        Self::with_spec_resume(spec, cfg, None)
    }

    /// Like [`Trainer::with_spec`] but loading parameters from a
    /// checkpoint dir written by [`Trainer::save_checkpoint`].
    pub fn with_spec_resume(
        spec: S,
        cfg: TrainConfig,
        resume_from: Option<PathBuf>,
    ) -> Result<Trainer<S>> {
        Self::with_spec_transport_resume(spec, cfg, &InProcTransport, resume_from)
    }

    /// Like [`Trainer::with_spec`], over an explicit transport — e.g. a
    /// [`super::transport::VirtualTransport`] for deterministic fault
    /// injection.
    pub fn with_spec_transport<T: Transport>(
        spec: S,
        cfg: TrainConfig,
        transport: &T,
    ) -> Result<Trainer<S>> {
        Self::with_spec_transport_resume(spec, cfg, transport, None)
    }

    /// The fully general constructor: backend spec × transport × resume.
    pub fn with_spec_transport_resume<T: Transport>(
        spec: S,
        cfg: TrainConfig,
        transport: &T,
        resume_from: Option<PathBuf>,
    ) -> Result<Trainer<S>> {
        let model = spec.model();
        let buckets = spec.buckets();
        cfg.validate(model.seq_len, &buckets)?;
        let k = model.num_stages;
        let timings = cfg.trace || cfg.replan_every.is_some();

        let Fabric { to_stages, from_workers, stages } = transport.connect(k);
        if to_stages.len() != k || stages.len() != k {
            bail!("transport wired {} stages, model has {k}", stages.len());
        }
        let mut handles = Vec::with_capacity(k);
        for (stage, endpoint) in stages.into_iter().enumerate() {
            let cfg_w = WorkerCfg {
                stage,
                num_stages: k,
                spec: spec.clone(),
                resume_from: resume_from.clone(),
                timings,
                heartbeat_ms: cfg.heartbeat_ms,
                endpoint,
            };
            handles.push(
                std::thread::Builder::new()
                    .name(format!("terapipe-stage-{stage}"))
                    .spawn(move || run_worker(cfg_w))?,
            );
        }

        let steps_done = resume_from
            .as_ref()
            .and_then(|d| std::fs::read_to_string(d.join("meta.json")).ok())
            .and_then(|t| crate::util::json::Json::parse(&t).ok())
            .and_then(|v| v.get("next_step").and_then(|s| s.as_usize()))
            .unwrap_or(0);

        Ok(Trainer {
            health: HealthMonitor::new(model.num_stages),
            anomaly: AnomalyDetector::new(),
            anomalies: Vec::new(),
            model,
            buckets,
            cfg,
            steps_done,
            to_all: to_stages,
            from_workers,
            handles,
            timings: Vec::new(),
        })
    }

    /// One deadline-bounded driver receive. `progress` renders the
    /// collect loop's state into the diagnostic (only on failure).
    ///
    /// Every arrival marks its source stage alive for the health
    /// monitor. Heartbeats are consumed here — they feed the monitor
    /// but are never surfaced to collect loops and do NOT reset the
    /// deadline, so a dead peer still trips it while healthy stages
    /// keep beating. The deadline is split into [`IDLE_PROBES`]
    /// sub-intervals; each silent sub-interval charges a liveness miss
    /// to every stage unseen since the last probe.
    fn recv_driver(&mut self, phase: &str, progress: impl FnOnce() -> String) -> Result<DriverMsg> {
        let k = self.model.num_stages;
        match self.cfg.recv_timeout_ms {
            None => loop {
                match self.from_workers.recv() {
                    Ok(DriverMsg::Heartbeat { stage }) => self.health.on_arrival(stage),
                    Ok(m) => {
                        self.health.on_arrival(m.source_stage(k));
                        return Ok(m);
                    }
                    Err(_) => bail!("all workers hung up during {phase} ({})", progress()),
                }
            },
            Some(ms) => {
                let start = Instant::now();
                let deadline = start + Duration::from_millis(ms);
                let probe = Duration::from_millis((ms / IDLE_PROBES as u64).max(1));
                // Probe boundaries are *absolute* ticks within this
                // deadline — heartbeat arrivals must not push them back,
                // or a steadily-beating stage would mask a dead peer's
                // silence forever.
                let mut next_probe = start + probe;
                loop {
                    let now = Instant::now();
                    if now >= deadline {
                        bail!(
                            "no driver message for {ms} ms during {phase}: a stage is dead, \
                             wedged, or a message was dropped ({})",
                            progress()
                        );
                    }
                    let target = next_probe.min(deadline);
                    match self.from_workers.recv_timeout(target.saturating_duration_since(now)) {
                        DriverRecv::Msg(DriverMsg::Heartbeat { stage }) => {
                            self.health.on_arrival(stage);
                        }
                        DriverRecv::Msg(m) => {
                            self.health.on_arrival(m.source_stage(k));
                            return Ok(m);
                        }
                        DriverRecv::Disconnected => {
                            bail!("all workers hung up during {phase} ({})", progress())
                        }
                        DriverRecv::TimedOut => {
                            self.health.probe_tick();
                            next_probe += probe;
                        }
                    }
                }
            }
        }
    }

    /// Fold one live slice sample into the timing buffer and both
    /// observers (latency-track health evidence + anomaly windows).
    fn note_slice_time(&mut self, t: SliceTime) {
        self.health.observe_slice_ms(t.stage, t.ms);
        let phase = match t.phase {
            TimedPhase::Fwd => 0u8,
            TimedPhase::Bwd => 1u8,
        };
        self.anomaly.observe_slice(t.stage, t.slice as u32, phase, t.ms);
        self.timings.push(t);
    }

    /// One synchronous training step over `microbatches` batches.
    pub fn step(&mut self, batches: &[Batch]) -> Result<StepStats> {
        assert_eq!(batches.len(), self.cfg.microbatches);
        let offs = self.cfg.offsets();
        let num_slices = self.cfg.slicing.len();
        let lr = self.cfg.lr;
        self.timings.clear();
        let step_id = (self.steps_done + 1) as u64;
        self.health.begin_step(step_id);
        let t0 = Instant::now();

        // ---- stream forward slices into the pipe ----
        for (mb, batch) in batches.iter().enumerate() {
            assert_eq!(batch.batch, self.model.batch);
            assert_eq!(batch.seq_len, self.model.seq_len);
            for (i, (&len, &off)) in self.cfg.slicing.iter().zip(&offs).enumerate() {
                let mut tokens = Vec::with_capacity(self.model.batch * len);
                let mut targets = Vec::with_capacity(self.model.batch * len);
                for b in 0..self.model.batch {
                    let row = b * self.model.seq_len + off;
                    tokens.extend_from_slice(&batch.tokens[row..row + len]);
                    targets.extend_from_slice(&batch.targets[row..row + len]);
                }
                self.to_all[0]
                    .send(Msg::Fwd {
                        mb,
                        slice: i,
                        off,
                        len,
                        last: i == num_slices - 1,
                        payload: FwdPayload::Tokens(tokens),
                        targets,
                    })
                    .map_err(|_| anyhow!("pipeline stage 0 is down"))?;
            }
        }

        // ---- collect losses and backward completions ----
        let expected = self.cfg.microbatches * num_slices;
        let mut losses = 0f64;
        let mut loss_cnt = 0usize;
        let mut bwd_done = 0usize;
        let mut fwd_ms = 0f64;
        while loss_cnt < expected || bwd_done < expected {
            let msg = self.recv_driver("step", || {
                format!("{loss_cnt}/{expected} losses, {bwd_done}/{expected} backward acks")
            })?;
            match msg {
                DriverMsg::Loss { loss_sum, .. } => {
                    losses += loss_sum as f64;
                    loss_cnt += 1;
                    if loss_cnt == expected {
                        fwd_ms = t0.elapsed().as_secs_f64() * 1e3;
                    }
                }
                DriverMsg::BwdDone { .. } => bwd_done += 1,
                DriverMsg::SliceTime(t) => self.note_slice_time(t),
                DriverMsg::Fatal { stage, error } => {
                    self.health.on_fatal(stage);
                    bail!("stage {stage} failed: {error}")
                }
                other => bail!("unexpected {other:?} mid-step"),
            }
        }
        let pipe_ms = t0.elapsed().as_secs_f64() * 1e3;

        // ---- optimizer update on every stage ----
        let global_step = self.steps_done + 1; // 1-based Adam bias correction
        for tx in &self.to_all {
            tx.send(Msg::Update {
                step: global_step as i32,
                lr,
            })
            .map_err(|_| anyhow!("worker hung up before update"))?;
        }
        let expected_updates = self.to_all.len();
        let mut updates = 0;
        while updates < expected_updates {
            let msg = self
                .recv_driver("update", || format!("{updates}/{expected_updates} update acks"))?;
            match msg {
                DriverMsg::UpdateDone { .. } => updates += 1,
                DriverMsg::SliceTime(t) => self.note_slice_time(t),
                DriverMsg::Fatal { stage, error } => {
                    self.health.on_fatal(stage);
                    bail!("stage {stage} failed: {error}")
                }
                _ => bail!("unexpected message during update"),
            }
        }

        self.steps_done += 1;

        // ---- close out the step's health + anomaly bookkeeping ----
        self.health.end_step(step_id);
        for d in self.anomaly.end_step(step_id) {
            let stage = match d.cause {
                Cause::ComputeStraggler { stage, .. } => stage as i32,
                _ => crate::obs::DRIVER,
            };
            crate::obs::instant(
                crate::obs::SpanKind::Anomaly,
                stage,
                d.cause.code() as u64,
                d.cause.factor().to_bits(),
            );
            eprintln!(
                "anomaly at step {}: {} (factor {:.2}x)",
                d.step,
                d.cause.name(),
                d.cause.factor()
            );
            self.anomalies.push(d);
        }

        let tokens = self.cfg.microbatches * self.model.batch * self.model.seq_len;
        // Per-stage busy time from this step's slice samples. The update
        // collect loop above may have appended post-step samples too;
        // all of them belong to this step (timings cleared at entry).
        let stage_busy_ms = if self.timings.is_empty() {
            Vec::new()
        } else {
            let mut busy = vec![0.0f64; self.model.num_stages];
            for t in &self.timings {
                if t.stage < busy.len() {
                    busy[t.stage] += t.ms;
                }
            }
            busy
        };
        Ok(StepStats { loss: losses / tokens as f64, tokens, fwd_ms, pipe_ms, stage_busy_ms })
    }

    /// Per-slice wall-clock samples from the most recent step (empty
    /// unless `cfg.trace` or a replan cadence enabled collection).
    pub fn last_timings(&self) -> &[SliceTime] {
        &self.timings
    }

    /// The driver-side health monitor (read-only view).
    pub fn health(&self) -> &HealthMonitor {
        &self.health
    }

    /// Every health transition recorded so far, in order.
    pub fn health_timeline(&self) -> &HealthTimeline {
        self.health.timeline()
    }

    /// Drain the anomaly detections accumulated since the last call
    /// (oldest first). Each maps onto a typed planner event via
    /// [`crate::obs::anomaly::Detection::to_event`].
    pub fn take_anomalies(&mut self) -> Vec<Detection> {
        std::mem::take(&mut self.anomalies)
    }

    /// Feed per-link delivery evidence into the anomaly attributor's
    /// comm windows. The trainer only sees channel endpoints, so the
    /// transport's owner bridges the evidence across — e.g. draining
    /// [`super::transport::VirtualTransport::take_deliveries`] between
    /// steps (a future TCP transport's stats thread fits the same
    /// seam). Link keys are [`super::transport::LinkId::index`] values.
    pub fn observe_deliveries(
        &mut self,
        deliveries: &[(super::transport::LinkId, Vec<super::transport::DeliverySample>)],
    ) {
        let k = self.model.num_stages;
        for (link, samples) in deliveries {
            let idx = link.index(k);
            for s in samples {
                self.anomaly.observe_link(idx, s.delay_ms);
            }
        }
    }

    /// Drive `cfg.steps` steps pulling microbatches from `next_batch`.
    pub fn train(
        &mut self,
        next_batch: impl FnMut() -> Batch,
        on_step: impl FnMut(&StepReport),
    ) -> Result<Vec<StepReport>> {
        self.train_with_replan(next_batch, on_step, |_| None)
    }

    fn run_one_step(
        &mut self,
        step: usize,
        next_batch: &mut impl FnMut() -> Batch,
    ) -> Result<StepReport> {
        let batches: Vec<Batch> = (0..self.cfg.microbatches).map(|_| next_batch()).collect();
        let t0 = Instant::now();
        let stats = self.step(&batches)?;
        Ok(StepReport {
            step,
            loss: stats.loss,
            wall_ms: t0.elapsed().as_secs_f64() * 1e3,
            fwd_ms: stats.fwd_ms,
            pipe_ms: stats.pipe_ms,
            tokens: stats.tokens,
            bubble_fraction: stats.bubble_fraction(),
            stage_busy_ms: stats.stage_busy_ms,
            stage_health: self.health.codes(),
        })
    }

    /// Adopt `slicing` if it validates against the model geometry and
    /// bucket set; report and keep the current slicing otherwise, so a
    /// bad replan can never kill a long training run.
    fn try_adopt_slicing(&mut self, step: usize, slicing: Vec<usize>) {
        let mut cand = self.cfg.clone();
        cand.slicing = slicing;
        match cand.validate(self.model.seq_len, &self.buckets) {
            Ok(()) => {
                if cand.slicing != self.cfg.slicing {
                    eprintln!(
                        "replan at step {step}: slicing {:?} -> {:?}",
                        self.cfg.slicing, cand.slicing
                    );
                    crate::obs::instant(crate::obs::SpanKind::PlanSwitch, crate::obs::DRIVER, step as u64, 0);
                }
                self.cfg = cand;
            }
            Err(e) => eprintln!("replan at step {step} rejected: {e}"),
        }
    }

    /// Like [`Trainer::train`], with the online planner in the loop: when
    /// `cfg.replan_every = Some(n)`, `replan(step)` is invoked every `n`
    /// steps (before the step runs) and may return a new slicing — e.g.
    /// from a fresh measure → fit → bucketed-DP solve, or a
    /// `crate::planner::Planner` decision. A returned slicing is adopted
    /// only if it validates against the bucket set.
    pub fn train_with_replan(
        &mut self,
        mut next_batch: impl FnMut() -> Batch,
        mut on_step: impl FnMut(&StepReport),
        mut replan: impl FnMut(usize) -> Option<Vec<usize>>,
    ) -> Result<Vec<StepReport>> {
        let steps = self.cfg.steps;
        let mut reports = Vec::with_capacity(steps);
        for step in 0..steps {
            if let Some(n) = self.cfg.replan_every {
                if step > 0 && step % n == 0 {
                    if let Some(slicing) = replan(step) {
                        self.try_adopt_slicing(step, slicing);
                    }
                }
            }
            let rep = self.run_one_step(step, &mut next_batch)?;
            on_step(&rep);
            reports.push(rep);
        }
        Ok(reports)
    }

    /// The drift-aware replan loop (ROADMAP "planner on the real
    /// runtime"): live per-slice samples from the executing pipeline
    /// stream into a [`DriftDetector`] judged against `solved_against`
    /// (the model the active slicing was solved on). On the
    /// `replan_every` cadence the trainer consults the window verdict and
    /// pays for `resolve` — a re-measure/re-solve — **only when the
    /// samples say the model drifted**; drift-free steps trigger zero
    /// re-solves. A detected drift folds the fitted rescale factor into
    /// the solved-against model (the same `ScaledModel` representation
    /// the planner service uses), so repeated verdicts judge against the
    /// updated belief.
    ///
    /// Samples are taken from stage 0 (every pipeline has one), as
    /// combined fwd+bwd latency per slice — the [`CostModel`] unit. Note
    /// stage 0's samples include the embedding, which the measurement
    /// harness's middle-cell model does not; that constant offset is one
    /// reason the drift threshold should stay comfortably above fit
    /// error (the CLI defaults to 0.35).
    pub fn train_with_drift_replan<M: CostModel>(
        &mut self,
        mut next_batch: impl FnMut() -> Batch,
        mut on_step: impl FnMut(&StepReport),
        solved_against: M,
        drift_cfg: DriftConfig,
        mut resolve: impl FnMut(usize, f64) -> Option<Vec<usize>>,
    ) -> Result<(Vec<StepReport>, DriftReplanReport)> {
        let steps = self.cfg.steps;
        let cadence = self.cfg.replan_every;
        let anomalies_at_entry = self.anomalies.len();
        let mut detector = DriftDetector::new(drift_cfg);
        let mut scale = 1.0f64;
        let mut report = DriftReplanReport::default();
        let mut reports = Vec::with_capacity(steps);
        for step in 0..steps {
            if let Some(n) = cadence {
                if step > 0 && step % n == 0 {
                    let current = ScaledModel {
                        inner: &solved_against,
                        compute: scale,
                        comm: scale,
                    };
                    match detector.verdict(&current) {
                        DriftVerdict::Warmup => {
                            report.warmups += 1;
                            crate::obs::instant(crate::obs::SpanKind::DriftVerdict, crate::obs::DRIVER, 0, 0);
                        }
                        DriftVerdict::Stable { mean_rel_err } => {
                            report.stable_checks += 1;
                            crate::obs::instant(
                                crate::obs::SpanKind::DriftVerdict,
                                crate::obs::DRIVER,
                                1,
                                mean_rel_err.to_bits(),
                            );
                        }
                        DriftVerdict::Drifted { factor, mean_rel_err } => {
                            report.resolves += 1;
                            crate::obs::instant(
                                crate::obs::SpanKind::DriftVerdict,
                                crate::obs::DRIVER,
                                2,
                                mean_rel_err.to_bits(),
                            );
                            scale *= factor;
                            if let Some(slicing) = resolve(step, factor) {
                                self.try_adopt_slicing(step, slicing);
                            }
                            detector.clear();
                        }
                    }
                }
            }
            let rep = self.run_one_step(step, &mut next_batch)?;
            // fold this step's stage-0 samples into the window: one
            // combined fwd+bwd latency per (mb, slice), paired through a
            // single-pass map instead of a per-sample linear scan
            let mut bwd_ms: HashMap<(usize, usize), f64> = HashMap::new();
            for t in &self.timings {
                if t.stage == 0 && t.phase == TimedPhase::Bwd {
                    bwd_ms.insert((t.mb, t.slice), t.ms);
                }
            }
            for t in &self.timings {
                if t.stage == 0 && t.phase == TimedPhase::Fwd {
                    let bwd = bwd_ms.get(&(t.mb, t.slice)).copied().unwrap_or(0.0);
                    detector.push(LatencySample {
                        i: t.len as u32,
                        j: t.off as u32,
                        ms: t.ms + bwd,
                    });
                    report.samples_seen += 1;
                }
            }
            on_step(&rep);
            reports.push(rep);
        }
        report.named_causes = self.anomalies.len() - anomalies_at_entry;
        Ok((reports, report))
    }

    pub fn config(&self) -> &TrainConfig {
        &self.cfg
    }

    /// Persist all stages' parameters under `dir` (init-file layout; load
    /// with [`Trainer::with_spec_resume`]).
    pub fn save_checkpoint(&mut self, dir: &Path) -> Result<()> {
        std::fs::create_dir_all(dir)?;
        std::fs::write(
            dir.join("meta.json"),
            crate::util::json::Json::obj(vec![("next_step", self.steps_done.into())]).to_string(),
        )?;
        for tx in &self.to_all {
            tx.send(Msg::Checkpoint { dir: dir.to_path_buf() })
                .map_err(|_| anyhow!("worker hung up before checkpoint"))?;
        }
        let expected = self.to_all.len();
        let mut done = 0;
        while done < expected {
            let msg =
                self.recv_driver("checkpoint", || format!("{done}/{expected} checkpoint acks"))?;
            match msg {
                DriverMsg::CheckpointDone { .. } => done += 1,
                DriverMsg::SliceTime(t) => self.note_slice_time(t),
                DriverMsg::Fatal { stage, error } => {
                    self.health.on_fatal(stage);
                    bail!("stage {stage} failed: {error}")
                }
                _ => bail!("unexpected message during checkpoint"),
            }
        }
        Ok(())
    }

    /// Graceful shutdown (also called on drop).
    pub fn shutdown(&mut self) {
        for tx in &self.to_all {
            let _ = tx.send(Msg::Shutdown);
        }
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

impl<S: BackendSpec> Drop for Trainer<S> {
    fn drop(&mut self) {
        self.shutdown();
    }
}

// ---- PJRT-flavored constructors (the original API) ----

#[cfg(feature = "pjrt")]
impl Trainer<crate::backend::PjrtSpec> {
    /// Spawn a PJRT pipeline from an artifact dir (each worker compiles
    /// its own executables on its own PJRT client).
    pub fn new(artifacts: &Path, cfg: TrainConfig) -> Result<Self> {
        Self::new_with_resume(artifacts, cfg, None)
    }

    /// Like [`Trainer::new`] but loading parameters from a checkpoint.
    pub fn new_with_resume(
        artifacts: &Path,
        cfg: TrainConfig,
        resume_from: Option<PathBuf>,
    ) -> Result<Self> {
        let spec = crate::backend::PjrtSpec::new(artifacts)?;
        Self::with_spec_resume(spec, cfg, resume_from)
    }
}

/// Convenience one-call API on the native backend: spawn, train on a
/// batcher, shut down.
pub fn train_native(
    spec: crate::backend::NativeSpec,
    cfg: TrainConfig,
    corpus: &str,
    mut on_step: impl FnMut(&StepReport),
) -> Result<Vec<StepReport>> {
    let seed = cfg.seed;
    let mut trainer = Trainer::with_spec(spec, cfg)?;
    let m = trainer.model.clone();
    let mut batcher = crate::data::Batcher::new(corpus, m.batch, m.seq_len, seed);
    trainer.train(|| batcher.next_batch(), &mut on_step)
}

/// Convenience one-call API on the PJRT backend: spawn, train, shut down.
#[cfg(feature = "pjrt")]
pub fn train(
    artifacts: &Path,
    cfg: TrainConfig,
    corpus: &str,
    mut on_step: impl FnMut(&StepReport),
) -> Result<Vec<StepReport>> {
    let mut trainer = Trainer::new(artifacts, cfg)?;
    let m = trainer.model.clone();
    let seed = trainer.cfg.seed;
    let mut batcher = crate::data::Batcher::new(corpus, m.batch, m.seq_len, seed);
    trainer.train(|| batcher.next_batch(), &mut on_step)
}
