//! The driver: spawns stage workers, streams token slices into the
//! pipeline, collects losses, and coordinates optimizer updates.

use std::path::{Path, PathBuf};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::thread::JoinHandle;
use std::time::Instant;

use anyhow::{anyhow, bail, Result};

use super::messages::{DriverMsg, FwdPayload, Msg};
use super::worker::{run_worker, WorkerCfg};
use super::TrainConfig;
use crate::data::Batch;
use crate::runtime::manifest::Manifest;

/// Per-step telemetry.
#[derive(Debug, Clone)]
pub struct StepReport {
    pub step: usize,
    /// Mean per-token cross-entropy (nats).
    pub loss: f64,
    pub wall_ms: f64,
    /// Tokens processed this step (microbatches · batch · L).
    pub tokens: usize,
}

/// A running pipeline: workers + channel endpoints.
pub struct Trainer {
    pub manifest: Manifest,
    cfg: TrainConfig,
    /// Global step counter (continues across checkpoint resume).
    steps_done: usize,
    to_first: Sender<Msg>,
    to_all: Vec<Sender<Msg>>,
    from_workers: Receiver<DriverMsg>,
    handles: Vec<JoinHandle<()>>,
}

impl Trainer {
    /// Spawn one worker thread per stage (each compiles its own
    /// executables on its own PJRT client).
    pub fn new(artifacts: &Path, cfg: TrainConfig) -> Result<Trainer> {
        Self::new_with_resume(artifacts, cfg, None)
    }

    /// Like [`Trainer::new`] but loading parameters from a checkpoint dir
    /// written by [`Trainer::save_checkpoint`].
    pub fn new_with_resume(
        artifacts: &Path,
        cfg: TrainConfig,
        resume_from: Option<PathBuf>,
    ) -> Result<Trainer> {
        let manifest = Manifest::load(artifacts)?;
        cfg.validate(manifest.model.seq_len, &manifest.buckets)?;
        let k = manifest.model.num_stages;

        let (driver_tx, from_workers) = channel::<DriverMsg>();
        let mut senders: Vec<Sender<Msg>> = Vec::with_capacity(k);
        let mut receivers: Vec<Option<Receiver<Msg>>> = Vec::with_capacity(k);
        for _ in 0..k {
            let (tx, rx) = channel::<Msg>();
            senders.push(tx);
            receivers.push(Some(rx));
        }

        let mut handles = Vec::with_capacity(k);
        for stage in 0..k {
            let cfg_w = WorkerCfg {
                stage,
                num_stages: k,
                artifacts: PathBuf::from(artifacts),
                resume_from: resume_from.clone(),
                inbox: receivers[stage].take().unwrap(),
                next: (stage + 1 < k).then(|| senders[stage + 1].clone()),
                prev: (stage > 0).then(|| senders[stage - 1].clone()),
                driver: driver_tx.clone(),
            };
            handles.push(
                std::thread::Builder::new()
                    .name(format!("terapipe-stage-{stage}"))
                    .spawn(move || run_worker(cfg_w))?,
            );
        }

        let steps_done = resume_from
            .as_ref()
            .and_then(|d| std::fs::read_to_string(d.join("meta.json")).ok())
            .and_then(|t| crate::util::json::Json::parse(&t).ok())
            .and_then(|v| v.get("next_step").and_then(|s| s.as_usize()))
            .unwrap_or(0);

        Ok(Trainer {
            manifest,
            cfg,
            steps_done,
            to_first: senders[0].clone(),
            to_all: senders,
            from_workers,
            handles,
        })
    }

    /// One synchronous training step over `microbatches` batches.
    /// Returns (mean per-token loss, tokens processed).
    pub fn step(&mut self, step_idx: usize, batches: &[Batch]) -> Result<(f64, usize)> {
        let m = &self.manifest.model;
        let cfg = &self.cfg;
        assert_eq!(batches.len(), cfg.microbatches);
        let offs = cfg.offsets();
        let num_slices = cfg.slicing.len();

        // ---- stream forward slices into the pipe ----
        for (mb, batch) in batches.iter().enumerate() {
            assert_eq!(batch.batch, m.batch);
            assert_eq!(batch.seq_len, m.seq_len);
            for (i, (&len, &off)) in cfg.slicing.iter().zip(&offs).enumerate() {
                let mut tokens = Vec::with_capacity(m.batch * len);
                let mut targets = Vec::with_capacity(m.batch * len);
                for b in 0..m.batch {
                    let row = b * m.seq_len + off;
                    tokens.extend_from_slice(&batch.tokens[row..row + len]);
                    targets.extend_from_slice(&batch.targets[row..row + len]);
                }
                self.to_first
                    .send(Msg::Fwd {
                        mb,
                        slice: i,
                        off,
                        len,
                        last: i == num_slices - 1,
                        payload: FwdPayload::Tokens(tokens),
                        targets,
                    })
                    .map_err(|_| anyhow!("pipeline stage 0 is down"))?;
            }
        }

        // ---- collect losses and backward completions ----
        let expected = cfg.microbatches * num_slices;
        let mut losses = 0f64;
        let mut loss_cnt = 0usize;
        let mut bwd_done = 0usize;
        while loss_cnt < expected || bwd_done < expected {
            match self.from_workers.recv() {
                Ok(DriverMsg::Loss { loss_sum, .. }) => {
                    losses += loss_sum as f64;
                    loss_cnt += 1;
                }
                Ok(DriverMsg::BwdDone { .. }) => bwd_done += 1,
                Ok(DriverMsg::Fatal { stage, error }) => {
                    bail!("stage {stage} failed: {error}")
                }
                Ok(other) => bail!("unexpected {other:?} mid-step"),
                Err(_) => bail!("all workers hung up"),
            }
        }

        // ---- optimizer update on every stage ----
        let global_step = self.steps_done + 1; // 1-based Adam bias correction
        let _ = step_idx;
        for tx in &self.to_all {
            tx.send(Msg::Update {
                step: global_step as i32,
                lr: cfg.lr,
            })
            .map_err(|_| anyhow!("worker hung up before update"))?;
        }
        let mut updates = 0;
        while updates < self.to_all.len() {
            match self.from_workers.recv() {
                Ok(DriverMsg::UpdateDone { .. }) => updates += 1,
                Ok(DriverMsg::Fatal { stage, error }) => bail!("stage {stage} failed: {error}"),
                Ok(_) => bail!("unexpected message during update"),
                Err(_) => bail!("all workers hung up"),
            }
        }

        self.steps_done += 1;
        let tokens =
            self.cfg.microbatches * self.manifest.model.batch * self.manifest.model.seq_len;
        Ok((losses / tokens as f64, tokens))
    }

    /// Drive `cfg.steps` steps pulling microbatches from `next_batch`.
    pub fn train(
        &mut self,
        next_batch: impl FnMut() -> Batch,
        on_step: impl FnMut(&StepReport),
    ) -> Result<Vec<StepReport>> {
        self.train_with_replan(next_batch, on_step, |_| None)
    }

    /// Like [`Trainer::train`], with the online planner in the loop: when
    /// `cfg.replan_every = Some(n)`, `replan(step)` is invoked every `n`
    /// steps (before the step runs) and may return a new slicing — e.g.
    /// from a fresh measure → fit → bucketed-DP solve, or a
    /// `crate::planner::Planner` decision. A returned slicing is adopted
    /// only if it validates against the manifest (sum = L, every slice an
    /// AOT bucket); an invalid one is reported and the current slicing
    /// kept, so a bad replan can never kill a long training run.
    pub fn train_with_replan(
        &mut self,
        mut next_batch: impl FnMut() -> Batch,
        mut on_step: impl FnMut(&StepReport),
        mut replan: impl FnMut(usize) -> Option<Vec<usize>>,
    ) -> Result<Vec<StepReport>> {
        let steps = self.cfg.steps;
        let mbs = self.cfg.microbatches;
        let mut reports = Vec::with_capacity(steps);
        for step in 0..steps {
            if let Some(n) = self.cfg.replan_every {
                if step > 0 && step % n == 0 {
                    if let Some(slicing) = replan(step) {
                        let mut cand = self.cfg.clone();
                        cand.slicing = slicing;
                        match cand.validate(self.manifest.model.seq_len, &self.manifest.buckets) {
                            Ok(()) => {
                                if cand.slicing != self.cfg.slicing {
                                    eprintln!(
                                        "replan at step {step}: slicing {:?} -> {:?}",
                                        self.cfg.slicing, cand.slicing
                                    );
                                }
                                self.cfg = cand;
                            }
                            Err(e) => eprintln!("replan at step {step} rejected: {e}"),
                        }
                    }
                }
            }
            let batches: Vec<Batch> = (0..mbs).map(|_| next_batch()).collect();
            let t0 = Instant::now();
            let (loss, tokens) = self.step(step, &batches)?;
            let rep = StepReport {
                step,
                loss,
                wall_ms: t0.elapsed().as_secs_f64() * 1e3,
                tokens,
            };
            on_step(&rep);
            reports.push(rep);
        }
        Ok(reports)
    }

    pub fn config(&self) -> &TrainConfig {
        &self.cfg
    }

    /// Persist all stages' parameters under `dir` (init-file layout; load
    /// with [`Trainer::new_with_resume`]).
    pub fn save_checkpoint(&mut self, dir: &Path) -> Result<()> {
        std::fs::create_dir_all(dir)?;
        std::fs::write(
            dir.join("meta.json"),
            crate::util::json::Json::obj(vec![("next_step", self.steps_done.into())]).to_string(),
        )?;
        for tx in &self.to_all {
            tx.send(Msg::Checkpoint { dir: dir.to_path_buf() })
                .map_err(|_| anyhow!("worker hung up before checkpoint"))?;
        }
        let mut done = 0;
        while done < self.to_all.len() {
            match self.from_workers.recv() {
                Ok(DriverMsg::CheckpointDone { .. }) => done += 1,
                Ok(DriverMsg::Fatal { stage, error }) => bail!("stage {stage} failed: {error}"),
                Ok(_) => bail!("unexpected message during checkpoint"),
                Err(_) => bail!("all workers hung up"),
            }
        }
        Ok(())
    }

    /// Graceful shutdown (also called on drop).
    pub fn shutdown(&mut self) {
        for tx in &self.to_all {
            let _ = tx.send(Msg::Shutdown);
        }
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

impl Drop for Trainer {
    fn drop(&mut self) {
        self.shutdown();
    }
}

/// Convenience one-call API: spawn, train on a batcher, shut down.
pub fn train(
    artifacts: &Path,
    cfg: TrainConfig,
    corpus: &str,
    mut on_step: impl FnMut(&StepReport),
) -> Result<Vec<StepReport>> {
    let mut trainer = Trainer::new(artifacts, cfg)?;
    let m = trainer.manifest.model.clone();
    let seed = trainer.cfg.seed;
    let mut batcher = crate::data::Batcher::new(corpus, m.batch, m.seq_len, seed);
    trainer.train(|| batcher.next_batch(), &mut on_step)
}
