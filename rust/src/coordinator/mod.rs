//! The token-level pipeline training coordinator — TeraPipe's mechanism,
//! actually executed, in the default build.
//!
//! One OS thread per pipeline cell (stage), each owning its own
//! [`crate::backend::StageBackend`] — parameters, Adam state and the
//! slice compute (the native CPU cell by default; AOT PJRT executables
//! behind the `pjrt` feature). Token slices flow downstream as
//! [`crate::runtime::tensor::HostTensor`] activations over a pluggable
//! [`transport::Transport`] fabric (in-process channels by default, the
//! deterministic fault-injecting virtual network in tests);
//! gradients flow back upstream in reverse slice order, carrying the
//! context-gradient accumulation that makes the pipelined backward
//! *exactly* equal the unsliced one (validated by
//! `rust/tests/pipeline_integration.rs` and
//! `rust/tests/backend_equivalence.rs` on the native backend, and by the
//! python oracle tests on the PJRT executables).
//!
//! Execution schedule (paper §3.2/3.4, per microbatch `mb` with slices
//! s_1..s_M of one training sequence batch):
//!
//! ```text
//! driver  → stage 0:   Fwd(mb, i, tokens sᵢ)            i = 1..M in order
//! stage k → stage k+1: Fwd(mb, i, h)                    pipelined
//! stage K-1:           on Fwd of the final slice, run head loss + begin
//!                      Bwd(mb, i) for i = M..1 (reverse), sending
//! stage k ← stage k+1: Bwd(mb, i, g_h)                  pipelined
//! driver  ← stage 0:   BwdDone per slice; when all arrive → Update
//! all stages:          Adam step (AOT executable), zero accumulators
//! ```
//!
//! While one microbatch is in backward, the next microbatch's forward
//! slices overlap on upstream stages — the fine-grained pipelining of
//! Fig. 1d / Fig. 2c, driven purely by message arrival.

pub mod messages;
pub mod trainer;
pub mod transport;
pub mod worker;

pub use messages::{SliceTime, TimedPhase};
#[cfg(feature = "pjrt")]
pub use trainer::train;
pub use trainer::{train_native, DriftReplanReport, StepReport, Trainer};
pub use transport::{InProcTransport, Transport, VirtualTransport};

use anyhow::{bail, Result};

/// Default driver recv deadline (ms): generous enough that no healthy
/// pipeline — however slow the hardware — ever trips it between two
/// consecutive driver messages, small enough that a wedged run fails in
/// minutes instead of hanging a CI job to its global timeout.
pub const DEFAULT_RECV_TIMEOUT_MS: u64 = 120_000;

/// Training-run configuration.
#[derive(Debug, Clone)]
pub struct TrainConfig {
    /// Token slice lengths (each must be a backend bucket; sum must be L).
    pub slicing: Vec<usize>,
    /// Microbatches per step (each is `batch` sequences; gradients
    /// accumulate across them before the Adam step).
    pub microbatches: usize,
    pub steps: usize,
    pub lr: f32,
    /// RNG seed for the batcher.
    pub seed: u64,
    /// Solver-in-the-loop cadence: every N steps the trainer invokes its
    /// replan callback ([`Trainer::train_with_replan`], or the window
    /// verdict in [`Trainer::train_with_drift_replan`]) and adopts the
    /// returned slicing if it validates against the bucket set — the
    /// coordinator-side hook of the online planner service
    /// (`crate::planner`). `None` keeps one slicing for the whole run.
    pub replan_every: Option<usize>,
    /// Collect per-slice fwd/bwd wall-clock samples every step
    /// ([`Trainer::last_timings`]). Implied by `replan_every`.
    pub trace: bool,
    /// Driver-side *inactivity* deadline per collect loop (step, update,
    /// checkpoint): if no driver message arrives for this long, the step
    /// fails with a progress diagnostic instead of blocking forever on a
    /// dead stage or a dropped message. Any arrival resets it, so it
    /// bounds silence, not step duration. `None` waits forever (the
    /// pre-deadline behavior).
    pub recv_timeout_ms: Option<u64>,
    /// Worker heartbeat period (ms): each worker spawns a beacon thread
    /// sending [`messages::DriverMsg::Heartbeat`] at this cadence, so
    /// the driver's health monitor can tell an *idle* stage from a
    /// *dead* one between real messages. `None` (the default) sends no
    /// heartbeats — note a heartbeat thread is a second sender on the
    /// worker's driver link, which perturbs the virtual transport's
    /// per-link RNG stream, so determinism-pinned runs leave this off.
    pub heartbeat_ms: Option<u64>,
}

impl Default for TrainConfig {
    fn default() -> Self {
        TrainConfig {
            slicing: Vec::new(),
            microbatches: 1,
            steps: 1,
            lr: 1e-3,
            seed: 0,
            replan_every: None,
            trace: false,
            recv_timeout_ms: Some(DEFAULT_RECV_TIMEOUT_MS),
            heartbeat_ms: None,
        }
    }
}

impl TrainConfig {
    /// Validate against the manifest geometry.
    pub fn validate(&self, seq_len: usize, buckets: &[usize]) -> Result<()> {
        if self.slicing.is_empty() {
            bail!("slicing must be non-empty");
        }
        let total: usize = self.slicing.iter().sum();
        if total != seq_len {
            bail!("slicing sums to {total}, sequence length is {seq_len}");
        }
        for &s in &self.slicing {
            if !buckets.contains(&s) {
                bail!("slice length {s} is not an AOT bucket ({buckets:?}); re-run `make artifacts` with it or pick bucketed lengths");
            }
        }
        if self.microbatches == 0 || self.steps == 0 {
            bail!("microbatches and steps must be ≥ 1");
        }
        if self.replan_every == Some(0) {
            bail!("replan_every must be ≥ 1 when set");
        }
        if self.recv_timeout_ms == Some(0) {
            bail!("recv_timeout_ms must be ≥ 1 when set (use None to wait forever)");
        }
        if self.heartbeat_ms == Some(0) {
            bail!("heartbeat_ms must be ≥ 1 when set (use None to disable heartbeats)");
        }
        Ok(())
    }

    /// Slice offsets (prefix sums).
    pub fn offsets(&self) -> Vec<usize> {
        let mut offs = Vec::with_capacity(self.slicing.len());
        let mut acc = 0;
        for &s in &self.slicing {
            offs.push(acc);
            acc += s;
        }
        offs
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn validate_accepts_bucketed_cover() {
        let c = TrainConfig {
            slicing: vec![64, 32, 16, 16],
            ..Default::default()
        };
        c.validate(128, &[16, 32, 64, 128]).unwrap();
        assert_eq!(c.offsets(), vec![0, 64, 96, 112]);
    }

    #[test]
    fn validate_rejects_bad_sum_and_bucket() {
        let mut c = TrainConfig {
            slicing: vec![64, 32],
            ..Default::default()
        };
        assert!(c.validate(128, &[16, 32, 64]).is_err()); // sums to 96
        c.slicing = vec![100, 28];
        assert!(c.validate(128, &[16, 32, 64]).is_err()); // not buckets
        c.slicing = vec![];
        assert!(c.validate(128, &[16]).is_err());
    }

    #[test]
    fn validate_rejects_zero_recv_timeout() {
        let c = TrainConfig {
            slicing: vec![64, 64],
            recv_timeout_ms: Some(0),
            ..Default::default()
        };
        assert!(c.validate(128, &[64]).is_err());
    }

    #[test]
    fn validate_rejects_zero_replan_cadence() {
        let c = TrainConfig {
            slicing: vec![64, 64],
            replan_every: Some(0),
            ..Default::default()
        };
        assert!(c.validate(128, &[64]).is_err());
    }
}
