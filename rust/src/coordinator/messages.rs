//! Message types flowing between the driver and the stage workers.

use crate::runtime::tensor::HostTensor;

/// Forward payload: raw tokens into stage 0, activations between stages.
#[derive(Debug, Clone)]
pub enum FwdPayload {
    /// [B, S] token ids (driver → first stage).
    Tokens(Vec<i32>),
    /// [B, S, H] hidden states (stage k → stage k+1).
    Act(HostTensor),
}

/// Worker inbox. One receiver per stage; senders held by the previous
/// stage (Fwd), the next stage (Bwd) and the driver (Fwd to stage 0,
/// Update/Shutdown to all).
#[derive(Debug)]
pub enum Msg {
    Fwd {
        mb: usize,
        slice: usize,
        /// Token offset of this slice in the sequence (= context length).
        off: usize,
        len: usize,
        /// True iff this is the final slice of the microbatch (off+len=L);
        /// triggers the backward sweep on the last stage.
        last: bool,
        payload: FwdPayload,
        /// [B, S] next-token targets for this slice (used by the last
        /// stage; carried along the pipe so no side channel is needed).
        targets: Vec<i32>,
    },
    Bwd {
        mb: usize,
        slice: usize,
        off: usize,
        len: usize,
        /// Gradient w.r.t. this stage's output for the slice, [B, S, H].
        g_h: HostTensor,
    },
    /// Apply the optimizer with the accumulated gradients, then reset
    /// per-step state.
    Update { step: i32, lr: f32 },
    /// Persist this stage's parameters under `dir` (init-file format, so a
    /// checkpoint is loadable wherever the init weights are).
    Checkpoint { dir: std::path::PathBuf },
    Shutdown,
}

impl Msg {
    /// Approximate wire size in bytes — what a serialized send would
    /// cost. Drives the bandwidth term of the virtual transport
    /// ([`crate::coordinator::transport::virt`]); control messages count
    /// a small fixed header.
    pub fn approx_bytes(&self) -> usize {
        const HEADER: usize = 64;
        match self {
            Msg::Fwd { payload, targets, .. } => {
                let p = match payload {
                    FwdPayload::Tokens(t) => 4 * t.len(),
                    FwdPayload::Act(h) => 4 * h.len(),
                };
                HEADER + p + 4 * targets.len()
            }
            Msg::Bwd { g_h, .. } => HEADER + 4 * g_h.len(),
            Msg::Update { .. } | Msg::Checkpoint { .. } | Msg::Shutdown => HEADER,
        }
    }

    /// Token-slice length for payload messages (the cost model's `i`),
    /// `None` for control messages. Lets per-link delivery metrics be
    /// grouped by slice length when fitting `t_comm`.
    pub fn slice_len(&self) -> Option<usize> {
        match self {
            Msg::Fwd { len, .. } | Msg::Bwd { len, .. } => Some(*len),
            _ => None,
        }
    }
}

/// Which half of a slice's work a timing sample covers.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TimedPhase {
    /// Embedding (first stage) + stage forward + head loss (last stage).
    Fwd,
    /// Head backward (last stage) + stage backward + embedding backward
    /// (first stage) — recompute included, like the executables.
    Bwd,
}

/// One measured slice execution on one stage — the live counterpart of
/// [`crate::perfmodel::measure`]'s offline samples. `off` is the slice's
/// context length (the model's `j`), `len` the slice length (`i`).
#[derive(Debug, Clone, Copy)]
pub struct SliceTime {
    pub stage: usize,
    pub mb: usize,
    pub slice: usize,
    pub off: usize,
    pub len: usize,
    pub phase: TimedPhase,
    pub ms: f64,
}

/// Driver inbox.
#[derive(Debug)]
pub enum DriverMsg {
    /// Stage 0 finished backward for one (mb, slice).
    BwdDone { mb: usize, slice: usize },
    /// A per-slice wall-clock sample (sent only when timing collection is
    /// on: `TrainConfig::trace` or an active replan cadence).
    SliceTime(SliceTime),
    /// Last stage's summed token cross-entropy for one (mb, slice).
    Loss { mb: usize, slice: usize, loss_sum: f32 },
    /// A worker applied its optimizer update.
    UpdateDone { stage: usize },
    /// A worker wrote its checkpoint files.
    CheckpointDone { stage: usize },
    /// A worker hit an unrecoverable error.
    Fatal { stage: usize, error: String },
    /// Periodic liveness beacon from a worker's heartbeat thread (sent
    /// only when [`super::TrainConfig::heartbeat_ms`] is set). Consumed
    /// by the driver's health monitor; never surfaced to collect loops
    /// and never resets the recv inactivity deadline — a dead peer must
    /// still trip it even while healthy stages keep beating.
    Heartbeat { stage: usize },
}

impl DriverMsg {
    /// Approximate wire size — driver-bound messages are all small.
    pub fn approx_bytes(&self) -> usize {
        match self {
            DriverMsg::Fatal { error, .. } => 64 + error.len(),
            _ => 64,
        }
    }

    /// Which stage sent this message in a `k`-stage pipeline (identifies
    /// the `ToDriver` link it traveled for recv-side span attribution).
    pub fn source_stage(&self, k: usize) -> usize {
        match self {
            // BwdDone is emitted by the first stage after embed_bwd.
            DriverMsg::BwdDone { .. } => 0,
            // Losses come from the last stage's head.
            DriverMsg::Loss { .. } => k.saturating_sub(1),
            DriverMsg::SliceTime(t) => t.stage,
            DriverMsg::UpdateDone { stage }
            | DriverMsg::CheckpointDone { stage }
            | DriverMsg::Fatal { stage, .. }
            | DriverMsg::Heartbeat { stage } => *stage,
        }
    }
}
