//! Stage worker: one OS thread per pipeline cell.
//!
//! Owns parameters + Adam state for its layers (plus the embedding on the
//! first stage and the LM head on the last), the per-microbatch KV context
//! buffers, stored slice inputs for the recompute-based backward, and the
//! context-gradient accumulators. All compute goes through AOT
//! executables; this file is pure orchestration and buffer bookkeeping.

use std::collections::HashMap;
use std::path::PathBuf;
use std::sync::mpsc::{Receiver, Sender};

use anyhow::{anyhow, Context, Result};

use super::messages::{DriverMsg, FwdPayload, Msg};
use crate::runtime::manifest::ModelDims;
use crate::runtime::tensor::HostTensor;
use crate::runtime::{stage_exe_names, StageRuntime};

/// Bookkeeping for one token slice of one microbatch.
#[derive(Debug, Clone)]
struct SliceMeta {
    off: usize,
    len: usize,
    /// Slice token ids (kept on the first stage for embed_bwd).
    tokens: Option<Vec<i32>>,
    /// Slice targets (kept on the last stage for head_bwd).
    targets: Vec<i32>,
}

/// Per-microbatch in-flight state (the "activations of the whole
/// minibatch" the paper stores; freed after the microbatch's backward).
struct MbState {
    k_ctx: HostTensor,
    v_ctx: HostTensor,
    g_kacc: HostTensor,
    g_vacc: HostTensor,
    /// Stage-input activation per slice (recompute-based bwd needs it).
    h_in: HashMap<usize, HostTensor>,
    /// Last stage only: stage-output activation per slice (head input).
    h_out: HashMap<usize, HostTensor>,
    meta: HashMap<usize, SliceMeta>,
}

impl MbState {
    fn new(dims: &ModelDims) -> Self {
        let kv = dims.kv_shape();
        MbState {
            k_ctx: HostTensor::zeros_f32(&kv),
            v_ctx: HostTensor::zeros_f32(&kv),
            g_kacc: HostTensor::zeros_f32(&kv),
            g_vacc: HostTensor::zeros_f32(&kv),
            h_in: HashMap::new(),
            h_out: HashMap::new(),
            meta: HashMap::new(),
        }
    }
}

/// An optimizer-managed parameter group backed by `adam_<group>`.
///
/// Parameters are kept both as host tensors (for the optimizer step) and
/// as pre-converted PJRT literals: they only change at `apply`, but are
/// inputs to *every* slice executable — caching the upload halves the
/// per-slice host work (EXPERIMENTS.md §Perf L3 iteration 2).
struct ParamGroup {
    exe: String,
    params: Vec<HostTensor>,
    /// Cached literal uploads of `params` (invalidated by `apply`).
    lits: Vec<xla::Literal>,
    grads: Vec<HostTensor>,
    m: Vec<HostTensor>,
    v: Vec<HostTensor>,
}

impl ParamGroup {
    fn new(exe: &str, params: Vec<HostTensor>) -> Result<Self> {
        let zeros: Vec<HostTensor> = params
            .iter()
            .map(|p| HostTensor::zeros_f32(&p.shape))
            .collect();
        let lits = params
            .iter()
            .map(|p| p.to_literal())
            .collect::<Result<Vec<_>>>()?;
        Ok(ParamGroup {
            exe: exe.to_string(),
            lits,
            grads: zeros.clone(),
            m: zeros.clone(),
            v: zeros,
            params,
        })
    }

    fn accumulate(&mut self, slice_grads: &[HostTensor]) {
        assert_eq!(slice_grads.len(), self.grads.len(), "{} grad arity", self.exe);
        for (g, s) in self.grads.iter_mut().zip(slice_grads) {
            g.add_assign(s);
        }
    }

    fn apply(&mut self, rt: &StageRuntime, step: i32, lr: f32) -> Result<()> {
        let n = self.params.len();
        let mut inputs = Vec::with_capacity(4 * n + 2);
        inputs.extend(self.params.iter().cloned());
        inputs.extend(self.grads.iter().cloned());
        inputs.extend(self.m.iter().cloned());
        inputs.extend(self.v.iter().cloned());
        inputs.push(HostTensor::scalar_i32(step));
        inputs.push(HostTensor::scalar_f32(lr));
        let mut out = rt.run(&self.exe, &inputs)?;
        // outputs: params, m, v — in that order
        self.v = out.split_off(2 * n);
        self.m = out.split_off(n);
        self.params = out;
        self.lits = self
            .params
            .iter()
            .map(|p| p.to_literal())
            .collect::<Result<Vec<_>>>()?;
        for g in &mut self.grads {
            g.fill_zero();
        }
        Ok(())
    }
}

/// `init/stage0.w.bin` → `init/m.stage0.w.bin` (same dir, prefixed stem).
fn moment_path(dir: &std::path::Path, file: &str, prefix: &str) -> PathBuf {
    let p = std::path::Path::new(file);
    let name = p.file_name().unwrap().to_string_lossy();
    dir.join(p.parent().unwrap_or_else(|| std::path::Path::new("")))
        .join(format!("{prefix}.{name}"))
}

/// Worker configuration handed to [`run_worker`].
pub struct WorkerCfg {
    pub stage: usize,
    pub num_stages: usize,
    pub artifacts: PathBuf,
    /// Load parameters from this checkpoint dir instead of artifacts/init.
    pub resume_from: Option<PathBuf>,
    pub inbox: Receiver<Msg>,
    /// Next stage's inbox (forward direction), if any.
    pub next: Option<Sender<Msg>>,
    /// Previous stage's inbox (backward direction), if any.
    pub prev: Option<Sender<Msg>>,
    pub driver: Sender<DriverMsg>,
}

/// Thread body. Errors are reported to the driver as `Fatal`.
pub fn run_worker(cfg: WorkerCfg) {
    let stage = cfg.stage;
    let driver = cfg.driver.clone();
    if let Err(e) = Worker::init_and_run(cfg) {
        let _ = driver.send(DriverMsg::Fatal {
            stage,
            error: format!("{e:#}"),
        });
    }
}

struct Worker {
    stage: usize,
    is_first: bool,
    is_last: bool,
    rt: StageRuntime,
    dims: ModelDims,
    stage_group: ParamGroup,
    embed_group: Option<ParamGroup>,
    head_group: Option<ParamGroup>,
    mbs: HashMap<usize, MbState>,
    next: Option<Sender<Msg>>,
    prev: Option<Sender<Msg>>,
    driver: Sender<DriverMsg>,
}

impl Worker {
    fn init_and_run(cfg: WorkerCfg) -> Result<()> {
        let WorkerCfg {
            stage,
            num_stages,
            artifacts,
            resume_from,
            inbox,
            next,
            prev,
            driver,
        } = cfg;
        let is_first = stage == 0;
        let is_last = stage == num_stages - 1;

        let manifest = crate::runtime::manifest::Manifest::load(&artifacts)?;
        let names = stage_exe_names(stage, num_stages, &manifest.buckets);
        let rt = StageRuntime::load(&artifacts, &names)
            .with_context(|| format!("stage {stage}: loading runtime"))?;
        let dims = rt.manifest.model.clone();

        // Parameters come from artifacts/init, or from a checkpoint dir
        // (same file layout — see Msg::Checkpoint).
        // Parameters (and, when resuming, Adam moments) from artifacts/init
        // or a checkpoint dir.
        let read_file = |path: std::path::PathBuf, shape: &[usize]| -> Result<HostTensor> {
            let bytes = std::fs::read(&path)
                .with_context(|| format!("reading checkpoint {}", path.display()))?;
            let n: usize = shape.iter().product::<usize>().max(1);
            anyhow::ensure!(bytes.len() == 4 * n, "{}: wrong size", path.display());
            let floats = bytes
                .chunks_exact(4)
                .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
                .collect();
            Ok(HostTensor::f32(shape, floats))
        };
        let mk_group = |exe: &str,
                        entries: &[crate::runtime::manifest::InitEntry]|
         -> Result<ParamGroup> {
            match &resume_from {
                None => ParamGroup::new(exe, rt.manifest.load_init(entries)?),
                Some(dir) => {
                    let params = entries
                        .iter()
                        .map(|e| read_file(dir.join(&e.file), &e.shape))
                        .collect::<Result<Vec<_>>>()?;
                    let mut g = ParamGroup::new(exe, params)?;
                    // moments are optional (params-only checkpoints load too)
                    if entries
                        .iter()
                        .all(|e| moment_path(dir, &e.file, "m").exists())
                    {
                        g.m = entries
                            .iter()
                            .map(|e| read_file(moment_path(dir, &e.file, "m"), &e.shape))
                            .collect::<Result<Vec<_>>>()?;
                        g.v = entries
                            .iter()
                            .map(|e| read_file(moment_path(dir, &e.file, "v"), &e.shape))
                            .collect::<Result<Vec<_>>>()?;
                    }
                    Ok(g)
                }
            }
        };
        let stage_group = mk_group("adam_stage", &rt.manifest.init_stages[stage])?;
        let embed_group = is_first
            .then(|| mk_group("adam_embed", &rt.manifest.init_embed))
            .transpose()?;
        let head_group = is_last
            .then(|| mk_group("adam_head", &rt.manifest.init_head))
            .transpose()?;
        drop(manifest);

        let mut w = Worker {
            stage,
            is_first,
            is_last,
            rt,
            dims,
            stage_group,
            embed_group,
            head_group,
            mbs: HashMap::new(),
            next,
            prev,
            driver,
        };

        while let Ok(msg) = inbox.recv() {
            match msg {
                Msg::Shutdown => break,
                Msg::Update { step, lr } => w.handle_update(step, lr)?,
                Msg::Checkpoint { dir } => w.handle_checkpoint(&dir)?,
                Msg::Fwd {
                    mb,
                    slice,
                    off,
                    len,
                    last,
                    payload,
                    targets,
                } => w.handle_fwd(mb, slice, off, len, last, payload, targets)?,
                Msg::Bwd {
                    mb,
                    slice,
                    off,
                    len,
                    g_h,
                } => w.handle_bwd(mb, slice, off, len, g_h)?,
            }
        }
        Ok(())
    }

    /// Write this stage's parameter groups under `dir` in the init-file
    /// layout (init/stage{k}.name.bin etc.), so checkpoints are loadable
    /// via `resume_from`.
    fn handle_checkpoint(&mut self, dir: &std::path::Path) -> Result<()> {
        std::fs::create_dir_all(dir.join("init"))?;
        let manifest = &self.rt.manifest;
        let groups: Vec<(&[crate::runtime::manifest::InitEntry], &ParamGroup)> = {
            let mut v: Vec<(&[crate::runtime::manifest::InitEntry], &ParamGroup)> = vec![(
                manifest.init_stages[self.stage].as_slice(),
                &self.stage_group,
            )];
            if let Some(g) = &self.embed_group {
                v.push((manifest.init_embed.as_slice(), g));
            }
            if let Some(g) = &self.head_group {
                v.push((manifest.init_head.as_slice(), g));
            }
            v
        };
        let write = |path: std::path::PathBuf, t: &HostTensor| -> Result<()> {
            let bytes: Vec<u8> = t.as_f32().iter().flat_map(|x| x.to_le_bytes()).collect();
            std::fs::write(path, bytes)?;
            Ok(())
        };
        for (entries, group) in groups {
            for (i, e) in entries.iter().enumerate() {
                write(dir.join(&e.file), &group.params[i])?;
                // optimizer moments beside the params, "m."/"v." prefixed
                write(moment_path(dir, &e.file, "m"), &group.m[i])?;
                write(moment_path(dir, &e.file, "v"), &group.v[i])?;
            }
        }
        self.driver
            .send(DriverMsg::CheckpointDone { stage: self.stage })
            .ok();
        Ok(())
    }

    fn handle_update(&mut self, step: i32, lr: f32) -> Result<()> {
        self.stage_group.apply(&self.rt, step, lr)?;
        if let Some(g) = self.embed_group.as_mut() {
            g.apply(&self.rt, step, lr)?;
        }
        if let Some(g) = self.head_group.as_mut() {
            g.apply(&self.rt, step, lr)?;
        }
        self.mbs.clear();
        self.driver
            .send(DriverMsg::UpdateDone { stage: self.stage })
            .ok();
        Ok(())
    }

    #[allow(clippy::too_many_arguments)]
    fn handle_fwd(
        &mut self,
        mb: usize,
        slice: usize,
        off: usize,
        len: usize,
        last: bool,
        payload: FwdPayload,
        targets: Vec<i32>,
    ) -> Result<()> {
        // 1. Materialize this stage's input activation.
        let (h_in, tokens) = match payload {
            FwdPayload::Tokens(tokens) => {
                let eg = self
                    .embed_group
                    .as_ref()
                    .ok_or_else(|| anyhow!("tokens arrived at non-first stage {}", self.stage))?;
                let tok_l = HostTensor::i32(&[self.dims.batch, len], tokens.clone()).to_literal()?;
                let off_l = HostTensor::scalar_i32(off as i32).to_literal()?;
                let mut args: Vec<&xla::Literal> = eg.lits.iter().collect();
                args.push(&tok_l);
                args.push(&off_l);
                let h = self
                    .rt
                    .run_literal_refs(&format!("embed_fwd_s{len}"), &args)?
                    .remove(0);
                (h, Some(tokens))
            }
            FwdPayload::Act(h) => (h, None),
        };

        // 2. Stage forward with the KV context accumulated so far.
        let st = self.mbs.entry(mb).or_insert_with(|| MbState::new(&self.dims));
        let h_l = h_in.to_literal()?;
        let k_l = st.k_ctx.to_literal()?;
        let v_l = st.v_ctx.to_literal()?;
        let off_l = HostTensor::scalar_i32(off as i32).to_literal()?;
        let mut args: Vec<&xla::Literal> = self.stage_group.lits.iter().collect();
        args.extend([&h_l, &k_l, &v_l, &off_l]);
        let mut out = self.rt.run_literal_refs(&format!("stage_fwd_s{len}"), &args)?;
        let v_new = out.pop().unwrap();
        let k_new = out.pop().unwrap();
        let h_out = out.pop().unwrap();

        // 3. Grow the context buffers (axis 2 = token position) and stash
        // what backward will need.
        st.k_ctx.write_at_axis(2, off, &k_new);
        st.v_ctx.write_at_axis(2, off, &v_new);
        st.h_in.insert(slice, h_in);
        st.meta.insert(
            slice,
            SliceMeta {
                off,
                len,
                tokens,
                targets: targets.clone(),
            },
        );

        if self.is_last {
            // 4a. Head loss for this slice (reported to the driver).
            let hg = self.head_group.as_ref().unwrap();
            let tg_l = HostTensor::i32(&[self.dims.batch, len], targets).to_literal()?;
            let h_l = h_out.to_literal()?;
            let mut args: Vec<&xla::Literal> = hg.lits.iter().collect();
            args.extend([&h_l, &tg_l]);
            let loss = self.rt.run_literal_refs(&format!("head_fwd_s{len}"), &args)?.remove(0);
            self.driver
                .send(DriverMsg::Loss {
                    mb,
                    slice,
                    loss_sum: loss.as_f32()[0],
                })
                .ok();
            self.mbs.get_mut(&mb).unwrap().h_out.insert(slice, h_out);

            // 4b. Final slice arrived → run the backward sweep for this
            // microbatch in reverse slice order.
            if last {
                self.backward_sweep(mb)?;
                self.mbs.remove(&mb);
            }
        } else {
            // 4. Hand the activation to the next stage.
            self.next
                .as_ref()
                .unwrap()
                .send(Msg::Fwd {
                    mb,
                    slice,
                    off,
                    len,
                    last,
                    payload: FwdPayload::Act(h_out),
                    targets,
                })
                .map_err(|_| anyhow!("stage {}: next stage hung up", self.stage))?;
        }
        Ok(())
    }

    fn handle_bwd(
        &mut self,
        mb: usize,
        slice: usize,
        off: usize,
        len: usize,
        g_h: HostTensor,
    ) -> Result<()> {
        let g_h_in = self.backward_one_slice(mb, slice, off, len, g_h)?;
        self.finish_bwd_slice(mb, slice, off, len, g_h_in)?;
        if self.mbs.get(&mb).map(|s| s.h_in.is_empty()).unwrap_or(false) {
            self.mbs.remove(&mb);
        }
        Ok(())
    }

    /// Backward for one slice on this stage: reads the accumulated K/V
    /// grads for the slice's own keys, runs the recompute-based stage_bwd,
    /// folds returned context grads into the accumulators and param grads
    /// into the group. Returns grad w.r.t. the stage input.
    fn backward_one_slice(
        &mut self,
        mb: usize,
        slice: usize,
        off: usize,
        len: usize,
        g_h: HostTensor,
    ) -> Result<HostTensor> {
        let st = self
            .mbs
            .get_mut(&mb)
            .ok_or_else(|| anyhow!("stage {}: Bwd for unknown mb {mb}", self.stage))?;
        let h_in = st
            .h_in
            .remove(&slice)
            .ok_or_else(|| anyhow!("missing stored activation for slice {slice}"))?;
        // Gradients w.r.t. this slice's own K/V, deposited by later slices
        // (zero for the final slice — nothing attends past it).
        let g_know = st.g_kacc.read_at_axis(2, off, len);
        let g_vnow = st.g_vacc.read_at_axis(2, off, len);

        let h_l = h_in.to_literal()?;
        let k_l = st.k_ctx.to_literal()?;
        let v_l = st.v_ctx.to_literal()?;
        let off_l = HostTensor::scalar_i32(off as i32).to_literal()?;
        let gh_l = g_h.to_literal()?;
        let gk_l = g_know.to_literal()?;
        let gv_l = g_vnow.to_literal()?;
        let mut args: Vec<&xla::Literal> = self.stage_group.lits.iter().collect();
        args.extend([&h_l, &k_l, &v_l, &off_l, &gh_l, &gk_l, &gv_l]);
        let mut out = self.rt.run_literal_refs(&format!("stage_bwd_s{len}"), &args)?;
        let g_vctx = out.pop().unwrap();
        let g_kctx = out.pop().unwrap();
        let g_h_in = out.pop().unwrap();
        self.stage_group.accumulate(&out);
        st.g_kacc.add_assign(&g_kctx);
        st.g_vacc.add_assign(&g_vctx);
        Ok(g_h_in)
    }

    /// Route the input-gradient of a finished backward slice: upstream, or
    /// into embed_bwd on the first stage (+ notify the driver).
    fn finish_bwd_slice(
        &mut self,
        mb: usize,
        slice: usize,
        off: usize,
        len: usize,
        g_h_in: HostTensor,
    ) -> Result<()> {
        if self.is_first {
            let meta = self
                .mbs
                .get(&mb)
                .and_then(|s| s.meta.get(&slice))
                .cloned()
                .ok_or_else(|| anyhow!("missing slice meta"))?;
            let tokens = meta
                .tokens
                .ok_or_else(|| anyhow!("first stage lost slice tokens"))?;
            let eg = self.embed_group.as_ref().unwrap();
            let tok_l = HostTensor::i32(&[self.dims.batch, len], tokens).to_literal()?;
            let off_l = HostTensor::scalar_i32(off as i32).to_literal()?;
            let gh_l = g_h_in.to_literal()?;
            let mut args: Vec<&xla::Literal> = eg.lits.iter().collect();
            args.extend([&tok_l, &off_l, &gh_l]);
            let out = self.rt.run_literal_refs(&format!("embed_bwd_s{len}"), &args)?;
            let eg = self.embed_group.as_mut().unwrap();
            eg.accumulate(&out);
            self.driver.send(DriverMsg::BwdDone { mb, slice }).ok();
        } else {
            self.prev
                .as_ref()
                .unwrap()
                .send(Msg::Bwd {
                    mb,
                    slice,
                    off,
                    len,
                    g_h: g_h_in,
                })
                .map_err(|_| anyhow!("stage {}: prev stage hung up", self.stage))?;
        }
        Ok(())
    }

    /// Last stage: backward over all slices of a microbatch in reverse
    /// order, seeding each slice with its head gradient.
    fn backward_sweep(&mut self, mb: usize) -> Result<()> {
        let mut order: Vec<usize> = self
            .mbs
            .get(&mb)
            .map(|s| s.meta.keys().copied().collect())
            .unwrap_or_default();
        order.sort_unstable_by(|a, b| b.cmp(a)); // reverse slice order

        for slice in order {
            let (meta, h_out) = {
                let st = self.mbs.get_mut(&mb).unwrap();
                let meta = st.meta.get(&slice).cloned().unwrap();
                let h_out = st
                    .h_out
                    .remove(&slice)
                    .ok_or_else(|| anyhow!("missing head input for slice {slice}"))?;
                (meta, h_out)
            };
            let hg = self.head_group.as_ref().unwrap();
            let tg_l = HostTensor::i32(&[self.dims.batch, meta.len], meta.targets.clone())
                .to_literal()?;
            let h_l = h_out.to_literal()?;
            let mut args: Vec<&xla::Literal> = hg.lits.iter().collect();
            args.extend([&h_l, &tg_l]);
            let mut out = self.rt.run_literal_refs(&format!("head_bwd_s{}", meta.len), &args)?;
            let hg = self.head_group.as_mut().unwrap();
            let g_h = out.pop().unwrap();
            hg.accumulate(&out);

            let g_h_in = self.backward_one_slice(mb, slice, meta.off, meta.len, g_h)?;
            self.finish_bwd_slice(mb, slice, meta.off, meta.len, g_h_in)?;
        }
        Ok(())
    }
}
