//! Stage worker: one OS thread per pipeline cell.
//!
//! Pure schedule + buffer bookkeeping: the worker owns the per-microbatch
//! KV context buffers, stored slice inputs for the recompute-based
//! backward, and the context-gradient accumulators, and routes messages.
//! All compute — and all parameter/optimizer state — lives behind the
//! [`StageBackend`] the worker builds from its [`BackendSpec`] on this
//! thread (so non-`Send` backend internals never cross threads).
//!
//! Messages travel over whatever [`transport::Transport`] wired the
//! pipeline ([`transport::StageEndpoint`]); the worker never sees the
//! fabric, only its endpoints.
//!
//! Failure semantics (see `coordinator/README.md`): anything that goes
//! wrong on this thread — an `Err` from the backend, a malformed message
//! sequence, or a **panic** anywhere in the body — is reported to the
//! driver as [`DriverMsg::Fatal`] before the thread exits. Message-
//! sequence violations (a `Bwd` for an unknown slice, tokens at a
//! non-first stage) are `Err`s, not unwraps, so a confused or faulty
//! peer degrades into a diagnosable failed step instead of a crash.
//!
//! When timing collection is on, every slice's forward and backward
//! compute is wall-clocked and reported to the driver as
//! [`DriverMsg::SliceTime`] — the live samples the measurement harness
//! and the drift detector consume.

use std::collections::HashMap;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::path::PathBuf;
use std::time::Instant;

use anyhow::{anyhow, Result};

use super::messages::{DriverMsg, FwdPayload, Msg, SliceTime, TimedPhase};
use super::transport::{DriverTx, MsgTx, StageEndpoint};
use crate::backend::{BackendSpec, StageBackend};
use crate::obs::{self, SpanKind};
use crate::runtime::manifest::ModelDims;
use crate::runtime::tensor::HostTensor;

/// Bookkeeping for one token slice of one microbatch.
#[derive(Debug, Clone)]
struct SliceMeta {
    off: usize,
    len: usize,
    /// Slice token ids (kept on the first stage for embed_bwd).
    tokens: Option<Vec<i32>>,
    /// Slice targets (kept on the last stage for head_bwd).
    targets: Vec<i32>,
}

/// Per-microbatch in-flight state (the "activations of the whole
/// minibatch" the paper stores; freed after the microbatch's backward).
struct MbState {
    k_ctx: HostTensor,
    v_ctx: HostTensor,
    g_kacc: HostTensor,
    g_vacc: HostTensor,
    /// Stage-input activation per slice (recompute-based bwd needs it).
    h_in: HashMap<usize, HostTensor>,
    /// Last stage only: stage-output activation per slice (head input).
    h_out: HashMap<usize, HostTensor>,
    meta: HashMap<usize, SliceMeta>,
}

impl MbState {
    fn new(dims: &ModelDims) -> Self {
        let kv = dims.kv_shape();
        MbState {
            k_ctx: HostTensor::zeros_f32(&kv),
            v_ctx: HostTensor::zeros_f32(&kv),
            g_kacc: HostTensor::zeros_f32(&kv),
            g_vacc: HostTensor::zeros_f32(&kv),
            h_in: HashMap::new(),
            h_out: HashMap::new(),
            meta: HashMap::new(),
        }
    }
}

/// Worker configuration handed to [`run_worker`].
pub struct WorkerCfg<S: BackendSpec> {
    pub stage: usize,
    pub num_stages: usize,
    pub spec: S,
    /// Load parameters from this checkpoint dir instead of the spec's
    /// initial weights.
    pub resume_from: Option<PathBuf>,
    /// Report per-slice fwd/bwd wall times to the driver.
    pub timings: bool,
    /// Send [`DriverMsg::Heartbeat`] at this period (ms) from a beacon
    /// thread, so the driver can tell idle from dead
    /// ([`super::TrainConfig::heartbeat_ms`]).
    pub heartbeat_ms: Option<u64>,
    /// This stage's view of the transport fabric.
    pub endpoint: StageEndpoint,
}

/// Thread body. Errors **and panics** are reported to the driver as
/// [`DriverMsg::Fatal`] — a worker thread never dies silently, so the
/// driver's collect loops always get either progress or a diagnosis
/// (backstopped by their recv deadline for the crash-stop case where
/// even the Fatal can't be sent).
pub fn run_worker<S: BackendSpec>(cfg: WorkerCfg<S>) {
    let stage = cfg.stage;
    let driver = cfg.endpoint.driver.clone_box();
    // Liveness beacon: a detached thread sending Heartbeat at the
    // configured period until the worker body exits (or the driver
    // hangs up). Lets the driver's health monitor distinguish a parked
    // stage (waiting for work) from a dead one.
    let beat = cfg.heartbeat_ms.map(|period_ms| {
        let alive = std::sync::Arc::new(std::sync::atomic::AtomicBool::new(true));
        let flag = alive.clone();
        let tx = cfg.endpoint.driver.clone_box();
        let handle = std::thread::Builder::new()
            .name(format!("terapipe-hb-{stage}"))
            .spawn(move || {
                let period = std::time::Duration::from_millis(period_ms.max(1));
                while flag.load(std::sync::atomic::Ordering::Relaxed) {
                    if tx.send(DriverMsg::Heartbeat { stage }).is_err() {
                        break;
                    }
                    std::thread::sleep(period);
                }
            })
            .expect("spawn heartbeat thread");
        (alive, handle)
    });
    let result = catch_unwind(AssertUnwindSafe(|| Worker::<S::Backend>::init_and_run(cfg)));
    if let Some((alive, handle)) = beat {
        alive.store(false, std::sync::atomic::Ordering::Relaxed);
        let _ = handle.join();
    }
    let error = match result {
        Ok(Ok(())) => return,
        Ok(Err(e)) => format!("{e:#}"),
        Err(payload) => {
            let what = payload
                .downcast_ref::<&str>()
                .map(|s| s.to_string())
                .or_else(|| payload.downcast_ref::<String>().cloned())
                .unwrap_or_else(|| "non-string panic payload".to_string());
            format!("worker panicked: {what}")
        }
    };
    let _ = driver.send(DriverMsg::Fatal { stage, error });
}

struct Worker<B: StageBackend> {
    stage: usize,
    is_first: bool,
    is_last: bool,
    backend: B,
    dims: ModelDims,
    timings: bool,
    mbs: HashMap<usize, MbState>,
    next: Option<Box<dyn MsgTx>>,
    prev: Option<Box<dyn MsgTx>>,
    driver: Box<dyn DriverTx>,
}

impl<B: StageBackend> Worker<B> {
    fn init_and_run<S: BackendSpec<Backend = B>>(cfg: WorkerCfg<S>) -> Result<()> {
        let WorkerCfg { stage, num_stages, spec, resume_from, timings, heartbeat_ms: _, endpoint } = cfg;
        let StageEndpoint { mut inbox, next, prev, driver } = endpoint;
        let backend = spec.build(stage, num_stages, resume_from.as_deref())?;
        let dims = backend.dims().clone();
        let mut w = Worker {
            stage,
            is_first: stage == 0,
            is_last: stage == num_stages - 1,
            backend,
            dims,
            timings,
            mbs: HashMap::new(),
            next,
            prev,
            driver,
        };

        while let Ok(msg) = inbox.recv() {
            match msg {
                Msg::Shutdown => break,
                Msg::Update { step, lr } => w.handle_update(step, lr)?,
                Msg::Checkpoint { dir } => w.handle_checkpoint(&dir)?,
                Msg::Fwd {
                    mb,
                    slice,
                    off,
                    len,
                    last,
                    payload,
                    targets,
                } => w.handle_fwd(mb, slice, off, len, last, payload, targets)?,
                Msg::Bwd {
                    mb,
                    slice,
                    off,
                    len,
                    g_h,
                } => w.handle_bwd(mb, slice, off, len, g_h)?,
            }
        }
        Ok(())
    }

    fn send_time(
        &self,
        mb: usize,
        slice: usize,
        off: usize,
        len: usize,
        phase: TimedPhase,
        ms: f64,
    ) {
        if self.timings {
            self.driver
                .send(DriverMsg::SliceTime(SliceTime {
                    stage: self.stage,
                    mb,
                    slice,
                    off,
                    len,
                    phase,
                    ms,
                }))
                .ok();
        }
    }

    fn handle_checkpoint(&mut self, dir: &std::path::Path) -> Result<()> {
        self.backend.checkpoint(dir)?;
        self.driver
            .send(DriverMsg::CheckpointDone { stage: self.stage })
            .ok();
        Ok(())
    }

    fn handle_update(&mut self, step: i32, lr: f32) -> Result<()> {
        let t_us = obs::maybe_start();
        self.backend.update(step, lr)?;
        obs::emit(SpanKind::AdamUpdate, self.stage as i32, 0, 0, step as u64, 0, t_us);
        self.mbs.clear();
        self.driver
            .send(DriverMsg::UpdateDone { stage: self.stage })
            .ok();
        Ok(())
    }

    #[allow(clippy::too_many_arguments)]
    fn handle_fwd(
        &mut self,
        mb: usize,
        slice: usize,
        off: usize,
        len: usize,
        last: bool,
        payload: FwdPayload,
        targets: Vec<i32>,
    ) -> Result<()> {
        let t0 = Instant::now();
        let t_us = obs::maybe_start();
        // 1. Materialize this stage's input activation.
        let (h_in, tokens) = match payload {
            FwdPayload::Tokens(tokens) => {
                if !self.is_first {
                    return Err(anyhow!("tokens arrived at non-first stage {}", self.stage));
                }
                (self.backend.embed_fwd(&tokens, len, off)?, Some(tokens))
            }
            FwdPayload::Act(h) => (h, None),
        };

        // 2. Stage forward with the KV context accumulated so far.
        let st = self.mbs.entry(mb).or_insert_with(|| MbState::new(&self.dims));
        let (h_out, k_new, v_new) = self.backend.stage_fwd(&h_in, &st.k_ctx, &st.v_ctx, off)?;

        // 3. Grow the context buffers (axis 2 = token position) and stash
        // what backward will need.
        let kv_us = obs::maybe_start();
        st.k_ctx.write_at_axis(2, off, &k_new);
        st.v_ctx.write_at_axis(2, off, &v_new);
        obs::emit(SpanKind::KvRoute, self.stage as i32, mb as u32, slice as u32, off as u64, len as u64, kv_us);
        st.h_in.insert(slice, h_in);
        st.meta.insert(
            slice,
            SliceMeta {
                off,
                len,
                tokens,
                targets: targets.clone(),
            },
        );

        if self.is_last {
            // 4a. Head loss for this slice (reported to the driver).
            let loss_sum = self.backend.head_loss(&h_out, &targets, len)?;
            obs::emit(SpanKind::SliceFwd, self.stage as i32, mb as u32, slice as u32, off as u64, len as u64, t_us);
            self.send_time(mb, slice, off, len, TimedPhase::Fwd, t0.elapsed().as_secs_f64() * 1e3);
            self.driver
                .send(DriverMsg::Loss {
                    mb,
                    slice,
                    loss_sum,
                })
                .ok();
            self.mbs
                .get_mut(&mb)
                .ok_or_else(|| anyhow!("stage {}: mb {mb} vanished mid-forward", self.stage))?
                .h_out
                .insert(slice, h_out);

            // 4b. Final slice arrived → run the backward sweep for this
            // microbatch in reverse slice order.
            if last {
                self.backward_sweep(mb)?;
                self.mbs.remove(&mb);
            }
        } else {
            // 4. Hand the activation to the next stage.
            obs::emit(SpanKind::SliceFwd, self.stage as i32, mb as u32, slice as u32, off as u64, len as u64, t_us);
            self.send_time(mb, slice, off, len, TimedPhase::Fwd, t0.elapsed().as_secs_f64() * 1e3);
            self.next
                .as_ref()
                .ok_or_else(|| anyhow!("stage {}: no next hop for forward slice", self.stage))?
                .send(Msg::Fwd {
                    mb,
                    slice,
                    off,
                    len,
                    last,
                    payload: FwdPayload::Act(h_out),
                    targets,
                })
                .map_err(|_| anyhow!("stage {}: next stage hung up", self.stage))?;
        }
        Ok(())
    }

    fn handle_bwd(
        &mut self,
        mb: usize,
        slice: usize,
        off: usize,
        len: usize,
        g_h: HostTensor,
    ) -> Result<()> {
        let t0 = Instant::now();
        let t_us = obs::maybe_start();
        let g_h_in = self.backward_one_slice(mb, slice, off, len, g_h)?;
        self.finish_bwd_slice(mb, slice, off, len, g_h_in, t0, t_us)?;
        if self.mbs.get(&mb).map(|s| s.h_in.is_empty()).unwrap_or(false) {
            self.mbs.remove(&mb);
        }
        Ok(())
    }

    /// Backward for one slice on this stage: reads the accumulated K/V
    /// grads for the slice's own keys, runs the recompute-based stage
    /// backward, folds returned context grads into the accumulators.
    /// Returns grad w.r.t. the stage input.
    fn backward_one_slice(
        &mut self,
        mb: usize,
        slice: usize,
        off: usize,
        len: usize,
        g_h: HostTensor,
    ) -> Result<HostTensor> {
        let st = self
            .mbs
            .get_mut(&mb)
            .ok_or_else(|| anyhow!("stage {}: Bwd for unknown mb {mb}", self.stage))?;
        let h_in = st
            .h_in
            .remove(&slice)
            .ok_or_else(|| anyhow!("missing stored activation for slice {slice}"))?;
        // Gradients w.r.t. this slice's own K/V, deposited by later slices
        // (zero for the final slice — nothing attends past it).
        let g_know = st.g_kacc.read_at_axis(2, off, len);
        let g_vnow = st.g_vacc.read_at_axis(2, off, len);

        let (g_h_in, g_kctx, g_vctx) =
            self.backend
                .stage_bwd(&h_in, &st.k_ctx, &st.v_ctx, off, &g_h, &g_know, &g_vnow)?;
        st.g_kacc.add_assign(&g_kctx);
        st.g_vacc.add_assign(&g_vctx);
        Ok(g_h_in)
    }

    /// Route the input-gradient of a finished backward slice: upstream, or
    /// into the embedding backward on the first stage (+ notify the
    /// driver). `t0` is when this slice's backward compute began (for the
    /// timing sample, which must cover embed_bwd too). `t_us` is the
    /// matching span start from [`obs::maybe_start`].
    #[allow(clippy::too_many_arguments)]
    fn finish_bwd_slice(
        &mut self,
        mb: usize,
        slice: usize,
        off: usize,
        len: usize,
        g_h_in: HostTensor,
        t0: Instant,
        t_us: u64,
    ) -> Result<()> {
        if self.is_first {
            let meta = self
                .mbs
                .get(&mb)
                .and_then(|s| s.meta.get(&slice))
                .cloned()
                .ok_or_else(|| anyhow!("missing slice meta"))?;
            let tokens = meta
                .tokens
                .ok_or_else(|| anyhow!("first stage lost slice tokens"))?;
            self.backend.embed_bwd(&tokens, len, off, &g_h_in)?;
            obs::emit(SpanKind::SliceBwd, self.stage as i32, mb as u32, slice as u32, off as u64, len as u64, t_us);
            self.send_time(mb, slice, off, len, TimedPhase::Bwd, t0.elapsed().as_secs_f64() * 1e3);
            self.driver.send(DriverMsg::BwdDone { mb, slice }).ok();
        } else {
            obs::emit(SpanKind::SliceBwd, self.stage as i32, mb as u32, slice as u32, off as u64, len as u64, t_us);
            self.send_time(mb, slice, off, len, TimedPhase::Bwd, t0.elapsed().as_secs_f64() * 1e3);
            self.prev
                .as_ref()
                .ok_or_else(|| anyhow!("stage {}: no prev hop for backward slice", self.stage))?
                .send(Msg::Bwd {
                    mb,
                    slice,
                    off,
                    len,
                    g_h: g_h_in,
                })
                .map_err(|_| anyhow!("stage {}: prev stage hung up", self.stage))?;
        }
        Ok(())
    }

    /// Last stage: backward over all slices of a microbatch in reverse
    /// order, seeding each slice with its head gradient.
    fn backward_sweep(&mut self, mb: usize) -> Result<()> {
        let mut order: Vec<usize> = self
            .mbs
            .get(&mb)
            .map(|s| s.meta.keys().copied().collect())
            .unwrap_or_default();
        order.sort_unstable_by(|a, b| b.cmp(a)); // reverse slice order

        for slice in order {
            let t0 = Instant::now();
            let t_us = obs::maybe_start();
            let (meta, h_out) = {
                let st = self
                    .mbs
                    .get_mut(&mb)
                    .ok_or_else(|| anyhow!("stage {}: mb {mb} vanished mid-sweep", self.stage))?;
                let meta = st
                    .meta
                    .get(&slice)
                    .cloned()
                    .ok_or_else(|| anyhow!("missing meta for slice {slice} in backward sweep"))?;
                let h_out = st
                    .h_out
                    .remove(&slice)
                    .ok_or_else(|| anyhow!("missing head input for slice {slice}"))?;
                (meta, h_out)
            };
            let g_h = self.backend.head_bwd(&h_out, &meta.targets, meta.len)?;
            let g_h_in = self.backward_one_slice(mb, slice, meta.off, meta.len, g_h)?;
            self.finish_bwd_slice(mb, slice, meta.off, meta.len, g_h_in, t0, t_us)?;
        }
        Ok(())
    }
}
