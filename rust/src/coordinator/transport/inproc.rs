//! The default fabric: plain in-process mpsc channels — exactly the
//! wiring the coordinator used before the [`Transport`] trait existed.
//! Zero injected delay, zero loss; `Disconnected` only when a peer
//! thread has really exited. The trait layer adds one virtual dispatch
//! per send/recv, which is noise next to a slice's compute.

use std::sync::mpsc::{channel, Receiver, RecvTimeoutError, Sender};
use std::time::Duration;

use super::super::messages::{DriverMsg, Msg};
use super::{
    Disconnected, DriverRecv, DriverRx, DriverTx, Fabric, MsgRx, MsgTx, StageEndpoint, Transport,
};

/// In-process mpsc transport (the default).
#[derive(Debug, Clone, Copy, Default)]
pub struct InProcTransport;

struct ChanMsgTx(Sender<Msg>);

impl MsgTx for ChanMsgTx {
    fn send(&self, msg: Msg) -> Result<(), Disconnected> {
        self.0.send(msg).map_err(|_| Disconnected)
    }
}

struct ChanMsgRx(Receiver<Msg>);

impl MsgRx for ChanMsgRx {
    fn recv(&mut self) -> Result<Msg, Disconnected> {
        self.0.recv().map_err(|_| Disconnected)
    }
}

struct ChanDriverTx(Sender<DriverMsg>);

impl DriverTx for ChanDriverTx {
    fn send(&self, msg: DriverMsg) -> Result<(), Disconnected> {
        self.0.send(msg).map_err(|_| Disconnected)
    }

    fn clone_box(&self) -> Box<dyn DriverTx> {
        Box::new(ChanDriverTx(self.0.clone()))
    }
}

struct ChanDriverRx(Receiver<DriverMsg>);

impl DriverRx for ChanDriverRx {
    fn recv(&mut self) -> Result<DriverMsg, Disconnected> {
        self.0.recv().map_err(|_| Disconnected)
    }

    fn recv_timeout(&mut self, timeout: Duration) -> DriverRecv {
        match self.0.recv_timeout(timeout) {
            Ok(m) => DriverRecv::Msg(m),
            Err(RecvTimeoutError::Timeout) => DriverRecv::TimedOut,
            Err(RecvTimeoutError::Disconnected) => DriverRecv::Disconnected,
        }
    }
}

impl Transport for InProcTransport {
    fn connect(&self, num_stages: usize) -> Fabric {
        assert!(num_stages >= 1);
        let (driver_tx, driver_rx) = channel::<DriverMsg>();
        let mut senders: Vec<Sender<Msg>> = Vec::with_capacity(num_stages);
        let mut receivers: Vec<Option<Receiver<Msg>>> = Vec::with_capacity(num_stages);
        for _ in 0..num_stages {
            let (tx, rx) = channel::<Msg>();
            senders.push(tx);
            receivers.push(Some(rx));
        }
        let stages = (0..num_stages)
            .map(|s| StageEndpoint {
                inbox: Box::new(ChanMsgRx(receivers[s].take().unwrap())) as Box<dyn MsgRx>,
                next: (s + 1 < num_stages)
                    .then(|| Box::new(ChanMsgTx(senders[s + 1].clone())) as Box<dyn MsgTx>),
                prev: (s > 0)
                    .then(|| Box::new(ChanMsgTx(senders[s - 1].clone())) as Box<dyn MsgTx>),
                driver: Box::new(ChanDriverTx(driver_tx.clone())),
            })
            .collect();
        Fabric {
            to_stages: senders
                .into_iter()
                .map(|tx| Box::new(ChanMsgTx(tx)) as Box<dyn MsgTx>)
                .collect(),
            from_workers: Box::new(ChanDriverRx(driver_rx)),
            stages,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_and_timeout() {
        let mut fabric = InProcTransport.connect(2);
        fabric.to_stages[0].send(Msg::Shutdown).unwrap();
        let ep = &mut fabric.stages[0];
        assert!(matches!(ep.inbox.recv(), Ok(Msg::Shutdown)));
        ep.driver.send(DriverMsg::UpdateDone { stage: 0 }).unwrap();
        match fabric.from_workers.recv_timeout(Duration::from_millis(200)) {
            DriverRecv::Msg(DriverMsg::UpdateDone { stage: 0 }) => {}
            other => panic!("expected UpdateDone, got {other:?}"),
        }
        assert!(matches!(
            fabric.from_workers.recv_timeout(Duration::from_millis(10)),
            DriverRecv::TimedOut
        ));
    }

    #[test]
    fn dropped_receiver_disconnects_sender() {
        let fabric = InProcTransport.connect(1);
        drop(fabric.stages);
        assert_eq!(fabric.to_stages[0].send(Msg::Shutdown), Err(Disconnected));
    }
}
