//! The default fabric: plain in-process mpsc channels — exactly the
//! wiring the coordinator used before the [`Transport`] trait existed.
//! Zero injected delay, zero loss; `Disconnected` only when a peer
//! thread has really exited. The trait layer adds one virtual dispatch
//! per send/recv, which is noise next to a slice's compute.
//!
//! Every endpoint knows its directed [`LinkId`] so sends and deliveries
//! emit `obs` instants (approx wire bytes + dense link index) when the
//! global recorder is on — one relaxed atomic load when it is off.

use std::sync::mpsc::{channel, Receiver, RecvTimeoutError, Sender};
use std::time::Duration;

use super::super::messages::{DriverMsg, Msg};
use super::{
    Disconnected, DriverRecv, DriverRx, DriverTx, Fabric, LinkId, MsgRx, MsgTx, StageEndpoint,
    Transport,
};
use crate::obs::{self, SpanKind};

/// In-process mpsc transport (the default).
#[derive(Debug, Clone, Copy, Default)]
pub struct InProcTransport;

struct ChanMsgTx {
    inner: Sender<Msg>,
    /// Sending endpoint (stage index, or [`obs::DRIVER`]).
    from_stage: i32,
    /// Dense index of the link this sender feeds ([`LinkId::index`]).
    link_idx: u64,
}

impl MsgTx for ChanMsgTx {
    fn send(&self, msg: Msg) -> Result<(), Disconnected> {
        obs::instant(SpanKind::Send, self.from_stage, msg.approx_bytes() as u64, self.link_idx);
        self.inner.send(msg).map_err(|_| Disconnected)
    }
}

struct ChanMsgRx {
    inner: Receiver<Msg>,
    /// Receiving stage (link inference via [`LinkId::incoming`]).
    stage: usize,
    k: usize,
}

impl MsgRx for ChanMsgRx {
    fn recv(&mut self) -> Result<Msg, Disconnected> {
        let msg = self.inner.recv().map_err(|_| Disconnected)?;
        obs::instant(
            SpanKind::Recv,
            self.stage as i32,
            msg.approx_bytes() as u64,
            LinkId::incoming(self.stage, &msg).index(self.k) as u64,
        );
        Ok(msg)
    }
}

struct ChanDriverTx {
    inner: Sender<DriverMsg>,
    from_stage: i32,
    link_idx: u64,
}

impl DriverTx for ChanDriverTx {
    fn send(&self, msg: DriverMsg) -> Result<(), Disconnected> {
        obs::instant(SpanKind::Send, self.from_stage, msg.approx_bytes() as u64, self.link_idx);
        self.inner.send(msg).map_err(|_| Disconnected)
    }

    fn clone_box(&self) -> Box<dyn DriverTx> {
        Box::new(ChanDriverTx {
            inner: self.inner.clone(),
            from_stage: self.from_stage,
            link_idx: self.link_idx,
        })
    }
}

struct ChanDriverRx {
    inner: Receiver<DriverMsg>,
    k: usize,
}

impl ChanDriverRx {
    fn note(&self, msg: &DriverMsg) {
        obs::instant(
            SpanKind::Recv,
            obs::DRIVER,
            msg.approx_bytes() as u64,
            LinkId::ToDriver(msg.source_stage(self.k)).index(self.k) as u64,
        );
    }
}

impl DriverRx for ChanDriverRx {
    fn recv(&mut self) -> Result<DriverMsg, Disconnected> {
        let msg = self.inner.recv().map_err(|_| Disconnected)?;
        self.note(&msg);
        Ok(msg)
    }

    fn recv_timeout(&mut self, timeout: Duration) -> DriverRecv {
        match self.inner.recv_timeout(timeout) {
            Ok(m) => {
                self.note(&m);
                DriverRecv::Msg(m)
            }
            Err(RecvTimeoutError::Timeout) => DriverRecv::TimedOut,
            Err(RecvTimeoutError::Disconnected) => DriverRecv::Disconnected,
        }
    }
}

impl Transport for InProcTransport {
    fn connect(&self, num_stages: usize) -> Fabric {
        assert!(num_stages >= 1);
        let k = num_stages;
        let (driver_tx, driver_rx) = channel::<DriverMsg>();
        let mut senders: Vec<Sender<Msg>> = Vec::with_capacity(k);
        let mut receivers: Vec<Option<Receiver<Msg>>> = Vec::with_capacity(k);
        for _ in 0..k {
            let (tx, rx) = channel::<Msg>();
            senders.push(tx);
            receivers.push(Some(rx));
        }
        let msg_tx = |s: usize, from_stage: i32, link: LinkId| -> Box<dyn MsgTx> {
            Box::new(ChanMsgTx {
                inner: senders[s].clone(),
                from_stage,
                link_idx: link.index(k) as u64,
            })
        };
        let stages = (0..k)
            .map(|s| StageEndpoint {
                inbox: Box::new(ChanMsgRx { inner: receivers[s].take().unwrap(), stage: s, k })
                    as Box<dyn MsgRx>,
                next: (s + 1 < k).then(|| msg_tx(s + 1, s as i32, LinkId::Fwd(s))),
                prev: (s > 0).then(|| msg_tx(s - 1, s as i32, LinkId::Bwd(s))),
                driver: Box::new(ChanDriverTx {
                    inner: driver_tx.clone(),
                    from_stage: s as i32,
                    link_idx: LinkId::ToDriver(s).index(k) as u64,
                }),
            })
            .collect();
        Fabric {
            to_stages: (0..k)
                .map(|s| msg_tx(s, obs::DRIVER, LinkId::DriverTo(s)))
                .collect(),
            from_workers: Box::new(ChanDriverRx { inner: driver_rx, k }),
            stages,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_and_timeout() {
        let mut fabric = InProcTransport.connect(2);
        fabric.to_stages[0].send(Msg::Shutdown).unwrap();
        let ep = &mut fabric.stages[0];
        assert!(matches!(ep.inbox.recv(), Ok(Msg::Shutdown)));
        ep.driver.send(DriverMsg::UpdateDone { stage: 0 }).unwrap();
        match fabric.from_workers.recv_timeout(Duration::from_millis(200)) {
            DriverRecv::Msg(DriverMsg::UpdateDone { stage: 0 }) => {}
            other => panic!("expected UpdateDone, got {other:?}"),
        }
        assert!(matches!(
            fabric.from_workers.recv_timeout(Duration::from_millis(10)),
            DriverRecv::TimedOut
        ));
    }

    #[test]
    fn dropped_receiver_disconnects_sender() {
        let fabric = InProcTransport.connect(1);
        drop(fabric.stages);
        assert_eq!(fabric.to_stages[0].send(Msg::Shutdown), Err(Disconnected));
    }
}
