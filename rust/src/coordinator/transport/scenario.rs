//! Fault-scenario generator: named cluster conditions — a straggler
//! stage, a degraded interconnect, a heterogeneous mix of link speeds —
//! rendered as [`NetConfig`]s for the virtual fabric, plus the
//! synthetic live-sample stream each scenario implies, so the drift
//! detector ([`crate::planner::drift`]) trains on *realistic* inputs
//! instead of scripted traces.
//!
//! The sample synthesis draws per-message delays from the **same**
//! [`LinkSim`] stream the live [`super::VirtualTransport`] would use for
//! that link and seed, so a scenario's synthetic window and an actual
//! pipelined run under the same `NetConfig` see identical injected
//! delays — the property `scenario_samples_match_live_link_draws`
//! pins below.

use super::virt::{LinkCfg, LinkSim, NetConfig};
use super::LinkId;
use crate::perfmodel::CostModel;
use crate::planner::drift::LatencySample;
use crate::util::Rng;

/// Every stage↔stage hop degraded uniformly: the "slow interconnect"
/// scenario (e.g. the paper's p3.16xlarge cluster on a congested fabric).
pub fn degraded_links(k: usize, latency_ms: f64, jitter_ms: f64, seed: u64) -> NetConfig {
    let mut net = NetConfig::seeded(seed);
    let cfg = LinkCfg { latency_ms, jitter_ms, ..Default::default() };
    for s in 0..k.saturating_sub(1) {
        net = net.with_link(LinkId::Fwd(s), cfg).with_link(LinkId::Bwd(s + 1), cfg);
    }
    net
}

/// One stage's outbound hops carry `extra_ms`: the "straggler stage"
/// scenario (one slow host drags every slice that crosses it).
pub fn straggler_stage(k: usize, stage: usize, extra_ms: f64, seed: u64) -> NetConfig {
    let mut net = NetConfig::seeded(seed);
    let cfg = LinkCfg::with_latency(extra_ms);
    if stage + 1 < k {
        net = net.with_link(LinkId::Fwd(stage), cfg);
    }
    if stage > 0 {
        net = net.with_link(LinkId::Bwd(stage), cfg);
    }
    net
}

/// Every hop draws its own latency uniformly from `[lo_ms, hi_ms)`: the
/// "heterogeneous cluster" scenario. Deterministic in `seed`.
pub fn heterogeneous(k: usize, lo_ms: f64, hi_ms: f64, seed: u64) -> NetConfig {
    assert!(hi_ms >= lo_ms);
    let mut rng = Rng::new(seed ^ 0xC0FFEE);
    let mut net = NetConfig::seeded(seed);
    for s in 0..k.saturating_sub(1) {
        let fwd = lo_ms + (hi_ms - lo_ms) * rng.f64();
        let bwd = lo_ms + (hi_ms - lo_ms) * rng.f64();
        net = net
            .with_link(LinkId::Fwd(s), LinkCfg::with_latency(fwd))
            .with_link(LinkId::Bwd(s + 1), LinkCfg::with_latency(bwd));
    }
    net
}

/// The live `LatencySample` stream a stage behind `hop` would report
/// under `net`, for `steps` passes over `slicing`: per slice, the cost
/// model's compute + comm prediction plus the hop delay the virtual
/// fabric would inject for an activation of that slice length
/// (`bytes_per_token · len` wire bytes). Feed the result to a
/// [`crate::planner::drift::DriftDetector`] judged against the *clean*
/// model to exercise drift verdicts on scenario-shaped data.
pub fn live_samples<M: CostModel>(
    model: &M,
    net: &NetConfig,
    k: usize,
    hop: LinkId,
    slicing: &[usize],
    steps: usize,
    bytes_per_token: usize,
) -> Vec<LatencySample> {
    let mut sim = LinkSim::new(net, hop, k);
    let mut now_ms = 0.0;
    let mut out = Vec::with_capacity(steps * slicing.len());
    for _ in 0..steps {
        let mut off = 0u32;
        for &len in slicing {
            let i = len as u32;
            let base = model.t(i, off) + model.t_comm(i);
            // a dropped activation would stall the pipe, not produce a
            // sample — skip it, like the live trace would
            if let Some(delay) = sim.admit(now_ms, bytes_per_token * len) {
                out.push(LatencySample { i, j: off, ms: base + delay });
            }
            now_ms += base;
            off += i;
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::planner::drift::{DriftConfig, DriftDetector, DriftVerdict};

    struct Toy;
    impl CostModel for Toy {
        fn t(&self, i: u32, j: u32) -> f64 {
            1.0 + 0.05 * i as f64 + 1e-4 * i as f64 * j as f64
        }
        fn t_comm(&self, i: u32) -> f64 {
            0.1 + 0.01 * i as f64
        }
    }

    fn feed(det: &mut DriftDetector, samples: &[LatencySample]) {
        for &s in samples {
            det.push(s);
        }
    }

    #[test]
    fn clean_fabric_samples_judge_stable() {
        let net = NetConfig::seeded(5);
        let samples = live_samples(&Toy, &net, 2, LinkId::Fwd(0), &[8, 8, 8, 8], 4, 4);
        let mut det = DriftDetector::new(DriftConfig { window: 16, rel_threshold: 0.2 });
        feed(&mut det, &samples);
        assert!(matches!(det.verdict(&Toy), DriftVerdict::Stable { .. }));
    }

    #[test]
    fn straggler_scenario_drives_a_drift_verdict() {
        // the straggler's extra hop latency dwarfs the clean stage time
        let net = straggler_stage(2, 0, 25.0, 5);
        let samples = live_samples(&Toy, &net, 2, LinkId::Fwd(0), &[8, 8, 8, 8], 4, 4);
        let mut det = DriftDetector::new(DriftConfig { window: 16, rel_threshold: 0.2 });
        feed(&mut det, &samples);
        match det.verdict(&Toy) {
            DriftVerdict::Drifted { factor, .. } => assert!(factor > 2.0, "factor {factor}"),
            v => panic!("expected Drifted, got {v:?}"),
        }
    }

    #[test]
    fn heterogeneous_is_deterministic_and_in_range() {
        let a = heterogeneous(4, 2.0, 6.0, 11);
        let b = heterogeneous(4, 2.0, 6.0, 11);
        let mut distinct = std::collections::HashSet::new();
        for s in 0..3 {
            for id in [LinkId::Fwd(s), LinkId::Bwd(s + 1)] {
                let l = a.link(id).latency_ms;
                assert_eq!(l, b.link(id).latency_ms);
                assert!((2.0..6.0).contains(&l), "{id:?}: {l}");
                distinct.insert(l.to_bits());
            }
        }
        assert!(distinct.len() > 1, "degenerate draw");
        assert_ne!(
            heterogeneous(4, 2.0, 6.0, 12).link(LinkId::Fwd(0)).latency_ms,
            a.link(LinkId::Fwd(0)).latency_ms
        );
    }

    #[test]
    fn degraded_links_cover_both_directions() {
        let net = degraded_links(3, 4.0, 1.0, 0);
        for s in 0..2 {
            assert_eq!(net.link(LinkId::Fwd(s)).latency_ms, 4.0);
            assert_eq!(net.link(LinkId::Bwd(s + 1)).jitter_ms, 1.0);
        }
        assert_eq!(net.link(LinkId::DriverTo(0)).latency_ms, 0.0);
    }

    #[test]
    fn scenario_samples_match_live_link_draws() {
        // the synthetic stream and a fresh LinkSim on the same (net, hop)
        // consume identical RNG streams: same delays, message for message
        let net = degraded_links(2, 3.0, 2.0, 21);
        let slicing = [8usize, 8, 8, 8];
        let samples = live_samples(&Toy, &net, 2, LinkId::Fwd(0), &slicing, 2, 4);
        let mut sim = LinkSim::new(&net, LinkId::Fwd(0), 2);
        let mut now_ms = 0.0;
        let mut idx = 0;
        for _ in 0..2 {
            let mut off = 0u32;
            for &len in &slicing {
                let base = Toy.t(len as u32, off) + Toy.t_comm(len as u32);
                if let Some(d) = sim.admit(now_ms, 4 * len) {
                    assert!((samples[idx].ms - (base + d)).abs() < 1e-12);
                    idx += 1;
                }
                now_ms += base;
                off += len as u32;
            }
        }
        assert_eq!(idx, samples.len());
    }
}
