//! The coordinator's wire: every [`Msg`]/[`DriverMsg`] between the
//! driver and the stage workers flows through a [`Transport`].
//!
//! The trait exists so the *same* coordinator code runs over different
//! fabrics: today's in-process mpsc channels ([`InProcTransport`], the
//! default — behavior-identical to the pre-trait wiring) and a
//! deterministic, seeded mock network ([`VirtualTransport`]) that
//! injects per-link latency, jitter, bandwidth caps, message drops and
//! per-stage kill-switches, recording per-link delivery metrics. The
//! virtual fabric is what lets CI exercise the failure paths (dead
//! stage, dropped message, slow link) deterministically, and what
//! validates the cost model's comm term against *injected* — therefore
//! known-true — latencies (`tests/transport_faults.rs`).
//!
//! # Contract
//!
//! * [`Transport::connect`] wires a `k`-stage pipeline: the driver gets
//!   one [`MsgTx`] per stage plus the merged [`DriverRx`]; stage `s`
//!   gets a [`StageEndpoint`] with its inbox, optional next/prev hops
//!   and a driver handle.
//! * Per-link ordering is FIFO; there is no ordering guarantee *across*
//!   links (exactly the mpsc semantics the workers were built on).
//! * Sends never block and never fail spuriously: `Err(Disconnected)`
//!   means the peer is permanently gone. A transport may also drop a
//!   message silently (lossy network) — endpoints cannot tell, which is
//!   why the driver's collect loops carry a recv deadline
//!   (`TrainConfig::recv_timeout_ms`).
//! * [`DriverRx::recv_timeout`] must return [`DriverRecv::TimedOut`]
//!   after ~`timeout` of *inactivity* — the hook the deadline sits on.

pub mod inproc;
pub mod scenario;
pub mod virt;

pub use inproc::InProcTransport;
pub use virt::{DeliverySample, LinkCfg, NetConfig, VirtualTransport};

use std::time::Duration;

use super::messages::{DriverMsg, Msg};

/// The peer endpoint is permanently gone (thread exited, stage killed).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Disconnected;

impl std::fmt::Display for Disconnected {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "peer disconnected")
    }
}

impl std::error::Error for Disconnected {}

/// Outcome of a deadline-bounded driver receive.
#[derive(Debug)]
pub enum DriverRecv {
    Msg(DriverMsg),
    /// No message arrived within the deadline — a stage is dead, wedged,
    /// or a message was dropped.
    TimedOut,
    /// Every worker-side sender is gone.
    Disconnected,
}

/// Sender half of a worker-bound link (driver→stage or stage→stage).
pub trait MsgTx: Send {
    fn send(&self, msg: Msg) -> Result<(), Disconnected>;
}

/// Receiver half of a stage inbox. `&mut` because virtual receivers keep
/// delivery state (deadlines, kill counters).
pub trait MsgRx: Send {
    /// Block until the next message. `Err` means no message will ever
    /// arrive again (all senders gone, or this stage was killed).
    fn recv(&mut self) -> Result<Msg, Disconnected>;
}

/// Sender half of the stage→driver link. Cloneable so the worker's
/// panic handler can hold a handle independent of the endpoint.
pub trait DriverTx: Send {
    fn send(&self, msg: DriverMsg) -> Result<(), Disconnected>;
    fn clone_box(&self) -> Box<dyn DriverTx>;
}

/// Receiver half of the driver's merged inbox.
pub trait DriverRx: Send {
    fn recv(&mut self) -> Result<DriverMsg, Disconnected>;
    /// Like [`DriverRx::recv`], bounded: give up after `timeout` with no
    /// arrival. An in-flight message whose injected delay crosses the
    /// deadline still counts as activity and is delivered.
    fn recv_timeout(&mut self, timeout: Duration) -> DriverRecv;
}

/// One stage's view of the fabric.
pub struct StageEndpoint {
    /// This stage's inbox (driver + neighbor traffic, merged FIFO-per-link).
    pub inbox: Box<dyn MsgRx>,
    /// Forward hop to stage `s+1`, `None` on the last stage.
    pub next: Option<Box<dyn MsgTx>>,
    /// Backward hop to stage `s-1`, `None` on the first stage.
    pub prev: Option<Box<dyn MsgTx>>,
    /// Upward link to the driver (losses, timings, completions, Fatal).
    pub driver: Box<dyn DriverTx>,
}

/// A fully wired `k`-stage pipeline, as handed to the trainer.
pub struct Fabric {
    /// Driver→stage senders, one per stage (index = stage).
    pub to_stages: Vec<Box<dyn MsgTx>>,
    /// The driver's merged inbox.
    pub from_workers: Box<dyn DriverRx>,
    /// Per-stage endpoints, moved into the worker threads.
    pub stages: Vec<StageEndpoint>,
}

/// A fabric factory: wires all links of a `num_stages` pipeline.
pub trait Transport {
    fn connect(&self, num_stages: usize) -> Fabric;
}

/// Identity of one directed link in a `k`-stage pipeline.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum LinkId {
    /// Driver → stage `s` (token slices into stage 0; update, checkpoint
    /// and shutdown control to every stage).
    DriverTo(usize),
    /// Stage `s` → stage `s+1` (forward activations).
    Fwd(usize),
    /// Stage `s` → stage `s-1` (backward gradients), `s ≥ 1`.
    Bwd(usize),
    /// Stage `s` → driver (losses, timings, completions, Fatal).
    ToDriver(usize),
}

impl LinkId {
    /// Dense index of this link among the `4k-2` links of a `k`-stage
    /// pipeline (used for per-link RNG streams and metrics storage).
    pub fn index(&self, k: usize) -> usize {
        match *self {
            LinkId::DriverTo(s) => s,
            LinkId::Fwd(s) => k + s,
            LinkId::Bwd(s) => k + (k - 1) + (s - 1),
            LinkId::ToDriver(s) => k + 2 * (k - 1) + s,
        }
    }

    /// Total link count of a `k`-stage pipeline.
    pub fn count(k: usize) -> usize {
        4 * k - 2
    }

    /// The link a message arriving at `stage`'s inbox traveled, inferred
    /// from the message variant (each stage has exactly one upstream
    /// source per variant: forwards come from `stage-1` — or the driver
    /// at stage 0 — backwards from `stage+1`, control from the driver).
    /// Used for recv-side span attribution without widening the wire.
    pub fn incoming(stage: usize, msg: &Msg) -> LinkId {
        match msg {
            Msg::Fwd { .. } => {
                if stage == 0 {
                    LinkId::DriverTo(0)
                } else {
                    LinkId::Fwd(stage - 1)
                }
            }
            Msg::Bwd { .. } => LinkId::Bwd(stage + 1),
            Msg::Update { .. } | Msg::Checkpoint { .. } | Msg::Shutdown => LinkId::DriverTo(stage),
        }
    }

    /// Enumerate every link of a `k`-stage pipeline in index order.
    pub fn all(k: usize) -> Vec<LinkId> {
        let mut v = Vec::with_capacity(Self::count(k));
        v.extend((0..k).map(LinkId::DriverTo));
        v.extend((0..k - 1).map(LinkId::Fwd));
        v.extend((1..k).map(LinkId::Bwd));
        v.extend((0..k).map(LinkId::ToDriver));
        v
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn link_indices_are_dense_and_bijective() {
        for k in [1usize, 2, 3, 5] {
            let all = LinkId::all(k);
            assert_eq!(all.len(), LinkId::count(k));
            for (i, l) in all.iter().enumerate() {
                assert_eq!(l.index(k), i, "{l:?} in k={k}");
            }
        }
    }
}
