//! A deterministic, seeded mock network (ROADMAP's "deterministic
//! virtual network"): real threads and real channels, but every link
//! gets a configurable latency, jitter, bandwidth cap and drop
//! probability, plus a per-stage kill-switch for crash-stop fault
//! injection — and every delivery is metered.
//!
//! # Determinism
//!
//! Each link owns a private SplitMix64 stream seeded from
//! `(NetConfig::seed, link index)`, and each link has exactly **one**
//! sending thread (the driver, or one worker), so the per-link sequence
//! of (drop, jitter, queue) draws is a pure function of the config and
//! the sender's message order — identical across runs regardless of OS
//! scheduling. The *injected* delay of each delivery is decided at send
//! time by that stream ([`LinkSim::admit`], exposed for scenario
//! synthesis) and recorded in [`LinkMetrics`]; the receiver then sleeps
//! until the computed due time. Metrics therefore report the injected
//! (intended) delay — deterministic and exactly recoverable by a fit —
//! while wall-clock effects (sleep overshoot) stay out of the record.
//!
//! # Kill-switch
//!
//! `kill_after(stage, n)` lets the stage's inbox deliver exactly `n`
//! messages; popping message `n+1` discards it and reports
//! [`Disconnected`] — the worker thread exits as if the process died.
//! Because the pop itself triggers death, `n` picks *which* driver
//! collect loop observes the loss: before the step's losses, after the
//! losses but before the update ack, or before the checkpoint ack.
//! [`VirtualTransport::kill_stage`] kills immediately instead (a wake
//! envelope unblocks a parked receiver).

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::{channel, Receiver, RecvTimeoutError, Sender};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use super::super::messages::{DriverMsg, Msg};
use super::{
    Disconnected, DriverRecv, DriverRx, DriverTx, Fabric, LinkId, MsgRx, MsgTx, StageEndpoint,
    Transport,
};
use crate::obs::{self, SpanKind};
use crate::util::Rng;

/// Delivery samples kept per link (the fit needs dozens, not millions).
const SAMPLE_CAP: usize = 4096;

/// One link's fault model. The default is a perfect link: zero latency,
/// zero jitter, infinite bandwidth, no drops.
#[derive(Debug, Clone, Copy)]
pub struct LinkCfg {
    /// Fixed propagation delay per message.
    pub latency_ms: f64,
    /// Uniform extra delay in `[0, jitter_ms)` per message.
    pub jitter_ms: f64,
    /// Transmission rate; messages serialize behind each other on the
    /// link. `None` = infinite bandwidth (no transmission term).
    pub bytes_per_ms: Option<f64>,
    /// Probability a message silently vanishes.
    pub drop_prob: f64,
}

impl Default for LinkCfg {
    fn default() -> Self {
        LinkCfg {
            latency_ms: 0.0,
            jitter_ms: 0.0,
            bytes_per_ms: None,
            drop_prob: 0.0,
        }
    }
}

impl LinkCfg {
    pub fn with_latency(latency_ms: f64) -> Self {
        LinkCfg { latency_ms, ..Default::default() }
    }
}

/// Whole-fabric fault configuration.
#[derive(Debug, Clone, Default)]
pub struct NetConfig {
    /// Root seed for every per-link RNG stream.
    pub seed: u64,
    /// Applied to links without an override.
    pub default_link: LinkCfg,
    /// Per-link overrides; the last entry for a link wins.
    pub overrides: Vec<(LinkId, LinkCfg)>,
    /// `(stage, n)`: the stage's inbox delivers exactly `n` messages,
    /// then the stage crash-stops.
    pub kill_after: Vec<(usize, u64)>,
}

impl NetConfig {
    pub fn seeded(seed: u64) -> Self {
        NetConfig { seed, ..Default::default() }
    }

    /// The effective config of `id` (override or default).
    pub fn link(&self, id: LinkId) -> LinkCfg {
        self.overrides
            .iter()
            .rev()
            .find(|(l, _)| *l == id)
            .map(|(_, c)| *c)
            .unwrap_or(self.default_link)
    }

    pub fn with_link(mut self, id: LinkId, cfg: LinkCfg) -> Self {
        self.overrides.push((id, cfg));
        self
    }

    pub fn with_kill_after(mut self, stage: usize, n: u64) -> Self {
        self.kill_after.push((stage, n));
        self
    }

    fn kill_budget(&self, stage: usize) -> u64 {
        self.kill_after
            .iter()
            .rev()
            .find(|(s, _)| *s == stage)
            .map(|(_, n)| *n)
            .unwrap_or(u64::MAX)
    }
}

/// The pure per-link delay law — the single definition both the live
/// fabric and [`super::scenario`]'s synthetic sample streams draw from,
/// so scenarios predict exactly what the transport would inject.
#[derive(Debug, Clone)]
pub struct LinkSim {
    cfg: LinkCfg,
    rng: Rng,
    busy_until_ms: f64,
}

impl LinkSim {
    /// The stream link `id` uses under `net` in a `k`-stage pipeline.
    pub fn new(net: &NetConfig, id: LinkId, k: usize) -> LinkSim {
        LinkSim {
            cfg: net.link(id),
            rng: Rng::new(net.seed ^ (id.index(k) as u64 + 1).wrapping_mul(0x9E3779B97F4A7C15)),
            busy_until_ms: 0.0,
        }
    }

    /// Decide the fate of a `bytes`-byte message sent at `now_ms` on the
    /// link's clock: `Some(delay)` to deliver `delay` ms after the send,
    /// `None` to drop it. Consumes the link's RNG stream and advances
    /// its transmission queue; call once per message, in send order.
    pub fn admit(&mut self, now_ms: f64, bytes: usize) -> Option<f64> {
        let drop_draw = if self.cfg.drop_prob > 0.0 { self.rng.f64() } else { 1.0 };
        let jitter =
            if self.cfg.jitter_ms > 0.0 { self.cfg.jitter_ms * self.rng.f64() } else { 0.0 };
        if drop_draw < self.cfg.drop_prob {
            return None;
        }
        let ready = self.busy_until_ms.max(now_ms);
        let xmit = self.cfg.bytes_per_ms.map_or(0.0, |bw| bytes as f64 / bw.max(1e-9));
        if self.cfg.bytes_per_ms.is_some() {
            self.busy_until_ms = ready + xmit;
        }
        Some((ready - now_ms) + xmit + self.cfg.latency_ms + jitter)
    }
}

/// One recorded delivery on a link.
#[derive(Debug, Clone, Copy)]
pub struct DeliverySample {
    /// Injected delay (queue wait + transmission + latency + jitter).
    pub delay_ms: f64,
    /// Token-slice length for `Fwd`/`Bwd` payloads, `None` for control.
    pub len: Option<usize>,
    pub bytes: usize,
}

/// Per-link delivery metrics.
#[derive(Debug, Clone, Default)]
pub struct LinkMetrics {
    pub sent: u64,
    pub dropped: u64,
    pub bytes: u64,
    pub delay_ms_sum: f64,
    /// First [`SAMPLE_CAP`] deliveries, in send order.
    pub deliveries: Vec<DeliverySample>,
}

impl LinkMetrics {
    pub fn mean_delay_ms(&self) -> f64 {
        if self.sent == 0 {
            0.0
        } else {
            self.delay_ms_sum / self.sent as f64
        }
    }
}

/// Live link: the delay law plus a wall-clock epoch and the meter.
struct LinkState {
    sim: LinkSim,
    epoch: Instant,
    metrics: LinkMetrics,
}

impl LinkState {
    /// Returns the absolute due time, or `None` if dropped.
    fn admit(&mut self, bytes: usize, len: Option<usize>) -> Option<Instant> {
        let now = Instant::now();
        let now_ms = now.duration_since(self.epoch).as_secs_f64() * 1e3;
        match self.sim.admit(now_ms, bytes) {
            None => {
                self.metrics.dropped += 1;
                None
            }
            Some(delay_ms) => {
                self.metrics.sent += 1;
                self.metrics.bytes += bytes as u64;
                self.metrics.delay_ms_sum += delay_ms;
                if self.metrics.deliveries.len() < SAMPLE_CAP {
                    self.metrics.deliveries.push(DeliverySample { delay_ms, len, bytes });
                }
                Some(now + Duration::from_secs_f64(delay_ms.max(0.0) / 1e3))
            }
        }
    }
}

/// Channel envelope: a timed delivery, or a control nudge so a parked
/// receiver re-checks its kill-switch.
enum Env<T> {
    Deliver { due: Instant, msg: T },
    Wake,
}

fn sleep_until(due: Instant) {
    let now = Instant::now();
    if due > now {
        std::thread::sleep(due - now);
    }
}

struct VirtualMsgTx {
    inner: Sender<Env<Msg>>,
    link: Arc<Mutex<LinkState>>,
    /// Sending endpoint (stage index, or [`obs::DRIVER`]).
    from_stage: i32,
    /// Dense index of the link this sender feeds ([`LinkId::index`]).
    link_idx: u64,
}

impl MsgTx for VirtualMsgTx {
    fn send(&self, msg: Msg) -> Result<(), Disconnected> {
        obs::instant(SpanKind::Send, self.from_stage, msg.approx_bytes() as u64, self.link_idx);
        let due = {
            let mut l = self.link.lock().unwrap();
            l.admit(msg.approx_bytes(), msg.slice_len())
        };
        match due {
            None => Ok(()), // dropped: a lossy network tells no one
            Some(due) => self.inner.send(Env::Deliver { due, msg }).map_err(|_| Disconnected),
        }
    }
}

struct VirtualMsgRx {
    inner: Receiver<Env<Msg>>,
    /// Deliveries allowed before crash-stop (`u64::MAX` = never dies).
    kill_after: Arc<AtomicU64>,
    delivered: u64,
    /// Receiving stage + pipeline size (recv-span link inference).
    stage: usize,
    k: usize,
}

impl MsgRx for VirtualMsgRx {
    fn recv(&mut self) -> Result<Msg, Disconnected> {
        loop {
            if self.delivered >= self.kill_after.load(Ordering::Acquire) {
                return Err(Disconnected);
            }
            match self.inner.recv().map_err(|_| Disconnected)? {
                Env::Wake => continue,
                Env::Deliver { due, msg } => {
                    if self.delivered >= self.kill_after.load(Ordering::Acquire) {
                        // the stage died holding this message: discard it
                        return Err(Disconnected);
                    }
                    sleep_until(due);
                    self.delivered += 1;
                    obs::instant(
                        SpanKind::Recv,
                        self.stage as i32,
                        msg.approx_bytes() as u64,
                        LinkId::incoming(self.stage, &msg).index(self.k) as u64,
                    );
                    return Ok(msg);
                }
            }
        }
    }
}

struct VirtualDriverTx {
    inner: Sender<Env<DriverMsg>>,
    link: Arc<Mutex<LinkState>>,
    from_stage: i32,
    link_idx: u64,
}

impl DriverTx for VirtualDriverTx {
    fn send(&self, msg: DriverMsg) -> Result<(), Disconnected> {
        obs::instant(SpanKind::Send, self.from_stage, msg.approx_bytes() as u64, self.link_idx);
        let due = {
            let mut l = self.link.lock().unwrap();
            l.admit(msg.approx_bytes(), None)
        };
        match due {
            None => Ok(()),
            Some(due) => self.inner.send(Env::Deliver { due, msg }).map_err(|_| Disconnected),
        }
    }

    fn clone_box(&self) -> Box<dyn DriverTx> {
        Box::new(VirtualDriverTx {
            inner: self.inner.clone(),
            link: self.link.clone(),
            from_stage: self.from_stage,
            link_idx: self.link_idx,
        })
    }
}

struct VirtualDriverRx {
    inner: Receiver<Env<DriverMsg>>,
    k: usize,
}

impl VirtualDriverRx {
    fn note(&self, msg: &DriverMsg) {
        obs::instant(
            SpanKind::Recv,
            obs::DRIVER,
            msg.approx_bytes() as u64,
            LinkId::ToDriver(msg.source_stage(self.k)).index(self.k) as u64,
        );
    }
}

impl DriverRx for VirtualDriverRx {
    fn recv(&mut self) -> Result<DriverMsg, Disconnected> {
        loop {
            match self.inner.recv().map_err(|_| Disconnected)? {
                Env::Wake => continue,
                Env::Deliver { due, msg } => {
                    sleep_until(due);
                    self.note(&msg);
                    return Ok(msg);
                }
            }
        }
    }

    fn recv_timeout(&mut self, timeout: Duration) -> DriverRecv {
        let deadline = Instant::now() + timeout;
        loop {
            let remaining = deadline.saturating_duration_since(Instant::now());
            match self.inner.recv_timeout(remaining) {
                Err(RecvTimeoutError::Timeout) => return DriverRecv::TimedOut,
                Err(RecvTimeoutError::Disconnected) => return DriverRecv::Disconnected,
                Ok(Env::Wake) => continue,
                Ok(Env::Deliver { due, msg }) => {
                    // an in-flight message is activity: honor its injected
                    // delay even when the due time crosses the deadline
                    sleep_until(due);
                    self.note(&msg);
                    return DriverRecv::Msg(msg);
                }
            }
        }
    }
}

/// Fabric state of the most recent [`Transport::connect`].
#[derive(Default)]
struct Shared {
    num_stages: usize,
    links: Vec<Arc<Mutex<LinkState>>>,
    kills: Vec<Arc<AtomicU64>>,
    /// Keeps one sender per stage inbox for wake nudges. (These also keep
    /// the channels alive; receivers disconnect senders on drop, so a
    /// dead worker still surfaces as `Disconnected` to its peers.)
    wakers: Vec<Sender<Env<Msg>>>,
}

/// The deterministic mock-network transport.
pub struct VirtualTransport {
    cfg: NetConfig,
    shared: Mutex<Shared>,
}

impl VirtualTransport {
    pub fn new(cfg: NetConfig) -> Self {
        VirtualTransport { cfg, shared: Mutex::new(Shared::default()) }
    }

    pub fn config(&self) -> &NetConfig {
        &self.cfg
    }

    /// Crash-stop `stage` now: zero its delivery budget and nudge its
    /// (possibly parked) receiver. No-op before `connect` or for an
    /// out-of-range stage.
    pub fn kill_stage(&self, stage: usize) {
        let shared = self.shared.lock().unwrap();
        if let Some(kill) = shared.kills.get(stage) {
            kill.store(0, Ordering::Release);
            let _ = shared.wakers[stage].send(Env::Wake);
        }
    }

    /// Snapshot of one link's delivery metrics (empty before `connect`).
    pub fn link_metrics(&self, id: LinkId) -> LinkMetrics {
        let shared = self.shared.lock().unwrap();
        if shared.num_stages == 0 {
            return LinkMetrics::default();
        }
        shared.links[id.index(shared.num_stages)].lock().unwrap().metrics.clone()
    }

    /// Drain every link's buffered [`DeliverySample`]s (in [`LinkId::all`]
    /// order), leaving the cumulative counters untouched. Draining resets
    /// each link's sample buffer, so periodic callers — e.g. a driver
    /// feeding per-link delays into [`crate::obs::anomaly`] — see each
    /// delivery exactly once and the [`SAMPLE_CAP`] ceiling never starves
    /// later windows. Links with no new deliveries are omitted.
    pub fn take_deliveries(&self) -> Vec<(LinkId, Vec<DeliverySample>)> {
        let shared = self.shared.lock().unwrap();
        LinkId::all(shared.num_stages)
            .into_iter()
            .filter_map(|id| {
                let mut l = shared.links[id.index(shared.num_stages)].lock().unwrap();
                if l.metrics.deliveries.is_empty() {
                    None
                } else {
                    Some((id, std::mem::take(&mut l.metrics.deliveries)))
                }
            })
            .collect()
    }

    /// Snapshot of every link's metrics, in [`LinkId::all`] order.
    pub fn all_metrics(&self) -> Vec<(LinkId, LinkMetrics)> {
        let shared = self.shared.lock().unwrap();
        LinkId::all(shared.num_stages)
            .into_iter()
            .map(|id| {
                let m = shared.links[id.index(shared.num_stages)].lock().unwrap().metrics.clone();
                (id, m)
            })
            .collect()
    }
}

impl Transport for VirtualTransport {
    fn connect(&self, num_stages: usize) -> Fabric {
        assert!(num_stages >= 1);
        let k = num_stages;
        let epoch = Instant::now();
        let links: Vec<Arc<Mutex<LinkState>>> = LinkId::all(k)
            .into_iter()
            .map(|id| {
                Arc::new(Mutex::new(LinkState {
                    sim: LinkSim::new(&self.cfg, id, k),
                    epoch,
                    metrics: LinkMetrics::default(),
                }))
            })
            .collect();
        let kills: Vec<Arc<AtomicU64>> =
            (0..k).map(|s| Arc::new(AtomicU64::new(self.cfg.kill_budget(s)))).collect();

        let (driver_tx, driver_rx) = channel::<Env<DriverMsg>>();
        let mut stage_txs: Vec<Sender<Env<Msg>>> = Vec::with_capacity(k);
        let mut stage_rxs: Vec<Option<Receiver<Env<Msg>>>> = Vec::with_capacity(k);
        for _ in 0..k {
            let (tx, rx) = channel::<Env<Msg>>();
            stage_txs.push(tx);
            stage_rxs.push(Some(rx));
        }

        let link = |id: LinkId| links[id.index(k)].clone();
        let msg_tx = |to: usize, from_stage: i32, id: LinkId| -> Box<dyn MsgTx> {
            Box::new(VirtualMsgTx {
                inner: stage_txs[to].clone(),
                link: link(id),
                from_stage,
                link_idx: id.index(k) as u64,
            })
        };
        let stages = (0..k)
            .map(|s| StageEndpoint {
                inbox: Box::new(VirtualMsgRx {
                    inner: stage_rxs[s].take().unwrap(),
                    kill_after: kills[s].clone(),
                    delivered: 0,
                    stage: s,
                    k,
                }) as Box<dyn MsgRx>,
                next: (s + 1 < k).then(|| msg_tx(s + 1, s as i32, LinkId::Fwd(s))),
                prev: (s > 0).then(|| msg_tx(s - 1, s as i32, LinkId::Bwd(s))),
                driver: Box::new(VirtualDriverTx {
                    inner: driver_tx.clone(),
                    link: link(LinkId::ToDriver(s)),
                    from_stage: s as i32,
                    link_idx: LinkId::ToDriver(s).index(k) as u64,
                }),
            })
            .collect();
        let to_stages = (0..k).map(|s| msg_tx(s, obs::DRIVER, LinkId::DriverTo(s))).collect();

        *self.shared.lock().unwrap() = Shared { num_stages: k, links, kills, wakers: stage_txs };
        Fabric { to_stages, from_workers: Box::new(VirtualDriverRx { inner: driver_rx, k }), stages }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn link_sim_is_deterministic_per_seed() {
        let net = NetConfig {
            seed: 42,
            default_link: LinkCfg {
                latency_ms: 2.0,
                jitter_ms: 3.0,
                bytes_per_ms: Some(1000.0),
                drop_prob: 0.3,
            },
            ..Default::default()
        };
        let mut a = LinkSim::new(&net, LinkId::Fwd(0), 2);
        let mut b = LinkSim::new(&net, LinkId::Fwd(0), 2);
        let mut dropped = 0;
        for i in 0..200 {
            let now = i as f64 * 0.5;
            let da = a.admit(now, 512);
            assert_eq!(da, b.admit(now, 512));
            match da {
                None => dropped += 1,
                Some(d) => assert!(d >= 2.0 && d.is_finite()),
            }
        }
        assert!(dropped > 20 && dropped < 120, "drop_prob 0.3 drew {dropped}/200");
        // distinct links draw distinct streams
        let mut c = LinkSim::new(&net, LinkId::Bwd(1), 2);
        let same = (0..50).filter(|&i| a.admit(i as f64, 64) == c.admit(i as f64, 64)).count();
        assert!(same < 50);
    }

    #[test]
    fn bandwidth_serializes_back_to_back_sends() {
        let net = NetConfig {
            default_link: LinkCfg {
                bytes_per_ms: Some(100.0),
                ..Default::default()
            },
            ..Default::default()
        };
        let mut sim = LinkSim::new(&net, LinkId::Fwd(0), 2);
        // two 1000-byte messages at t=0: 10 ms each, second queues
        assert_eq!(sim.admit(0.0, 1000), Some(10.0));
        assert_eq!(sim.admit(0.0, 1000), Some(20.0));
        // after the queue drains, no residual wait
        assert_eq!(sim.admit(100.0, 1000), Some(10.0));
    }

    #[test]
    fn override_precedence_is_last_wins() {
        let net = NetConfig::seeded(1)
            .with_link(LinkId::Fwd(0), LinkCfg::with_latency(5.0))
            .with_link(LinkId::Fwd(0), LinkCfg::with_latency(9.0));
        assert_eq!(net.link(LinkId::Fwd(0)).latency_ms, 9.0);
        assert_eq!(net.link(LinkId::Fwd(1)).latency_ms, 0.0);
    }

    #[test]
    fn injected_latency_is_recorded_and_enforced() {
        let net = NetConfig::seeded(7).with_link(LinkId::DriverTo(0), LinkCfg::with_latency(30.0));
        let vt = VirtualTransport::new(net);
        let mut fabric = vt.connect(2);
        let t0 = Instant::now();
        fabric.to_stages[0].send(Msg::Shutdown).unwrap();
        assert!(matches!(fabric.stages[0].inbox.recv(), Ok(Msg::Shutdown)));
        assert!(t0.elapsed() >= Duration::from_millis(25));
        let m = vt.link_metrics(LinkId::DriverTo(0));
        assert_eq!(m.sent, 1);
        assert!((m.deliveries[0].delay_ms - 30.0).abs() < 1e-9);
    }

    #[test]
    fn kill_after_budget_delivers_exactly_n() {
        let net = NetConfig::seeded(0).with_kill_after(0, 1);
        let vt = VirtualTransport::new(net);
        let mut fabric = vt.connect(1);
        fabric.to_stages[0].send(Msg::Update { step: 1, lr: 0.1 }).unwrap();
        fabric.to_stages[0].send(Msg::Update { step: 2, lr: 0.1 }).unwrap();
        assert!(matches!(fabric.stages[0].inbox.recv(), Ok(Msg::Update { step: 1, .. })));
        assert_eq!(fabric.stages[0].inbox.recv().err(), Some(Disconnected));
    }

    #[test]
    fn kill_stage_unblocks_a_parked_receiver() {
        let vt = VirtualTransport::new(NetConfig::default());
        let mut fabric = vt.connect(1);
        let mut inbox = fabric.stages.remove(0).inbox;
        let h = std::thread::spawn(move || inbox.recv().err());
        std::thread::sleep(Duration::from_millis(50));
        vt.kill_stage(0);
        assert_eq!(h.join().unwrap(), Some(Disconnected));
    }

    #[test]
    fn take_deliveries_drains_samples_but_keeps_counters() {
        let net = NetConfig::seeded(7).with_link(LinkId::DriverTo(0), LinkCfg::with_latency(5.0));
        let vt = VirtualTransport::new(net);
        let mut fabric = vt.connect(1);
        for _ in 0..3 {
            fabric.to_stages[0].send(Msg::Update { step: 1, lr: 0.1 }).unwrap();
        }
        let drained = vt.take_deliveries();
        let (id, samples) = drained
            .iter()
            .find(|(id, _)| *id == LinkId::DriverTo(0))
            .expect("driver link has samples");
        assert_eq!((*id, samples.len()), (LinkId::DriverTo(0), 3));
        assert!(samples.iter().all(|s| (s.delay_ms - 5.0).abs() < 1e-9));
        // second drain sees nothing new; cumulative counters survive
        assert!(vt.take_deliveries().iter().all(|(l, _)| *l != LinkId::DriverTo(0)));
        let m = vt.link_metrics(LinkId::DriverTo(0));
        assert_eq!(m.sent, 3);
        assert!(m.deliveries.is_empty());
        // and the buffer refills after a drain
        fabric.to_stages[0].send(Msg::Update { step: 2, lr: 0.1 }).unwrap();
        assert_eq!(vt.take_deliveries().len(), 1);
        for _ in 0..4 {
            let _ = fabric.stages[0].inbox.recv();
        }
    }

    #[test]
    fn full_drop_link_delivers_nothing_and_counts() {
        let net = NetConfig::seeded(3).with_link(
            LinkId::DriverTo(0),
            LinkCfg { drop_prob: 1.0, ..Default::default() },
        );
        let vt = VirtualTransport::new(net);
        let mut fabric = vt.connect(1);
        for _ in 0..5 {
            fabric.to_stages[0].send(Msg::Shutdown).unwrap();
        }
        let m = vt.link_metrics(LinkId::DriverTo(0));
        assert_eq!((m.sent, m.dropped), (0, 5));
        // nothing ever arrives: a zero-budget timeout probe via try-ish recv
        vt.kill_stage(0);
        assert_eq!(fabric.stages[0].inbox.recv().err(), Some(Disconnected));
    }
}
