//! Minimal JSON parser/serializer (substrate module).
//!
//! This workspace builds fully offline, so instead of serde we carry a
//! small recursive-descent JSON implementation: enough for the AOT
//! manifest (`artifacts/manifest.json`), config files, bench reports, and
//! Chrome traces. Numbers are f64 (manifest values are small ints, exact
//! in f64); strings support the standard escapes incl. \uXXXX.

use std::collections::BTreeMap;
use std::fmt::Write as _;

/// A JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    /// BTreeMap for deterministic serialization order.
    Obj(BTreeMap<String, Json>),
}

impl Json {
    // ---- constructors ----
    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn arr(vals: Vec<Json>) -> Json {
        Json::Arr(vals)
    }

    // ---- accessors ----
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// `get` that errors with the key name — manifest reads want loud
    /// failures, not silent Nones.
    pub fn req(&self, key: &str) -> Result<&Json, String> {
        self.get(key).ok_or_else(|| format!("missing key '{key}'"))
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_u32(&self) -> Option<u32> {
        self.as_f64().map(|f| f as u32)
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|f| f as usize)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn members(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    // ---- parsing ----
    pub fn parse(text: &str) -> Result<Json, String> {
        let mut p = Parser {
            b: text.as_bytes(),
            i: 0,
        };
        p.ws();
        let v = p.value()?;
        p.ws();
        if p.i != p.b.len() {
            return Err(format!("trailing garbage at byte {}", p.i));
        }
        Ok(v)
    }

    // ---- serialization ----
    pub fn to_string(&self) -> String {
        let mut s = String::new();
        self.write(&mut s);
        s
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 9e15 {
                    let _ = write!(out, "{}", *n as i64);
                } else {
                    let _ = write!(out, "{n}");
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(v) => {
                out.push('[');
                for (i, x) in v.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    x.write(out);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(out, k);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

impl From<f64> for Json {
    fn from(n: f64) -> Json {
        Json::Num(n)
    }
}
impl From<u32> for Json {
    fn from(n: u32) -> Json {
        Json::Num(n as f64)
    }
}
impl From<usize> for Json {
    fn from(n: usize) -> Json {
        Json::Num(n as f64)
    }
}
impl From<&str> for Json {
    fn from(s: &str) -> Json {
        Json::Str(s.to_string())
    }
}
impl From<String> for Json {
    fn from(s: String) -> Json {
        Json::Str(s)
    }
}
impl From<bool> for Json {
    fn from(b: bool) -> Json {
        Json::Bool(b)
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn eat(&mut self, c: u8) -> Result<(), String> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(format!(
                "expected '{}' at byte {}, found {:?}",
                c as char,
                self.i,
                self.peek().map(|b| b as char)
            ))
        }
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json, String> {
        if self.b[self.i..].starts_with(s.as_bytes()) {
            self.i += s.len();
            Ok(v)
        } else {
            Err(format!("bad literal at byte {}", self.i))
        }
    }

    fn value(&mut self) -> Result<Json, String> {
        match self.peek() {
            Some(b'n') => self.lit("null", Json::Null),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'"') => self.string().map(Json::Str),
            Some(b'[') => self.array(),
            Some(b'{') => self.object(),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            other => Err(format!("unexpected {:?} at byte {}", other.map(|b| b as char), self.i)),
        }
    }

    fn array(&mut self) -> Result<Json, String> {
        self.eat(b'[')?;
        let mut v = Vec::new();
        self.ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            self.ws();
            v.push(self.value()?);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(v));
                }
                _ => return Err(format!("expected ',' or ']' at byte {}", self.i)),
            }
        }
    }

    fn object(&mut self) -> Result<Json, String> {
        self.eat(b'{')?;
        let mut m = BTreeMap::new();
        self.ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.ws();
            let k = self.string()?;
            self.ws();
            self.eat(b':')?;
            self.ws();
            let v = self.value()?;
            m.insert(k, v);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(m));
                }
                _ => return Err(format!("expected ',' or '}}' at byte {}", self.i)),
            }
        }
    }

    fn string(&mut self) -> Result<String, String> {
        self.eat(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek() {
                None => return Err("unterminated string".into()),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.i += 1;
                    match self.peek() {
                        Some(b'"') => s.push('"'),
                        Some(b'\\') => s.push('\\'),
                        Some(b'/') => s.push('/'),
                        Some(b'n') => s.push('\n'),
                        Some(b't') => s.push('\t'),
                        Some(b'r') => s.push('\r'),
                        Some(b'b') => s.push('\u{8}'),
                        Some(b'f') => s.push('\u{c}'),
                        Some(b'u') => {
                            if self.i + 4 >= self.b.len() {
                                return Err("bad \\u escape".into());
                            }
                            let hex = std::str::from_utf8(&self.b[self.i + 1..self.i + 5])
                                .map_err(|_| "bad \\u escape")?;
                            let code =
                                u32::from_str_radix(hex, 16).map_err(|_| "bad \\u escape")?;
                            s.push(char::from_u32(code).unwrap_or('\u{FFFD}'));
                            self.i += 4;
                        }
                        other => return Err(format!("bad escape {other:?}")),
                    }
                    self.i += 1;
                }
                Some(_) => {
                    // consume one UTF-8 char
                    let rest = std::str::from_utf8(&self.b[self.i..])
                        .map_err(|_| "invalid utf-8".to_string())?;
                    let c = rest.chars().next().unwrap();
                    s.push(c);
                    self.i += c.len_utf8();
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, String> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while matches!(
            self.peek(),
            Some(c) if c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-')
        ) {
            self.i += 1;
        }
        std::str::from_utf8(&self.b[start..self.i])
            .ok()
            .and_then(|s| s.parse::<f64>().ok())
            .map(Json::Num)
            .ok_or_else(|| format!("bad number at byte {start}"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_nested() {
        let src = r#"{"a": [1, 2.5, -3], "b": {"c": "x\ny", "d": true}, "e": null}"#;
        let v = Json::parse(src).unwrap();
        assert_eq!(v.get("a").unwrap().as_arr().unwrap()[1].as_f64(), Some(2.5));
        assert_eq!(v.get("b").unwrap().get("c").unwrap().as_str(), Some("x\ny"));
        assert_eq!(v.get("e"), Some(&Json::Null));
        let back = Json::parse(&v.to_string()).unwrap();
        assert_eq!(v, back);
    }

    #[test]
    fn integers_serialize_without_decimal_point() {
        assert_eq!(Json::Num(42.0).to_string(), "42");
        assert_eq!(Json::Num(2.5).to_string(), "2.5");
        assert_eq!(Json::Num(-7.0).to_string(), "-7");
    }

    #[test]
    fn parses_real_manifest_shapes() {
        let src = r#"{"model": {"hidden": 128, "seed": 0},
                      "buckets": [16, 32, 64, 128],
                      "executables": {"stage_fwd_s16": {"inputs": [{"name": "h", "shape": [4, 16, 128], "dtype": "float32"}]}}}"#;
        let v = Json::parse(src).unwrap();
        assert_eq!(v.req("model").unwrap().req("hidden").unwrap().as_u32(), Some(128));
        let buckets: Vec<u32> = v.req("buckets").unwrap().as_arr().unwrap().iter().map(|b| b.as_u32().unwrap()).collect();
        assert_eq!(buckets, vec![16, 32, 64, 128]);
        let exe = v.req("executables").unwrap().get("stage_fwd_s16").unwrap();
        let shape = exe.get("inputs").unwrap().as_arr().unwrap()[0].get("shape").unwrap();
        assert_eq!(shape.as_arr().unwrap().len(), 3);
    }

    #[test]
    fn escapes_roundtrip() {
        let v = Json::Str("quote\" slash\\ nl\n tab\t ctrl\u{1}".into());
        let s = v.to_string();
        assert_eq!(Json::parse(&s).unwrap(), v);
    }

    #[test]
    fn unicode_escape_parses() {
        let v = Json::parse(r#""éA""#).unwrap();
        assert_eq!(v.as_str(), Some("éA"));
    }

    #[test]
    fn errors_are_positioned() {
        assert!(Json::parse("{\"a\": }").is_err());
        assert!(Json::parse("[1, 2").is_err());
        assert!(Json::parse("[] junk").unwrap_err().contains("trailing"));
        assert!(Json::parse("").is_err());
    }

    #[test]
    fn req_reports_missing_key() {
        let v = Json::parse("{}").unwrap();
        assert!(v.req("nope").unwrap_err().contains("nope"));
    }

    #[test]
    fn deterministic_object_order() {
        let a = Json::parse(r#"{"z": 1, "a": 2}"#).unwrap().to_string();
        let b = Json::parse(r#"{"a": 2, "z": 1}"#).unwrap().to_string();
        assert_eq!(a, b);
    }
}
