//! Lightweight property-testing harness (substrate module — proptest is
//! not in the offline crate set).
//!
//! [`run_cases`] drives a closure with a deterministic [`Gen`] per case and
//! reports the failing seed on panic, so failures reproduce exactly:
//!
//! ```ignore
//! prop::run_cases(256, |g| {
//!     let lens = g.composition(64, 8);
//!     assert_eq!(lens.iter().sum::<u32>(), 64);
//! });
//! ```

use super::Rng;

/// Per-case random generator with domain-specific helpers.
pub struct Gen {
    rng: Rng,
    pub case: u64,
}

impl Gen {
    pub fn new(seed: u64) -> Gen {
        Gen {
            rng: Rng::new(seed),
            case: seed,
        }
    }

    /// Uniform u32 in [lo, hi] inclusive.
    pub fn int(&mut self, lo: u32, hi: u32) -> u32 {
        assert!(lo <= hi);
        lo + self.rng.below(hi - lo + 1)
    }

    /// Uniform f64 in [lo, hi).
    pub fn float(&mut self, lo: f64, hi: f64) -> f64 {
        lo + self.rng.f64() * (hi - lo)
    }

    pub fn bool(&mut self) -> bool {
        self.rng.below(2) == 1
    }

    /// Pick one element of a slice.
    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.rng.below(xs.len() as u32) as usize]
    }

    /// Random composition of `total` into parts that are multiples of
    /// `granularity` (total must be a multiple too) — random slicing
    /// schemes for the solver/sim/coordinator invariants.
    pub fn composition(&mut self, total: u32, granularity: u32) -> Vec<u32> {
        assert!(granularity >= 1 && total % granularity == 0 && total > 0);
        let units = total / granularity;
        let mut lens = Vec::new();
        let mut rem = units;
        while rem > 0 {
            let take = self.int(1, rem);
            lens.push(take * granularity);
            rem -= take;
        }
        lens
    }

    /// Vector of `n` floats in [lo, hi).
    pub fn floats(&mut self, n: usize, lo: f64, hi: f64) -> Vec<f64> {
        (0..n).map(|_| self.float(lo, hi)).collect()
    }
}

/// Run `cases` deterministic property cases; on panic, re-raise with the
/// case index so `Gen::new(i)` reproduces it.
pub fn run_cases(cases: u64, mut body: impl FnMut(&mut Gen)) {
    for i in 0..cases {
        let mut g = Gen::new(i);
        let r = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| body(&mut g)));
        if let Err(e) = r {
            let msg = e
                .downcast_ref::<String>()
                .cloned()
                .or_else(|| e.downcast_ref::<&str>().map(|s| s.to_string()))
                .unwrap_or_else(|| "<non-string panic>".into());
            panic!("property failed at case {i}: {msg}");
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn composition_covers_total() {
        run_cases(200, |g| {
            let total = g.int(1, 32) * 8;
            let lens = g.composition(total, 8);
            assert_eq!(lens.iter().sum::<u32>(), total);
            assert!(lens.iter().all(|&l| l > 0 && l % 8 == 0));
        });
    }

    #[test]
    fn int_bounds_inclusive() {
        run_cases(100, |g| {
            let x = g.int(3, 5);
            assert!((3..=5).contains(&x));
        });
    }

    #[test]
    fn failing_case_reports_index() {
        let r = std::panic::catch_unwind(|| {
            run_cases(50, |g| {
                assert!(g.case != 17, "boom");
            })
        });
        let msg = *r.unwrap_err().downcast::<String>().unwrap();
        assert!(msg.contains("case 17"), "{msg}");
    }

    #[test]
    fn deterministic_across_runs() {
        let mut a = Vec::new();
        run_cases(5, |g| a.push(g.int(0, 1000)));
        let mut b = Vec::new();
        run_cases(5, |g| b.push(g.int(0, 1000)));
        assert_eq!(a, b);
    }
}
