//! Small shared utilities: summary statistics, a deterministic RNG, a
//! wall-clock timer, and the offline-build substrates (JSON, CLI parsing,
//! property testing).

pub mod cli;
pub mod json;
pub mod prop;

use std::time::Instant;

/// Mean / standard deviation over repeated latency measurements — Table
/// 2–4 report "mean ± std over 10 runs" and so do we.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Stats {
    pub mean: f64,
    pub std: f64,
    pub min: f64,
    pub max: f64,
    pub n: usize,
}

impl Stats {
    pub fn from_samples(xs: &[f64]) -> Stats {
        assert!(!xs.is_empty());
        let n = xs.len();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = if n > 1 {
            xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / (n - 1) as f64
        } else {
            0.0
        };
        let min = xs.iter().copied().fold(f64::INFINITY, f64::min);
        let max = xs.iter().copied().fold(f64::NEG_INFINITY, f64::max);
        Stats {
            mean,
            std: var.sqrt(),
            min,
            max,
            n,
        }
    }

    /// "1.328 ± 0.037" (paper table style, seconds with 3 decimals).
    pub fn pm(&self) -> String {
        format!("{:.3} ± {:.3}", self.mean, self.std)
    }
}

/// Time a closure in milliseconds.
pub fn time_ms<R>(f: impl FnOnce() -> R) -> (R, f64) {
    let t0 = Instant::now();
    let r = f();
    (r, t0.elapsed().as_secs_f64() * 1e3)
}

/// SplitMix64 — deterministic, dependency-free RNG for synthetic data.
#[derive(Debug, Clone)]
pub struct Rng(u64);

impl Rng {
    pub fn new(seed: u64) -> Self {
        Rng(seed.wrapping_add(0x9E3779B97F4A7C15))
    }

    pub fn next_u64(&mut self) -> u64 {
        self.0 = self.0.wrapping_add(0x9E3779B97F4A7C15);
        let mut z = self.0;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
        z ^ (z >> 31)
    }

    /// Uniform in [0, m).
    pub fn below(&mut self, m: u32) -> u32 {
        (self.next_u64() % m as u64) as u32
    }

    /// Uniform in [0, 1).
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 / (1u64 << 53) as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stats_basic() {
        let s = Stats::from_samples(&[1.0, 2.0, 3.0]);
        assert!((s.mean - 2.0).abs() < 1e-12);
        assert!((s.std - 1.0).abs() < 1e-12);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 3.0);
        assert_eq!(s.pm(), "2.000 ± 1.000");
    }

    #[test]
    fn stats_single_sample_has_zero_std() {
        let s = Stats::from_samples(&[5.0]);
        assert_eq!(s.std, 0.0);
    }

    #[test]
    fn rng_deterministic_and_in_range() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            let x = a.below(17);
            assert_eq!(x, b.below(17));
            assert!(x < 17);
        }
        let f = a.f64();
        assert!((0.0..1.0).contains(&f));
    }

    #[test]
    fn rng_seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        let same = (0..32).filter(|_| a.next_u64() == b.next_u64()).count();
        assert_eq!(same, 0);
    }
}
