//! Tiny CLI argument parser (substrate module, offline build — no clap).
//!
//! Supports `--flag`, `--key value`, `--key=value` and positional args.
//! Value-binding is greedy: `--name tok` treats `tok` as the value unless
//! it starts with `--`; bare boolean flags should therefore come last or
//! use `--flag=true`.

use std::collections::BTreeMap;

#[derive(Debug, Clone, Default)]
pub struct Args {
    pub positional: Vec<String>,
    opts: BTreeMap<String, String>,
    flags: Vec<String>,
}

impl Args {
    /// Parse a raw arg list (without argv[0]).
    pub fn parse(raw: &[String]) -> Args {
        let mut a = Args::default();
        let mut i = 0;
        while i < raw.len() {
            let arg = &raw[i];
            if let Some(stripped) = arg.strip_prefix("--") {
                if let Some((k, v)) = stripped.split_once('=') {
                    a.opts.insert(k.to_string(), v.to_string());
                } else if i + 1 < raw.len() && !raw[i + 1].starts_with("--") {
                    a.opts.insert(stripped.to_string(), raw[i + 1].clone());
                    i += 1;
                } else {
                    a.flags.push(stripped.to_string());
                }
            } else {
                a.positional.push(arg.clone());
            }
            i += 1;
        }
        a
    }

    pub fn from_env() -> Args {
        Args::parse(&std::env::args().skip(1).collect::<Vec<_>>())
    }

    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name) || self.opts.get(name).map(|v| v == "true").unwrap_or(false)
    }

    pub fn get(&self, name: &str) -> Option<&str> {
        self.opts.get(name).map(|s| s.as_str())
    }

    pub fn get_or<'a>(&'a self, name: &str, default: &'a str) -> &'a str {
        self.get(name).unwrap_or(default)
    }

    pub fn u32(&self, name: &str, default: u32) -> u32 {
        self.get(name)
            .map(|v| v.parse().unwrap_or_else(|_| panic!("--{name} expects an integer, got '{v}'")))
            .unwrap_or(default)
    }

    pub fn usize(&self, name: &str, default: usize) -> usize {
        self.u32(name, default as u32) as usize
    }

    pub fn f64(&self, name: &str, default: f64) -> f64 {
        self.get(name)
            .map(|v| v.parse().unwrap_or_else(|_| panic!("--{name} expects a number, got '{v}'")))
            .unwrap_or(default)
    }

    /// Comma-separated u32 list.
    pub fn u32_list(&self, name: &str, default: &[u32]) -> Vec<u32> {
        match self.get(name) {
            None => default.to_vec(),
            Some(v) => v
                .split(',')
                .map(|x| x.trim().parse().unwrap_or_else(|_| panic!("--{name}: bad entry '{x}'")))
                .collect(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(&s.split_whitespace().map(String::from).collect::<Vec<_>>())
    }

    #[test]
    fn positional_and_options() {
        let a = parse("solve out.json --setting 5 --eps=0.1 --verbose");
        assert_eq!(a.positional, vec!["solve", "out.json"]);
        assert_eq!(a.u32("setting", 0), 5);
        assert_eq!(a.f64("eps", 0.0), 0.1);
        assert!(a.flag("verbose"));
        assert!(!a.flag("quiet"));
    }

    #[test]
    fn defaults_apply() {
        let a = parse("run");
        assert_eq!(a.u32("steps", 100), 100);
        assert_eq!(a.get_or("mode", "sim"), "sim");
        assert_eq!(a.u32_list("buckets", &[16, 32]), vec![16, 32]);
    }

    #[test]
    fn list_parsing() {
        let a = parse("--buckets 16,32,64");
        assert_eq!(a.u32_list("buckets", &[]), vec![16, 32, 64]);
    }

    #[test]
    fn flag_followed_by_flag() {
        let a = parse("--dry-run --setting 3");
        assert!(a.flag("dry-run"));
        assert_eq!(a.u32("setting", 0), 3);
    }

    #[test]
    #[should_panic(expected = "expects an integer")]
    fn bad_integer_panics() {
        parse("--setting five").u32("setting", 0);
    }
}
