//! TeraPipe — token-level pipeline parallelism for training large-scale
//! language models (Li et al., ICML 2021), reproduced as a three-layer
//! Rust + JAX + Pallas system.
//!
//! The paper's contribution lives in this crate:
//!
//! * [`solver`] — the dynamic-programming slicing algorithm (Alg. 1, Eq. 5–8)
//!   plus the joint batch+token extension and the 1-D knapsack (§3.4).
//! * [`perfmodel`] — the `t_fwd(i, j) = t_fwd(i, 0) + t_ctx(i, j)` latency
//!   model (Eq. 9), both the analytic V100-shaped instantiation used for the
//!   paper-scale experiments and a least-squares fit over real measurements.
//! * [`sim`] — a discrete-event pipeline simulator standing in for the
//!   48-node GPU testbed (DESIGN.md §2): executes GPipe, TeraPipe and
//!   memory-capped (Appendix A) schedules under the cost model.
//! * [`backend`] — pluggable stage compute behind the `StageBackend`
//!   trait: the default pure-Rust multi-threaded CPU cell (exact
//!   transformer forward/backward + Adam, no artifacts needed) and, with
//!   the `pjrt` feature, the AOT-compiled XLA executables.
//! * [`runtime`] — host tensors + the artifact manifest; with `pjrt`, a
//!   PJRT wrapper (via the `xla` crate) that loads the HLO text artifacts
//!   lowered by `python/compile/aot.py` and executes them on the CPU
//!   device; python never runs on the request path.
//! * [`coordinator`] — the real execution engine: one worker thread per
//!   pipeline cell, token slices flowing downstream and gradients flowing
//!   back upstream, with the context-gradient accumulation that makes the
//!   pipelined backward exactly equal the unsliced one. Generic over the
//!   stage backend; runs in the default build.
//! * [`planner`] — the online planner service: long-lived plan ownership
//!   with a cost-table cache, warm-started re-solves on cluster deltas,
//!   and a drift-aware replan loop with hysteresis (`terapipe autotune`).
//! * [`obs`] — unified tracing & metrics: a lock-free span recorder
//!   threaded through the measure→plan→execute loop, Chrome/Perfetto
//!   trace export, a Prometheus-style metrics snapshot, and the
//!   exec↔sim span differential that localizes cost-model misses to a
//!   (stage, slice) cell.
//! * [`config`] — model / cluster / parallelism configuration incl. the
//!   paper's Table 1 presets.
//! * [`data`] — synthetic corpus + byte-level tokenizer + batcher for the
//!   end-to-end training example.

pub mod backend;
pub mod config;
pub mod coordinator;
pub mod data;
pub mod experiments;
pub mod obs;
pub mod perfmodel;
pub mod planner;
pub mod runtime;
pub mod sim;
pub mod solver;
pub mod util;
