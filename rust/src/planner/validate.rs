//! Sim-backed plan validation: replay an emitted plan through the
//! discrete-event engine and check the planner's predicted Eq. 5 latency
//! against the simulated makespan.
//!
//! The replay regime is the one where Eq. 5 is exact (the same regime the
//! `solver_sim_differential` suite pins): every stage executes the plan's
//! slice stream in order, each item's duration the Eq. 4 stage time
//! `t(lᵢ, ctxᵢ) + t_comm(lᵢ)`, no extra edge delay. The simulator then
//! independently re-derives `Σ tᵢ + (K-1)·max tᵢ`; a planner that
//! mis-predicts (stale totals, wrong scale factor, budget-vs-achieved
//! `t_max` confusion) diverges within 1e-9 and `terapipe autotune`
//! refuses the plan.

use crate::perfmodel::CostModel;
use crate::sim::engine::simulate;
use crate::sim::{Item, Phase, Plan};
use crate::solver::SliceScheme;

/// Simulated pipeline latency (ms) of slicing `lens` on a `stages`-deep
/// pipeline under `model` — the independent judge for a planner
/// prediction.
pub fn replay_latency<M: CostModel>(model: &M, lens: &[u32], stages: u32) -> f64 {
    assert!(!lens.is_empty() && stages >= 1);
    let stages = stages as usize;
    let mut durs = Vec::with_capacity(lens.len());
    let mut ctx = 0u32;
    for &l in lens {
        durs.push(model.t(l, ctx) + model.t_comm(l));
        ctx += l;
    }
    let m = durs.len();
    let mut items = Vec::with_capacity(m * stages);
    for s in 0..stages {
        for (i, &d) in durs.iter().enumerate() {
            let mut deps = Vec::new();
            if s > 0 {
                deps.push(((s - 1) * m + i, 0.0));
            }
            if i > 0 {
                deps.push((s * m + i - 1, 0.0));
            }
            items.push(Item {
                id: s * m + i,
                stage: s,
                phase: Phase::Fwd,
                part: 0,
                slice: i,
                dur_ms: d,
                deps,
                priority: (s * m + i) as u64,
            });
        }
    }
    simulate(&Plan {
        stages,
        items,
        mem_cap_parts: None,
        flush_barrier: false,
    })
    .expect("replay plan has no cap/barrier, cannot deadlock")
    .makespan_ms
}

/// Replay `scheme` and compare against its own predicted latency.
/// `Ok(simulated_ms)` when |sim − predicted| ≤ `tol_ms`, `Err` with both
/// numbers otherwise.
pub fn validate_scheme<M: CostModel>(
    model: &M,
    scheme: &SliceScheme,
    stages: u32,
    tol_ms: f64,
) -> Result<f64, String> {
    let sim = replay_latency(model, &scheme.lens, stages);
    if (sim - scheme.latency_ms).abs() <= tol_ms {
        Ok(sim)
    } else {
        Err(format!(
            "plan {} predicts {:.9} ms but replays at {:.9} ms (Δ {:.3e} > {tol_ms:.1e})",
            scheme.notation(),
            scheme.latency_ms,
            sim,
            (sim - scheme.latency_ms).abs()
        ))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::solver::dp::solve_tokens;

    struct Toy;
    impl CostModel for Toy {
        fn t(&self, i: u32, j: u32) -> f64 {
            0.5 + 0.02 * i as f64 + 1e-4 * i as f64 * j as f64
        }
        fn t_comm(&self, i: u32) -> f64 {
            0.01 * i as f64
        }
    }

    #[test]
    fn solver_plan_validates() {
        let (scheme, _) = solve_tokens(&Toy, 256, 8, 8, 0.0);
        let sim = validate_scheme(&Toy, &scheme, 8, 1e-9).unwrap();
        assert!((sim - scheme.latency_ms).abs() < 1e-9);
    }

    #[test]
    fn corrupted_prediction_is_rejected() {
        let (mut scheme, _) = solve_tokens(&Toy, 256, 8, 8, 0.0);
        scheme.latency_ms *= 1.01;
        let err = validate_scheme(&Toy, &scheme, 8, 1e-9).unwrap_err();
        assert!(err.contains("replays at"), "{err}");
    }

    #[test]
    fn replay_matches_closed_form_eq5() {
        let lens = [64u32, 128, 64];
        let sim = replay_latency(&Toy, &lens, 5);
        let want = crate::perfmodel::pipeline_latency(&Toy, &lens, 5);
        assert!((sim - want).abs() < 1e-9, "{sim} vs {want}");
    }
}
