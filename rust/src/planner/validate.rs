//! Sim-backed plan validation: replay an emitted plan through the
//! simulator and check the planner's predicted Eq. 5 latency against the
//! simulated makespan.
//!
//! The replay regime is the one where Eq. 5 is exact (the same regime the
//! `solver_sim_differential` suite pins): every stage executes the plan's
//! slice stream in order, each item's duration the Eq. 4 stage time
//! `t(lᵢ, ctxᵢ) + t_comm(lᵢ)`, no extra edge delay. The simulator then
//! independently re-derives `Σ tᵢ + (K-1)·max tᵢ`; a planner that
//! mis-predicts (stale totals, wrong scale factor, budget-vs-achieved
//! `t_max` confusion) diverges within 1e-9 and `terapipe autotune`
//! refuses the plan.
//!
//! Validation is the fast path now: replay plans are *regular* (per-stage
//! chains, no barrier/cap), so [`crate::sim::engine::simulate_opts`]
//! routes them to the closed-form wavefront evaluator with trace
//! collection off — no event heap, no span bookkeeping. Batch consumers
//! (the autotune trace replayer, the planner property suites) build their
//! replay plans with [`replay_plan`] and fan them through
//! [`validate_plans`], which rides [`crate::sim::engine::simulate_many`]
//! across rayon with one reusable `SimArena` per worker.

use crate::perfmodel::CostModel;
use crate::sim::engine::{simulate_many, simulate_opts};
use crate::sim::schedule::stream_plan;
use crate::sim::Plan;
use crate::solver::SliceScheme;

/// Build the Eq. 5-exact replay plan for slicing `lens` on a
/// `stages`-deep pipeline under `model`: the K×M replay stream
/// ([`stream_plan`]) with each slice's duration the Eq. 4 stage time
/// `t(lᵢ, ctxᵢ) + t_comm(lᵢ)`. The model snapshot is baked into the item
/// durations, so the plan can be validated later (batched) even after
/// the planner's live model has drifted on.
pub fn replay_plan<M: CostModel>(model: &M, lens: &[u32], stages: u32) -> Plan {
    assert!(!lens.is_empty() && stages >= 1);
    let mut durs = Vec::with_capacity(lens.len());
    let mut ctx = 0u32;
    for &l in lens {
        durs.push(model.t(l, ctx) + model.t_comm(l));
        ctx += l;
    }
    stream_plan(&durs, stages as usize)
}

/// Simulated pipeline latency (ms) of slicing `lens` on a `stages`-deep
/// pipeline under `model` — the independent judge for a planner
/// prediction. Single-plan convenience over the wavefront fast path
/// (trace off); use [`replay_plan`] + [`validate_plans`] to batch.
/// `Err` when the plan cannot be simulated at all — a degenerate model
/// (NaN/negative stage times) is a validation failure, not a panic: this
/// runs inside the long-lived planner service.
pub fn replay_latency<M: CostModel>(model: &M, lens: &[u32], stages: u32) -> Result<f64, String> {
    let t_us = crate::obs::maybe_start();
    let out = simulate_opts(&replay_plan(model, lens, stages), false)?.makespan_ms;
    crate::obs::emit(
        crate::obs::SpanKind::SimReplay,
        crate::obs::DRIVER,
        0,
        0,
        1,
        0,
        t_us,
    );
    Ok(out)
}

/// Replay `scheme` and compare against its own predicted latency.
/// `Ok(simulated_ms)` when |sim − predicted| ≤ `tol_ms`, `Err` with both
/// numbers otherwise.
pub fn validate_scheme<M: CostModel>(
    model: &M,
    scheme: &SliceScheme,
    stages: u32,
    tol_ms: f64,
) -> Result<f64, String> {
    let sim = replay_latency(model, &scheme.lens, stages)?;
    if (sim - scheme.latency_ms).abs() <= tol_ms {
        Ok(sim)
    } else {
        Err(format!(
            "plan {} predicts {:.9} ms but replays at {:.9} ms (Δ {:.3e} > {tol_ms:.1e})",
            scheme.notation(),
            scheme.latency_ms,
            sim,
            (sim - scheme.latency_ms).abs()
        ))
    }
}

/// Batched validation: replay every plan (built with [`replay_plan`]
/// against the model snapshot it was solved under) through
/// `simulate_many` with trace collection off, and compare each simulated
/// makespan to its predicted latency. Returns the simulated latencies in
/// input order, or the first divergence.
pub fn validate_plans(
    plans: &[Plan],
    predicted_ms: &[f64],
    tol_ms: f64,
) -> Result<Vec<f64>, String> {
    if plans.len() != predicted_ms.len() {
        // Err, not assert: this runs inside the long-lived planner
        // service, which must survive a caller that drops an infeasible
        // scheme from one of the two lists
        return Err(format!(
            "one prediction per replay plan: {} plans vs {} predictions",
            plans.len(),
            predicted_ms.len()
        ));
    }
    let t_us = crate::obs::maybe_start();
    let results = simulate_many(plans, false);
    crate::obs::emit(
        crate::obs::SpanKind::SimReplay,
        crate::obs::DRIVER,
        0,
        0,
        plans.len() as u64,
        0,
        t_us,
    );
    let mut sims = Vec::with_capacity(plans.len());
    for (i, (r, &pred)) in results.into_iter().zip(predicted_ms).enumerate() {
        let sim = r
            .map_err(|e| format!("replay plan #{i} failed to simulate: {e}"))?
            .makespan_ms;
        if (sim - pred).abs() > tol_ms {
            return Err(format!(
                "plan #{i} predicts {pred:.9} ms but replays at {sim:.9} ms (Δ {:.3e} > {tol_ms:.1e})",
                (sim - pred).abs()
            ));
        }
        sims.push(sim);
    }
    Ok(sims)
}

/// Batched [`validate_scheme`]: all schemes solved under one `model`
/// snapshot, each with its own stage count.
pub fn validate_schemes<M: CostModel>(
    model: &M,
    schemes: &[(&SliceScheme, u32)],
    tol_ms: f64,
) -> Result<Vec<f64>, String> {
    let plans: Vec<Plan> =
        schemes.iter().map(|(s, k)| replay_plan(model, &s.lens, *k)).collect();
    let preds: Vec<f64> = schemes.iter().map(|(s, _)| s.latency_ms).collect();
    validate_plans(&plans, &preds, tol_ms)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::wavefront;
    use crate::solver::dp::solve_tokens;

    struct Toy;
    impl CostModel for Toy {
        fn t(&self, i: u32, j: u32) -> f64 {
            0.5 + 0.02 * i as f64 + 1e-4 * i as f64 * j as f64
        }
        fn t_comm(&self, i: u32) -> f64 {
            0.01 * i as f64
        }
    }

    #[test]
    fn solver_plan_validates() {
        let (scheme, _) = solve_tokens(&Toy, 256, 8, 8, 0.0);
        let sim = validate_scheme(&Toy, &scheme, 8, 1e-9).unwrap();
        assert!((sim - scheme.latency_ms).abs() < 1e-9);
    }

    #[test]
    fn corrupted_prediction_is_rejected() {
        let (mut scheme, _) = solve_tokens(&Toy, 256, 8, 8, 0.0);
        scheme.latency_ms *= 1.01;
        let err = validate_scheme(&Toy, &scheme, 8, 1e-9).unwrap_err();
        assert!(err.contains("replays at"), "{err}");
    }

    #[test]
    fn replay_matches_closed_form_eq5() {
        let lens = [64u32, 128, 64];
        let sim = replay_latency(&Toy, &lens, 5).unwrap();
        let want = crate::perfmodel::pipeline_latency(&Toy, &lens, 5);
        assert!((sim - want).abs() < 1e-9, "{sim} vs {want}");
    }

    #[test]
    fn degenerate_model_is_an_error_not_a_panic() {
        struct Nan;
        impl CostModel for Nan {
            fn t(&self, _i: u32, _j: u32) -> f64 {
                f64::NAN
            }
            fn t_comm(&self, _i: u32) -> f64 {
                0.0
            }
        }
        let err = replay_latency(&Nan, &[64, 64], 4).unwrap_err();
        assert!(err.contains("duration"), "{err}");
    }

    #[test]
    fn replay_plans_are_regular_so_validation_takes_the_wavefront_path() {
        let p = replay_plan(&Toy, &[64, 128, 64], 5);
        assert!(wavefront::is_regular(&p));
    }

    #[test]
    fn batched_validation_matches_per_scheme_validation() {
        let (a, _) = solve_tokens(&Toy, 256, 8, 8, 0.0);
        let (b, _) = solve_tokens(&Toy, 128, 4, 8, 0.0);
        let sims = validate_schemes(&Toy, &[(&a, 8), (&b, 4)], 1e-9).unwrap();
        assert_eq!(sims.len(), 2);
        assert!((sims[0] - validate_scheme(&Toy, &a, 8, 1e-9).unwrap()).abs() == 0.0);
        assert!((sims[1] - validate_scheme(&Toy, &b, 4, 1e-9).unwrap()).abs() == 0.0);
    }

    #[test]
    fn batched_validation_reports_the_first_divergence() {
        let (a, _) = solve_tokens(&Toy, 256, 8, 8, 0.0);
        let plans = vec![replay_plan(&Toy, &a.lens, 8), replay_plan(&Toy, &a.lens, 8)];
        let preds = vec![a.latency_ms, a.latency_ms * 1.5];
        let err = validate_plans(&plans, &preds, 1e-9).unwrap_err();
        assert!(err.contains("plan #1"), "{err}");
    }
}
