//! Warm-started `t_max` enumeration.
//!
//! A cold solve spends its probe budget binary-searching the *whole*
//! candidate pool for the feasibility boundary (the first budget
//! Algorithm 1 can satisfy) — `O(log |pool|)` full feasibility DPs —
//! before the blocked parallel scan runs. After a small cluster delta the
//! boundary barely moves, so the warm path **seeds the search from the
//! previous winner's neighborhood** instead of from scratch:
//!
//! 1. Probe the candidate nearest the (delta-rescaled) previous winner's
//!    `t_max`.
//! 2. Gallop (exponentially growing steps) toward the boundary until it
//!    is bracketed — `O(log shift)` probes when the boundary moved by
//!    `shift` candidates, so a good hint costs ~3 probes where the cold
//!    search pays ~`log₂ |pool|`.
//! 3. Binary-search inside the bracket; when the gallop had to leave the
//!    `[hint/γ, hint·γ]` window, that *is* the cold fallback — the
//!    bracket has degenerated to the full-pool search and the report
//!    marks the window as missed.
//! 4. Run the engine's **identical** blocked parallel scan
//!    ([`engine::scan_from`]) from the boundary.
//!
//! Because feasibility is monotone in `t_max`, galloping + bracketed
//! binary search finds *exactly* the index the cold binary search finds,
//! and the scan is the same code — so the warm solve is **bit-identical**
//! to the cold one (plan, latency, tie-breaks), which
//! `rust/tests/planner_warm_equivalence.rs` pins across 100+ randomized
//! cluster-delta sequences. Only the probe count changes.
//!
//! (The scan itself cannot be narrowed without breaking exactness: a
//! feasible candidate below any window can still be the Eq. 5 winner, so
//! every candidate from the boundary to the pruning break must be
//! evaluated — warm or cold. The planner's other warm lever is the
//! cost-table cache, which removes the densification cost entirely on
//! scale-only deltas.)

use crate::perfmodel::TableCostModel;
use crate::solver::dp::{self, SolveStats};
use crate::solver::engine::{self, EnumResult};
use crate::solver::SliceScheme;

/// What the warm enumeration did — telemetry for the replan log and the
/// planner bench.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct WarmReport {
    /// Candidate-index window `[lo, hi]` implied by the hint and γ.
    pub window: (usize, usize),
    /// First-feasible candidate index the search settled on.
    pub boundary: usize,
    /// The boundary's budget value (ms) — the seed the planner stores for
    /// the *next* warm solve (rescaled by the cluster delta).
    pub boundary_tmax: f64,
    /// Feasibility probes spent (backstop + gallop + bracket search).
    pub probes: usize,
    /// Full evaluations the scan ran (same count as a cold scan).
    pub evals: usize,
    /// True when the boundary lay inside the window — the warm seed did
    /// its job. False is the documented cold fallback.
    pub hit: bool,
}

/// Default multiplicative half-width of the warm window: a hint is
/// considered "good" while the boundary stays within `[hint/γ, hint·γ]`.
pub const DEFAULT_WINDOW: f64 = 1.3;

/// Warm-started equivalent of `engine::enumerate_par`: same contract
/// (`eval`, monotone `feasible`), bit-identical result, with the
/// feasibility search seeded at `hint` — the previous solve's boundary
/// budget, rescaled by the caller for the cluster delta.
pub(crate) fn enumerate_warm<P, E, F>(
    stages: u32,
    cands: &[f64],
    hint: f64,
    gamma: f64,
    feasible: F,
    eval: E,
) -> (EnumResult<P>, WarmReport)
where
    P: Send,
    E: Fn(f64) -> Option<(f64, P)> + Sync,
    F: Fn(f64) -> bool,
{
    let mut rep = WarmReport::default();
    if cands.is_empty() {
        rep.hit = true;
        return (
            EnumResult { best: None, dps_run: 0, probe_dps: 0 },
            rep,
        );
    }
    let gamma = if gamma > 1.0 { gamma } else { DEFAULT_WINDOW };
    let last = cands.len() - 1;

    // Backstop: if even the loosest budget is infeasible, the cold search
    // finds nothing either.
    rep.probes += 1;
    if !feasible(cands[last]) {
        rep.hit = true;
        rep.window = (last, last);
        return (
            EnumResult { best: None, dps_run: 0, probe_dps: rep.probes },
            rep,
        );
    }

    let h = cands.partition_point(|&c| c < hint).min(last);
    rep.window = (
        cands.partition_point(|&c| c < hint / gamma).min(last),
        cands
            .partition_point(|&c| c <= hint * gamma)
            .saturating_sub(1)
            .min(last),
    );

    // Gallop from the hint to bracket the feasibility boundary:
    // afterwards `lb == 0 || !feasible(cands[lb-1])` is NOT yet known,
    // but `cands[ub]` is feasible and every probed index < lb was
    // infeasible — the invariants the bracketed binary search needs.
    let mut lb; // lowest index that may still be the boundary
    let mut ub; // known-feasible index
    rep.probes += 1;
    if feasible(cands[h]) {
        ub = h;
        lb = 0;
        let mut off = 1usize;
        while ub > 0 {
            let p = ub.saturating_sub(off);
            rep.probes += 1;
            if feasible(cands[p]) {
                ub = p;
                off *= 2;
            } else {
                lb = p + 1;
                break;
            }
        }
    } else {
        lb = h + 1;
        ub = last;
        let mut off = 1usize;
        loop {
            let p = h + off;
            if p >= last {
                break; // `last` is the known-feasible bound
            }
            rep.probes += 1;
            if feasible(cands[p]) {
                ub = p;
                break;
            }
            lb = p + 1;
            off *= 2;
        }
    }
    // Binary search inside the bracket — exactly the cold search's loop,
    // on a (usually much) smaller range.
    while lb < ub {
        let mid = lb + (ub - lb) / 2;
        rep.probes += 1;
        if feasible(cands[mid]) {
            ub = mid;
        } else {
            lb = mid + 1;
        }
    }
    rep.boundary = lb;
    rep.boundary_tmax = cands[lb];
    rep.hit = lb >= rep.window.0 && lb <= rep.window.1;

    let (best, dps_run) = engine::scan_from(stages, cands, lb, eval);
    rep.evals = dps_run;
    (
        EnumResult {
            best,
            dps_run,
            probe_dps: rep.probes,
        },
        rep,
    )
}

/// Warm-started §3.3 token solve over a pre-densified table: identical
/// candidate pool and eval closure as [`dp::solve_tokens_table`], with
/// the feasibility search seeded at `hint_tmax`. Bit-identical output
/// (scheme and latency) to the cold solve.
pub fn solve_tokens_table_warm(
    table: &TableCostModel,
    stages: u32,
    eps_ms: f64,
    hint_tmax: f64,
    gamma: f64,
) -> (SliceScheme, SolveStats, WarmReport) {
    let cands = engine::dedup_candidates(table.stage_time_candidates(), eps_ms);
    let (r, rep) = enumerate_warm(
        stages,
        &cands,
        hint_tmax,
        gamma,
        |tmax| dp::solve_fixed_tmax(table, tmax).is_some(),
        dp::token_eval(table, stages),
    );
    let (scheme, stats) = dp::finish(table.granularity(), cands.len(), r);
    (scheme, stats, rep)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::perfmodel::CostModel;
    use crate::solver::dp::solve_tokens_table;
    use crate::util::prop;

    struct Affine {
        over: f64,
        lin: f64,
        ctx: f64,
        comm: f64,
    }
    impl CostModel for Affine {
        fn t(&self, i: u32, j: u32) -> f64 {
            self.over + self.lin * i as f64 + self.ctx * i as f64 * j as f64
        }
        fn t_comm(&self, _i: u32) -> f64 {
            self.comm
        }
    }

    fn random_table(g: &mut prop::Gen) -> TableCostModel {
        let m = Affine {
            over: g.float(0.01, 2.0),
            lin: g.float(0.001, 0.1),
            ctx: g.float(0.0, 3e-4),
            comm: g.float(0.0, 0.3),
        };
        let gran = *g.choose(&[8u32, 16, 32]);
        let l = g.int(2, 20) * gran;
        TableCostModel::build(&m, l, gran)
    }

    /// Any hint — good, terrible, or degenerate — must still produce the
    /// cold solve's exact result.
    #[test]
    fn prop_warm_matches_cold_for_arbitrary_hints() {
        prop::run_cases(120, |g| {
            let table = random_table(g);
            let stages = g.int(1, 24);
            let eps = *g.choose(&[0.0f64, 0.1]);
            let (cold, cold_stats) = solve_tokens_table(&table, stages, eps);
            let hint = match g.int(0, 3) {
                0 => cold.t_max_ms,                     // near-perfect
                1 => cold.t_max_ms * g.float(0.3, 3.0), // off by a delta
                2 => g.float(1e-6, 1e4),                // wild
                _ => 0.0,                               // degenerate
            };
            let (warm, warm_stats, rep) =
                solve_tokens_table_warm(&table, stages, eps, hint, DEFAULT_WINDOW);
            assert_eq!(warm.lens, cold.lens, "case {} hint={hint}", g.case);
            assert!(
                warm.total_ms == cold.total_ms
                    && warm.t_max_ms == cold.t_max_ms
                    && warm.latency_ms == cold.latency_ms,
                "case {}: warm {warm:?} vs cold {cold:?}",
                g.case
            );
            // the scan is shared code: identical evaluation count
            assert_eq!(warm_stats.dps_run, cold_stats.dps_run, "case {}", g.case);
            assert_eq!(rep.evals, warm_stats.dps_run);
        });
    }

    /// Seeding at the previous boundary finds it in O(1) probes — fewer
    /// than the cold full-pool binary search on any pool where log₂ is
    /// non-trivial.
    #[test]
    fn good_hint_beats_cold_probe_count() {
        let mut g = prop::Gen::new(42);
        for _ in 0..20 {
            let table = random_table(&mut g);
            let stages = 16;
            let (_, cold_stats) = solve_tokens_table(&table, stages, 0.0);
            if cold_stats.probe_dps < 6 {
                continue; // pool too small for the comparison to mean much
            }
            // the exact seed a planner would carry: the previous solve's
            // boundary budget
            let cands = engine::dedup_candidates(table.stage_time_candidates(), 0.0);
            let boundary = cands
                .iter()
                .copied()
                .find(|&t| dp::solve_fixed_tmax(&table, t).is_some())
                .expect("loosest budget is feasible");
            let (_, warm_stats, rep) =
                solve_tokens_table_warm(&table, stages, 0.0, boundary, DEFAULT_WINDOW);
            assert!(rep.hit, "boundary hint must land in the window: {rep:?}");
            assert!(
                warm_stats.probe_dps < cold_stats.probe_dps,
                "warm probes {} vs cold {} (report {rep:?})",
                warm_stats.probe_dps,
                cold_stats.probe_dps
            );
        }
    }

    #[test]
    fn empty_pool_and_infeasible_pool_behave_like_cold() {
        let mut g = prop::Gen::new(7);
        let table = random_table(&mut g);
        let (r, rep) = enumerate_warm(
            4,
            &[],
            1.0,
            DEFAULT_WINDOW,
            |t| dp::solve_fixed_tmax(&table, t).is_some(),
            dp::token_eval(&table, 4),
        );
        assert!(r.best.is_none() && rep.hit);
        // all-infeasible pool: the backstop probe answers immediately
        let tiny = table.at(1, 0) * 0.25;
        let (r, rep) = enumerate_warm(
            4,
            &[tiny * 0.5, tiny],
            tiny,
            DEFAULT_WINDOW,
            |t| dp::solve_fixed_tmax(&table, t).is_some(),
            dp::token_eval(&table, 4),
        );
        assert!(r.best.is_none());
        assert_eq!(rep.probes, 1);
        assert_eq!(rep.evals, 0);
    }

    /// A hint far outside the pool still terminates and reports the miss
    /// (the documented cold fallback).
    #[test]
    fn wild_hints_report_window_miss() {
        let mut g = prop::Gen::new(3);
        let table = random_table(&mut g);
        let (cold, _) = solve_tokens_table(&table, 8, 0.0);
        for hint in [1e-9, 1e9] {
            let (warm, _, rep) = solve_tokens_table_warm(&table, 8, 0.0, hint, DEFAULT_WINDOW);
            assert_eq!(warm.lens, cold.lens, "hint={hint}");
            // boundary may coincidentally sit at a pool edge the window
            // covers; for these extreme hints it should not
            if !rep.hit {
                assert!(rep.boundary < rep.window.0 || rep.boundary > rep.window.1);
            }
        }
    }
}
