//! Drift detection over live latency samples, and the hysteresis rule
//! that decides when a detected drift is worth a plan switch.
//!
//! The planner owns a model it last solved against. Live samples — the
//! `(i, j, ms)` stage-time observations the measurement harness
//! ([`crate::perfmodel::measure`]) produces on the real runtime — stream
//! in; when the observed latencies depart from the solved-against model
//! by more than `rel_threshold` (windowed mean relative error), the
//! detector reports drift together with a **fitted rescale factor** (the
//! median observed/predicted ratio — robust to outlier samples the same
//! way `measure`'s median-of-repeats is). The planner folds that factor
//! into its cumulative compute scale and re-solves warm; for shape drift
//! (the ratio spread is wide, a single factor cannot explain the window)
//! the samples can instead be refit through the full Eq. 9 pipeline
//! ([`DriftDetector::refit_ctx`] → [`crate::perfmodel::linear`]).
//!
//! Switching is **hysteretic**: a fresh solve replaces the active plan
//! only when its predicted Eq. 5 latency beats the active plan's
//! (re-evaluated under the *new* model) by more than `hysteresis_rel` —
//! replanning is cheap with the warm engine, but a plan switch
//! resteers the runtime (new slice buckets, new schedule), so marginal
//! wins are not worth the churn.

use std::collections::VecDeque;

use crate::perfmodel::linear::{CtxCoeffs, LinearCtxModel};
use crate::perfmodel::CostModel;

/// One observed stage-time sample: a slice of `i` tokens over `j` tokens
/// of context took `ms` (computation + transmission, as Eq. 4 counts it).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct LatencySample {
    pub i: u32,
    pub j: u32,
    pub ms: f64,
}

/// Detector configuration.
#[derive(Debug, Clone, Copy)]
pub struct DriftConfig {
    /// Samples kept in the sliding window (and the minimum needed before
    /// drift is ever reported).
    pub window: usize,
    /// Mean relative |observed − predicted| / predicted above which the
    /// window counts as drifted.
    pub rel_threshold: f64,
}

impl Default for DriftConfig {
    fn default() -> Self {
        DriftConfig {
            window: 16,
            rel_threshold: 0.05,
        }
    }
}

/// Verdict over the current sample window.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum DriftVerdict {
    /// Not enough samples yet.
    Warmup,
    /// Window agrees with the model within the threshold.
    Stable { mean_rel_err: f64 },
    /// Window departs from the model: `factor` is the median
    /// observed/predicted ratio to fold into the compute scale.
    Drifted { mean_rel_err: f64, factor: f64 },
}

/// Sliding-window drift detector.
#[derive(Debug, Clone)]
pub struct DriftDetector {
    cfg: DriftConfig,
    samples: VecDeque<LatencySample>,
}

impl DriftDetector {
    pub fn new(cfg: DriftConfig) -> Self {
        DriftDetector {
            cfg,
            samples: VecDeque::with_capacity(cfg.window.max(1)),
        }
    }

    pub fn config(&self) -> &DriftConfig {
        &self.cfg
    }

    pub fn len(&self) -> usize {
        self.samples.len()
    }

    pub fn is_empty(&self) -> bool {
        self.samples.is_empty()
    }

    /// Push one observation, evicting the oldest beyond the window.
    pub fn push(&mut self, s: LatencySample) {
        if self.samples.len() == self.cfg.window.max(1) {
            self.samples.pop_front();
        }
        self.samples.push_back(s);
    }

    /// Drop the window (after the planner has acted on a verdict, so the
    /// same samples don't trigger twice).
    pub fn clear(&mut self) {
        self.samples.clear();
    }

    /// Judge the window against `model` — the model the active plan was
    /// solved against.
    ///
    /// Samples whose prediction is non-positive or non-finite (a cost
    /// model evaluated outside its fitted range can return 0), and
    /// observations that are non-finite, carry no usable ratio: they are
    /// excluded from the window's statistics rather than poisoning them
    /// (one NaN ratio used to panic the sort on a live run). A window
    /// with **no** usable sample judges as [`DriftVerdict::Warmup`].
    pub fn verdict<M: CostModel>(&self, model: &M) -> DriftVerdict {
        if self.samples.len() < self.cfg.window.max(1) {
            return DriftVerdict::Warmup;
        }
        let mut ratios = Vec::with_capacity(self.samples.len());
        let mut sum_rel = 0.0;
        for s in &self.samples {
            let pred = model.t(s.i, s.j) + model.t_comm(s.i);
            if !pred.is_finite() || pred <= 0.0 || !s.ms.is_finite() {
                continue;
            }
            ratios.push(s.ms / pred);
            sum_rel += ((s.ms - pred) / pred).abs();
        }
        if ratios.is_empty() {
            return DriftVerdict::Warmup;
        }
        let mean_rel_err = sum_rel / ratios.len() as f64;
        if mean_rel_err <= self.cfg.rel_threshold {
            return DriftVerdict::Stable { mean_rel_err };
        }
        ratios.sort_by(f64::total_cmp);
        let factor = ratios[ratios.len() / 2];
        DriftVerdict::Drifted { mean_rel_err, factor }
    }

    /// Uniform-vs-shape discriminator: the relative interquartile
    /// spread `(p75 − p25) / median` of the window's
    /// observed/predicted ratios. Near 0 means one rescale factor
    /// explains the whole window (uniform drift — a global slowdown);
    /// large means the window mixes regimes (a straggler or a degraded
    /// link inflating only some cells) and [`DriftDetector::refit_ctx`]
    /// or a named-cause event is the better response. `None` until ≥ 4
    /// usable ratios exist.
    pub fn ratio_spread<M: CostModel>(&self, model: &M) -> Option<f64> {
        let mut ratios: Vec<f64> = self
            .samples
            .iter()
            .filter_map(|s| {
                let pred = model.t(s.i, s.j) + model.t_comm(s.i);
                if !pred.is_finite() || pred <= 0.0 || !s.ms.is_finite() {
                    None
                } else {
                    Some(s.ms / pred)
                }
            })
            .collect();
        if ratios.len() < 4 {
            return None;
        }
        ratios.sort_by(f64::total_cmp);
        let n = ratios.len();
        let (p25, med, p75) = (ratios[n / 4], ratios[n / 2], ratios[(3 * n) / 4]);
        if med <= 0.0 {
            return None;
        }
        Some((p75 - p25) / med)
    }

    /// Shape-drift escape hatch: refit the Eq. 9 context coefficients
    /// from the window's samples (observed minus the base model's
    /// zero-context prediction), via the same least-squares path
    /// `perfmodel::measure::fit` uses. Needs ≥ 4 samples with `j > 0`
    /// spanning the feature space.
    pub fn refit_ctx<M: CostModel>(&self, base: &M) -> Result<CtxCoeffs, String> {
        let ctx: Vec<(u32, u32, f64)> = self
            .samples
            .iter()
            .filter(|s| s.j > 0)
            .map(|s| (s.i, s.j, s.ms - (base.t(s.i, 0) + base.t_comm(s.i))))
            .collect();
        LinearCtxModel::fit_ctx(&ctx)
    }
}

/// The hysteresis rule, factored out so the planner, the autotune CLI and
/// the tests share one definition: switch iff the fresh solve's predicted
/// latency beats the active plan's (both under the *new* model) by more
/// than `hysteresis_rel` of the active plan's latency.
pub fn should_switch(active_ms: f64, fresh_ms: f64, hysteresis_rel: f64) -> bool {
    fresh_ms < active_ms * (1.0 - hysteresis_rel.max(0.0))
}

#[cfg(test)]
mod tests {
    use super::*;

    struct Toy;
    impl CostModel for Toy {
        fn t(&self, i: u32, j: u32) -> f64 {
            1.0 + 0.01 * i as f64 + 1e-4 * i as f64 * j as f64
        }
        fn t_comm(&self, i: u32) -> f64 {
            0.05 + 0.001 * i as f64
        }
    }

    fn stage_time(m: &impl CostModel, i: u32, j: u32) -> f64 {
        m.t(i, j) + m.t_comm(i)
    }

    fn fill(det: &mut DriftDetector, factor: f64) {
        for k in 0..det.config().window {
            let i = 32 + 16 * (k as u32 % 4);
            let j = 64 * (k as u32 % 3);
            det.push(LatencySample { i, j, ms: factor * stage_time(&Toy, i, j) });
        }
    }

    #[test]
    fn warmup_until_window_full() {
        let mut d = DriftDetector::new(DriftConfig { window: 8, rel_threshold: 0.05 });
        for _ in 0..7 {
            d.push(LatencySample { i: 32, j: 0, ms: 1.0 });
        }
        assert_eq!(d.verdict(&Toy), DriftVerdict::Warmup);
    }

    #[test]
    fn exact_samples_are_stable() {
        let mut d = DriftDetector::new(DriftConfig::default());
        fill(&mut d, 1.0);
        match d.verdict(&Toy) {
            DriftVerdict::Stable { mean_rel_err } => assert!(mean_rel_err < 1e-12),
            v => panic!("expected Stable, got {v:?}"),
        }
    }

    #[test]
    fn uniform_slowdown_is_detected_with_the_right_factor() {
        let mut d = DriftDetector::new(DriftConfig::default());
        fill(&mut d, 1.3);
        match d.verdict(&Toy) {
            DriftVerdict::Drifted { mean_rel_err, factor } => {
                assert!((factor - 1.3).abs() < 1e-9, "factor {factor}");
                assert!((mean_rel_err - 0.3).abs() < 1e-9);
            }
            v => panic!("expected Drifted, got {v:?}"),
        }
    }

    #[test]
    fn small_noise_stays_below_threshold() {
        let mut d = DriftDetector::new(DriftConfig { window: 16, rel_threshold: 0.05 });
        for k in 0..16u32 {
            let i = 32 + 16 * (k % 4);
            let noise = if k % 2 == 0 { 1.02 } else { 0.98 };
            d.push(LatencySample { i, j: 0, ms: noise * stage_time(&Toy, i, 0) });
        }
        assert!(matches!(d.verdict(&Toy), DriftVerdict::Stable { .. }));
    }

    #[test]
    fn median_factor_is_robust_to_one_outlier() {
        let mut d = DriftDetector::new(DriftConfig { window: 9, rel_threshold: 0.05 });
        fill(&mut d, 1.5);
        // one wild outlier replaces the oldest sample
        d.push(LatencySample { i: 32, j: 0, ms: 100.0 * stage_time(&Toy, 32, 0) });
        match d.verdict(&Toy) {
            DriftVerdict::Drifted { factor, .. } => {
                assert!((factor - 1.5).abs() < 1e-9, "factor {factor}");
            }
            v => panic!("expected Drifted, got {v:?}"),
        }
    }

    /// Predicts 0 at j = 0 (e.g. an affine fit extrapolated to a corner
    /// of the (i, j) plane it never saw) — the ratio there is inf/NaN.
    struct ZeroAtBase;
    impl CostModel for ZeroAtBase {
        fn t(&self, _i: u32, j: u32) -> f64 {
            j as f64 * 0.01
        }
        fn t_comm(&self, _i: u32) -> f64 {
            0.0
        }
    }

    #[test]
    fn zero_prediction_samples_cannot_panic_the_verdict() {
        let mut d = DriftDetector::new(DriftConfig { window: 8, rel_threshold: 0.05 });
        // half the window sits at j=0 where the model predicts exactly 0
        for k in 0..8u32 {
            let j = if k % 2 == 0 { 0 } else { 100 };
            d.push(LatencySample { i: 32, j, ms: 1.3 * (j as f64 * 0.01).max(0.0) });
        }
        match d.verdict(&ZeroAtBase) {
            DriftVerdict::Drifted { factor, .. } => {
                assert!(factor.is_finite());
                assert!((factor - 1.3).abs() < 1e-9, "factor {factor}");
            }
            v => panic!("expected Drifted from the valid half, got {v:?}"),
        }
    }

    #[test]
    fn all_invalid_window_judges_warmup_not_panic() {
        let mut d = DriftDetector::new(DriftConfig { window: 4, rel_threshold: 0.05 });
        for _ in 0..4 {
            d.push(LatencySample { i: 32, j: 0, ms: 1.0 });
        }
        assert_eq!(d.verdict(&ZeroAtBase), DriftVerdict::Warmup);
    }

    #[test]
    fn non_finite_observations_are_excluded() {
        let mut d = DriftDetector::new(DriftConfig { window: 8, rel_threshold: 0.05 });
        fill(&mut d, 1.5);
        // a NaN and an inf observation replace the two oldest samples
        d.push(LatencySample { i: 32, j: 0, ms: f64::NAN });
        d.push(LatencySample { i: 32, j: 0, ms: f64::INFINITY });
        match d.verdict(&Toy) {
            DriftVerdict::Drifted { mean_rel_err, factor } => {
                assert!(mean_rel_err.is_finite());
                assert!((factor - 1.5).abs() < 1e-9, "factor {factor}");
            }
            v => panic!("expected Drifted, got {v:?}"),
        }
    }

    #[test]
    fn refit_recovers_planted_ctx_coefficients() {
        let truth = CtxCoeffs { a0: 0.2, a1: 0.001, a2: 0.0005, a3: 2e-6 };
        let mut d = DriftDetector::new(DriftConfig { window: 32, rel_threshold: 0.05 });
        for &i in &[32u32, 64, 128, 256] {
            for &j in &[64u32, 128, 512, 1024] {
                d.push(LatencySample {
                    i,
                    j,
                    ms: stage_time(&Toy, i, 0) + truth.eval(i, j),
                });
            }
        }
        let fit = d.refit_ctx(&Toy).unwrap();
        assert!((fit.a0 - truth.a0).abs() < 1e-9);
        assert!((fit.a3 - truth.a3).abs() < 1e-12);
    }

    #[test]
    fn ratio_spread_separates_uniform_from_shape_drift() {
        // uniform 1.3x slowdown: every ratio identical, spread ~ 0
        let mut d = DriftDetector::new(DriftConfig::default());
        fill(&mut d, 1.3);
        let s = d.ratio_spread(&Toy).unwrap();
        assert!(s < 1e-9, "uniform drift spread {s}");
        // mixed regimes: half the window 1x, half 4x — wide spread
        let mut d = DriftDetector::new(DriftConfig::default());
        for k in 0..d.config().window {
            let factor = if k % 2 == 0 { 1.0 } else { 4.0 };
            d.push(LatencySample { i: 32, j: 0, ms: factor * stage_time(&Toy, 32, 0) });
        }
        let s = d.ratio_spread(&Toy).unwrap();
        assert!(s > 0.5, "shape drift spread {s}");
        // warmup: too few samples
        let d = DriftDetector::new(DriftConfig::default());
        assert_eq!(d.ratio_spread(&Toy), None);
    }

    #[test]
    fn hysteresis_rule() {
        assert!(should_switch(100.0, 90.0, 0.05));
        assert!(!should_switch(100.0, 96.0, 0.05));
        assert!(!should_switch(100.0, 100.0, 0.0));
        assert!(should_switch(100.0, 99.9, 0.0));
    }
}
