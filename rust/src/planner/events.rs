//! Scripted cluster-event traces for `terapipe autotune`.
//!
//! A trace is a JSON array of timestamped events replayed against a
//! [`super::Planner`] — the offline stand-in for the live feeds a
//! deployment would wire in (scheduler topology updates, fabric
//! telemetry, the runtime's per-slice timings):
//!
//! ```json
//! { "events": [
//!   { "step": 10, "kind": "stages",    "stages": 48 },
//!   { "step": 20, "kind": "bandwidth", "factor": 0.5 },
//!   { "step": 30, "kind": "slowdown",  "factor": 1.25 },
//!   { "step": 40, "kind": "samples",   "factor": 1.2, "count": 16 },
//!   { "step": 50, "kind": "straggler", "stage": 2, "factor": 4.0 },
//!   { "step": 60, "kind": "link-degraded", "link": 3, "factor": 10.0 }
//! ] }
//! ```
//!
//! * `stages` — pipeline depth change (K → K′): nodes joined or left.
//! * `bandwidth` — inter-stage bandwidth multiplied by `factor`
//!   (comm times scale by 1/factor).
//! * `slowdown` — per-stage compute slowed by `factor` (thermal
//!   throttling, a degraded replica pinning the stage time).
//! * `samples` — `count` live latency observations whose stage times run
//!   `factor` slower than the planner's *current* model believes — a
//!   drift the planner is NOT told about and must detect from the
//!   samples alone. The factor is relative, so two successive
//!   `factor: 1.25` events script two successive 25% degradations
//!   (drift marching on), not a repeat of one absolute state.
//! * `straggler` / `link-degraded` — *named* causes, the typed form the
//!   live anomaly detector ([`crate::obs::anomaly`]) emits: one stage's
//!   compute or one link's delivery delay degraded by `factor`.

use crate::util::json::Json;

/// One scripted event.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum EventKind {
    /// K → K′.
    Stages(u32),
    /// Bandwidth multiplied by the factor (> 1 = faster network).
    Bandwidth(f64),
    /// Compute slowed by the factor (> 1 = slower stages).
    Slowdown(f64),
    /// Emit `count` latency samples running `true_factor` slower than
    /// the planner's current model — undisclosed (relative) drift the
    /// planner must detect.
    Samples { true_factor: f64, count: u32 },
    /// One stage's compute runs `factor` slower — the anomaly
    /// detector's named compute-straggler cause
    /// ([`crate::obs::anomaly::Cause::ComputeStraggler`]). The current
    /// single-dimension cost model has no per-stage term, so the
    /// planner conservatively folds this into the compute scale; a
    /// per-stage planner can use `stage` directly.
    Straggler { stage: u32, factor: f64 },
    /// One link's delivery delay is inflated by `factor` (`link` is the
    /// dense [`crate::coordinator::transport::LinkId::index`]) — the
    /// named comm-degradation cause. Maps onto the bandwidth knob as a
    /// `1/factor` effective-bandwidth change.
    LinkDegraded { link: u32, factor: f64 },
}

#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Event {
    /// Training step at which the event fires (informational; events are
    /// replayed in array order).
    pub step: u64,
    pub kind: EventKind,
}

/// Parse a trace from JSON text.
pub fn parse_trace(text: &str) -> Result<Vec<Event>, String> {
    let v = Json::parse(text)?;
    let events = v
        .req("events")?
        .as_arr()
        .ok_or("'events' must be an array")?;
    let mut out = Vec::with_capacity(events.len());
    for (idx, e) in events.iter().enumerate() {
        let ctx = |msg: &str| format!("event {idx}: {msg}");
        let step = e
            .get("step")
            .and_then(|s| s.as_f64())
            .map(|f| f as u64)
            .unwrap_or(idx as u64);
        let kind = e
            .req("kind")
            .map_err(|m| ctx(&m))?
            .as_str()
            .ok_or_else(|| ctx("'kind' must be a string"))?;
        let f = |key: &str| -> Result<f64, String> {
            e.req(key)
                .map_err(|m| ctx(&m))?
                .as_f64()
                .filter(|x| x.is_finite() && *x > 0.0)
                .ok_or_else(|| ctx(&format!("'{key}' must be a positive number")))
        };
        let kind = match kind {
            "stages" => {
                let s = f("stages")?;
                if s.fract() != 0.0 || s < 1.0 || s > u32::MAX as f64 {
                    return Err(ctx("'stages' must be a positive integer"));
                }
                EventKind::Stages(s as u32)
            }
            "bandwidth" => EventKind::Bandwidth(f("factor")?),
            "slowdown" => EventKind::Slowdown(f("factor")?),
            "samples" => EventKind::Samples {
                true_factor: f("factor")?,
                count: e
                    .get("count")
                    .and_then(|c| c.as_u32())
                    .unwrap_or(16)
                    .max(1),
            },
            "straggler" => {
                let stage = e
                    .get("stage")
                    .and_then(|s| s.as_u32())
                    .ok_or_else(|| ctx("'stage' must be a non-negative integer"))?;
                EventKind::Straggler { stage, factor: f("factor")? }
            }
            "link-degraded" => {
                let link = e
                    .get("link")
                    .and_then(|l| l.as_u32())
                    .ok_or_else(|| ctx("'link' must be a non-negative integer"))?;
                EventKind::LinkDegraded { link, factor: f("factor")? }
            }
            other => return Err(ctx(&format!("unknown kind '{other}'"))),
        };
        out.push(Event { step, kind });
    }
    Ok(out)
}

/// The built-in demo trace `terapipe autotune` replays when no
/// `--events` file is given: a node-count change, a bandwidth
/// degradation, and an undisclosed slowdown surfaced only through
/// samples.
pub fn demo_trace(stages: u32) -> Vec<Event> {
    vec![
        Event { step: 100, kind: EventKind::Stages((stages / 2).max(1)) },
        Event { step: 200, kind: EventKind::Bandwidth(0.5) },
        Event { step: 300, kind: EventKind::Stages(stages) },
        Event { step: 400, kind: EventKind::Samples { true_factor: 1.25, count: 32 } },
        Event { step: 500, kind: EventKind::Samples { true_factor: 1.25, count: 32 } },
    ]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_all_kinds() {
        let text = r#"{ "events": [
            { "step": 10, "kind": "stages", "stages": 48 },
            { "step": 20, "kind": "bandwidth", "factor": 0.5 },
            { "step": 30, "kind": "slowdown", "factor": 1.25 },
            { "step": 40, "kind": "samples", "factor": 1.2, "count": 8 },
            { "kind": "samples", "factor": 1.0 }
        ] }"#;
        let evs = parse_trace(text).unwrap();
        assert_eq!(evs.len(), 5);
        assert_eq!(evs[0], Event { step: 10, kind: EventKind::Stages(48) });
        assert_eq!(evs[1].kind, EventKind::Bandwidth(0.5));
        assert_eq!(evs[2].kind, EventKind::Slowdown(1.25));
        assert_eq!(
            evs[3].kind,
            EventKind::Samples { true_factor: 1.2, count: 8 }
        );
        // step defaults to the index, count to 16
        assert_eq!(evs[4].step, 4);
        assert_eq!(
            evs[4].kind,
            EventKind::Samples { true_factor: 1.0, count: 16 }
        );
    }

    #[test]
    fn parses_named_causes() {
        let text = r#"{ "events": [
            { "step": 50, "kind": "straggler", "stage": 2, "factor": 4.0 },
            { "step": 60, "kind": "link-degraded", "link": 3, "factor": 10.0 }
        ] }"#;
        let evs = parse_trace(text).unwrap();
        assert_eq!(evs.len(), 2);
        assert_eq!(evs[0].kind, EventKind::Straggler { stage: 2, factor: 4.0 });
        assert_eq!(evs[1].kind, EventKind::LinkDegraded { link: 3, factor: 10.0 });
        // missing stage/link or non-positive factors are parse errors
        assert!(parse_trace(r#"{ "events": [ { "kind": "straggler", "factor": 4.0 } ] }"#)
            .unwrap_err()
            .contains("stage"));
        assert!(parse_trace(r#"{ "events": [ { "kind": "link-degraded", "factor": 2.0 } ] }"#)
            .unwrap_err()
            .contains("link"));
        assert!(
            parse_trace(r#"{ "events": [ { "kind": "straggler", "stage": 1, "factor": -1 } ] }"#)
                .unwrap_err()
                .contains("positive")
        );
    }

    #[test]
    fn rejects_malformed_traces() {
        assert!(parse_trace("{}").is_err());
        assert!(parse_trace(r#"{ "events": [ { "kind": "warp", "factor": 2 } ] }"#)
            .unwrap_err()
            .contains("unknown kind"));
        assert!(parse_trace(r#"{ "events": [ { "kind": "bandwidth", "factor": -1 } ] }"#)
            .unwrap_err()
            .contains("positive"));
        assert!(parse_trace(r#"{ "events": [ { "kind": "stages" } ] }"#).is_err());
        // fractional or zero stage counts are parse errors, not panics
        // downstream in Planner::on_stages_change
        assert!(parse_trace(r#"{ "events": [ { "kind": "stages", "stages": 0.5 } ] }"#)
            .unwrap_err()
            .contains("positive integer"));
        assert!(parse_trace(r#"{ "events": [ { "kind": "stages", "stages": 0 } ] }"#).is_err());
    }

    #[test]
    fn demo_trace_is_well_formed() {
        let evs = demo_trace(48);
        assert!(!evs.is_empty());
        assert!(evs.windows(2).all(|w| w[0].step <= w[1].step));
    }
}
