//! The online planner service — slicing plans served over time, not
//! solved once.
//!
//! The paper solves the §3.3/§3.4 DP offline for a fixed cluster. The
//! parallel engine made a full solve cheap enough to run *online*; this
//! subsystem is the component that exploits that: a long-lived
//! [`Planner`] that owns the active plan for one training instance and
//! re-solves as the cluster or the measured cost model drifts.
//!
//! It owns three mechanisms (see `README.md` in this directory for the
//! state machine):
//!
//! * a [`cache::CostTableCache`] keyed by `(model, L, g, microbatch)` —
//!   one densification per instance ever, with scale-only cluster deltas
//!   served by rescaling the cached diagonals
//!   ([`TableCostModel::rescaled`]) instead of re-querying the model;
//! * [`warm`]-started enumeration — the feasibility search seeded from
//!   the previous solve's boundary, bit-identical to a cold solve;
//! * a [`drift`]-aware replan loop — live latency samples are judged
//!   against the solved-against model; detected drift folds a fitted
//!   factor into the cumulative compute scale and triggers a warm
//!   re-solve, with a **hysteresis** rule deciding whether the fresh
//!   plan actually replaces the active one.
//!
//! Wired three ways: the `terapipe autotune` subcommand replays scripted
//! [`events`] traces; [`validate`] replays every emitted plan through
//! `sim::engine` to confirm the predicted Eq. 5 latency; and
//! `TrainConfig::replan_every` re-solves on the live `pjrt` coordinator
//! every N steps.

pub mod cache;
pub mod drift;
pub mod events;
pub mod validate;
pub mod warm;

use std::sync::Arc;

use crate::perfmodel::{pipeline_latency, CostModel, TableCostModel};
use crate::solver::dp::SolveStats;
use crate::solver::SliceScheme;

use cache::{CostTableCache, PlanKey};
use drift::{DriftConfig, DriftDetector, DriftVerdict, LatencySample};
use warm::WarmReport;

/// Planner configuration.
#[derive(Debug, Clone)]
pub struct PlannerConfig {
    /// Token-grid granularity for the DP.
    pub granularity: u32,
    /// ε for the t_max enumeration (ms).
    pub eps_ms: f64,
    /// Microbatch size the cost model is evaluated at.
    pub microbatch: u32,
    /// Warm-window half-width γ (hint considered good within
    /// `[hint/γ, hint·γ]`).
    pub warm_window: f64,
    /// Minimum relative Eq. 5 gain before a fresh plan replaces the
    /// active one.
    pub hysteresis_rel: f64,
    pub drift: DriftConfig,
    /// Cost-table cache capacity (tables, base + scaled).
    pub cache_capacity: usize,
}

impl Default for PlannerConfig {
    fn default() -> Self {
        PlannerConfig {
            granularity: 16,
            eps_ms: 0.1,
            microbatch: 1,
            warm_window: warm::DEFAULT_WINDOW,
            hysteresis_rel: 0.02,
            drift: DriftConfig::default(),
            cache_capacity: 32,
        }
    }
}

/// What caused a re-solve.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ReplanTrigger {
    /// First solve of the instance.
    Initial,
    /// Pipeline depth change (K → K′).
    Topology,
    /// Bandwidth or compute rescale announced by the cluster.
    ClusterScale,
    /// Departure detected from live latency samples.
    Drift,
    /// Caller-forced (e.g. `TrainConfig::replan_every`).
    Periodic,
}

/// One replan decision — the planner's log entry.
#[derive(Debug, Clone)]
pub struct ReplanDecision {
    pub trigger: ReplanTrigger,
    /// K the solve ran against.
    pub stages: u32,
    /// Cumulative scale factors the solve ran against.
    pub compute_scale: f64,
    pub comm_scale: f64,
    /// The fresh solve's plan and exact Eq. 5 latency prediction.
    pub scheme: SliceScheme,
    pub stats: SolveStats,
    /// Warm-start telemetry (`None` for the cold initial solve).
    pub warm: Option<WarmReport>,
    /// The active plan's latency re-evaluated under the new model
    /// (`None` when there was no active plan).
    pub active_ms: Option<f64>,
    /// Relative gain of the fresh plan over the active one.
    pub gain_rel: f64,
    /// Whether the fresh plan replaced the active one (hysteresis).
    pub switched: bool,
}

/// The long-lived planning service for one `(model, L, microbatch)`
/// training instance.
pub struct Planner<M> {
    base: M,
    key: PlanKey,
    stages: u32,
    /// Cumulative cluster-delta factors relative to `base`.
    compute_scale: f64,
    comm_scale: f64,
    cfg: PlannerConfig,
    cache: CostTableCache,
    detector: DriftDetector,
    /// The active plan + the state it was solved against.
    active: Option<ActivePlan>,
    /// Warm seed: the previous solve's feasibility-boundary budget.
    hint_tmax: Option<f64>,
}

struct ActivePlan {
    scheme: SliceScheme,
    table: Arc<TableCostModel>,
}

impl<M: CostModel> Planner<M> {
    /// `model_id` fingerprints `base` for the cache (same id ⇒ same
    /// table); `seq_len` must be divisible by `cfg.granularity`.
    pub fn new(model_id: &str, base: M, seq_len: u32, stages: u32, cfg: PlannerConfig) -> Self {
        assert!(stages >= 1 && cfg.granularity >= 1 && seq_len % cfg.granularity == 0);
        let key = PlanKey {
            model: model_id.into(),
            seq_len,
            granularity: cfg.granularity,
            microbatch: cfg.microbatch,
        };
        Planner {
            base,
            key,
            stages,
            compute_scale: 1.0,
            comm_scale: 1.0,
            cache: CostTableCache::new(cfg.cache_capacity),
            detector: DriftDetector::new(cfg.drift),
            cfg,
            active: None,
            hint_tmax: None,
        }
    }

    pub fn stages(&self) -> u32 {
        self.stages
    }

    pub fn scales(&self) -> (f64, f64) {
        (self.compute_scale, self.comm_scale)
    }

    pub fn cache_stats(&self) -> cache::CacheStats {
        self.cache.stats
    }

    /// The active plan, solving cold on first use.
    pub fn plan(&mut self) -> &SliceScheme {
        if self.active.is_none() {
            self.resolve(ReplanTrigger::Initial);
        }
        &self.active.as_ref().unwrap().scheme
    }

    /// The model the *current* cluster state implies (for validation /
    /// replay): the base model under the cumulative scale factors.
    pub fn current_model(&self) -> crate::perfmodel::ScaledModel<&M> {
        crate::perfmodel::ScaledModel {
            inner: &self.base,
            compute: self.compute_scale,
            comm: self.comm_scale,
        }
    }

    /// Pipeline depth change (K → K′). Always re-solves (warm); the
    /// hysteresis rule decides the switch.
    pub fn on_stages_change(&mut self, stages: u32) -> ReplanDecision {
        assert!(stages >= 1);
        self.stages = stages;
        self.resolve(ReplanTrigger::Topology)
    }

    /// Inter-stage bandwidth multiplied by `factor` (> 1 = faster).
    pub fn on_bandwidth_change(&mut self, factor: f64) -> ReplanDecision {
        assert!(factor.is_finite() && factor > 0.0);
        self.comm_scale /= factor;
        self.resolve(ReplanTrigger::ClusterScale)
    }

    /// Per-stage compute slowed by `factor` (> 1 = slower). The DP's
    /// homogeneous-stage cost model takes the slowest stage's factor —
    /// the pipeline's Eq. 5 latency is pinned by its slowest cell.
    pub fn on_slowdown(&mut self, factor: f64) -> ReplanDecision {
        assert!(factor.is_finite() && factor > 0.0);
        self.compute_scale *= factor;
        // the warm seed tracks the compute rescale directly
        if let Some(h) = self.hint_tmax.as_mut() {
            *h *= factor;
        }
        self.resolve(ReplanTrigger::ClusterScale)
    }

    /// Feed one live latency observation. Returns a decision when the
    /// sample tips the drift detector over its threshold (the fitted
    /// factor is folded into the compute scale before re-solving).
    ///
    /// Samples must lie on the planning grid (`i`, `j` multiples of the
    /// granularity, `i ≥ g`, `i + j ≤ L`) with a positive finite
    /// latency; anything else — a mid-bucket measurement, a wrapped
    /// counter — is silently dropped rather than allowed to poison the
    /// window or panic the service mid-run.
    pub fn on_sample(&mut self, s: LatencySample) -> Option<ReplanDecision> {
        let g = self.cfg.granularity;
        if s.i < g
            || s.i % g != 0
            || s.j % g != 0
            || s.i + s.j > self.key.seq_len
            || !s.ms.is_finite()
            || s.ms <= 0.0
        {
            return None;
        }
        self.detector.push(s);
        let verdict = match &self.active {
            // judge against the model the active plan was solved with
            Some(a) => self.detector.verdict(&*a.table),
            None => return None,
        };
        match verdict {
            DriftVerdict::Drifted { factor, mean_rel_err } => {
                crate::obs::instant(
                    crate::obs::SpanKind::DriftVerdict,
                    crate::obs::DRIVER,
                    2,
                    mean_rel_err.to_bits(),
                );
                self.detector.clear();
                self.compute_scale *= factor;
                if let Some(h) = self.hint_tmax.as_mut() {
                    *h *= factor;
                }
                Some(self.resolve(ReplanTrigger::Drift))
            }
            _ => None,
        }
    }

    /// Caller-forced re-solve (the coordinator's `replan_every` hook).
    pub fn replan_now(&mut self) -> ReplanDecision {
        self.resolve(ReplanTrigger::Periodic)
    }

    fn resolve(&mut self, trigger: ReplanTrigger) -> ReplanDecision {
        let t_us = crate::obs::maybe_start();
        let hits_before = self.cache.stats.base_hits + self.cache.stats.scaled_hits;
        let table =
            self.cache
                .scaled(&self.key, self.compute_scale, self.comm_scale, &self.base);
        if self.cache.stats.base_hits + self.cache.stats.scaled_hits > hits_before {
            crate::obs::instant(crate::obs::SpanKind::PlannerCacheHit, crate::obs::DRIVER, 0, 0);
        }

        let (scheme, stats, warm) = match self.hint_tmax {
            Some(hint) => {
                let (s, st, w) = warm::solve_tokens_table_warm(
                    &table,
                    self.stages,
                    self.cfg.eps_ms,
                    hint,
                    self.cfg.warm_window,
                );
                self.hint_tmax = Some(w.boundary_tmax);
                (s, st, Some(w))
            }
            None => {
                let (s, st) =
                    crate::solver::dp::solve_tokens_table(&table, self.stages, self.cfg.eps_ms);
                // seed future warm solves at the winner's achieved budget
                // (the boundary sits at or just below it)
                self.hint_tmax = Some(s.t_max_ms);
                (s, st, None)
            }
        };

        // hysteresis: re-evaluate the active plan under the NEW model and
        // switch only for a real gain
        let active_ms = self
            .active
            .as_ref()
            .map(|a| pipeline_latency(&*table, &a.scheme.lens, self.stages));
        let (gain_rel, switched) = match active_ms {
            None => (1.0, true),
            Some(old) => {
                let gain = (old - scheme.latency_ms) / old;
                (gain, drift::should_switch(old, scheme.latency_ms, self.cfg.hysteresis_rel))
            }
        };
        if switched {
            self.active = Some(ActivePlan { scheme: scheme.clone(), table: table.clone() });
            crate::obs::instant(crate::obs::SpanKind::PlanSwitch, crate::obs::DRIVER, 0, 0);
        } else if let Some(a) = self.active.as_mut() {
            // the active plan is now judged against the new model: future
            // drift verdicts must compare samples to it
            a.table = table.clone();
        }
        crate::obs::emit(
            if warm.is_some() {
                crate::obs::SpanKind::PlannerWarmResolve
            } else {
                crate::obs::SpanKind::PlannerSolve
            },
            crate::obs::DRIVER,
            0,
            0,
            self.stages as u64,
            trigger as u64,
            t_us,
        );

        ReplanDecision {
            trigger,
            stages: self.stages,
            compute_scale: self.compute_scale,
            comm_scale: self.comm_scale,
            scheme,
            stats,
            warm,
            active_ms,
            gain_rel,
            switched,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[derive(Clone)]
    struct Affine {
        over: f64,
        lin: f64,
        ctx: f64,
        comm: f64,
    }
    impl CostModel for Affine {
        fn t(&self, i: u32, j: u32) -> f64 {
            self.over + self.lin * i as f64 + self.ctx * i as f64 * j as f64
        }
        fn t_comm(&self, _i: u32) -> f64 {
            self.comm
        }
    }

    fn model() -> Affine {
        Affine { over: 1.0, lin: 0.05, ctx: 2e-4, comm: 0.05 }
    }

    fn planner(stages: u32) -> Planner<Affine> {
        Planner::new(
            "affine",
            model(),
            512,
            stages,
            PlannerConfig { granularity: 8, eps_ms: 0.0, ..Default::default() },
        )
    }

    #[test]
    fn first_plan_matches_cold_solver() {
        let mut p = planner(8);
        let got = p.plan().clone();
        let (want, _) = crate::solver::dp::solve_tokens(&model(), 512, 8, 8, 0.0);
        assert_eq!(got.lens, want.lens);
        assert!(got.latency_ms == want.latency_ms);
        // first solve densified exactly one table
        assert_eq!(p.cache_stats().base_misses, 1);
    }

    #[test]
    fn topology_change_resolves_warm_and_bit_identically() {
        let mut p = planner(8);
        p.plan();
        let d = p.on_stages_change(24);
        assert_eq!(d.trigger, ReplanTrigger::Topology);
        assert!(d.warm.is_some(), "second solve must be warm");
        let (want, _) = crate::solver::dp::solve_tokens(&model(), 512, 24, 8, 0.0);
        assert_eq!(d.scheme.lens, want.lens);
        assert!(d.scheme.latency_ms == want.latency_ms);
        // same model/scales: the cached table was reused, not rebuilt
        assert_eq!(p.cache_stats().base_misses, 1);
        assert!(p.cache_stats().base_hits >= 1);
    }

    #[test]
    fn slowdown_resolves_via_rescale_not_redensify() {
        let mut p = planner(16);
        p.plan();
        let d = p.on_slowdown(1.5);
        assert_eq!(p.scales(), (1.5, 1.0));
        assert_eq!(p.cache_stats().base_misses, 1, "no re-densification");
        assert_eq!(p.cache_stats().rescales, 1);
        // bit-identical to a cold solve over the scaled model
        let scaled = crate::perfmodel::ScaledModel { inner: model(), compute: 1.5, comm: 1.0 };
        let (want, _) = crate::solver::dp::solve_tokens(&scaled, 512, 16, 8, 0.0);
        assert_eq!(d.scheme.lens, want.lens);
        assert!(d.scheme.latency_ms == want.latency_ms);
    }

    #[test]
    fn bandwidth_change_scales_comm_only() {
        let mut p = planner(16);
        p.plan();
        let d = p.on_bandwidth_change(0.5); // halved bandwidth ⇒ comm ×2
        assert_eq!(p.scales(), (1.0, 2.0));
        let scaled = crate::perfmodel::ScaledModel { inner: model(), compute: 1.0, comm: 2.0 };
        let (want, _) = crate::solver::dp::solve_tokens(&scaled, 512, 16, 8, 0.0);
        assert_eq!(d.scheme.lens, want.lens);
        assert!(d.scheme.latency_ms == want.latency_ms);
    }

    #[test]
    fn uniform_scale_keeps_the_plan_hysteresis_holds() {
        // with no comm term, a compute slowdown scales every stage time —
        // and hence Eq. 5 — uniformly: the old plan stays optimal, the
        // gain is exactly 0, and hysteresis keeps it
        let mut p = Planner::new(
            "affine-nocomm",
            Affine { comm: 0.0, ..model() },
            512,
            16,
            PlannerConfig { granularity: 8, eps_ms: 0.0, ..Default::default() },
        );
        let before = p.plan().lens.clone();
        let d = p.on_slowdown(1.25);
        assert!(d.gain_rel.abs() < 1e-12, "gain {}", d.gain_rel);
        assert!(!d.switched, "uniform rescale must not churn the plan");
        assert_eq!(p.plan().lens, before);
    }

    #[test]
    fn drift_detected_from_samples_triggers_replan() {
        let mut p = planner(16);
        p.plan();
        let truth = crate::perfmodel::ScaledModel { inner: model(), compute: 1.4, comm: 1.0 };
        let window = p.cfg.drift.window;
        let mut decision = None;
        for k in 0..2 * window as u32 {
            let i = 8 * (1 + (k % 4));
            let j = 8 * (k % 3);
            let ms = truth.t(i, j) + truth.t_comm(i);
            if let Some(d) = p.on_sample(LatencySample { i, j, ms }) {
                decision = Some(d);
                break;
            }
        }
        let d = decision.expect("a 40% slowdown must trip the detector");
        assert_eq!(d.trigger, ReplanTrigger::Drift);
        // fitted factor ≈ 1.4 folded into the compute scale... but the
        // fit is over mixed (i, j) where comm is unscaled in truth vs
        // scaled in the planner's model — allow the fit's slack
        assert!((p.scales().0 - 1.4).abs() < 0.1, "scale {}", p.scales().0);
    }

    #[test]
    fn malformed_samples_are_dropped_not_fatal() {
        let mut p = planner(16);
        p.plan();
        // off-grid, oversized, and garbage samples must neither panic
        // (the table model hard-asserts grid alignment) nor fill the
        // drift window
        for s in [
            LatencySample { i: 100, j: 0, ms: 1.0 },  // i off-grid
            LatencySample { i: 64, j: 3, ms: 1.0 },   // j off-grid
            LatencySample { i: 0, j: 0, ms: 1.0 },    // below one unit
            LatencySample { i: 512, j: 8, ms: 1.0 },  // i + j > L
            LatencySample { i: 64, j: 0, ms: f64::NAN },
            LatencySample { i: 64, j: 0, ms: -1.0 },
        ] {
            assert!(p.on_sample(s).is_none());
        }
    }

    #[test]
    fn stable_samples_never_replan() {
        let mut p = planner(16);
        p.plan();
        let m = model();
        for k in 0..64u32 {
            let i = 8 * (1 + (k % 4));
            let j = 8 * (k % 3);
            let ms = m.t(i, j) + m.t_comm(i);
            assert!(p.on_sample(LatencySample { i, j, ms }).is_none());
        }
    }

    #[test]
    fn periodic_replan_is_a_cache_hit_and_keeps_the_plan() {
        let mut p = planner(16);
        p.plan();
        let d = p.replan_now();
        assert_eq!(d.trigger, ReplanTrigger::Periodic);
        assert!(!d.switched);
        assert_eq!(p.cache_stats().base_misses, 1);
    }

    #[test]
    fn emitted_plans_validate_against_the_simulator() {
        // each decision must be judged against the cluster state it was
        // solved under, immediately after its event: the replay plan is
        // built right away (baking the model snapshot into durations),
        // then the whole batch fans through simulate_many at the end
        let mut p = planner(8);
        p.plan();
        let mut plans = Vec::new();
        let mut preds = Vec::new();
        let d = p.on_stages_change(16);
        plans.push(validate::replay_plan(&p.current_model(), &d.scheme.lens, d.stages));
        preds.push(d.scheme.latency_ms);
        let d = p.on_slowdown(1.3);
        plans.push(validate::replay_plan(&p.current_model(), &d.scheme.lens, d.stages));
        preds.push(d.scheme.latency_ms);
        let d = p.on_bandwidth_change(0.7);
        plans.push(validate::replay_plan(&p.current_model(), &d.scheme.lens, d.stages));
        preds.push(d.scheme.latency_ms);
        validate::validate_plans(&plans, &preds, 1e-9).unwrap();
    }
}
