//! The planner's cost-table cache.
//!
//! Densifying a [`TableCostModel`] is the dominant fixed cost of a
//! re-solve (`n(n+1)/2` model evaluations — for measured/fitted models
//! each one is real work). A long-lived planner re-solves the *same*
//! `(model, L, g, microbatch)` instance under small cluster deltas, so the
//! cache keeps:
//!
//! * **Base tables**, keyed by [`PlanKey`] — one densification per
//!   instance, ever.
//! * **Scaled tables**, keyed by `PlanKey` + the exact `(compute, comm)`
//!   factor bits — derived from the base table via
//!   [`TableCostModel::rescaled`], which reuses the densified
//!   anti-diagonals (one multiply per entry, no model calls) and is
//!   bit-identical to re-densifying a [`ScaledModel`].
//!
//! Eviction is LRU over a fixed capacity, preferring scaled victims; a
//! key's own base table is never evicted to make room for entries derived
//! from it (it is their rescale source — losing it would re-trigger a
//! full densification on the next delta). All tables are handed out as
//! `Arc`s so a re-solve never copies one.

use std::collections::HashMap;
use std::sync::Arc;

use crate::perfmodel::{CostModel, TableCostModel};

/// Identity of one planning instance: which model is being sliced, over
/// what sequence length, on what grid, at what microbatch size. `model`
/// is a caller-chosen fingerprint string (e.g. `"analytic/setting9"` or
/// `"measured@v3"`) — two models with the same fingerprint are assumed to
/// produce identical tables.
#[derive(Debug, Clone, PartialEq, Eq, Hash)]
pub struct PlanKey {
    pub model: String,
    pub seq_len: u32,
    pub granularity: u32,
    pub microbatch: u32,
}

#[derive(Debug, Clone, PartialEq, Eq, Hash)]
enum CacheKey {
    Base(PlanKey),
    /// Factors keyed by exact f64 bits: a rescale is only reusable when
    /// the cumulative factors match bit-for-bit (f64 products are not
    /// associative, and the planner promises bit-identical plans).
    Scaled(PlanKey, u64, u64),
}

/// Hit/miss counters, split by path (reported by `terapipe autotune` and
/// the planner bench).
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct CacheStats {
    /// Base-table lookups served from the cache.
    pub base_hits: usize,
    /// Base-table densifications (full model evaluation passes).
    pub base_misses: usize,
    /// Scaled-table lookups served from the cache.
    pub scaled_hits: usize,
    /// Rescale passes (diagonal reuse: one multiply per entry, no model
    /// calls).
    pub rescales: usize,
    pub evictions: usize,
}

/// LRU cache of densified cost tables.
pub struct CostTableCache {
    map: HashMap<CacheKey, (u64, Arc<TableCostModel>)>,
    clock: u64,
    capacity: usize,
    pub stats: CacheStats,
}

impl CostTableCache {
    /// `capacity` ≥ 1: max resident tables (base + scaled combined).
    pub fn new(capacity: usize) -> Self {
        CostTableCache {
            map: HashMap::new(),
            clock: 0,
            capacity: capacity.max(1),
            stats: CacheStats::default(),
        }
    }

    pub fn len(&self) -> usize {
        self.map.len()
    }

    pub fn is_empty(&self) -> bool {
        self.map.is_empty()
    }

    fn touch(&mut self, key: &CacheKey) -> Option<Arc<TableCostModel>> {
        self.clock += 1;
        let clock = self.clock;
        self.map.get_mut(key).map(|(stamp, table)| {
            *stamp = clock;
            table.clone()
        })
    }

    fn insert(&mut self, key: CacheKey, table: Arc<TableCostModel>) {
        self.clock += 1;
        if self.map.len() >= self.capacity && !self.map.contains_key(&key) {
            // Evict the least-recently-used entry, preferring scaled
            // tables: base tables are the rescale source for every
            // future delta, so evicting one re-triggers a full
            // densification later. The inserting key's own base is
            // never a victim (a capacity-1 cache would otherwise evict
            // it for every rescale it feeds); with no other candidate
            // the cache briefly exceeds capacity instead.
            let own_base = match &key {
                CacheKey::Base(pk) | CacheKey::Scaled(pk, ..) => CacheKey::Base(pk.clone()),
            };
            let lru = |scaled_only: bool| {
                self.map
                    .iter()
                    .filter(|(k, _)| !scaled_only || matches!(k, CacheKey::Scaled(..)))
                    .filter(|(k, _)| **k != own_base)
                    .min_by_key(|(_, (stamp, _))| *stamp)
                    .map(|(k, _)| k.clone())
            };
            let victim = lru(true).or_else(|| lru(false));
            if let Some(v) = victim {
                self.map.remove(&v);
                self.stats.evictions += 1;
            }
        }
        self.map.insert(key, (self.clock, table));
    }

    /// The base table for `key`, densifying from `model` on a miss.
    pub fn base<M: CostModel>(&mut self, key: &PlanKey, model: &M) -> Arc<TableCostModel> {
        if let Some(t) = self.touch(&CacheKey::Base(key.clone())) {
            self.stats.base_hits += 1;
            return t;
        }
        self.stats.base_misses += 1;
        let t = Arc::new(TableCostModel::build(model, key.seq_len, key.granularity));
        self.insert(CacheKey::Base(key.clone()), t.clone());
        t
    }

    /// The table for `key` under cumulative cluster-delta factors
    /// `(compute, comm)`. A `(1, 1)` request is the base table itself;
    /// otherwise the base table's diagonals are rescaled in place-order
    /// (never the model re-queried), and the result cached under the
    /// exact factor bits.
    pub fn scaled<M: CostModel>(
        &mut self,
        key: &PlanKey,
        compute: f64,
        comm: f64,
        model: &M,
    ) -> Arc<TableCostModel> {
        if compute == 1.0 && comm == 1.0 {
            return self.base(key, model);
        }
        let ck = CacheKey::Scaled(key.clone(), compute.to_bits(), comm.to_bits());
        if let Some(t) = self.touch(&ck) {
            self.stats.scaled_hits += 1;
            return t;
        }
        let base = self.base(key, model);
        self.stats.rescales += 1;
        let t = Arc::new(base.rescaled(compute, comm));
        self.insert(ck, t.clone());
        t
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::cell::Cell;

    struct Counting<'a> {
        calls: &'a Cell<usize>,
    }
    impl CostModel for Counting<'_> {
        fn t(&self, i: u32, j: u32) -> f64 {
            self.calls.set(self.calls.get() + 1);
            0.5 + 0.01 * i as f64 + 1e-4 * i as f64 * j as f64
        }
        fn t_comm(&self, i: u32) -> f64 {
            0.02 * i as f64
        }
    }

    fn key(model: &str, b: u32) -> PlanKey {
        PlanKey {
            model: model.into(),
            seq_len: 64,
            granularity: 8,
            microbatch: b,
        }
    }

    #[test]
    fn base_is_densified_once() {
        let calls = Cell::new(0);
        let m = Counting { calls: &calls };
        let mut c = CostTableCache::new(8);
        let a = c.base(&key("m", 1), &m);
        let first = calls.get();
        assert!(first > 0);
        let b = c.base(&key("m", 1), &m);
        assert_eq!(calls.get(), first, "second lookup must not re-densify");
        assert!(Arc::ptr_eq(&a, &b));
        assert_eq!(c.stats.base_misses, 1);
        assert_eq!(c.stats.base_hits, 1);
    }

    #[test]
    fn scaled_reuses_diagonals_without_model_calls() {
        let calls = Cell::new(0);
        let m = Counting { calls: &calls };
        let mut c = CostTableCache::new(8);
        c.base(&key("m", 1), &m);
        let after_base = calls.get();
        let s = c.scaled(&key("m", 1), 1.25, 0.5, &m);
        assert_eq!(calls.get(), after_base, "rescale must not query the model");
        assert_eq!(c.stats.rescales, 1);
        // rescale matches a fresh build from the scaled model, bit for bit
        let scaled_model = crate::perfmodel::ScaledModel {
            inner: Counting { calls: &calls },
            compute: 1.25,
            comm: 0.5,
        };
        let built = TableCostModel::build(&scaled_model, 64, 8);
        for a in 1..=8usize {
            for b in 0..=(8 - a) {
                assert!(s.at(a, b) == built.at(a, b));
            }
            assert!(s.comm_at(a) == built.comm_at(a));
        }
        // second lookup with the same factor bits hits
        let s2 = c.scaled(&key("m", 1), 1.25, 0.5, &m);
        assert!(Arc::ptr_eq(&s, &s2));
        assert_eq!(c.stats.scaled_hits, 1);
    }

    #[test]
    fn unit_factors_resolve_to_the_base_table() {
        let calls = Cell::new(0);
        let m = Counting { calls: &calls };
        let mut c = CostTableCache::new(8);
        let b = c.base(&key("m", 1), &m);
        let s = c.scaled(&key("m", 1), 1.0, 1.0, &m);
        assert!(Arc::ptr_eq(&b, &s));
        assert_eq!(c.stats.rescales, 0);
    }

    #[test]
    fn distinct_keys_do_not_collide() {
        let calls = Cell::new(0);
        let m = Counting { calls: &calls };
        let mut c = CostTableCache::new(8);
        c.base(&key("m", 1), &m);
        c.base(&key("m", 2), &m);
        c.base(&key("other", 1), &m);
        assert_eq!(c.stats.base_misses, 3);
        assert_eq!(c.len(), 3);
    }

    #[test]
    fn eviction_prefers_scaled_entries_and_respects_capacity() {
        let calls = Cell::new(0);
        let m = Counting { calls: &calls };
        let mut c = CostTableCache::new(2);
        c.base(&key("m", 1), &m);
        c.scaled(&key("m", 1), 2.0, 1.0, &m); // fills capacity
        c.scaled(&key("m", 1), 3.0, 1.0, &m); // evicts the 2.0 rescale
        assert_eq!(c.len(), 2);
        assert_eq!(c.stats.evictions, 1);
        // the base table survived: a unit-factor lookup still hits
        c.scaled(&key("m", 1), 1.0, 1.0, &m);
        assert_eq!(c.stats.base_misses, 1);
    }

    #[test]
    fn own_base_is_never_evicted_even_at_capacity_one() {
        let calls = Cell::new(0);
        let m = Counting { calls: &calls };
        let mut c = CostTableCache::new(1);
        // every rescale needs the base: a capacity-1 cache must keep it
        // (briefly exceeding capacity) rather than densify per delta
        c.scaled(&key("m", 1), 2.0, 1.0, &m);
        c.scaled(&key("m", 1), 3.0, 1.0, &m);
        c.scaled(&key("m", 1), 4.0, 1.0, &m);
        assert_eq!(c.stats.base_misses, 1, "{:?}", c.stats);
        assert_eq!(c.stats.rescales, 3);
    }
}
