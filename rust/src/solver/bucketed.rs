//! Bucket-restricted slicing DP — Algorithm 1 over the AOT bucket set.
//!
//! The paper's DP may pick any slice length; our AOT runtime only ships
//! executables for a bucket set (static HLO shapes, DESIGN.md §9). This
//! variant restricts the inner DP's choice of `k` to bucketed lengths, so
//! `terapipe train --auto` / `terapipe measure` can go straight from the
//! fitted Eq. 9 model to an executable schedule. Collapses to the paper's
//! solver when every grid multiple is a bucket.

use super::dp::{FixedTmaxSolution, SolveStats};
use super::engine;
use super::SliceScheme;
use crate::perfmodel::{CostModel, TableCostModel};

/// Algorithm 1 with `k` restricted to `allowed_units` (grid units).
pub fn solve_fixed_tmax_restricted(
    table: &TableCostModel,
    t_max: f64,
    allowed_units: &[usize],
) -> Option<FixedTmaxSolution> {
    let n = table.units();
    let mut s = vec![f64::INFINITY; n + 1];
    let mut q = vec![0usize; n + 1];
    s[0] = 0.0;
    for i in 1..=n {
        let mut best = f64::INFINITY;
        let mut bestk = 0usize;
        for &k in allowed_units {
            if k == 0 || k > i || !s[i - k].is_finite() {
                continue;
            }
            let t = table.at(k, i - k) + table.comm_at(k);
            if t <= t_max {
                let cand = s[i - k] + t;
                if cand < best {
                    best = cand;
                    bestk = k;
                }
            }
        }
        s[i] = best;
        q[i] = bestk;
    }
    if !s[n].is_finite() {
        return None;
    }
    let mut lens = Vec::new();
    let mut i = n;
    while i > 0 {
        lens.push(q[i]);
        i -= q[i];
    }
    lens.reverse();
    Some(FixedTmaxSolution {
        lens_units: lens,
        total_ms: s[n],
    })
}

/// Full bucketed solver: optimal Eq. 5 slicing of `seq_len` into lengths
/// drawn from `buckets` (tokens). Granularity = gcd of the buckets.
/// Returns `None` if the buckets cannot compose `seq_len`.
pub fn solve_tokens_bucketed<M: CostModel>(
    model: &M,
    seq_len: u32,
    stages: u32,
    buckets: &[u32],
    eps_ms: f64,
) -> Option<(SliceScheme, SolveStats)> {
    assert!(!buckets.is_empty());
    let g = buckets.iter().copied().fold(0u32, gcd).max(1);
    if seq_len % g != 0 {
        return None;
    }
    let table = TableCostModel::build(model, seq_len, g);
    let allowed: Vec<usize> = buckets.iter().map(|&b| (b / g) as usize).collect();

    // Candidate t_max pool: only bucketed slice lengths are reachable.
    let n = table.units();
    let mut cands = Vec::new();
    for &a in &allowed {
        if a == 0 || a > n {
            continue; // bucket longer than the sequence
        }
        for b in 0..=(n - a) {
            cands.push(table.at(a, b) + table.comm_at(a));
        }
    }
    if cands.is_empty() {
        return None;
    }
    let filtered = engine::dedup_candidates(cands, eps_ms);

    // Same parallel enumeration engine as the unrestricted solver, with
    // Algorithm 1's `k` choices restricted to the bucket set.
    let k_f = stages as f64 - 1.0;
    let r = engine::enumerate_par(
        stages,
        &filtered,
        |tmax| solve_fixed_tmax_restricted(&table, tmax, &allowed).is_some(),
        |tmax| {
            solve_fixed_tmax_restricted(&table, tmax, &allowed).map(|sol| {
                let achieved = engine::achieved_tmax(&table, &sol.lens_units);
                (sol.total_ms + k_f * achieved, (sol, achieved))
            })
        },
    );
    let stats = SolveStats {
        candidates: filtered.len(),
        dps_run: r.dps_run,
        probe_dps: r.probe_dps,
    };
    r.best.map(|(latency, (sol, tmax))| {
        (
            SliceScheme {
                lens: sol.lens_units.iter().map(|&u| u as u32 * g).collect(),
                total_ms: sol.total_ms,
                t_max_ms: tmax,
                latency_ms: latency,
            },
            stats,
        )
    })
}

fn gcd(a: u32, b: u32) -> u32 {
    if b == 0 {
        a
    } else {
        gcd(b, a % b)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::perfmodel::pipeline_latency;
    use crate::util::prop;

    struct Affine;
    impl CostModel for Affine {
        fn t(&self, i: u32, j: u32) -> f64 {
            0.5 + 0.02 * i as f64 + 1e-4 * i as f64 * j as f64
        }
    }

    #[test]
    fn collapses_to_unrestricted_when_all_lengths_allowed() {
        let buckets: Vec<u32> = (1..=16).map(|u| u * 8).collect();
        let (restricted, _) = solve_tokens_bucketed(&Affine, 128, 8, &buckets, 0.0).unwrap();
        let (free, _) = crate::solver::dp::solve_tokens(&Affine, 128, 8, 8, 0.0);
        assert!((restricted.latency_ms - free.latency_ms).abs() < 1e-9);
    }

    #[test]
    fn scheme_uses_only_buckets_and_covers() {
        let buckets = [16u32, 32, 64, 128];
        let (s, _) = solve_tokens_bucketed(&Affine, 128, 4, &buckets, 0.0).unwrap();
        assert_eq!(s.seq_len(), 128);
        assert!(s.lens.iter().all(|l| buckets.contains(l)), "{:?}", s.lens);
    }

    #[test]
    fn exhaustive_optimality_over_bucket_compositions() {
        // enumerate every composition of 128 from {16,32,64,128} and check
        // the DP's latency is minimal
        let buckets = [16u32, 32, 64, 128];
        let k = 6u32;
        let (s, _) = solve_tokens_bucketed(&Affine, 128, k, &buckets, 0.0).unwrap();

        fn rec(rem: u32, cur: &mut Vec<u32>, buckets: &[u32], k: u32, best: &mut f64) {
            if rem == 0 {
                *best = best.min(pipeline_latency(&Affine, cur, k));
                return;
            }
            for &b in buckets {
                if b <= rem {
                    cur.push(b);
                    rec(rem - b, cur, buckets, k, best);
                    cur.pop();
                }
            }
        }
        let mut best = f64::INFINITY;
        rec(128, &mut Vec::new(), &buckets, k, &mut best);
        assert!((s.latency_ms - best).abs() < 1e-9, "dp {} vs exhaustive {best}", s.latency_ms);
    }

    #[test]
    fn impossible_coverage_returns_none() {
        assert!(solve_tokens_bucketed(&Affine, 100, 4, &[64, 128], 0.0).is_none());
        // 96 not composable from {64, 128} even though gcd divides it
        assert!(solve_tokens_bucketed(&Affine, 96, 4, &[64, 128], 0.0).is_none());
    }

    #[test]
    fn prop_restricted_never_beats_unrestricted() {
        prop::run_cases(50, |g| {
            let k = g.int(1, 12);
            let l = g.int(2, 8) * 16;
            let (free, _) = crate::solver::dp::solve_tokens(&Affine, l, k, 16, 0.0);
            if let Some((restr, _)) = solve_tokens_bucketed(&Affine, l, k, &[16, 32, 64], 0.0) {
                assert!(restr.latency_ms >= free.latency_ms - 1e-9);
                assert_eq!(restr.seq_len(), l);
            }
        });
    }
}
