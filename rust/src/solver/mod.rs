//! The TeraPipe slicing solvers (paper §3.3–3.4).
//!
//! * [`dp`] — Algorithm 1: the `S*(i; t_max)` dynamic program, plus the
//!   outer `t_max` enumeration with the ε-grid and the `K·t_max` pruning
//!   optimizations the paper describes.
//! * `engine` (crate-private) — the generic enumeration engine behind
//!   **every** solver front-end (`dp`, `bucketed`, and `joint`),
//!   parameterized over an eval closure and a feasibility probe:
//!   feasibility binary search over the sorted candidate pool + a blocked
//!   rayon scan with a shared atomic pruning bound, bit-identical to the
//!   retained sequential references (`solve_tokens_seq`,
//!   `solve_joint_seq`).
//! * [`uniform`] — the uniform-slicing heuristic baseline of Fig. 6.
//! * [`joint`] — the §3.4 joint batch+token extension: token-DP per batch
//!   size, then a batch composition with the bubble term counted once
//!   (`solve_joint`), or the exact global-`t_max` search on the engine
//!   (`solve_joint_exact`).
//! * [`knapsack`] — the exact unbounded min-cost composition solvers the
//!   joint schemes reduce to.
//!
//! See `solver/README.md` for the engine API and the differential test
//! harness (seq/par equivalence + solver-vs-simulator) that locks the
//! whole tree together.

pub mod bucketed;
pub mod dp;
pub(crate) mod engine;
pub mod joint;
pub mod knapsack;
pub mod uniform;

/// A slicing of one (micro)batch along the token dimension.
#[derive(Debug, Clone, PartialEq)]
pub struct SliceScheme {
    /// Slice lengths l_1..l_M in tokens (sum = L).
    pub lens: Vec<u32>,
    /// Σ tᵢ — total per-cell occupancy (ms).
    pub total_ms: f64,
    /// maxⱼ tⱼ — the pipeline's slowest stage time (ms).
    pub t_max_ms: f64,
    /// Eq. 5 latency: total + (K-1)·t_max (ms).
    pub latency_ms: f64,
}

impl SliceScheme {
    pub fn num_slices(&self) -> usize {
        self.lens.len()
    }

    pub fn seq_len(&self) -> u32 {
        self.lens.iter().sum()
    }

    /// Paper notation, e.g. `[776, 640, 632]` (Table 2).
    pub fn notation(&self) -> String {
        format!(
            "[{}]",
            self.lens
                .iter()
                .map(|l| l.to_string())
                .collect::<Vec<_>>()
                .join(", ")
        )
    }
}

/// The full §3.4 plan for a minibatch: batch split + per-batch-slice token
/// schemes, e.g. the paper's `[(1, [776, 640, 632])] * 16`.
#[derive(Debug, Clone, PartialEq)]
pub struct JointScheme {
    /// (microbatch sequences, token scheme) per pipelined batch slice, in
    /// execution order.
    pub parts: Vec<(u32, SliceScheme)>,
    /// Predicted iteration latency (ms) under the Eq. 5-style objective.
    pub latency_ms: f64,
}

impl JointScheme {
    pub fn batch(&self) -> u32 {
        self.parts.iter().map(|(b, _)| b).sum()
    }

    /// Paper notation with run-length folding: `[(1, [2048])] * 32`.
    pub fn notation(&self) -> String {
        let mut runs: Vec<(String, u32)> = Vec::new();
        for (b, s) in &self.parts {
            let token = format!("({}, {})", b, s.notation());
            match runs.last_mut() {
                Some((t, n)) if *t == token => *n += 1,
                _ => runs.push((token, 1)),
            }
        }
        runs.iter()
            .map(|(t, n)| {
                if *n == 1 {
                    format!("[{t}]")
                } else {
                    format!("[{t}] * {n}")
                }
            })
            .collect::<Vec<_>>()
            .join(" + ")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn scheme(lens: &[u32]) -> SliceScheme {
        SliceScheme {
            lens: lens.to_vec(),
            total_ms: 1.0,
            t_max_ms: 1.0,
            latency_ms: 1.0,
        }
    }

    #[test]
    fn notation_matches_paper_style() {
        assert_eq!(scheme(&[776, 640, 632]).notation(), "[776, 640, 632]");
        let j = JointScheme {
            parts: vec![(1, scheme(&[2048])); 3],
            latency_ms: 0.0,
        };
        assert_eq!(j.notation(), "[(1, [2048])] * 3");
        let j2 = JointScheme {
            parts: vec![(1, scheme(&[2048])), (2, scheme(&[1024, 1024]))],
            latency_ms: 0.0,
        };
        assert_eq!(j2.notation(), "[(1, [2048])] + [(2, [1024, 1024])]");
    }

    #[test]
    fn joint_batch_sums_parts() {
        let j = JointScheme {
            parts: vec![(2, scheme(&[8])), (3, scheme(&[8]))],
            latency_ms: 0.0,
        };
        assert_eq!(j.batch(), 5);
    }
}
