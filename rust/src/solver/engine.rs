//! The generic parallel `t_max`-enumeration engine shared by every solver
//! front-end: [`super::dp`], [`super::bucketed`], and [`super::joint`].
//!
//! Each §3.3/§3.4 solver is, semantically, the same *sequential* search:
//! scan a sorted candidate pool of per-slice budgets ascending, evaluate
//! each budget into a plan and its Eq. 5 latency, keep the first-best
//! latency (ties broken by candidate order), and stop at the first
//! candidate where the paper's bound `(K-1)·t_max ≥ best` fires. What
//! differs per solver is only the *evaluation* of one budget — Algorithm 1
//! for the token DP, Algorithm 1 restricted to a bucket set, or the per-b
//! Algorithm-1 fan-out plus batch knapsack for the joint solver — so the
//! engine is parameterized over two closures:
//!
//! * `eval: Fn(t_max) -> Option<(latency, P)>` — run the solver's DP(s)
//!   under the budget and return the plan `P` with its Eq. 5 latency, or
//!   `None` when the budget is infeasible.
//! * `feasible: Fn(t_max) -> bool` (parallel path only) — a
//!   feasibility-only probe for the binary search, so solvers with a
//!   cheaper probe than a full `eval` (the joint solver skips scheme
//!   reconstruction) don't pay for plans the search throws away.
//!
//! The engine reproduces the sequential semantics **bit-identically**
//! while extracting parallelism from two places:
//!
//! 1. **Feasibility binary search** — every solver's feasibility is
//!    monotone in `t_max` (a larger budget only adds DP transitions, and a
//!    feasible knapsack composition stays feasible at a looser budget), so
//!    the infeasible prefix of the pool is skipped with O(log n) probes
//!    instead of one failed evaluation per infeasible candidate.
//! 2. **Blocked parallel scan** — candidates are processed in blocks of
//!    a few per thread; within a block every evaluation runs on its own
//!    worker (rayon), sharing an atomic best-latency bound so the
//!    `(K-1)·t_max` pruning keeps firing across workers. A sequential
//!    merge then replays the block's results *in candidate order* with
//!    exactly the serial update/break logic, so the chosen plan, its
//!    latency, and the tie-breaking are identical to [`enumerate_seq`].
//!
//! Why the merge is sound: a worker skips candidate `i` only when
//! `(K-1)·t_max(i) ≥ bound` for some already-published latency `bound`.
//! If that `bound` came from a candidate `< i`, the merge's own running
//! best is ≤ `bound` by the time it reaches `i`, so the serial break fires
//! at or before `i` and the skipped result is never needed. If it came
//! from a candidate `> i` (a wall-clock race), the merge recomputes the
//! evaluation inline — rare, and never changes the outcome.

use rayon::prelude::*;
use std::sync::atomic::{AtomicU64, Ordering};

use crate::perfmodel::TableCostModel;

/// Outcome of one enumeration: the winning `(latency, plan)` plus
/// evaluation counts for [`super::dp::SolveStats`].
pub(crate) struct EnumResult<P> {
    pub best: Option<(f64, P)>,
    /// Evaluations consumed by the scan itself (= the sequential
    /// reference's count from the first feasible candidate to the pruning
    /// break).
    pub dps_run: usize,
    /// Extra evaluations spent probing feasibility in the binary search.
    pub probe_dps: usize,
}

/// Sort ascending, drop exact duplicates, then apply the paper's ε-grid
/// (skip candidates closer than ε to the last kept one). The single shared
/// pool-preparation step for every solver front-end.
///
/// The maximum candidate is always retained even when the ε-grid would
/// merge it away: it is the loosest budget — the feasibility backstop
/// behind every solver's "the single-slice scheme always fits"
/// expectation — and dropping it could turn a solvable instance into a
/// panic for large ε.
pub(crate) fn dedup_candidates(mut cands: Vec<f64>, eps_ms: f64) -> Vec<f64> {
    cands.sort_unstable_by(|x, y| x.partial_cmp(y).unwrap());
    cands.dedup();
    if eps_ms <= 0.0 || cands.is_empty() {
        return cands;
    }
    let max = *cands.last().unwrap();
    let mut filtered = Vec::with_capacity(cands.len());
    let mut last = f64::NEG_INFINITY;
    for c in cands {
        if c - last >= eps_ms {
            filtered.push(c);
            last = c;
        }
    }
    if *filtered.last().unwrap() != max {
        filtered.push(max);
    }
    filtered
}

/// Max achieved per-slice stage time of a scheme (recomputing it under the
/// table tightens Eq. 5 versus using the enumerated budget directly).
pub(crate) fn achieved_tmax(table: &TableCostModel, lens_units: &[usize]) -> f64 {
    let mut ctx = 0usize;
    let mut m = f64::NEG_INFINITY;
    for &l in lens_units {
        m = m.max(table.at(l, ctx) + table.comm_at(l));
        ctx += l;
    }
    m
}

/// The retained sequential reference: the paper's plain ascending scan
/// with `(K-1)·t_max` pruning. Kept as the ground truth the parallel path
/// is property-tested against (and as the honest baseline for the
/// `dp_solver` bench).
pub(crate) fn enumerate_seq<P, E>(stages: u32, cands: &[f64], eval: E) -> EnumResult<P>
where
    E: Fn(f64) -> Option<(f64, P)>,
{
    let k_f = stages as f64 - 1.0;
    let mut best: Option<(f64, P)> = None;
    let mut dps_run = 0usize;
    for &tmax in cands {
        if let Some((bl, _)) = &best {
            if k_f * tmax >= *bl {
                break;
            }
        }
        dps_run += 1;
        if let Some((latency, plan)) = eval(tmax) {
            if best.as_ref().map_or(true, |(bl, _)| latency < *bl) {
                best = Some((latency, plan));
            }
        }
    }
    EnumResult {
        best,
        dps_run,
        probe_dps: 0,
    }
}

/// Per-candidate worker outcome inside one block.
enum CandOutcome<P> {
    /// Pruned by the shared bound — the merge either breaks before this
    /// index or recomputes it inline.
    Skipped,
    /// Evaluation ran: `(latency, plan)`, or `None` infeasible.
    Ran(Option<(f64, P)>),
}

/// The parallel engine. Bit-identical to [`enumerate_seq`] on the same
/// candidate list and `eval` closure (same winning plan, latency, and
/// tie-breaks); only the evaluation *counts* differ (the infeasible prefix
/// is binary-searched away, and wasted speculative evaluations past the
/// pruning break are not billed).
///
/// `feasible(t)` must agree with `eval(t).is_some()` for every candidate,
/// and feasibility must be monotone in `t` — both hold for every Algorithm
/// 1 variant and for the joint knapsack composition (see module docs).
pub(crate) fn enumerate_par<P, E, F>(
    stages: u32,
    cands: &[f64],
    feasible: F,
    eval: E,
) -> EnumResult<P>
where
    P: Send,
    E: Fn(f64) -> Option<(f64, P)> + Sync,
    F: Fn(f64) -> bool + Sync,
{
    if cands.is_empty() {
        return EnumResult {
            best: None,
            dps_run: 0,
            probe_dps: 0,
        };
    }
    // Feasibility binary search (monotone in t_max): find the first
    // feasible candidate; everything before it contributes nothing to the
    // sequential scan either.
    let mut probe_dps = 1usize;
    if !feasible(*cands.last().unwrap()) {
        // Even the loosest budget is infeasible (bucket sets that cannot
        // compose the sequence) — identical to the reference scanning
        // everything and finding nothing.
        return EnumResult {
            best: None,
            dps_run: 0,
            probe_dps,
        };
    }
    let mut lo = 0usize;
    let mut hi = cands.len() - 1; // known feasible
    while lo < hi {
        let mid = lo + (hi - lo) / 2;
        probe_dps += 1;
        if feasible(cands[mid]) {
            hi = mid;
        } else {
            lo = mid + 1;
        }
    }
    let first = lo;

    let (best, dps_run) = scan_from(stages, cands, first, eval);
    EnumResult {
        best,
        dps_run,
        probe_dps,
    }
}

/// The blocked parallel scan with the shared atomic best-latency bound —
/// the back half of [`enumerate_par`], starting at candidate index
/// `first` (all candidates below it must be infeasible, which is what the
/// caller's feasibility search established). Exposed crate-wide so the
/// planner's warm-started front-end (which finds `first` by galloping
/// from the previous solve's boundary instead of a full binary search)
/// runs the *identical* scan. Returns `(best, dps_run)`.
///
/// Latencies are positive finite f64s, whose IEEE-754 bit patterns order
/// identically to their values — so an AtomicU64 + fetch_min is a
/// lock-free shared upper bound.
pub(crate) fn scan_from<P, E>(
    stages: u32,
    cands: &[f64],
    first: usize,
    eval: E,
) -> (Option<(f64, P)>, usize)
where
    P: Send,
    E: Fn(f64) -> Option<(f64, P)> + Sync,
{
    let k_f = stages as f64 - 1.0;
    let threads = rayon::current_num_threads().max(1);
    let block = (4 * threads).max(16);
    let mut best: Option<(f64, P)> = None;
    let mut dps_run = 0usize;
    let mut start = first;
    'scan: while start < cands.len() {
        let end = (start + block).min(cands.len());
        let bound = AtomicU64::new(
            best.as_ref()
                .map(|(bl, _)| bl.to_bits())
                .unwrap_or(f64::INFINITY.to_bits()),
        );
        let outcomes: Vec<CandOutcome<P>> = cands[start..end]
            .par_iter()
            .map(|&tmax| {
                if k_f * tmax >= f64::from_bits(bound.load(Ordering::Relaxed)) {
                    return CandOutcome::Skipped;
                }
                match eval(tmax) {
                    None => CandOutcome::Ran(None),
                    Some((latency, plan)) => {
                        bound.fetch_min(latency.to_bits(), Ordering::Relaxed);
                        CandOutcome::Ran(Some((latency, plan)))
                    }
                }
            })
            .collect();

        // Sequential merge in candidate order — literally the reference
        // loop, with the evaluations precomputed.
        for (off, outcome) in outcomes.into_iter().enumerate() {
            let tmax = cands[start + off];
            if let Some((bl, _)) = &best {
                if k_f * tmax >= *bl {
                    break 'scan;
                }
            }
            dps_run += 1;
            let resolved = match outcome {
                CandOutcome::Ran(r) => r,
                CandOutcome::Skipped => {
                    // The bound raced ahead of the in-order prefix (set by
                    // a later candidate): replay this evaluation inline.
                    eval(tmax)
                }
            };
            if let Some((latency, plan)) = resolved {
                if best.as_ref().map_or(true, |(bl, _)| latency < *bl) {
                    best = Some((latency, plan));
                }
            }
        }
        start = end;
    }
    (best, dps_run)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::perfmodel::CostModel;
    use crate::solver::dp::{solve_fixed_tmax, token_eval};
    use crate::util::prop;

    struct Affine {
        over: f64,
        lin: f64,
        ctx: f64,
    }
    impl CostModel for Affine {
        fn t(&self, i: u32, j: u32) -> f64 {
            self.over + self.lin * i as f64 + self.ctx * i as f64 * j as f64
        }
    }

    fn table_for(g: &mut prop::Gen) -> TableCostModel {
        let m = Affine {
            over: g.float(0.01, 2.0),
            lin: g.float(0.001, 0.1),
            ctx: g.float(0.0, 3e-4),
        };
        let gran = *g.choose(&[8u32, 16]);
        let l = g.int(2, 24) * gran;
        TableCostModel::build(&m, l, gran)
    }

    #[test]
    fn dedup_sorts_dedups_and_eps_filters() {
        let out = dedup_candidates(vec![3.0, 1.0, 1.0, 2.0, 1.05], 0.0);
        assert_eq!(out, vec![1.0, 1.05, 2.0, 3.0]);
        let out = dedup_candidates(vec![3.0, 1.0, 1.0, 2.0, 1.05], 0.1);
        assert_eq!(out, vec![1.0, 2.0, 3.0]);
    }

    #[test]
    fn dedup_always_retains_the_loosest_candidate() {
        // the ε-grid would merge 1.05 into 1.0, but 1.05 is the
        // feasibility backstop (loosest budget) and must survive
        let out = dedup_candidates(vec![1.0, 1.05], 0.1);
        assert_eq!(out, vec![1.0, 1.05]);
        // huge ε: collapses to {min, max}
        let out = dedup_candidates(vec![1.0, 2.0, 3.0, 4.0], 100.0);
        assert_eq!(out, vec![1.0, 4.0]);
    }

    #[test]
    fn prop_par_enum_bit_identical_to_seq() {
        prop::run_cases(80, |g| {
            let table = table_for(g);
            let stages = g.int(1, 24);
            let eps = *g.choose(&[0.0f64, 0.05, 0.2]);
            let cands = dedup_candidates(table.stage_time_candidates(), eps);
            let seq = enumerate_seq(stages, &cands, token_eval(&table, stages));
            let par = enumerate_par(
                stages,
                &cands,
                |t| solve_fixed_tmax(&table, t).is_some(),
                token_eval(&table, stages),
            );
            match (&seq.best, &par.best) {
                (None, None) => {}
                (Some((sl, (ss, sa))), Some((pl, (ps, pa)))) => {
                    assert_eq!(ss.lens_units, ps.lens_units, "case {}", g.case);
                    assert!(sl == pl && sa == pa && ss.total_ms == ps.total_ms);
                }
                _ => panic!("feasibility disagreement at case {}", g.case),
            }
        });
    }

    #[test]
    fn empty_pool_yields_nothing() {
        let mut g = prop::Gen::new(7);
        let table = table_for(&mut g);
        let r = enumerate_par(
            4,
            &[],
            |t| solve_fixed_tmax(&table, t).is_some(),
            token_eval(&table, 4),
        );
        assert!(r.best.is_none());
        assert_eq!(r.dps_run + r.probe_dps, 0);
    }

    #[test]
    fn singleton_pool_evaluates_exactly_once() {
        let mut g = prop::Gen::new(11);
        let table = table_for(&mut g);
        let n = table.units();
        // the loosest budget: the whole-sequence slice always fits
        let loose = table.at(n, 0) + table.comm_at(n) + 1.0;
        let cands = vec![loose];
        let seq = enumerate_seq(6, &cands, token_eval(&table, 6));
        let par = enumerate_par(
            6,
            &cands,
            |t| solve_fixed_tmax(&table, t).is_some(),
            token_eval(&table, 6),
        );
        let (sl, (ss, _)) = seq.best.expect("loosest budget is feasible");
        let (pl, (ps, _)) = par.best.expect("loosest budget is feasible");
        assert_eq!(ss.lens_units, ps.lens_units);
        assert!(sl == pl);
        assert_eq!(seq.dps_run, 1);
        assert_eq!(par.dps_run, 1);
    }

    #[test]
    fn infeasible_pool_yields_nothing_for_both_paths() {
        let mut g = prop::Gen::new(3);
        let table = table_for(&mut g);
        // budgets below the cheapest single-unit slice: nothing is solvable
        let tiny = table.at(1, 0) * 0.5;
        let cands = vec![tiny * 0.5, tiny];
        let seq = enumerate_seq(4, &cands, token_eval(&table, 4));
        let par = enumerate_par(
            4,
            &cands,
            |t| solve_fixed_tmax(&table, t).is_some(),
            token_eval(&table, 4),
        );
        assert!(seq.best.is_none() && par.best.is_none());
        // the parallel path learns this from the single backstop probe
        assert_eq!(par.probe_dps, 1);
        assert_eq!(par.dps_run, 0);
    }

    #[test]
    fn single_unit_sequence_solves_on_both_paths() {
        // L = 1 grid unit: exactly one scheme ([1]) and one candidate.
        struct Toy;
        impl CostModel for Toy {
            fn t(&self, i: u32, j: u32) -> f64 {
                i as f64 + 0.01 * i as f64 * j as f64
            }
        }
        let table = TableCostModel::build(&Toy, 8, 8);
        assert_eq!(table.units(), 1);
        let cands = dedup_candidates(table.stage_time_candidates(), 0.0);
        assert_eq!(cands.len(), 1);
        for stages in [1u32, 4] {
            let seq = enumerate_seq(stages, &cands, token_eval(&table, stages));
            let par = enumerate_par(
                stages,
                &cands,
                |t| solve_fixed_tmax(&table, t).is_some(),
                token_eval(&table, stages),
            );
            let (sl, (ss, _)) = seq.best.expect("single-unit scheme fits");
            let (pl, (ps, _)) = par.best.expect("single-unit scheme fits");
            assert_eq!(ss.lens_units, vec![1]);
            assert_eq!(ps.lens_units, vec![1]);
            assert!(sl == pl);
        }
    }

    #[test]
    fn single_stage_scans_without_pruning() {
        // K = 1 ⇒ (K-1)·t_max = 0 never reaches a positive best: the scan
        // must visit every candidate from the first feasible one and both
        // paths must still agree.
        let mut g = prop::Gen::new(5);
        let table = table_for(&mut g);
        let cands = dedup_candidates(table.stage_time_candidates(), 0.0);
        let seq = enumerate_seq(1, &cands, token_eval(&table, 1));
        let par = enumerate_par(
            1,
            &cands,
            |t| solve_fixed_tmax(&table, t).is_some(),
            token_eval(&table, 1),
        );
        let (sl, (ss, _)) = seq.best.expect("loosest budget is feasible");
        let (pl, (ps, _)) = par.best.expect("loosest budget is feasible");
        assert_eq!(ss.lens_units, ps.lens_units);
        assert!(sl == pl);
        // no pruning: the merge walks every candidate past the first
        // feasible one (the parallel path still skips the infeasible
        // prefix, so its count is ≤ the reference's)
        assert_eq!(seq.dps_run, cands.len());
        assert!(par.dps_run <= seq.dps_run);
    }
}
