//! Algorithm 1 + the t_max enumeration (paper §3.3).
//!
//! Inner DP (Eq. 8): for a fixed per-slice budget `t_max`,
//!
//! ```text
//! S*(i; t_max) = min_{1≤k≤i} { S*(i-k; t_max) + t(k, i-k) | t(k, i-k) ≤ t_max }
//! ```
//!
//! computed over a granularity grid of `n = L / g` units in O(n²). The
//! inner loop at position `i` reads the table's anti-diagonal `d = i`
//! ([`TableCostModel::diag`]), which the diagonal-major layout makes one
//! contiguous run — the cache behaviour that lets the enumeration engine
//! stay memory-bound-free when it fans DPs out across cores.
//!
//! The outer loop (Eq. 6) enumerates candidate `t_max` values ascending,
//! with the paper's two optimizations:
//!
//! 1. **Pruning** — once `(K-1)·t_max` alone exceeds the best latency so
//!    far, no larger `t_max` can win; stop.
//! 2. **ε-grid** — skip candidates closer than ε to the last one tried;
//!    the result is within `K·ε` of the optimum (we default ε = 0.1 ms,
//!    the paper's value, and verify ε = 0 agreement in tests).
//!
//! [`solve_tokens`] runs the enumeration on the parallel engine
//! ([`super::engine`]): feasibility binary search over the sorted pool,
//! then a blocked multi-threaded scan with a shared atomic pruning bound.
//! [`solve_tokens_seq`] is the retained sequential reference — the two are
//! property-tested to be bit-identical (ties broken by candidate order).

use super::engine;
use super::SliceScheme;
use crate::perfmodel::{CostModel, TableCostModel};

/// Result of the inner DP for a fixed `t_max` (Algorithm 1).
#[derive(Debug, Clone)]
pub struct FixedTmaxSolution {
    /// Slice lengths in grid *units* (multiply by granularity for tokens).
    pub lens_units: Vec<usize>,
    /// S*(L; t_max) — minimal total time (ms).
    pub total_ms: f64,
}

/// The Alg-1 inner reduction at position `i`, unrolled into 4 independent
/// accumulator lanes (ROADMAP "SIMD inner loop"): lane `l` scans
/// `k ≡ 1 + l (mod 4)`, so the four `min(s[i-k] + t + comm[k])` chains
/// carry no cross-iteration dependency and auto-vectorize; a horizontal
/// min combines them.
///
/// Bit-identical to the scalar scan ([`inner_min_scalar`]): each lane's
/// strict-`<` update keeps the *first* (smallest-`k`) candidate achieving
/// the lane minimum, and the horizontal min prefers the smallest `k` among
/// value-tied lanes — exactly the scalar first-best tie-break. `f64` min
/// over finite/+∞ sums is order-insensitive, so the value is identical
/// too. Pinned by `prop_lanes_inner_reduction_bit_identical_to_scalar`.
#[inline]
fn inner_min_lanes(diag: &[f64], comm: &[f64], s: &[f64], i: usize, t_max: f64) -> (f64, usize) {
    let mut bl = [f64::INFINITY; 4];
    let mut bk = [0usize; 4];
    let mut k = 1usize;
    while k + 3 <= i {
        for lane in 0..4 {
            let kk = k + lane;
            let t = diag[kk - 1] + comm[kk];
            if t <= t_max {
                let cand = s[i - kk] + t;
                if cand < bl[lane] {
                    bl[lane] = cand;
                    bk[lane] = kk;
                }
            }
        }
        k += 4;
    }
    // tail (≤ 3 candidates): folding into lane 0 keeps the within-lane
    // first-best property — every tail k is larger than every chunked k
    while k <= i {
        let t = diag[k - 1] + comm[k];
        if t <= t_max {
            let cand = s[i - k] + t;
            if cand < bl[0] {
                bl[0] = cand;
                bk[0] = k;
            }
        }
        k += 1;
    }
    // horizontal min, smallest k on value ties (bk = 0 ⟺ lane empty)
    let mut best = f64::INFINITY;
    let mut bestk = 0usize;
    for lane in 0..4 {
        if bl[lane] < best || (bl[lane] == best && bk[lane] != 0 && bk[lane] < bestk) {
            best = bl[lane];
            bestk = bk[lane];
        }
    }
    (best, bestk)
}

/// The scalar reference for the inner reduction — the paper's literal
/// `min_{1≤k≤i}` scan. Retained as the property-test oracle for
/// [`inner_min_lanes`] and as the baseline `benches/planner.rs` times.
#[inline]
fn inner_min_scalar(diag: &[f64], comm: &[f64], s: &[f64], i: usize, t_max: f64) -> (f64, usize) {
    let mut best = f64::INFINITY;
    let mut bestk = 0usize;
    for k in 1..=i {
        let t = diag[k - 1] + comm[k];
        if t <= t_max {
            let cand = s[i - k] + t;
            if cand < best {
                best = cand;
                bestk = k;
            }
        }
    }
    (best, bestk)
}

fn solve_fixed_tmax_with(
    table: &TableCostModel,
    t_max: f64,
    inner: impl Fn(&[f64], &[f64], &[f64], usize, f64) -> (f64, usize),
) -> Option<FixedTmaxSolution> {
    let n = table.units();
    let comm = table.comms();
    // s[i] = S*(i; t_max); q[i] = argmin k (last-slice length in units)
    let mut s = vec![f64::INFINITY; n + 1];
    let mut q = vec![0usize; n + 1];
    s[0] = 0.0;
    for i in 1..=n {
        // diag[k-1] = t(k, i-k): the whole inner loop reads one
        // contiguous anti-diagonal instead of striding n-1 per candidate.
        let diag = table.diag(i);
        let (best, bestk) = inner(diag, comm, &s, i, t_max);
        s[i] = best;
        q[i] = bestk;
    }
    if !s[n].is_finite() {
        return None;
    }
    // Derive the slicing scheme by walking q back from L (Algorithm 1's
    // prepend loop).
    let mut lens = Vec::new();
    let mut i = n;
    while i > 0 {
        lens.push(q[i]);
        i -= q[i];
    }
    lens.reverse();
    Some(FixedTmaxSolution {
        lens_units: lens,
        total_ms: s[n],
    })
}

/// Algorithm 1: minimal total forward(+backward) time under `t_max`,
/// over `n` grid units. Returns `None` when no feasible slicing exists
/// (some position unreachable without exceeding `t_max`). Runs the
/// 4-lane unrolled inner reduction; bit-identical to
/// [`solve_fixed_tmax_ref`].
pub fn solve_fixed_tmax(table: &TableCostModel, t_max: f64) -> Option<FixedTmaxSolution> {
    solve_fixed_tmax_with(table, t_max, inner_min_lanes)
}

/// The retained scalar-scan reference for [`solve_fixed_tmax`] — the
/// property-test oracle and the honest per-DP baseline for the planner
/// bench.
pub fn solve_fixed_tmax_ref(table: &TableCostModel, t_max: f64) -> Option<FixedTmaxSolution> {
    solve_fixed_tmax_with(table, t_max, inner_min_scalar)
}

/// Solver statistics (for the §3.3 "within a minute" bench and EXPERIMENTS).
#[derive(Debug, Clone, Default)]
pub struct SolveStats {
    /// Candidate t_max values after exact + ε deduplication.
    pub candidates: usize,
    /// Inner DPs consumed by the enumeration scan (≤ candidates thanks to
    /// pruning; the parallel path also skips the infeasible prefix).
    pub dps_run: usize,
    /// Inner DPs spent probing feasibility in the binary search (parallel
    /// path only; 0 for the sequential reference).
    pub probe_dps: usize,
}

/// Full §3.3 solver: optimal token slicing of `seq_len` for a `stages`-deep
/// pipeline under `model`, on a `granularity`-token grid with the ε-grid
/// t_max enumeration. Returns the scheme in *tokens*. Runs on the parallel
/// engine; bit-identical to [`solve_tokens_seq`].
pub fn solve_tokens<M: CostModel>(
    model: &M,
    seq_len: u32,
    stages: u32,
    granularity: u32,
    eps_ms: f64,
) -> (SliceScheme, SolveStats) {
    let table = TableCostModel::build(model, seq_len, granularity);
    solve_tokens_table(&table, stages, eps_ms)
}

/// The engine's eval shape for the plain token DP: run Algorithm 1 under
/// the budget, tighten to the achieved stage max, report Eq. 5. Shared by
/// the parallel path, the sequential reference, and the engine's own test
/// suite, so everything enumerates literally the same closure.
pub(crate) fn token_eval<'a>(
    table: &'a TableCostModel,
    stages: u32,
) -> impl Fn(f64) -> Option<(f64, (FixedTmaxSolution, f64))> + Sync + 'a {
    let k_f = stages as f64 - 1.0;
    move |tmax| {
        solve_fixed_tmax(table, tmax).map(|sol| {
            let achieved = engine::achieved_tmax(table, &sol.lens_units);
            (sol.total_ms + k_f * achieved, (sol, achieved))
        })
    }
}

/// Same, over a pre-densified table (the hot path for the joint solver and
/// the benches, which reuse one table across runs).
pub fn solve_tokens_table(
    table: &TableCostModel,
    stages: u32,
    eps_ms: f64,
) -> (SliceScheme, SolveStats) {
    let cands = engine::dedup_candidates(table.stage_time_candidates(), eps_ms);
    let r = engine::enumerate_par(
        stages,
        &cands,
        |tmax| solve_fixed_tmax(table, tmax).is_some(),
        token_eval(table, stages),
    );
    finish(table.granularity(), cands.len(), r)
}

/// The retained sequential reference: identical candidate pool, plain
/// ascending scan with the paper's pruning. Ground truth for the
/// equivalence property tests and the bench's speedup baseline.
pub fn solve_tokens_seq<M: CostModel>(
    model: &M,
    seq_len: u32,
    stages: u32,
    granularity: u32,
    eps_ms: f64,
) -> (SliceScheme, SolveStats) {
    let table = TableCostModel::build(model, seq_len, granularity);
    solve_tokens_table_seq(&table, stages, eps_ms)
}

/// Sequential reference over a pre-densified table.
pub fn solve_tokens_table_seq(
    table: &TableCostModel,
    stages: u32,
    eps_ms: f64,
) -> (SliceScheme, SolveStats) {
    let cands = engine::dedup_candidates(table.stage_time_candidates(), eps_ms);
    let r = engine::enumerate_seq(stages, &cands, token_eval(table, stages));
    finish(table.granularity(), cands.len(), r)
}

/// Package an enumeration result as a token [`SliceScheme`] + stats —
/// shared by the cold front-ends here and the planner's warm path.
pub(crate) fn finish(
    granularity: u32,
    candidates: usize,
    r: engine::EnumResult<(FixedTmaxSolution, f64)>,
) -> (SliceScheme, SolveStats) {
    let stats = SolveStats {
        candidates,
        dps_run: r.dps_run,
        probe_dps: r.probe_dps,
    };
    let (latency, (sol, tmax)) = r.best.expect("t_max = max stage time is always feasible");
    (
        SliceScheme {
            lens: sol.lens_units.iter().map(|&u| u as u32 * granularity).collect(),
            total_ms: sol.total_ms,
            t_max_ms: tmax,
            latency_ms: latency,
        },
        stats,
    )
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::perfmodel::{pipeline_latency, CostModel, TableCostModel};

    /// Cost with a fixed overhead per slice + linear + context term — makes
    /// both extremes (1 slice, n slices) suboptimal.
    struct Affine {
        over: f64,
        lin: f64,
        ctx: f64,
    }
    impl CostModel for Affine {
        fn t(&self, i: u32, j: u32) -> f64 {
            self.over + self.lin * i as f64 + self.ctx * i as f64 * j as f64
        }
    }

    fn default_model() -> Affine {
        Affine {
            over: 1.0,
            lin: 0.05,
            ctx: 2e-4,
        }
    }

    #[test]
    fn scheme_covers_sequence_exactly() {
        let (s, _) = solve_tokens(&default_model(), 256, 8, 8, 0.0);
        assert_eq!(s.seq_len(), 256);
        assert!(s.lens.iter().all(|&l| l > 0 && l % 8 == 0));
    }

    #[test]
    fn latency_matches_eq5_evaluation() {
        let m = default_model();
        let (s, _) = solve_tokens(&m, 256, 8, 8, 0.0);
        let eval = pipeline_latency(&m, &s.lens, 8);
        assert!((eval - s.latency_ms).abs() < 1e-9, "{eval} vs {}", s.latency_ms);
    }

    #[test]
    fn exhaustive_optimality_small_instance() {
        // n = 8 units: enumerate all 2^(n-1) = 128 compositions and check
        // the DP finds the global optimum of Eq. 5.
        let m = default_model();
        let k = 5u32;
        let g = 8u32;
        let n = 8usize;
        let (s, _) = solve_tokens(&m, (n as u32) * g, k, g, 0.0);

        let mut best = f64::INFINITY;
        for mask in 0..(1u32 << (n - 1)) {
            let mut lens = Vec::new();
            let mut run = 1u32;
            for bit in 0..(n - 1) {
                if mask >> bit & 1 == 1 {
                    lens.push(run * g);
                    run = 1;
                } else {
                    run += 1;
                }
            }
            lens.push(run * g);
            best = best.min(pipeline_latency(&m, &lens, k));
        }
        assert!(
            (s.latency_ms - best).abs() < 1e-9,
            "dp {} vs exhaustive {}",
            s.latency_ms,
            best
        );
    }

    #[test]
    fn deep_pipeline_prefers_finer_slices() {
        let m = default_model();
        let (s1, _) = solve_tokens(&m, 512, 1, 8, 0.0);
        let (s16, _) = solve_tokens(&m, 512, 16, 8, 0.0);
        // K=1: no bubble term, one big slice minimizes overhead-dominated sum
        assert_eq!(s1.num_slices(), 1);
        assert!(s16.num_slices() > s1.num_slices());
    }

    #[test]
    fn nonuniform_context_gives_decreasing_slice_lengths() {
        // With a strong context term, the optimal scheme starts long and
        // shrinks (paper §3.2: "long slice in the beginning, shorter in the
        // end"). Weak monotonicity with granularity rounding.
        let m = Affine {
            over: 0.1,
            lin: 0.02,
            ctx: 4e-5,
        };
        let (s, _) = solve_tokens(&m, 512, 24, 8, 0.0);
        assert!(s.num_slices() >= 3);
        let first = s.lens.first().copied().unwrap();
        let last = s.lens.last().copied().unwrap();
        assert!(
            first >= last,
            "expected front-loaded scheme, got {:?}",
            s.lens
        );
    }

    #[test]
    fn epsilon_grid_matches_exact_on_paper_sized_instance() {
        // The paper reports ε = 0.1 ms always matched ε = 0 in their
        // settings; verify on our model.
        let m = default_model();
        let (exact, _) = solve_tokens(&m, 2048, 24, 64, 0.0);
        let (eps, _) = solve_tokens(&m, 2048, 24, 64, 0.1);
        assert!((exact.latency_ms - eps.latency_ms).abs() <= 24.0 * 0.1 + 1e-9);
        // and in practice identical:
        assert_eq!(exact.lens, eps.lens);
    }

    #[test]
    fn pruning_reduces_dps_run() {
        let m = default_model();
        let (_, stats) = solve_tokens(&m, 1024, 8, 32, 0.0);
        assert!(stats.dps_run < stats.candidates, "{stats:?}");
        // the sequential reference prunes too
        let (_, sstats) = solve_tokens_seq(&m, 1024, 8, 32, 0.0);
        assert!(sstats.dps_run < sstats.candidates, "{sstats:?}");
        // and the parallel path's binary search skips the infeasible
        // prefix the reference pays for candidate-by-candidate
        assert!(stats.dps_run <= sstats.dps_run, "{stats:?} vs {sstats:?}");
    }

    #[test]
    fn parallel_and_sequential_agree_on_default_model() {
        let m = default_model();
        for eps in [0.0, 0.1] {
            let (p, ps) = solve_tokens(&m, 1024, 16, 32, eps);
            let (s, ss) = solve_tokens_seq(&m, 1024, 16, 32, eps);
            assert_eq!(p.lens, s.lens);
            assert!(p.latency_ms == s.latency_ms && p.total_ms == s.total_ms);
            assert_eq!(ps.candidates, ss.candidates);
        }
    }

    /// The 4-lane unrolled inner reduction must be **bit-identical** to
    /// the scalar scan — same `s` values (f64 `==`), same argmin
    /// tie-breaks (first smallest `k`), across random models, grid sizes
    /// (covering the ≤3-unit tail-only case), and budgets spanning
    /// infeasible → loose.
    #[test]
    fn prop_lanes_inner_reduction_bit_identical_to_scalar() {
        use crate::util::prop;
        prop::run_cases(120, |g| {
            let m = Affine {
                over: g.float(0.01, 2.0),
                lin: g.float(0.001, 0.1),
                ctx: g.float(0.0, 3e-4),
            };
            let gran = *g.choose(&[8u32, 16]);
            let l = g.int(1, 24) * gran; // incl. n ∈ {1, 2, 3}: tail-only
            let table = TableCostModel::build(&m, l, gran);
            let n = table.units();
            let top = table.at(n, 0) + table.comm_at(n);
            for f in [0.05f64, 0.3, 0.6, 0.9, 1.0, 1.4] {
                let tmax = top * f;
                let lanes = solve_fixed_tmax(&table, tmax);
                let scalar = solve_fixed_tmax_ref(&table, tmax);
                match (lanes, scalar) {
                    (None, None) => {}
                    (Some(a), Some(b)) => {
                        assert_eq!(a.lens_units, b.lens_units, "case {} f={f}", g.case);
                        assert!(a.total_ms == b.total_ms, "case {} f={f}", g.case);
                    }
                    (a, b) => panic!(
                        "feasibility disagreement at case {} f={f}: lanes={} scalar={}",
                        g.case,
                        a.is_some(),
                        b.is_some()
                    ),
                }
            }
        });
    }

    #[test]
    fn fixed_tmax_infeasible_returns_none() {
        let m = default_model();
        let table = TableCostModel::build(&m, 64, 8);
        assert!(solve_fixed_tmax(&table, 0.5).is_none()); // below min cost
    }

    #[test]
    fn fixed_tmax_reconstruction_consistent() {
        let m = default_model();
        let table = TableCostModel::build(&m, 256, 8);
        let sol = solve_fixed_tmax(&table, 3.0).unwrap();
        assert_eq!(sol.lens_units.iter().sum::<usize>(), 32);
        // recompute total from the scheme
        let mut ctx = 0usize;
        let mut total = 0.0;
        for &l in &sol.lens_units {
            let t = table.at(l, ctx);
            assert!(t <= 3.0 + 1e-12);
            total += t;
            ctx += l;
        }
        assert!((total - sol.total_ms).abs() < 1e-9);
    }

    #[test]
    fn single_stage_picks_single_slice_when_no_overhead_amortization() {
        // K=1 ⇒ Eq. 5 = Σtᵢ; with per-slice overhead the single slice wins.
        let m = default_model();
        let (s, _) = solve_tokens(&m, 1024, 1, 32, 0.0);
        assert_eq!(s.lens, vec![1024]);
    }
}
