//! Uniform slicing — the Fig. 6 ablation baseline.
//!
//! "Splitting inputs into multiple same-size chunks for pipelining, as
//! normally done in existing work, is not the ideal way for pipelining on
//! the token dimension" (§3.2). This module builds those same-size schemes
//! so the benches can reproduce the DP-vs-uniform gap.

use super::SliceScheme;
use crate::perfmodel::CostModel;

/// Slice `seq_len` into `num_slices` near-equal parts (remainder spread
/// over the leading slices, keeping every length a multiple of
/// `granularity` when possible).
pub fn uniform_lens(seq_len: u32, num_slices: u32, granularity: u32) -> Vec<u32> {
    assert!(num_slices >= 1 && num_slices * granularity <= seq_len.max(granularity));
    let units = seq_len / granularity;
    let base = units / num_slices;
    let extra = units % num_slices;
    let mut lens: Vec<u32> = (0..num_slices)
        .map(|i| (base + u32::from(i < extra)) * granularity)
        .collect();
    // granularity may not divide seq_len exactly: pad the first slice
    let covered: u32 = lens.iter().sum();
    lens[0] += seq_len - covered;
    lens
}

/// Evaluate the uniform scheme with `num_slices` under Eq. 5.
pub fn uniform_scheme<M: CostModel>(
    model: &M,
    seq_len: u32,
    stages: u32,
    num_slices: u32,
    granularity: u32,
) -> SliceScheme {
    let lens = uniform_lens(seq_len, num_slices, granularity);
    let mut ctx = 0u32;
    let mut total = 0.0;
    let mut tmax = f64::NEG_INFINITY;
    for &l in &lens {
        let t = model.t(l, ctx) + model.t_comm(l);
        total += t;
        tmax = tmax.max(t);
        ctx += l;
    }
    SliceScheme {
        lens,
        total_ms: total,
        t_max_ms: tmax,
        latency_ms: total + (stages as f64 - 1.0) * tmax,
    }
}

/// Sweep #slices over powers of two (the Fig. 6 x-axis) and return
/// (num_slices, scheme) pairs. Each slice count is evaluated on its own
/// thread (they are independent); the output order stays ascending.
pub fn sweep<M: CostModel + Sync>(
    model: &M,
    seq_len: u32,
    stages: u32,
    max_slices: u32,
    granularity: u32,
) -> Vec<(u32, SliceScheme)> {
    use rayon::prelude::*;
    let mut counts = Vec::new();
    let mut m = 1u32;
    while m <= max_slices && m * granularity <= seq_len {
        counts.push(m);
        m *= 2;
    }
    counts
        .into_par_iter()
        .map(|n| (n, uniform_scheme(model, seq_len, stages, n, granularity)))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::perfmodel::CostModel;

    struct Toy;
    impl CostModel for Toy {
        fn t(&self, i: u32, j: u32) -> f64 {
            0.5 + 0.01 * i as f64 + 1e-5 * i as f64 * j as f64
        }
    }

    #[test]
    fn uniform_lens_cover_and_balance() {
        let lens = uniform_lens(2048, 16, 8);
        assert_eq!(lens.iter().sum::<u32>(), 2048);
        assert!(lens.iter().all(|&l| l == 128));
        let lens = uniform_lens(2048, 3, 8);
        assert_eq!(lens.iter().sum::<u32>(), 2048);
        let max = lens.iter().max().unwrap();
        let min = lens.iter().min().unwrap();
        assert!(max - min <= 8, "{lens:?}");
    }

    #[test]
    fn uniform_lens_handles_indivisible_seq() {
        let lens = uniform_lens(100, 3, 8);
        assert_eq!(lens.iter().sum::<u32>(), 100);
    }

    #[test]
    fn later_uniform_slices_dominate_tmax() {
        // Non-uniform running time of uniform splits (paper Fig. 4 top):
        // the last slice carries the most context ⇒ defines t_max.
        let s = uniform_scheme(&Toy, 1024, 4, 8, 8);
        let last_ctx: u32 = s.lens[..7].iter().sum();
        let t_last = Toy.t(s.lens[7], last_ctx);
        assert!((s.t_max_ms - t_last).abs() < 1e-12);
    }

    #[test]
    fn sweep_returns_powers_of_two() {
        let sw = sweep(&Toy, 2048, 8, 128, 8);
        let ns: Vec<u32> = sw.iter().map(|(n, _)| *n).collect();
        assert_eq!(ns, vec![1, 2, 4, 8, 16, 32, 64, 128]);
    }

    #[test]
    fn some_intermediate_slice_count_wins() {
        // Fig. 6: both #slices=1 (big bubbles) and #slices=max (overhead)
        // lose to an intermediate count.
        let sw = sweep(&Toy, 2048, 16, 128, 8);
        let best = sw
            .iter()
            .min_by(|a, b| a.1.latency_ms.partial_cmp(&b.1.latency_ms).unwrap())
            .unwrap();
        assert!(best.0 > 1 && best.0 < 128, "best #slices {}", best.0);
    }
}
