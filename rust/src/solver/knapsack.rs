//! The 1-D knapsack the joint batch+token scheme reduces to (§3.4).
//!
//! Given per-batch-size costs `T_b` (b = 1..=B), pick counts of batch
//! slices `b_1, …, b_D` with `Σ b_d = B` minimizing `Σ T_{b_d}` — an
//! unbounded min-cost exact-cover over the batch dimension, solved by DP in
//! O(B²).
//!
//! The paper's stated reduction sets `T_b = S_b + (K-1)·t_max,b`, which
//! charges the pipeline-fill bubble once *per part* while Eq. 5 charges it
//! once *per iteration* — [`min_latency_composition`] is the corrected
//! objective `Σ S_{b_d} + (K-1)·max_d t_max,{b_d}`, solved exactly by
//! enumerating the bubble-defining budget over the distinct per-b stage
//! maxima (O(B) knapsacks).

/// `costs[b-1]` = T_b for a batch slice of `b` sequences. Returns the
/// minimizing composition (descending) and its total cost, or `None` if
/// `costs` is empty or `total` is 0.
pub fn min_cost_composition(costs: &[f64], total: u32) -> Option<(Vec<u32>, f64)> {
    if costs.is_empty() || total == 0 {
        return None;
    }
    let b_max = costs.len().min(total as usize);
    let n = total as usize;
    let mut dp = vec![f64::INFINITY; n + 1];
    let mut choice = vec![0usize; n + 1];
    dp[0] = 0.0;
    for i in 1..=n {
        for b in 1..=b_max.min(i) {
            let c = dp[i - b] + costs[b - 1];
            if c < dp[i] {
                dp[i] = c;
                choice[i] = b;
            }
        }
    }
    if !dp[n].is_finite() {
        return None;
    }
    let mut parts = Vec::new();
    let mut i = n;
    while i > 0 {
        parts.push(choice[i] as u32);
        i -= choice[i];
    }
    parts.sort_unstable_by(|a, b| b.cmp(a));
    Some((parts, dp[n]))
}

/// The corrected §3.4 composition objective: given per-batch-size *totals*
/// `totals[b-1] = S_b` and per-batch-size max stage times
/// `tmaxes[b-1] = t_max,b`, pick `b_1 + … + b_D = total` minimizing the
/// Eq. 5 latency `Σ S_{b_d} + (K-1)·max_d t_max,{b_d}` — the bubble term
/// counted **once**, not once per part as the paper's `T_b` reduction
/// does.
///
/// Exact in O(B) knapsacks: the max term takes one of the distinct
/// `t_max,b` values `m`; for each, restrict the knapsack to batch sizes
/// with `t_max,b ≤ m` and charge `(K-1)·m` once. An entry with a
/// non-finite total (infeasible batch size) is never picked. Returns the
/// minimizing composition (descending) and its latency.
pub fn min_latency_composition(
    totals: &[f64],
    tmaxes: &[f64],
    total: u32,
    stages: u32,
) -> Option<(Vec<u32>, f64)> {
    assert_eq!(totals.len(), tmaxes.len());
    if totals.is_empty() || total == 0 {
        return None;
    }
    let k_f = stages as f64 - 1.0;
    let mut budgets: Vec<f64> = tmaxes
        .iter()
        .zip(totals)
        .filter(|(_, &s)| s.is_finite())
        .map(|(&m, _)| m)
        .collect();
    budgets.sort_by(|a, b| a.partial_cmp(b).unwrap());
    budgets.dedup();
    let mut best: Option<(Vec<u32>, f64)> = None;
    for &m in &budgets {
        // mask out batch sizes whose own stage max exceeds the budget
        let masked: Vec<f64> = totals
            .iter()
            .zip(tmaxes)
            .map(|(&s, &t)| if t <= m { s } else { f64::INFINITY })
            .collect();
        if let Some((parts, cost)) = min_cost_composition(&masked, total) {
            if cost.is_finite() {
                let latency = cost + k_f * m;
                if best.as_ref().map_or(true, |(_, bl)| latency < *bl) {
                    best = Some((parts, latency));
                }
            }
        }
    }
    best
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;

    #[test]
    fn picks_cheapest_single_part_when_subadditive() {
        // T_b = b (perfectly linear): any composition costs the same.
        let costs: Vec<f64> = (1..=8).map(|b| b as f64).collect();
        let (parts, cost) = min_cost_composition(&costs, 8).unwrap();
        assert_eq!(parts.iter().sum::<u32>(), 8);
        assert!((cost - 8.0).abs() < 1e-12);
    }

    #[test]
    fn prefers_large_parts_with_economies_of_scale() {
        // T_b = 1 + 0.1·b: fixed overhead per part ⇒ one big part wins.
        let costs: Vec<f64> = (1..=8).map(|b| 1.0 + 0.1 * b as f64).collect();
        let (parts, _) = min_cost_composition(&costs, 8).unwrap();
        assert_eq!(parts, vec![8]);
    }

    #[test]
    fn prefers_small_parts_with_diseconomies() {
        // Superlinear T_b ⇒ all-ones wins.
        let costs: Vec<f64> = (1..=8).map(|b| (b * b) as f64).collect();
        let (parts, cost) = min_cost_composition(&costs, 8).unwrap();
        assert_eq!(parts, vec![1; 8]);
        assert!((cost - 8.0).abs() < 1e-12);
    }

    #[test]
    fn handles_total_larger_than_cost_table() {
        let costs = vec![1.0, 1.5]; // only b ∈ {1, 2} available
        let (parts, cost) = min_cost_composition(&costs, 5).unwrap();
        assert_eq!(parts.iter().sum::<u32>(), 5);
        assert!((cost - (2.0 * 1.5 + 1.0)).abs() < 1e-12);
    }

    #[test]
    fn empty_and_zero_rejected() {
        assert!(min_cost_composition(&[], 4).is_none());
        assert!(min_cost_composition(&[1.0], 0).is_none());
    }

    /// Regression for the double-counted bubble (joint.rs audit): the
    /// paper's `T_b = S_b + (K-1)·t_max,b` knapsack pays the bubble once
    /// per part, steering it away from multi-part compositions that the
    /// true Eq. 5 objective prefers.
    #[test]
    fn single_counted_bubble_fixes_double_count_regression() {
        // b=1: S=1.0, m=0.5; b=2: S=2.2, m=0.5; K=11 (k_f = 10), B=2.
        // True objective:  [1,1] = 2.0 + 10·0.5 = 7.0  <  [2] = 7.2
        // T_b reduction:   [1,1] = 2·(1.0+5.0) = 12.0  >  [2] = 7.2
        let totals = [1.0, 2.2];
        let tmaxes = [0.5, 0.5];
        let (parts, latency) = min_latency_composition(&totals, &tmaxes, 2, 11).unwrap();
        assert_eq!(parts, vec![1, 1]);
        assert!((latency - 7.0).abs() < 1e-12, "{latency}");
        // pin the old behaviour the fix replaces: the double-counting
        // knapsack picks the strictly worse single part
        let t_b: Vec<f64> = totals.iter().zip(&tmaxes).map(|(s, m)| s + 10.0 * m).collect();
        let (old_parts, _) = min_cost_composition(&t_b, 2).unwrap();
        assert_eq!(old_parts, vec![2]);
    }

    #[test]
    fn min_latency_composition_skips_infeasible_batch_sizes() {
        // b=2 infeasible (∞ total): composition must fall back to 1s and
        // its t_max must not poison the budget enumeration.
        let totals = [1.0, f64::INFINITY];
        let tmaxes = [0.4, 0.1];
        let (parts, latency) = min_latency_composition(&totals, &tmaxes, 3, 5).unwrap();
        assert_eq!(parts, vec![1, 1, 1]);
        assert!((latency - (3.0 + 4.0 * 0.4)).abs() < 1e-12);
        assert!(min_latency_composition(&[], &[], 3, 5).is_none());
        assert!(min_latency_composition(&totals, &tmaxes, 0, 5).is_none());
    }

    /// Property: the single-counted composition is valid, its latency is
    /// the recomputed Eq. 5 value, and no random composition beats it.
    #[test]
    fn prop_min_latency_composition_optimal() {
        prop::run_cases(128, |g| {
            let n = g.int(1, 6) as usize;
            let totals = g.floats(n, 0.01, 10.0);
            let tmaxes = g.floats(n, 0.01, 5.0);
            let total = g.int(1, 10);
            let stages = g.int(1, 24);
            let k_f = stages as f64 - 1.0;
            let (parts, latency) =
                min_latency_composition(&totals, &tmaxes, total, stages).unwrap();
            assert_eq!(parts.iter().sum::<u32>(), total);
            let recomputed: f64 = parts.iter().map(|&p| totals[p as usize - 1]).sum::<f64>()
                + k_f
                    * parts
                        .iter()
                        .map(|&p| tmaxes[p as usize - 1])
                        .fold(f64::NEG_INFINITY, f64::max);
            assert!((recomputed - latency).abs() < 1e-9, "case {}", g.case);

            for _ in 0..100 {
                let mut rem = total;
                let mut sum = 0.0;
                let mut m = f64::NEG_INFINITY;
                while rem > 0 {
                    let b = g.int(1, rem.min(totals.len() as u32));
                    sum += totals[b as usize - 1];
                    m = m.max(tmaxes[b as usize - 1]);
                    rem -= b;
                }
                let adversary = sum + k_f * m;
                assert!(latency <= adversary + 1e-9, "case {}: {latency} beaten by {adversary}", g.case);
            }
        });
    }

    /// Property: the DP result is a valid composition and beats 200 random
    /// compositions per case.
    #[test]
    fn prop_optimal_vs_random_compositions() {
        prop::run_cases(256, |g| {
            let n = g.int(1, 6) as usize;
            let costs = g.floats(n, 0.01, 10.0);
            let total = g.int(1, 12);
            let (parts, cost) = min_cost_composition(&costs, total).unwrap();
            assert_eq!(parts.iter().sum::<u32>(), total);
            assert!(parts.iter().all(|&p| p >= 1 && p as usize <= costs.len()));
            let recomputed: f64 = parts.iter().map(|&p| costs[p as usize - 1]).sum();
            assert!((recomputed - cost).abs() < 1e-9);

            // random adversary compositions
            for _ in 0..200 {
                let mut rem = total;
                let mut c = 0.0;
                while rem > 0 {
                    let b = g.int(1, rem.min(costs.len() as u32));
                    c += costs[b as usize - 1];
                    rem -= b;
                }
                assert!(cost <= c + 1e-9, "dp {cost} beaten by random {c}");
            }
        });
    }
}
