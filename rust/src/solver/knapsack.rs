//! The 1-D knapsack the joint batch+token scheme reduces to (§3.4).
//!
//! Given per-batch-size costs `T_b` (b = 1..=B), pick counts of batch
//! slices `b_1, …, b_D` with `Σ b_d = B` minimizing `Σ T_{b_d}` — an
//! unbounded min-cost exact-cover over the batch dimension, solved by DP in
//! O(B²).

/// `costs[b-1]` = T_b for a batch slice of `b` sequences. Returns the
/// minimizing composition (descending) and its total cost, or `None` if
/// `costs` is empty or `total` is 0.
pub fn min_cost_composition(costs: &[f64], total: u32) -> Option<(Vec<u32>, f64)> {
    if costs.is_empty() || total == 0 {
        return None;
    }
    let b_max = costs.len().min(total as usize);
    let n = total as usize;
    let mut dp = vec![f64::INFINITY; n + 1];
    let mut choice = vec![0usize; n + 1];
    dp[0] = 0.0;
    for i in 1..=n {
        for b in 1..=b_max.min(i) {
            let c = dp[i - b] + costs[b - 1];
            if c < dp[i] {
                dp[i] = c;
                choice[i] = b;
            }
        }
    }
    if !dp[n].is_finite() {
        return None;
    }
    let mut parts = Vec::new();
    let mut i = n;
    while i > 0 {
        parts.push(choice[i] as u32);
        i -= choice[i];
    }
    parts.sort_unstable_by(|a, b| b.cmp(a));
    Some((parts, dp[n]))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;

    #[test]
    fn picks_cheapest_single_part_when_subadditive() {
        // T_b = b (perfectly linear): any composition costs the same.
        let costs: Vec<f64> = (1..=8).map(|b| b as f64).collect();
        let (parts, cost) = min_cost_composition(&costs, 8).unwrap();
        assert_eq!(parts.iter().sum::<u32>(), 8);
        assert!((cost - 8.0).abs() < 1e-12);
    }

    #[test]
    fn prefers_large_parts_with_economies_of_scale() {
        // T_b = 1 + 0.1·b: fixed overhead per part ⇒ one big part wins.
        let costs: Vec<f64> = (1..=8).map(|b| 1.0 + 0.1 * b as f64).collect();
        let (parts, _) = min_cost_composition(&costs, 8).unwrap();
        assert_eq!(parts, vec![8]);
    }

    #[test]
    fn prefers_small_parts_with_diseconomies() {
        // Superlinear T_b ⇒ all-ones wins.
        let costs: Vec<f64> = (1..=8).map(|b| (b * b) as f64).collect();
        let (parts, cost) = min_cost_composition(&costs, 8).unwrap();
        assert_eq!(parts, vec![1; 8]);
        assert!((cost - 8.0).abs() < 1e-12);
    }

    #[test]
    fn handles_total_larger_than_cost_table() {
        let costs = vec![1.0, 1.5]; // only b ∈ {1, 2} available
        let (parts, cost) = min_cost_composition(&costs, 5).unwrap();
        assert_eq!(parts.iter().sum::<u32>(), 5);
        assert!((cost - (2.0 * 1.5 + 1.0)).abs() < 1e-12);
    }

    #[test]
    fn empty_and_zero_rejected() {
        assert!(min_cost_composition(&[], 4).is_none());
        assert!(min_cost_composition(&[1.0], 0).is_none());
    }

    /// Property: the DP result is a valid composition and beats 200 random
    /// compositions per case.
    #[test]
    fn prop_optimal_vs_random_compositions() {
        prop::run_cases(256, |g| {
            let n = g.int(1, 6) as usize;
            let costs = g.floats(n, 0.01, 10.0);
            let total = g.int(1, 12);
            let (parts, cost) = min_cost_composition(&costs, total).unwrap();
            assert_eq!(parts.iter().sum::<u32>(), total);
            assert!(parts.iter().all(|&p| p >= 1 && p as usize <= costs.len()));
            let recomputed: f64 = parts.iter().map(|&p| costs[p as usize - 1]).sum();
            assert!((recomputed - cost).abs() < 1e-9);

            // random adversary compositions
            for _ in 0..200 {
                let mut rem = total;
                let mut c = 0.0;
                while rem > 0 {
                    let b = g.int(1, rem.min(costs.len() as u32));
                    c += costs[b as usize - 1];
                    rem -= b;
                }
                assert!(cost <= c + 1e-9, "dp {cost} beaten by random {c}");
            }
        });
    }
}
