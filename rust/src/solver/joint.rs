//! Joint batch + token slicing (paper §3.4).
//!
//! "We first run the whole DP algorithm for all different batch sizes b
//! from 1 to B; for each b we derive the optimal T_b and slicing scheme
//! s_b. We then only need to determine the size of each slice in the batch
//! dimension b_1, …, b_D such that b_1 + … + b_D = B and T_{b_1} + … +
//! T_{b_D} is minimized — a 1-D knapsack."
//!
//! The paper's knapsack objective double-counts the (K-1)·t_max bubble
//! term — summing `T_b = S_b + (K-1)·t_max,b` charges the pipeline fill
//! once per batch part where Eq. 5 charges it once per iteration.
//! [`solve_joint`] therefore composes the batch dimension with
//! [`min_latency_composition`] (totals knapsacked, the bubble charged once
//! on the composition's max stage time), and re-evaluates the chosen plan
//! under the exact Eq. 5 objective over the concatenated slice stream;
//! that value is what we report and what the simulator is checked against.
//!
//! [`solve_joint_exact`] goes further: it enumerates a *global* `t_max`
//! over the union candidate pool on the shared enumeration engine
//! ([`super::engine`]) — the same feasibility binary search + blocked
//! parallel scan the §3.3 token solver runs on — and is bit-identical to
//! the retained sequential oracle [`solve_joint_seq`] (enforced by
//! `rust/tests/solver_joint_equivalence.rs`).

use rayon::prelude::*;

use super::dp::{solve_fixed_tmax, solve_tokens_table, FixedTmaxSolution};
use super::engine;
use super::knapsack::{min_cost_composition, min_latency_composition};
use super::{JointScheme, SliceScheme};
use crate::perfmodel::analytic::AnalyticModel;
use crate::perfmodel::{CostModel, TableCostModel};

/// Options for the joint solver.
#[derive(Debug, Clone)]
pub struct JointOpts {
    /// Token-grid granularity (tokens); the paper's schemes are multiples
    /// of 8.
    pub granularity: u32,
    /// ε for the t_max enumeration (ms); paper uses 0.1.
    pub eps_ms: f64,
    /// Cap on per-part microbatch (≤ pipeline batch).
    pub max_microbatch: Option<u32>,
}

impl Default for JointOpts {
    fn default() -> Self {
        JointOpts {
            granularity: 8,
            eps_ms: 0.1,
            max_microbatch: None,
        }
    }
}

/// Solve the joint batch+token problem for a pipeline of `stages` cells
/// processing `batch` sequences of `seq_len` tokens, where `model_for(b)`
/// yields the per-cell cost model at microbatch b. This is the paper's
/// two-phase reduction (per-b token DP, then one batch composition) with
/// the corrected single-counted bubble objective; [`solve_joint_exact`]
/// searches the joint space directly.
pub fn solve_joint<F, M>(
    model_for: F,
    batch: u32,
    seq_len: u32,
    stages: u32,
    opts: &JointOpts,
) -> JointScheme
where
    F: Fn(u32) -> M + Sync,
    M: CostModel,
{
    assert!(batch >= 1);
    let b_max = opts.max_microbatch.unwrap_or(batch).min(batch);

    // Token DP per candidate microbatch size — independent by
    // construction, so they fan out across threads; each densifies its
    // table once and reuses it for the whole enumeration.
    let per_b: Vec<SliceScheme> = (1..b_max + 1)
        .into_par_iter()
        .map(|b| {
            let m = model_for(b);
            let table = TableCostModel::build(&m, seq_len, opts.granularity);
            let (scheme, _) = solve_tokens_table(&table, stages, opts.eps_ms);
            scheme
        })
        .collect();

    // Composition over the batch dimension: knapsack the per-cell totals
    // and charge the (K-1)·max bubble once (the paper's T_b reduction
    // double-counts it — see knapsack.rs's regression test).
    let totals: Vec<f64> = per_b.iter().map(|s| s.total_ms).collect();
    let tmaxes: Vec<f64> = per_b.iter().map(|s| s.t_max_ms).collect();
    let (parts, _) = min_latency_composition(&totals, &tmaxes, batch, stages).expect("batch ≥ 1");

    let mut plan: Vec<(u32, SliceScheme)> = parts
        .iter()
        .map(|&b| (b, per_b[b as usize - 1].clone()))
        .collect();
    // Execute larger batch parts first (their slices dominate t_max; the
    // simulator confirms ordering is latency-neutral under Eq. 5).
    plan.sort_by(|a, b| b.0.cmp(&a.0));

    let latency = evaluate_joint_with(&|b| model_for(b), &plan, stages);
    JointScheme {
        parts: plan,
        latency_ms: latency,
    }
}

/// The per-candidate plan the joint evaluation hands the engine: the
/// knapsack's batch parts plus the per-batch-size schemes they index.
struct JointPlan {
    parts: Vec<u32>,
    schemes: Vec<Option<SliceScheme>>,
}

/// Union candidate pool over every batch size's table, sorted +
/// ε-deduplicated once.
fn joint_candidates(tables: &[TableCostModel], eps_ms: f64) -> Vec<f64> {
    let mut cands: Vec<f64> = Vec::new();
    for t in tables {
        cands.extend(t.stage_time_candidates());
    }
    engine::dedup_candidates(cands, eps_ms)
}

/// Evaluate one global t_max: Algorithm 1 per batch size (fanned across
/// threads on the parallel path — the per-b DPs are independent), then the
/// knapsack over the finite totals, then Eq. 5 with the budget tightened
/// to the achieved stage max of the chosen composition (same tightening
/// the token engine applies). `None` = no batch composition is feasible
/// under this budget. The sequential oracle runs the identical code with
/// `parallel = false`; per-b results are collected in batch-size order
/// either way, so the two paths are bit-identical.
fn eval_joint_tmax(
    tables: &[TableCostModel],
    batch: u32,
    granularity: u32,
    stages: u32,
    tmax: f64,
    parallel: bool,
) -> Option<(f64, JointPlan)> {
    let k_f = stages as f64 - 1.0;
    let sols: Vec<Option<FixedTmaxSolution>> = if parallel {
        tables
            .par_iter()
            .map(|table| solve_fixed_tmax(table, tmax))
            .collect()
    } else {
        tables
            .iter()
            .map(|table| solve_fixed_tmax(table, tmax))
            .collect()
    };
    let b_max = tables.len();
    let mut usable = vec![1e30f64; b_max];
    let mut achieved_b = vec![f64::NEG_INFINITY; b_max];
    let mut schemes: Vec<Option<SliceScheme>> = vec![None; b_max];
    let mut any = false;
    for (bi, sol) in sols.into_iter().enumerate() {
        if let Some(sol) = sol {
            any = true;
            usable[bi] = sol.total_ms;
            achieved_b[bi] = engine::achieved_tmax(&tables[bi], &sol.lens_units);
            schemes[bi] = Some(SliceScheme {
                lens: sol
                    .lens_units
                    .iter()
                    .map(|&u| u as u32 * granularity)
                    .collect(),
                total_ms: sol.total_ms,
                t_max_ms: achieved_b[bi],
                latency_ms: 0.0,
            });
        }
    }
    if !any {
        return None;
    }
    let (parts, cost) = min_cost_composition(&usable, batch)?;
    if cost >= 1e29 {
        return None; // forced to use an infeasible b
    }
    let achieved = parts
        .iter()
        .map(|&b| achieved_b[b as usize - 1])
        .fold(f64::NEG_INFINITY, f64::max);
    Some((cost + k_f * achieved, JointPlan { parts, schemes }))
}

/// Feasibility-only probe for the engine's binary search: same per-b DPs
/// and knapsack check as [`eval_joint_tmax`], but skips building the token
/// schemes the probe would throw away.
fn joint_feasible(tables: &[TableCostModel], batch: u32, tmax: f64) -> bool {
    let totals: Vec<f64> = tables
        .par_iter()
        .map(|table| solve_fixed_tmax(table, tmax).map_or(1e30, |sol| sol.total_ms))
        .collect();
    if totals.iter().all(|&t| t >= 1e29) {
        return false;
    }
    matches!(min_cost_composition(&totals, batch), Some((_, cost)) if cost < 1e29)
}

/// Assemble the winning plan (larger batch parts first, as in
/// [`solve_joint`]) — shared by the exact solver and the oracle so the
/// equivalence suite compares like for like.
fn finish_joint(r: engine::EnumResult<JointPlan>) -> JointScheme {
    let (latency, plan) = r.best.expect("tmax = t(L,0) at b=1 is always feasible");
    let mut parts: Vec<(u32, SliceScheme)> = plan
        .parts
        .iter()
        .map(|&b| (b, plan.schemes[b as usize - 1].clone().unwrap()))
        .collect();
    parts.sort_by(|a, b| b.0.cmp(&a.0));
    JointScheme {
        parts,
        latency_ms: latency,
    }
}

/// Exact joint solver: enumerate a *global* `t_max` over the union of all
/// per-microbatch-size slice-time candidates; for each, Algorithm 1 gives
/// the minimal per-cell total `S*_b(t_max)` for every batch size `b`, a
/// knapsack composes the batch dimension over those totals, and the plan
/// latency is `Σ S* + (K-1)·t_max` (budget tightened to the achieved
/// stage max) — the direct generalization of Eq. 5 to the joint space,
/// with the bubble term counted once so the objective matches the
/// simulator.
///
/// Runs on the shared enumeration engine: joint feasibility is monotone in
/// `t_max` (every per-b DP is, and a composition feasible at `t` stays
/// feasible at `t' > t`), so the engine's binary search skips the
/// infeasible prefix and its blocked scan fans candidate evaluations
/// across threads under the shared `(K-1)·t_max` pruning bound.
/// Bit-identical to [`solve_joint_seq`].
pub fn solve_joint_exact<F, M>(
    model_for: F,
    batch: u32,
    seq_len: u32,
    stages: u32,
    opts: &JointOpts,
) -> JointScheme
where
    F: Fn(u32) -> M + Sync,
    M: CostModel + Sync,
{
    assert!(batch >= 1);
    let b_max = opts.max_microbatch.unwrap_or(batch).min(batch);

    // One densified table per batch size — the per-b builds fan out across
    // threads, and each build fans its anti-diagonals out too (build_par);
    // rayon's work-stealing nests the two levels. The tables are shared by
    // every candidate evaluation below.
    let tables: Vec<TableCostModel> = (1..b_max + 1)
        .into_par_iter()
        .map(|b| TableCostModel::build_par(&model_for(b), seq_len, opts.granularity))
        .collect();

    let filtered = joint_candidates(&tables, opts.eps_ms);
    let r = engine::enumerate_par(
        stages,
        &filtered,
        |tmax| joint_feasible(&tables, batch, tmax),
        |tmax| eval_joint_tmax(&tables, batch, opts.granularity, stages, tmax, true),
    );
    finish_joint(r)
}

/// The retained sequential oracle for [`solve_joint_exact`]: serial table
/// builds, serial per-b DPs, and the engine's plain ascending reference
/// scan ([`engine::enumerate_seq`]) — no rayon anywhere on the solve path.
/// The equivalence property suite asserts the two are bit-identical
/// (plans, per-part `t_max_ms`/`total_ms`, and total latency).
pub fn solve_joint_seq<F, M>(
    model_for: F,
    batch: u32,
    seq_len: u32,
    stages: u32,
    opts: &JointOpts,
) -> JointScheme
where
    F: Fn(u32) -> M,
    M: CostModel,
{
    assert!(batch >= 1);
    let b_max = opts.max_microbatch.unwrap_or(batch).min(batch);
    let tables: Vec<TableCostModel> = (1..b_max + 1)
        .map(|b| TableCostModel::build(&model_for(b), seq_len, opts.granularity))
        .collect();
    let filtered = joint_candidates(&tables, opts.eps_ms);
    let r = engine::enumerate_seq(stages, &filtered, |tmax| {
        eval_joint_tmax(&tables, batch, opts.granularity, stages, tmax, false)
    });
    finish_joint(r)
}

/// Convenience: exact joint solve for an [`AnalyticModel`] derived from a
/// setting (`base` must be the microbatch=1 model).
pub fn solve_joint_analytic(
    base: &AnalyticModel,
    batch: u32,
    seq_len: u32,
    stages: u32,
    opts: &JointOpts,
) -> JointScheme {
    solve_joint_exact(|b| base.with_microbatch(b), batch, seq_len, stages, opts)
}

/// Exact Eq. 5 objective over the concatenated slice stream of a joint
/// plan: Σ all slice times + (K-1)·max slice time.
pub fn evaluate_joint_with<M: CostModel>(
    model_for: &dyn Fn(u32) -> M,
    parts: &[(u32, SliceScheme)],
    stages: u32,
) -> f64 {
    let mut total = 0.0;
    let mut tmax = f64::NEG_INFINITY;
    for (b, scheme) in parts {
        let m = model_for(*b);
        let mut ctx = 0u32;
        for &l in &scheme.lens {
            let t = m.t(l, ctx) + m.t_comm(l);
            total += t;
            tmax = tmax.max(t);
            ctx += l;
        }
    }
    total + (stages as f64 - 1.0) * tmax
}

/// The w/o-TeraPipe baseline plan: GPipe microbatches of one full-length
/// sequence each — the `[(1, [2048])] * B` rows of Table 2.
pub fn gpipe_plan<M: CostModel>(
    model_for: &dyn Fn(u32) -> M,
    batch: u32,
    seq_len: u32,
    stages: u32,
) -> JointScheme {
    let m = model_for(1);
    let t = m.t(seq_len, 0) + m.t_comm(seq_len);
    let scheme = SliceScheme {
        lens: vec![seq_len],
        total_ms: t,
        t_max_ms: t,
        latency_ms: t * (1.0 + (stages as f64 - 1.0)),
    };
    let parts: Vec<(u32, SliceScheme)> = (0..batch).map(|_| (1, scheme.clone())).collect();
    let latency = evaluate_joint_with(model_for, &parts, stages);
    JointScheme {
        parts,
        latency_ms: latency,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::presets;
    use crate::perfmodel::analytic::AnalyticModel;

    fn model(setting_id: u32) -> AnalyticModel {
        AnalyticModel::from_setting(&presets::setting(setting_id), 1)
    }

    #[test]
    fn joint_covers_batch() {
        let m = model(5);
        let opts = JointOpts { granularity: 64, ..Default::default() };
        let j = solve_joint_analytic(&m, 4, 2048, 40, &opts);
        assert_eq!(j.batch(), 4);
        for (_, s) in &j.parts {
            assert_eq!(s.seq_len(), 2048);
        }
    }

    #[test]
    fn joint_beats_gpipe_on_small_batch_deep_pipeline() {
        // Setting 8-like regime (B=8, K=48): token slicing is the paper's
        // headline win.
        let m = model(8);
        let opts = JointOpts { granularity: 64, ..Default::default() };
        let j = solve_joint_analytic(&m, 8, 2048, 48, &opts);
        let g = gpipe_plan(&|b| m.with_microbatch(b), 8, 2048, 48);
        assert!(
            j.latency_ms < 0.7 * g.latency_ms,
            "terapipe {} vs gpipe {}",
            j.latency_ms,
            g.latency_ms
        );
    }

    #[test]
    fn large_batch_shallow_pipeline_declines_token_slicing() {
        // Settings (2)/(3) regime: batch alone saturates the pipeline and
        // the DP keeps whole sequences — paper Fig. 5 "no speedup" rows.
        let m = model(3);
        let opts = JointOpts { granularity: 64, ..Default::default() };
        let j = solve_joint_analytic(&m, 72, 2048, 24, &opts);
        let whole_seq_parts = j
            .parts
            .iter()
            .filter(|(_, s)| s.num_slices() == 1)
            .count();
        assert!(
            whole_seq_parts >= j.parts.len() / 2,
            "expected mostly unsliced parts, got {}",
            j.notation()
        );
    }

    #[test]
    fn reduction_reported_latency_is_the_exact_eq5_evaluation() {
        // solve_joint's latency_ms must be the re-evaluated Eq. 5 value of
        // its own plan (single-counted bubble), not the knapsack's
        // composition objective.
        let m = model(5);
        let opts = JointOpts { granularity: 128, ..Default::default() };
        let j = solve_joint(|b| m.with_microbatch(b), 6, 2048, 40, &opts);
        let eval = evaluate_joint_with(&|b| m.with_microbatch(b), &j.parts, 40);
        assert!((j.latency_ms - eval).abs() < 1e-9, "{} vs {eval}", j.latency_ms);
    }

    #[test]
    fn exact_solver_never_loses_to_the_reduction() {
        // The global-t_max search explores a superset of the reduction's
        // plans (every per-b scheme is discoverable at its own achieved
        // budget when ε = 0), so its Eq. 5 latency is ≤ the reduction's.
        let m = model(8);
        let opts = JointOpts {
            granularity: 128,
            eps_ms: 0.0,
            max_microbatch: Some(4),
        };
        let exact = solve_joint_exact(|b| m.with_microbatch(b), 8, 2048, 48, &opts);
        let reduction = solve_joint(|b| m.with_microbatch(b), 8, 2048, 48, &opts);
        assert!(
            exact.latency_ms <= reduction.latency_ms + 1e-6,
            "exact {} vs reduction {}",
            exact.latency_ms,
            reduction.latency_ms
        );
    }

    #[test]
    fn evaluate_joint_matches_manual_sum() {
        let m = model(5);
        let scheme = SliceScheme {
            lens: vec![1024, 1024],
            total_ms: 0.0,
            t_max_ms: 0.0,
            latency_ms: 0.0,
        };
        let parts = vec![(1u32, scheme)];
        let got = evaluate_joint_with(&|b| m.with_microbatch(b), &parts, 40);
        let t1 = m.t(1024, 0) + m.t_comm(1024);
        let t2 = m.t(1024, 1024) + m.t_comm(1024);
        let want = t1 + t2 + 39.0 * t2.max(t1);
        assert!((got - want).abs() < 1e-9);
    }

    #[test]
    fn gpipe_plan_is_all_unsliced_singletons() {
        let m = model(5);
        let g = gpipe_plan(&|b| m.with_microbatch(b), 32, 2048, 40);
        assert_eq!(g.parts.len(), 32);
        assert!(g.parts.iter().all(|(b, s)| *b == 1 && s.lens == vec![2048]));
        assert_eq!(g.notation(), "[(1, [2048])] * 32");
    }
}
