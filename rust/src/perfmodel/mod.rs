//! Performance models: the paper's `t_fwd(i, j)` (Eq. 4/9).
//!
//! `i` is the slice length in tokens, `j` the total length of all previous
//! sub-sequences (the attention context). Every latency is **ms** and — as
//! §3.3 prescribes for optimizing training time — already includes the
//! backward pass (`t_fwd + t_bwd`) unless a model says otherwise.
//!
//! Three instantiations:
//! * [`analytic::AnalyticModel`] — FLOPs/bandwidth/launch-overhead model of
//!   a V100 pipeline cell, calibrated against the paper's published
//!   latencies (DESIGN.md §6). Drives the paper-scale simulations.
//! * [`linear::LinearCtxModel`] — the paper's measured form: tabulated
//!   `t(i, 0)` plus the fitted `t_ctx(i,j) = a0 + a1·i + a2·j + a3·ij`.
//! * [`TableCostModel`] — any model densified onto a granularity grid for
//!   O(1) lookups inside the DP inner loop.

pub mod analytic;
pub mod linear;
pub mod measure;

/// A per-cell slice-latency model: time (ms) to push a slice of `i` tokens
/// with `j` tokens of context through one pipeline cell.
pub trait CostModel {
    /// Latency (ms) for slice length `i` ≥ 1 with context `j` ≥ 0.
    fn t(&self, i: u32, j: u32) -> f64;

    /// Per-hop activation transfer latency (ms) for an `i`-token slice;
    /// included so Eq. 4's "computation + data transmission" holds. Models
    /// may fold this into `t` and return 0 here.
    fn t_comm(&self, _i: u32) -> f64 {
        0.0
    }
}

impl<M: CostModel + ?Sized> CostModel for &M {
    fn t(&self, i: u32, j: u32) -> f64 {
        (**self).t(i, j)
    }
    fn t_comm(&self, i: u32) -> f64 {
        (**self).t_comm(i)
    }
}

/// Dense `t(i, j)` table on a `granularity`-token grid, for the DP hot loop.
///
/// Entry `(a, b)` holds `t(a·g, b·g)` for `a ∈ 1..=n`, `b ∈ 0..=n-a` where
/// `n = L / g`. Infeasible combinations (`a + b > n`) hold +∞.
pub struct TableCostModel {
    n: usize,
    granularity: u32,
    /// Row-major `[a-1][b]`, `n × n` (+∞ where a + b > n).
    table: Vec<f64>,
    comm: Vec<f64>,
}

impl TableCostModel {
    /// Densify `model` over sequence length `seq_len` at `granularity`
    /// tokens per grid unit. `seq_len` must be divisible by `granularity`.
    pub fn build<M: CostModel>(model: &M, seq_len: u32, granularity: u32) -> Self {
        assert!(granularity >= 1 && seq_len % granularity == 0);
        let n = (seq_len / granularity) as usize;
        let mut table = vec![f64::INFINITY; n * n];
        for a in 1..=n {
            for b in 0..=(n - a) {
                table[(a - 1) * n + b] = model.t(a as u32 * granularity, b as u32 * granularity);
            }
        }
        let comm = (0..=n)
            .map(|a| model.t_comm(a as u32 * granularity))
            .collect();
        TableCostModel {
            n,
            granularity,
            table,
            comm,
        }
    }

    pub fn units(&self) -> usize {
        self.n
    }

    pub fn granularity(&self) -> u32 {
        self.granularity
    }

    /// `t` in grid units: slice of `a` units with `b` units of context.
    #[inline]
    pub fn at(&self, a: usize, b: usize) -> f64 {
        debug_assert!(a >= 1 && a <= self.n && b < self.n);
        self.table[(a - 1) * self.n + b]
    }

    #[inline]
    pub fn comm_at(&self, a: usize) -> f64 {
        self.comm[a]
    }

    /// All finite `t` values (candidate `t_max` pool for the enumeration).
    pub fn finite_values(&self) -> Vec<f64> {
        self.table.iter().copied().filter(|v| v.is_finite()).collect()
    }
}

impl CostModel for TableCostModel {
    fn t(&self, i: u32, j: u32) -> f64 {
        assert!(i % self.granularity == 0 && j % self.granularity == 0);
        self.at((i / self.granularity) as usize, (j / self.granularity) as usize)
    }
    fn t_comm(&self, i: u32) -> f64 {
        self.comm_at((i / self.granularity) as usize)
    }
}

/// Evaluate the paper's pipeline-latency objective (Eq. 5) for a given
/// slicing: `T = Σᵢ tᵢ + (K-1)·maxⱼ tⱼ`, with `tᵢ = t(lᵢ, Σ_{<i} lⱼ)`.
pub fn pipeline_latency<M: CostModel>(model: &M, lens: &[u32], stages: u32) -> f64 {
    assert!(stages >= 1 && !lens.is_empty());
    let mut ctx = 0u32;
    let mut total = 0.0;
    let mut tmax = f64::NEG_INFINITY;
    for &l in lens {
        let t = model.t(l, ctx) + model.t_comm(l);
        total += t;
        tmax = tmax.max(t);
        ctx += l;
    }
    total + (stages as f64 - 1.0) * tmax
}

#[cfg(test)]
mod tests {
    use super::*;

    /// t = i + 0.01·i·j — trivially checkable.
    pub struct Toy;
    impl CostModel for Toy {
        fn t(&self, i: u32, j: u32) -> f64 {
            i as f64 + 0.01 * i as f64 * j as f64
        }
    }

    #[test]
    fn table_matches_model_on_grid() {
        let t = TableCostModel::build(&Toy, 64, 8);
        assert_eq!(t.units(), 8);
        for a in 1..=8usize {
            for b in 0..=(8 - a) {
                let want = Toy.t(a as u32 * 8, b as u32 * 8);
                assert_eq!(t.at(a, b), want);
                assert_eq!(t.t(a as u32 * 8, b as u32 * 8), want);
            }
        }
    }

    #[test]
    fn table_marks_infeasible_as_infinite() {
        let t = TableCostModel::build(&Toy, 32, 8);
        assert!(t.at(4, 1).is_infinite()); // 4 + 1 > 4 units
        assert!(t.at(4, 0).is_finite());
    }

    #[test]
    fn pipeline_latency_matches_hand_computation() {
        // lens [2, 2] over L=4, K=3 with Toy: t1 = 2, t2 = 2 + 0.01·2·2 = 2.04
        let lat = pipeline_latency(&Toy, &[2, 2], 3);
        let want = (2.0 + 2.04) + 2.0 * 2.04;
        assert!((lat - want).abs() < 1e-12, "{lat} vs {want}");
    }

    #[test]
    fn single_slice_single_stage_is_plain_cost() {
        let lat = pipeline_latency(&Toy, &[16], 1);
        assert_eq!(lat, 16.0);
    }

    #[test]
    fn finite_values_counts_feasible_pairs() {
        let t = TableCostModel::build(&Toy, 32, 8);
        // feasible (a,b): a=1..4, b=0..4-a → 4+3+2+1 = 10
        assert_eq!(t.finite_values().len(), 10);
    }
}
