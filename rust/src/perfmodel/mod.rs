//! Performance models: the paper's `t_fwd(i, j)` (Eq. 4/9).
//!
//! `i` is the slice length in tokens, `j` the total length of all previous
//! sub-sequences (the attention context). Every latency is **ms** and — as
//! §3.3 prescribes for optimizing training time — already includes the
//! backward pass (`t_fwd + t_bwd`) unless a model says otherwise.
//!
//! Three instantiations:
//! * [`analytic::AnalyticModel`] — FLOPs/bandwidth/launch-overhead model of
//!   a V100 pipeline cell, calibrated against the paper's published
//!   latencies (DESIGN.md §6). Drives the paper-scale simulations.
//! * [`linear::LinearCtxModel`] — the paper's measured form: tabulated
//!   `t(i, 0)` plus the fitted `t_ctx(i,j) = a0 + a1·i + a2·j + a3·ij`.
//! * [`TableCostModel`] — any model densified onto a granularity grid for
//!   O(1) lookups inside the DP inner loop.

pub mod analytic;
pub mod linear;
pub mod measure;

/// A per-cell slice-latency model: time (ms) to push a slice of `i` tokens
/// with `j` tokens of context through one pipeline cell.
pub trait CostModel {
    /// Latency (ms) for slice length `i` ≥ 1 with context `j` ≥ 0.
    fn t(&self, i: u32, j: u32) -> f64;

    /// Per-hop activation transfer latency (ms) for an `i`-token slice;
    /// included so Eq. 4's "computation + data transmission" holds. Models
    /// may fold this into `t` and return 0 here.
    fn t_comm(&self, _i: u32) -> f64 {
        0.0
    }
}

impl<M: CostModel + ?Sized> CostModel for &M {
    fn t(&self, i: u32, j: u32) -> f64 {
        (**self).t(i, j)
    }
    fn t_comm(&self, i: u32) -> f64 {
        (**self).t_comm(i)
    }
}

/// Multiplicative rescale of a cost model: compute times scaled by
/// `compute` (a per-stage slowdown), comm times by `comm` (an inverse
/// bandwidth factor). This is how the planner represents cluster drift —
/// a degraded node or a bandwidth change moves every `t(i, j)` by one
/// factor, so the fitted model stays the base model plus two scalars.
///
/// [`TableCostModel::rescaled`] produces the same table *bit-identically*
/// without re-querying the base model (one multiply per stored entry, in
/// the same `factor * t` order — pinned by a unit test), which is what
/// makes the planner's cache able to reuse densified diagonals across
/// scale-only cluster deltas.
#[derive(Debug, Clone)]
pub struct ScaledModel<M> {
    pub inner: M,
    /// Factor on `t(i, j)` (1.0 = unchanged, >1 = slower compute).
    pub compute: f64,
    /// Factor on `t_comm(i)` (1.0 = unchanged, >1 = slower network).
    pub comm: f64,
}

impl<M: CostModel> CostModel for ScaledModel<M> {
    fn t(&self, i: u32, j: u32) -> f64 {
        self.compute * self.inner.t(i, j)
    }
    fn t_comm(&self, i: u32) -> f64 {
        self.comm * self.inner.t_comm(i)
    }
}

/// Dense `t(i, j)` table on a `granularity`-token grid, for the DP hot loop.
///
/// Entry `(a, b)` holds `t(a·g, b·g)` for `a ∈ 1..=n`, `b ∈ 0..=n-a` where
/// `n = L / g`. Infeasible combinations (`a + b > n`) read as +∞.
///
/// Storage is **anti-diagonal-major**: all entries with `a + b = d` are
/// contiguous, ordered by `a`. Algorithm 1's inner loop at position `i`
/// reads exactly `t(k, i-k)` for `k = 1..=i` — the anti-diagonal `d = i` —
/// so the layout turns the old stride-`n` walk (one cache miss per
/// candidate `k`) into a single sequential run ([`Self::diag`]). Only the
/// n(n+1)/2 feasible pairs are stored.
pub struct TableCostModel {
    n: usize,
    granularity: u32,
    /// Anti-diagonal-major: diagonal `d = a + b` starts at `d(d-1)/2`;
    /// entry `a - 1` within it holds `t(a, d - a)`.
    table: Vec<f64>,
    comm: Vec<f64>,
}

impl TableCostModel {
    /// Densify `model` over sequence length `seq_len` at `granularity`
    /// tokens per grid unit. `seq_len` must be divisible by `granularity`.
    pub fn build<M: CostModel>(model: &M, seq_len: u32, granularity: u32) -> Self {
        assert!(granularity >= 1 && seq_len % granularity == 0);
        let n = (seq_len / granularity) as usize;
        let mut table = Vec::with_capacity(n * (n + 1) / 2);
        for d in 1..=n {
            for a in 1..=d {
                table.push(model.t(a as u32 * granularity, (d - a) as u32 * granularity));
            }
        }
        let comm = (0..=n)
            .map(|a| model.t_comm(a as u32 * granularity))
            .collect();
        TableCostModel {
            n,
            granularity,
            table,
            comm,
        }
    }

    /// Parallel twin of [`Self::build`] (ROADMAP: "parallel table
    /// densification"): the anti-diagonals are independent contiguous
    /// runs, so they fan out across threads — worth it for expensive cost
    /// models (measured/fitted) or fine grids. Requires `M: Sync` (the
    /// model is shared read-only across workers) and produces a
    /// **bit-identical** table: the same `model.t` calls land at the same
    /// offsets, each diagonal filled left-to-right exactly as in the
    /// serial build (equality is pinned by a unit test).
    pub fn build_par<M: CostModel + Sync>(model: &M, seq_len: u32, granularity: u32) -> Self {
        use rayon::prelude::*;
        assert!(granularity >= 1 && seq_len % granularity == 0);
        let n = (seq_len / granularity) as usize;
        let diags: Vec<Vec<f64>> = (1..n + 1)
            .into_par_iter()
            .map(|d| {
                (1..d + 1)
                    .map(|a| model.t(a as u32 * granularity, (d - a) as u32 * granularity))
                    .collect()
            })
            .collect();
        let mut table = Vec::with_capacity(n * (n + 1) / 2);
        for row in &diags {
            table.extend_from_slice(row);
        }
        let comm = (0..=n)
            .map(|a| model.t_comm(a as u32 * granularity))
            .collect();
        TableCostModel {
            n,
            granularity,
            table,
            comm,
        }
    }

    pub fn units(&self) -> usize {
        self.n
    }

    pub fn granularity(&self) -> u32 {
        self.granularity
    }

    #[inline]
    fn diag_off(d: usize) -> usize {
        d * (d - 1) / 2
    }

    /// `t` in grid units: slice of `a` units with `b` units of context.
    #[inline]
    pub fn at(&self, a: usize, b: usize) -> f64 {
        debug_assert!(a >= 1);
        let d = a + b;
        if d > self.n {
            return f64::INFINITY;
        }
        self.table[Self::diag_off(d) + (a - 1)]
    }

    /// Anti-diagonal `i` (`1 ≤ i ≤ n`): `diag(i)[k - 1] = t(k, i - k)` for
    /// `k ∈ 1..=i` — exactly the reads of Algorithm 1's inner loop at
    /// position `i`, contiguous in memory.
    #[inline]
    pub fn diag(&self, i: usize) -> &[f64] {
        debug_assert!(i >= 1 && i <= self.n);
        let off = Self::diag_off(i);
        &self.table[off..off + i]
    }

    /// Per-hop comm latencies indexed by slice length in units (`0..=n`),
    /// exposed as a slice so the DP avoids a bounds check per candidate.
    #[inline]
    pub fn comms(&self) -> &[f64] {
        &self.comm
    }

    #[inline]
    pub fn comm_at(&self, a: usize) -> f64 {
        self.comm[a]
    }

    /// Rescale every stored entry by `compute` and every comm value by
    /// `comm` **without touching the underlying model** — the densified
    /// anti-diagonals are reused as-is, so a scale-only cluster delta
    /// (per-stage slowdown, bandwidth change) costs one multiply pass
    /// instead of `n(n+1)/2` model evaluations.
    ///
    /// Bit-identical to `TableCostModel::build(&ScaledModel { inner,
    /// compute, comm }, ..)` over the same base: both compute the same
    /// `factor * t` f64 product per entry (pinned by a unit test), which
    /// is what lets the planner's warm path stay exactly equivalent to a
    /// cold solve over a freshly densified scaled model.
    pub fn rescaled(&self, compute: f64, comm: f64) -> Self {
        TableCostModel {
            n: self.n,
            granularity: self.granularity,
            table: self.table.iter().map(|&t| compute * t).collect(),
            comm: self.comm.iter().map(|&c| comm * c).collect(),
        }
    }

    /// The §3.3 candidate `t_max` pool: the per-slice *stage* time
    /// `t(a, b) + t_comm(a)` (Eq. 4's computation + transmission) for every
    /// feasible `(a, b)`, built in one pass over the dense storage. Callers
    /// sort/ε-dedup it once — this replaces the seed's double enumeration
    /// (a comm-less `finite_values` pass plus a second comm loop) in the
    /// solver.
    pub fn stage_time_candidates(&self) -> Vec<f64> {
        let mut out = Vec::with_capacity(self.table.len());
        for d in 1..=self.n {
            let diag = self.diag(d);
            for (idx, &t) in diag.iter().enumerate() {
                out.push(t + self.comm[idx + 1]);
            }
        }
        out
    }
}

impl CostModel for TableCostModel {
    fn t(&self, i: u32, j: u32) -> f64 {
        assert!(i % self.granularity == 0 && j % self.granularity == 0);
        self.at((i / self.granularity) as usize, (j / self.granularity) as usize)
    }
    fn t_comm(&self, i: u32) -> f64 {
        self.comm_at((i / self.granularity) as usize)
    }
}

/// Evaluate the paper's pipeline-latency objective (Eq. 5) for a given
/// slicing: `T = Σᵢ tᵢ + (K-1)·maxⱼ tⱼ`, with `tᵢ = t(lᵢ, Σ_{<i} lⱼ)`.
pub fn pipeline_latency<M: CostModel>(model: &M, lens: &[u32], stages: u32) -> f64 {
    assert!(stages >= 1 && !lens.is_empty());
    let mut ctx = 0u32;
    let mut total = 0.0;
    let mut tmax = f64::NEG_INFINITY;
    for &l in lens {
        let t = model.t(l, ctx) + model.t_comm(l);
        total += t;
        tmax = tmax.max(t);
        ctx += l;
    }
    total + (stages as f64 - 1.0) * tmax
}

#[cfg(test)]
mod tests {
    use super::*;

    /// t = i + 0.01·i·j — trivially checkable.
    pub struct Toy;
    impl CostModel for Toy {
        fn t(&self, i: u32, j: u32) -> f64 {
            i as f64 + 0.01 * i as f64 * j as f64
        }
    }

    #[test]
    fn table_matches_model_on_grid() {
        let t = TableCostModel::build(&Toy, 64, 8);
        assert_eq!(t.units(), 8);
        for a in 1..=8usize {
            for b in 0..=(8 - a) {
                let want = Toy.t(a as u32 * 8, b as u32 * 8);
                assert_eq!(t.at(a, b), want);
                assert_eq!(t.t(a as u32 * 8, b as u32 * 8), want);
            }
        }
    }

    #[test]
    fn table_marks_infeasible_as_infinite() {
        let t = TableCostModel::build(&Toy, 32, 8);
        assert!(t.at(4, 1).is_infinite()); // 4 + 1 > 4 units
        assert!(t.at(4, 0).is_finite());
    }

    #[test]
    fn build_par_is_bit_identical_to_build() {
        struct WithComm;
        impl CostModel for WithComm {
            fn t(&self, i: u32, j: u32) -> f64 {
                0.3 + 0.07 * i as f64 + 2.5e-4 * i as f64 * j as f64
            }
            fn t_comm(&self, i: u32) -> f64 {
                0.05 * i as f64
            }
        }
        for (l, g) in [(8u32, 8u32), (64, 8), (96, 16), (512, 8)] {
            let a = TableCostModel::build(&WithComm, l, g);
            let b = TableCostModel::build_par(&WithComm, l, g);
            assert_eq!(a.n, b.n);
            assert_eq!(a.granularity, b.granularity);
            // exact f64 equality, storage order included
            assert_eq!(a.table, b.table, "L={l} g={g}");
            assert_eq!(a.comm, b.comm, "L={l} g={g}");
        }
    }

    #[test]
    fn pipeline_latency_matches_hand_computation() {
        // lens [2, 2] over L=4, K=3 with Toy: t1 = 2, t2 = 2 + 0.01·2·2 = 2.04
        let lat = pipeline_latency(&Toy, &[2, 2], 3);
        let want = (2.0 + 2.04) + 2.0 * 2.04;
        assert!((lat - want).abs() < 1e-12, "{lat} vs {want}");
    }

    #[test]
    fn single_slice_single_stage_is_plain_cost() {
        let lat = pipeline_latency(&Toy, &[16], 1);
        assert_eq!(lat, 16.0);
    }

    #[test]
    fn candidate_pool_counts_feasible_pairs() {
        let t = TableCostModel::build(&Toy, 32, 8);
        // feasible (a,b): a=1..4, b=0..4-a → 4+3+2+1 = 10
        assert_eq!(t.stage_time_candidates().len(), 10);
    }

    #[test]
    fn diag_matches_at_lookups() {
        let t = TableCostModel::build(&Toy, 64, 8);
        for i in 1..=t.units() {
            let d = t.diag(i);
            assert_eq!(d.len(), i);
            for k in 1..=i {
                assert_eq!(d[k - 1], t.at(k, i - k), "diag({i})[{}]", k - 1);
            }
        }
    }

    #[test]
    fn rescaled_table_bit_identical_to_build_from_scaled_model() {
        struct WithComm;
        impl CostModel for WithComm {
            fn t(&self, i: u32, j: u32) -> f64 {
                0.3 + 0.07 * i as f64 + 2.5e-4 * i as f64 * j as f64
            }
            fn t_comm(&self, i: u32) -> f64 {
                0.05 * i as f64
            }
        }
        for (compute, comm) in [(1.0f64, 1.0f64), (1.37, 0.5), (0.81, 2.25)] {
            let base = TableCostModel::build(&WithComm, 128, 8);
            let rescaled = base.rescaled(compute, comm);
            let built = TableCostModel::build(
                &ScaledModel { inner: WithComm, compute, comm },
                128,
                8,
            );
            // exact f64 equality, storage order included
            assert_eq!(rescaled.table, built.table, "compute={compute} comm={comm}");
            assert_eq!(rescaled.comm, built.comm, "compute={compute} comm={comm}");
            assert_eq!(rescaled.n, built.n);
            assert_eq!(rescaled.granularity, built.granularity);
        }
    }

    #[test]
    fn scaled_model_scales_both_terms() {
        struct WithComm;
        impl CostModel for WithComm {
            fn t(&self, _i: u32, _j: u32) -> f64 {
                2.0
            }
            fn t_comm(&self, _i: u32) -> f64 {
                0.5
            }
        }
        let s = ScaledModel { inner: WithComm, compute: 3.0, comm: 2.0 };
        assert_eq!(s.t(8, 0), 6.0);
        assert_eq!(s.t_comm(8), 1.0);
    }

    #[test]
    fn stage_time_candidates_cover_all_feasible_pairs_with_comm() {
        struct WithComm;
        impl CostModel for WithComm {
            fn t(&self, i: u32, j: u32) -> f64 {
                i as f64 + 0.01 * i as f64 * j as f64
            }
            fn t_comm(&self, i: u32) -> f64 {
                0.125 * i as f64
            }
        }
        let t = TableCostModel::build(&WithComm, 32, 8);
        let mut want = Vec::new();
        for a in 1..=4usize {
            for b in 0..=(4 - a) {
                want.push(t.at(a, b) + t.comm_at(a));
            }
        }
        let mut got = t.stage_time_candidates();
        want.sort_by(|x, y| x.partial_cmp(y).unwrap());
        got.sort_by(|x, y| x.partial_cmp(y).unwrap());
        assert_eq!(got, want);
    }
}
