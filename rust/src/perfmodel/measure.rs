//! Measurement harness — the paper's "Estimating t_fwd" procedure run
//! against *real* executables instead of a model.
//!
//! Given any timeable slice runner (in production, a
//! [`crate::runtime::StageExecutor`] bucket; in tests, a closure), this
//! measures `t(i, 0)` for every bucketed slice length, samples `t(i, j)`
//! on a subset grid, and fits the Eq. 9 linear context model — exactly the
//! small-number-of-simple-workloads calibration the paper describes.

use super::linear::{CtxCoeffs, LinearCtxModel};
use super::CostModel;

/// What a pipeline stage actually computes per slice — the first stage
/// adds the embedding, the last adds the LM head, so their latency laws
/// differ from a middle cell's and deserve separate fits.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum StageRole {
    /// Stage 0 of a ≥2-stage pipeline: embed + transformer layers.
    First,
    /// Interior stage: transformer layers only.
    Middle,
    /// Last stage: transformer layers + head loss/VJP. A single-stage
    /// pipeline maps here (it carries the head; the embed rides along).
    Last,
}

impl StageRole {
    pub fn of(stage: usize, num_stages: usize) -> StageRole {
        assert!(stage < num_stages);
        if stage == 0 && num_stages > 1 {
            StageRole::First
        } else if stage == num_stages - 1 {
            StageRole::Last
        } else {
            StageRole::Middle
        }
    }
}

/// One Eq. 9 fit per stage role — the per-stage cost tables the planner
/// and the exec↔sim differential consume instead of a single
/// representative-cell model.
#[derive(Debug, Clone)]
pub struct StageModels {
    pub first: LinearCtxModel,
    pub middle: LinearCtxModel,
    pub last: LinearCtxModel,
}

impl StageModels {
    pub fn for_stage(&self, stage: usize, num_stages: usize) -> &LinearCtxModel {
        match StageRole::of(stage, num_stages) {
            StageRole::First => &self.first,
            StageRole::Middle => &self.middle,
            StageRole::Last => &self.last,
        }
    }

    /// The planner-facing [`CostModel`] over these fits: Alg. 1 plans one
    /// slicing that *every* stage executes, and Eq. 5's latency is driven
    /// by the slowest stage, so the DP's `t(i, j)` is the per-point
    /// **bottleneck** across the roles a `num_stages`-stage pipeline
    /// actually contains.
    pub fn planning_model(&self, num_stages: usize) -> BottleneckStageModel {
        BottleneckStageModel::new(self.clone(), num_stages)
    }
}

/// Per-(i, j) max over the stage roles present in a K-stage pipeline —
/// what the slicing DP consumes instead of one averaged model. Role
/// presence follows [`StageRole::of`]: K=1 has only a `Last` stage (it
/// carries the head), K=2 has `First`+`Last`, K≥3 adds `Middle`.
#[derive(Debug, Clone)]
pub struct BottleneckStageModel {
    models: StageModels,
    num_stages: usize,
}

impl BottleneckStageModel {
    pub fn new(models: StageModels, num_stages: usize) -> BottleneckStageModel {
        assert!(num_stages >= 1);
        BottleneckStageModel { models, num_stages }
    }

    pub fn models(&self) -> &StageModels {
        &self.models
    }

    pub fn num_stages(&self) -> usize {
        self.num_stages
    }

    fn present(&self) -> impl Iterator<Item = &LinearCtxModel> {
        let k = self.num_stages;
        [
            (k > 1).then_some(&self.models.first),
            (k > 2).then_some(&self.models.middle),
            Some(&self.models.last),
        ]
        .into_iter()
        .flatten()
    }
}

impl CostModel for BottleneckStageModel {
    fn t(&self, i: u32, j: u32) -> f64 {
        self.present().map(|m| m.t(i, j)).fold(f64::NEG_INFINITY, f64::max)
    }

    fn t_comm(&self, i: u32) -> f64 {
        self.present().map(|m| m.t_comm(i)).fold(f64::NEG_INFINITY, f64::max)
    }
}

/// Anything whose slice latency can be measured: returns wall-clock ms for
/// one (slice_len, ctx_len) execution.
pub trait SliceTimer {
    fn time_slice(&mut self, slice_len: u32, ctx_len: u32) -> f64;
    /// Slice lengths this timer supports (the AOT bucket set).
    fn buckets(&self) -> Vec<u32>;
}

impl<F: FnMut(u32, u32) -> f64> SliceTimer for (F, Vec<u32>) {
    fn time_slice(&mut self, i: u32, j: u32) -> f64 {
        (self.0)(i, j)
    }
    fn buckets(&self) -> Vec<u32> {
        self.1.clone()
    }
}

/// Raw measurement set: base curve + context samples.
#[derive(Debug, Clone)]
pub struct Measurements {
    pub granularity: u32,
    /// (slice_len, t(slice_len, 0)) for each bucket.
    pub base: Vec<(u32, f64)>,
    /// (i, j, t(i, j)) context samples.
    pub ctx_samples: Vec<(u32, u32, f64)>,
    pub repeats: u32,
}

/// Run the paper's measurement plan: `repeats` timed runs per point,
/// keeping the median (robust to scheduler noise on a shared box).
pub fn measure<T: SliceTimer>(
    timer: &mut T,
    seq_len: u32,
    ctx_grid_points: u32,
    repeats: u32,
) -> Measurements {
    let buckets = timer.buckets();
    assert!(!buckets.is_empty());
    let granularity = *buckets.iter().min().unwrap();

    let median = |timer: &mut T, i: u32, j: u32| -> f64 {
        let mut v: Vec<f64> = (0..repeats.max(1)).map(|_| timer.time_slice(i, j)).collect();
        v.sort_by(|a, b| a.partial_cmp(b).unwrap());
        v[v.len() / 2]
    };

    let mut base = Vec::new();
    for &i in &buckets {
        base.push((i, median(timer, i, 0)));
    }

    // Subset grid of context lengths per bucket (paper: "a subset of all
    // (i, j) combinations").
    let mut ctx_samples = Vec::new();
    for &i in &buckets {
        let max_ctx = seq_len.saturating_sub(i);
        if max_ctx == 0 {
            continue;
        }
        let step = (max_ctx / ctx_grid_points.max(1)).max(granularity);
        let mut j = step;
        while j <= max_ctx {
            // snap to grid so the fitted model can be queried on-grid
            let jj = j / granularity * granularity;
            if jj > 0 {
                ctx_samples.push((i, jj, median(timer, i, jj)));
            }
            j += step;
        }
    }

    Measurements { granularity, base, ctx_samples, repeats }
}

/// Turn measurements into the Eq. 9 model: tabulated base (interpolating
/// between buckets on the granularity grid) + fitted ctx coefficients.
pub fn fit(meas: &Measurements, seq_len: u32) -> Result<LinearCtxModel, String> {
    let g = meas.granularity;
    if seq_len % g != 0 {
        return Err(format!("seq_len {seq_len} not divisible by granularity {g}"));
    }
    let n = (seq_len / g) as usize;

    // Base curve: piecewise-linear interpolation of the measured buckets
    // onto every grid point (the paper measures all L; buckets + interp is
    // our static-shape concession, documented in DESIGN.md §7).
    let mut pts: Vec<(f64, f64)> = meas.base.iter().map(|&(i, t)| (i as f64, t)).collect();
    pts.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap());
    if pts.is_empty() {
        return Err("no base measurements".into());
    }
    let interp = |x: f64| -> f64 {
        if x <= pts[0].0 {
            // below smallest bucket: flat (launch-bound, Fig. 3)
            return pts[0].1;
        }
        for w in pts.windows(2) {
            let (x0, y0) = w[0];
            let (x1, y1) = w[1];
            if x <= x1 {
                return y0 + (y1 - y0) * (x - x0) / (x1 - x0);
            }
        }
        // above largest bucket: extrapolate last segment
        let (x0, y0) = pts[pts.len() - 2];
        let (x1, y1) = pts[pts.len() - 1];
        y1 + (y1 - y0) / (x1 - x0) * (x - x1)
    };
    let mut base = vec![0.0; n + 1];
    for a in 1..=n {
        base[a] = interp((a as u32 * g) as f64);
    }

    // Context overhead samples: subtract the interpolated base.
    let ctx: Vec<(u32, u32, f64)> = meas
        .ctx_samples
        .iter()
        .map(|&(i, j, t)| (i, j, t - interp(i as f64)))
        .collect();
    let coeffs = if ctx.len() >= 4 {
        LinearCtxModel::fit_ctx(&ctx)?
    } else {
        CtxCoeffs { a0: 0.0, a1: 0.0, a2: 0.0, a3: 0.0 }
    };
    Ok(LinearCtxModel::new(g, base, coeffs))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::perfmodel::CostModel;

    /// Synthetic timer with a known bilinear law + deterministic "noise".
    fn toy_timer() -> (impl FnMut(u32, u32) -> f64, Vec<u32>) {
        let mut call = 0u32;
        (
            move |i: u32, j: u32| {
                call += 1;
                let noise = if call % 3 == 0 { 0.05 } else { 0.0 }; // median kills it
                0.2 + 0.01 * i as f64 + 0.001 * i as f64 * j as f64 / 64.0 + noise
            },
            vec![16, 32, 64, 128],
        )
    }

    #[test]
    fn measure_collects_base_and_ctx_samples() {
        let mut t = toy_timer();
        let m = measure(&mut t, 128, 4, 3);
        assert_eq!(m.base.len(), 4);
        assert!(!m.ctx_samples.is_empty());
        assert_eq!(m.granularity, 16);
    }

    #[test]
    fn fit_recovers_toy_law_within_2pct() {
        let mut t = toy_timer();
        let m = measure(&mut t, 128, 6, 5);
        let fitted = fit(&m, 128).unwrap();
        for &(i, j) in &[(16u32, 16u32), (32, 64), (64, 64), (128, 0), (16, 112)] {
            let ctx = if j > 0 { 0.001 * i as f64 * j as f64 / 64.0 } else { 0.0 };
            let truth = 0.2 + 0.01 * i as f64 + ctx;
            let pred = fitted.t(i, j);
            let rel = ((pred - truth) / truth).abs();
            assert!(rel < 0.02, "({i},{j}): pred {pred} truth {truth} rel {rel}");
        }
    }

    #[test]
    fn fit_rejects_bad_granularity() {
        let mut t = toy_timer();
        let m = measure(&mut t, 128, 4, 1);
        assert!(fit(&m, 100).is_err());
    }

    fn flat_model(g: u32, n: usize, level: f64) -> LinearCtxModel {
        LinearCtxModel::new(g, vec![level; n + 1], CtxCoeffs { a0: 0.0, a1: 0.0, a2: 0.0, a3: 0.0 })
    }

    #[test]
    fn bottleneck_takes_max_over_present_roles() {
        let models = StageModels {
            first: flat_model(4, 8, 3.0),
            middle: flat_model(4, 8, 7.0),
            last: flat_model(4, 8, 5.0),
        };
        // K=1: only a Last stage exists — the slow middle fit is ignored.
        assert_eq!(models.planning_model(1).t(4, 0), 5.0);
        // K=2: First vs Last.
        assert_eq!(models.planning_model(2).t(4, 0), 5.0);
        let heavy_first = StageModels { first: flat_model(4, 8, 9.0), ..models.clone() };
        assert_eq!(heavy_first.planning_model(2).t(4, 0), 9.0);
        // K≥3: the middle fit joins and dominates here.
        assert_eq!(models.planning_model(3).t(4, 8), 7.0);
        assert_eq!(models.planning_model(3).t_comm(4), 0.0);
    }

    #[test]
    fn slicing_dp_consumes_bottleneck_model() {
        // Flat per-slice cost: Eq. 5 says fewer slices always win, so the
        // DP over the bottleneck model must return one full-length slice
        // with latency (1 + (K-1)) · bottleneck.
        let models = StageModels {
            first: flat_model(4, 8, 1.0),
            middle: flat_model(4, 8, 2.0),
            last: flat_model(4, 8, 1.5),
        };
        let pm = models.planning_model(3);
        let (scheme, _) =
            crate::solver::bucketed::solve_tokens_bucketed(&pm, 32, 2, &[4, 8, 16, 32], 0.0)
                .expect("solvable");
        assert_eq!(scheme.lens.iter().sum::<u32>(), 32);
        assert_eq!(scheme.lens, vec![32]);
        assert!((scheme.latency_ms - 4.0).abs() < 1e-9, "got {}", scheme.latency_ms);
    }

    #[test]
    fn base_interpolation_flat_below_smallest_bucket() {
        let meas = Measurements {
            granularity: 8,
            base: vec![(16, 1.0), (32, 2.0)],
            ctx_samples: vec![],
            repeats: 1,
        };
        let m = fit(&meas, 32).unwrap();
        assert_eq!(m.t(8, 0), 1.0); // launch-bound flat region
        assert_eq!(m.t(16, 0), 1.0);
        assert_eq!(m.t(32, 0), 2.0);
        assert!((m.t(24, 0) - 1.5).abs() < 1e-12);
    }
}
