//! Analytic V100-shaped instantiation of the paper's `t_fwd(i, j)` model.
//!
//! Stands in for the 48×p3.16xlarge measurements (DESIGN.md §2): every term
//! is a physically-motivated function of the model geometry and cluster
//! spec, with two calibrated constants (`GpuSpec::efficiency`,
//! `GpuSpec::saturation_tokens_h2048`) chosen so the simulator's
//! w/o-TeraPipe latencies land near the paper's Table 2 column (see
//! EXPERIMENTS.md §Calibration). The *shape* — the Fig. 3 flat-then-linear
//! knee and the quadratic context term — is what drives all DP decisions.
//!
//! Per-cell slice latency for `i` tokens with `j` tokens of context, `b`
//! sequences in the microbatch (everything in ms):
//!
//! ```text
//! t_fwd(i,j) = launch·layers
//!            + FLOPs(max(i, i_sat), j) / (op · peak · eff)     # compute
//!            + 4·layers·ring(b·i·H·2B, op) / intra_bw          # Megatron allreduce
//! t(i,j)     = 3 · t_fwd(i,j)                                  # bwd ≈ 2× fwd
//! t_comm(i)  = latency + b·i·H·2B / inter_bw                   # stage hand-off
//! ```

use super::CostModel;
use crate::config::Setting;

/// Analytic per-cell cost model derived from a [`Setting`].
#[derive(Debug, Clone)]
pub struct AnalyticModel {
    /// Layers per pipeline cell.
    pub layers: u32,
    /// Hidden size H.
    pub hidden: u32,
    /// Attention heads.
    pub num_heads: u32,
    /// Sequences per microbatch flowing through the pipeline together.
    pub microbatch: u32,
    /// Megatron op-partition width.
    pub op: u32,
    /// Device throughput actually achieved on saturated matmuls, TFLOP/s.
    pub eff_tflops: f64,
    /// Saturation knee in tokens (per-device, already op-scaled).
    pub sat_tokens: f64,
    /// Per-layer launch/framework overhead, ms.
    pub launch_ms: f64,
    /// Intra-node (NVLink) bandwidth, GB/s.
    pub intra_bw: f64,
    /// Inter-node bandwidth, GB/s.
    pub inter_bw: f64,
    /// P2P latency, ms.
    pub p2p_latency_ms: f64,
    /// Backward-to-forward cost ratio (2.0 ⇒ t = 3·t_fwd).
    pub bwd_ratio: f64,
    /// GPU memory, GiB (for the in-flight cap, Appendix A).
    pub mem_gib: f64,
    /// Activation-memory fudge (allocator/framework overhead), calibrated.
    pub act_overhead: f64,
    /// Sequence length (memory model only).
    pub seq_len: u32,
}

impl AnalyticModel {
    /// Model a pipeline cell of `setting` with the pipeline-level microbatch
    /// of `microbatch` sequences (≤ B/#data).
    pub fn from_setting(setting: &Setting, microbatch: u32) -> Self {
        let m = &setting.model;
        let c = &setting.cluster;
        let p = &setting.parallel;
        let h = m.hidden as f64;
        AnalyticModel {
            layers: setting.layers_per_stage(),
            hidden: m.hidden,
            num_heads: m.num_heads,
            microbatch,
            op: p.op_parallel,
            eff_tflops: c.gpu.peak_tflops * c.gpu.efficiency,
            // Per-token per-GPU work scales as H²/op ⇒ the knee moves as
            // (2048/H)²·op relative to the Fig. 3 measurement at H=2048.
            sat_tokens: (c.gpu.saturation_tokens_h2048 * (2048.0 / h) * (2048.0 / h)
                * p.op_parallel as f64)
                .max(1.0),
            launch_ms: c.gpu.launch_overhead_ms,
            intra_bw: c.intra_bw_gbps,
            inter_bw: c.inter_bw_gbps,
            p2p_latency_ms: c.p2p_latency_ms,
            bwd_ratio: 2.0,
            mem_gib: c.gpu.mem_gib,
            act_overhead: 6.0,
            seq_len: m.seq_len,
        }
    }

    /// Forward-only latency (ms); `t()` adds the backward multiple.
    pub fn t_fwd(&self, i: u32, j: u32) -> f64 {
        let h = self.hidden as f64;
        let b = self.microbatch as f64;
        let lay = self.layers as f64;
        // Underutilization floor: below the knee a V100 takes the same time
        // as at the knee (paper Fig. 3 top, flat segment).
        let i_eff = (i as f64 * b).max(self.sat_tokens);
        let dense_flops = 24.0 * h * h * i_eff * lay;
        let ctx_flops = 4.0 * h * (i as f64 * b) * (j as f64 + i as f64 / 2.0) * lay;
        let compute_ms = (dense_flops + ctx_flops) / (self.op as f64 * self.eff_tflops * 1e9);
        let allreduce_ms = if self.op > 1 {
            let bytes = b * i as f64 * h * 2.0;
            let ring = 2.0 * (self.op as f64 - 1.0) / self.op as f64;
            4.0 * lay * ring * bytes / (self.intra_bw * 1e6)
        } else {
            0.0
        };
        self.launch_ms * lay + compute_ms + allreduce_ms
    }

    /// Gradient allreduce time (ms) per iteration for `data` replicas over
    /// the inter-node network (ring, fp16 grads of this cell's params).
    pub fn dp_allreduce_ms(&self, data: u32) -> f64 {
        if data <= 1 {
            return 0.0;
        }
        let h = self.hidden as f64;
        let param_bytes = 12.0 * h * h * self.layers as f64 / self.op as f64 * 2.0;
        2.0 * (data as f64 - 1.0) / data as f64 * param_bytes / (self.inter_bw * 1e6)
    }

    /// Bytes of stored activations one sequence leaves on this cell
    /// (no rematerialization, as in the paper's implementation).
    pub fn act_bytes_per_seq(&self) -> f64 {
        let h = self.hidden as f64;
        let l = self.seq_len as f64;
        let lay = self.layers as f64;
        let heads = self.num_heads as f64 / self.op as f64;
        // ~8 L×H tensors per layer (split across op) + attention scores.
        let dense = 8.0 * l * h * 2.0 / self.op as f64;
        let attn = 2.0 * heads * l * l * 2.0;
        self.act_overhead * lay * (dense + attn)
    }

    /// Max sequences whose activations fit beside the parameters +
    /// optimizer state (Appendix A's constraint).
    pub fn max_inflight_seqs(&self) -> u32 {
        let h = self.hidden as f64;
        // fp16 param+grad, fp32 master+m+v = 16 bytes/param
        let param_bytes = 12.0 * h * h * self.layers as f64 / self.op as f64 * 16.0;
        let budget = self.mem_gib * 1.073e9 - param_bytes;
        (budget / self.act_bytes_per_seq()).floor().max(1.0) as u32
    }

    /// Clone with a different microbatch size (joint batch+token DP sweeps
    /// this, §3.4).
    pub fn with_microbatch(&self, microbatch: u32) -> Self {
        AnalyticModel {
            microbatch,
            ..self.clone()
        }
    }
}

impl CostModel for AnalyticModel {
    fn t(&self, i: u32, j: u32) -> f64 {
        (1.0 + self.bwd_ratio) * self.t_fwd(i, j)
    }

    fn t_comm(&self, i: u32) -> f64 {
        let bytes = self.microbatch as f64 * i as f64 * self.hidden as f64 * 2.0;
        self.p2p_latency_ms + bytes / (self.inter_bw * 1e6)
    }
}

/// Analytic phase costs for the simulator (fwd/bwd split from the model's
/// `bwd_ratio`). The one shared [`crate::sim::schedule::PhaseCost`] impl
/// over [`AnalyticModel`] — used by the experiment harness, the planner's
/// validation path, and the CLI (previously duplicated in
/// `experiments.rs`).
pub struct AnalyticPhase<'a> {
    pub base: &'a AnalyticModel,
}

impl crate::sim::schedule::PhaseCost for AnalyticPhase<'_> {
    fn fwd_ms(&self, b: u32, i: u32, j: u32) -> f64 {
        self.base.with_microbatch(b).t_fwd(i, j)
    }
    fn bwd_ms(&self, b: u32, i: u32, j: u32) -> f64 {
        let m = self.base.with_microbatch(b);
        m.bwd_ratio * m.t_fwd(i, j)
    }
    fn comm_ms(&self, b: u32, i: u32) -> f64 {
        self.base.with_microbatch(b).t_comm(i)
    }
}

/// Single-layer forward time on one V100 with no context — the Fig. 3
/// measurement. Built from a model config with op=1, one layer, b=1.
///
/// Uses the *microbenchmark* overhead constants (50 µs launch, knee at
/// 256 tokens) rather than the cluster-calibrated GpuSpec defaults: the
/// calibrated `launch_overhead_ms` folds in per-slice pipeline-framework
/// cost (PyTorch scheduling, NCCL p2p setup) that does not exist in the
/// isolated single-layer measurement the paper's Fig. 3 reports.
pub fn fig3_model(model: &crate::config::ModelConfig) -> AnalyticModel {
    let mut gpu = crate::config::GpuSpec::default();
    gpu.launch_overhead_ms = 0.05;
    gpu.saturation_tokens_h2048 = 256.0;
    let setting = Setting {
        id: 0,
        model: model.clone(),
        cluster: crate::config::ClusterConfig {
            num_nodes: 1,
            gpu,
            ..Default::default()
        },
        parallel: crate::config::ParallelConfig {
            batch_size: 1,
            data_parallel: 1,
            pipeline_stages: model.num_layers,
            op_parallel: 1,
        },
    };
    AnalyticModel::from_setting(&setting, 1)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::presets;

    fn model5() -> AnalyticModel {
        AnalyticModel::from_setting(&presets::setting(5), 1)
    }

    #[test]
    fn fig3_shape_flat_then_linear() {
        // The paper's Fig. 3: per-layer fwd time flat below the knee,
        // linear above; throughput (tokens/ms) rises then plateaus.
        let m = fig3_model(&presets::gpt3_1b());
        let t1 = m.t_fwd(1, 0);
        let t128 = m.t_fwd(128, 0);
        let t256 = m.t_fwd(256, 0);
        let t512 = m.t_fwd(512, 0);
        let t1024 = m.t_fwd(1024, 0);
        // flat region (ctx term is tiny below the knee)
        assert!((t128 - t1) / t1 < 0.15, "flat region: {t1} vs {t128}");
        // linear region: doubling tokens ≈ doubles time
        let r = t1024 / t512;
        assert!(r > 1.8 && r < 2.2, "linear region ratio {r}");
        // knee is where it bends
        assert!(t512 > 1.5 * t256 * 0.9);
        // throughput monotone non-decreasing up to the knee
        assert!(128.0 / t128 > 1.0 / t1);
    }

    #[test]
    fn cost_monotone_in_slice_and_context() {
        let m = model5();
        let mut prev = 0.0;
        for i in [64, 128, 256, 512, 1024, 2048] {
            let t = m.t(i, 0);
            assert!(t > prev);
            prev = t;
        }
        assert!(m.t(256, 1024) > m.t(256, 256));
    }

    #[test]
    fn later_slices_cost_more_than_earlier_equal_slices() {
        // The paper's Fig. 4 motivation: same length, later position ⇒
        // heavier attention load.
        let m = fig3_model(&presets::gpt3_1b());
        assert!(m.t(512, 1536) > m.t(512, 0) * 1.08);
        // and on the op-partitioned 13B cell the effect is present too
        let m5 = model5();
        assert!(m5.t(512, 1536) > m5.t(512, 0) * 1.01);
    }

    #[test]
    fn op_partitioning_reduces_compute_time() {
        let s = presets::setting(5);
        let with_op = AnalyticModel::from_setting(&s, 1);
        let mut s1 = s.clone();
        s1.parallel.op_parallel = 1;
        s1.parallel.pipeline_stages = 40;
        s1.parallel.data_parallel = 8;
        let without = AnalyticModel::from_setting(&s1, 1);
        assert!(with_op.t(2048, 0) < without.t(2048, 0));
    }

    #[test]
    fn bwd_ratio_applied() {
        let m = model5();
        assert!((m.t(512, 0) / m.t_fwd(512, 0) - 3.0).abs() < 1e-9);
    }

    #[test]
    fn comm_scales_with_slice_length() {
        let m = model5();
        let c1 = m.t_comm(128);
        let c2 = m.t_comm(2048);
        assert!(c2 > c1);
        assert!(c1 > m.p2p_latency_ms);
    }

    #[test]
    fn dp_allreduce_zero_for_single_replica() {
        let m = model5();
        assert_eq!(m.dp_allreduce_ms(1), 0.0);
        assert!(m.dp_allreduce_ms(8) > 0.0);
    }

    #[test]
    fn memory_cap_tighter_for_larger_models() {
        let small = AnalyticModel::from_setting(&presets::setting(1), 1);
        let big = AnalyticModel::from_setting(&presets::setting(10), 1);
        assert!(big.max_inflight_seqs() <= small.max_inflight_seqs());
        assert!(big.max_inflight_seqs() >= 1);
    }

    #[test]
    fn microbatch_scales_cost() {
        let m1 = model5();
        let m4 = m1.with_microbatch(4);
        assert!(m4.t(2048, 0) > 2.0 * m1.t(2048, 0));
    }

    #[test]
    fn saturation_knee_scales_with_hidden_and_op() {
        let m1b = fig3_model(&presets::gpt3_1b());
        assert!((m1b.sat_tokens - 256.0).abs() < 1.0);
        let m175 = AnalyticModel::from_setting(&presets::setting(9), 1);
        assert!(m175.sat_tokens < 50.0); // huge layers saturate early
    }
}
