//! The paper's measured performance model (Eq. 9):
//!
//! `t_fwd(i, j) = t_fwd(i, 0) + t_ctx(i, j)` with
//! `t_ctx(i, j) = a0 + a1·i + a2·j + a3·i·j`,
//!
//! where `t_fwd(i, 0)` is tabulated (L measurements) and the four `a_k`
//! are fit by ordinary least squares on a *subset* of (i, j) samples —
//! the paper reports < 2 % relative error from this form, and
//! [`fit_report`]'s output is checked against that bound in our tests.

use super::CostModel;

/// `t_ctx` coefficients (ms).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct CtxCoeffs {
    pub a0: f64,
    pub a1: f64,
    pub a2: f64,
    pub a3: f64,
}

impl CtxCoeffs {
    pub fn eval(&self, i: u32, j: u32) -> f64 {
        let (i, j) = (i as f64, j as f64);
        self.a0 + self.a1 * i + self.a2 * j + self.a3 * i * j
    }
}

/// Eq. 9 instantiated: base curve on a granularity grid + fitted context
/// overhead.
#[derive(Debug, Clone)]
pub struct LinearCtxModel {
    granularity: u32,
    /// `base[a]` = measured t(a·g, 0); base[0] unused.
    base: Vec<f64>,
    pub coeffs: CtxCoeffs,
    /// Per-slice comm cost on the same grid (0 if folded into base).
    comm: Vec<f64>,
}

impl LinearCtxModel {
    /// `base[a]` must hold t(a·g, 0) for a in 0..=n (index 0 ignored).
    pub fn new(granularity: u32, base: Vec<f64>, coeffs: CtxCoeffs) -> Self {
        let comm = vec![0.0; base.len()];
        LinearCtxModel { granularity, base, coeffs, comm }
    }

    pub fn with_comm(mut self, comm: Vec<f64>) -> Self {
        assert_eq!(comm.len(), self.base.len());
        self.comm = comm;
        self
    }

    /// Fit the four `a_k` by least squares from `(i, j, t_ctx)` samples.
    /// Needs ≥ 4 samples spanning distinct i, j and i·j values.
    pub fn fit_ctx(samples: &[(u32, u32, f64)]) -> Result<CtxCoeffs, String> {
        if samples.len() < 4 {
            return Err("need at least 4 samples".into());
        }
        // Normal equations AᵀA x = Aᵀb with features [1, i, j, ij].
        let mut ata = [[0.0f64; 4]; 4];
        let mut atb = [0.0f64; 4];
        for &(i, j, t) in samples {
            let f = [1.0, i as f64, j as f64, i as f64 * j as f64];
            for r in 0..4 {
                for c in 0..4 {
                    ata[r][c] += f[r] * f[c];
                }
                atb[r] += f[r] * t;
            }
        }
        let x = solve4(ata, atb).ok_or_else(|| "singular normal equations (samples don't span the feature space)".to_string())?;
        Ok(CtxCoeffs { a0: x[0], a1: x[1], a2: x[2], a3: x[3] })
    }
}

impl CostModel for LinearCtxModel {
    fn t(&self, i: u32, j: u32) -> f64 {
        assert!(i % self.granularity == 0 && j % self.granularity == 0, "off-grid query");
        let a = (i / self.granularity) as usize;
        assert!(a >= 1 && a < self.base.len(), "slice length {i} outside measured range");
        let ctx = if j == 0 { 0.0 } else { self.coeffs.eval(i, j) };
        self.base[a] + ctx.max(0.0)
    }

    fn t_comm(&self, i: u32) -> f64 {
        self.comm[(i / self.granularity) as usize]
    }
}

/// Gaussian elimination with partial pivoting on a 4×4 system.
fn solve4(mut a: [[f64; 4]; 4], mut b: [f64; 4]) -> Option<[f64; 4]> {
    for col in 0..4 {
        let piv = (col..4).max_by(|&r1, &r2| {
            a[r1][col].abs().partial_cmp(&a[r2][col].abs()).unwrap()
        })?;
        if a[piv][col].abs() < 1e-12 {
            return None;
        }
        a.swap(col, piv);
        b.swap(col, piv);
        for r in (col + 1)..4 {
            let f = a[r][col] / a[col][col];
            for c in col..4 {
                a[r][c] -= f * a[col][c];
            }
            b[r] -= f * b[col];
        }
    }
    let mut x = [0.0; 4];
    for r in (0..4).rev() {
        let mut s = b[r];
        for c in (r + 1)..4 {
            s -= a[r][c] * x[c];
        }
        x[r] = s / a[r][r];
    }
    Some(x)
}

/// Fit quality: max and mean relative error of the fitted model against
/// held-out samples `(i, j, t_true)` (full-cost, not just the ctx term).
pub struct FitReport {
    pub max_rel_err: f64,
    pub mean_rel_err: f64,
    pub n: usize,
}

pub fn fit_report<M: CostModel>(
    model: &M,
    fitted: &LinearCtxModel,
    grid: &[(u32, u32)],
) -> FitReport {
    let mut max_rel = 0.0f64;
    let mut sum_rel = 0.0f64;
    for &(i, j) in grid {
        let truth = model.t(i, j);
        let pred = fitted.t(i, j);
        let rel = ((pred - truth) / truth).abs();
        max_rel = max_rel.max(rel);
        sum_rel += rel;
    }
    FitReport { max_rel_err: max_rel, mean_rel_err: sum_rel / grid.len() as f64, n: grid.len() }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::perfmodel::analytic::AnalyticModel;
    use crate::config::presets;

    #[test]
    fn exact_recovery_of_planted_coefficients() {
        let truth = CtxCoeffs { a0: 0.3, a1: 0.002, a2: 0.0007, a3: 1.5e-6 };
        let mut samples = Vec::new();
        for i in [64u32, 128, 256, 512] {
            for j in [0u32, 128, 512, 1024] {
                samples.push((i, j, truth.eval(i, j)));
            }
        }
        let fit = LinearCtxModel::fit_ctx(&samples).unwrap();
        assert!((fit.a0 - truth.a0).abs() < 1e-9);
        assert!((fit.a1 - truth.a1).abs() < 1e-12);
        assert!((fit.a2 - truth.a2).abs() < 1e-12);
        assert!((fit.a3 - truth.a3).abs() < 1e-15);
    }

    #[test]
    fn too_few_or_degenerate_samples_rejected() {
        assert!(LinearCtxModel::fit_ctx(&[(1, 1, 1.0)]).is_err());
        // all identical rows → singular
        let s = vec![(8u32, 8u32, 1.0f64); 8];
        assert!(LinearCtxModel::fit_ctx(&s).is_err());
    }

    /// The paper's claim: the 4-term linear model predicts the context
    /// overhead within ~2 % — it must hold against our analytic substrate
    /// (whose ctx term is exactly bilinear, so the fit is near-exact).
    #[test]
    fn subset_fit_predicts_analytic_model_within_2pct() {
        let m = AnalyticModel::from_setting(&presets::setting(5), 1);
        let g = 64u32;
        let l = 2048u32;
        // tabulate base curve
        let n = (l / g) as usize;
        let mut base = vec![0.0; n + 1];
        for a in 1..=n {
            base[a] = m.t(a as u32 * g, 0);
        }
        // subset of (i, j) pairs for the ctx fit
        let mut samples = Vec::new();
        for &i in &[64u32, 256, 512, 1024] {
            for &j in &[64u32, 256, 512, 1024] {
                if i + j <= l {
                    samples.push((i, j, m.t(i, j) - m.t(i, 0)));
                }
            }
        }
        let coeffs = LinearCtxModel::fit_ctx(&samples).unwrap();
        let fitted = LinearCtxModel::new(g, base, coeffs);
        // held-out grid
        let mut grid = Vec::new();
        for a in 1..=n {
            for b in 0..=(n - a) {
                grid.push((a as u32 * g, b as u32 * g));
            }
        }
        let rep = fit_report(&m, &fitted, &grid);
        assert!(rep.max_rel_err < 0.02, "max rel err {}", rep.max_rel_err);
    }

    #[test]
    fn off_grid_query_panics() {
        let zero = CtxCoeffs { a0: 0.0, a1: 0.0, a2: 0.0, a3: 0.0 };
        let m = LinearCtxModel::new(8, vec![0.0, 1.0, 2.0], zero);
        let r = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| m.t(7, 0)));
        assert!(r.is_err());
    }
}
