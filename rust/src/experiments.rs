//! Experiment harness: regenerates every table and figure of the paper's
//! evaluation (DESIGN.md §5 maps each to its bench target).
//!
//! All functions are pure library code so `terapipe <fig…>` subcommands,
//! the `benches/` binaries, and the tests share one implementation. The
//! GPU testbed is the calibrated analytic model + discrete-event simulator
//! (DESIGN.md §2); the paper's own published numbers are embedded as
//! constants for side-by-side reporting in EXPERIMENTS.md.

use crate::config::{presets, Setting};
use crate::perfmodel::analytic::{fig3_model, AnalyticModel};
use crate::sim::schedule::{build_plan, PhaseCost};
use crate::sim::{engine::simulate, SimResult};
use crate::solver::joint::{gpipe_plan, solve_joint_analytic, JointOpts};
use crate::solver::JointScheme;

// The simulator-facing fwd/bwd split of the analytic model lives with the
// model itself; re-exported here so existing callers keep their import
// path.
pub use crate::perfmodel::analytic::AnalyticPhase;

/// One w/o-vs-w/ TeraPipe comparison row (Fig. 5 / Table 2).
#[derive(Debug, Clone)]
pub struct ComparisonRow {
    pub setting: u32,
    pub model_name: String,
    pub gpipe_scheme: String,
    pub gpipe_latency_s: f64,
    pub gpipe_tflops: f64,
    pub terapipe_scheme: String,
    pub terapipe_latency_s: f64,
    pub terapipe_tflops: f64,
    pub speedup: f64,
    /// The paper's measured latencies (s) for this row, for reference.
    pub paper_gpipe_s: f64,
    pub paper_terapipe_s: f64,
}

/// Paper Table 2 latency columns (mean seconds), rows 1–10.
pub const PAPER_TABLE2: [(f64, f64); 10] = [
    (1.517, 1.254),
    (1.018, 1.018),
    (0.913, 0.913),
    (2.637, 1.891),
    (1.863, 1.328),
    (13.319, 7.103),
    (4.311, 2.771),
    (2.662, 1.111),
    (9.990, 1.481),
    (5.822, 1.160),
];

/// Simulated iteration latency (ms) of a joint scheme on a setting:
/// pipeline makespan (flush schedule, as the paper's implementation) plus
/// the data-parallel gradient allreduce.
pub fn sim_iteration_ms(setting: &Setting, scheme: &JointScheme) -> SimResult {
    let base = AnalyticModel::from_setting(setting, 1);
    let cost = AnalyticPhase { base: &base };
    let plan = build_plan(
        &cost,
        scheme,
        setting.parallel.pipeline_stages as usize,
        None,
        true,
    );
    let mut r = simulate(&plan).expect("uncapped flush schedule cannot deadlock");
    r.makespan_ms += base.dp_allreduce_ms(setting.parallel.data_parallel);
    r
}

/// Model FLOPs utilization per GPU (TFLOP/s), the paper's last column:
/// 6 · #params · B · L / (#GPUs · latency).
pub fn tflops_per_gpu(setting: &Setting, latency_s: f64) -> f64 {
    let flops = 6.0
        * setting.model.num_params() as f64
        * setting.parallel.batch_size as f64
        * setting.model.seq_len as f64;
    flops / (setting.parallel.total_gpus() as f64 * latency_s) / 1e12
}

/// Solve + simulate one Table 1 setting both ways (Fig. 5 / Table 2 row).
pub fn fig5_row(setting_id: u32, opts: &JointOpts) -> ComparisonRow {
    fig5_row_for(&presets::setting(setting_id), opts)
}

/// Same, over a caller-supplied (possibly customized) setting — used by
/// the calibration sweep (`terapipe calibrate`, EXPERIMENTS.md §Calib).
pub fn fig5_row_for(setting: &Setting, opts: &JointOpts) -> ComparisonRow {
    let setting_id = setting.id;
    let base = AnalyticModel::from_setting(setting, 1);
    let b_pipe = setting.batch_per_pipeline();
    let k = setting.parallel.pipeline_stages;
    let l = setting.model.seq_len;

    let gpipe = gpipe_plan(&|b| base.with_microbatch(b), b_pipe, l, k);
    let tera = solve_joint_analytic(&base, b_pipe, l, k, opts);

    let g_ms = sim_iteration_ms(setting, &gpipe).makespan_ms;
    let t_ms = sim_iteration_ms(setting, &tera).makespan_ms;
    let (pg, pt) = PAPER_TABLE2[setting_id as usize - 1];

    ComparisonRow {
        setting: setting_id,
        model_name: setting.model.name.clone(),
        gpipe_scheme: gpipe.notation(),
        gpipe_latency_s: g_ms / 1e3,
        gpipe_tflops: tflops_per_gpu(setting, g_ms / 1e3),
        terapipe_scheme: tera.notation(),
        terapipe_latency_s: t_ms / 1e3,
        terapipe_tflops: tflops_per_gpu(setting, t_ms / 1e3),
        speedup: g_ms / t_ms,
        paper_gpipe_s: pg,
        paper_terapipe_s: pt,
    }
}

/// All ten rows (Fig. 5).
pub fn fig5_all(opts: &JointOpts) -> Vec<ComparisonRow> {
    (1..=10).map(|i| fig5_row(i, opts)).collect()
}

/// Fig. 3: single-layer forward time + throughput vs token count on one
/// V100 (analytic). Returns (tokens, ms, tokens/ms).
pub fn fig3_curve(model: &crate::config::ModelConfig, max_tokens: u32) -> Vec<(u32, f64, f64)> {
    let m = fig3_model(model);
    let mut out = Vec::new();
    let mut t = 1u32;
    while t <= max_tokens {
        let ms = m.t_fwd(t, 0);
        out.push((t, ms, t as f64 / ms));
        t *= 2;
    }
    out
}

/// Fig. 6: uniform #slices sweep vs the DP scheme on one setting.
/// Returns (label, scheme notation, latency_s, tflops).
pub fn fig6_rows(
    setting_id: u32,
    max_slices: u32,
    opts: &JointOpts,
) -> Vec<(String, String, f64, f64)> {
    let setting = presets::setting(setting_id);
    let base = AnalyticModel::from_setting(&setting, 1);
    let b_pipe = setting.batch_per_pipeline();
    let k = setting.parallel.pipeline_stages;
    let l = setting.model.seq_len;
    let mut rows = Vec::new();

    let mut n = 1u32;
    while n <= max_slices {
        let s = crate::solver::uniform::uniform_scheme(&base, l, k, n, opts.granularity);
        let scheme = JointScheme {
            parts: (0..b_pipe).map(|_| (1u32, s.clone())).collect(),
            latency_ms: 0.0,
        };
        let ms = sim_iteration_ms(&setting, &scheme).makespan_ms;
        rows.push((
            format!("#Slices={n}"),
            scheme.notation(),
            ms / 1e3,
            tflops_per_gpu(&setting, ms / 1e3),
        ));
        n *= 2;
    }

    let tera = solve_joint_analytic(&base, b_pipe, l, k, opts);
    let ms = sim_iteration_ms(&setting, &tera).makespan_ms;
    rows.push((
        "DP".into(),
        tera.notation(),
        ms / 1e3,
        tflops_per_gpu(&setting, ms / 1e3),
    ));
    rows
}

/// Fig. 7 / Table 4: sequence-length sweep on GPT3-13B setting (5).
/// Returns (seq_len, gpipe_s, terapipe_s, speedup, terapipe scheme).
pub fn fig7_rows(opts: &JointOpts) -> Vec<(u32, f64, f64, f64, String)> {
    presets::long_sequence_settings()
        .into_iter()
        .map(|(seq_len, setting)| {
            let base = AnalyticModel::from_setting(&setting, 1);
            let b_pipe = setting.batch_per_pipeline();
            let k = setting.parallel.pipeline_stages;
            let gpipe = gpipe_plan(&|b| base.with_microbatch(b), b_pipe, seq_len, k);
            let tera = solve_joint_analytic(&base, b_pipe, seq_len, k, opts);
            let g = sim_iteration_ms(&setting, &gpipe).makespan_ms / 1e3;
            let t = sim_iteration_ms(&setting, &tera).makespan_ms / 1e3;
            (seq_len, g, t, g / t, tera.notation())
        })
        .collect()
}

/// Appendix A: 3-stage pipeline, per-stage memory cap of 2 sequences, six
/// input sequences. Returns (label, makespan) for (a) uncapped GA,
/// (b) capped GA, (c) capped TeraPipe-split.
pub fn appendix_a_rows() -> Vec<(String, f64)> {
    struct Unit;
    impl PhaseCost for Unit {
        fn fwd_ms(&self, _b: u32, i: u32, _j: u32) -> f64 {
            i as f64
        }
        fn bwd_ms(&self, _b: u32, i: u32, _j: u32) -> f64 {
            2.0 * i as f64
        }
        fn comm_ms(&self, _b: u32, _i: u32) -> f64 {
            0.0
        }
    }
    let seqs = |lens: Vec<u32>| JointScheme {
        parts: (0..6)
            .map(|_| {
                (
                    1u32,
                    crate::solver::SliceScheme {
                        lens: lens.clone(),
                        total_ms: 0.0,
                        t_max_ms: 0.0,
                        latency_ms: 0.0,
                    },
                )
            })
            .collect(),
        latency_ms: 0.0,
    };
    let k = 3usize;
    let run = |scheme: &JointScheme, cap: Option<u32>| {
        simulate(&build_plan(&Unit, scheme, k, cap, false))
            .unwrap()
            .makespan_ms
    };
    vec![
        ("(a) GA, no memory cap".into(), run(&seqs(vec![2]), None)),
        ("(b) GA, cap 2 seqs".into(), run(&seqs(vec![2]), Some(2))),
        ("(c) TeraPipe split, cap 2 seqs".into(), run(&seqs(vec![1, 1]), Some(2))),
    ]
}

/// Markdown-ish table rendering shared by the CLI and the benches.
pub fn render_fig5(rows: &[ComparisonRow]) -> String {
    let mut out = String::new();
    out.push_str(
        "| set | model     | algorithm    | slicing scheme | latency (s) | TFLOPs/GPU | paper (s) |\n",
    );
    out.push_str(
        "|-----|-----------|--------------|----------------|-------------|------------|-----------|\n",
    );
    for r in rows {
        out.push_str(&format!(
            "| ({}) | {} | w/o TeraPipe | {} | {:.3} | {:.4} | {:.3} |\n",
            r.setting,
            r.model_name,
            clip(&r.gpipe_scheme, 34),
            r.gpipe_latency_s,
            r.gpipe_tflops,
            r.paper_gpipe_s
        ));
        out.push_str(&format!(
            "| ({}) | {} | w/ TeraPipe  | {} | {:.3} | {:.4} | {:.3} | speedup {:.2}x (paper {:.2}x)\n",
            r.setting,
            r.model_name,
            clip(&r.terapipe_scheme, 34),
            r.terapipe_latency_s,
            r.terapipe_tflops,
            r.paper_terapipe_s,
            r.speedup,
            r.paper_gpipe_s / r.paper_terapipe_s,
        ));
    }
    out
}

fn clip(s: &str, n: usize) -> String {
    if s.len() <= n {
        s.to_string()
    } else {
        format!("{}…", &s[..n.saturating_sub(1)])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fast_opts() -> JointOpts {
        JointOpts {
            granularity: 128,
            eps_ms: 0.5,
            max_microbatch: Some(4),
        }
    }

    #[test]
    fn fig5_headline_shape_holds() {
        // The paper's headline: biggest wins on the biggest models (9)/(10),
        // no win on large-batch GPT3-1B settings (2)/(3).
        let r9 = fig5_row(9, &fast_opts());
        assert!(r9.speedup > 3.0, "setting 9 speedup {}", r9.speedup);
        let r2 = fig5_row(2, &fast_opts());
        assert!(r2.speedup < 1.3, "setting 2 speedup {}", r2.speedup);
        assert!(r9.terapipe_tflops > r9.gpipe_tflops);
    }

    #[test]
    fn fig3_curve_flat_then_linear() {
        let c = fig3_curve(&presets::gpt3_1b(), 2048);
        let t1 = c[0].1;
        let t256 = c.iter().find(|r| r.0 == 256).unwrap().1;
        let t2048 = c.iter().find(|r| r.0 == 2048).unwrap().1;
        assert!(t256 < 1.5 * t1, "flat region");
        assert!(t2048 > 5.0 * t256, "linear region");
        // throughput plateaus
        let tp_last = c.last().unwrap().2;
        let tp_first = c[0].2;
        assert!(tp_last > 20.0 * tp_first);
    }

    #[test]
    fn fig6_dp_at_least_matches_best_uniform() {
        // DP optimizes the Eq. 5 objective while the judge is the full
        // fwd+bwd flush simulation, so allow a small modelling gap; the
        // paper's Fig. 6 claim (extremes lose, DP ≈/beats best uniform)
        // is asserted at bench granularity in benches/fig6_dp_ablation.
        let opts = JointOpts { granularity: 32, eps_ms: 0.2, max_microbatch: Some(4) };
        let rows = fig6_rows(8, 16, &opts);
        let dp = rows.last().unwrap();
        assert_eq!(dp.0, "DP");
        let best_uniform = rows[..rows.len() - 1]
            .iter()
            .map(|r| r.2)
            .fold(f64::INFINITY, f64::min);
        assert!(dp.2 <= best_uniform * 1.05, "dp {} vs best uniform {}", dp.2, best_uniform);
        // both extremes lose (Fig. 6 U-shape)
        let one = rows[0].2;
        let finest = rows[rows.len() - 2].2;
        assert!(one > dp.2 * 1.2, "single slice must lose: {one} vs {}", dp.2);
        assert!(finest > best_uniform, "finest slicing must lose to the best");
    }

    #[test]
    fn fig7_speedup_grows_with_sequence_length() {
        let rows = fig7_rows(&fast_opts());
        assert_eq!(rows.len(), 4);
        let speedups: Vec<f64> = rows.iter().map(|r| r.3).collect();
        // paper: 1.4x → 2.76x → 4.97x → 7.83x: strictly growing
        for w in speedups.windows(2) {
            assert!(w[1] > w[0], "speedups not increasing: {speedups:?}");
        }
        assert!(*speedups.last().unwrap() > 3.0);
    }

    #[test]
    fn appendix_a_ordering() {
        let rows = appendix_a_rows();
        let (a, b, c) = (rows[0].1, rows[1].1, rows[2].1);
        // cap hurts GA; TeraPipe split recovers most of it
        assert!(b > a, "cap must slow GA: {a} vs {b}");
        assert!(c < b, "token split must beat capped GA: {c} vs {b}");
    }

    #[test]
    fn render_fig5_contains_paper_columns() {
        let rows = vec![fig5_row(5, &fast_opts())];
        let s = render_fig5(&rows);
        assert!(s.contains("w/o TeraPipe"));
        assert!(s.contains("speedup"));
    }
}
