//! Per-stage health: liveness + latency state machines over the
//! telemetry the driver already sees.
//!
//! Two independent evidence tracks feed one three-state machine per
//! stage:
//!
//! * **Liveness** — the trainer calls [`HealthMonitor::on_arrival`] for
//!   every `DriverMsg` (including heartbeats) and
//!   [`HealthMonitor::probe_tick`] on a fixed sub-interval of its recv
//!   deadline. A stage silent across a whole probe interval collects a
//!   *miss*; consecutive misses escalate Healthy → Suspect → Unhealthy.
//!   Any arrival clears the track and (absent a `Fatal`) recovers the
//!   stage.
//! * **Latency** — per-step mean slice time per stage is compared
//!   against an EWMA baseline frozen on anomalous samples; a step mean
//!   above `latency_factor ×` baseline (after warmup) is a latency
//!   miss, escalating through the same thresholds.
//!
//! A worker `Fatal` pins the stage Unhealthy permanently (no half-open
//! recovery: the thread is gone). Every transition is appended to a
//! [`HealthTimeline`] — the artifact the flight recorder dumps and the
//! future circuit-breaker/re-partition PR subscribes to — and the
//! current states render as `terapipe_stage_health` gauges via
//! [`health_metrics`].

use super::metrics::MetricsRegistry;
use super::SpanKind;
use crate::util::json::Json;

/// Per-stage verdict. Codes are part of the span/JSON schema
/// ([`SpanKind::HealthVerdict`]'s `a` payload) — append, never renumber.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum HealthState {
    Healthy,
    Suspect,
    Unhealthy,
}

impl HealthState {
    pub fn code(self) -> u8 {
        match self {
            HealthState::Healthy => 0,
            HealthState::Suspect => 1,
            HealthState::Unhealthy => 2,
        }
    }

    pub fn from_code(c: u8) -> Option<HealthState> {
        match c {
            0 => Some(HealthState::Healthy),
            1 => Some(HealthState::Suspect),
            2 => Some(HealthState::Unhealthy),
            _ => None,
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            HealthState::Healthy => "healthy",
            HealthState::Suspect => "suspect",
            HealthState::Unhealthy => "unhealthy",
        }
    }
}

/// Why a transition happened (the `b` payload of a `HealthVerdict`
/// span; same append-only contract as the state codes).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum HealthReason {
    /// Consecutive probe intervals with no message from the stage.
    Miss,
    /// Step mean slice time blew past the EWMA baseline.
    Latency,
    /// The worker reported `DriverMsg::Fatal` (or its thread panicked).
    Fatal,
    /// Evidence cleared: a message arrived / latency returned to
    /// baseline.
    Recovered,
}

impl HealthReason {
    pub fn code(self) -> u8 {
        match self {
            HealthReason::Miss => 0,
            HealthReason::Latency => 1,
            HealthReason::Fatal => 2,
            HealthReason::Recovered => 3,
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            HealthReason::Miss => "miss",
            HealthReason::Latency => "latency",
            HealthReason::Fatal => "fatal",
            HealthReason::Recovered => "recovered",
        }
    }
}

/// Thresholds for both evidence tracks.
#[derive(Debug, Clone, Copy)]
pub struct HealthConfig {
    /// Consecutive misses (either track) before Healthy → Suspect.
    pub suspect_after: u32,
    /// Consecutive misses before → Unhealthy.
    pub unhealthy_after: u32,
    /// Step mean above `latency_factor × ewma` counts as a latency miss.
    pub latency_factor: f64,
    /// EWMA smoothing for the per-stage slice-time baseline.
    pub ewma_alpha: f64,
    /// Clean steps absorbed into the baseline before latency verdicts.
    pub warmup_samples: u32,
}

impl Default for HealthConfig {
    fn default() -> HealthConfig {
        HealthConfig {
            suspect_after: 2,
            unhealthy_after: 3,
            latency_factor: 3.0,
            ewma_alpha: 0.2,
            warmup_samples: 5,
        }
    }
}

/// One recorded state change.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct HealthTransition {
    pub step: u64,
    pub stage: usize,
    pub from: HealthState,
    pub to: HealthState,
    pub reason: HealthReason,
}

impl HealthTransition {
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("step", Json::Num(self.step as f64)),
            ("stage", Json::Num(self.stage as f64)),
            ("from", Json::Str(self.from.name().into())),
            ("to", Json::Str(self.to.name().into())),
            ("reason", Json::Str(self.reason.name().into())),
        ])
    }
}

/// Append-only record of every per-stage state change — what the
/// flight recorder dumps as `health.json` and what a circuit breaker
/// would subscribe to.
#[derive(Debug, Clone, Default)]
pub struct HealthTimeline {
    pub entries: Vec<HealthTransition>,
}

impl HealthTimeline {
    pub fn to_json(&self) -> Json {
        Json::Arr(self.entries.iter().map(|t| t.to_json()).collect())
    }

    /// Transitions touching one stage (tests, postmortem rendering).
    pub fn for_stage(&self, stage: usize) -> Vec<&HealthTransition> {
        self.entries.iter().filter(|t| t.stage == stage).collect()
    }
}

#[derive(Debug, Clone)]
struct StageHealth {
    state: HealthState,
    fatal: bool,
    live_misses: u32,
    lat_misses: u32,
    seen_since_probe: bool,
    ewma_ms: f64,
    ewma_n: u32,
    step_sum_ms: f64,
    step_n: u64,
}

impl StageHealth {
    fn new() -> StageHealth {
        StageHealth {
            state: HealthState::Healthy,
            fatal: false,
            live_misses: 0,
            lat_misses: 0,
            seen_since_probe: true,
            ewma_ms: 0.0,
            ewma_n: 0,
            step_sum_ms: 0.0,
            step_n: 0,
        }
    }
}

/// The per-stage health state machines plus their shared timeline.
#[derive(Debug, Clone)]
pub struct HealthMonitor {
    cfg: HealthConfig,
    step: u64,
    stages: Vec<StageHealth>,
    timeline: HealthTimeline,
}

impl HealthMonitor {
    pub fn new(num_stages: usize) -> HealthMonitor {
        HealthMonitor::with_config(num_stages, HealthConfig::default())
    }

    pub fn with_config(num_stages: usize, cfg: HealthConfig) -> HealthMonitor {
        HealthMonitor {
            cfg,
            step: 0,
            stages: (0..num_stages).map(|_| StageHealth::new()).collect(),
            timeline: HealthTimeline::default(),
        }
    }

    pub fn num_stages(&self) -> usize {
        self.stages.len()
    }

    /// Attribute subsequent transitions to `step`.
    pub fn begin_step(&mut self, step: u64) {
        self.step = step;
    }

    fn transition(&mut self, stage: usize, to: HealthState, reason: HealthReason) {
        let from = self.stages[stage].state;
        if from == to {
            return;
        }
        self.stages[stage].state = to;
        self.timeline.entries.push(HealthTransition {
            step: self.step,
            stage,
            from,
            to,
            reason,
        });
        super::instant(
            SpanKind::HealthVerdict,
            stage as i32,
            to.code() as u64,
            reason.code() as u64,
        );
    }

    fn escalate(&mut self, stage: usize, misses: u32, reason: HealthReason) {
        let s = &self.stages[stage];
        if s.fatal {
            return;
        }
        let target = if misses >= self.cfg.unhealthy_after {
            HealthState::Unhealthy
        } else if misses >= self.cfg.suspect_after {
            HealthState::Suspect
        } else {
            return;
        };
        // never downgrade a verdict reached through the other track
        if target > s.state {
            self.transition(stage, target, reason);
        }
    }

    fn maybe_recover(&mut self, stage: usize) {
        let s = &self.stages[stage];
        if s.fatal || s.state == HealthState::Healthy {
            return;
        }
        if s.live_misses < self.cfg.suspect_after && s.lat_misses < self.cfg.suspect_after {
            self.transition(stage, HealthState::Healthy, HealthReason::Recovered);
        }
    }

    /// Any `DriverMsg` (heartbeat included) arrived from `stage`.
    pub fn on_arrival(&mut self, stage: usize) {
        if stage >= self.stages.len() {
            return;
        }
        self.stages[stage].seen_since_probe = true;
        self.stages[stage].live_misses = 0;
        self.maybe_recover(stage);
    }

    /// One liveness probe interval elapsed: stages silent since the last
    /// tick collect a miss.
    pub fn probe_tick(&mut self) {
        for i in 0..self.stages.len() {
            if self.stages[i].seen_since_probe {
                self.stages[i].seen_since_probe = false;
                continue;
            }
            self.stages[i].live_misses += 1;
            let m = self.stages[i].live_misses;
            self.escalate(i, m, HealthReason::Miss);
        }
    }

    /// The worker for `stage` died (Fatal / panic). Pins Unhealthy.
    pub fn on_fatal(&mut self, stage: usize) {
        if stage >= self.stages.len() {
            return;
        }
        self.transition(stage, HealthState::Unhealthy, HealthReason::Fatal);
        self.stages[stage].fatal = true;
    }

    /// Feed one measured slice time (ms) into the step accumulator.
    pub fn observe_slice_ms(&mut self, stage: usize, ms: f64) {
        if stage >= self.stages.len() || !ms.is_finite() || ms < 0.0 {
            return;
        }
        self.stages[stage].step_sum_ms += ms;
        self.stages[stage].step_n += 1;
    }

    /// Close the step's latency track: compare each stage's step mean
    /// against its EWMA baseline, escalate or recover, then fold clean
    /// samples into the baseline (anomalous samples are *not* absorbed,
    /// so a persistent straggler keeps escalating instead of silently
    /// becoming the new normal).
    pub fn end_step(&mut self, step: u64) {
        self.step = step;
        for i in 0..self.stages.len() {
            let (sum, n) = (self.stages[i].step_sum_ms, self.stages[i].step_n);
            self.stages[i].step_sum_ms = 0.0;
            self.stages[i].step_n = 0;
            if n == 0 {
                continue;
            }
            let mean = sum / n as f64;
            let s = &self.stages[i];
            let warm = s.ewma_n >= self.cfg.warmup_samples;
            if warm && mean > self.cfg.latency_factor * s.ewma_ms && s.ewma_ms > 0.0 {
                self.stages[i].lat_misses += 1;
                let m = self.stages[i].lat_misses;
                self.escalate(i, m, HealthReason::Latency);
                continue; // baseline frozen on anomalous samples
            }
            let st = &mut self.stages[i];
            st.lat_misses = 0;
            st.ewma_ms = if st.ewma_n == 0 {
                mean
            } else {
                self.cfg.ewma_alpha * mean + (1.0 - self.cfg.ewma_alpha) * st.ewma_ms
            };
            st.ewma_n += 1;
            self.maybe_recover(i);
        }
    }

    pub fn state(&self, stage: usize) -> HealthState {
        self.stages[stage].state
    }

    pub fn states(&self) -> Vec<HealthState> {
        self.stages.iter().map(|s| s.state).collect()
    }

    /// Current states as schema codes (the `StepReport` carrier).
    pub fn codes(&self) -> Vec<u8> {
        self.stages.iter().map(|s| s.state.code()).collect()
    }

    pub fn ewma_ms(&self, stage: usize) -> f64 {
        self.stages[stage].ewma_ms
    }

    pub fn timeline(&self) -> &HealthTimeline {
        &self.timeline
    }
}

/// Render the monitor's current view as gauges: one
/// `terapipe_stage_health` per stage (0 healthy / 1 suspect /
/// 2 unhealthy) plus the EWMA slice-time baseline.
pub fn health_metrics(reg: &mut MetricsRegistry, hm: &HealthMonitor) {
    for s in 0..hm.num_stages() {
        let stage = s.to_string();
        let labels: [(&str, &str); 1] = [("stage", stage.as_str())];
        reg.gauge(
            "terapipe_stage_health",
            "Stage health state (0 healthy, 1 suspect, 2 unhealthy)",
            &labels,
            hm.state(s).code() as f64,
        );
        reg.gauge(
            "terapipe_stage_slice_ms_ewma",
            "EWMA baseline of per-stage mean slice time (ms)",
            &labels,
            hm.ewma_ms(s),
        );
    }
    reg.counter(
        "terapipe_health_transitions_total",
        "Health state transitions recorded",
        &[],
        hm.timeline().entries.len() as f64,
    );
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn consecutive_misses_escalate_and_arrival_recovers() {
        let mut hm = HealthMonitor::new(2);
        hm.on_arrival(0);
        hm.on_arrival(1);
        hm.probe_tick(); // clears seen flags
        hm.on_arrival(0);
        hm.probe_tick(); // stage 1 miss 1
        assert_eq!(hm.state(1), HealthState::Healthy);
        hm.on_arrival(0);
        hm.probe_tick(); // stage 1 miss 2 -> suspect
        assert_eq!(hm.state(1), HealthState::Suspect);
        assert_eq!(hm.state(0), HealthState::Healthy);
        hm.on_arrival(0);
        hm.probe_tick(); // stage 1 miss 3 -> unhealthy
        assert_eq!(hm.state(1), HealthState::Unhealthy);
        // the stage comes back: non-fatal unhealthy recovers
        hm.on_arrival(1);
        assert_eq!(hm.state(1), HealthState::Healthy);
        let t = hm.timeline();
        let stages: Vec<usize> = t.entries.iter().map(|e| e.stage).collect();
        assert_eq!(stages, vec![1, 1, 1]);
        assert_eq!(t.entries[0].to, HealthState::Suspect);
        assert_eq!(t.entries[1].to, HealthState::Unhealthy);
        assert_eq!(t.entries[2].reason, HealthReason::Recovered);
    }

    #[test]
    fn fatal_is_sticky() {
        let mut hm = HealthMonitor::new(1);
        hm.on_fatal(0);
        assert_eq!(hm.state(0), HealthState::Unhealthy);
        hm.on_arrival(0);
        hm.end_step(1);
        assert_eq!(hm.state(0), HealthState::Unhealthy, "fatal must not recover");
    }

    #[test]
    fn latency_track_escalates_after_warmup_and_freezes_baseline() {
        let cfg = HealthConfig { warmup_samples: 3, ..HealthConfig::default() };
        let mut hm = HealthMonitor::with_config(1, cfg);
        for step in 0..4u64 {
            hm.observe_slice_ms(0, 1.0);
            hm.end_step(step);
        }
        assert_eq!(hm.state(0), HealthState::Healthy);
        let base = hm.ewma_ms(0);
        assert!((base - 1.0).abs() < 1e-9);
        // 4x straggler: miss 1, miss 2 (suspect), miss 3 (unhealthy)
        for step in 4..7u64 {
            hm.observe_slice_ms(0, 4.0);
            hm.end_step(step);
        }
        assert_eq!(hm.state(0), HealthState::Unhealthy);
        assert!((hm.ewma_ms(0) - base).abs() < 1e-9, "anomalous steps must not move the baseline");
        // back to baseline: latency track clears and the stage recovers
        hm.observe_slice_ms(0, 1.0);
        hm.end_step(7);
        assert_eq!(hm.state(0), HealthState::Healthy);
    }

    #[test]
    fn timeline_json_round_trips_through_parser() {
        let mut hm = HealthMonitor::new(2);
        hm.begin_step(3);
        hm.on_fatal(1);
        let text = hm.timeline().to_json().to_string();
        let parsed = Json::parse(&text).unwrap();
        let arr = parsed.as_arr().unwrap();
        assert_eq!(arr.len(), 1);
        assert_eq!(arr[0].get("stage").unwrap().as_usize(), Some(1));
        assert_eq!(arr[0].get("to").unwrap().as_str(), Some("unhealthy"));
        assert_eq!(arr[0].get("reason").unwrap().as_str(), Some("fatal"));
        assert_eq!(arr[0].get("step").unwrap().as_usize(), Some(3));
    }

    #[test]
    fn gauges_expose_states() {
        let mut hm = HealthMonitor::new(2);
        hm.on_fatal(1);
        let mut reg = MetricsRegistry::new();
        health_metrics(&mut reg, &hm);
        assert_eq!(reg.get("terapipe_stage_health", &[("stage", "0")]), Some(0.0));
        assert_eq!(reg.get("terapipe_stage_health", &[("stage", "1")]), Some(2.0));
        assert_eq!(reg.get("terapipe_health_transitions_total", &[]), Some(1.0));
    }
}
