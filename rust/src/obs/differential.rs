//! Exec↔sim span differential: the §3.5 contract, localized.
//!
//! The wavefront simulator predicts, per (stage, slice), how long each
//! forward/backward work item takes; the recorder measures what actually
//! happened. This module aligns the two streams into per-cell relative
//! error so a contract miss *names the worst-offending (stage, slice)*
//! instead of failing on an aggregate makespan number — and computes a
//! measured counterpart to the simulator's `bubble_fraction` from real
//! spans.
//!
//! Alignment is per-occurrence-mean: for each (stage, slice) cell the
//! executed time is mean(slice_fwd durations) + mean(slice_bwd
//! durations) over every microbatch and step that touched the cell, and
//! the predicted time is the same statistic over the wavefront's spans.
//! Means (not sums) make the comparison invariant to how many steps or
//! microbatches each stream covers. Measurement probes
//! ([`super::MB_PROBE`]) and driver-side spans are excluded.

use std::collections::BTreeMap;

use super::{SpanKind, SpanRecord, MB_PROBE};
use crate::sim::trace::Span;
use crate::sim::Phase;

/// One aligned (stage, slice) cell.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Cell {
    pub stage: usize,
    pub slice: usize,
    /// Mean executed fwd+bwd time per occurrence (ms).
    pub exec_ms: f64,
    /// Mean predicted fwd+bwd time per occurrence (ms).
    pub pred_ms: f64,
    /// `|exec - pred| / pred` (0 when both sides are 0).
    pub rel_err: f64,
}

/// The aligned exec↔sim timeline.
#[derive(Debug, Clone, Default)]
pub struct Differential {
    /// One entry per (stage, slice) present in either stream, ordered.
    pub cells: Vec<Cell>,
    /// Wall span of the executed slice-compute window (ms).
    pub exec_makespan_ms: f64,
    /// Predicted makespan (ms).
    pub pred_makespan_ms: f64,
}

/// (sum fwd, n fwd, sum bwd, n bwd) accumulator per cell.
type Acc = (f64, u64, f64, u64);

fn add(acc: &mut Acc, is_fwd: bool, ms: f64) {
    if is_fwd {
        acc.0 += ms;
        acc.1 += 1;
    } else {
        acc.2 += ms;
        acc.3 += 1;
    }
}

fn mean_total(acc: &Acc) -> f64 {
    let f = if acc.1 > 0 { acc.0 / acc.1 as f64 } else { 0.0 };
    let b = if acc.3 > 0 { acc.2 / acc.3 as f64 } else { 0.0 };
    f + b
}

fn rel_err(exec: f64, pred: f64) -> f64 {
    if pred > 0.0 {
        (exec - pred).abs() / pred
    } else if exec > 0.0 {
        f64::INFINITY
    } else {
        0.0
    }
}

/// An exec-side span that participates in cell alignment: slice compute
/// on a real stage, not a measurement probe.
fn is_exec_cell_span(r: &SpanRecord) -> bool {
    matches!(r.kind, SpanKind::SliceFwd | SpanKind::SliceBwd) && r.stage >= 0 && r.mb != MB_PROBE
}

impl Differential {
    /// Align an executed span stream against wavefront-predicted spans.
    pub fn from_spans(exec: &[SpanRecord], pred: &[Span]) -> Differential {
        let mut table: BTreeMap<(usize, usize), (Acc, Acc)> = BTreeMap::new();
        let mut t_min = f64::INFINITY;
        let mut t_max = f64::NEG_INFINITY;
        for r in exec.iter().filter(|r| is_exec_cell_span(r)) {
            let e = table.entry((r.stage as usize, r.slice as usize)).or_default();
            add(&mut e.0, r.kind == SpanKind::SliceFwd, r.dur_ms());
            t_min = t_min.min(r.start_ms());
            t_max = t_max.max(r.start_ms() + r.dur_ms());
        }
        let mut pred_makespan = 0.0f64;
        for s in pred {
            let e = table.entry((s.stage, s.slice)).or_default();
            add(&mut e.1, s.phase == Phase::Fwd, s.end_ms - s.start_ms);
            pred_makespan = pred_makespan.max(s.end_ms);
        }
        let cells = table
            .into_iter()
            .map(|((stage, slice), (e, p))| {
                let exec_ms = mean_total(&e);
                let pred_ms = mean_total(&p);
                Cell { stage, slice, exec_ms, pred_ms, rel_err: rel_err(exec_ms, pred_ms) }
            })
            .collect();
        Differential {
            cells,
            exec_makespan_ms: if t_max > t_min { t_max - t_min } else { 0.0 },
            pred_makespan_ms: pred_makespan,
        }
    }

    /// Align pre-aggregated per-stage, per-slice times (row = stage).
    pub fn from_cells(exec: &[Vec<f64>], pred: &[Vec<f64>]) -> Differential {
        let mut cells = Vec::new();
        let stages = exec.len().max(pred.len());
        for stage in 0..stages {
            let er = exec.get(stage).map(|v| v.as_slice()).unwrap_or(&[]);
            let pr = pred.get(stage).map(|v| v.as_slice()).unwrap_or(&[]);
            for slice in 0..er.len().max(pr.len()) {
                let e = er.get(slice).copied().unwrap_or(0.0);
                let p = pr.get(slice).copied().unwrap_or(0.0);
                cells.push(Cell { stage, slice, exec_ms: e, pred_ms: p, rel_err: rel_err(e, p) });
            }
        }
        Differential {
            cells,
            exec_makespan_ms: exec.iter().map(|v| v.iter().sum::<f64>()).fold(0.0, f64::max),
            pred_makespan_ms: pred.iter().map(|v| v.iter().sum::<f64>()).fold(0.0, f64::max),
        }
    }

    /// The worst-offending cell by relative error.
    pub fn worst(&self) -> Option<&Cell> {
        self.cells
            .iter()
            .max_by(|a, b| a.rel_err.partial_cmp(&b.rel_err).unwrap_or(std::cmp::Ordering::Equal))
    }

    /// Mean per-cell relative error (cells with prediction coverage).
    pub fn mean_rel_err(&self) -> f64 {
        let finite: Vec<f64> =
            self.cells.iter().map(|c| c.rel_err).filter(|e| e.is_finite()).collect();
        if finite.is_empty() {
            0.0
        } else {
            finite.iter().sum::<f64>() / finite.len() as f64
        }
    }

    /// Human-readable summary naming the worst cell first.
    pub fn report(&self) -> String {
        let mut out = String::new();
        match self.worst() {
            Some(w) => out.push_str(&format!(
                "worst cell: stage {} slice {} — exec {:.3} ms vs pred {:.3} ms (rel err {:.1}%)\n",
                w.stage,
                w.slice,
                w.exec_ms,
                w.pred_ms,
                w.rel_err * 100.0
            )),
            None => out.push_str("no aligned cells\n"),
        }
        out.push_str(&format!(
            "mean rel err {:.1}% over {} cells; makespan exec {:.3} ms vs pred {:.3} ms\n",
            self.mean_rel_err() * 100.0,
            self.cells.len(),
            self.exec_makespan_ms,
            self.pred_makespan_ms
        ));
        out
    }
}

/// Measured bubble fraction: `1 - Σ busy / (stages · window)` over the
/// executed slice-compute spans — the real-run counterpart to
/// [`crate::sim::SimResult::bubble_fraction`]. `None` without spans.
pub fn measured_bubble_fraction(spans: &[SpanRecord], stages: usize) -> Option<f64> {
    if stages == 0 {
        return None;
    }
    let mut busy = 0.0f64;
    let mut t_min = f64::INFINITY;
    let mut t_max = f64::NEG_INFINITY;
    let mut any = false;
    for r in spans.iter().filter(|r| is_exec_cell_span(r)) {
        any = true;
        busy += r.dur_ms();
        t_min = t_min.min(r.start_ms());
        t_max = t_max.max(r.start_ms() + r.dur_ms());
    }
    if !any || t_max <= t_min {
        return None;
    }
    Some((1.0 - busy / (stages as f64 * (t_max - t_min))).max(0.0))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn pred_span(stage: usize, slice: usize, phase: Phase, start: f64, dur: f64) -> Span {
        Span { stage, start_ms: start, end_ms: start + dur, phase, part: 0, slice }
    }

    fn exec_span(stage: i32, mb: u32, slice: u32, kind: SpanKind, start_us: u64, dur_us: u64) -> SpanRecord {
        SpanRecord { kind, stage, mb, slice, a: 0, b: 0, start_us, dur_us }
    }

    #[test]
    fn perfect_agreement_has_zero_error() {
        let pred = vec![
            pred_span(0, 0, Phase::Fwd, 0.0, 1.0),
            pred_span(0, 0, Phase::Bwd, 2.0, 2.0),
        ];
        let exec = vec![
            exec_span(0, 0, 0, SpanKind::SliceFwd, 0, 1000),
            exec_span(0, 0, 0, SpanKind::SliceBwd, 2000, 2000),
        ];
        let d = Differential::from_spans(&exec, &pred);
        assert_eq!(d.cells.len(), 1);
        assert!(d.cells[0].rel_err < 1e-9);
        assert!((d.exec_makespan_ms - 4.0).abs() < 1e-9);
    }

    #[test]
    fn means_are_occurrence_invariant() {
        // exec covers 3 steps of the same cell; pred covers 1 step.
        let pred = vec![pred_span(1, 2, Phase::Fwd, 0.0, 1.0)];
        let exec: Vec<SpanRecord> = (0..3)
            .map(|i| exec_span(1, 0, 2, SpanKind::SliceFwd, i * 10_000, 1000))
            .collect();
        let d = Differential::from_spans(&exec, &pred);
        assert_eq!(d.cells.len(), 1);
        assert!(d.cells[0].rel_err < 1e-9, "3x occurrences must not triple the cell time");
    }

    #[test]
    fn straggler_stage_is_worst_offender() {
        let mut pred = Vec::new();
        let mut exec = Vec::new();
        for stage in 0..4usize {
            for slice in 0..3u32 {
                let start = (stage as f64) + slice as f64 * 0.5;
                pred.push(pred_span(stage, slice as usize, Phase::Fwd, start, 1.0));
                // stage 2 runs 4x slower than predicted
                let dur_us = if stage == 2 { 4000 } else { 1000 };
                exec.push(exec_span(stage as i32, 0, slice, SpanKind::SliceFwd, (start * 1000.0) as u64, dur_us));
            }
        }
        let d = Differential::from_spans(&exec, &pred);
        let w = d.worst().unwrap();
        assert_eq!(w.stage, 2);
        assert!((w.rel_err - 3.0).abs() < 1e-9);
        assert!(d.report().contains("stage 2"));
    }

    #[test]
    fn probes_and_driver_spans_are_excluded() {
        let exec = vec![
            exec_span(super::super::DRIVER, 0, 0, SpanKind::SliceFwd, 0, 1000),
            exec_span(0, MB_PROBE, 0, SpanKind::SliceFwd, 0, 1000),
        ];
        let d = Differential::from_spans(&exec, &[]);
        assert!(d.cells.is_empty());
        assert_eq!(measured_bubble_fraction(&exec, 2), None);
    }

    #[test]
    fn bubble_fraction_counts_idle() {
        // 2 stages, window 4 ms, busy 1+1 ms -> bubble = 1 - 2/8 = 0.75
        let exec = vec![
            exec_span(0, 0, 0, SpanKind::SliceFwd, 0, 1000),
            exec_span(1, 0, 0, SpanKind::SliceFwd, 3000, 1000),
        ];
        let bf = measured_bubble_fraction(&exec, 2).unwrap();
        assert!((bf - 0.75).abs() < 1e-9);
    }

    #[test]
    fn from_cells_aligns_rows() {
        let d = Differential::from_cells(
            &[vec![1.0, 1.0], vec![4.0]],
            &[vec![1.0, 2.0], vec![1.0]],
        );
        assert_eq!(d.cells.len(), 3);
        let w = d.worst().unwrap();
        assert_eq!((w.stage, w.slice), (1, 0));
        assert!((w.rel_err - 3.0).abs() < 1e-9);
    }
}
