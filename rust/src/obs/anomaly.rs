//! Rolling anomaly attribution: robust per-(stage, slice, phase)
//! statistics over the `SliceTime` stream plus per-link delivery
//! delays, classified into *named* causes.
//!
//! Each sample stream keeps a bounded ring of recent values; a new
//! sample is anomalous when it clears **all three** guards against the
//! ring's robust statistics (median / MAD — immune to the occasional
//! prior outlier, unlike mean / stddev):
//!
//! 1. `x > median + k_mad · 1.4826 · MAD` — statistically surprising;
//! 2. `x > median · (1 + rel_floor)` — materially slower, not just a
//!    tight-distribution blip;
//! 3. `x > median + abs_floor_ms` — above timer noise.
//!
//! Anomalous samples are **not** absorbed into the window, so a
//! persistent straggler keeps firing instead of becoming the new
//! baseline (the drift detector handles legitimate regime changes).
//!
//! [`AnomalyDetector::end_step`] folds the step's per-slice flags into
//! per-stage verdicts and classifies:
//!
//! * ≥ [`AnomalyConfig::global_frac`] of observed stages slow →
//!   [`Cause::GlobalSlowdown`];
//! * otherwise each slow stage (majority of its observed slices
//!   anomalous) → [`Cause::ComputeStraggler`];
//! * each flagged link → [`Cause::CommDegradation`].
//!
//! Detections convert to typed [`crate::planner::events`] via
//! [`Detection::to_event`], so drift-replan reacts to named causes.

use std::collections::BTreeMap;

use crate::planner::events::{Event, EventKind};
use crate::util::json::Json;

/// Detector thresholds. Defaults are deliberately conservative: a 2×
/// blip on one slice stays quiet; the ISSUE's planted 4× straggler and
/// 10 ms link delay clear every guard within one window.
#[derive(Debug, Clone, Copy)]
pub struct AnomalyConfig {
    /// Ring capacity per sample stream.
    pub window: usize,
    /// Minimum ring fill before verdicts are issued.
    pub min_fill: usize,
    /// MAD multiplier (guard 1), in normalized-MAD units.
    pub k_mad: f64,
    /// Relative floor (guard 2): sample must exceed `median · (1+this)`.
    pub rel_floor: f64,
    /// Absolute floor (guard 3), ms above the median.
    pub abs_floor_ms: f64,
    /// Fraction of observed stages slow at once ⇒ global slowdown.
    pub global_frac: f64,
}

impl Default for AnomalyConfig {
    fn default() -> AnomalyConfig {
        AnomalyConfig {
            window: 64,
            min_fill: 12,
            k_mad: 4.0,
            rel_floor: 0.75,
            abs_floor_ms: 0.25,
            global_frac: 2.0 / 3.0,
        }
    }
}

/// What the detector decided a detection *is*.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Cause {
    /// One stage's compute is slow; `factor` = observed / median.
    ComputeStraggler { stage: usize, factor: f64 },
    /// One link's delivery delay is inflated; `link` is the dense
    /// [`crate::coordinator::transport::LinkId::index`].
    CommDegradation { link: usize, factor: f64 },
    /// Most stages slowed together (thermal, co-tenant, ...).
    GlobalSlowdown { factor: f64 },
}

impl Cause {
    /// Schema code (the `a` payload of an `Anomaly` span).
    pub fn code(self) -> u8 {
        match self {
            Cause::ComputeStraggler { .. } => 0,
            Cause::CommDegradation { .. } => 1,
            Cause::GlobalSlowdown { .. } => 2,
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            Cause::ComputeStraggler { .. } => "compute_straggler",
            Cause::CommDegradation { .. } => "comm_degradation",
            Cause::GlobalSlowdown { .. } => "global_slowdown",
        }
    }

    pub fn factor(self) -> f64 {
        match self {
            Cause::ComputeStraggler { factor, .. }
            | Cause::CommDegradation { factor, .. }
            | Cause::GlobalSlowdown { factor } => factor,
        }
    }
}

/// One classified detection.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Detection {
    pub step: u64,
    pub cause: Cause,
}

impl Detection {
    /// The typed planner event this detection names.
    pub fn to_event(&self) -> Event {
        let kind = match self.cause {
            Cause::ComputeStraggler { stage, factor } => {
                EventKind::Straggler { stage: stage as u32, factor }
            }
            Cause::CommDegradation { link, factor } => {
                EventKind::LinkDegraded { link: link as u32, factor }
            }
            Cause::GlobalSlowdown { factor } => EventKind::Slowdown(factor),
        };
        Event { step: self.step, kind }
    }

    pub fn to_json(&self) -> Json {
        let mut fields = vec![
            ("step", Json::Num(self.step as f64)),
            ("cause", Json::Str(self.cause.name().into())),
            ("factor", Json::Num(self.cause.factor())),
        ];
        match self.cause {
            Cause::ComputeStraggler { stage, .. } => {
                fields.push(("stage", Json::Num(stage as f64)));
            }
            Cause::CommDegradation { link, .. } => {
                fields.push(("link", Json::Num(link as f64)));
            }
            Cause::GlobalSlowdown { .. } => {}
        }
        Json::obj(fields)
    }
}

/// Fixed-capacity ring with reusable sort scratch.
#[derive(Debug, Clone)]
struct RollingWindow {
    buf: Vec<f64>,
    pos: usize,
    cap: usize,
}

impl RollingWindow {
    fn new(cap: usize) -> RollingWindow {
        RollingWindow { buf: Vec::with_capacity(cap), pos: 0, cap: cap.max(4) }
    }

    fn push(&mut self, x: f64) {
        if self.buf.len() < self.cap {
            self.buf.push(x);
        } else {
            self.buf[self.pos] = x;
            self.pos = (self.pos + 1) % self.cap;
        }
    }

    fn len(&self) -> usize {
        self.buf.len()
    }

    /// (median, normalized MAD) over the current contents.
    fn robust_stats(&self, scratch: &mut Vec<f64>) -> (f64, f64) {
        scratch.clear();
        scratch.extend_from_slice(&self.buf);
        scratch.sort_by(f64::total_cmp);
        let med = median_sorted(scratch);
        for v in scratch.iter_mut() {
            *v = (*v - med).abs();
        }
        scratch.sort_by(f64::total_cmp);
        let mad = median_sorted(scratch);
        (med, 1.4826 * mad)
    }
}

fn median_sorted(v: &[f64]) -> f64 {
    if v.is_empty() {
        return 0.0;
    }
    let n = v.len();
    if n % 2 == 1 {
        v[n / 2]
    } else {
        0.5 * (v[n / 2 - 1] + v[n / 2])
    }
}

/// Per-stage flags accumulated within one step.
#[derive(Debug, Clone, Copy, Default)]
struct StageStep {
    observed: u32,
    anomalous: u32,
    factor_sum: f64,
}

/// The rolling detector: one window per (stage, slice, phase) compute
/// stream and one per transport link.
#[derive(Debug, Clone)]
pub struct AnomalyDetector {
    cfg: AnomalyConfig,
    compute: BTreeMap<(usize, u32, u8), RollingWindow>,
    links: BTreeMap<usize, RollingWindow>,
    stage_step: BTreeMap<usize, StageStep>,
    link_step: BTreeMap<usize, (u32, f64)>,
    scratch: Vec<f64>,
}

impl Default for AnomalyDetector {
    fn default() -> AnomalyDetector {
        AnomalyDetector::new()
    }
}

impl AnomalyDetector {
    pub fn new() -> AnomalyDetector {
        AnomalyDetector::with_config(AnomalyConfig::default())
    }

    pub fn with_config(cfg: AnomalyConfig) -> AnomalyDetector {
        AnomalyDetector {
            cfg,
            compute: BTreeMap::new(),
            links: BTreeMap::new(),
            stage_step: BTreeMap::new(),
            link_step: BTreeMap::new(),
            scratch: Vec::with_capacity(cfg.window),
        }
    }

    /// Triple-guard verdict against one window; returns the anomaly
    /// factor (`x / median`) when flagged. Clean samples join the
    /// window, flagged ones do not.
    fn check(cfg: &AnomalyConfig, scratch: &mut Vec<f64>, w: &mut RollingWindow, x: f64) -> Option<f64> {
        if !x.is_finite() || x < 0.0 {
            return None;
        }
        if w.len() < cfg.min_fill {
            w.push(x);
            return None;
        }
        let (med, nmad) = w.robust_stats(scratch);
        let surprising = x > med + cfg.k_mad * nmad;
        let material = x > med * (1.0 + cfg.rel_floor);
        let above_noise = x > med + cfg.abs_floor_ms;
        if surprising && material && above_noise {
            Some(x / med.max(cfg.abs_floor_ms))
        } else {
            w.push(x);
            None
        }
    }

    /// Feed one measured slice time. `phase`: 0 = fwd, 1 = bwd.
    pub fn observe_slice(&mut self, stage: usize, slice: u32, phase: u8, ms: f64) {
        let cap = self.cfg.window;
        let w = self
            .compute
            .entry((stage, slice, phase))
            .or_insert_with(|| RollingWindow::new(cap));
        let flagged = Self::check(&self.cfg, &mut self.scratch, w, ms);
        let s = self.stage_step.entry(stage).or_default();
        s.observed += 1;
        if let Some(f) = flagged {
            s.anomalous += 1;
            s.factor_sum += f;
        }
    }

    /// Feed one link delivery delay (`link` = dense `LinkId::index`).
    pub fn observe_link(&mut self, link: usize, delay_ms: f64) {
        let cap = self.cfg.window;
        let w = self.links.entry(link).or_insert_with(|| RollingWindow::new(cap));
        if let Some(f) = Self::check(&self.cfg, &mut self.scratch, w, delay_ms) {
            let e = self.link_step.entry(link).or_insert((0, 0.0));
            e.0 += 1;
            e.1 += f;
        } else {
            self.link_step.entry(link).or_insert((0, 0.0));
        }
    }

    /// Close the step: fold per-slice flags into per-stage verdicts,
    /// classify, and reset the step accumulators.
    pub fn end_step(&mut self, step: u64) -> Vec<Detection> {
        let mut out = Vec::new();
        // a stage is "slow" when a majority of its observed slices
        // flagged this step — one noisy slice is not a straggler
        let mut slow: Vec<(usize, f64)> = Vec::new();
        let mut observed_stages = 0usize;
        for (&stage, s) in &self.stage_step {
            if s.observed == 0 {
                continue;
            }
            observed_stages += 1;
            if s.anomalous * 2 >= s.observed && s.anomalous > 0 {
                slow.push((stage, s.factor_sum / s.anomalous as f64));
            }
        }
        if observed_stages > 0
            && slow.len() >= 2
            && slow.len() as f64 >= self.cfg.global_frac * observed_stages as f64
        {
            let factor = slow.iter().map(|(_, f)| f).sum::<f64>() / slow.len() as f64;
            out.push(Detection { step, cause: Cause::GlobalSlowdown { factor } });
        } else {
            for (stage, factor) in slow {
                out.push(Detection { step, cause: Cause::ComputeStraggler { stage, factor } });
            }
        }
        for (&link, &(n, fsum)) in &self.link_step {
            if n > 0 {
                out.push(Detection {
                    step,
                    cause: Cause::CommDegradation { link, factor: fsum / n as f64 },
                });
            }
        }
        self.stage_step.clear();
        self.link_step.clear();
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn feed_baseline(det: &mut AnomalyDetector, stages: usize, slices: u32, steps: u64, ms: f64) {
        for step in 0..steps {
            for stage in 0..stages {
                for slice in 0..slices {
                    det.observe_slice(stage, slice, 0, ms);
                }
            }
            assert!(det.end_step(step).is_empty(), "baseline must not trigger");
        }
    }

    #[test]
    fn stationary_stream_stays_quiet() {
        let mut det = AnomalyDetector::new();
        // deterministic small jitter around 1 ms
        for step in 0..40u64 {
            for stage in 0..4usize {
                for slice in 0..4u32 {
                    let jitter = ((step + stage as u64 + slice as u64) % 7) as f64 * 0.01;
                    det.observe_slice(stage, slice, 0, 1.0 + jitter);
                }
            }
            assert!(det.end_step(step).is_empty(), "stationary stream must not trigger (step {step})");
        }
    }

    #[test]
    fn planted_4x_straggler_is_named() {
        let mut det = AnomalyDetector::new();
        feed_baseline(&mut det, 4, 4, 20, 1.0);
        // stage 2 goes 4x slow on every slice
        for stage in 0..4usize {
            for slice in 0..4u32 {
                let ms = if stage == 2 { 4.0 } else { 1.0 };
                det.observe_slice(stage, slice, 0, ms);
            }
        }
        let det_out = det.end_step(20);
        assert_eq!(det_out.len(), 1);
        match det_out[0].cause {
            Cause::ComputeStraggler { stage, factor } => {
                assert_eq!(stage, 2);
                assert!((factor - 4.0).abs() < 0.5, "factor {factor} should be ~4");
            }
            other => panic!("expected straggler, got {other:?}"),
        }
    }

    #[test]
    fn planted_link_delay_is_comm_degradation() {
        let mut det = AnomalyDetector::new();
        for step in 0..5u64 {
            for _ in 0..4 {
                det.observe_link(3, 0.1);
                det.observe_link(4, 0.1);
            }
            assert!(det.end_step(step).is_empty());
        }
        // link 3 delivery delay jumps to 10 ms
        det.observe_link(3, 10.0);
        det.observe_link(4, 0.1);
        let out = det.end_step(5);
        assert_eq!(out.len(), 1);
        match out[0].cause {
            Cause::CommDegradation { link, factor } => {
                assert_eq!(link, 3);
                assert!(factor > 10.0, "10ms over a 0.1ms median, factor {factor}");
            }
            other => panic!("expected comm degradation, got {other:?}"),
        }
    }

    #[test]
    fn correlated_slowdown_is_global() {
        let mut det = AnomalyDetector::new();
        feed_baseline(&mut det, 4, 4, 20, 1.0);
        for stage in 0..4usize {
            for slice in 0..4u32 {
                det.observe_slice(stage, slice, 0, 3.0);
            }
        }
        let out = det.end_step(20);
        assert_eq!(out.len(), 1);
        assert!(matches!(out[0].cause, Cause::GlobalSlowdown { .. }), "got {:?}", out[0].cause);
    }

    #[test]
    fn anomalies_do_not_poison_the_window() {
        let mut det = AnomalyDetector::new();
        feed_baseline(&mut det, 1, 1, 20, 1.0);
        // a persistent 4x straggler keeps firing every step
        for step in 20..30u64 {
            det.observe_slice(0, 0, 0, 4.0);
            let out = det.end_step(step);
            assert_eq!(out.len(), 1, "step {step}: straggler must keep firing");
        }
    }

    #[test]
    fn detections_map_to_typed_events() {
        let d = Detection { step: 7, cause: Cause::ComputeStraggler { stage: 2, factor: 4.0 } };
        let ev = d.to_event();
        assert_eq!(ev.step, 7);
        assert!(matches!(ev.kind, EventKind::Straggler { stage: 2, factor } if (factor - 4.0).abs() < 1e-12));
        let d = Detection { step: 8, cause: Cause::CommDegradation { link: 3, factor: 10.0 } };
        assert!(matches!(d.to_event().kind, EventKind::LinkDegraded { link: 3, .. }));
        let d = Detection { step: 9, cause: Cause::GlobalSlowdown { factor: 2.0 } };
        assert!(matches!(d.to_event().kind, EventKind::Slowdown(f) if (f - 2.0).abs() < 1e-12));
        // JSON rendering names the cause
        let j = d.to_json().to_string();
        assert!(j.contains("global_slowdown"));
    }
}
