//! Black-box flight recorder: a bounded ring of the last N steps'
//! telemetry, dumped as a postmortem bundle when a run dies (or on
//! demand).
//!
//! Each [`StepFrame`] holds that step's span flush, loss/wall numbers,
//! per-stage health codes, and per-link traffic snapshots. All frame
//! storage is pre-allocated at construction and reused in place
//! (`clear()` + `extend_from_slice`), so once the ring has filled and
//! per-step volumes have stabilized, [`FlightRecorder::record_step`]
//! performs **zero heap allocations** — the same counting-allocator
//! contract `benches/exec.rs` pins for the kernels and the span
//! recorder (gated in `BENCH_obs.json`).
//!
//! [`FlightRecorder::dump`] writes the bundle:
//!
//! * `trace.json`    — Perfetto trace of every retained span (plus the
//!   predicted sim track when available);
//! * `metrics.prom`  — the caller's rendered metrics snapshot;
//! * `health.json`   — reason, plan fingerprint, final per-stage
//!   states, and the full [`HealthTimeline`];
//! * `report.txt`    — human-readable postmortem: per-step table and
//!   the exec↔sim differential;
//! * `manifest.json` — what's in the bundle.

use std::fmt::Write as _;
use std::path::Path;

use super::differential::Differential;
use super::export::{perfetto_trace, TraceBundle};
use super::health::{HealthState, HealthTimeline};
use super::SpanRecord;
use crate::sim::trace::Span;
use crate::util::json::Json;

/// One link's cumulative traffic counters at step end (`Copy` — the
/// ring stores these by value).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct LinkSnap {
    /// Dense [`crate::coordinator::transport::LinkId::index`].
    pub link: u32,
    pub sent: u64,
    pub dropped: u64,
    pub bytes: u64,
    pub mean_delay_ms: f64,
}

/// One retained step.
#[derive(Debug, Clone, Default)]
pub struct StepFrame {
    pub step: u64,
    pub loss: f64,
    pub wall_ms: f64,
    /// The step's merged span flush.
    pub spans: Vec<SpanRecord>,
    /// Spans lost to recorder overflow during the step.
    pub dropped: u64,
    /// Per-stage [`HealthState`] codes at step end.
    pub health: Vec<u8>,
    pub links: Vec<LinkSnap>,
    used: bool,
}

/// Everything the bundle needs that the ring itself doesn't carry.
pub struct DumpContext<'a> {
    /// Why the bundle exists ("worker fatal: ...", "on demand", ...).
    pub reason: &'a str,
    /// The active slicing plan.
    pub slicing: &'a [usize],
    pub stages: usize,
    /// Pre-rendered Prometheus text (written verbatim).
    pub metrics_text: &'a str,
    pub timeline: &'a HealthTimeline,
    /// Per-stage health codes at dump time.
    pub final_health: &'a [u8],
    /// Wavefront-predicted spans for the active plan (may be empty).
    pub predicted: &'a [Span],
}

/// The bounded ring.
#[derive(Debug, Clone)]
pub struct FlightRecorder {
    frames: Vec<StepFrame>,
    next: usize,
    recorded: u64,
    fingerprint: u64,
}

/// FNV-1a fingerprint of the active plan (+ arbitrary salt words, e.g.
/// a cost-model tag) — cheap identity for "which plan was flying".
pub fn plan_fingerprint(slicing: &[usize], salt: &[u64]) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    let mut mix = |x: u64| {
        for i in 0..8 {
            h ^= (x >> (8 * i)) & 0xff;
            h = h.wrapping_mul(0x0000_0100_0000_01b3);
        }
    };
    mix(slicing.len() as u64);
    for &s in slicing {
        mix(s as u64);
    }
    for &s in salt {
        mix(s);
    }
    h
}

impl FlightRecorder {
    /// A ring retaining the last `cap` steps (min 1). All frame slots
    /// are pre-allocated; per-slot buffers grow on first use and are
    /// reused thereafter.
    pub fn new(cap: usize) -> FlightRecorder {
        let cap = cap.max(1);
        FlightRecorder {
            frames: (0..cap).map(|_| StepFrame::default()).collect(),
            next: 0,
            recorded: 0,
            fingerprint: 0,
        }
    }

    pub fn capacity(&self) -> usize {
        self.frames.len()
    }

    /// Frames currently retained.
    pub fn len(&self) -> usize {
        (self.recorded as usize).min(self.frames.len())
    }

    pub fn is_empty(&self) -> bool {
        self.recorded == 0
    }

    /// Stamp the active plan/cost-model fingerprint
    /// (see [`plan_fingerprint`]).
    pub fn set_fingerprint(&mut self, fp: u64) {
        self.fingerprint = fp;
    }

    pub fn fingerprint(&self) -> u64 {
        self.fingerprint
    }

    /// Record one step, overwriting the oldest frame in place.
    pub fn record_step(
        &mut self,
        step: u64,
        loss: f64,
        wall_ms: f64,
        spans: &[SpanRecord],
        dropped: u64,
        health: &[u8],
        links: &[LinkSnap],
    ) {
        let f = &mut self.frames[self.next];
        f.step = step;
        f.loss = loss;
        f.wall_ms = wall_ms;
        f.dropped = dropped;
        f.spans.clear();
        f.spans.extend_from_slice(spans);
        f.health.clear();
        f.health.extend_from_slice(health);
        f.links.clear();
        f.links.extend_from_slice(links);
        f.used = true;
        self.next = (self.next + 1) % self.frames.len();
        self.recorded += 1;
    }

    /// Retained frames, oldest first.
    pub fn frames(&self) -> Vec<&StepFrame> {
        let cap = self.frames.len();
        let n = self.len();
        (0..n)
            .map(|i| &self.frames[(self.next + cap - n + i) % cap])
            .filter(|f| f.used)
            .collect()
    }

    /// Write the postmortem bundle into `dir` (created if missing).
    /// Returns the list of files written.
    pub fn dump(&self, dir: &Path, ctx: &DumpContext) -> Result<Vec<String>, String> {
        std::fs::create_dir_all(dir).map_err(|e| format!("create {}: {e}", dir.display()))?;
        let frames = self.frames();
        let mut written = Vec::new();
        let mut write = |name: &str, text: String| -> Result<(), String> {
            let p = dir.join(name);
            std::fs::write(&p, text).map_err(|e| format!("write {}: {e}", p.display()))?;
            written.push(name.to_string());
            Ok(())
        };

        // trace.json — every retained span, chronological across frames
        let mut exec: Vec<SpanRecord> = Vec::new();
        let mut dropped = 0u64;
        for f in &frames {
            exec.extend_from_slice(&f.spans);
            dropped += f.dropped;
        }
        let bundle = TraceBundle {
            exec,
            predicted: ctx.predicted.to_vec(),
            stages: ctx.stages,
            dropped,
        };
        write("trace.json", perfetto_trace(&bundle).to_string() + "\n")?;

        // metrics.prom
        write("metrics.prom", ctx.metrics_text.to_string())?;

        // health.json
        let fp = format!("{:016x}", self.fingerprint);
        let health_doc = Json::obj(vec![
            ("reason", Json::Str(ctx.reason.into())),
            ("plan_fingerprint", Json::Str(fp.clone())),
            (
                "slicing",
                Json::Arr(ctx.slicing.iter().map(|&s| Json::Num(s as f64)).collect()),
            ),
            (
                "final",
                Json::Arr(ctx.final_health.iter().map(|&c| Json::Num(c as f64)).collect()),
            ),
            ("timeline", ctx.timeline.to_json()),
        ]);
        write("health.json", health_doc.to_string() + "\n")?;

        // report.txt
        let mut rep = String::new();
        let _ = writeln!(rep, "terapipe postmortem");
        let _ = writeln!(rep, "reason: {}", ctx.reason);
        let _ = writeln!(rep, "plan fingerprint: {fp}");
        let _ = writeln!(rep, "slicing: {:?}", ctx.slicing);
        let _ = writeln!(rep, "retained steps: {} (ring capacity {})", frames.len(), self.capacity());
        let _ = writeln!(rep, "\n| step | loss | wall ms | spans | dropped | health |");
        for f in &frames {
            let health: Vec<&str> = f
                .health
                .iter()
                .map(|&c| HealthState::from_code(c).map(|s| s.name()).unwrap_or("?"))
                .collect();
            let _ = writeln!(
                rep,
                "| {} | {:.4} | {:.2} | {} | {} | {} |",
                f.step,
                f.loss,
                f.wall_ms,
                f.spans.len(),
                f.dropped,
                health.join(",")
            );
        }
        if !ctx.predicted.is_empty() {
            let d = Differential::from_spans(&bundle.exec, ctx.predicted);
            let _ = writeln!(rep, "\nexec<->sim differential over retained spans:");
            rep.push_str(&d.report());
        }
        if !ctx.timeline.entries.is_empty() {
            let _ = writeln!(rep, "\nhealth transitions:");
            for t in &ctx.timeline.entries {
                let _ = writeln!(
                    rep,
                    "  step {} stage {}: {} -> {} ({})",
                    t.step,
                    t.stage,
                    t.from.name(),
                    t.to.name(),
                    t.reason.name()
                );
            }
        }
        write("report.txt", rep)?;

        // manifest.json
        let manifest = Json::obj(vec![
            ("bundle", Json::Str("terapipe_postmortem".into())),
            ("reason", Json::Str(ctx.reason.into())),
            ("plan_fingerprint", Json::Str(fp)),
            ("steps_retained", Json::Num(frames.len() as f64)),
            (
                "files",
                Json::Arr(
                    ["trace.json", "metrics.prom", "health.json", "report.txt"]
                        .iter()
                        .map(|&f| Json::Str(f.into()))
                        .collect(),
                ),
            ),
        ]);
        write("manifest.json", manifest.to_string() + "\n")?;
        Ok(written)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::obs::SpanKind;
    use std::sync::atomic::{AtomicU64, Ordering};

    fn span(step: u64) -> SpanRecord {
        SpanRecord {
            kind: SpanKind::SliceFwd,
            stage: 0,
            mb: 0,
            slice: 0,
            a: 4,
            b: 0,
            start_us: step * 1000,
            dur_us: 500,
        }
    }

    #[test]
    fn ring_keeps_last_n_in_order() {
        let mut fr = FlightRecorder::new(3);
        assert!(fr.is_empty());
        for step in 0..5u64 {
            fr.record_step(step, step as f64, 1.0, &[span(step)], 0, &[0, 0], &[]);
        }
        let frames = fr.frames();
        let steps: Vec<u64> = frames.iter().map(|f| f.step).collect();
        assert_eq!(steps, vec![2, 3, 4]);
        assert_eq!(fr.len(), 3);
        assert_eq!(frames[0].spans.len(), 1);
    }

    #[test]
    fn fingerprint_is_stable_and_plan_sensitive() {
        let a = plan_fingerprint(&[16, 16, 32], &[7]);
        let b = plan_fingerprint(&[16, 16, 32], &[7]);
        let c = plan_fingerprint(&[16, 32, 16], &[7]);
        let d = plan_fingerprint(&[16, 16, 32], &[8]);
        assert_eq!(a, b);
        assert_ne!(a, c);
        assert_ne!(a, d);
    }

    static DUMP_SEQ: AtomicU64 = AtomicU64::new(0);

    #[test]
    fn dump_writes_a_parseable_bundle() {
        let mut fr = FlightRecorder::new(2);
        fr.set_fingerprint(plan_fingerprint(&[8, 8], &[]));
        for step in 0..3u64 {
            fr.record_step(step, 2.5, 1.0, &[span(step)], 1, &[0, 2], &[LinkSnap {
                link: 0,
                sent: 3,
                dropped: 0,
                bytes: 192,
                mean_delay_ms: 0.1,
            }]);
        }
        let mut timeline = HealthTimeline::default();
        timeline.entries.push(crate::obs::health::HealthTransition {
            step: 2,
            stage: 1,
            from: HealthState::Healthy,
            to: HealthState::Unhealthy,
            reason: crate::obs::health::HealthReason::Fatal,
        });
        let dir = std::env::temp_dir().join(format!(
            "terapipe_flight_test_{}_{}",
            std::process::id(),
            DUMP_SEQ.fetch_add(1, Ordering::SeqCst)
        ));
        let ctx = DumpContext {
            reason: "unit test",
            slicing: &[8, 8],
            stages: 2,
            metrics_text: "# HELP x y\n",
            timeline: &timeline,
            final_health: &[0, 2],
            predicted: &[],
        };
        let files = fr.dump(&dir, &ctx).unwrap();
        assert_eq!(files.len(), 5);
        // trace parses back as a Chrome trace document
        let trace = std::fs::read_to_string(dir.join("trace.json")).unwrap();
        let doc = Json::parse(&trace).unwrap();
        assert!(doc.get("traceEvents").unwrap().as_arr().unwrap().len() > 2);
        // health.json names the unhealthy stage
        let health = std::fs::read_to_string(dir.join("health.json")).unwrap();
        let doc = Json::parse(&health).unwrap();
        assert_eq!(doc.get("reason").unwrap().as_str(), Some("unit test"));
        let tl = doc.get("timeline").unwrap().as_arr().unwrap();
        assert_eq!(tl[0].get("stage").unwrap().as_usize(), Some(1));
        let report = std::fs::read_to_string(dir.join("report.txt")).unwrap();
        assert!(report.contains("stage 1: healthy -> unhealthy (fatal)"));
        std::fs::remove_dir_all(&dir).ok();
    }
}
