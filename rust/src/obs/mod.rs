//! Unified tracing & metrics: span-level exec↔sim attribution for the
//! whole measure→plan→execute loop.
//!
//! Everything the repo previously scattered over ad-hoc channels —
//! `SliceTime` samples in the worker, `LinkMetrics` in the virtual
//! transport, cache counters in the planner, printf diagnostics in
//! `terapipe autotune` — flows through one structured span stream:
//!
//! * [`recorder`] — a lock-free per-thread span recorder: fixed-capacity
//!   per-thread buffers claimed on first use, merged deterministically at
//!   flush, **zero steady-state heap allocations** on the hot path (the
//!   same counting-allocator discipline `benches/exec.rs` pins for the
//!   kernels; the `obs` bench section pins it with the recorder enabled).
//! * [`export`] — Chrome/Perfetto trace-event JSON (one track per stage,
//!   one per link, one per predicted sim stage; instant events for plan
//!   switches and drift verdicts) and a Prometheus-style text metrics
//!   snapshot ([`metrics::MetricsRegistry`]).
//! * [`differential`] — the payoff: the executed span stream and the
//!   wavefront's predicted [`crate::sim::trace::Span`]s converted into
//!   one aligned timeline with per-(stage, slice) relative error, so a
//!   §3.5 contract miss names the worst-offending cell instead of
//!   failing on an aggregate number, and `bubble_fraction` gets a
//!   measured counterpart computed from real spans.
//!
//! The global recorder is **off by default**: every emission site guards
//! on one relaxed atomic load, so untraced runs pay a few nanoseconds
//! per would-be span. `terapipe train --trace-out trace.json
//! --metrics-out metrics.prom` (and the same flags on `autotune`) turn
//! it on. See `rust/src/obs/README.md` for the span taxonomy, the
//! overhead budget, and how to open a trace in Perfetto.

pub mod anomaly;
pub mod differential;
pub mod export;
pub mod flight;
pub mod health;
pub mod metrics;
pub mod recorder;

pub use differential::Differential;
pub use metrics::MetricsRegistry;
pub use recorder::{Flush, Recorder};

use crate::util::json::Json;

/// Stage id recorded for driver/planner-side events (no stage thread).
pub const DRIVER: i32 = -1;

/// Microbatch sentinel for offline measurement probes (the
/// `backend::slice_timer` harness runs outside any training step).
pub const MB_PROBE: u32 = u32::MAX;

/// What a span covers. Codes are part of the on-disk schema
/// ([`SpanRecord::to_json`]) — append, never renumber.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum SpanKind {
    /// One slice's forward compute on one stage (embed + cell on the
    /// first stage, cell + head loss on the last). `a` = token offset,
    /// `b` = slice length.
    SliceFwd,
    /// One slice's backward compute (recompute included). Same payload.
    SliceBwd,
    /// Growing the per-microbatch KV context buffers after a slice's
    /// forward (the token-level pipeline's routing step). Same payload.
    KvRoute,
    /// A transport send (instant). `a` = approx wire bytes, `b` = dense
    /// link index ([`crate::coordinator::transport::LinkId::index`]).
    Send,
    /// A transport delivery (instant). Same payload.
    Recv,
    /// One stage's Adam update. `a` = global step.
    AdamUpdate,
    /// A cold DP solve in the planner. `a` = stages, `b` = trigger code.
    PlannerSolve,
    /// A warm-started re-solve. Same payload.
    PlannerWarmResolve,
    /// The cost-table cache served a solve without densifying (instant).
    PlannerCacheHit,
    /// A drift-window verdict (instant). `a` = 0 warmup / 1 stable /
    /// 2 drifted, `b` = `f64::to_bits(mean_rel_err)`.
    DriftVerdict,
    /// One simulator replay of a plan (validation). `a` = plans replayed.
    SimReplay,
    /// The active plan was replaced (instant). `a` = step when known.
    PlanSwitch,
    /// A stage's health state changed (instant). `stage` = the stage,
    /// `a` = new [`health::HealthState`] code, `b` =
    /// [`health::HealthReason`] code.
    HealthVerdict,
    /// The anomaly detector named a cause (instant). `stage` = the
    /// straggler stage ([`DRIVER`] for link/global causes), `a` =
    /// [`anomaly::Cause`] code, `b` = `f64::to_bits(factor)`.
    Anomaly,
}

impl SpanKind {
    pub const ALL: [SpanKind; 14] = [
        SpanKind::SliceFwd,
        SpanKind::SliceBwd,
        SpanKind::KvRoute,
        SpanKind::Send,
        SpanKind::Recv,
        SpanKind::AdamUpdate,
        SpanKind::PlannerSolve,
        SpanKind::PlannerWarmResolve,
        SpanKind::PlannerCacheHit,
        SpanKind::DriftVerdict,
        SpanKind::SimReplay,
        SpanKind::PlanSwitch,
        SpanKind::HealthVerdict,
        SpanKind::Anomaly,
    ];

    pub fn code(self) -> u8 {
        match self {
            SpanKind::SliceFwd => 0,
            SpanKind::SliceBwd => 1,
            SpanKind::KvRoute => 2,
            SpanKind::Send => 3,
            SpanKind::Recv => 4,
            SpanKind::AdamUpdate => 5,
            SpanKind::PlannerSolve => 6,
            SpanKind::PlannerWarmResolve => 7,
            SpanKind::PlannerCacheHit => 8,
            SpanKind::DriftVerdict => 9,
            SpanKind::SimReplay => 10,
            SpanKind::PlanSwitch => 11,
            SpanKind::HealthVerdict => 12,
            SpanKind::Anomaly => 13,
        }
    }

    pub fn from_code(c: u8) -> Option<SpanKind> {
        SpanKind::ALL.get(c as usize).copied()
    }

    pub fn name(self) -> &'static str {
        match self {
            SpanKind::SliceFwd => "slice_fwd",
            SpanKind::SliceBwd => "slice_bwd",
            SpanKind::KvRoute => "kv_route",
            SpanKind::Send => "send",
            SpanKind::Recv => "recv",
            SpanKind::AdamUpdate => "adam_update",
            SpanKind::PlannerSolve => "planner_solve",
            SpanKind::PlannerWarmResolve => "planner_warm_resolve",
            SpanKind::PlannerCacheHit => "planner_cache_hit",
            SpanKind::DriftVerdict => "drift_verdict",
            SpanKind::SimReplay => "sim_replay",
            SpanKind::PlanSwitch => "plan_switch",
            SpanKind::HealthVerdict => "health_verdict",
            SpanKind::Anomaly => "anomaly",
        }
    }

    pub fn from_name(n: &str) -> Option<SpanKind> {
        SpanKind::ALL.into_iter().find(|k| k.name() == n)
    }

    pub fn category(self) -> &'static str {
        match self {
            SpanKind::SliceFwd | SpanKind::SliceBwd | SpanKind::KvRoute | SpanKind::AdamUpdate => {
                "compute"
            }
            SpanKind::Send | SpanKind::Recv => "transport",
            SpanKind::PlannerSolve
            | SpanKind::PlannerWarmResolve
            | SpanKind::PlannerCacheHit
            | SpanKind::DriftVerdict
            | SpanKind::PlanSwitch => "planner",
            SpanKind::SimReplay => "sim",
            SpanKind::HealthVerdict | SpanKind::Anomaly => "health",
        }
    }

    /// Zero-duration point events (Perfetto `ph:"i"`).
    pub fn is_instant(self) -> bool {
        matches!(
            self,
            SpanKind::Send
                | SpanKind::Recv
                | SpanKind::PlannerCacheHit
                | SpanKind::DriftVerdict
                | SpanKind::PlanSwitch
                | SpanKind::HealthVerdict
                | SpanKind::Anomaly
        )
    }
}

/// One recorded span: fixed-size, `Copy`, no heap — the unit the
/// per-thread buffers store verbatim.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct SpanRecord {
    pub kind: SpanKind,
    /// Stage index, or [`DRIVER`] for driver/planner-side events.
    pub stage: i32,
    pub mb: u32,
    pub slice: u32,
    /// Kind-specific payload (see [`SpanKind`]).
    pub a: u64,
    pub b: u64,
    /// Microseconds since the process trace epoch ([`now_us`]).
    pub start_us: u64,
    /// Span duration in microseconds (0 for instants).
    pub dur_us: u64,
}

impl SpanRecord {
    pub fn start_ms(&self) -> f64 {
        self.start_us as f64 / 1e3
    }

    pub fn dur_ms(&self) -> f64 {
        self.dur_us as f64 / 1e3
    }

    /// Schema round-trip: the record as a JSON object.
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("kind", Json::Str(self.kind.name().into())),
            ("stage", Json::Num(self.stage as f64)),
            ("mb", Json::Num(self.mb as f64)),
            ("slice", Json::Num(self.slice as f64)),
            ("a", Json::Num(self.a as f64)),
            ("b", Json::Num(self.b as f64)),
            ("start_us", Json::Num(self.start_us as f64)),
            ("dur_us", Json::Num(self.dur_us as f64)),
        ])
    }

    /// Inverse of [`SpanRecord::to_json`]. `Err` names the missing or
    /// malformed field (payloads above 2^53 µs/bytes are out of scope —
    /// the JSON carrier is f64).
    pub fn from_json(v: &Json) -> Result<SpanRecord, String> {
        let kind_name = v.req("kind")?.as_str().ok_or("kind must be a string")?;
        let kind = SpanKind::from_name(kind_name)
            .ok_or_else(|| format!("unknown span kind '{kind_name}'"))?;
        let num = |key: &str| -> Result<f64, String> {
            v.req(key)?.as_f64().ok_or_else(|| format!("{key} must be a number"))
        };
        Ok(SpanRecord {
            kind,
            stage: num("stage")? as i32,
            mb: num("mb")? as u32,
            slice: num("slice")? as u32,
            a: num("a")? as u64,
            b: num("b")? as u64,
            start_us: num("start_us")? as u64,
            dur_us: num("dur_us")? as u64,
        })
    }
}

// ---- global recorder conveniences (the emission-site API) ----

/// Microseconds since the process trace epoch (first call wins).
pub fn now_us() -> u64 {
    recorder::now_us()
}

/// Is the global recorder collecting?
#[inline]
pub fn enabled() -> bool {
    recorder::global().is_enabled()
}

/// Turn the global recorder on/off (off by default).
pub fn set_enabled(on: bool) {
    recorder::global().set_enabled(on);
}

/// Record one span on the global recorder (no-op when disabled).
#[inline]
pub fn record(rec: SpanRecord) {
    recorder::global().record(rec);
}

/// Drain the global recorder (see [`Recorder::flush`] for the contract).
/// The first flush of the process that reports dropped spans emits a
/// one-time stderr warning (the count still lands in
/// `terapipe_obs_spans_dropped_total` every time).
pub fn flush() -> Flush {
    use std::sync::atomic::{AtomicBool, Ordering};
    static WARNED: AtomicBool = AtomicBool::new(false);
    let f = recorder::global().flush();
    if f.dropped > 0 && !WARNED.swap(true, Ordering::Relaxed) {
        eprintln!(
            "warning: span recorder overflowed — {} span(s) dropped this flush; \
             traces and span-derived metrics are incomplete \
             (per-thread buffer capacity exceeded; further drops counted silently)",
            f.dropped
        );
    }
    f
}

/// Start timestamp for a would-be span: `u64::MAX` when the recorder is
/// off, so the matching [`emit`] is a no-op. Keeps disabled-path cost to
/// one relaxed load.
#[inline]
pub fn maybe_start() -> u64 {
    if enabled() {
        now_us()
    } else {
        u64::MAX
    }
}

/// Close and record a span opened with [`maybe_start`].
#[inline]
pub fn emit(kind: SpanKind, stage: i32, mb: u32, slice: u32, a: u64, b: u64, start_us: u64) {
    if start_us != u64::MAX {
        record(SpanRecord {
            kind,
            stage,
            mb,
            slice,
            a,
            b,
            start_us,
            dur_us: now_us().saturating_sub(start_us),
        });
    }
}

/// Record an instant event (zero duration) on the global recorder.
#[inline]
pub fn instant(kind: SpanKind, stage: i32, a: u64, b: u64) {
    if enabled() {
        record(SpanRecord {
            kind,
            stage,
            mb: 0,
            slice: 0,
            a,
            b,
            start_us: now_us(),
            dur_us: 0,
        });
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn kind_codes_round_trip_and_are_dense() {
        for (i, k) in SpanKind::ALL.into_iter().enumerate() {
            assert_eq!(k.code() as usize, i);
            assert_eq!(SpanKind::from_code(k.code()), Some(k));
            assert_eq!(SpanKind::from_name(k.name()), Some(k));
        }
        assert_eq!(SpanKind::from_code(200), None);
        assert_eq!(SpanKind::from_name("nope"), None);
    }

    #[test]
    fn record_json_round_trip() {
        let r = SpanRecord {
            kind: SpanKind::SliceBwd,
            stage: 3,
            mb: 2,
            slice: 7,
            a: 16,
            b: 8,
            start_us: 1234,
            dur_us: 567,
        };
        let back = SpanRecord::from_json(&Json::parse(&r.to_json().to_string()).unwrap()).unwrap();
        assert_eq!(back, r);
        // driver-side (negative stage) survives the f64 carrier
        let d = SpanRecord { stage: DRIVER, ..r };
        let back = SpanRecord::from_json(&Json::parse(&d.to_json().to_string()).unwrap()).unwrap();
        assert_eq!(back.stage, DRIVER);
    }

    #[test]
    fn from_json_rejects_malformed() {
        assert!(SpanRecord::from_json(&Json::parse("{}").unwrap()).is_err());
        let bad_kind = Json::parse(r#"{"kind":"zzz","stage":0,"mb":0,"slice":0,"a":0,"b":0,"start_us":0,"dur_us":0}"#).unwrap();
        assert!(SpanRecord::from_json(&bad_kind).is_err());
    }
}
