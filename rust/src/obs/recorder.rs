//! Lock-free per-thread span recorder.
//!
//! # Design
//!
//! Each recording thread owns one fixed-capacity buffer ([`SLOT_CAP`]
//! spans), claimed from the recorder on its first span and cached in
//! thread-local storage. The hot path is a single-producer append: one
//! relaxed enabled-check, one head load, six relaxed word stores, one
//! release head store — no locks, no CAS loops, and **zero heap
//! allocations** once the thread's slot exists (the claim itself is the
//! only allocation, paid once per thread per recorder — a warmup cost,
//! like the kernels' scratch arena).
//!
//! Spans are stored as atomic `u64` words rather than raw memory so a
//! racing flush reads stale-but-defined values instead of UB; the
//! *consistency* contract is still quiescence (below).
//!
//! # Flush contract
//!
//! [`Recorder::flush`] drains every slot, merges, and sorts into one
//! deterministic timeline. Call it at a quiescent point — a step
//! boundary, after a pool's tasks joined, after shutdown. A span
//! recorded concurrently with the flush that drains it may be lost or
//! duplicated (never torn into UB); the trainer flushes between steps,
//! where workers are parked on their inboxes.
//!
//! When a thread outruns its buffer the overflow spans are counted in
//! [`Flush::dropped`], not silently lost — the metrics snapshot surfaces
//! the counter so a truncated trace is visible as such.

use std::cell::RefCell;
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex, OnceLock};
use std::time::Instant;

use super::{SpanKind, SpanRecord};

/// Spans one thread can hold between flushes.
pub const SLOT_CAP: usize = 8192;

/// `u64` words per encoded span.
const WORDS: usize = 6;

fn encode(r: &SpanRecord) -> [u64; WORDS] {
    [
        r.kind.code() as u64 | ((r.stage as u32 as u64) << 32),
        r.mb as u64 | ((r.slice as u64) << 32),
        r.a,
        r.b,
        r.start_us,
        r.dur_us,
    ]
}

fn decode(w: &[u64; WORDS]) -> SpanRecord {
    SpanRecord {
        kind: SpanKind::from_code((w[0] & 0xFF) as u8).unwrap_or(SpanKind::SliceFwd),
        stage: ((w[0] >> 32) as u32) as i32,
        mb: w[1] as u32,
        slice: (w[1] >> 32) as u32,
        a: w[2],
        b: w[3],
        start_us: w[4],
        dur_us: w[5],
    }
}

/// One thread's buffer. Single producer (the owning thread); the
/// flusher reads through the same atomics.
struct Slot {
    /// Spans written since the last flush (may exceed [`SLOT_CAP`]; the
    /// excess is counted, not stored).
    head: AtomicUsize,
    dropped: AtomicU64,
    /// `SLOT_CAP * WORDS` words, span `i` at `i * WORDS`.
    buf: Box<[AtomicU64]>,
}

impl Slot {
    fn new() -> Slot {
        Slot {
            head: AtomicUsize::new(0),
            dropped: AtomicU64::new(0),
            buf: (0..SLOT_CAP * WORDS).map(|_| AtomicU64::new(0)).collect(),
        }
    }

    #[inline]
    fn push(&self, rec: &SpanRecord) {
        let h = self.head.load(Ordering::Relaxed);
        if h >= SLOT_CAP {
            self.dropped.fetch_add(1, Ordering::Relaxed);
            return;
        }
        let words = encode(rec);
        let base = h * WORDS;
        for (i, w) in words.iter().enumerate() {
            self.buf[base + i].store(*w, Ordering::Relaxed);
        }
        self.head.store(h + 1, Ordering::Release);
    }

    fn read(&self, i: usize) -> SpanRecord {
        let base = i * WORDS;
        let mut w = [0u64; WORDS];
        for (j, slot) in w.iter_mut().enumerate() {
            *slot = self.buf[base + j].load(Ordering::Acquire);
        }
        decode(&w)
    }
}

/// Result of one [`Recorder::flush`]: the merged, deterministically
/// sorted span stream plus the overflow count.
#[derive(Debug, Clone, Default)]
pub struct Flush {
    pub spans: Vec<SpanRecord>,
    /// Spans lost to per-thread buffer overflow since the last flush.
    pub dropped: u64,
}

impl Flush {
    /// Fold another flush (e.g. per-step drains) into this one, keeping
    /// the merged stream sorted.
    pub fn absorb(&mut self, mut other: Flush) {
        self.spans.append(&mut other.spans);
        self.dropped += other.dropped;
        sort_spans(&mut self.spans);
    }
}

fn sort_key(r: &SpanRecord) -> (u64, i32, u8, u32, u32, u64, u64, u64) {
    (r.start_us, r.stage, r.kind.code(), r.mb, r.slice, r.dur_us, r.a, r.b)
}

fn sort_spans(spans: &mut [SpanRecord]) {
    spans.sort_unstable_by_key(sort_key);
}

static NEXT_RECORDER_ID: AtomicUsize = AtomicUsize::new(0);

thread_local! {
    /// (recorder id → slot) for every recorder this thread has recorded
    /// to. Tiny (one global + test instances); linear scan.
    static SLOTS: RefCell<Vec<(usize, Arc<Slot>)>> = const { RefCell::new(Vec::new()) };
}

/// A span recorder. Most code uses the process-global instance through
/// [`super::record`]/[`super::flush`]; tests build private instances so
/// concurrent test threads cannot pollute each other's streams.
pub struct Recorder {
    id: usize,
    enabled: AtomicBool,
    /// Every slot ever claimed (slots are never reclaimed; threads are
    /// bounded — stage workers, the driver, a rayon pool).
    slots: Mutex<Vec<Arc<Slot>>>,
}

impl Default for Recorder {
    fn default() -> Self {
        Self::new()
    }
}

impl Recorder {
    pub fn new() -> Recorder {
        Recorder {
            id: NEXT_RECORDER_ID.fetch_add(1, Ordering::Relaxed),
            enabled: AtomicBool::new(false),
            slots: Mutex::new(Vec::new()),
        }
    }

    #[inline]
    pub fn is_enabled(&self) -> bool {
        self.enabled.load(Ordering::Relaxed)
    }

    pub fn set_enabled(&self, on: bool) {
        self.enabled.store(on, Ordering::Release);
    }

    /// Record one span (no-op when disabled). Allocation-free once this
    /// thread's slot exists.
    #[inline]
    pub fn record(&self, rec: SpanRecord) {
        if !self.is_enabled() {
            return;
        }
        SLOTS.with(|tl| {
            let mut tl = tl.borrow_mut();
            if let Some((_, slot)) = tl.iter().find(|(id, _)| *id == self.id) {
                slot.push(&rec);
                return;
            }
            let slot = Arc::new(Slot::new());
            self.slots.lock().unwrap().push(slot.clone());
            slot.push(&rec);
            tl.push((self.id, slot));
        });
    }

    /// Drain every thread's buffer into one deterministically ordered
    /// stream (sorted by start time, then stage/kind/ids — identical
    /// span sets merge identically regardless of which threads recorded
    /// them). See the module docs for the quiescence contract.
    pub fn flush(&self) -> Flush {
        let slots = self.slots.lock().unwrap();
        let mut out = Flush::default();
        for s in slots.iter() {
            let h = s.head.swap(0, Ordering::AcqRel).min(SLOT_CAP);
            out.dropped += s.dropped.swap(0, Ordering::AcqRel);
            for i in 0..h {
                out.spans.push(s.read(i));
            }
        }
        sort_spans(&mut out.spans);
        out
    }
}

static GLOBAL: OnceLock<Recorder> = OnceLock::new();

/// The process-global recorder (off until [`Recorder::set_enabled`]).
pub fn global() -> &'static Recorder {
    GLOBAL.get_or_init(Recorder::new)
}

static EPOCH: OnceLock<Instant> = OnceLock::new();

/// Microseconds since the process trace epoch (set on first call).
#[inline]
pub fn now_us() -> u64 {
    EPOCH.get_or_init(Instant::now).elapsed().as_micros() as u64
}

#[cfg(test)]
mod tests {
    use super::*;

    fn span(stage: i32, start_us: u64) -> SpanRecord {
        SpanRecord {
            kind: SpanKind::SliceFwd,
            stage,
            mb: 0,
            slice: 0,
            a: 1,
            b: 2,
            start_us,
            dur_us: 10,
        }
    }

    #[test]
    fn disabled_recorder_drops_everything() {
        let r = Recorder::new();
        r.record(span(0, 1));
        assert!(r.flush().spans.is_empty());
    }

    #[test]
    fn flush_merges_and_sorts_across_threads() {
        let r = Arc::new(Recorder::new());
        r.set_enabled(true);
        let mut handles = Vec::new();
        for t in 0..4i32 {
            let r = r.clone();
            handles.push(std::thread::spawn(move || {
                for i in 0..10u64 {
                    r.record(span(t, 1000 - i * 7 - t as u64));
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        let f = r.flush();
        assert_eq!(f.spans.len(), 40);
        assert_eq!(f.dropped, 0);
        assert!(f.spans.windows(2).all(|w| sort_key(&w[0]) <= sort_key(&w[1])));
        // drained: a second flush is empty
        assert!(r.flush().spans.is_empty());
    }

    #[test]
    fn overflow_is_counted_not_lost_silently() {
        let r = Recorder::new();
        r.set_enabled(true);
        for i in 0..(SLOT_CAP as u64 + 100) {
            r.record(span(0, i));
        }
        let f = r.flush();
        assert_eq!(f.spans.len(), SLOT_CAP);
        assert_eq!(f.dropped, 100);
        // counters reset with the flush
        assert_eq!(r.flush().dropped, 0);
    }

    #[test]
    fn encode_decode_round_trip() {
        let r = SpanRecord {
            kind: SpanKind::DriftVerdict,
            stage: super::super::DRIVER,
            mb: 7,
            slice: 11,
            a: u64::MAX,
            b: 42,
            start_us: 123_456,
            dur_us: 0,
        };
        assert_eq!(decode(&encode(&r)), r);
    }

    #[test]
    fn absorb_keeps_order() {
        let mut a = Flush { spans: vec![span(0, 5), span(0, 9)], dropped: 1 };
        let b = Flush { spans: vec![span(1, 2), span(1, 7)], dropped: 2 };
        a.absorb(b);
        assert_eq!(a.dropped, 3);
        let starts: Vec<u64> = a.spans.iter().map(|s| s.start_us).collect();
        assert_eq!(starts, vec![2, 5, 7, 9]);
    }
}
