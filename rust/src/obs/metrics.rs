//! Prometheus-style text metrics: one registry, rendered identically to
//! `metrics.prom` and stdout (single source of truth — `terapipe
//! autotune`'s old bespoke print path routes through here).
//!
//! The registry is deliberately small: counters, gauges and fixed-bucket
//! histograms, labeled, rendered in insertion order (deterministic
//! output for pinned tests). Populator helpers at the bottom translate
//! the repo's existing telemetry structs — recorder flushes, step
//! reports, planner cache stats, virtual-transport link metrics — into
//! metric families with a stable naming scheme (`terapipe_*`).

use super::recorder::Flush;
use super::SpanKind;
use crate::coordinator::trainer::StepReport;
use crate::coordinator::transport::virt::LinkMetrics;
use crate::coordinator::transport::LinkId;
use crate::planner::cache::CacheStats;
use std::fmt::Write as _;

/// Injected-delay histogram bounds (ms) for link metrics; `+Inf` is
/// implicit.
pub const DELAY_BUCKETS_MS: [f64; 8] = [0.05, 0.1, 0.5, 1.0, 5.0, 10.0, 50.0, 100.0];

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum Kind {
    Counter,
    Gauge,
    Histogram,
}

impl Kind {
    fn as_str(self) -> &'static str {
        match self {
            Kind::Counter => "counter",
            Kind::Gauge => "gauge",
            Kind::Histogram => "histogram",
        }
    }
}

#[derive(Debug, Clone)]
enum Value {
    Scalar(f64),
    Hist {
        /// Upper bounds, ascending; the `+Inf` bucket is implicit.
        bounds: Vec<f64>,
        /// Non-cumulative per-bucket counts, `bounds.len() + 1` long.
        counts: Vec<u64>,
        sum: f64,
        count: u64,
    },
}

#[derive(Debug, Clone)]
struct Sample {
    labels: Vec<(String, String)>,
    value: Value,
}

#[derive(Debug, Clone)]
struct Family {
    name: String,
    help: String,
    kind: Kind,
    samples: Vec<Sample>,
}

/// An insertion-ordered metrics registry with Prometheus text rendering.
#[derive(Debug, Clone, Default)]
pub struct MetricsRegistry {
    families: Vec<Family>,
}

fn labels_eq(a: &[(String, String)], b: &[(&str, &str)]) -> bool {
    a.len() == b.len() && a.iter().zip(b).all(|((k, v), (k2, v2))| k == k2 && v == v2)
}

fn own(labels: &[(&str, &str)]) -> Vec<(String, String)> {
    labels.iter().map(|(k, v)| (k.to_string(), v.to_string())).collect()
}

impl MetricsRegistry {
    pub fn new() -> MetricsRegistry {
        MetricsRegistry::default()
    }

    fn family(&mut self, name: &str, help: &str, kind: Kind) -> &mut Family {
        if let Some(i) = self.families.iter().position(|f| f.name == name) {
            assert_eq!(self.families[i].kind, kind, "metric '{name}' re-registered as {kind:?}");
            return &mut self.families[i];
        }
        self.families.push(Family {
            name: name.to_string(),
            help: help.to_string(),
            kind,
            samples: Vec::new(),
        });
        self.families.last_mut().unwrap()
    }

    fn scalar(&mut self, name: &str, help: &str, kind: Kind, labels: &[(&str, &str)], v: f64, add: bool) {
        let fam = self.family(name, help, kind);
        if let Some(s) = fam.samples.iter_mut().find(|s| labels_eq(&s.labels, labels)) {
            match &mut s.value {
                Value::Scalar(x) => {
                    if add {
                        *x += v;
                    } else {
                        *x = v;
                    }
                }
                Value::Hist { .. } => unreachable!("scalar write to histogram sample"),
            }
            return;
        }
        fam.samples.push(Sample { labels: own(labels), value: Value::Scalar(v) });
    }

    /// Add `v` to a counter (creating it at `v`).
    pub fn counter(&mut self, name: &str, help: &str, labels: &[(&str, &str)], v: f64) {
        self.scalar(name, help, Kind::Counter, labels, v, true);
    }

    /// Set a gauge to `v`.
    pub fn gauge(&mut self, name: &str, help: &str, labels: &[(&str, &str)], v: f64) {
        self.scalar(name, help, Kind::Gauge, labels, v, false);
    }

    /// Observe `v` into a fixed-bucket histogram (`bounds` ascending;
    /// the `+Inf` bucket is implicit).
    pub fn observe(&mut self, name: &str, help: &str, labels: &[(&str, &str)], bounds: &[f64], v: f64) {
        let fam = self.family(name, help, Kind::Histogram);
        let sample = match fam.samples.iter_mut().find(|s| labels_eq(&s.labels, labels)) {
            Some(s) => s,
            None => {
                fam.samples.push(Sample {
                    labels: own(labels),
                    value: Value::Hist {
                        bounds: bounds.to_vec(),
                        counts: vec![0; bounds.len() + 1],
                        sum: 0.0,
                        count: 0,
                    },
                });
                fam.samples.last_mut().unwrap()
            }
        };
        match &mut sample.value {
            Value::Hist { bounds, counts, sum, count } => {
                let i = bounds.iter().position(|b| v <= *b).unwrap_or(bounds.len());
                counts[i] += 1;
                *sum += v;
                *count += 1;
            }
            Value::Scalar(_) => unreachable!("histogram observe on scalar sample"),
        }
    }

    /// Current value of a counter/gauge sample (tests, stdout summaries).
    pub fn get(&self, name: &str, labels: &[(&str, &str)]) -> Option<f64> {
        let fam = self.families.iter().find(|f| f.name == name)?;
        let s = fam.samples.iter().find(|s| labels_eq(&s.labels, labels))?;
        match &s.value {
            Value::Scalar(v) => Some(*v),
            Value::Hist { sum, .. } => Some(*sum),
        }
    }

    /// Prometheus text exposition format, families in insertion order.
    pub fn render(&self) -> String {
        let mut out = String::new();
        for f in &self.families {
            let _ = writeln!(out, "# HELP {} {}", f.name, f.help);
            let _ = writeln!(out, "# TYPE {} {}", f.name, f.kind.as_str());
            for s in &f.samples {
                match &s.value {
                    Value::Scalar(v) => {
                        let _ = writeln!(out, "{}{} {}", f.name, label_str(&s.labels, None), num(*v));
                    }
                    Value::Hist { bounds, counts, sum, count } => {
                        let mut cum = 0u64;
                        for (i, b) in bounds.iter().enumerate() {
                            cum += counts[i];
                            let _ = writeln!(
                                out,
                                "{}_bucket{} {}",
                                f.name,
                                label_str(&s.labels, Some(&num(*b))),
                                cum
                            );
                        }
                        cum += counts[bounds.len()];
                        let _ = writeln!(
                            out,
                            "{}_bucket{} {}",
                            f.name,
                            label_str(&s.labels, Some("+Inf")),
                            cum
                        );
                        let _ = writeln!(out, "{}_sum{} {}", f.name, label_str(&s.labels, None), num(*sum));
                        let _ = writeln!(out, "{}_count{} {}", f.name, label_str(&s.labels, None), count);
                    }
                }
            }
        }
        out
    }
}

fn num(v: f64) -> String {
    if v.fract() == 0.0 && v.abs() < 9e15 {
        format!("{}", v as i64)
    } else {
        format!("{v}")
    }
}

fn label_str(labels: &[(String, String)], le: Option<&str>) -> String {
    if labels.is_empty() && le.is_none() {
        return String::new();
    }
    let mut parts: Vec<String> =
        labels.iter().map(|(k, v)| format!("{k}=\"{v}\"")).collect();
    if let Some(le) = le {
        parts.push(format!("le=\"{le}\""));
    }
    format!("{{{}}}", parts.join(","))
}

// ---- populators: repo telemetry -> metric families ----

/// Per-kind span counts + recorder overflow from a (merged) flush.
pub fn span_metrics(reg: &mut MetricsRegistry, flush: &Flush) {
    for kind in SpanKind::ALL {
        let n = flush.spans.iter().filter(|s| s.kind == kind).count();
        reg.counter(
            "terapipe_spans_total",
            "Recorded spans by kind",
            &[("kind", kind.name())],
            n as f64,
        );
    }
    reg.counter(
        "terapipe_spans_dropped_total",
        "Spans lost to per-thread recorder buffer overflow",
        &[],
        flush.dropped as f64,
    );
    // canonical name going forward (the old name is kept for dashboards
    // already scraping it)
    reg.counter(
        "terapipe_obs_spans_dropped_total",
        "Spans lost to per-thread recorder buffer overflow",
        &[],
        flush.dropped as f64,
    );
    for (code, name) in [(0u64, "warmup"), (1, "stable"), (2, "drifted")] {
        let n = flush
            .spans
            .iter()
            .filter(|s| s.kind == SpanKind::DriftVerdict && s.a == code)
            .count();
        reg.counter(
            "terapipe_drift_verdicts_total",
            "Drift-window verdicts by outcome",
            &[("verdict", name)],
            n as f64,
        );
    }
    let switches = flush.spans.iter().filter(|s| s.kind == SpanKind::PlanSwitch).count();
    reg.counter(
        "terapipe_plan_switches_total",
        "Times the active slicing plan was replaced",
        &[],
        switches as f64,
    );
}

/// Training progress: totals plus per-stage busy time and the measured
/// bubble fraction from the most recent step that carried one.
pub fn step_metrics(reg: &mut MetricsRegistry, reports: &[StepReport]) {
    reg.counter("terapipe_steps_total", "Optimizer steps completed", &[], reports.len() as f64);
    let tokens: usize = reports.iter().map(|r| r.tokens).sum();
    let wall_ms: f64 = reports.iter().map(|r| r.wall_ms).sum();
    reg.counter("terapipe_tokens_total", "Tokens processed", &[], tokens as f64);
    reg.counter("terapipe_step_wall_ms_total", "Wall time spent in steps (ms)", &[], wall_ms);
    if wall_ms > 0.0 {
        reg.gauge(
            "terapipe_tokens_per_sec",
            "Training throughput over the reported window",
            &[],
            tokens as f64 / (wall_ms / 1e3),
        );
    }
    let stages = reports.iter().map(|r| r.stage_busy_ms.len()).max().unwrap_or(0);
    for s in 0..stages {
        let busy: f64 = reports.iter().map(|r| r.stage_busy_ms.get(s).copied().unwrap_or(0.0)).sum();
        let stage = s.to_string();
        reg.counter(
            "terapipe_stage_busy_ms_total",
            "Per-stage compute busy time (ms)",
            &[("stage", stage.as_str())],
            busy,
        );
    }
    if let Some(bf) = reports.iter().rev().find_map(|r| r.bubble_fraction) {
        reg.gauge(
            "terapipe_bubble_fraction",
            "Measured pipeline bubble fraction (latest step)",
            &[],
            bf,
        );
    }
}

/// Planner cost-table cache counters (the autotune stdout summary reads
/// these back via [`MetricsRegistry::get`]).
pub fn cache_metrics(reg: &mut MetricsRegistry, stats: &CacheStats) {
    let pairs: [(&str, usize); 5] = [
        ("base_hits", stats.base_hits),
        ("base_misses", stats.base_misses),
        ("scaled_hits", stats.scaled_hits),
        ("rescales", stats.rescales),
        ("evictions", stats.evictions),
    ];
    for (event, n) in pairs {
        reg.counter(
            "terapipe_planner_cache_events_total",
            "Cost-table cache events by type",
            &[("event", event)],
            n as f64,
        );
    }
    let hits = (stats.base_hits + stats.scaled_hits) as f64;
    let lookups = hits + stats.base_misses as f64 + stats.rescales as f64;
    if lookups > 0.0 {
        reg.gauge(
            "terapipe_planner_cache_hit_rate",
            "Cache lookups served without densify or rescale",
            &[],
            hits / lookups,
        );
    }
}

/// Virtual-transport link telemetry: per-link traffic counters plus an
/// injected-delay histogram per link (satellite: previously reachable
/// only from tests).
pub fn link_metrics(reg: &mut MetricsRegistry, links: &[(LinkId, LinkMetrics)]) {
    for (id, m) in links {
        let label = super::export::link_label(*id);
        let labels: [(&str, &str); 1] = [("link", label.as_str())];
        reg.counter("terapipe_link_sent_total", "Messages sent per link", &labels, m.sent as f64);
        reg.counter(
            "terapipe_link_dropped_total",
            "Messages dropped per link (injected loss)",
            &labels,
            m.dropped as f64,
        );
        reg.counter("terapipe_link_bytes_total", "Approx wire bytes per link", &labels, m.bytes as f64);
        for d in &m.deliveries {
            reg.observe(
                "terapipe_link_delay_ms",
                "Injected delivery delay per link (ms)",
                &labels,
                &DELAY_BUCKETS_MS,
                d.delay_ms,
            );
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::transport::virt::DeliverySample;
    use crate::obs::SpanRecord;

    #[test]
    fn counters_accumulate_and_gauges_overwrite() {
        let mut reg = MetricsRegistry::new();
        reg.counter("c", "h", &[("k", "v")], 1.0);
        reg.counter("c", "h", &[("k", "v")], 2.0);
        reg.counter("c", "h", &[("k", "w")], 5.0);
        reg.gauge("g", "h", &[], 1.0);
        reg.gauge("g", "h", &[], 9.0);
        assert_eq!(reg.get("c", &[("k", "v")]), Some(3.0));
        assert_eq!(reg.get("c", &[("k", "w")]), Some(5.0));
        assert_eq!(reg.get("g", &[]), Some(9.0));
        assert_eq!(reg.get("c", &[("k", "x")]), None);
    }

    #[test]
    fn render_is_prometheus_shaped() {
        let mut reg = MetricsRegistry::new();
        reg.counter("terapipe_steps_total", "Steps", &[], 3.0);
        reg.observe("d", "Delay", &[("link", "s0->s1")], &[1.0, 10.0], 0.5);
        reg.observe("d", "Delay", &[("link", "s0->s1")], &[1.0, 10.0], 5.0);
        reg.observe("d", "Delay", &[("link", "s0->s1")], &[1.0, 10.0], 99.0);
        let text = reg.render();
        assert!(text.contains("# TYPE terapipe_steps_total counter"));
        assert!(text.contains("terapipe_steps_total 3"));
        assert!(text.contains("d_bucket{link=\"s0->s1\",le=\"1\"} 1"));
        assert!(text.contains("d_bucket{link=\"s0->s1\",le=\"10\"} 2"));
        assert!(text.contains("d_bucket{link=\"s0->s1\",le=\"+Inf\"} 3"));
        assert!(text.contains("d_sum{link=\"s0->s1\"} 104.5"));
        assert!(text.contains("d_count{link=\"s0->s1\"} 3"));
    }

    #[test]
    fn span_populator_counts_kinds_and_verdicts() {
        let mk = |kind: SpanKind, a: u64| SpanRecord {
            kind,
            stage: 0,
            mb: 0,
            slice: 0,
            a,
            b: 0,
            start_us: 0,
            dur_us: 0,
        };
        let flush = Flush {
            spans: vec![
                mk(SpanKind::SliceFwd, 0),
                mk(SpanKind::SliceFwd, 0),
                mk(SpanKind::DriftVerdict, 2),
                mk(SpanKind::PlanSwitch, 0),
            ],
            dropped: 7,
        };
        let mut reg = MetricsRegistry::new();
        span_metrics(&mut reg, &flush);
        assert_eq!(reg.get("terapipe_spans_total", &[("kind", "slice_fwd")]), Some(2.0));
        assert_eq!(reg.get("terapipe_spans_dropped_total", &[]), Some(7.0));
        assert_eq!(reg.get("terapipe_obs_spans_dropped_total", &[]), Some(7.0));
        assert_eq!(reg.get("terapipe_drift_verdicts_total", &[("verdict", "drifted")]), Some(1.0));
        assert_eq!(reg.get("terapipe_plan_switches_total", &[]), Some(1.0));
    }

    #[test]
    fn link_populator_builds_histograms() {
        let m = LinkMetrics {
            sent: 3,
            dropped: 1,
            bytes: 640,
            delay_ms_sum: 6.0,
            deliveries: vec![
                DeliverySample { delay_ms: 0.01, len: Some(4), bytes: 320 },
                DeliverySample { delay_ms: 6.0, len: Some(4), bytes: 320 },
            ],
        };
        let mut reg = MetricsRegistry::new();
        link_metrics(&mut reg, &[(LinkId::Fwd(0), m)]);
        assert_eq!(reg.get("terapipe_link_sent_total", &[("link", "s0->s1")]), Some(3.0));
        let text = reg.render();
        assert!(text.contains("terapipe_link_delay_ms_bucket{link=\"s0->s1\",le=\"0.05\"} 1"));
        assert!(text.contains("terapipe_link_delay_ms_count{link=\"s0->s1\"} 2"));
    }
}
