//! Exporters: Chrome/Perfetto trace-event JSON for the merged exec+sim
//! timeline, layered over the same span stream the metrics snapshot
//! summarizes.
//!
//! Track layout (`pid`/`tid` in trace-event terms):
//!
//! * **pid 0 "exec"** — one track per stage (`tid = stage`) plus a
//!   driver track (`tid = k`) for planner events. Slice compute spans
//!   keep the simulator's naming (`F{mb}.{slice}` / `B{mb}.{slice}`)
//!   so the same cell is string-identical across exec and sim tracks.
//! * **pid 1 "links"** — one track per directed link (`tid` = dense
//!   [`LinkId::index`]), carrying send/recv instants.
//! * **pid 2 "sim (predicted)"** — the wavefront's predicted spans, one
//!   track per stage, so Perfetto shows prediction and reality stacked.
//!
//! Plan switches, drift verdicts and cache hits render as instant
//! events (`ph:"i"`) on the driver track. Executed timestamps are
//! re-based to the earliest exec span so both timelines start at 0.

use super::{SpanKind, SpanRecord};
use crate::coordinator::transport::LinkId;
use crate::sim::trace::Span;
use crate::sim::Phase;
use crate::util::json::Json;

/// Trainer-facing bundle: everything one traced run exports.
pub struct TraceBundle {
    /// Executed spans (merged recorder flushes).
    pub exec: Vec<SpanRecord>,
    /// Wavefront-predicted spans for the active plan (may be empty).
    pub predicted: Vec<Span>,
    /// Pipeline stage count.
    pub stages: usize,
    /// Spans lost to recorder-buffer overflow (surfaced in metrics).
    pub dropped: u64,
}

fn meta(pid: u32, tid: u32, what: &str, name: &str) -> Json {
    Json::obj(vec![
        ("name", Json::Str(what.into())),
        ("ph", Json::Str("M".into())),
        ("pid", Json::Num(pid as f64)),
        ("tid", Json::Num(tid as f64)),
        ("args", Json::obj(vec![("name", Json::Str(name.into()))])),
    ])
}

/// Human-readable name for one directed link's track.
pub fn link_label(l: LinkId) -> String {
    match l {
        LinkId::DriverTo(s) => format!("driver->s{s}"),
        LinkId::Fwd(s) => format!("s{s}->s{}", s + 1),
        LinkId::Bwd(s) => format!("s{s}->s{}", s - 1),
        LinkId::ToDriver(s) => format!("s{s}->driver"),
    }
}

/// Exec-track tid for a span: stages map to themselves, driver-side
/// events ([`super::DRIVER`]) to the extra track after the last stage.
fn exec_tid(stage: i32, k: usize) -> u32 {
    if stage < 0 {
        k as u32
    } else {
        stage as u32
    }
}

fn slice_name(kind: SpanKind, mb: u32, slice: u32) -> String {
    let tag = if kind == SpanKind::SliceFwd { "F" } else { "B" };
    format!("{tag}{mb}.{slice}")
}

/// Build the full Chrome trace-event document:
/// `{"traceEvents": [...], "displayTimeUnit": "ms"}` — loadable by
/// Perfetto (ui.perfetto.dev) and chrome://tracing.
pub fn perfetto_trace(bundle: &TraceBundle) -> Json {
    let k = bundle.stages;
    let mut evs: Vec<Json> = Vec::new();

    evs.push(meta(0, 0, "process_name", "exec"));
    evs.push(meta(1, 0, "process_name", "links"));
    evs.push(meta(2, 0, "process_name", "sim (predicted)"));
    for s in 0..k {
        evs.push(meta(0, s as u32, "thread_name", &format!("stage {s}")));
        evs.push(meta(2, s as u32, "thread_name", &format!("stage {s} (sim)")));
    }
    evs.push(meta(0, k as u32, "thread_name", "driver"));
    if k >= 1 {
        for l in LinkId::all(k) {
            evs.push(meta(1, l.index(k) as u32, "thread_name", &link_label(l)));
        }
    }

    // Re-base exec time so the trace starts at 0 like the sim track.
    let t0 = bundle.exec.iter().map(|r| r.start_us).min().unwrap_or(0);
    for r in &bundle.exec {
        let ts = (r.start_us - t0) as f64;
        let name = match r.kind {
            SpanKind::SliceFwd | SpanKind::SliceBwd => slice_name(r.kind, r.mb, r.slice),
            _ => r.kind.name().to_string(),
        };
        let (pid, tid) = match r.kind {
            SpanKind::Send | SpanKind::Recv => (1u32, r.b as u32),
            _ => (0u32, exec_tid(r.stage, k)),
        };
        let mut fields = vec![
            ("name", Json::Str(name)),
            ("cat", Json::Str(r.kind.category().into())),
            ("pid", Json::Num(pid as f64)),
            ("tid", Json::Num(tid as f64)),
            ("ts", Json::Num(ts)),
            (
                "args",
                Json::obj(vec![
                    ("a", Json::Num(r.a as f64)),
                    ("b", Json::Num(r.b as f64)),
                    ("mb", Json::Num(r.mb as f64)),
                    ("slice", Json::Num(r.slice as f64)),
                ]),
            ),
        ];
        if r.kind.is_instant() {
            fields.push(("ph", Json::Str("i".into())));
            fields.push(("s", Json::Str("t".into())));
        } else {
            fields.push(("ph", Json::Str("X".into())));
            fields.push(("dur", Json::Num(r.dur_us as f64)));
        }
        evs.push(Json::obj(fields));
    }

    for s in &bundle.predicted {
        let kind =
            if s.phase == Phase::Fwd { SpanKind::SliceFwd } else { SpanKind::SliceBwd };
        evs.push(Json::obj(vec![
            ("name", Json::Str(slice_name(kind, s.part as u32, s.slice as u32))),
            ("cat", Json::Str(if s.phase == Phase::Fwd { "fwd" } else { "bwd" }.into())),
            ("ph", Json::Str("X".into())),
            ("ts", Json::Num(s.start_ms * 1000.0)),
            ("dur", Json::Num((s.end_ms - s.start_ms) * 1000.0)),
            ("pid", Json::Num(2.0)),
            ("tid", Json::Num(s.stage as f64)),
        ]));
    }

    Json::obj(vec![
        ("traceEvents", Json::Arr(evs)),
        ("displayTimeUnit", Json::Str("ms".into())),
    ])
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::obs::DRIVER;

    fn rec(kind: SpanKind, stage: i32, mb: u32, slice: u32, start_us: u64, dur_us: u64) -> SpanRecord {
        SpanRecord { kind, stage, mb, slice, a: 4, b: 1, start_us, dur_us }
    }

    fn bundle() -> TraceBundle {
        TraceBundle {
            exec: vec![
                rec(SpanKind::SliceFwd, 0, 0, 0, 1000, 500),
                rec(SpanKind::Send, 0, 0, 0, 1500, 0),
                rec(SpanKind::SliceBwd, 1, 0, 0, 2000, 700),
                rec(SpanKind::PlanSwitch, DRIVER, 0, 0, 2500, 0),
            ],
            predicted: vec![Span {
                stage: 0,
                start_ms: 0.0,
                end_ms: 0.5,
                phase: Phase::Fwd,
                part: 0,
                slice: 0,
            }],
            stages: 2,
            dropped: 0,
        }
    }

    #[test]
    fn trace_parses_back_and_has_all_tracks() {
        let doc = perfetto_trace(&bundle());
        let parsed = Json::parse(&doc.to_string()).unwrap();
        let evs = parsed.get("traceEvents").unwrap().as_arr().unwrap();
        // 3 process names + 2*2 stage threads + driver + 6 links + 4 exec + 1 sim
        assert_eq!(evs.len(), 3 + 4 + 1 + LinkId::count(2) + 4 + 1);
        assert_eq!(parsed.get("displayTimeUnit").unwrap().as_str(), Some("ms"));
    }

    #[test]
    fn exec_time_is_rebased_and_names_match_sim() {
        let doc = perfetto_trace(&bundle());
        let evs = doc.get("traceEvents").unwrap().as_arr().unwrap();
        let first_exec = evs
            .iter()
            .find(|e| e.get("ph").unwrap().as_str() == Some("X") && e.get("pid").unwrap().as_usize() == Some(0))
            .unwrap();
        assert_eq!(first_exec.get("ts").unwrap().as_f64(), Some(0.0));
        assert_eq!(first_exec.get("name").unwrap().as_str(), Some("F0.0"));
        let sim_ev = evs.iter().find(|e| e.get("pid").unwrap().as_usize() == Some(2)).unwrap();
        assert_eq!(sim_ev.get("name").unwrap().as_str(), Some("F0.0"));
    }

    #[test]
    fn instants_land_on_link_and_driver_tracks() {
        let doc = perfetto_trace(&bundle());
        let evs = doc.get("traceEvents").unwrap().as_arr().unwrap();
        let send = evs
            .iter()
            .find(|e| e.get("name").unwrap().as_str() == Some("send"))
            .unwrap();
        assert_eq!(send.get("pid").unwrap().as_usize(), Some(1));
        assert_eq!(send.get("ph").unwrap().as_str(), Some("i"));
        let switch = evs
            .iter()
            .find(|e| e.get("name").unwrap().as_str() == Some("plan_switch"))
            .unwrap();
        assert_eq!(switch.get("pid").unwrap().as_usize(), Some(0));
        assert_eq!(switch.get("tid").unwrap().as_usize(), Some(2)); // driver track = k
    }

    #[test]
    fn link_labels_name_both_endpoints() {
        assert_eq!(link_label(LinkId::Fwd(0)), "s0->s1");
        assert_eq!(link_label(LinkId::Bwd(1)), "s1->s0");
        assert_eq!(link_label(LinkId::DriverTo(0)), "driver->s0");
        assert_eq!(link_label(LinkId::ToDriver(1)), "s1->driver");
    }
}
