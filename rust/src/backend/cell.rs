//! The sliced transformer cell: native-Rust forward and backward for one
//! pipeline stage, plus the embedding and LM-head cells.
//!
//! This is a line-for-line transcription of `python/compile/model.py`
//! (the functions `aot.py` lowers to the PJRT executables): pre-LN GPT
//! blocks over one token slice, causal attention over a padded KV context
//! buffer, tanh-GELU MLP, final layernorm + cross-entropy head, with the
//! VJPs written out by hand so the backward is *exact* — not approximate —
//! and `stage_bwd` returns the context K/V gradients the coordinator
//! accumulates into earlier slices (the dependency structure that makes
//! token-level pipelining a pure scheduling choice).
//!
//! Layouts (row-major, `H = num_heads · head_dim`):
//!
//! * hidden states `h`: `[B, S, H]` for slice length S
//! * per-layer KV context: `[B, T, H]` (T = full sequence length), the
//!   `[B, T, NH, HD]` view with the head axes merged
//! * stage KV buffers: `[NL, B, T, H]`; per-slice K/V: `[NL, B, S, H]`
//!
//! The backward recomputes the forward (rematerialization, exactly like
//! the `jax.vjp`-based executables) so callers only keep each slice's
//! *input* activation and the grown KV buffers.
//!
//! **Hot-path memory discipline.** The `*_into` entry points
//! ([`stage_fwd_into`] / [`stage_bwd_into`]) write into caller-provided
//! buffers, and every internal temporary — activations, KV scatter
//! buffers, rematerialization caches, gradient intermediates, attention
//! score rows — comes from the per-thread arena in
//! [`super::native::scratch`] and is returned before the call ends.
//! All arena traffic happens on the calling thread (rayon workers receive
//! pre-partitioned slabs), so a warmed-up fwd+bwd performs **zero heap
//! allocations**; `benches/exec.rs` pins this with a counting allocator.

use super::math::{
    add_into, colsum_into, gelu_into, gelu_grad_mul, layernorm_into, layernorm_bwd_into,
    matmul_bias_into, matmul_nt_into, matmul_tn_acc, LnStats, PAR_THRESHOLD,
};
use super::native::scratch;
use super::simd;
use crate::runtime::manifest::ModelDims;
use crate::runtime::tensor::HostTensor;
use rayon::prelude::*;
use std::cell::RefCell;

/// Parameters per transformer layer, in canonical flat order (mirrors
/// `LAYER_PARAM_NAMES` in model.py).
pub const PARAMS_PER_LAYER: usize = 12;

/// Canonical per-layer parameter names (order is the contract).
pub const LAYER_PARAM_NAMES: [&str; PARAMS_PER_LAYER] = [
    "ln1_g", "ln1_b", "w_qkv", "b_qkv", "w_proj", "b_proj", "ln2_g", "ln2_b", "w_fc1", "b_fc1",
    "w_fc2", "b_fc2",
];

// ---------------------------------------------------------------------------
// Attention over the padded KV context
// ---------------------------------------------------------------------------

/// Causal attention for one slice: query position `t` (global `off + t`)
/// attends to buffer positions `0..=off+t`. `q` is `[B,S,H]`, `k_buf` /
/// `v_buf` are `[B,T,H]` with this slice's K/V already scattered at
/// `off`. Accumulates into `out` (`[B,S,H]`, caller-zeroed).
fn attention_fwd_into(
    d: &ModelDims,
    s: usize,
    off: usize,
    q: &[f32],
    k_buf: &[f32],
    v_buf: &[f32],
    out: &mut [f32],
) {
    let (b_n, t_len, h, nh, hd) = (d.batch, d.seq_len, d.hidden, d.num_heads, d.head_dim());
    let scale = 1.0 / (hd as f32).sqrt();
    let row = off + s;
    // per-batch score rows come from one caller-grabbed slab so rayon
    // workers never touch the arena
    let mut scores_all = scratch::grab(b_n * row);
    let per_b = |b: usize, out_b: &mut [f32], scores: &mut [f32]| {
        let q_b = &q[b * s * h..(b + 1) * s * h];
        let k_b = &k_buf[b * t_len * h..(b + 1) * t_len * h];
        let v_b = &v_buf[b * t_len * h..(b + 1) * t_len * h];
        for head in 0..nh {
            let hoff = head * hd;
            for t in 0..s {
                let p = off + t; // attends to 0..=p
                let qv = &q_b[t * h + hoff..t * h + hoff + hd];
                let mut mx = f32::NEG_INFINITY;
                for (j, sc) in scores.iter_mut().enumerate().take(p + 1) {
                    let kv = &k_b[j * h + hoff..j * h + hoff + hd];
                    let mut dot = 0f32;
                    for (&a, &b2) in qv.iter().zip(kv) {
                        dot += a * b2;
                    }
                    let v = dot * scale;
                    *sc = v;
                    if v > mx {
                        mx = v;
                    }
                }
                let mut z = 0f32;
                for sc in scores.iter_mut().take(p + 1) {
                    *sc = (*sc - mx).exp();
                    z += *sc;
                }
                let o = &mut out_b[t * h + hoff..t * h + hoff + hd];
                for (j, &w) in scores.iter().enumerate().take(p + 1) {
                    let wv = w / z;
                    let vv = &v_b[j * h + hoff..j * h + hoff + hd];
                    for (ov, &x) in o.iter_mut().zip(vv) {
                        *ov += wv * x;
                    }
                }
            }
        }
    };
    let work = b_n * nh * s * row * hd;
    if work >= PAR_THRESHOLD && b_n > 1 {
        out.par_chunks_mut(s * h)
            .zip(scores_all.par_chunks_mut(row))
            .enumerate()
            .for_each(|(b, (o, sc))| per_b(b, o, sc));
    } else {
        for (b, (o, sc)) in out.chunks_mut(s * h).zip(scores_all.chunks_mut(row)).enumerate() {
            per_b(b, o, sc);
        }
    }
    scratch::give(scores_all);
}

/// VJP of [`attention_fwd_into`]: recomputes the softmax weights and
/// accumulates into `g_q` (`[B,S,H]`), `g_k` / `g_v` (`[B,T,H]`), all
/// caller-zeroed.
#[allow(clippy::too_many_arguments)]
fn attention_bwd_into(
    d: &ModelDims,
    s: usize,
    off: usize,
    q: &[f32],
    k_buf: &[f32],
    v_buf: &[f32],
    g_out: &[f32],
    g_q: &mut [f32],
    g_k: &mut [f32],
    g_v: &mut [f32],
) {
    let (b_n, t_len, h, nh, hd) = (d.batch, d.seq_len, d.hidden, d.num_heads, d.head_dim());
    let scale = 1.0 / (hd as f32).sqrt();
    let row = off + s;
    let mut wg_all = scratch::grab(b_n * 2 * row);
    let per_b = |b: usize, gq_b: &mut [f32], gk_b: &mut [f32], gv_b: &mut [f32], wg: &mut [f32]| {
        let q_b = &q[b * s * h..(b + 1) * s * h];
        let k_b = &k_buf[b * t_len * h..(b + 1) * t_len * h];
        let v_b = &v_buf[b * t_len * h..(b + 1) * t_len * h];
        let go_b = &g_out[b * s * h..(b + 1) * s * h];
        let (w, gw) = wg.split_at_mut(row);
        for head in 0..nh {
            let hoff = head * hd;
            for t in 0..s {
                let p = off + t;
                let qv = &q_b[t * h + hoff..t * h + hoff + hd];
                // recompute softmax weights w[0..=p]
                let mut mx = f32::NEG_INFINITY;
                for (j, sc) in w.iter_mut().enumerate().take(p + 1) {
                    let kv = &k_b[j * h + hoff..j * h + hoff + hd];
                    let mut dot = 0f32;
                    for (&a, &b2) in qv.iter().zip(kv) {
                        dot += a * b2;
                    }
                    let v = dot * scale;
                    *sc = v;
                    if v > mx {
                        mx = v;
                    }
                }
                let mut z = 0f32;
                for sc in w.iter_mut().take(p + 1) {
                    *sc = (*sc - mx).exp();
                    z += *sc;
                }
                for sc in w.iter_mut().take(p + 1) {
                    *sc /= z;
                }
                let go = &go_b[t * h + hoff..t * h + hoff + hd];
                // g_w[j] = g_out · v_j ; g_v[j] += w[j] * g_out
                let mut dot_wgw = 0f32;
                for j in 0..=p {
                    let vv = &v_b[j * h + hoff..j * h + hoff + hd];
                    let mut acc = 0f32;
                    for (&a, &b2) in go.iter().zip(vv) {
                        acc += a * b2;
                    }
                    gw[j] = acc;
                    dot_wgw += w[j] * acc;
                    let gvj = &mut gv_b[j * h + hoff..j * h + hoff + hd];
                    for (o, &x) in gvj.iter_mut().zip(go) {
                        *o += w[j] * x;
                    }
                }
                // softmax VJP: g_s[j] = w[j]*(g_w[j] - Σ w·g_w), then the
                // scaled dot-product grads
                let gq_t = &mut gq_b[t * h + hoff..t * h + hoff + hd];
                for j in 0..=p {
                    let gs = w[j] * (gw[j] - dot_wgw) * scale;
                    let kv = &k_b[j * h + hoff..j * h + hoff + hd];
                    for (o, &x) in gq_t.iter_mut().zip(kv) {
                        *o += gs * x;
                    }
                    let gkj = &mut gk_b[j * h + hoff..j * h + hoff + hd];
                    for (o, &x) in gkj.iter_mut().zip(qv) {
                        *o += gs * x;
                    }
                }
            }
        }
    };
    let work = b_n * nh * s * row * hd;
    if work >= PAR_THRESHOLD && b_n > 1 {
        g_q.par_chunks_mut(s * h)
            .zip(
                g_k.par_chunks_mut(t_len * h)
                    .zip(g_v.par_chunks_mut(t_len * h).zip(wg_all.par_chunks_mut(2 * row))),
            )
            .enumerate()
            .for_each(|(b, (gq, (gk, (gv, wg))))| per_b(b, gq, gk, gv, wg));
    } else {
        for (b, (((gq, gk), gv), wg)) in g_q
            .chunks_mut(s * h)
            .zip(g_k.chunks_mut(t_len * h))
            .zip(g_v.chunks_mut(t_len * h))
            .zip(wg_all.chunks_mut(2 * row))
            .enumerate()
        {
            per_b(b, gq, gk, gv, wg);
        }
    }
    scratch::give(wg_all);
}

// ---------------------------------------------------------------------------
// One pre-LN GPT block over a token slice
// ---------------------------------------------------------------------------

/// Forward intermediates one layer's backward needs (rematerialized).
/// Every buffer is arena-owned; [`LayerCache::release`] returns them.
struct LayerCache {
    h_in: Vec<f32>,
    ln1: LnStats,
    x1: Vec<f32>,
    q: Vec<f32>,
    k_buf: Vec<f32>,
    v_buf: Vec<f32>,
    att: Vec<f32>,
    h2: Vec<f32>,
    ln2: LnStats,
    x2: Vec<f32>,
    mpre: Vec<f32>,
    gm: Vec<f32>,
}

impl LayerCache {
    fn release(self) {
        for v in [
            self.h_in,
            self.x1,
            self.q,
            self.k_buf,
            self.v_buf,
            self.att,
            self.h2,
            self.x2,
            self.mpre,
            self.gm,
            self.ln1.mean,
            self.ln1.rstd,
            self.ln2.mean,
            self.ln2.rstd,
        ] {
            scratch::give(v);
        }
    }
}

// Reusable `Vec<LayerCache>` spines (capacity NL) so `stage_bwd_into`
// doesn't heap-allocate the cache list each call.
thread_local! {
    static CACHE_POOL: RefCell<Vec<Vec<LayerCache>>> = const { RefCell::new(Vec::new()) };
}

fn take_caches() -> Vec<LayerCache> {
    CACHE_POOL.with(|p| p.borrow_mut().pop()).unwrap_or_default()
}

fn put_caches(v: Vec<LayerCache>) {
    debug_assert!(v.is_empty());
    CACHE_POOL.with(|p| {
        let mut p = p.borrow_mut();
        if p.len() < 4 {
            p.push(v);
        }
    });
}

/// Split `[rows, 3H]` into three `[rows, H]` buffers (jnp.split order).
fn split_qkv_into(qkv: &[f32], rows: usize, h: usize, q: &mut [f32], k: &mut [f32], v: &mut [f32]) {
    for r in 0..rows {
        let src = &qkv[r * 3 * h..(r + 1) * 3 * h];
        q[r * h..(r + 1) * h].copy_from_slice(&src[..h]);
        k[r * h..(r + 1) * h].copy_from_slice(&src[h..2 * h]);
        v[r * h..(r + 1) * h].copy_from_slice(&src[2 * h..]);
    }
}

/// Inverse interleave of [`split_qkv_into`] for the gradient.
fn merge_qkv(g_q: &[f32], g_k: &[f32], g_v: &[f32], rows: usize, h: usize, g_qkv: &mut [f32]) {
    for r in 0..rows {
        let dst = &mut g_qkv[r * 3 * h..(r + 1) * 3 * h];
        dst[..h].copy_from_slice(&g_q[r * h..(r + 1) * h]);
        dst[h..2 * h].copy_from_slice(&g_k[r * h..(r + 1) * h]);
        dst[2 * h..].copy_from_slice(&g_v[r * h..(r + 1) * h]);
    }
}

/// Scatter a `[B,S,H]` slice tensor into a `[B,T,H]` buffer at `off`.
fn scatter_slice(d: &ModelDims, s: usize, off: usize, src: &[f32], buf: &mut [f32]) {
    let (h, t_len) = (d.hidden, d.seq_len);
    for b in 0..d.batch {
        for t in 0..s {
            let dst = (b * t_len + off + t) * h;
            let sr = (b * s + t) * h;
            buf[dst..dst + h].copy_from_slice(&src[sr..sr + h]);
        }
    }
}

/// Gather the `[off, off+s)` window of a `[B,T,H]` buffer into `[B,S,H]`.
fn gather_slice_into(d: &ModelDims, s: usize, off: usize, buf: &[f32], out: &mut [f32]) {
    let (h, t_len) = (d.hidden, d.seq_len);
    for b in 0..d.batch {
        for t in 0..s {
            let src = (b * t_len + off + t) * h;
            let dst = (b * s + t) * h;
            out[dst..dst + h].copy_from_slice(&buf[src..src + h]);
        }
    }
}

/// Zero the `[off, off+s)` window of a `[B,T,H]` buffer (VJP of the
/// scatter w.r.t. the pre-scatter buffer).
fn zero_slice_window(d: &ModelDims, s: usize, off: usize, buf: &mut [f32]) {
    let (h, t_len) = (d.hidden, d.seq_len);
    for b in 0..d.batch {
        for t in 0..s {
            let dst = (b * t_len + off + t) * h;
            buf[dst..dst + h].iter_mut().for_each(|x| *x = 0.0);
        }
    }
}

/// One transformer layer forward. `lp` is the layer's 12 parameters in
/// canonical order; `k_ctx_l`/`v_ctx_l` are the layer's `[B,T,H]` context
/// buffers. Writes `h_out [B,S,H]` and this slice's `k_s`/`v_s`
/// (`[B,S,H]`, typically windows of the stage's `k_new`/`v_new`); `h_out`
/// must not alias `h`.
#[allow(clippy::too_many_arguments)]
fn layer_forward(
    d: &ModelDims,
    s: usize,
    off: usize,
    lp: &[HostTensor],
    h: &[f32],
    k_ctx_l: &[f32],
    v_ctx_l: &[f32],
    want_cache: bool,
    h_out: &mut [f32],
    k_s: &mut [f32],
    v_s: &mut [f32],
) -> Option<LayerCache> {
    let hd = d.hidden;
    let rows = d.batch * s;
    let f = 4 * hd;
    let (ln1_g, ln1_b) = (lp[0].as_f32(), lp[1].as_f32());
    let (w_qkv, b_qkv) = (lp[2].as_f32(), lp[3].as_f32());
    let (w_proj, b_proj) = (lp[4].as_f32(), lp[5].as_f32());
    let (ln2_g, ln2_b) = (lp[6].as_f32(), lp[7].as_f32());
    let (w_fc1, b_fc1) = (lp[8].as_f32(), lp[9].as_f32());
    let (w_fc2, b_fc2) = (lp[10].as_f32(), lp[11].as_f32());

    let mut x1 = scratch::grab(rows * hd);
    let mut m1 = scratch::grab(rows);
    let mut r1 = scratch::grab(rows);
    layernorm_into(h, ln1_g, ln1_b, hd, &mut x1, &mut m1, &mut r1);
    let mut qkv = scratch::grab(rows * 3 * hd);
    matmul_bias_into(&x1, w_qkv, b_qkv, rows, hd, 3 * hd, &mut qkv);
    let mut q = scratch::grab(rows * hd);
    split_qkv_into(&qkv, rows, hd, &mut q, k_s, v_s);
    scratch::give(qkv);

    let mut k_buf = scratch::grab_copy(k_ctx_l);
    let mut v_buf = scratch::grab_copy(v_ctx_l);
    scatter_slice(d, s, off, k_s, &mut k_buf);
    scatter_slice(d, s, off, v_s, &mut v_buf);

    let mut att = scratch::grab(rows * hd); // zeroed: attention accumulates
    attention_fwd_into(d, s, off, &q, &k_buf, &v_buf, &mut att);
    let mut h2 = scratch::grab(rows * hd);
    matmul_bias_into(&att, w_proj, b_proj, rows, hd, hd, &mut h2);
    add_into(&mut h2, h);

    let mut x2 = scratch::grab(rows * hd);
    let mut m2 = scratch::grab(rows);
    let mut r2 = scratch::grab(rows);
    layernorm_into(&h2, ln2_g, ln2_b, hd, &mut x2, &mut m2, &mut r2);
    let mut mpre = scratch::grab(rows * f);
    matmul_bias_into(&x2, w_fc1, b_fc1, rows, hd, f, &mut mpre);
    let mut gm = scratch::grab(rows * f);
    gelu_into(&mpre, &mut gm);
    matmul_bias_into(&gm, w_fc2, b_fc2, rows, f, hd, h_out);
    add_into(h_out, &h2);

    if want_cache {
        Some(LayerCache {
            h_in: scratch::grab_copy(h),
            ln1: LnStats { mean: m1, rstd: r1 },
            x1,
            q,
            k_buf,
            v_buf,
            att,
            h2,
            ln2: LnStats { mean: m2, rstd: r2 },
            x2,
            mpre,
            gm,
        })
    } else {
        for v in [x1, m1, r1, q, k_buf, v_buf, att, h2, x2, m2, r2, mpre, gm] {
            scratch::give(v);
        }
        None
    }
}

/// One layer's VJP. `g_h3` is the upstream hidden-state grad; `g_k_ext` /
/// `g_v_ext` (`[B,S,H]`) are the accumulated grads w.r.t. this slice's
/// own K/V contributed by later slices. Parameter grads accumulate into
/// `grads` (12 tensors, canonical order). Writes `g_h_in [B,S,H]` and the
/// layer's `[B,T,H]` context grads into `g_kctx_l`/`g_vctx_l`
/// (caller-zeroed; the slice's own window ends up zeroed — those grads
/// flowed into `g_qkv` instead).
#[allow(clippy::too_many_arguments)]
fn layer_backward(
    d: &ModelDims,
    s: usize,
    off: usize,
    lp: &[HostTensor],
    cache: &LayerCache,
    g_h3: &[f32],
    g_k_ext: &[f32],
    g_v_ext: &[f32],
    grads: &mut [HostTensor],
    g_h_in: &mut [f32],
    g_kctx_l: &mut [f32],
    g_vctx_l: &mut [f32],
) {
    let hd = d.hidden;
    let rows = d.batch * s;
    let f = 4 * hd;
    let (ln1_g, w_qkv, w_proj, ln2_g, w_fc1, w_fc2) = (
        lp[0].as_f32(),
        lp[2].as_f32(),
        lp[4].as_f32(),
        lp[6].as_f32(),
        lp[8].as_f32(),
        lp[10].as_f32(),
    );

    // --- MLP: h3 = h2 + gelu(x2 @ w_fc1 + b_fc1) @ w_fc2 + b_fc2 ---
    let mut g_gm = scratch::grab(rows * f);
    matmul_nt_into(g_h3, w_fc2, rows, hd, f, &mut g_gm);
    matmul_tn_acc(&cache.gm, g_h3, rows, f, hd, grads[10].as_f32_mut());
    colsum_into(g_h3, hd, grads[11].as_f32_mut());
    gelu_grad_mul(&cache.mpre, &mut g_gm); // g_gm is g_mpre from here on
    let mut g_x2 = scratch::grab(rows * hd);
    matmul_nt_into(&g_gm, w_fc1, rows, f, hd, &mut g_x2);
    matmul_tn_acc(&cache.x2, &g_gm, rows, hd, f, grads[8].as_f32_mut());
    colsum_into(&g_gm, f, grads[9].as_f32_mut());
    scratch::give(g_gm);
    let mut g_h2 = scratch::grab(rows * hd);
    {
        let (a, b) = grads.split_at_mut(7);
        layernorm_bwd_into(
            &cache.h2,
            &cache.ln2,
            ln2_g,
            &g_x2,
            hd,
            a[6].as_f32_mut(),
            b[0].as_f32_mut(),
            &mut g_h2,
        );
    }
    scratch::give(g_x2);
    add_into(&mut g_h2, g_h3); // residual

    // --- attention block: h2 = h + att @ w_proj + b_proj ---
    let mut g_att = scratch::grab(rows * hd);
    matmul_nt_into(&g_h2, w_proj, rows, hd, hd, &mut g_att);
    matmul_tn_acc(&cache.att, &g_h2, rows, hd, hd, grads[4].as_f32_mut());
    colsum_into(&g_h2, hd, grads[5].as_f32_mut());
    let mut g_q = scratch::grab(rows * hd); // zeroed: attention accumulates
    attention_bwd_into(
        d,
        s,
        off,
        &cache.q,
        &cache.k_buf,
        &cache.v_buf,
        &g_att,
        &mut g_q,
        g_kctx_l,
        g_vctx_l,
    );
    scratch::give(g_att);

    // VJP of the scatter: the slice window of the buffer grad flows into
    // this slice's K/V (plus the externally accumulated later-slice
    // grads); the rest is the context grad returned to the coordinator.
    let mut g_k_slice = scratch::grab(rows * hd);
    let mut g_v_slice = scratch::grab(rows * hd);
    gather_slice_into(d, s, off, g_kctx_l, &mut g_k_slice);
    gather_slice_into(d, s, off, g_vctx_l, &mut g_v_slice);
    add_into(&mut g_k_slice, g_k_ext);
    add_into(&mut g_v_slice, g_v_ext);
    zero_slice_window(d, s, off, g_kctx_l);
    zero_slice_window(d, s, off, g_vctx_l);

    // --- QKV projection: qkv = x1 @ w_qkv + b_qkv ---
    let mut g_qkv = scratch::grab(rows * 3 * hd);
    merge_qkv(&g_q, &g_k_slice, &g_v_slice, rows, hd, &mut g_qkv);
    for v in [g_q, g_k_slice, g_v_slice] {
        scratch::give(v);
    }
    let mut g_x1 = scratch::grab(rows * hd);
    matmul_nt_into(&g_qkv, w_qkv, rows, 3 * hd, hd, &mut g_x1);
    matmul_tn_acc(&cache.x1, &g_qkv, rows, hd, 3 * hd, grads[2].as_f32_mut());
    colsum_into(&g_qkv, 3 * hd, grads[3].as_f32_mut());
    scratch::give(g_qkv);
    {
        let (a, b) = grads.split_at_mut(1);
        layernorm_bwd_into(
            &cache.h_in,
            &cache.ln1,
            ln1_g,
            &g_x1,
            hd,
            a[0].as_f32_mut(),
            b[0].as_f32_mut(),
            g_h_in,
        );
    }
    scratch::give(g_x1);
    add_into(g_h_in, &g_h2); // residual
    scratch::give(g_h2);
}

// ---------------------------------------------------------------------------
// Stage, embedding and head cells
// ---------------------------------------------------------------------------

/// Shared forward walk: runs the stage's layers, writing the final hidden
/// state into `h_out` and each layer's slice K/V into `k_new`/`v_new`
/// windows. Returns the rematerialization caches when `want_cache`.
#[allow(clippy::too_many_arguments)]
fn stage_fwd_walk(
    d: &ModelDims,
    s: usize,
    off: usize,
    params: &[HostTensor],
    h: &[f32],
    k_ctx: &[f32],
    v_ctx: &[f32],
    want_cache: bool,
    h_out: &mut [f32],
    k_new: &mut [f32],
    v_new: &mut [f32],
) -> Vec<LayerCache> {
    let nl = d.layers_per_stage;
    assert_eq!(params.len(), nl * PARAMS_PER_LAYER, "stage param arity");
    let per_ctx = d.batch * d.seq_len * d.hidden;
    let per_new = d.batch * s * d.hidden;
    assert_eq!(h_out.len(), per_new);
    assert_eq!(k_new.len(), nl * per_new);
    assert_eq!(v_new.len(), nl * per_new);
    let mut caches = take_caches();
    let mut cur = scratch::grab_copy(h);
    let mut nxt = scratch::grab(per_new);
    for l in 0..nl {
        let lp = &params[l * PARAMS_PER_LAYER..(l + 1) * PARAMS_PER_LAYER];
        let target: &mut [f32] = if l == nl - 1 { h_out } else { &mut nxt };
        let cache = layer_forward(
            d,
            s,
            off,
            lp,
            &cur,
            &k_ctx[l * per_ctx..(l + 1) * per_ctx],
            &v_ctx[l * per_ctx..(l + 1) * per_ctx],
            want_cache,
            target,
            &mut k_new[l * per_new..(l + 1) * per_new],
            &mut v_new[l * per_new..(l + 1) * per_new],
        );
        if let Some(c) = cache {
            caches.push(c);
        }
        if l < nl - 1 {
            std::mem::swap(&mut cur, &mut nxt);
        }
    }
    scratch::give(cur);
    scratch::give(nxt);
    caches
}

/// One pipeline cell forward over one token slice (model.py `stage_fwd`)
/// into caller-provided buffers — the allocation-free hot path.
///
/// `params`: `NL · 12` tensors; `h`: `[B,S,H]`; `k_ctx`/`v_ctx`:
/// `[NL,B,T,H]`. Writes `h_out [B,S,H]` and `k_new`/`v_new [NL,B,S,H]`
/// (all fully overwritten).
#[allow(clippy::too_many_arguments)]
pub fn stage_fwd_into(
    d: &ModelDims,
    s: usize,
    off: usize,
    params: &[HostTensor],
    h: &[f32],
    k_ctx: &[f32],
    v_ctx: &[f32],
    h_out: &mut [f32],
    k_new: &mut [f32],
    v_new: &mut [f32],
) {
    let caches = stage_fwd_walk(d, s, off, params, h, k_ctx, v_ctx, false, h_out, k_new, v_new);
    put_caches(caches);
}

/// Allocating wrapper around [`stage_fwd_into`]: returns
/// `(h_out [B,S,H], k_new [NL,B,S,H], v_new)`.
pub fn stage_fwd(
    d: &ModelDims,
    s: usize,
    off: usize,
    params: &[HostTensor],
    h: &[f32],
    k_ctx: &[f32],
    v_ctx: &[f32],
) -> (Vec<f32>, Vec<f32>, Vec<f32>) {
    let nl = d.layers_per_stage;
    let per_new = d.batch * s * d.hidden;
    let mut h_out = vec![0f32; per_new];
    let mut k_new = vec![0f32; nl * per_new];
    let mut v_new = vec![0f32; nl * per_new];
    stage_fwd_into(d, s, off, params, h, k_ctx, v_ctx, &mut h_out, &mut k_new, &mut v_new);
    (h_out, k_new, v_new)
}

/// VJP of [`stage_fwd_into`] (recompute-based) into caller-provided
/// buffers. Parameter grads accumulate into `grads` (`NL · 12`, canonical
/// order); writes `g_h_in [B,S,H]` (overwritten) and `g_kctx`/`g_vctx`
/// (`[NL,B,T,H]`, **must be zeroed by the caller**).
#[allow(clippy::too_many_arguments)]
pub fn stage_bwd_into(
    d: &ModelDims,
    s: usize,
    off: usize,
    params: &[HostTensor],
    h_in: &[f32],
    k_ctx: &[f32],
    v_ctx: &[f32],
    g_hout: &[f32],
    g_know: &[f32],
    g_vnow: &[f32],
    grads: &mut [HostTensor],
    g_h_in: &mut [f32],
    g_kctx: &mut [f32],
    g_vctx: &mut [f32],
) {
    let nl = d.layers_per_stage;
    let per_ctx = d.batch * d.seq_len * d.hidden;
    let per_new = d.batch * s * d.hidden;
    // rematerialize the forward; the recomputed outputs are scratch
    let mut h_tmp = scratch::grab(per_new);
    let mut k_tmp = scratch::grab(nl * per_new);
    let mut v_tmp = scratch::grab(nl * per_new);
    let mut caches =
        stage_fwd_walk(d, s, off, params, h_in, k_ctx, v_ctx, true, &mut h_tmp, &mut k_tmp, &mut v_tmp);
    for v in [h_tmp, k_tmp, v_tmp] {
        scratch::give(v);
    }
    let mut g = scratch::grab_copy(g_hout);
    let mut g_next = scratch::grab(per_new);
    for l in (0..nl).rev() {
        let lp = &params[l * PARAMS_PER_LAYER..(l + 1) * PARAMS_PER_LAYER];
        let target: &mut [f32] = if l == 0 { g_h_in } else { &mut g_next };
        layer_backward(
            d,
            s,
            off,
            lp,
            &caches[l],
            &g,
            &g_know[l * per_new..(l + 1) * per_new],
            &g_vnow[l * per_new..(l + 1) * per_new],
            &mut grads[l * PARAMS_PER_LAYER..(l + 1) * PARAMS_PER_LAYER],
            target,
            &mut g_kctx[l * per_ctx..(l + 1) * per_ctx],
            &mut g_vctx[l * per_ctx..(l + 1) * per_ctx],
        );
        if l > 0 {
            std::mem::swap(&mut g, &mut g_next);
        }
    }
    scratch::give(g);
    scratch::give(g_next);
    for c in caches.drain(..) {
        c.release();
    }
    put_caches(caches);
}

/// Allocating wrapper around [`stage_bwd_into`]: returns
/// `(g_h_in [B,S,H], g_kctx [NL,B,T,H], g_vctx [NL,B,T,H])`.
#[allow(clippy::too_many_arguments)]
pub fn stage_bwd(
    d: &ModelDims,
    s: usize,
    off: usize,
    params: &[HostTensor],
    h_in: &[f32],
    k_ctx: &[f32],
    v_ctx: &[f32],
    g_hout: &[f32],
    g_know: &[f32],
    g_vnow: &[f32],
    grads: &mut [HostTensor],
) -> (Vec<f32>, Vec<f32>, Vec<f32>) {
    let nl = d.layers_per_stage;
    let per_ctx = d.batch * d.seq_len * d.hidden;
    let per_new = d.batch * s * d.hidden;
    let mut g_h_in = vec![0f32; per_new];
    let mut g_kctx = vec![0f32; nl * per_ctx];
    let mut g_vctx = vec![0f32; nl * per_ctx];
    stage_bwd_into(
        d, s, off, params, h_in, k_ctx, v_ctx, g_hout, g_know, g_vnow, grads, &mut g_h_in,
        &mut g_kctx, &mut g_vctx,
    );
    (g_h_in, g_kctx, g_vctx)
}

/// Token + position embedding for one slice (model.py `embed_fwd`).
/// `params`: `[tok_emb [V,H], pos_emb [T,H]]`; `tokens`: `B·S` ids.
pub fn embed_fwd(d: &ModelDims, s: usize, off: usize, params: &[HostTensor], tokens: &[i32]) -> Vec<f32> {
    let h = d.hidden;
    let tok_emb = params[0].as_f32();
    let pos_emb = params[1].as_f32();
    let mut out = vec![0f32; d.batch * s * h];
    for b in 0..d.batch {
        for t in 0..s {
            let tok = tokens[b * s + t] as usize;
            let dst = &mut out[(b * s + t) * h..(b * s + t + 1) * h];
            let te = &tok_emb[tok * h..(tok + 1) * h];
            let pe = &pos_emb[(off + t) * h..(off + t + 1) * h];
            for ((o, &a), &p) in dst.iter_mut().zip(te).zip(pe) {
                *o = a + p;
            }
        }
    }
    out
}

/// VJP of [`embed_fwd`]: scatter-add into the embedding grads.
pub fn embed_bwd(
    d: &ModelDims,
    s: usize,
    off: usize,
    tokens: &[i32],
    g_h: &[f32],
    grads: &mut [HostTensor],
) {
    let h = d.hidden;
    {
        let g_tok = grads[0].as_f32_mut();
        for b in 0..d.batch {
            for t in 0..s {
                let tok = tokens[b * s + t] as usize;
                let src = &g_h[(b * s + t) * h..(b * s + t + 1) * h];
                let dst = &mut g_tok[tok * h..(tok + 1) * h];
                for (o, &v) in dst.iter_mut().zip(src) {
                    *o += v;
                }
            }
        }
    }
    let g_pos = grads[1].as_f32_mut();
    for b in 0..d.batch {
        for t in 0..s {
            let src = &g_h[(b * s + t) * h..(b * s + t + 1) * h];
            let dst = &mut g_pos[(off + t) * h..(off + t + 1) * h];
            for (o, &v) in dst.iter_mut().zip(src) {
                *o += v;
            }
        }
    }
}

/// Final LN + LM head + summed token cross-entropy (model.py `head_fwd`).
/// `params`: `[lnf_g, lnf_b, w_out [H,V], b_out [V]]`. Returns the loss
/// summed over the slice's `B·S` tokens (rows reduced in ascending order,
/// so the total is thread-count independent).
pub fn head_fwd(d: &ModelDims, s: usize, params: &[HostTensor], h: &[f32], targets: &[i32]) -> f32 {
    let (hd, v) = (d.hidden, d.vocab);
    let rows = d.batch * s;
    let mut x = scratch::grab(rows * hd);
    let mut mean = scratch::grab(rows);
    let mut rstd = scratch::grab(rows);
    layernorm_into(h, params[0].as_f32(), params[1].as_f32(), hd, &mut x, &mut mean, &mut rstd);
    let mut logits = scratch::grab(rows * v);
    matmul_bias_into(&x, params[2].as_f32(), params[3].as_f32(), rows, hd, v, &mut logits);
    let mut row_loss = scratch::grab(rows);
    let ops = simd::ops();
    let per_row = |r: usize, row: &[f32]| -> f32 {
        let mx = (ops.row_max)(row);
        let z = (ops.exp_sum_sub)(row, mx);
        let gold = row[targets[r] as usize] - mx;
        z.ln() - gold
    };
    if rows * v >= PAR_THRESHOLD {
        row_loss
            .par_iter_mut()
            .zip(logits.par_chunks(v))
            .enumerate()
            .for_each(|(r, (o, row))| *o = per_row(r, row));
    } else {
        for (r, (o, row)) in row_loss.iter_mut().zip(logits.chunks(v)).enumerate() {
            *o = per_row(r, row);
        }
    }
    let loss = row_loss.iter().sum::<f32>();
    for b in [x, mean, rstd, logits, row_loss] {
        scratch::give(b);
    }
    loss
}

/// VJP of [`head_fwd`] with cotangent 1.0 on the loss: accumulates the
/// head parameter grads and returns `g_h [B,S,H]`.
pub fn head_bwd(
    d: &ModelDims,
    s: usize,
    params: &[HostTensor],
    h: &[f32],
    targets: &[i32],
    grads: &mut [HostTensor],
) -> Vec<f32> {
    let (hd, v) = (d.hidden, d.vocab);
    let rows = d.batch * s;
    let lnf_g = params[0].as_f32();
    let w_out = params[2].as_f32();
    let mut x = scratch::grab(rows * hd);
    let mut mean = scratch::grab(rows);
    let mut rstd = scratch::grab(rows);
    layernorm_into(h, lnf_g, params[1].as_f32(), hd, &mut x, &mut mean, &mut rstd);
    let mut g_logits = scratch::grab(rows * v);
    matmul_bias_into(&x, w_out, params[3].as_f32(), rows, hd, v, &mut g_logits);
    // g_logits = softmax(logits) - onehot(target), row-parallel
    let ops = simd::ops();
    let per_row = |r: usize, row: &mut [f32]| {
        let mx = (ops.row_max)(row);
        let z = (ops.exp_norm_sub)(row, mx);
        for l in row.iter_mut() {
            *l /= z;
        }
        row[targets[r] as usize] -= 1.0;
    };
    if rows * v >= PAR_THRESHOLD {
        g_logits.par_chunks_mut(v).enumerate().for_each(|(r, row)| per_row(r, row));
    } else {
        for (r, row) in g_logits.chunks_mut(v).enumerate() {
            per_row(r, row);
        }
    }
    let mut g_x = scratch::grab(rows * hd);
    matmul_nt_into(&g_logits, w_out, rows, v, hd, &mut g_x);
    matmul_tn_acc(&x, &g_logits, rows, hd, v, grads[2].as_f32_mut());
    colsum_into(&g_logits, v, grads[3].as_f32_mut());
    let stats = LnStats { mean, rstd };
    let mut g_h = vec![0f32; rows * hd];
    {
        let (a, b) = grads.split_at_mut(1);
        layernorm_bwd_into(h, &stats, lnf_g, &g_x, hd, a[0].as_f32_mut(), b[0].as_f32_mut(), &mut g_h);
    }
    for b in [x, g_logits, g_x, stats.mean, stats.rstd] {
        scratch::give(b);
    }
    g_h
}

/// Fused Adam over one parameter set (model.py `adam_step`): bias-corrected
/// moments, `p -= lr · (m/c1) / (sqrt(v/c2) + eps)`. Element-parallel for
/// large tensors (each element owned by one worker — bit-identical to the
/// serial sweep).
pub fn adam_step(
    params: &mut [HostTensor],
    grads: &[HostTensor],
    m: &mut [HostTensor],
    v: &mut [HostTensor],
    step: i32,
    lr: f32,
) {
    const CHUNK: usize = 1 << 13;
    let t = step as f32;
    let c1 = 1.0 - simd::ADAM_BETA1.powf(t);
    let c2 = 1.0 - simd::ADAM_BETA2.powf(t);
    let ops = simd::ops();
    let upd = |pd: &mut [f32], gd: &[f32], md: &mut [f32], vd: &mut [f32]| {
        (ops.adam_chunk)(pd, gd, md, vd, lr, c1, c2)
    };
    for (((p, g), mi), vi) in params.iter_mut().zip(grads).zip(m.iter_mut()).zip(v.iter_mut()) {
        let pd = p.as_f32_mut();
        let gd = g.as_f32();
        let md = mi.as_f32_mut();
        let vd = vi.as_f32_mut();
        if pd.len() >= PAR_THRESHOLD {
            pd.par_chunks_mut(CHUNK)
                .zip(gd.par_chunks(CHUNK).zip(md.par_chunks_mut(CHUNK).zip(vd.par_chunks_mut(CHUNK))))
                .for_each(|(pc, (gc, (mc, vc)))| upd(pc, gc, mc, vc));
        } else {
            upd(pd, gd, md, vd);
        }
    }
}
