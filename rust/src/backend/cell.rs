//! The sliced transformer cell: native-Rust forward and backward for one
//! pipeline stage, plus the embedding and LM-head cells.
//!
//! This is a line-for-line transcription of `python/compile/model.py`
//! (the functions `aot.py` lowers to the PJRT executables): pre-LN GPT
//! blocks over one token slice, causal attention over a padded KV context
//! buffer, tanh-GELU MLP, final layernorm + cross-entropy head, with the
//! VJPs written out by hand so the backward is *exact* — not approximate —
//! and `stage_bwd` returns the context K/V gradients the coordinator
//! accumulates into earlier slices (the dependency structure that makes
//! token-level pipelining a pure scheduling choice).
//!
//! Layouts (row-major, `H = num_heads · head_dim`):
//!
//! * hidden states `h`: `[B, S, H]` for slice length S
//! * per-layer KV context: `[B, T, H]` (T = full sequence length), the
//!   `[B, T, NH, HD]` view with the head axes merged
//! * stage KV buffers: `[NL, B, T, H]`; per-slice K/V: `[NL, B, S, H]`
//!
//! The backward recomputes the forward (rematerialization, exactly like
//! the `jax.vjp`-based executables) so callers only keep each slice's
//! *input* activation and the grown KV buffers.

use super::math::{
    add_bias, add_into, colsum_into, gelu, gelu_grad, layernorm, layernorm_bwd, matmul, matmul_nt,
    matmul_tn, LnStats, PAR_THRESHOLD,
};
use crate::runtime::manifest::ModelDims;
use crate::runtime::tensor::HostTensor;
use rayon::prelude::*;

/// Parameters per transformer layer, in canonical flat order (mirrors
/// `LAYER_PARAM_NAMES` in model.py).
pub const PARAMS_PER_LAYER: usize = 12;

/// Canonical per-layer parameter names (order is the contract).
pub const LAYER_PARAM_NAMES: [&str; PARAMS_PER_LAYER] = [
    "ln1_g", "ln1_b", "w_qkv", "b_qkv", "w_proj", "b_proj", "ln2_g", "ln2_b", "w_fc1", "b_fc1",
    "w_fc2", "b_fc2",
];

// ---------------------------------------------------------------------------
// Attention over the padded KV context
// ---------------------------------------------------------------------------

/// Causal attention for one slice: query position `t` (global `off + t`)
/// attends to buffer positions `0..=off+t`. `q` is `[B,S,H]`, `k_buf` /
/// `v_buf` are `[B,T,H]` with this slice's K/V already scattered at
/// `off`. Returns `[B,S,H]`.
fn attention_fwd(d: &ModelDims, s: usize, off: usize, q: &[f32], k_buf: &[f32], v_buf: &[f32]) -> Vec<f32> {
    let (b_n, t_len, h, nh, hd) = (d.batch, d.seq_len, d.hidden, d.num_heads, d.head_dim());
    let scale = 1.0 / (hd as f32).sqrt();
    let mut out = vec![0f32; b_n * s * h];
    let per_b = |b: usize, out_b: &mut [f32]| {
        let q_b = &q[b * s * h..(b + 1) * s * h];
        let k_b = &k_buf[b * t_len * h..(b + 1) * t_len * h];
        let v_b = &v_buf[b * t_len * h..(b + 1) * t_len * h];
        let mut scores = vec![0f32; off + s];
        for head in 0..nh {
            let hoff = head * hd;
            for t in 0..s {
                let p = off + t; // attends to 0..=p
                let qv = &q_b[t * h + hoff..t * h + hoff + hd];
                let mut mx = f32::NEG_INFINITY;
                for (j, sc) in scores.iter_mut().enumerate().take(p + 1) {
                    let kv = &k_b[j * h + hoff..j * h + hoff + hd];
                    let mut dot = 0f32;
                    for (&a, &b2) in qv.iter().zip(kv) {
                        dot += a * b2;
                    }
                    let v = dot * scale;
                    *sc = v;
                    if v > mx {
                        mx = v;
                    }
                }
                let mut z = 0f32;
                for sc in scores.iter_mut().take(p + 1) {
                    *sc = (*sc - mx).exp();
                    z += *sc;
                }
                let o = &mut out_b[t * h + hoff..t * h + hoff + hd];
                for (j, &w) in scores.iter().enumerate().take(p + 1) {
                    let wv = w / z;
                    let vv = &v_b[j * h + hoff..j * h + hoff + hd];
                    for (ov, &x) in o.iter_mut().zip(vv) {
                        *ov += wv * x;
                    }
                }
            }
        }
    };
    let work = b_n * nh * s * (off + s) * hd;
    if work >= PAR_THRESHOLD && b_n > 1 {
        out.par_chunks_mut(s * h).enumerate().for_each(|(b, o)| per_b(b, o));
    } else {
        for (b, o) in out.chunks_mut(s * h).enumerate() {
            per_b(b, o);
        }
    }
    out
}

/// VJP of [`attention_fwd`]: recomputes the softmax weights and returns
/// `(g_q [B,S,H], g_kbuf [B,T,H], g_vbuf [B,T,H])`.
fn attention_bwd(
    d: &ModelDims,
    s: usize,
    off: usize,
    q: &[f32],
    k_buf: &[f32],
    v_buf: &[f32],
    g_out: &[f32],
) -> (Vec<f32>, Vec<f32>, Vec<f32>) {
    let (b_n, t_len, h, nh, hd) = (d.batch, d.seq_len, d.hidden, d.num_heads, d.head_dim());
    let scale = 1.0 / (hd as f32).sqrt();
    let mut g_q = vec![0f32; b_n * s * h];
    let mut g_k = vec![0f32; b_n * t_len * h];
    let mut g_v = vec![0f32; b_n * t_len * h];
    let per_b = |b: usize, gq_b: &mut [f32], gk_b: &mut [f32], gv_b: &mut [f32]| {
        let q_b = &q[b * s * h..(b + 1) * s * h];
        let k_b = &k_buf[b * t_len * h..(b + 1) * t_len * h];
        let v_b = &v_buf[b * t_len * h..(b + 1) * t_len * h];
        let go_b = &g_out[b * s * h..(b + 1) * s * h];
        let mut w = vec![0f32; off + s];
        let mut gw = vec![0f32; off + s];
        for head in 0..nh {
            let hoff = head * hd;
            for t in 0..s {
                let p = off + t;
                let qv = &q_b[t * h + hoff..t * h + hoff + hd];
                // recompute softmax weights w[0..=p]
                let mut mx = f32::NEG_INFINITY;
                for (j, sc) in w.iter_mut().enumerate().take(p + 1) {
                    let kv = &k_b[j * h + hoff..j * h + hoff + hd];
                    let mut dot = 0f32;
                    for (&a, &b2) in qv.iter().zip(kv) {
                        dot += a * b2;
                    }
                    let v = dot * scale;
                    *sc = v;
                    if v > mx {
                        mx = v;
                    }
                }
                let mut z = 0f32;
                for sc in w.iter_mut().take(p + 1) {
                    *sc = (*sc - mx).exp();
                    z += *sc;
                }
                for sc in w.iter_mut().take(p + 1) {
                    *sc /= z;
                }
                let go = &go_b[t * h + hoff..t * h + hoff + hd];
                // g_w[j] = g_out · v_j ; g_v[j] += w[j] * g_out
                let mut dot_wgw = 0f32;
                for j in 0..=p {
                    let vv = &v_b[j * h + hoff..j * h + hoff + hd];
                    let mut acc = 0f32;
                    for (&a, &b2) in go.iter().zip(vv) {
                        acc += a * b2;
                    }
                    gw[j] = acc;
                    dot_wgw += w[j] * acc;
                    let gvj = &mut gv_b[j * h + hoff..j * h + hoff + hd];
                    for (o, &x) in gvj.iter_mut().zip(go) {
                        *o += w[j] * x;
                    }
                }
                // softmax VJP: g_s[j] = w[j]*(g_w[j] - Σ w·g_w), then the
                // scaled dot-product grads
                let gq_t = &mut gq_b[t * h + hoff..t * h + hoff + hd];
                for j in 0..=p {
                    let gs = w[j] * (gw[j] - dot_wgw) * scale;
                    let kv = &k_b[j * h + hoff..j * h + hoff + hd];
                    for (o, &x) in gq_t.iter_mut().zip(kv) {
                        *o += gs * x;
                    }
                    let gkj = &mut gk_b[j * h + hoff..j * h + hoff + hd];
                    for (o, &x) in gkj.iter_mut().zip(qv) {
                        *o += gs * x;
                    }
                }
            }
        }
    };
    let work = b_n * nh * s * (off + s) * hd;
    if work >= PAR_THRESHOLD && b_n > 1 {
        g_q.par_chunks_mut(s * h)
            .zip(g_k.par_chunks_mut(t_len * h).zip(g_v.par_chunks_mut(t_len * h)))
            .enumerate()
            .for_each(|(b, (gq, (gk, gv)))| per_b(b, gq, gk, gv));
    } else {
        for (b, ((gq, gk), gv)) in g_q
            .chunks_mut(s * h)
            .zip(g_k.chunks_mut(t_len * h))
            .zip(g_v.chunks_mut(t_len * h))
            .enumerate()
        {
            per_b(b, gq, gk, gv);
        }
    }
    (g_q, g_k, g_v)
}

// ---------------------------------------------------------------------------
// One pre-LN GPT block over a token slice
// ---------------------------------------------------------------------------

/// Forward intermediates one layer's backward needs (rematerialized).
struct LayerCache {
    h_in: Vec<f32>,
    ln1: LnStats,
    x1: Vec<f32>,
    q: Vec<f32>,
    k_buf: Vec<f32>,
    v_buf: Vec<f32>,
    att: Vec<f32>,
    h2: Vec<f32>,
    ln2: LnStats,
    x2: Vec<f32>,
    mpre: Vec<f32>,
    gm: Vec<f32>,
}

/// Split `[rows, 3H]` into three `[rows, H]` buffers (jnp.split order).
fn split_qkv(qkv: &[f32], rows: usize, h: usize) -> (Vec<f32>, Vec<f32>, Vec<f32>) {
    let mut q = vec![0f32; rows * h];
    let mut k = vec![0f32; rows * h];
    let mut v = vec![0f32; rows * h];
    for r in 0..rows {
        let src = &qkv[r * 3 * h..(r + 1) * 3 * h];
        q[r * h..(r + 1) * h].copy_from_slice(&src[..h]);
        k[r * h..(r + 1) * h].copy_from_slice(&src[h..2 * h]);
        v[r * h..(r + 1) * h].copy_from_slice(&src[2 * h..]);
    }
    (q, k, v)
}

/// Scatter a `[B,S,H]` slice tensor into a `[B,T,H]` buffer at `off`.
fn scatter_slice(d: &ModelDims, s: usize, off: usize, src: &[f32], buf: &mut [f32]) {
    let (h, t_len) = (d.hidden, d.seq_len);
    for b in 0..d.batch {
        for t in 0..s {
            let dst = (b * t_len + off + t) * h;
            let sr = (b * s + t) * h;
            buf[dst..dst + h].copy_from_slice(&src[sr..sr + h]);
        }
    }
}

/// Gather the `[off, off+s)` window of a `[B,T,H]` buffer into `[B,S,H]`.
fn gather_slice(d: &ModelDims, s: usize, off: usize, buf: &[f32]) -> Vec<f32> {
    let (h, t_len) = (d.hidden, d.seq_len);
    let mut out = vec![0f32; d.batch * s * h];
    for b in 0..d.batch {
        for t in 0..s {
            let src = (b * t_len + off + t) * h;
            let dst = (b * s + t) * h;
            out[dst..dst + h].copy_from_slice(&buf[src..src + h]);
        }
    }
    out
}

/// Zero the `[off, off+s)` window of a `[B,T,H]` buffer (VJP of the
/// scatter w.r.t. the pre-scatter buffer).
fn zero_slice_window(d: &ModelDims, s: usize, off: usize, buf: &mut [f32]) {
    let (h, t_len) = (d.hidden, d.seq_len);
    for b in 0..d.batch {
        for t in 0..s {
            let dst = (b * t_len + off + t) * h;
            buf[dst..dst + h].iter_mut().for_each(|x| *x = 0.0);
        }
    }
}

/// One transformer layer forward. `lp` is the layer's 12 parameters in
/// canonical order; `k_ctx_l`/`v_ctx_l` are the layer's `[B,T,H]` context
/// buffers. Returns `(h_out, k_slice, v_slice, cache?)`.
#[allow(clippy::too_many_arguments)]
fn layer_forward(
    d: &ModelDims,
    s: usize,
    off: usize,
    lp: &[HostTensor],
    h: &[f32],
    k_ctx_l: &[f32],
    v_ctx_l: &[f32],
    want_cache: bool,
) -> (Vec<f32>, Vec<f32>, Vec<f32>, Option<LayerCache>) {
    let hd = d.hidden;
    let rows = d.batch * s;
    let f = 4 * hd;
    let (ln1_g, ln1_b) = (lp[0].as_f32(), lp[1].as_f32());
    let (w_qkv, b_qkv) = (lp[2].as_f32(), lp[3].as_f32());
    let (w_proj, b_proj) = (lp[4].as_f32(), lp[5].as_f32());
    let (ln2_g, ln2_b) = (lp[6].as_f32(), lp[7].as_f32());
    let (w_fc1, b_fc1) = (lp[8].as_f32(), lp[9].as_f32());
    let (w_fc2, b_fc2) = (lp[10].as_f32(), lp[11].as_f32());

    let (x1, ln1) = layernorm(h, ln1_g, ln1_b, hd);
    let mut qkv = matmul(&x1, w_qkv, rows, hd, 3 * hd);
    add_bias(&mut qkv, b_qkv);
    let (q, k_slice, v_slice) = split_qkv(&qkv, rows, hd);

    let mut k_buf = k_ctx_l.to_vec();
    let mut v_buf = v_ctx_l.to_vec();
    scatter_slice(d, s, off, &k_slice, &mut k_buf);
    scatter_slice(d, s, off, &v_slice, &mut v_buf);

    let att = attention_fwd(d, s, off, &q, &k_buf, &v_buf);
    let mut h2 = matmul(&att, w_proj, rows, hd, hd);
    add_bias(&mut h2, b_proj);
    add_into(&mut h2, h);

    let (x2, ln2) = layernorm(&h2, ln2_g, ln2_b, hd);
    let mut mpre = matmul(&x2, w_fc1, rows, hd, f);
    add_bias(&mut mpre, b_fc1);
    let gm = gelu(&mpre);
    let mut h3 = matmul(&gm, w_fc2, rows, f, hd);
    add_bias(&mut h3, b_fc2);
    add_into(&mut h3, &h2);

    let cache = want_cache.then(|| LayerCache {
        h_in: h.to_vec(),
        ln1,
        x1,
        q,
        k_buf,
        v_buf,
        att,
        h2,
        ln2,
        x2,
        mpre,
        gm,
    });
    (h3, k_slice, v_slice, cache)
}

/// One layer's VJP. `g_h3` is the upstream hidden-state grad; `g_k_ext` /
/// `g_v_ext` (`[B,S,H]`) are the accumulated grads w.r.t. this slice's
/// own K/V contributed by later slices. Parameter grads accumulate into
/// `grads` (12 tensors, canonical order). Returns
/// `(g_h_in, g_kctx_l, g_vctx_l)` — the latter two `[B,T,H]` with the
/// slice's own window zeroed (those grads flowed into `g_qkv` instead).
#[allow(clippy::too_many_arguments)]
fn layer_backward(
    d: &ModelDims,
    s: usize,
    off: usize,
    lp: &[HostTensor],
    cache: &LayerCache,
    g_h3: &[f32],
    g_k_ext: &[f32],
    g_v_ext: &[f32],
    grads: &mut [HostTensor],
) -> (Vec<f32>, Vec<f32>, Vec<f32>) {
    let hd = d.hidden;
    let rows = d.batch * s;
    let f = 4 * hd;
    let (ln1_g, w_qkv, w_proj, ln2_g, w_fc1, w_fc2) = (
        lp[0].as_f32(),
        lp[2].as_f32(),
        lp[4].as_f32(),
        lp[6].as_f32(),
        lp[8].as_f32(),
        lp[10].as_f32(),
    );

    // --- MLP: h3 = h2 + gelu(x2 @ w_fc1 + b_fc1) @ w_fc2 + b_fc2 ---
    let g_gm = matmul_nt(g_h3, w_fc2, rows, hd, f);
    add_into(grads[10].as_f32_mut(), &matmul_tn(&cache.gm, g_h3, rows, f, hd));
    colsum_into(g_h3, hd, grads[11].as_f32_mut());
    let gp = gelu_grad(&cache.mpre);
    let g_mpre: Vec<f32> = g_gm.iter().zip(&gp).map(|(&a, &b)| a * b).collect();
    let g_x2 = matmul_nt(&g_mpre, w_fc1, rows, f, hd);
    add_into(grads[8].as_f32_mut(), &matmul_tn(&cache.x2, &g_mpre, rows, hd, f));
    colsum_into(&g_mpre, f, grads[9].as_f32_mut());
    let (gg, gb) = {
        let (a, b) = grads.split_at_mut(7);
        (a[6].as_f32_mut(), b[0].as_f32_mut())
    };
    let mut g_h2 = layernorm_bwd(&cache.h2, &cache.ln2, ln2_g, &g_x2, hd, gg, gb);
    add_into(&mut g_h2, g_h3); // residual

    // --- attention block: h2 = h + att @ w_proj + b_proj ---
    let g_att = matmul_nt(&g_h2, w_proj, rows, hd, hd);
    add_into(grads[4].as_f32_mut(), &matmul_tn(&cache.att, &g_h2, rows, hd, hd));
    colsum_into(&g_h2, hd, grads[5].as_f32_mut());
    let (g_q, mut g_kbuf, mut g_vbuf) =
        attention_bwd(d, s, off, &cache.q, &cache.k_buf, &cache.v_buf, &g_att);

    // VJP of the scatter: the slice window of the buffer grad flows into
    // this slice's K/V (plus the externally accumulated later-slice
    // grads); the rest is the context grad returned to the coordinator.
    let mut g_k_slice = gather_slice(d, s, off, &g_kbuf);
    let mut g_v_slice = gather_slice(d, s, off, &g_vbuf);
    add_into(&mut g_k_slice, g_k_ext);
    add_into(&mut g_v_slice, g_v_ext);
    zero_slice_window(d, s, off, &mut g_kbuf);
    zero_slice_window(d, s, off, &mut g_vbuf);

    // --- QKV projection: qkv = x1 @ w_qkv + b_qkv ---
    let mut g_qkv = vec![0f32; rows * 3 * hd];
    for r in 0..rows {
        let dst = &mut g_qkv[r * 3 * hd..(r + 1) * 3 * hd];
        dst[..hd].copy_from_slice(&g_q[r * hd..(r + 1) * hd]);
        dst[hd..2 * hd].copy_from_slice(&g_k_slice[r * hd..(r + 1) * hd]);
        dst[2 * hd..].copy_from_slice(&g_v_slice[r * hd..(r + 1) * hd]);
    }
    let g_x1 = matmul_nt(&g_qkv, w_qkv, rows, 3 * hd, hd);
    add_into(grads[2].as_f32_mut(), &matmul_tn(&cache.x1, &g_qkv, rows, hd, 3 * hd));
    colsum_into(&g_qkv, 3 * hd, grads[3].as_f32_mut());
    let (gg, gb) = {
        let (a, b) = grads.split_at_mut(1);
        (a[0].as_f32_mut(), b[0].as_f32_mut())
    };
    let mut g_h = layernorm_bwd(&cache.h_in, &cache.ln1, ln1_g, &g_x1, hd, gg, gb);
    add_into(&mut g_h, &g_h2); // residual

    (g_h, g_kbuf, g_vbuf)
}

// ---------------------------------------------------------------------------
// Stage, embedding and head cells
// ---------------------------------------------------------------------------

/// One pipeline cell forward over one token slice (model.py `stage_fwd`).
///
/// `params`: `NL · 12` tensors; `h`: `[B,S,H]`; `k_ctx`/`v_ctx`:
/// `[NL,B,T,H]`. Returns `(h_out [B,S,H], k_new [NL,B,S,H], v_new)`.
pub fn stage_fwd(
    d: &ModelDims,
    s: usize,
    off: usize,
    params: &[HostTensor],
    h: &[f32],
    k_ctx: &[f32],
    v_ctx: &[f32],
) -> (Vec<f32>, Vec<f32>, Vec<f32>) {
    let (out, k_new, v_new, _) = stage_fwd_cached(d, s, off, params, h, k_ctx, v_ctx, false);
    (out, k_new, v_new)
}

#[allow(clippy::too_many_arguments)]
fn stage_fwd_cached(
    d: &ModelDims,
    s: usize,
    off: usize,
    params: &[HostTensor],
    h: &[f32],
    k_ctx: &[f32],
    v_ctx: &[f32],
    want_cache: bool,
) -> (Vec<f32>, Vec<f32>, Vec<f32>, Vec<LayerCache>) {
    let nl = d.layers_per_stage;
    assert_eq!(params.len(), nl * PARAMS_PER_LAYER, "stage param arity");
    let per_ctx = d.batch * d.seq_len * d.hidden;
    let per_new = d.batch * s * d.hidden;
    let mut k_new = vec![0f32; nl * per_new];
    let mut v_new = vec![0f32; nl * per_new];
    let mut caches = Vec::with_capacity(if want_cache { nl } else { 0 });
    let mut cur = h.to_vec();
    for l in 0..nl {
        let lp = &params[l * PARAMS_PER_LAYER..(l + 1) * PARAMS_PER_LAYER];
        let (next, k_s, v_s, cache) = layer_forward(
            d,
            s,
            off,
            lp,
            &cur,
            &k_ctx[l * per_ctx..(l + 1) * per_ctx],
            &v_ctx[l * per_ctx..(l + 1) * per_ctx],
            want_cache,
        );
        k_new[l * per_new..(l + 1) * per_new].copy_from_slice(&k_s);
        v_new[l * per_new..(l + 1) * per_new].copy_from_slice(&v_s);
        if let Some(c) = cache {
            caches.push(c);
        }
        cur = next;
    }
    (cur, k_new, v_new, caches)
}

/// VJP of [`stage_fwd`] (recompute-based). Parameter grads accumulate
/// into `grads` (`NL · 12`, canonical order); returns
/// `(g_h_in [B,S,H], g_kctx [NL,B,T,H], g_vctx [NL,B,T,H])`.
#[allow(clippy::too_many_arguments)]
pub fn stage_bwd(
    d: &ModelDims,
    s: usize,
    off: usize,
    params: &[HostTensor],
    h_in: &[f32],
    k_ctx: &[f32],
    v_ctx: &[f32],
    g_hout: &[f32],
    g_know: &[f32],
    g_vnow: &[f32],
    grads: &mut [HostTensor],
) -> (Vec<f32>, Vec<f32>, Vec<f32>) {
    let nl = d.layers_per_stage;
    let per_ctx = d.batch * d.seq_len * d.hidden;
    let per_new = d.batch * s * d.hidden;
    let (_, _, _, caches) = stage_fwd_cached(d, s, off, params, h_in, k_ctx, v_ctx, true);
    let mut g = g_hout.to_vec();
    let mut g_kctx = vec![0f32; nl * per_ctx];
    let mut g_vctx = vec![0f32; nl * per_ctx];
    for l in (0..nl).rev() {
        let lp = &params[l * PARAMS_PER_LAYER..(l + 1) * PARAMS_PER_LAYER];
        let (g_new, g_kl, g_vl) = layer_backward(
            d,
            s,
            off,
            lp,
            &caches[l],
            &g,
            &g_know[l * per_new..(l + 1) * per_new],
            &g_vnow[l * per_new..(l + 1) * per_new],
            &mut grads[l * PARAMS_PER_LAYER..(l + 1) * PARAMS_PER_LAYER],
        );
        g = g_new;
        g_kctx[l * per_ctx..(l + 1) * per_ctx].copy_from_slice(&g_kl);
        g_vctx[l * per_ctx..(l + 1) * per_ctx].copy_from_slice(&g_vl);
    }
    (g, g_kctx, g_vctx)
}

/// Token + position embedding for one slice (model.py `embed_fwd`).
/// `params`: `[tok_emb [V,H], pos_emb [T,H]]`; `tokens`: `B·S` ids.
pub fn embed_fwd(d: &ModelDims, s: usize, off: usize, params: &[HostTensor], tokens: &[i32]) -> Vec<f32> {
    let h = d.hidden;
    let tok_emb = params[0].as_f32();
    let pos_emb = params[1].as_f32();
    let mut out = vec![0f32; d.batch * s * h];
    for b in 0..d.batch {
        for t in 0..s {
            let tok = tokens[b * s + t] as usize;
            let dst = &mut out[(b * s + t) * h..(b * s + t + 1) * h];
            let te = &tok_emb[tok * h..(tok + 1) * h];
            let pe = &pos_emb[(off + t) * h..(off + t + 1) * h];
            for ((o, &a), &p) in dst.iter_mut().zip(te).zip(pe) {
                *o = a + p;
            }
        }
    }
    out
}

/// VJP of [`embed_fwd`]: scatter-add into the embedding grads.
pub fn embed_bwd(
    d: &ModelDims,
    s: usize,
    off: usize,
    tokens: &[i32],
    g_h: &[f32],
    grads: &mut [HostTensor],
) {
    let h = d.hidden;
    {
        let g_tok = grads[0].as_f32_mut();
        for b in 0..d.batch {
            for t in 0..s {
                let tok = tokens[b * s + t] as usize;
                let src = &g_h[(b * s + t) * h..(b * s + t + 1) * h];
                let dst = &mut g_tok[tok * h..(tok + 1) * h];
                for (o, &v) in dst.iter_mut().zip(src) {
                    *o += v;
                }
            }
        }
    }
    let g_pos = grads[1].as_f32_mut();
    for b in 0..d.batch {
        for t in 0..s {
            let src = &g_h[(b * s + t) * h..(b * s + t + 1) * h];
            let dst = &mut g_pos[(off + t) * h..(off + t + 1) * h];
            for (o, &v) in dst.iter_mut().zip(src) {
                *o += v;
            }
        }
    }
}

/// Final LN + LM head + summed token cross-entropy (model.py `head_fwd`).
/// `params`: `[lnf_g, lnf_b, w_out [H,V], b_out [V]]`. Returns the loss
/// summed over the slice's `B·S` tokens.
pub fn head_fwd(d: &ModelDims, s: usize, params: &[HostTensor], h: &[f32], targets: &[i32]) -> f32 {
    let (hd, v) = (d.hidden, d.vocab);
    let rows = d.batch * s;
    let (x, _) = layernorm(h, params[0].as_f32(), params[1].as_f32(), hd);
    let mut logits = matmul(&x, params[2].as_f32(), rows, hd, v);
    add_bias(&mut logits, params[3].as_f32());
    let mut loss = 0f32;
    for r in 0..rows {
        let row = &logits[r * v..(r + 1) * v];
        let mx = row.iter().fold(f32::NEG_INFINITY, |a, &b| a.max(b));
        let z: f32 = row.iter().map(|&l| (l - mx).exp()).sum();
        let gold = row[targets[r] as usize] - mx;
        loss += z.ln() - gold;
    }
    loss
}

/// VJP of [`head_fwd`] with cotangent 1.0 on the loss: accumulates the
/// head parameter grads and returns `g_h [B,S,H]`.
pub fn head_bwd(
    d: &ModelDims,
    s: usize,
    params: &[HostTensor],
    h: &[f32],
    targets: &[i32],
    grads: &mut [HostTensor],
) -> Vec<f32> {
    let (hd, v) = (d.hidden, d.vocab);
    let rows = d.batch * s;
    let lnf_g = params[0].as_f32();
    let w_out = params[2].as_f32();
    let (x, stats) = layernorm(h, lnf_g, params[1].as_f32(), hd);
    let mut logits = matmul(&x, w_out, rows, hd, v);
    add_bias(&mut logits, params[3].as_f32());
    // g_logits = softmax(logits) - onehot(target)
    let mut g_logits = logits;
    for r in 0..rows {
        let row = &mut g_logits[r * v..(r + 1) * v];
        let mx = row.iter().fold(f32::NEG_INFINITY, |a, &b| a.max(b));
        let mut z = 0f32;
        for l in row.iter_mut() {
            *l = (*l - mx).exp();
            z += *l;
        }
        for l in row.iter_mut() {
            *l /= z;
        }
        row[targets[r] as usize] -= 1.0;
    }
    let g_x = matmul_nt(&g_logits, w_out, rows, v, hd);
    add_into(grads[2].as_f32_mut(), &matmul_tn(&x, &g_logits, rows, hd, v));
    colsum_into(&g_logits, v, grads[3].as_f32_mut());
    let (gg, gb) = {
        let (a, b) = grads.split_at_mut(1);
        (a[0].as_f32_mut(), b[0].as_f32_mut())
    };
    layernorm_bwd(h, &stats, lnf_g, &g_x, hd, gg, gb)
}

/// Fused Adam over one parameter set (model.py `adam_step`): bias-corrected
/// moments, `p -= lr · (m/c1) / (sqrt(v/c2) + eps)`.
pub fn adam_step(
    params: &mut [HostTensor],
    grads: &[HostTensor],
    m: &mut [HostTensor],
    v: &mut [HostTensor],
    step: i32,
    lr: f32,
) {
    const BETA1: f32 = 0.9;
    const BETA2: f32 = 0.999;
    const EPS: f32 = 1e-8;
    let t = step as f32;
    let c1 = 1.0 - BETA1.powf(t);
    let c2 = 1.0 - BETA2.powf(t);
    for (((p, g), mi), vi) in params.iter_mut().zip(grads).zip(m.iter_mut()).zip(v.iter_mut()) {
        let pd = p.as_f32_mut();
        let gd = g.as_f32();
        let md = mi.as_f32_mut();
        let vd = vi.as_f32_mut();
        for i in 0..pd.len() {
            md[i] = BETA1 * md[i] + (1.0 - BETA1) * gd[i];
            vd[i] = BETA2 * vd[i] + (1.0 - BETA2) * gd[i] * gd[i];
            pd[i] -= lr * (md[i] / c1) / ((vd[i] / c2).sqrt() + EPS);
        }
    }
}
