//! Scalar kernel tier — the inner loops of the PR 6 cache-blocked
//! kernels, moved here verbatim so the blocked outer structure in
//! `math.rs`/`cell.rs` can dispatch over [`super::KernelOps`].
//!
//! Every reduction keeps one accumulator in fixed ascending order and
//! Rust/LLVM does not contract `a * b + c` into an FMA, so this tier is
//! bit-identical to the naive `*_ref` oracles — it is the determinism
//! baseline the AVX2 tier is tolerance-pinned against, and the tier
//! `TERAPIPE_NO_SIMD` forces.

#![allow(clippy::needless_range_loop)] // index loops are the idiom in kernels

use super::{ADAM_BETA1, ADAM_BETA2, ADAM_EPS, MR, NR, NT_TILE};

/// `MR×NR` register microkernel: `acc[r][c] = Σ_l a[i0+r, l] · panel[l, c]`
/// with `l` strictly ascending and one accumulator per element — the same
/// reduction order as `matmul_ref`, hence bit-identical results.
pub fn mm_micro(a: &[f32], i0: usize, mr: usize, k: usize, strip: &[f32], acc: &mut [[f32; NR]; MR]) {
    for row in acc.iter_mut() {
        *row = [0.0; NR];
    }
    if mr == MR {
        // hot case with constant bounds so the 4×8 accumulators stay in registers
        let (a0, a1, a2, a3) = (
            &a[i0 * k..(i0 + 1) * k],
            &a[(i0 + 1) * k..(i0 + 2) * k],
            &a[(i0 + 2) * k..(i0 + 3) * k],
            &a[(i0 + 3) * k..(i0 + 4) * k],
        );
        for l in 0..k {
            let bp = &strip[l * NR..l * NR + NR];
            let (x0, x1, x2, x3) = (a0[l], a1[l], a2[l], a3[l]);
            for c in 0..NR {
                let bv = bp[c];
                acc[0][c] += x0 * bv;
                acc[1][c] += x1 * bv;
                acc[2][c] += x2 * bv;
                acc[3][c] += x3 * bv;
            }
        }
    } else {
        for l in 0..k {
            let bp = &strip[l * NR..l * NR + NR];
            for r in 0..mr {
                let av = a[(i0 + r) * k + l];
                for c in 0..NR {
                    acc[r][c] += av * bp[c];
                }
            }
        }
    }
}

/// 1×NR microkernel for the column-parallel (skinny-M) matmul path;
/// accumulates into caller-zeroed `acc` in the same ascending-`l` order
/// as [`mm_micro`].
pub fn mm_panel_row(ar: &[f32], strip: &[f32], k: usize, acc: &mut [f32; NR]) {
    for l in 0..k {
        let bp = &strip[l * NR..l * NR + NR];
        let av = ar[l];
        for c in 0..NR {
            acc[c] += av * bp[c];
        }
    }
}

/// 4×4 dot-product tile for `matmul_nt`: 16 independent sequential
/// chains (ILP) with the per-dot order of `matmul_nt_ref`, hence
/// bit-identical. `acc` arrives zeroed from the caller.
#[allow(clippy::too_many_arguments)]
pub fn nt_tile(
    a: &[f32],
    b: &[f32],
    n: usize,
    i0: usize,
    j0: usize,
    mr: usize,
    jw: usize,
    acc: &mut [[f32; NT_TILE]; NT_TILE],
) {
    if mr == NT_TILE && jw == NT_TILE {
        let (a0, a1, a2, a3) = (
            &a[i0 * n..(i0 + 1) * n],
            &a[(i0 + 1) * n..(i0 + 2) * n],
            &a[(i0 + 2) * n..(i0 + 3) * n],
            &a[(i0 + 3) * n..(i0 + 4) * n],
        );
        let (b0, b1, b2, b3) = (
            &b[j0 * n..(j0 + 1) * n],
            &b[(j0 + 1) * n..(j0 + 2) * n],
            &b[(j0 + 2) * n..(j0 + 3) * n],
            &b[(j0 + 3) * n..(j0 + 4) * n],
        );
        for l in 0..n {
            let (x0, x1, x2, x3) = (a0[l], a1[l], a2[l], a3[l]);
            let (y0, y1, y2, y3) = (b0[l], b1[l], b2[l], b3[l]);
            acc[0][0] += x0 * y0;
            acc[0][1] += x0 * y1;
            acc[0][2] += x0 * y2;
            acc[0][3] += x0 * y3;
            acc[1][0] += x1 * y0;
            acc[1][1] += x1 * y1;
            acc[1][2] += x1 * y2;
            acc[1][3] += x1 * y3;
            acc[2][0] += x2 * y0;
            acc[2][1] += x2 * y1;
            acc[2][2] += x2 * y2;
            acc[2][3] += x2 * y3;
            acc[3][0] += x3 * y0;
            acc[3][1] += x3 * y1;
            acc[3][2] += x3 * y2;
            acc[3][3] += x3 * y3;
        }
    } else {
        for l in 0..n {
            for r in 0..mr {
                let av = a[(i0 + r) * n + l];
                for c in 0..jw {
                    acc[r][c] += av * b[(j0 + c) * n + l];
                }
            }
        }
    }
}

/// Plain ascending dot product — the skinny-M `matmul_nt` path, same
/// association as `matmul_nt_ref`.
pub fn nt_dot(x: &[f32], y: &[f32]) -> f32 {
    let mut acc = 0f32;
    for (&a, &b) in x.iter().zip(y) {
        acc += a * b;
    }
    acc
}

/// Rank-1 row update `o[j] += av * br[j]` for `matmul_tn_acc` (the
/// caller iterates `r` ascending, preserving `matmul_tn_ref`'s order).
pub fn tn_axpy(o: &mut [f32], br: &[f32], av: f32) {
    for (ov, &bv) in o.iter_mut().zip(br) {
        *ov += av * bv;
    }
}

/// Ascending row sum (layernorm mean numerator).
pub fn sum(x: &[f32]) -> f32 {
    x.iter().sum::<f32>()
}

/// Ascending `Σ (x - mu)²` (layernorm variance numerator).
pub fn sq_dev_sum(x: &[f32], mu: f32) -> f32 {
    x.iter().map(|&v| (v - mu) * (v - mu)).sum::<f32>()
}

/// LayerNorm backward fused first pass: accumulates gamma/beta grads in
/// place and returns `(Σ dxhat, Σ dxhat·xhat)`.
pub fn ln_bwd_sums(
    xr: &[f32],
    gyr: &[f32],
    gamma: &[f32],
    mu: f32,
    rs: f32,
    gg: &mut [f32],
    gb: &mut [f32],
) -> (f32, f32) {
    let n = xr.len();
    let mut sum_dxhat = 0f32;
    let mut sum_dxhat_xhat = 0f32;
    for i in 0..n {
        let xhat = (xr[i] - mu) * rs;
        let dxhat = gyr[i] * gamma[i];
        sum_dxhat += dxhat;
        sum_dxhat_xhat += dxhat * xhat;
        gg[i] += gyr[i] * xhat;
        gb[i] += gyr[i];
    }
    (sum_dxhat, sum_dxhat_xhat)
}

/// LayerNorm backward second pass: `gxr[i] = rs·(dxhat − m1 − xhat·m2)`.
#[allow(clippy::too_many_arguments)]
pub fn ln_bwd_gx(
    xr: &[f32],
    gyr: &[f32],
    gamma: &[f32],
    mu: f32,
    rs: f32,
    m1: f32,
    m2: f32,
    gxr: &mut [f32],
) {
    let n = xr.len();
    for i in 0..n {
        let xhat = (xr[i] - mu) * rs;
        let dxhat = gyr[i] * gamma[i];
        gxr[i] = rs * (dxhat - m1 - xhat * m2);
    }
}

/// sqrt(2/pi), matching model.py's constant.
pub const GELU_C: f32 = 0.797_884_56;
pub const GELU_A: f32 = 0.044_715;

/// Tanh-approximation GELU, one element.
#[inline]
pub fn gelu_one(v: f32) -> f32 {
    let u = GELU_C * (v + GELU_A * v * v * v);
    0.5 * v * (1.0 + u.tanh())
}

/// d gelu(v) / dv, one element.
#[inline]
pub fn gelu_grad_one(v: f32) -> f32 {
    let u = GELU_C * (v + GELU_A * v * v * v);
    let t = u.tanh();
    let du = GELU_C * (1.0 + 3.0 * GELU_A * v * v);
    0.5 * (1.0 + t) + 0.5 * v * (1.0 - t * t) * du
}

/// GELU over one chunk (outer chunking stays in `math.rs`).
pub fn gelu(x: &[f32], out: &mut [f32]) {
    for (ov, &v) in out.iter_mut().zip(x) {
        *ov = gelu_one(v);
    }
}

/// `g[i] *= gelu'(x[i])` over one chunk.
pub fn gelu_grad_mul(x: &[f32], g: &mut [f32]) {
    for (gv, &v) in g.iter_mut().zip(x) {
        *gv *= gelu_grad_one(v);
    }
}

/// Row max (softmax stabilizer).
pub fn row_max(row: &[f32]) -> f32 {
    row.iter().fold(f32::NEG_INFINITY, |a, &b| a.max(b))
}

/// `Σ exp(x − mx)` without mutating the row (`head_fwd` log-sum-exp).
pub fn exp_sum_sub(row: &[f32], mx: f32) -> f32 {
    row.iter().map(|&l| (l - mx).exp()).sum()
}

/// Rewrites the row to `exp(x − mx)` and returns the sum (`head_bwd`
/// softmax numerators; the `/z` normalize stays in the caller).
pub fn exp_norm_sub(row: &mut [f32], mx: f32) -> f32 {
    let mut z = 0f32;
    for l in row.iter_mut() {
        *l = (*l - mx).exp();
        z += *l;
    }
    z
}

/// Fused Adam chunk update (moments + parameter, `ADAM_*` baked in).
pub fn adam_chunk(pd: &mut [f32], gd: &[f32], md: &mut [f32], vd: &mut [f32], lr: f32, c1: f32, c2: f32) {
    for i in 0..pd.len() {
        md[i] = ADAM_BETA1 * md[i] + (1.0 - ADAM_BETA1) * gd[i];
        vd[i] = ADAM_BETA2 * vd[i] + (1.0 - ADAM_BETA2) * gd[i] * gd[i];
        pd[i] -= lr * (md[i] / c1) / ((vd[i] / c2).sqrt() + ADAM_EPS);
    }
}
