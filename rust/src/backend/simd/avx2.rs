//! AVX2+FMA kernel tier (`x86_64` only).
//!
//! Every public function here is a safe wrapper around a
//! `#[target_feature(enable = "avx2,fma")]` implementation. The safety
//! argument is dispatch-level: these functions are only reachable
//! through [`super::ops`] after the CPUID probe installed the AVX2
//! table, or through [`super::set_tier`], which asserts
//! [`super::simd_available`] — so the target features are always
//! present when the `unsafe` inner functions run.
//!
//! **Rounding policy.** FMA contracts `a·b + c` into one rounding and
//! the dot/sum kernels reduce across 8 lanes plus two unrolled
//! accumulators, so results differ from the scalar tier by O(k·ε)
//! relative error — the differential tests in
//! `tests/kernel_properties.rs` pin the per-op bounds. What *is*
//! preserved exactly is the determinism contract: each element's
//! association is a pure function of its reduction length and lane
//! position (never of tile position, slice boundary, or rayon pool
//! size), so within this tier results are bit-stable across runs,
//! thread counts, and token slicings.
//!
//! `exp`/`tanh` use a Cephes-style degree-5 polynomial (the classic
//! `sse_mathfun` constants): ≲4e-6 relative error worst-case at the
//! clamp edges, ~1e-7 over the softmax/GELU operating range (validated
//! against a float32 NumPy mirror). Vector tails fall back to the
//! scalar libm forms, covered by the same tolerance pins.

#![allow(clippy::needless_range_loop)] // index loops are the idiom in kernels
#![allow(clippy::missing_safety_doc)] // inner unsafe fns are module-private

use super::scalar;
use super::{ADAM_BETA1, ADAM_BETA2, ADAM_EPS, MR, NR, NT_TILE};
use std::arch::x86_64::*;

// ---------------------------------------------------------------------------
// Reduction helpers
// ---------------------------------------------------------------------------

/// Horizontal sum with a fixed merge order (low128+high128, then pairs).
#[inline]
#[target_feature(enable = "avx2,fma")]
unsafe fn hsum(v: __m256) -> f32 {
    let lo = _mm256_castps256_ps128(v);
    let hi = _mm256_extractf128_ps::<1>(v);
    let s = _mm_add_ps(lo, hi);
    let s = _mm_add_ps(s, _mm_movehl_ps(s, s));
    let s = _mm_add_ss(s, _mm_movehdup_ps(s));
    _mm_cvtss_f32(s)
}

/// Fixed-association FMA dot product: two unrolled 8-lane accumulators
/// over 16-element steps, an 8-element step folded into the first, one
/// horizontal sum, then a scalar tail. The association depends only on
/// the length, so every call site (nt tiles, skinny rows) agrees.
#[target_feature(enable = "avx2,fma")]
unsafe fn dot_fma(x: &[f32], y: &[f32]) -> f32 {
    let n = x.len().min(y.len());
    let xp = x.as_ptr();
    let yp = y.as_ptr();
    let mut acc0 = _mm256_setzero_ps();
    let mut acc1 = _mm256_setzero_ps();
    let mut i = 0usize;
    while i + 16 <= n {
        acc0 = _mm256_fmadd_ps(_mm256_loadu_ps(xp.add(i)), _mm256_loadu_ps(yp.add(i)), acc0);
        acc1 = _mm256_fmadd_ps(_mm256_loadu_ps(xp.add(i + 8)), _mm256_loadu_ps(yp.add(i + 8)), acc1);
        i += 16;
    }
    if i + 8 <= n {
        acc0 = _mm256_fmadd_ps(_mm256_loadu_ps(xp.add(i)), _mm256_loadu_ps(yp.add(i)), acc0);
        i += 8;
    }
    let mut acc = hsum(_mm256_add_ps(acc0, acc1));
    while i < n {
        acc = (*xp.add(i)).mul_add(*yp.add(i), acc);
        i += 1;
    }
    acc
}

// ---------------------------------------------------------------------------
// Vector exp / tanh (Cephes / sse_mathfun constants)
// ---------------------------------------------------------------------------

#[target_feature(enable = "avx2,fma")]
unsafe fn exp_ps(x: __m256) -> __m256 {
    const EXP_HI: f32 = 88.376_26; // just below ln(f32::MAX)
    const EXP_LO: f32 = -87.336_54; // smallest x with a normal exp(x)
    const LOG2E: f32 = 1.442_695_04;
    const LN2_HI: f32 = 0.693_359_375;
    const LN2_LO: f32 = -2.121_944_4e-4;
    const P0: f32 = 1.987_569_1e-4;
    const P1: f32 = 1.398_199_9e-3;
    const P2: f32 = 8.333_451_9e-3;
    const P3: f32 = 4.166_579_6e-2;
    const P4: f32 = 1.666_666_5e-1;
    const P5: f32 = 5.000_000_1e-1;

    let x = _mm256_min_ps(_mm256_set1_ps(EXP_HI), _mm256_max_ps(_mm256_set1_ps(EXP_LO), x));
    // n = round(x · log2 e) — cvtps rounds to nearest even (MXCSR default)
    let ni = _mm256_cvtps_epi32(_mm256_mul_ps(x, _mm256_set1_ps(LOG2E)));
    let n = _mm256_cvtepi32_ps(ni);
    // r = x − n·ln2, split high/low for precision
    let r = _mm256_fnmadd_ps(n, _mm256_set1_ps(LN2_HI), x);
    let r = _mm256_fnmadd_ps(n, _mm256_set1_ps(LN2_LO), r);
    // exp(r) ≈ 1 + r + r²·P(r), degree-5 Horner
    let mut y = _mm256_set1_ps(P0);
    y = _mm256_fmadd_ps(y, r, _mm256_set1_ps(P1));
    y = _mm256_fmadd_ps(y, r, _mm256_set1_ps(P2));
    y = _mm256_fmadd_ps(y, r, _mm256_set1_ps(P3));
    y = _mm256_fmadd_ps(y, r, _mm256_set1_ps(P4));
    y = _mm256_fmadd_ps(y, r, _mm256_set1_ps(P5));
    let r2 = _mm256_mul_ps(r, r);
    let y = _mm256_fmadd_ps(y, r2, _mm256_add_ps(r, _mm256_set1_ps(1.0)));
    // scale by 2ⁿ through the exponent bits
    let pow2 = _mm256_castsi256_ps(_mm256_slli_epi32::<23>(_mm256_add_epi32(ni, _mm256_set1_epi32(127))));
    _mm256_mul_ps(y, pow2)
}

/// `tanh(u) = 1 − 2/(exp(2u) + 1)`; `exp_ps`'s clamp makes the extremes
/// saturate cleanly to ±1 without overflow.
#[target_feature(enable = "avx2,fma")]
unsafe fn tanh_ps(u: __m256) -> __m256 {
    let e = exp_ps(_mm256_add_ps(u, u));
    let one = _mm256_set1_ps(1.0);
    _mm256_sub_ps(one, _mm256_div_ps(_mm256_set1_ps(2.0), _mm256_add_ps(e, one)))
}

// ---------------------------------------------------------------------------
// Matmul-family kernels
// ---------------------------------------------------------------------------

pub fn mm_micro(a: &[f32], i0: usize, mr: usize, k: usize, strip: &[f32], acc: &mut [[f32; NR]; MR]) {
    unsafe { mm_micro_fma(a, i0, mr, k, strip, acc) }
}

#[target_feature(enable = "avx2,fma")]
unsafe fn mm_micro_fma(a: &[f32], i0: usize, mr: usize, k: usize, strip: &[f32], acc: &mut [[f32; NR]; MR]) {
    let sp = strip.as_ptr();
    if mr == MR {
        let a0 = a.as_ptr().add(i0 * k);
        let a1 = a0.add(k);
        let a2 = a1.add(k);
        let a3 = a2.add(k);
        let mut c0 = _mm256_setzero_ps();
        let mut c1 = _mm256_setzero_ps();
        let mut c2 = _mm256_setzero_ps();
        let mut c3 = _mm256_setzero_ps();
        for l in 0..k {
            let bv = _mm256_loadu_ps(sp.add(l * NR));
            c0 = _mm256_fmadd_ps(_mm256_set1_ps(*a0.add(l)), bv, c0);
            c1 = _mm256_fmadd_ps(_mm256_set1_ps(*a1.add(l)), bv, c1);
            c2 = _mm256_fmadd_ps(_mm256_set1_ps(*a2.add(l)), bv, c2);
            c3 = _mm256_fmadd_ps(_mm256_set1_ps(*a3.add(l)), bv, c3);
        }
        _mm256_storeu_ps(acc[0].as_mut_ptr(), c0);
        _mm256_storeu_ps(acc[1].as_mut_ptr(), c1);
        _mm256_storeu_ps(acc[2].as_mut_ptr(), c2);
        _mm256_storeu_ps(acc[3].as_mut_ptr(), c3);
    } else {
        for r in 0..mr {
            let ar = a.as_ptr().add((i0 + r) * k);
            let mut c = _mm256_setzero_ps();
            for l in 0..k {
                c = _mm256_fmadd_ps(_mm256_set1_ps(*ar.add(l)), _mm256_loadu_ps(sp.add(l * NR)), c);
            }
            _mm256_storeu_ps(acc[r].as_mut_ptr(), c);
        }
        for r in mr..MR {
            acc[r] = [0.0; NR];
        }
    }
}

pub fn mm_panel_row(ar: &[f32], strip: &[f32], k: usize, acc: &mut [f32; NR]) {
    unsafe { mm_panel_row_fma(ar, strip, k, acc) }
}

#[target_feature(enable = "avx2,fma")]
unsafe fn mm_panel_row_fma(ar: &[f32], strip: &[f32], k: usize, acc: &mut [f32; NR]) {
    let sp = strip.as_ptr();
    // acc arrives zeroed; load-accumulate-store keeps the same per-lane
    // fmadd chain as mm_micro's single-row case
    let mut c = _mm256_loadu_ps(acc.as_ptr());
    for l in 0..k {
        c = _mm256_fmadd_ps(_mm256_set1_ps(ar[l]), _mm256_loadu_ps(sp.add(l * NR)), c);
    }
    _mm256_storeu_ps(acc.as_mut_ptr(), c);
}

#[allow(clippy::too_many_arguments)]
pub fn nt_tile(
    a: &[f32],
    b: &[f32],
    n: usize,
    i0: usize,
    j0: usize,
    mr: usize,
    jw: usize,
    acc: &mut [[f32; NT_TILE]; NT_TILE],
) {
    unsafe { nt_tile_fma(a, b, n, i0, j0, mr, jw, acc) }
}

#[allow(clippy::too_many_arguments)]
#[target_feature(enable = "avx2,fma")]
unsafe fn nt_tile_fma(
    a: &[f32],
    b: &[f32],
    n: usize,
    i0: usize,
    j0: usize,
    mr: usize,
    jw: usize,
    acc: &mut [[f32; NT_TILE]; NT_TILE],
) {
    // mr×jw independent dots, each with dot_fma's length-only association
    // — identical to the skinny-path nt_dot, so tiling is invisible.
    for r in 0..mr {
        let ar = &a[(i0 + r) * n..(i0 + r + 1) * n];
        for c in 0..jw {
            let br = &b[(j0 + c) * n..(j0 + c + 1) * n];
            acc[r][c] = dot_fma(ar, br);
        }
    }
}

pub fn nt_dot(x: &[f32], y: &[f32]) -> f32 {
    unsafe { dot_fma(x, y) }
}

pub fn tn_axpy(o: &mut [f32], br: &[f32], av: f32) {
    unsafe { tn_axpy_fma(o, br, av) }
}

#[target_feature(enable = "avx2,fma")]
unsafe fn tn_axpy_fma(o: &mut [f32], br: &[f32], av: f32) {
    let n = o.len().min(br.len());
    let op = o.as_mut_ptr();
    let bp = br.as_ptr();
    let va = _mm256_set1_ps(av);
    let mut i = 0usize;
    while i + 8 <= n {
        let cur = _mm256_loadu_ps(op.add(i));
        _mm256_storeu_ps(op.add(i), _mm256_fmadd_ps(va, _mm256_loadu_ps(bp.add(i)), cur));
        i += 8;
    }
    while i < n {
        *op.add(i) = av.mul_add(*bp.add(i), *op.add(i));
        i += 1;
    }
}

// ---------------------------------------------------------------------------
// LayerNorm reductions
// ---------------------------------------------------------------------------

pub fn sum(x: &[f32]) -> f32 {
    unsafe { sum_fma(x) }
}

#[target_feature(enable = "avx2,fma")]
unsafe fn sum_fma(x: &[f32]) -> f32 {
    let n = x.len();
    let p = x.as_ptr();
    let mut acc0 = _mm256_setzero_ps();
    let mut acc1 = _mm256_setzero_ps();
    let mut i = 0usize;
    while i + 16 <= n {
        acc0 = _mm256_add_ps(acc0, _mm256_loadu_ps(p.add(i)));
        acc1 = _mm256_add_ps(acc1, _mm256_loadu_ps(p.add(i + 8)));
        i += 16;
    }
    if i + 8 <= n {
        acc0 = _mm256_add_ps(acc0, _mm256_loadu_ps(p.add(i)));
        i += 8;
    }
    let mut s = hsum(_mm256_add_ps(acc0, acc1));
    while i < n {
        s += *p.add(i);
        i += 1;
    }
    s
}

pub fn sq_dev_sum(x: &[f32], mu: f32) -> f32 {
    unsafe { sq_dev_sum_fma(x, mu) }
}

#[target_feature(enable = "avx2,fma")]
unsafe fn sq_dev_sum_fma(x: &[f32], mu: f32) -> f32 {
    let n = x.len();
    let p = x.as_ptr();
    let vmu = _mm256_set1_ps(mu);
    let mut acc = _mm256_setzero_ps();
    let mut i = 0usize;
    while i + 8 <= n {
        let d = _mm256_sub_ps(_mm256_loadu_ps(p.add(i)), vmu);
        acc = _mm256_fmadd_ps(d, d, acc);
        i += 8;
    }
    let mut s = hsum(acc);
    while i < n {
        let d = *p.add(i) - mu;
        s = d.mul_add(d, s);
        i += 1;
    }
    s
}

pub fn ln_bwd_sums(
    xr: &[f32],
    gyr: &[f32],
    gamma: &[f32],
    mu: f32,
    rs: f32,
    gg: &mut [f32],
    gb: &mut [f32],
) -> (f32, f32) {
    unsafe { ln_bwd_sums_fma(xr, gyr, gamma, mu, rs, gg, gb) }
}

#[target_feature(enable = "avx2,fma")]
unsafe fn ln_bwd_sums_fma(
    xr: &[f32],
    gyr: &[f32],
    gamma: &[f32],
    mu: f32,
    rs: f32,
    gg: &mut [f32],
    gb: &mut [f32],
) -> (f32, f32) {
    let n = xr.len();
    let vmu = _mm256_set1_ps(mu);
    let vrs = _mm256_set1_ps(rs);
    let mut v1 = _mm256_setzero_ps();
    let mut v2 = _mm256_setzero_ps();
    let mut i = 0usize;
    while i + 8 <= n {
        let xv = _mm256_loadu_ps(xr.as_ptr().add(i));
        let gy = _mm256_loadu_ps(gyr.as_ptr().add(i));
        let gm = _mm256_loadu_ps(gamma.as_ptr().add(i));
        let xhat = _mm256_mul_ps(_mm256_sub_ps(xv, vmu), vrs);
        let dxhat = _mm256_mul_ps(gy, gm);
        v1 = _mm256_add_ps(v1, dxhat);
        v2 = _mm256_fmadd_ps(dxhat, xhat, v2);
        let ggv = _mm256_loadu_ps(gg.as_ptr().add(i));
        _mm256_storeu_ps(gg.as_mut_ptr().add(i), _mm256_fmadd_ps(gy, xhat, ggv));
        let gbv = _mm256_loadu_ps(gb.as_ptr().add(i));
        _mm256_storeu_ps(gb.as_mut_ptr().add(i), _mm256_add_ps(gbv, gy));
        i += 8;
    }
    let mut s1 = hsum(v1);
    let mut s2 = hsum(v2);
    while i < n {
        let xhat = (xr[i] - mu) * rs;
        let dxhat = gyr[i] * gamma[i];
        s1 += dxhat;
        s2 = dxhat.mul_add(xhat, s2);
        gg[i] = gyr[i].mul_add(xhat, gg[i]);
        gb[i] += gyr[i];
        i += 1;
    }
    (s1, s2)
}

#[allow(clippy::too_many_arguments)]
pub fn ln_bwd_gx(
    xr: &[f32],
    gyr: &[f32],
    gamma: &[f32],
    mu: f32,
    rs: f32,
    m1: f32,
    m2: f32,
    gxr: &mut [f32],
) {
    unsafe { ln_bwd_gx_fma(xr, gyr, gamma, mu, rs, m1, m2, gxr) }
}

#[allow(clippy::too_many_arguments)]
#[target_feature(enable = "avx2,fma")]
unsafe fn ln_bwd_gx_fma(
    xr: &[f32],
    gyr: &[f32],
    gamma: &[f32],
    mu: f32,
    rs: f32,
    m1: f32,
    m2: f32,
    gxr: &mut [f32],
) {
    let n = xr.len();
    let vmu = _mm256_set1_ps(mu);
    let vrs = _mm256_set1_ps(rs);
    let vm1 = _mm256_set1_ps(m1);
    let vm2 = _mm256_set1_ps(m2);
    let mut i = 0usize;
    while i + 8 <= n {
        let xv = _mm256_loadu_ps(xr.as_ptr().add(i));
        let gy = _mm256_loadu_ps(gyr.as_ptr().add(i));
        let gm = _mm256_loadu_ps(gamma.as_ptr().add(i));
        let xhat = _mm256_mul_ps(_mm256_sub_ps(xv, vmu), vrs);
        let dxhat = _mm256_mul_ps(gy, gm);
        let t = _mm256_sub_ps(_mm256_sub_ps(dxhat, vm1), _mm256_mul_ps(xhat, vm2));
        _mm256_storeu_ps(gxr.as_mut_ptr().add(i), _mm256_mul_ps(vrs, t));
        i += 8;
    }
    if i < n {
        scalar::ln_bwd_gx(&xr[i..], &gyr[i..], &gamma[i..], mu, rs, m1, m2, &mut gxr[i..]);
    }
}

// ---------------------------------------------------------------------------
// GELU
// ---------------------------------------------------------------------------

pub fn gelu(x: &[f32], out: &mut [f32]) {
    unsafe { gelu_fma(x, out) }
}

#[target_feature(enable = "avx2,fma")]
unsafe fn gelu_fma(x: &[f32], out: &mut [f32]) {
    let n = x.len().min(out.len());
    let vc = _mm256_set1_ps(scalar::GELU_C);
    let va = _mm256_set1_ps(scalar::GELU_A);
    let half = _mm256_set1_ps(0.5);
    let one = _mm256_set1_ps(1.0);
    let mut i = 0usize;
    while i + 8 <= n {
        let v = _mm256_loadu_ps(x.as_ptr().add(i));
        let v3 = _mm256_mul_ps(_mm256_mul_ps(v, v), v);
        let u = _mm256_mul_ps(vc, _mm256_fmadd_ps(va, v3, v));
        let t = tanh_ps(u);
        let y = _mm256_mul_ps(_mm256_mul_ps(half, v), _mm256_add_ps(one, t));
        _mm256_storeu_ps(out.as_mut_ptr().add(i), y);
        i += 8;
    }
    if i < n {
        scalar::gelu(&x[i..], &mut out[i..]);
    }
}

pub fn gelu_grad_mul(x: &[f32], g: &mut [f32]) {
    unsafe { gelu_grad_mul_fma(x, g) }
}

#[target_feature(enable = "avx2,fma")]
unsafe fn gelu_grad_mul_fma(x: &[f32], g: &mut [f32]) {
    let n = x.len().min(g.len());
    let vc = _mm256_set1_ps(scalar::GELU_C);
    let va3 = _mm256_set1_ps(3.0 * scalar::GELU_A);
    let va = _mm256_set1_ps(scalar::GELU_A);
    let half = _mm256_set1_ps(0.5);
    let one = _mm256_set1_ps(1.0);
    let mut i = 0usize;
    while i + 8 <= n {
        let v = _mm256_loadu_ps(x.as_ptr().add(i));
        let v2 = _mm256_mul_ps(v, v);
        let v3 = _mm256_mul_ps(v2, v);
        let u = _mm256_mul_ps(vc, _mm256_fmadd_ps(va, v3, v));
        let t = tanh_ps(u);
        let du = _mm256_mul_ps(vc, _mm256_fmadd_ps(va3, v2, one));
        // 0.5·(1+t) + 0.5·v·(1−t²)·du
        let sech2 = _mm256_fnmadd_ps(t, t, one);
        let lhs = _mm256_mul_ps(half, _mm256_add_ps(one, t));
        let rhs = _mm256_mul_ps(_mm256_mul_ps(half, v), _mm256_mul_ps(sech2, du));
        let grad = _mm256_add_ps(lhs, rhs);
        let gv = _mm256_loadu_ps(g.as_ptr().add(i));
        _mm256_storeu_ps(g.as_mut_ptr().add(i), _mm256_mul_ps(gv, grad));
        i += 8;
    }
    if i < n {
        scalar::gelu_grad_mul(&x[i..], &mut g[i..]);
    }
}

// ---------------------------------------------------------------------------
// Head softmax
// ---------------------------------------------------------------------------

pub fn row_max(row: &[f32]) -> f32 {
    unsafe { row_max_fma(row) }
}

#[target_feature(enable = "avx2,fma")]
unsafe fn row_max_fma(row: &[f32]) -> f32 {
    // max is associative on finite data, so lane order doesn't matter:
    // this agrees bit-for-bit with the scalar fold.
    let n = row.len();
    let p = row.as_ptr();
    let mut m = f32::NEG_INFINITY;
    let mut i = 0usize;
    if n >= 8 {
        let mut vm = _mm256_loadu_ps(p);
        i = 8;
        while i + 8 <= n {
            vm = _mm256_max_ps(vm, _mm256_loadu_ps(p.add(i)));
            i += 8;
        }
        let lo = _mm256_castps256_ps128(vm);
        let hi = _mm256_extractf128_ps::<1>(vm);
        let s = _mm_max_ps(lo, hi);
        let s = _mm_max_ps(s, _mm_movehl_ps(s, s));
        let s = _mm_max_ss(s, _mm_movehdup_ps(s));
        m = _mm_cvtss_f32(s);
    }
    while i < n {
        m = m.max(*p.add(i));
        i += 1;
    }
    m
}

pub fn exp_sum_sub(row: &[f32], mx: f32) -> f32 {
    unsafe { exp_sum_sub_fma(row, mx) }
}

#[target_feature(enable = "avx2,fma")]
unsafe fn exp_sum_sub_fma(row: &[f32], mx: f32) -> f32 {
    let n = row.len();
    let p = row.as_ptr();
    let vm = _mm256_set1_ps(mx);
    let mut acc = _mm256_setzero_ps();
    let mut i = 0usize;
    while i + 8 <= n {
        acc = _mm256_add_ps(acc, exp_ps(_mm256_sub_ps(_mm256_loadu_ps(p.add(i)), vm)));
        i += 8;
    }
    let mut s = hsum(acc);
    while i < n {
        s += (*p.add(i) - mx).exp();
        i += 1;
    }
    s
}

pub fn exp_norm_sub(row: &mut [f32], mx: f32) -> f32 {
    unsafe { exp_norm_sub_fma(row, mx) }
}

#[target_feature(enable = "avx2,fma")]
unsafe fn exp_norm_sub_fma(row: &mut [f32], mx: f32) -> f32 {
    let n = row.len();
    let p = row.as_mut_ptr();
    let vm = _mm256_set1_ps(mx);
    let mut acc = _mm256_setzero_ps();
    let mut i = 0usize;
    while i + 8 <= n {
        let e = exp_ps(_mm256_sub_ps(_mm256_loadu_ps(p.add(i)), vm));
        _mm256_storeu_ps(p.add(i), e);
        acc = _mm256_add_ps(acc, e);
        i += 8;
    }
    let mut s = hsum(acc);
    while i < n {
        let e = (*p.add(i) - mx).exp();
        *p.add(i) = e;
        s += e;
        i += 1;
    }
    s
}

// ---------------------------------------------------------------------------
// Adam
// ---------------------------------------------------------------------------

pub fn adam_chunk(pd: &mut [f32], gd: &[f32], md: &mut [f32], vd: &mut [f32], lr: f32, c1: f32, c2: f32) {
    unsafe { adam_chunk_fma(pd, gd, md, vd, lr, c1, c2) }
}

#[target_feature(enable = "avx2,fma")]
unsafe fn adam_chunk_fma(pd: &mut [f32], gd: &[f32], md: &mut [f32], vd: &mut [f32], lr: f32, c1: f32, c2: f32) {
    let n = pd.len();
    let vb1 = _mm256_set1_ps(ADAM_BETA1);
    let vb1c = _mm256_set1_ps(1.0 - ADAM_BETA1);
    let vb2 = _mm256_set1_ps(ADAM_BETA2);
    let vb2c = _mm256_set1_ps(1.0 - ADAM_BETA2);
    let veps = _mm256_set1_ps(ADAM_EPS);
    let vlr = _mm256_set1_ps(lr);
    let vc1 = _mm256_set1_ps(c1);
    let vc2 = _mm256_set1_ps(c2);
    let mut i = 0usize;
    while i + 8 <= n {
        let g = _mm256_loadu_ps(gd.as_ptr().add(i));
        let m = _mm256_fmadd_ps(vb1, _mm256_loadu_ps(md.as_ptr().add(i)), _mm256_mul_ps(vb1c, g));
        _mm256_storeu_ps(md.as_mut_ptr().add(i), m);
        let g2 = _mm256_mul_ps(g, g);
        let v = _mm256_fmadd_ps(vb2, _mm256_loadu_ps(vd.as_ptr().add(i)), _mm256_mul_ps(vb2c, g2));
        _mm256_storeu_ps(vd.as_mut_ptr().add(i), v);
        let num = _mm256_div_ps(m, vc1);
        let den = _mm256_add_ps(_mm256_sqrt_ps(_mm256_div_ps(v, vc2)), veps);
        let step = _mm256_mul_ps(vlr, _mm256_div_ps(num, den));
        let p = _mm256_sub_ps(_mm256_loadu_ps(pd.as_ptr().add(i)), step);
        _mm256_storeu_ps(pd.as_mut_ptr().add(i), p);
        i += 8;
    }
    if i < n {
        scalar::adam_chunk(&mut pd[i..], &gd[i..], &mut md[i..], &mut vd[i..], lr, c1, c2);
    }
}
