//! Runtime-dispatched kernel tiers for the native backend.
//!
//! The blocked kernels in [`super::math`] and the hot loops in
//! [`super::cell`] do their outer blocking / parallel decomposition in
//! one place, but route every *inner* loop through a table of function
//! pointers — [`KernelOps`] — with exactly two implementations:
//!
//! * [`scalar`] — the inner loops of the PR 6 cache-blocked kernels,
//!   moved here verbatim. Rust/LLVM does not contract `a * b + c` into
//!   an FMA, every reduction keeps one accumulator in fixed ascending
//!   order, so this tier is **bit-identical** to the naive `*_ref`
//!   oracles and is the determinism baseline for all differential
//!   tests.
//! * [`avx2`] — explicit `std::arch` AVX2+FMA microkernels
//!   (`x86_64` only). FMA contraction and 8-lane reduction trees change
//!   rounding, so this tier is *tolerance-pinned* against the scalar
//!   tier (see `tests/kernel_properties.rs` for the per-op bounds), but
//!   within the tier every element's floating-point association is a
//!   pure function of its (row, column) position — independent of slice
//!   boundaries, tile position, and rayon pool size — so slicing- and
//!   pool-invariance hold exactly as they do for the scalar tier.
//!
//! Dispatch is resolved **once**: the first call to [`ops`] probes
//! `TERAPIPE_NO_SIMD` (any non-empty value other than `"0"` forces the
//! scalar tier) and then `is_x86_feature_detected!("avx2"/"fma")`, and
//! caches a `&'static KernelOps` in an atomic. Steady-state calls are
//! one `Acquire` load — no per-call probing, no allocation. Kernel
//! entry points load the table once and capture it in their closures,
//! so rayon workers never touch the atomic in inner loops.
//!
//! [`set_tier`] / [`tier_guard`] exist for tests and benches that need
//! an in-process A/B (the guard serializes tier flips behind a mutex
//! and restores the previous tier on drop). Production code never
//! flips tiers after startup.

use std::sync::atomic::{AtomicPtr, Ordering};
use std::sync::{Mutex, MutexGuard};

pub mod scalar;

#[cfg(target_arch = "x86_64")]
pub mod avx2;

/// Microkernel row count (rows of A per register block).
pub const MR: usize = 4;
/// Microkernel column count (one packed B panel width).
pub const NR: usize = 8;
/// `matmul_nt` square tile edge.
pub const NT_TILE: usize = 4;

/// Adam moment decay for the first moment.
pub const ADAM_BETA1: f32 = 0.9;
/// Adam moment decay for the second moment.
pub const ADAM_BETA2: f32 = 0.999;
/// Adam denominator epsilon.
pub const ADAM_EPS: f32 = 1e-8;

/// Which kernel tier a [`KernelOps`] table belongs to.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Tier {
    /// Blocked scalar loops — the bit-exact determinism oracle.
    Scalar,
    /// AVX2+FMA intrinsics — tolerance-pinned against `Scalar`.
    Avx2,
}

/// `matmul_nt` tile kernel: `(a, b, n, i0, j0, mr, jw, acc)` fills
/// `acc[r][c] = dot(a[i0+r], b[j0+c])` for `r < mr`, `c < jw` (rows of
/// length `n`); the caller zeroes `acc`.
pub type NtTileFn = fn(&[f32], &[f32], usize, usize, usize, usize, usize, &mut [[f32; NT_TILE]; NT_TILE]);

/// LayerNorm backward fused first pass: `(xr, gyr, gamma, mu, rs, gg, gb)`
/// accumulates the gamma/beta grads in place and returns
/// `(sum_dxhat, sum_dxhat_xhat)`.
pub type LnBwdSumsFn = fn(&[f32], &[f32], &[f32], f32, f32, &mut [f32], &mut [f32]) -> (f32, f32);

/// LayerNorm backward second pass: `(xr, gyr, gamma, mu, rs, m1, m2, gxr)`
/// writes `gxr[i] = rs * (dxhat - m1 - xhat * m2)`.
pub type LnBwdGxFn = fn(&[f32], &[f32], &[f32], f32, f32, f32, f32, &mut [f32]);

/// Fused Adam chunk update: `(pd, gd, md, vd, lr, c1, c2)` with the
/// `ADAM_*` constants baked in.
pub type AdamChunkFn = fn(&mut [f32], &[f32], &mut [f32], &mut [f32], f32, f32, f32);

/// The full inner-loop surface the blocked kernels dispatch over.
///
/// Each field documents its contract where the type alias (or the
/// scalar implementation) is defined; both tiers must satisfy the same
/// contracts, differing only in floating-point association.
pub struct KernelOps {
    /// Which tier this table implements.
    pub tier: Tier,
    /// `(a, i0, mr, k, strip, acc)` — MR×NR microkernel over one packed
    /// B panel; writes all MR rows of `acc` (rows ≥ `mr` zeroed).
    pub mm_micro: fn(&[f32], usize, usize, usize, &[f32], &mut [[f32; NR]; MR]),
    /// `(ar, strip, k, acc)` — 1×NR row kernel for the skinny-M path;
    /// accumulates into caller-zeroed `acc`.
    pub mm_panel_row: fn(&[f32], &[f32], usize, &mut [f32; NR]),
    /// 4×4 dot-product tile for `matmul_nt`.
    pub nt_tile: NtTileFn,
    /// Plain dot product for the skinny-M `matmul_nt` path.
    pub nt_dot: fn(&[f32], &[f32]) -> f32,
    /// `(o, br, av)` — `o[j] += av * br[j]` rank-1 row update for
    /// `matmul_tn_acc`.
    pub tn_axpy: fn(&mut [f32], &[f32], f32),
    /// Row sum (LayerNorm mean).
    pub sum: fn(&[f32]) -> f32,
    /// `(xr, mu)` — `Σ (x - mu)²` (LayerNorm variance numerator).
    pub sq_dev_sum: fn(&[f32], f32) -> f32,
    /// LayerNorm backward fused reduction pass.
    pub ln_bwd_sums: LnBwdSumsFn,
    /// LayerNorm backward input-grad pass.
    pub ln_bwd_gx: LnBwdGxFn,
    /// `(x, out)` — tanh-approximation GELU over one chunk.
    pub gelu: fn(&[f32], &mut [f32]),
    /// `(x, g)` — `g[i] *= gelu'(x[i])` over one chunk.
    pub gelu_grad_mul: fn(&[f32], &mut [f32]),
    /// Row max (softmax stabilizer). Max is exact under reassociation,
    /// so both tiers agree bit-for-bit on finite inputs.
    pub row_max: fn(&[f32]) -> f32,
    /// `(row, mx)` — `Σ exp(x - mx)` without mutating the row
    /// (`head_fwd` log-sum-exp).
    pub exp_sum_sub: fn(&[f32], f32) -> f32,
    /// `(row, mx)` — rewrites the row to `exp(x - mx)` and returns the
    /// sum (`head_bwd` softmax; the `/z` normalize stays in the caller).
    pub exp_norm_sub: fn(&mut [f32], f32) -> f32,
    /// Fused Adam parameter/moment update over one chunk.
    pub adam_chunk: AdamChunkFn,
}

static SCALAR_OPS: KernelOps = KernelOps {
    tier: Tier::Scalar,
    mm_micro: scalar::mm_micro,
    mm_panel_row: scalar::mm_panel_row,
    nt_tile: scalar::nt_tile,
    nt_dot: scalar::nt_dot,
    tn_axpy: scalar::tn_axpy,
    sum: scalar::sum,
    sq_dev_sum: scalar::sq_dev_sum,
    ln_bwd_sums: scalar::ln_bwd_sums,
    ln_bwd_gx: scalar::ln_bwd_gx,
    gelu: scalar::gelu,
    gelu_grad_mul: scalar::gelu_grad_mul,
    row_max: scalar::row_max,
    exp_sum_sub: scalar::exp_sum_sub,
    exp_norm_sub: scalar::exp_norm_sub,
    adam_chunk: scalar::adam_chunk,
};

#[cfg(target_arch = "x86_64")]
static AVX2_OPS: KernelOps = KernelOps {
    tier: Tier::Avx2,
    mm_micro: avx2::mm_micro,
    mm_panel_row: avx2::mm_panel_row,
    nt_tile: avx2::nt_tile,
    nt_dot: avx2::nt_dot,
    tn_axpy: avx2::tn_axpy,
    sum: avx2::sum,
    sq_dev_sum: avx2::sq_dev_sum,
    ln_bwd_sums: avx2::ln_bwd_sums,
    ln_bwd_gx: avx2::ln_bwd_gx,
    gelu: avx2::gelu,
    gelu_grad_mul: avx2::gelu_grad_mul,
    row_max: avx2::row_max,
    exp_sum_sub: avx2::exp_sum_sub,
    exp_norm_sub: avx2::exp_norm_sub,
    adam_chunk: avx2::adam_chunk,
};

/// Resolved dispatch table. Null until the first [`ops`] call; after
/// that always one of the two `static` tables above, so the pointer is
/// `'static` and a racing double-initialize is benign.
static CURRENT: AtomicPtr<KernelOps> = AtomicPtr::new(std::ptr::null_mut());

/// Serializes [`set_tier`] / [`tier_guard`] flips (tests run
/// concurrently in one process and the table is global).
static TIER_LOCK: Mutex<()> = Mutex::new(());

/// True iff the host supports the AVX2+FMA tier. Pure probe: ignores
/// `TERAPIPE_NO_SIMD` and the currently installed tier.
#[cfg(target_arch = "x86_64")]
pub fn simd_available() -> bool {
    std::arch::is_x86_feature_detected!("avx2") && std::arch::is_x86_feature_detected!("fma")
}

/// True iff the host supports the AVX2+FMA tier (never, off x86-64).
#[cfg(not(target_arch = "x86_64"))]
pub fn simd_available() -> bool {
    false
}

fn no_simd_env() -> bool {
    match std::env::var_os("TERAPIPE_NO_SIMD") {
        Some(v) => !v.is_empty() && v != "0",
        None => false,
    }
}

#[cfg(target_arch = "x86_64")]
fn avx2_ops() -> &'static KernelOps {
    &AVX2_OPS
}

#[cfg(not(target_arch = "x86_64"))]
fn avx2_ops() -> &'static KernelOps {
    unreachable!("AVX2 tier requested on a non-x86_64 target")
}

fn detect() -> &'static KernelOps {
    if no_simd_env() {
        return &SCALAR_OPS;
    }
    if simd_available() {
        return avx2_ops();
    }
    &SCALAR_OPS
}

/// The active dispatch table. First call resolves the tier (env +
/// CPUID probe, may allocate for the env read); every later call is a
/// single atomic load. Kernel entry points call this **once** and
/// capture the reference in their parallel closures.
#[inline]
pub fn ops() -> &'static KernelOps {
    let p = CURRENT.load(Ordering::Acquire);
    if p.is_null() {
        let resolved = detect();
        CURRENT.store(resolved as *const KernelOps as *mut KernelOps, Ordering::Release);
        resolved
    } else {
        // SAFETY: only ever set to one of the two `'static` tables.
        unsafe { &*p }
    }
}

/// The tier the next kernel call will run under.
pub fn active_tier() -> Tier {
    ops().tier
}

/// Installs `tier` as the global dispatch table, returning the
/// previously active tier. Panics if [`Tier::Avx2`] is requested on a
/// host without AVX2+FMA. Meant for benches and tests; use
/// [`tier_guard`] from tests so concurrent tier flips serialize.
pub fn set_tier(tier: Tier) -> Tier {
    let prev = active_tier();
    let next = match tier {
        Tier::Scalar => &SCALAR_OPS,
        Tier::Avx2 => {
            assert!(simd_available(), "AVX2+FMA tier requested but the host lacks it");
            avx2_ops()
        }
    };
    CURRENT.store(next as *const KernelOps as *mut KernelOps, Ordering::Release);
    prev
}

/// Holds the tier-flip lock and restores the previous tier on drop.
pub struct TierGuard {
    prev: Tier,
    _lock: MutexGuard<'static, ()>,
}

/// Pins the global dispatch to `tier` for the guard's lifetime. Tests
/// that assert scalar-tier bit-identity (or force an A/B) take this so
/// concurrently running tier-sensitive tests serialize; the previous
/// tier is restored when the guard drops. A panic while holding the
/// guard poisons only the flip lock, which later guards recover.
pub fn tier_guard(tier: Tier) -> TierGuard {
    let lock = TIER_LOCK.lock().unwrap_or_else(|e| e.into_inner());
    let prev = set_tier(tier);
    TierGuard { prev, _lock: lock }
}

impl Drop for TierGuard {
    fn drop(&mut self) {
        set_tier(self.prev);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ops_resolves_once_and_is_stable() {
        let first = ops() as *const KernelOps;
        for _ in 0..4 {
            assert_eq!(ops() as *const KernelOps, first);
        }
    }

    #[test]
    fn tier_guard_restores_previous_tier() {
        let before = {
            let _g = tier_guard(Tier::Scalar);
            assert_eq!(active_tier(), Tier::Scalar);
            // Nested flip inside the guard's critical section.
            let prev = set_tier(Tier::Scalar);
            assert_eq!(prev, Tier::Scalar);
            Tier::Scalar
        };
        // Whatever tier the process detected is back after the guard,
        // and pinning scalar again still works.
        let _ = before;
        let _g = tier_guard(Tier::Scalar);
        assert_eq!(active_tier(), Tier::Scalar);
    }

    #[test]
    fn avx2_guard_round_trips_when_available() {
        if !simd_available() {
            eprintln!("note: host lacks AVX2+FMA, skipping avx2 guard test");
            return;
        }
        {
            let _g = tier_guard(Tier::Avx2);
            assert_eq!(active_tier(), Tier::Avx2);
        }
        {
            let _g = tier_guard(Tier::Scalar);
            assert_eq!(active_tier(), Tier::Scalar);
        }
    }

    #[test]
    fn scalar_table_reports_scalar_tier() {
        assert_eq!(SCALAR_OPS.tier, Tier::Scalar);
        #[cfg(target_arch = "x86_64")]
        assert_eq!(AVX2_OPS.tier, Tier::Avx2);
    }
}
