//! The native CPU stage backend: pure-Rust parameters + compute, no AOT
//! artifacts, no PJRT — the default build's execution engine.
//!
//! A [`NativeBackend`] is one pipeline cell: the stage's transformer
//! layers (plus the embedding on the first stage and the LM head on the
//! last), their Adam state, and the [`cell`](super::cell) compute. It is
//! constructed from a [`NativeSpec`] on the worker thread that owns it.
//!
//! Initialization mirrors model.py's GPT-2-style scheme (normal 0.02,
//! residual projections scaled by `1/sqrt(2·num_layers)`, positional
//! embeddings 0.01, ones/zeros for layernorm), drawn from a seeded
//! SplitMix64 stream per tensor, so two backends built from the same spec
//! hold bit-identical parameters. The exact draws differ from the JAX
//! init (different RNG), which is fine: the artifacts carry their own
//! weights, and equivalence claims are always *within* a backend.
//!
//! Checkpoints use the same layout the PJRT worker writes: one raw
//! little-endian f32 file per tensor under `dir/init/`, with Adam moments
//! beside them as `m.<file>` / `v.<file>`.

use std::path::{Path, PathBuf};

use anyhow::{bail, Result};

use super::cell;
use super::{moment_path, read_f32_file, write_f32_file, BackendSpec, StageBackend};
use crate::runtime::manifest::ModelDims;
use crate::runtime::tensor::HostTensor;
use crate::util::Rng;

/// Per-thread scratch arena for the cell hot path.
///
/// `cell.rs` grabs every temporary (activations, KV scatter buffers,
/// rematerialization caches, gradient intermediates) from here and gives
/// it back before returning, so a warmed-up `stage_fwd_into` +
/// `stage_bwd_into` performs **zero heap allocations** — the property
/// `benches/exec.rs` pins with a counting allocator.
///
/// Ownership rules (see `backend/README.md` §scratch):
///
/// 1. Only the *calling* thread touches the arena. Kernels hand rayon
///    workers pre-partitioned slabs (`par_chunks_mut` over one grabbed
///    buffer); workers never call [`grab`]/[`give`] themselves.
/// 2. Borrows of the thread-local pool are instantaneous (a `grab` or
///    `give` is one push/pop) and never held across a parallel region,
///    so re-entrant kernel calls on a work-stealing thread compose.
/// 3. [`grab`] returns a **zeroed** buffer of exactly `n` elements;
///    accumulating kernels (attention, scatter-add) rely on this.
/// 4. Buffers are matched best-fit by capacity, so steady-state reuse
///    never reallocates even when slice lengths vary across a schedule.
pub mod scratch {
    use std::cell::RefCell;

    struct Pool {
        free: Vec<Vec<f32>>,
        grabs: u64,
        misses: u64,
    }

    thread_local! {
        static POOL: RefCell<Pool> = const {
            RefCell::new(Pool { free: Vec::new(), grabs: 0, misses: 0 })
        };
    }

    /// Free-list depth bound: beyond this, returned buffers are dropped.
    const MAX_FREE: usize = 64;

    fn take(n: usize) -> Vec<f32> {
        POOL.with(|p| {
            let mut p = p.borrow_mut();
            p.grabs += 1;
            // best fit: the smallest free buffer whose capacity covers n
            let mut best: Option<(usize, usize)> = None;
            for (i, b) in p.free.iter().enumerate() {
                let c = b.capacity();
                if c >= n && best.map_or(true, |(_, bc)| c < bc) {
                    best = Some((i, c));
                }
            }
            if let Some((i, _)) = best {
                return p.free.swap_remove(i);
            }
            p.misses += 1;
            // no buffer is big enough: grow the largest one (one realloc
            // now, a hit on every later grab of this size)
            if let Some(i) = (0..p.free.len()).max_by_key(|&i| p.free[i].capacity()) {
                p.free.swap_remove(i)
            } else {
                Vec::new()
            }
        })
    }

    /// A zeroed scratch buffer of exactly `n` elements.
    pub fn grab(n: usize) -> Vec<f32> {
        let mut v = take(n);
        v.clear();
        v.resize(n, 0.0);
        v
    }

    /// A scratch buffer holding a copy of `src`.
    pub fn grab_copy(src: &[f32]) -> Vec<f32> {
        let mut v = take(src.len());
        v.clear();
        v.extend_from_slice(src);
        v
    }

    /// Return a buffer to this thread's free list.
    pub fn give(v: Vec<f32>) {
        if v.capacity() == 0 {
            return;
        }
        POOL.with(|p| {
            let mut p = p.borrow_mut();
            if p.free.len() < MAX_FREE {
                p.free.push(v);
            }
        });
    }

    /// `(grabs, misses)` on this thread — misses ≙ grabs that had to
    /// touch the allocator. Steady state is misses staying flat.
    pub fn stats() -> (u64, u64) {
        POOL.with(|p| {
            let p = p.borrow();
            (p.grabs, p.misses)
        })
    }

    #[cfg(test)]
    mod tests {
        use super::*;

        #[test]
        fn grab_is_zeroed_and_reuse_hits_free_list() {
            let mut v = grab(64);
            assert!(v.iter().all(|&x| x == 0.0));
            v.iter_mut().for_each(|x| *x = 7.0);
            give(v);
            let (_, misses_before) = stats();
            let w = grab(48); // smaller request must reuse the 64-cap buffer
            assert_eq!(w.len(), 48);
            assert!(w.iter().all(|&x| x == 0.0), "reused buffer must be re-zeroed");
            let (_, misses_after) = stats();
            assert_eq!(misses_before, misses_after, "48-elem grab after 64-elem give must not miss");
            give(w);
        }

        #[test]
        fn grab_copy_preserves_contents() {
            let src = [1.0f32, 2.0, 3.0];
            let v = grab_copy(&src);
            assert_eq!(v, src);
            give(v);
        }
    }
}

/// A named parameter group with its gradient accumulators and Adam state.
pub struct ParamSet {
    /// File-stem names, aligned with `params` (e.g. `stage0.layer0.w_qkv`).
    pub names: Vec<String>,
    pub params: Vec<HostTensor>,
    pub grads: Vec<HostTensor>,
    pub m: Vec<HostTensor>,
    pub v: Vec<HostTensor>,
}

impl ParamSet {
    pub fn new(entries: Vec<(String, HostTensor)>) -> ParamSet {
        let names = entries.iter().map(|(n, _)| n.clone()).collect();
        let params: Vec<HostTensor> = entries.into_iter().map(|(_, t)| t).collect();
        let zeros: Vec<HostTensor> = params.iter().map(|p| HostTensor::zeros_f32(&p.shape)).collect();
        ParamSet {
            names,
            grads: zeros.clone(),
            m: zeros.clone(),
            v: zeros,
            params,
        }
    }

    /// Apply bias-corrected Adam with the accumulated grads, then zero
    /// the accumulators for the next step.
    pub fn adam(&mut self, step: i32, lr: f32) {
        cell::adam_step(&mut self.params, &self.grads, &mut self.m, &mut self.v, step, lr);
        for g in &mut self.grads {
            g.fill_zero();
        }
    }

    /// Max |grad| across the set (test/telemetry helper).
    pub fn grad_max_abs(&self) -> f32 {
        self.grads.iter().fold(0f32, |acc, g| acc.max(g.max_abs()))
    }

    fn file(dir: &Path, name: &str) -> PathBuf {
        dir.join("init").join(format!("{name}.bin"))
    }

    /// Write params + moments under `dir/init/` (raw LE f32).
    pub fn save(&self, dir: &Path) -> Result<()> {
        std::fs::create_dir_all(dir.join("init"))?;
        for (i, name) in self.names.iter().enumerate() {
            let f = Self::file(dir, name);
            write_f32_file(&f, &self.params[i])?;
            write_f32_file(&moment_path(&f, "m"), &self.m[i])?;
            write_f32_file(&moment_path(&f, "v"), &self.v[i])?;
        }
        Ok(())
    }

    /// Load params (and moments when present) from a checkpoint written
    /// by [`ParamSet::save`]. Shapes must match the current set.
    pub fn load(&mut self, dir: &Path) -> Result<()> {
        for (i, name) in self.names.iter().enumerate() {
            let f = Self::file(dir, name);
            self.params[i] = read_f32_file(&f, &self.params[i].shape)?;
        }
        // Moments are optional: params-only checkpoints load too.
        let have_moments = self
            .names
            .iter()
            .all(|n| moment_path(&Self::file(dir, n), "m").exists());
        if have_moments {
            for (i, name) in self.names.iter().enumerate() {
                let f = Self::file(dir, name);
                self.m[i] = read_f32_file(&moment_path(&f, "m"), &self.m[i].shape)?;
                self.v[i] = read_f32_file(&moment_path(&f, "v"), &self.v[i].shape)?;
            }
        }
        Ok(())
    }
}

// ---------------------------------------------------------------------------
// Deterministic init
// ---------------------------------------------------------------------------

/// Standard normal via Box–Muller over the SplitMix64 stream.
fn normal_tensor(rng: &mut Rng, shape: &[usize], std: f32) -> HostTensor {
    let n: usize = shape.iter().product();
    let mut data = Vec::with_capacity(n);
    while data.len() < n {
        let u1 = rng.f64().max(1e-12);
        let u2 = rng.f64();
        let r = (-2.0 * u1.ln()).sqrt();
        let th = 2.0 * std::f64::consts::PI * u2;
        data.push((r * th.cos()) as f32 * std);
        if data.len() < n {
            data.push((r * th.sin()) as f32 * std);
        }
    }
    HostTensor::f32(shape, data)
}

fn const_tensor(shape: &[usize], v: f32) -> HostTensor {
    let n: usize = shape.iter().product();
    HostTensor::f32(shape, vec![v; n])
}

/// Per-tensor RNG: independent stream keyed on (seed, group, index).
fn tensor_rng(seed: u64, group: u64, index: u64) -> Rng {
    Rng::new(
        seed.wrapping_mul(0x9E37_79B9_7F4A_7C15)
            ^ group.wrapping_mul(0xBF58_476D_1CE4_E5B9)
            ^ index.wrapping_add(0x94D0_49BB_1331_11EB),
    )
}

/// Embedding group: `tok_emb [V,H]`, `pos_emb [T,H]`.
pub fn init_embed(d: &ModelDims) -> ParamSet {
    let mut entries = Vec::new();
    let mut r0 = tensor_rng(d.seed, 1, 0);
    entries.push(("embed.tok_emb".to_string(), normal_tensor(&mut r0, &[d.vocab, d.hidden], 0.02)));
    let mut r1 = tensor_rng(d.seed, 1, 1);
    entries.push(("embed.pos_emb".to_string(), normal_tensor(&mut r1, &[d.seq_len, d.hidden], 0.01)));
    ParamSet::new(entries)
}

/// Head group: `lnf_g [H]`, `lnf_b [H]`, `w_out [H,V]`, `b_out [V]`.
pub fn init_head(d: &ModelDims) -> ParamSet {
    let h = d.hidden;
    let mut rng = tensor_rng(d.seed, 2, 0);
    ParamSet::new(vec![
        ("head.lnf_g".to_string(), const_tensor(&[h], 1.0)),
        ("head.lnf_b".to_string(), const_tensor(&[h], 0.0)),
        ("head.w_out".to_string(), normal_tensor(&mut rng, &[h, d.vocab], 0.02)),
        ("head.b_out".to_string(), const_tensor(&[d.vocab], 0.0)),
    ])
}

/// One stage's transformer-layer group (`layers_per_stage · 12` tensors,
/// canonical order).
pub fn init_stage(d: &ModelDims, stage: usize) -> ParamSet {
    let h = d.hidden;
    let f = 4 * h;
    let num_layers = d.layers_per_stage * d.num_stages;
    let resid_std = 0.02 / (2.0 * num_layers as f32).sqrt();
    let mut entries = Vec::new();
    for l in 0..d.layers_per_stage {
        let global = (stage * d.layers_per_stage + l) as u64;
        let mk = |idx: u64| tensor_rng(d.seed, 3 + global, idx);
        let shapes: [(&str, Vec<usize>, Option<(u64, f32)>); 12] = [
            ("ln1_g", vec![h], None),
            ("ln1_b", vec![h], None),
            ("w_qkv", vec![h, 3 * h], Some((0, 0.02))),
            ("b_qkv", vec![3 * h], None),
            ("w_proj", vec![h, h], Some((1, resid_std))),
            ("b_proj", vec![h], None),
            ("ln2_g", vec![h], None),
            ("ln2_b", vec![h], None),
            ("w_fc1", vec![h, f], Some((2, 0.02))),
            ("b_fc1", vec![f], None),
            ("w_fc2", vec![f, h], Some((3, resid_std))),
            ("b_fc2", vec![h], None),
        ];
        for (name, shape, draw) in shapes {
            let t = match draw {
                Some((idx, std)) => normal_tensor(&mut mk(idx), &shape, std),
                // layernorm gains are ones, every bias zero
                None => const_tensor(&shape, if name.ends_with("_g") { 1.0 } else { 0.0 }),
            };
            entries.push((format!("stage{stage}.layer{l}.{name}"), t));
        }
    }
    ParamSet::new(entries)
}

// ---------------------------------------------------------------------------
// The backend
// ---------------------------------------------------------------------------

/// Spec for building native pipeline cells: model geometry + the slice
/// buckets the planner may use. The native backend has no static-shape
/// constraint, so the bucket set is simply every multiple of
/// `granularity` up to the sequence length.
#[derive(Debug, Clone)]
pub struct NativeSpec {
    pub model: ModelDims,
    /// Slice-length granularity (buckets are g, 2g, …, L).
    pub granularity: usize,
}

impl NativeSpec {
    pub fn new(model: ModelDims, granularity: usize) -> NativeSpec {
        assert!(granularity >= 1 && model.seq_len % granularity == 0, "granularity must divide L");
        NativeSpec { model, granularity }
    }
}

impl BackendSpec for NativeSpec {
    type Backend = NativeBackend;

    fn model(&self) -> ModelDims {
        self.model.clone()
    }

    fn buckets(&self) -> Vec<usize> {
        (1..=self.model.seq_len / self.granularity)
            .map(|a| a * self.granularity)
            .collect()
    }

    fn build(&self, stage: usize, num_stages: usize, resume_from: Option<&Path>) -> Result<NativeBackend> {
        if num_stages != self.model.num_stages {
            bail!("spec has {} stages, pipeline has {num_stages}", self.model.num_stages);
        }
        if stage >= num_stages {
            bail!("stage {stage} out of range");
        }
        NativeBackend::new(self.model.clone(), stage, num_stages, resume_from)
    }
}

/// One native pipeline cell (see module docs).
pub struct NativeBackend {
    dims: ModelDims,
    #[allow(dead_code)]
    stage: usize,
    pub stage_p: ParamSet,
    pub embed_p: Option<ParamSet>,
    pub head_p: Option<ParamSet>,
}

impl NativeBackend {
    pub fn new(
        dims: ModelDims,
        stage: usize,
        num_stages: usize,
        resume_from: Option<&Path>,
    ) -> Result<NativeBackend> {
        let is_first = stage == 0;
        let is_last = stage == num_stages - 1;
        let mut b = NativeBackend {
            stage_p: init_stage(&dims, stage),
            embed_p: is_first.then(|| init_embed(&dims)),
            head_p: is_last.then(|| init_head(&dims)),
            dims,
            stage,
        };
        if let Some(dir) = resume_from {
            b.stage_p.load(dir)?;
            if let Some(g) = b.embed_p.as_mut() {
                g.load(dir)?;
            }
            if let Some(g) = b.head_p.as_mut() {
                g.load(dir)?;
            }
        }
        Ok(b)
    }

    fn check_tokens(&self, tokens: &[i32], len: usize) -> Result<()> {
        if tokens.len() != self.dims.batch * len {
            bail!("expected {} tokens, got {}", self.dims.batch * len, tokens.len());
        }
        if let Some(&t) = tokens.iter().find(|&&t| t < 0 || t as usize >= self.dims.vocab) {
            bail!("token id {t} outside vocab 0..{}", self.dims.vocab);
        }
        Ok(())
    }
}

impl StageBackend for NativeBackend {
    fn dims(&self) -> &ModelDims {
        &self.dims
    }

    fn embed_fwd(&mut self, tokens: &[i32], len: usize, off: usize) -> Result<HostTensor> {
        self.check_tokens(tokens, len)?;
        let eg = self.embed_p.as_ref().ok_or_else(|| anyhow::anyhow!("no embedding on this stage"))?;
        let h = cell::embed_fwd(&self.dims, len, off, &eg.params, tokens);
        Ok(HostTensor::f32(&[self.dims.batch, len, self.dims.hidden], h))
    }

    fn stage_fwd(
        &mut self,
        h: &HostTensor,
        k_ctx: &HostTensor,
        v_ctx: &HostTensor,
        off: usize,
    ) -> Result<(HostTensor, HostTensor, HostTensor)> {
        let d = &self.dims;
        let len = h.shape[1];
        let mut h_out = HostTensor::zeros_f32(&[d.batch, len, d.hidden]);
        let mut k_new = HostTensor::zeros_f32(&d.kv_new_shape(len));
        let mut v_new = HostTensor::zeros_f32(&d.kv_new_shape(len));
        cell::stage_fwd_into(
            d,
            len,
            off,
            &self.stage_p.params,
            h.as_f32(),
            k_ctx.as_f32(),
            v_ctx.as_f32(),
            h_out.as_f32_mut(),
            k_new.as_f32_mut(),
            v_new.as_f32_mut(),
        );
        Ok((h_out, k_new, v_new))
    }

    fn head_loss(&mut self, h_out: &HostTensor, targets: &[i32], len: usize) -> Result<f32> {
        self.check_tokens(targets, len)?;
        let hg = self.head_p.as_ref().ok_or_else(|| anyhow::anyhow!("no head on this stage"))?;
        Ok(cell::head_fwd(&self.dims, len, &hg.params, h_out.as_f32(), targets))
    }

    fn head_bwd(&mut self, h_out: &HostTensor, targets: &[i32], len: usize) -> Result<HostTensor> {
        self.check_tokens(targets, len)?;
        let d = self.dims.clone();
        let hg = self.head_p.as_mut().ok_or_else(|| anyhow::anyhow!("no head on this stage"))?;
        let g_h = cell::head_bwd(&d, len, &hg.params, h_out.as_f32(), targets, &mut hg.grads);
        Ok(HostTensor::f32(&[d.batch, len, d.hidden], g_h))
    }

    fn stage_bwd(
        &mut self,
        h_in: &HostTensor,
        k_ctx: &HostTensor,
        v_ctx: &HostTensor,
        off: usize,
        g_h: &HostTensor,
        g_know: &HostTensor,
        g_vnow: &HostTensor,
    ) -> Result<(HostTensor, HostTensor, HostTensor)> {
        let d = self.dims.clone();
        let len = h_in.shape[1];
        let mut g_h_in = HostTensor::zeros_f32(&[d.batch, len, d.hidden]);
        let mut g_kctx = HostTensor::zeros_f32(&d.kv_shape());
        let mut g_vctx = HostTensor::zeros_f32(&d.kv_shape());
        cell::stage_bwd_into(
            &d,
            len,
            off,
            &self.stage_p.params,
            h_in.as_f32(),
            k_ctx.as_f32(),
            v_ctx.as_f32(),
            g_h.as_f32(),
            g_know.as_f32(),
            g_vnow.as_f32(),
            &mut self.stage_p.grads,
            g_h_in.as_f32_mut(),
            g_kctx.as_f32_mut(),
            g_vctx.as_f32_mut(),
        );
        Ok((g_h_in, g_kctx, g_vctx))
    }

    fn embed_bwd(&mut self, tokens: &[i32], len: usize, off: usize, g_h: &HostTensor) -> Result<()> {
        self.check_tokens(tokens, len)?;
        let d = self.dims.clone();
        let eg = self.embed_p.as_mut().ok_or_else(|| anyhow::anyhow!("no embedding on this stage"))?;
        cell::embed_bwd(&d, len, off, tokens, g_h.as_f32(), &mut eg.grads);
        Ok(())
    }

    fn update(&mut self, step: i32, lr: f32) -> Result<()> {
        self.stage_p.adam(step, lr);
        if let Some(g) = self.embed_p.as_mut() {
            g.adam(step, lr);
        }
        if let Some(g) = self.head_p.as_mut() {
            g.adam(step, lr);
        }
        Ok(())
    }

    fn checkpoint(&self, dir: &Path) -> Result<()> {
        self.stage_p.save(dir)?;
        if let Some(g) = &self.embed_p {
            g.save(dir)?;
        }
        if let Some(g) = &self.head_p {
            g.save(dir)?;
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny_dims() -> ModelDims {
        ModelDims {
            vocab: 17,
            hidden: 8,
            num_heads: 2,
            layers_per_stage: 1,
            num_stages: 2,
            seq_len: 8,
            batch: 2,
            block_ctx: 4,
            seed: 11,
        }
    }

    #[test]
    fn init_is_deterministic_and_role_scoped() {
        let spec = NativeSpec::new(tiny_dims(), 2);
        let a = spec.build(0, 2, None).unwrap();
        let b = spec.build(0, 2, None).unwrap();
        for (x, y) in a.stage_p.params.iter().zip(&b.stage_p.params) {
            assert_eq!(x, y);
        }
        assert!(a.embed_p.is_some() && a.head_p.is_none());
        let last = spec.build(1, 2, None).unwrap();
        assert!(last.embed_p.is_none() && last.head_p.is_some());
        // different stages draw different weights
        assert_ne!(a.stage_p.params[2], last.stage_p.params[2]);
    }

    #[test]
    fn buckets_are_multiples_of_granularity() {
        let spec = NativeSpec::new(tiny_dims(), 2);
        assert_eq!(spec.buckets(), vec![2, 4, 6, 8]);
    }

    #[test]
    fn checkpoint_roundtrips_params_and_moments() {
        let spec = NativeSpec::new(tiny_dims(), 2);
        let dir = std::env::temp_dir().join(format!("terapipe-native-ckpt-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let mut a = spec.build(0, 2, None).unwrap();
        // take one optimizer step so moments are nonzero
        for g in &mut a.stage_p.grads {
            g.as_f32_mut().iter_mut().for_each(|x| *x = 0.01);
        }
        a.update(1, 1e-3).unwrap();
        a.checkpoint(&dir).unwrap();
        let b = spec.build(0, 2, Some(&dir)).unwrap();
        for (x, y) in a.stage_p.params.iter().zip(&b.stage_p.params) {
            assert_eq!(x, y);
        }
        for (x, y) in a.stage_p.m.iter().zip(&b.stage_p.m) {
            assert_eq!(x, y);
        }
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn rejects_out_of_vocab_tokens() {
        let spec = NativeSpec::new(tiny_dims(), 2);
        let mut b = spec.build(0, 2, None).unwrap();
        let bad = vec![99i32; 2 * 2];
        assert!(b.embed_fwd(&bad, 2, 0).is_err());
    }
}
