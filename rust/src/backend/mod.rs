//! Pluggable stage-compute backends for the token-level pipeline.
//!
//! TeraPipe's coordinator is pure schedule: token slices flow downstream,
//! gradients flow back upstream, KV context buffers grow per slice. What
//! *computes* each slice on each stage is a backend behind the
//! [`StageBackend`] trait — the same pluggable-executor split GPipe and
//! Megatron-LM make between schedule and cell compute:
//!
//! * [`native::NativeBackend`] — the default: a pure-Rust, multi-threaded
//!   CPU implementation of the sliced transformer cell (embedding, causal
//!   attention over the padded KV context, MLP, layernorm, head loss)
//!   with exact forward *and* backward plus fused Adam. Always available;
//!   this is what `cargo test` and `terapipe train`/`measure` exercise.
//! * [`pjrt::PjrtBackend`] — (feature `pjrt`) the AOT-compiled XLA
//!   executables through the PJRT runtime, one client per stage worker.
//!
//! A backend owns its stage's parameters and optimizer state; the
//! coordinator never sees a weight. Construction happens on the worker
//! thread via a [`BackendSpec`] (the only thing that crosses threads), so
//! non-`Send` backend internals — PJRT handles, scratch arenas — are
//! fine. See `backend/README.md` for the full trait contract, numerics
//! and threading model.

use std::path::{Path, PathBuf};

use anyhow::Result;

use crate::perfmodel::linear::LinearCtxModel;
use crate::perfmodel::measure;
use crate::runtime::manifest::ModelDims;
use crate::runtime::tensor::HostTensor;

pub mod cell;
pub mod math;
pub mod native;
pub mod simd;
#[cfg(feature = "pjrt")]
pub mod pjrt;

pub use native::{NativeBackend, NativeSpec, ParamSet};
#[cfg(feature = "pjrt")]
pub use pjrt::PjrtSpec;

/// One pipeline cell's compute + state: slice-shaped forward/backward
/// with explicit KV-context plumbing, gradient accumulation, the
/// optimizer step and checkpoint I/O. All tensor traffic is
/// [`HostTensor`]; shapes follow `ModelDims` (`[B,S,H]` activations,
/// `[NL,B,T,NH,HD]` KV buffers).
///
/// Contract (what the coordinator relies on):
///
/// * `stage_fwd` reads the context buffers for positions `< off` only
///   (later positions may hold garbage) and returns this slice's K/V for
///   the coordinator to scatter at `off`.
/// * `stage_bwd` is the exact VJP of `stage_fwd`: `g_know`/`g_vnow` are
///   the accumulated grads w.r.t. this slice's own K/V from *later*
///   slices; the returned `g_kctx`/`g_vctx` are grads w.r.t. the padded
///   context buffers (the slice's own window zeroed), which the
///   coordinator accumulates for *earlier* slices.
/// * `head_bwd`/`stage_bwd`/`embed_bwd` accumulate parameter grads
///   internally; `update` applies Adam with the accumulated grads (bias
///   correction uses the 1-based `step`) and zeroes them.
/// * `checkpoint` writes every owned tensor under `dir` such that a
///   backend rebuilt with `resume_from = dir` continues the exact
///   trajectory.
pub trait StageBackend {
    fn dims(&self) -> &ModelDims;

    /// Token + position embedding for a slice (first stage only):
    /// `tokens` is `B·len` ids, `off` the slice's position offset.
    fn embed_fwd(&mut self, tokens: &[i32], len: usize, off: usize) -> Result<HostTensor>;

    /// One cell forward: `(h_out, k_new, v_new)` for a `[B,S,H]` slice
    /// against the `[NL,B,T,NH,HD]` context buffers.
    fn stage_fwd(
        &mut self,
        h: &HostTensor,
        k_ctx: &HostTensor,
        v_ctx: &HostTensor,
        off: usize,
    ) -> Result<(HostTensor, HostTensor, HostTensor)>;

    /// Summed token cross-entropy of a slice (last stage only).
    fn head_loss(&mut self, h_out: &HostTensor, targets: &[i32], len: usize) -> Result<f32>;

    /// Head VJP (last stage only): accumulates head param grads, returns
    /// the grad w.r.t. the stage output `h_out`.
    fn head_bwd(&mut self, h_out: &HostTensor, targets: &[i32], len: usize) -> Result<HostTensor>;

    /// Cell VJP: returns `(g_h_in, g_kctx, g_vctx)`; see trait docs.
    #[allow(clippy::too_many_arguments)]
    fn stage_bwd(
        &mut self,
        h_in: &HostTensor,
        k_ctx: &HostTensor,
        v_ctx: &HostTensor,
        off: usize,
        g_h: &HostTensor,
        g_know: &HostTensor,
        g_vnow: &HostTensor,
    ) -> Result<(HostTensor, HostTensor, HostTensor)>;

    /// Embedding VJP (first stage only): accumulates embedding grads.
    fn embed_bwd(&mut self, tokens: &[i32], len: usize, off: usize, g_h: &HostTensor) -> Result<()>;

    /// Apply the optimizer with the accumulated gradients, then zero them.
    fn update(&mut self, step: i32, lr: f32) -> Result<()>;

    /// Persist this stage's parameters (+ optimizer moments) under `dir`.
    fn checkpoint(&self, dir: &Path) -> Result<()>;
}

/// Recipe for building the per-stage backends of one pipeline. The spec
/// is the only backend object that crosses threads: each worker calls
/// [`BackendSpec::build`] on its own thread.
pub trait BackendSpec: Clone + Send + Sync + 'static {
    type Backend: StageBackend;

    /// Model geometry all stages share.
    fn model(&self) -> ModelDims;

    /// Slice lengths the backend supports (the planner's bucket set).
    fn buckets(&self) -> Vec<usize>;

    /// Build stage `stage` of a `num_stages`-deep pipeline, loading
    /// parameters from `resume_from` when given.
    fn build(&self, stage: usize, num_stages: usize, resume_from: Option<&Path>) -> Result<Self::Backend>;
}

/// `init/stage0.w.bin` → `init/m.stage0.w.bin` (same dir, prefixed stem) —
/// the shared moment-file convention for checkpoints.
pub fn moment_path(file: &Path, prefix: &str) -> PathBuf {
    let name = file.file_name().unwrap().to_string_lossy();
    file.parent()
        .unwrap_or_else(|| Path::new(""))
        .join(format!("{prefix}.{name}"))
}

/// Read one checkpoint tensor: raw little-endian f32, size-checked
/// against `shape` — the cross-backend file format both `checkpoint`
/// implementations share.
pub fn read_f32_file(path: &Path, shape: &[usize]) -> Result<HostTensor> {
    use anyhow::Context;
    let bytes = std::fs::read(path).with_context(|| format!("reading {}", path.display()))?;
    let n: usize = shape.iter().product::<usize>().max(1);
    anyhow::ensure!(
        bytes.len() == 4 * n,
        "{}: expected {} bytes, got {}",
        path.display(),
        4 * n,
        bytes.len()
    );
    let floats = bytes
        .chunks_exact(4)
        .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
        .collect();
    Ok(HostTensor::f32(shape, floats))
}

/// Write one checkpoint tensor (raw LE f32), the inverse of
/// [`read_f32_file`].
pub fn write_f32_file(path: &Path, t: &HostTensor) -> Result<()> {
    let bytes: Vec<u8> = t.as_f32().iter().flat_map(|x| x.to_le_bytes()).collect();
    std::fs::write(path, bytes)?;
    Ok(())
}

/// The §3.5 measurement harness on a real backend: wall-clock one slice
/// of `i` tokens over `j` tokens of context through `stage_fwd` +
/// `stage_bwd` (the combined fwd+bwd latency [`crate::perfmodel::CostModel`]
/// models). Returns a [`measure::SliceTimer`]-compatible pair.
pub fn slice_timer<B: StageBackend>(
    mut backend: B,
    buckets: Vec<usize>,
) -> (impl FnMut(u32, u32) -> f64, Vec<u32>) {
    let d = backend.dims().clone();
    let timer = move |i: u32, j: u32| -> f64 {
        let len = i as usize;
        let off = j as usize;
        let h = HostTensor::zeros_f32(&[d.batch, len, d.hidden]);
        let k_ctx = HostTensor::zeros_f32(&d.kv_shape());
        let v_ctx = HostTensor::zeros_f32(&d.kv_shape());
        let g_h = HostTensor::zeros_f32(&[d.batch, len, d.hidden]);
        let g_know = HostTensor::zeros_f32(&d.kv_new_shape(len));
        let g_vnow = HostTensor::zeros_f32(&d.kv_new_shape(len));
        let t_us = crate::obs::maybe_start();
        let (_, ms) = crate::util::time_ms(|| {
            let _ = backend
                .stage_fwd(&h, &k_ctx, &v_ctx, off)
                .expect("measure stage_fwd");
            let _ = backend
                .stage_bwd(&h, &k_ctx, &v_ctx, off, &g_h, &g_know, &g_vnow)
                .expect("measure stage_bwd");
        });
        // probe span: measurement traffic, not training work — tagged
        // with MB_PROBE so the exec↔sim differential ignores it.
        crate::obs::emit(
            crate::obs::SpanKind::SliceFwd,
            crate::obs::DRIVER,
            crate::obs::MB_PROBE,
            0,
            i as u64,
            j as u64,
            t_us,
        );
        ms
    };
    (timer, buckets.into_iter().map(|b| b as u32).collect())
}

/// Measure a representative cell of `spec` on real backend timings and
/// fit the Eq. 9 linear context model — the `perfmodel::measure` → `fit`
/// path behind `terapipe measure`, `--auto` slicing, and the drift
/// loop's re-measure, shared by both backends.
pub fn measure_fit<S: BackendSpec>(spec: &S, repeats: u32) -> Result<LinearCtxModel> {
    let m = spec.model();
    // a middle stage (no embed/head) is the representative cell
    let stage = 1 % m.num_stages;
    let backend = spec.build(stage, m.num_stages, None)?;
    let mut timer = slice_timer(backend, spec.buckets());
    let meas = measure::measure(&mut timer, m.seq_len as u32, 4, repeats);
    measure::fit(&meas, m.seq_len as u32).map_err(|e| anyhow::anyhow!(e))
}

/// [`slice_timer`] with the stage's *role* folded in, matching what the
/// coordinator's timing samples actually cover: the first stage's slice
/// latency includes `embed_fwd`/`embed_bwd`, the last stage's includes
/// `head_loss`/`head_bwd`. Middle stages reduce to the plain cell.
pub fn role_slice_timer<B: StageBackend>(
    mut backend: B,
    role: measure::StageRole,
    buckets: Vec<usize>,
) -> (impl FnMut(u32, u32) -> f64, Vec<u32>) {
    use measure::StageRole;
    let d = backend.dims().clone();
    let timer = move |i: u32, j: u32| -> f64 {
        let len = i as usize;
        let off = j as usize;
        let tokens = vec![0i32; d.batch * len];
        let h = HostTensor::zeros_f32(&[d.batch, len, d.hidden]);
        let k_ctx = HostTensor::zeros_f32(&d.kv_shape());
        let v_ctx = HostTensor::zeros_f32(&d.kv_shape());
        let g_h = HostTensor::zeros_f32(&[d.batch, len, d.hidden]);
        let g_know = HostTensor::zeros_f32(&d.kv_new_shape(len));
        let g_vnow = HostTensor::zeros_f32(&d.kv_new_shape(len));
        let t_us = crate::obs::maybe_start();
        let (_, ms) = crate::util::time_ms(|| {
            let h_in = if role == StageRole::First {
                backend.embed_fwd(&tokens, len, off).expect("measure embed_fwd")
            } else {
                h.clone()
            };
            let (h_out, _, _) = backend
                .stage_fwd(&h_in, &k_ctx, &v_ctx, off)
                .expect("measure stage_fwd");
            let g_up = if role == StageRole::Last {
                let _ = backend.head_loss(&h_out, &tokens, len).expect("measure head_loss");
                backend.head_bwd(&h_out, &tokens, len).expect("measure head_bwd")
            } else {
                g_h.clone()
            };
            let (g_h_in, _, _) = backend
                .stage_bwd(&h_in, &k_ctx, &v_ctx, off, &g_up, &g_know, &g_vnow)
                .expect("measure stage_bwd");
            if role == StageRole::First {
                backend.embed_bwd(&tokens, len, off, &g_h_in).expect("measure embed_bwd");
            }
        });
        crate::obs::emit(
            crate::obs::SpanKind::SliceFwd,
            crate::obs::DRIVER,
            crate::obs::MB_PROBE,
            0,
            i as u64,
            j as u64,
            t_us,
        );
        ms
    };
    (timer, buckets.into_iter().map(|b| b as u32).collect())
}

/// [`measure_fit`] per stage role: separate Eq. 9 fits for the first
/// stage (embed + cell), a middle cell, and the last stage (cell + head).
/// With fewer than three stages there is no middle cell to measure; the
/// slot is filled with the first stage's fit (it is never queried —
/// [`measure::StageModels::for_stage`] only maps interior stages to it).
pub fn measure_fit_per_stage<S: BackendSpec>(spec: &S, repeats: u32) -> Result<measure::StageModels> {
    use measure::StageRole;
    let m = spec.model();
    let k = m.num_stages;
    let mut fit_role = |stage: usize, role: StageRole| -> Result<LinearCtxModel> {
        let backend = spec.build(stage, k, None)?;
        let mut timer = role_slice_timer(backend, role, spec.buckets());
        let meas = measure::measure(&mut timer, m.seq_len as u32, 4, repeats);
        measure::fit(&meas, m.seq_len as u32).map_err(|e| anyhow::anyhow!(e))
    };
    let first = fit_role(0, StageRole::of(0, k))?;
    let last = fit_role(k - 1, StageRole::of(k - 1, k))?;
    let middle = if k >= 3 { fit_role(1, StageRole::Middle)? } else { first.clone() };
    Ok(measure::StageModels { first, middle, last })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn moment_path_prefixes_stem() {
        let p = moment_path(Path::new("ckpt/init/stage0.w.bin"), "m");
        assert_eq!(p, Path::new("ckpt/init/m.stage0.w.bin"));
    }

    #[test]
    fn per_stage_fits_are_queryable_and_role_mapped() {
        use crate::perfmodel::measure::StageRole;
        use crate::perfmodel::CostModel;
        let dims = ModelDims {
            vocab: 17,
            hidden: 8,
            num_heads: 2,
            layers_per_stage: 1,
            num_stages: 2,
            seq_len: 8,
            batch: 1,
            block_ctx: 4,
            seed: 5,
        };
        let spec = NativeSpec::new(dims, 2);
        let models = measure_fit_per_stage(&spec, 1).unwrap();
        for m in [&models.first, &models.middle, &models.last] {
            let t = m.t(4, 2);
            assert!(t.is_finite() && t >= 0.0, "t(4,2) = {t}");
        }
        assert_eq!(StageRole::of(0, 2), StageRole::First);
        assert_eq!(StageRole::of(1, 2), StageRole::Last);
        assert_eq!(StageRole::of(1, 3), StageRole::Middle);
        assert_eq!(StageRole::of(0, 1), StageRole::Last);
        // for_stage maps the ends of a 2-stage pipeline to first/last
        let f = models.for_stage(0, 2) as *const _;
        let l = models.for_stage(1, 2) as *const _;
        assert_eq!(f, &models.first as *const _);
        assert_eq!(l, &models.last as *const _);
    }

    #[test]
    fn measure_fit_produces_queryable_model() {
        use crate::perfmodel::CostModel;
        let dims = ModelDims {
            vocab: 17,
            hidden: 8,
            num_heads: 2,
            layers_per_stage: 1,
            num_stages: 2,
            seq_len: 8,
            batch: 1,
            block_ctx: 4,
            seed: 5,
        };
        let spec = NativeSpec::new(dims, 2);
        let fitted = measure_fit(&spec, 1).unwrap();
        // every on-grid (i, j) with i + j ≤ L answers with a finite time
        for i in [2u32, 4, 8] {
            for j in [0u32, 2, 4] {
                if i + j <= 8 {
                    let t = fitted.t(i, j);
                    assert!(t.is_finite() && t >= 0.0, "t({i},{j}) = {t}");
                }
            }
        }
    }
}
