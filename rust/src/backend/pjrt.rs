//! The PJRT stage backend: AOT-compiled XLA executables behind the
//! [`StageBackend`] trait (feature `pjrt`).
//!
//! This is the execution engine the coordinator originally hard-wired:
//! one `StageRuntime` (own PJRT client + compiled executables) per
//! worker, parameters kept both as host tensors (optimizer step,
//! checkpoints) and as pre-converted PJRT literals (they are inputs to
//! every slice executable, so caching the upload halves the per-slice
//! host work — EXPERIMENTS.md §Perf L3). The refactor moved all of that
//! here unchanged; the worker now only speaks the trait.

use std::path::{Path, PathBuf};

use anyhow::{anyhow, Context, Result};

use super::{moment_path, read_f32_file, write_f32_file, BackendSpec, StageBackend};
use crate::runtime::manifest::{InitEntry, Manifest, ModelDims};
use crate::runtime::tensor::HostTensor;
use crate::runtime::{stage_exe_names, StageRuntime};

/// An optimizer-managed parameter group backed by an `adam_<group>`
/// executable, with cached literal uploads of the current parameters.
struct ParamGroup {
    exe: String,
    params: Vec<HostTensor>,
    /// Cached literal uploads of `params` (invalidated by `apply`).
    lits: Vec<xla::Literal>,
    grads: Vec<HostTensor>,
    m: Vec<HostTensor>,
    v: Vec<HostTensor>,
}

impl ParamGroup {
    fn new(exe: &str, params: Vec<HostTensor>) -> Result<Self> {
        let zeros: Vec<HostTensor> = params
            .iter()
            .map(|p| HostTensor::zeros_f32(&p.shape))
            .collect();
        let lits = params
            .iter()
            .map(|p| p.to_literal())
            .collect::<Result<Vec<_>>>()?;
        Ok(ParamGroup {
            exe: exe.to_string(),
            lits,
            grads: zeros.clone(),
            m: zeros.clone(),
            v: zeros,
            params,
        })
    }

    fn accumulate(&mut self, slice_grads: &[HostTensor]) {
        assert_eq!(slice_grads.len(), self.grads.len(), "{} grad arity", self.exe);
        for (g, s) in self.grads.iter_mut().zip(slice_grads) {
            g.add_assign(s);
        }
    }

    fn apply(&mut self, rt: &StageRuntime, step: i32, lr: f32) -> Result<()> {
        let n = self.params.len();
        let mut inputs = Vec::with_capacity(4 * n + 2);
        inputs.extend(self.params.iter().cloned());
        inputs.extend(self.grads.iter().cloned());
        inputs.extend(self.m.iter().cloned());
        inputs.extend(self.v.iter().cloned());
        inputs.push(HostTensor::scalar_i32(step));
        inputs.push(HostTensor::scalar_f32(lr));
        let mut out = rt.run(&self.exe, &inputs)?;
        // outputs: params, m, v — in that order
        self.v = out.split_off(2 * n);
        self.m = out.split_off(n);
        self.params = out;
        self.lits = self
            .params
            .iter()
            .map(|p| p.to_literal())
            .collect::<Result<Vec<_>>>()?;
        for g in &mut self.grads {
            g.fill_zero();
        }
        Ok(())
    }
}

/// Spec for the PJRT pipeline: the artifact dir (manifest + HLO text +
/// init weights produced by `make artifacts`).
#[derive(Debug, Clone)]
pub struct PjrtSpec {
    pub artifacts: PathBuf,
    model: ModelDims,
    buckets: Vec<usize>,
}

impl PjrtSpec {
    pub fn new(artifacts: &Path) -> Result<PjrtSpec> {
        let manifest = Manifest::load(artifacts)?;
        Ok(PjrtSpec {
            artifacts: artifacts.to_path_buf(),
            model: manifest.model.clone(),
            buckets: manifest.buckets.clone(),
        })
    }
}

impl BackendSpec for PjrtSpec {
    type Backend = PjrtBackend;

    fn model(&self) -> ModelDims {
        self.model.clone()
    }

    fn buckets(&self) -> Vec<usize> {
        self.buckets.clone()
    }

    fn build(&self, stage: usize, num_stages: usize, resume_from: Option<&Path>) -> Result<PjrtBackend> {
        PjrtBackend::new(&self.artifacts, stage, num_stages, resume_from)
    }
}

/// One PJRT pipeline cell (see module docs).
pub struct PjrtBackend {
    stage: usize,
    rt: StageRuntime,
    dims: ModelDims,
    stage_group: ParamGroup,
    embed_group: Option<ParamGroup>,
    head_group: Option<ParamGroup>,
}

impl PjrtBackend {
    pub fn new(
        artifacts: &Path,
        stage: usize,
        num_stages: usize,
        resume_from: Option<&Path>,
    ) -> Result<PjrtBackend> {
        let is_first = stage == 0;
        let is_last = stage == num_stages - 1;
        let manifest = Manifest::load(artifacts)?;
        let names = stage_exe_names(stage, num_stages, &manifest.buckets);
        let rt = StageRuntime::load(artifacts, &names)
            .with_context(|| format!("stage {stage}: loading runtime"))?;
        let dims = rt.manifest.model.clone();

        // Parameters (and, when resuming, Adam moments) from artifacts/init
        // or a checkpoint dir (same file layout — see `checkpoint`).
        let mk_group = |exe: &str, entries: &[InitEntry]| -> Result<ParamGroup> {
            match resume_from {
                None => ParamGroup::new(exe, rt.manifest.load_init(entries)?),
                Some(dir) => {
                    let params = entries
                        .iter()
                        .map(|e| read_f32_file(&dir.join(&e.file), &e.shape))
                        .collect::<Result<Vec<_>>>()?;
                    let mut g = ParamGroup::new(exe, params)?;
                    // moments are optional (params-only checkpoints load too)
                    if entries
                        .iter()
                        .all(|e| moment_path(&dir.join(&e.file), "m").exists())
                    {
                        g.m = entries
                            .iter()
                            .map(|e| read_f32_file(&moment_path(&dir.join(&e.file), "m"), &e.shape))
                            .collect::<Result<Vec<_>>>()?;
                        g.v = entries
                            .iter()
                            .map(|e| read_f32_file(&moment_path(&dir.join(&e.file), "v"), &e.shape))
                            .collect::<Result<Vec<_>>>()?;
                    }
                    Ok(g)
                }
            }
        };
        let stage_group = mk_group("adam_stage", &rt.manifest.init_stages[stage])?;
        let embed_group = is_first
            .then(|| mk_group("adam_embed", &rt.manifest.init_embed))
            .transpose()?;
        let head_group = is_last
            .then(|| mk_group("adam_head", &rt.manifest.init_head))
            .transpose()?;
        drop(manifest);
        Ok(PjrtBackend {
            stage,
            rt,
            dims,
            stage_group,
            embed_group,
            head_group,
        })
    }
}

impl StageBackend for PjrtBackend {
    fn dims(&self) -> &ModelDims {
        &self.dims
    }

    fn embed_fwd(&mut self, tokens: &[i32], len: usize, off: usize) -> Result<HostTensor> {
        let eg = self
            .embed_group
            .as_ref()
            .ok_or_else(|| anyhow!("tokens arrived at non-first stage {}", self.stage))?;
        let tok_l = HostTensor::i32(&[self.dims.batch, len], tokens.to_vec()).to_literal()?;
        let off_l = HostTensor::scalar_i32(off as i32).to_literal()?;
        let mut args: Vec<&xla::Literal> = eg.lits.iter().collect();
        args.push(&tok_l);
        args.push(&off_l);
        Ok(self
            .rt
            .run_literal_refs(&format!("embed_fwd_s{len}"), &args)?
            .remove(0))
    }

    fn stage_fwd(
        &mut self,
        h: &HostTensor,
        k_ctx: &HostTensor,
        v_ctx: &HostTensor,
        off: usize,
    ) -> Result<(HostTensor, HostTensor, HostTensor)> {
        let len = h.shape[1];
        let h_l = h.to_literal()?;
        let k_l = k_ctx.to_literal()?;
        let v_l = v_ctx.to_literal()?;
        let off_l = HostTensor::scalar_i32(off as i32).to_literal()?;
        let mut args: Vec<&xla::Literal> = self.stage_group.lits.iter().collect();
        args.extend([&h_l, &k_l, &v_l, &off_l]);
        let mut out = self.rt.run_literal_refs(&format!("stage_fwd_s{len}"), &args)?;
        let v_new = out.pop().unwrap();
        let k_new = out.pop().unwrap();
        let h_out = out.pop().unwrap();
        Ok((h_out, k_new, v_new))
    }

    fn head_loss(&mut self, h_out: &HostTensor, targets: &[i32], len: usize) -> Result<f32> {
        let hg = self
            .head_group
            .as_ref()
            .ok_or_else(|| anyhow!("head_loss on non-last stage {}", self.stage))?;
        let tg_l = HostTensor::i32(&[self.dims.batch, len], targets.to_vec()).to_literal()?;
        let h_l = h_out.to_literal()?;
        let mut args: Vec<&xla::Literal> = hg.lits.iter().collect();
        args.extend([&h_l, &tg_l]);
        let loss = self
            .rt
            .run_literal_refs(&format!("head_fwd_s{len}"), &args)?
            .remove(0);
        Ok(loss.as_f32()[0])
    }

    fn head_bwd(&mut self, h_out: &HostTensor, targets: &[i32], len: usize) -> Result<HostTensor> {
        let hg = self
            .head_group
            .as_ref()
            .ok_or_else(|| anyhow!("head_bwd on non-last stage {}", self.stage))?;
        let tg_l = HostTensor::i32(&[self.dims.batch, len], targets.to_vec()).to_literal()?;
        let h_l = h_out.to_literal()?;
        let mut args: Vec<&xla::Literal> = hg.lits.iter().collect();
        args.extend([&h_l, &tg_l]);
        let mut out = self.rt.run_literal_refs(&format!("head_bwd_s{len}"), &args)?;
        let g_h = out.pop().unwrap();
        self.head_group.as_mut().unwrap().accumulate(&out);
        Ok(g_h)
    }

    fn stage_bwd(
        &mut self,
        h_in: &HostTensor,
        k_ctx: &HostTensor,
        v_ctx: &HostTensor,
        off: usize,
        g_h: &HostTensor,
        g_know: &HostTensor,
        g_vnow: &HostTensor,
    ) -> Result<(HostTensor, HostTensor, HostTensor)> {
        let len = h_in.shape[1];
        let h_l = h_in.to_literal()?;
        let k_l = k_ctx.to_literal()?;
        let v_l = v_ctx.to_literal()?;
        let off_l = HostTensor::scalar_i32(off as i32).to_literal()?;
        let gh_l = g_h.to_literal()?;
        let gk_l = g_know.to_literal()?;
        let gv_l = g_vnow.to_literal()?;
        let mut args: Vec<&xla::Literal> = self.stage_group.lits.iter().collect();
        args.extend([&h_l, &k_l, &v_l, &off_l, &gh_l, &gk_l, &gv_l]);
        let mut out = self.rt.run_literal_refs(&format!("stage_bwd_s{len}"), &args)?;
        let g_vctx = out.pop().unwrap();
        let g_kctx = out.pop().unwrap();
        let g_h_in = out.pop().unwrap();
        self.stage_group.accumulate(&out);
        Ok((g_h_in, g_kctx, g_vctx))
    }

    fn embed_bwd(&mut self, tokens: &[i32], len: usize, off: usize, g_h: &HostTensor) -> Result<()> {
        let eg = self
            .embed_group
            .as_ref()
            .ok_or_else(|| anyhow!("embed_bwd on non-first stage {}", self.stage))?;
        let tok_l = HostTensor::i32(&[self.dims.batch, len], tokens.to_vec()).to_literal()?;
        let off_l = HostTensor::scalar_i32(off as i32).to_literal()?;
        let gh_l = g_h.to_literal()?;
        let mut args: Vec<&xla::Literal> = eg.lits.iter().collect();
        args.extend([&tok_l, &off_l, &gh_l]);
        let out = self.rt.run_literal_refs(&format!("embed_bwd_s{len}"), &args)?;
        self.embed_group.as_mut().unwrap().accumulate(&out);
        Ok(())
    }

    fn update(&mut self, step: i32, lr: f32) -> Result<()> {
        self.stage_group.apply(&self.rt, step, lr)?;
        if let Some(g) = self.embed_group.as_mut() {
            g.apply(&self.rt, step, lr)?;
        }
        if let Some(g) = self.head_group.as_mut() {
            g.apply(&self.rt, step, lr)?;
        }
        Ok(())
    }

    /// Write this stage's parameter groups under `dir` in the init-file
    /// layout (init/stage{k}.name.bin etc.), so checkpoints are loadable
    /// via `resume_from`.
    fn checkpoint(&self, dir: &Path) -> Result<()> {
        std::fs::create_dir_all(dir.join("init"))?;
        let manifest = &self.rt.manifest;
        let groups: Vec<(&[InitEntry], &ParamGroup)> = {
            let mut v: Vec<(&[InitEntry], &ParamGroup)> =
                vec![(manifest.init_stages[self.stage].as_slice(), &self.stage_group)];
            if let Some(g) = &self.embed_group {
                v.push((manifest.init_embed.as_slice(), g));
            }
            if let Some(g) = &self.head_group {
                v.push((manifest.init_head.as_slice(), g));
            }
            v
        };
        for (entries, group) in groups {
            for (i, e) in entries.iter().enumerate() {
                write_f32_file(&dir.join(&e.file), &group.params[i])?;
                // optimizer moments beside the params, "m."/"v." prefixed
                write_f32_file(&moment_path(&dir.join(&e.file), "m"), &group.m[i])?;
                write_f32_file(&moment_path(&dir.join(&e.file), "v"), &group.v[i])?;
            }
        }
        Ok(())
    }
}
