//! Dense f32 kernels for the native CPU stage backend.
//!
//! Everything here is deliberately boring: row-major matmuls, layernorm,
//! GELU — the exact formulas `python/compile/model.py` lowers through XLA,
//! transcribed so the native backend and the PJRT backend compute the same
//! function. Two properties matter more than raw speed:
//!
//! * **Determinism.** Results must not depend on the rayon thread count or
//!   scheduling: row-parallel kernels give each output row to exactly one
//!   worker (no cross-thread accumulation), and the transposed-product
//!   reduction ([`matmul_tn`]) splits the contraction into a *fixed* number
//!   of chunks whose partials are summed in chunk order. Same inputs →
//!   bit-identical outputs, single-threaded or not.
//! * **Parallelism.** The big products (QKV, MLP, LM head and their
//!   gradients) fan out across rayon once the work crosses
//!   [`PAR_THRESHOLD`] multiply-adds; tiny test-sized problems stay serial
//!   to skip the fork/join overhead.

use rayon::prelude::*;

/// Multiply-add count below which kernels run serially.
pub const PAR_THRESHOLD: usize = 1 << 16;

/// Fixed chunk count for deterministic reductions (independent of the
/// rayon pool size, so results don't vary with `RAYON_NUM_THREADS`).
const REDUCE_CHUNKS: usize = 8;

/// `out[m,n] = a[m,k] @ b[k,n]` (row-major).
pub fn matmul(a: &[f32], b: &[f32], m: usize, k: usize, n: usize) -> Vec<f32> {
    assert_eq!(a.len(), m * k);
    assert_eq!(b.len(), k * n);
    let mut out = vec![0f32; m * n];
    let row = |i: usize, out_row: &mut [f32]| {
        let ar = &a[i * k..(i + 1) * k];
        for (l, &av) in ar.iter().enumerate() {
            let br = &b[l * n..(l + 1) * n];
            for (o, &bv) in out_row.iter_mut().zip(br) {
                *o += av * bv;
            }
        }
    };
    if m * k * n >= PAR_THRESHOLD && m > 1 {
        out.par_chunks_mut(n).enumerate().for_each(|(i, r)| row(i, r));
    } else {
        for (i, r) in out.chunks_mut(n).enumerate() {
            row(i, r);
        }
    }
    out
}

/// `out[m,k] = a[m,n] @ b[k,n]ᵀ` — the backward-through-weights product
/// (`grad @ Wᵀ`). Each output row is an independent set of dot products.
pub fn matmul_nt(a: &[f32], b: &[f32], m: usize, n: usize, k: usize) -> Vec<f32> {
    assert_eq!(a.len(), m * n);
    assert_eq!(b.len(), k * n);
    let mut out = vec![0f32; m * k];
    let row = |i: usize, out_row: &mut [f32]| {
        let ar = &a[i * n..(i + 1) * n];
        for (j, o) in out_row.iter_mut().enumerate() {
            let br = &b[j * n..(j + 1) * n];
            let mut acc = 0f32;
            for (&x, &y) in ar.iter().zip(br) {
                acc += x * y;
            }
            *o = acc;
        }
    };
    if m * n * k >= PAR_THRESHOLD && m > 1 {
        out.par_chunks_mut(k).enumerate().for_each(|(i, r)| row(i, r));
    } else {
        for (i, r) in out.chunks_mut(k).enumerate() {
            row(i, r);
        }
    }
    out
}

/// `out[k,n] = a[m,k]ᵀ @ b[m,n]` — the weight-gradient product
/// (`xᵀ @ grad`). The contraction runs over `m`, so parallel workers must
/// accumulate into shared output: we split `m` into [`REDUCE_CHUNKS`]
/// fixed ranges, let each produce a private partial, and sum the partials
/// in chunk order — deterministic for any pool size.
pub fn matmul_tn(a: &[f32], b: &[f32], m: usize, k: usize, n: usize) -> Vec<f32> {
    assert_eq!(a.len(), m * k);
    assert_eq!(b.len(), m * n);
    let accumulate = |range: std::ops::Range<usize>, out: &mut [f32]| {
        for r in range {
            let ar = &a[r * k..(r + 1) * k];
            let br = &b[r * n..(r + 1) * n];
            for (i, &av) in ar.iter().enumerate() {
                let o = &mut out[i * n..(i + 1) * n];
                for (ov, &bv) in o.iter_mut().zip(br) {
                    *ov += av * bv;
                }
            }
        }
    };
    if m * k * n >= PAR_THRESHOLD && m >= 2 * REDUCE_CHUNKS {
        let chunk = m.div_ceil(REDUCE_CHUNKS);
        let partials: Vec<Vec<f32>> = (0..REDUCE_CHUNKS)
            .into_par_iter()
            .map(|c| {
                let mut p = vec![0f32; k * n];
                let lo = c * chunk;
                let hi = ((c + 1) * chunk).min(m);
                if lo < hi {
                    accumulate(lo..hi, &mut p);
                }
                p
            })
            .collect();
        let mut out = vec![0f32; k * n];
        for p in partials {
            for (o, v) in out.iter_mut().zip(&p) {
                *o += v;
            }
        }
        out
    } else {
        let mut out = vec![0f32; k * n];
        accumulate(0..m, &mut out);
        out
    }
}

/// Add `bias[n]` to every row of `x[rows,n]` in place.
pub fn add_bias(x: &mut [f32], bias: &[f32]) {
    let n = bias.len();
    for row in x.chunks_mut(n) {
        for (o, &b) in row.iter_mut().zip(bias) {
            *o += b;
        }
    }
}

/// Column sums of `g[rows,n]` added into `out[n]` — the bias gradient.
pub fn colsum_into(g: &[f32], n: usize, out: &mut [f32]) {
    assert_eq!(out.len(), n);
    for row in g.chunks(n) {
        for (o, &v) in out.iter_mut().zip(row) {
            *o += v;
        }
    }
}

/// Elementwise add into the left operand.
pub fn add_into(dst: &mut [f32], src: &[f32]) {
    assert_eq!(dst.len(), src.len());
    for (d, &s) in dst.iter_mut().zip(src) {
        *d += s;
    }
}

/// Per-row layernorm statistics: (mean, 1/sqrt(var + eps)) with the
/// population variance `jnp.var` uses.
pub struct LnStats {
    pub mean: Vec<f32>,
    pub rstd: Vec<f32>,
}

pub const LN_EPS: f32 = 1e-5;

/// `y = (x - mean) / sqrt(var + eps) * gamma + beta`, per row of `x[rows,n]`.
pub fn layernorm(x: &[f32], gamma: &[f32], beta: &[f32], n: usize) -> (Vec<f32>, LnStats) {
    let rows = x.len() / n;
    let mut y = vec![0f32; x.len()];
    let mut mean = vec![0f32; rows];
    let mut rstd = vec![0f32; rows];
    for r in 0..rows {
        let xr = &x[r * n..(r + 1) * n];
        let mu = xr.iter().sum::<f32>() / n as f32;
        let var = xr.iter().map(|&v| (v - mu) * (v - mu)).sum::<f32>() / n as f32;
        let rs = 1.0 / (var + LN_EPS).sqrt();
        mean[r] = mu;
        rstd[r] = rs;
        let yr = &mut y[r * n..(r + 1) * n];
        for ((o, &xv), (&g, &b)) in yr.iter_mut().zip(xr).zip(gamma.iter().zip(beta)) {
            *o = (xv - mu) * rs * g + b;
        }
    }
    (y, LnStats { mean, rstd })
}

/// VJP of [`layernorm`]: returns grad w.r.t. `x` and accumulates the
/// gamma/beta grads into `g_gamma`/`g_beta`.
pub fn layernorm_bwd(
    x: &[f32],
    stats: &LnStats,
    gamma: &[f32],
    g_y: &[f32],
    n: usize,
    g_gamma: &mut [f32],
    g_beta: &mut [f32],
) -> Vec<f32> {
    let rows = x.len() / n;
    let mut g_x = vec![0f32; x.len()];
    for r in 0..rows {
        let xr = &x[r * n..(r + 1) * n];
        let gyr = &g_y[r * n..(r + 1) * n];
        let mu = stats.mean[r];
        let rs = stats.rstd[r];
        // dxhat = g_y * gamma; dx = rs*(dxhat - mean(dxhat) - xhat*mean(dxhat*xhat))
        let mut sum_dxhat = 0f32;
        let mut sum_dxhat_xhat = 0f32;
        for i in 0..n {
            let xhat = (xr[i] - mu) * rs;
            let dxhat = gyr[i] * gamma[i];
            sum_dxhat += dxhat;
            sum_dxhat_xhat += dxhat * xhat;
            g_gamma[i] += gyr[i] * xhat;
            g_beta[i] += gyr[i];
        }
        let m1 = sum_dxhat / n as f32;
        let m2 = sum_dxhat_xhat / n as f32;
        let gxr = &mut g_x[r * n..(r + 1) * n];
        for i in 0..n {
            let xhat = (xr[i] - mu) * rs;
            let dxhat = gyr[i] * gamma[i];
            gxr[i] = rs * (dxhat - m1 - xhat * m2);
        }
    }
    g_x
}

const GELU_C: f32 = 0.797_884_56; // sqrt(2/pi), matching model.py's constant
const GELU_A: f32 = 0.044_715;

/// Tanh-approximation GELU, elementwise (model.py's `gelu`).
pub fn gelu(x: &[f32]) -> Vec<f32> {
    x.iter()
        .map(|&v| {
            let u = GELU_C * (v + GELU_A * v * v * v);
            0.5 * v * (1.0 + u.tanh())
        })
        .collect()
}

/// d gelu(x) / dx, elementwise.
pub fn gelu_grad(x: &[f32]) -> Vec<f32> {
    x.iter()
        .map(|&v| {
            let u = GELU_C * (v + GELU_A * v * v * v);
            let t = u.tanh();
            let du = GELU_C * (1.0 + 3.0 * GELU_A * v * v);
            0.5 * (1.0 + t) + 0.5 * v * (1.0 - t * t) * du
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn matmul_small_identity() {
        // [2,3] @ [3,2]
        let a = [1., 2., 3., 4., 5., 6.];
        let b = [7., 8., 9., 10., 11., 12.];
        let c = matmul(&a, &b, 2, 3, 2);
        assert_eq!(c, vec![58., 64., 139., 154.]);
    }

    #[test]
    fn matmul_variants_agree_with_explicit_transposes() {
        let m = 5;
        let k = 4;
        let n = 3;
        let a: Vec<f32> = (0..m * k).map(|i| (i as f32 * 0.37).sin()).collect();
        let b: Vec<f32> = (0..k * n).map(|i| (i as f32 * 0.23).cos()).collect();
        let c = matmul(&a, &b, m, k, n);
        // bᵀ laid out [n,k]; a @ (bᵀ)ᵀ via matmul_nt must equal c
        let mut bt = vec![0f32; n * k];
        for i in 0..k {
            for j in 0..n {
                bt[j * k + i] = b[i * n + j];
            }
        }
        let c2 = matmul_nt(&a, &bt, m, k, n);
        for (x, y) in c.iter().zip(&c2) {
            assert!((x - y).abs() < 1e-6);
        }
        // aᵀ laid out [k,m]; (aᵀ)ᵀ @ b via matmul_tn must equal c
        let mut at = vec![0f32; k * m];
        for i in 0..m {
            for j in 0..k {
                at[j * m + i] = a[i * k + j];
            }
        }
        let c3 = matmul_tn(&at, &b, k, m, n);
        for (x, y) in c.iter().zip(&c3) {
            assert!((x - y).abs() < 1e-6);
        }
    }

    #[test]
    fn matmul_tn_parallel_matches_serial() {
        // Force the parallel path and compare against the serial chunking.
        let m = 64;
        let k = 16;
        let n = 64; // 64*16*64 = 65536 ≥ PAR_THRESHOLD
        let a: Vec<f32> = (0..m * k).map(|i| ((i * 37) % 101) as f32 * 0.01).collect();
        let b: Vec<f32> = (0..m * n).map(|i| ((i * 53) % 97) as f32 * 0.02 - 0.5).collect();
        let par = matmul_tn(&a, &b, m, k, n);
        let mut serial = vec![0f32; k * n];
        // chunked in the same fixed order, single-threaded
        let chunk = m.div_ceil(8);
        for c in 0..8 {
            let mut p = vec![0f32; k * n];
            for r in c * chunk..((c + 1) * chunk).min(m) {
                for i in 0..k {
                    for j in 0..n {
                        p[i * n + j] += a[r * k + i] * b[r * n + j];
                    }
                }
            }
            for (o, v) in serial.iter_mut().zip(&p) {
                *o += v;
            }
        }
        for (x, y) in par.iter().zip(&serial) {
            assert_eq!(x.to_bits(), y.to_bits(), "nondeterministic reduction");
        }
    }

    #[test]
    fn layernorm_rows_are_normalized() {
        let n = 8;
        let x: Vec<f32> = (0..2 * n).map(|i| i as f32 * 0.5 - 3.0).collect();
        let gamma = vec![1.0; n];
        let beta = vec![0.0; n];
        let (y, _) = layernorm(&x, &gamma, &beta, n);
        for r in 0..2 {
            let row = &y[r * n..(r + 1) * n];
            let mu: f32 = row.iter().sum::<f32>() / n as f32;
            let var: f32 = row.iter().map(|&v| (v - mu) * (v - mu)).sum::<f32>() / n as f32;
            assert!(mu.abs() < 1e-5);
            assert!((var - 1.0).abs() < 1e-3);
        }
    }

    #[test]
    fn layernorm_bwd_matches_finite_difference() {
        let n = 6;
        let x: Vec<f32> = (0..n).map(|i| (i as f32 * 1.3).sin()).collect();
        let gamma: Vec<f32> = (0..n).map(|i| 1.0 + 0.1 * i as f32).collect();
        let beta: Vec<f32> = (0..n).map(|i| 0.05 * i as f32).collect();
        let g_y: Vec<f32> = (0..n).map(|i| (i as f32 * 0.7).cos()).collect();
        let loss = |xv: &[f32]| -> f32 {
            let (y, _) = layernorm(xv, &gamma, &beta, n);
            y.iter().zip(&g_y).map(|(a, b)| a * b).sum()
        };
        let (_, stats) = layernorm(&x, &gamma, &beta, n);
        let mut gg = vec![0f32; n];
        let mut gb = vec![0f32; n];
        let g_x = layernorm_bwd(&x, &stats, &gamma, &g_y, n, &mut gg, &mut gb);
        let eps = 1e-3f32;
        for i in 0..n {
            let mut xp = x.clone();
            xp[i] += eps;
            let mut xm = x.clone();
            xm[i] -= eps;
            let fd = (loss(&xp) - loss(&xm)) / (2.0 * eps);
            assert!(
                (fd - g_x[i]).abs() < 2e-2,
                "dx[{i}]: fd {fd} vs analytic {}",
                g_x[i]
            );
        }
        // beta grad is just g_y
        for i in 0..n {
            assert!((gb[i] - g_y[i]).abs() < 1e-6);
        }
    }

    #[test]
    fn gelu_grad_matches_finite_difference() {
        for &v in &[-2.0f32, -0.5, 0.0, 0.3, 1.7] {
            let eps = 1e-3;
            let fp = gelu(&[v + eps])[0];
            let fm = gelu(&[v - eps])[0];
            let fd = (fp - fm) / (2.0 * eps);
            let an = gelu_grad(&[v])[0];
            assert!((fd - an).abs() < 1e-3, "gelu'({v}): fd {fd} vs {an}");
        }
    }

    #[test]
    fn bias_helpers() {
        let mut x = vec![1.0, 2.0, 3.0, 4.0];
        add_bias(&mut x, &[10.0, 20.0]);
        assert_eq!(x, vec![11.0, 22.0, 13.0, 24.0]);
        let mut out = vec![0f32; 2];
        colsum_into(&x, 2, &mut out);
        assert_eq!(out, vec![24.0, 46.0]);
    }
}
