//! Dense f32 kernels for the native CPU stage backend — cache-blocked,
//! packed, and allocation-free on the hot path.
//!
//! The kernels compute the exact formulas `python/compile/model.py` lowers
//! through XLA (the naive transcriptions are kept as the `*_ref` oracles),
//! but the shipping implementations are tiled:
//!
//! * [`matmul_into`] packs B into [`NR`]-wide column panels and runs an
//!   `MR×NR` register microkernel (4 output rows × 8 lanes of
//!   accumulators) over row blocks, parallelized across output tiles.
//!   Single-row products above [`PAR_THRESHOLD`] parallelize over column
//!   panels instead of silently running serial.
//! * [`matmul_nt_into`] computes 4×4 tiles of independent dot products
//!   (16 concurrent reduction chains for ILP; B rows are already
//!   contiguous, so no packing is needed).
//! * [`matmul_tn_acc`] keeps the fixed-chunk reduction but blocks the
//!   rank-1 updates over column panels so each partial stays cache
//!   resident, and accumulates straight into the (gradient) output.
//! * The cheap epilogues — bias add, GELU, layernorm stats + normalize,
//!   column sums — are row-/element-parallel passes, and bias is fused
//!   into the matmul store ([`matmul_bias_into`]).
//!
//! **Kernel tiers.** The outer blocking and parallel decomposition live
//! here once, but every inner loop routes through the function-pointer
//! table in [`super::simd`] ([`simd::ops`]), which resolves exactly once
//! at startup to either the scalar tier (the PR 6 loops, moved verbatim
//! to `simd::scalar`) or the AVX2+FMA tier (`simd::avx2`, x86-64 hosts
//! with both features; `TERAPIPE_NO_SIMD=1` forces scalar). Kernel entry
//! points load the table once and capture it in their closures, so the
//! hot path pays zero per-call probing.
//!
//! **Determinism.** Results are bit-identical for any rayon pool size:
//!
//! 1. Every output element is owned by exactly one worker, and its
//!    reduction runs in a fixed order that depends only on its (row,
//!    column) position and the contraction length — never on tile
//!    position, slice boundary, or lane split. Under the scalar tier
//!    Rust does not contract `mul`+`add` into FMA, so the blocked
//!    `matmul`/`matmul_nt` are *bit-identical to the naive refs*, tiled
//!    or not; the AVX2 tier changes the association (FMA + 8-lane
//!    trees) and is tolerance-pinned against scalar instead, but keeps
//!    the same position-only ownership, so it is equally pool- and
//!    slicing-invariant *within* the tier.
//! 2. Cross-row reductions (`matmul_tn`, `layernorm_bwd` gamma/beta)
//!    split the contraction into [`REDUCE_CHUNKS`] *fixed* ranges whose
//!    partials are summed in chunk order, independent of thread count.
//! 3. Serial vs parallel paths are chosen by problem size only
//!    ([`PAR_THRESHOLD`] multiply-adds), never by pool size.
//!
//! **Allocation.** Kernel scratch (B panels, reduction partials) comes
//! from a small per-thread buffer pool ([`take_buf`]/[`put_buf`]) that is
//! only touched by the *calling* thread — rayon workers never allocate —
//! so steady-state calls perform zero heap allocations. Activations and
//! gradient temporaries use the analogous arena in
//! [`super::native::scratch`].

#![allow(clippy::needless_range_loop)] // index loops are the idiom in kernels

use super::simd::{self, KernelOps, MR, NR, NT_TILE};
use rayon::prelude::*;
use std::cell::RefCell;

/// Multiply-add count below which kernels run serially.
pub const PAR_THRESHOLD: usize = 1 << 16;

/// Fixed chunk count for deterministic cross-row reductions (independent
/// of the rayon pool size, so results don't vary with `RAYON_NUM_THREADS`).
const REDUCE_CHUNKS: usize = 8;
/// Column panel width for `matmul_tn`'s blocked rank-1 updates.
const TN_JP: usize = 128;
/// Column block for parallel column sums.
const COL_BLOCK: usize = 64;
/// Element chunk for parallel elementwise passes.
const ELEM_CHUNK: usize = 1 << 13;

// ---------------------------------------------------------------------------
// Per-thread kernel scratch (packing panels, reduction partials)
// ---------------------------------------------------------------------------

thread_local! {
    static MATH_POOL: RefCell<Vec<Vec<f32>>> = const { RefCell::new(Vec::new()) };
}

/// Grab a scratch buffer from this thread's pool (push/pop, so nested or
/// stolen kernel invocations on the same thread compose safely).
fn take_buf() -> Vec<f32> {
    MATH_POOL.with(|p| p.borrow_mut().pop()).unwrap_or_default()
}

fn put_buf(v: Vec<f32>) {
    MATH_POOL.with(|p| {
        let mut p = p.borrow_mut();
        if p.len() < 8 {
            p.push(v);
        }
    });
}

// ---------------------------------------------------------------------------
// Naive reference kernels — the oracles the blocked paths are tested against
// ---------------------------------------------------------------------------

/// Reference `out[m,n] = a[m,k] @ b[k,n]`: serial row-major ikj loops.
/// The blocked [`matmul_into`] is bit-identical to this (same per-element
/// reduction order).
pub fn matmul_ref(a: &[f32], b: &[f32], m: usize, k: usize, n: usize) -> Vec<f32> {
    assert_eq!(a.len(), m * k);
    assert_eq!(b.len(), k * n);
    let mut out = vec![0f32; m * n];
    for (i, out_row) in out.chunks_mut(n).enumerate() {
        let ar = &a[i * k..(i + 1) * k];
        for (l, &av) in ar.iter().enumerate() {
            let br = &b[l * n..(l + 1) * n];
            for (o, &bv) in out_row.iter_mut().zip(br) {
                *o += av * bv;
            }
        }
    }
    out
}

/// Reference `out[m,k] = a[m,n] @ b[k,n]ᵀ`: serial per-element dots.
/// The blocked [`matmul_nt_into`] is bit-identical to this.
pub fn matmul_nt_ref(a: &[f32], b: &[f32], m: usize, n: usize, k: usize) -> Vec<f32> {
    assert_eq!(a.len(), m * n);
    assert_eq!(b.len(), k * n);
    let mut out = vec![0f32; m * k];
    for (i, out_row) in out.chunks_mut(k).enumerate() {
        let ar = &a[i * n..(i + 1) * n];
        for (j, o) in out_row.iter_mut().enumerate() {
            let br = &b[j * n..(j + 1) * n];
            let mut acc = 0f32;
            for (&x, &y) in ar.iter().zip(br) {
                acc += x * y;
            }
            *o = acc;
        }
    }
    out
}

/// Reference `out[k,n] = a[m,k]ᵀ @ b[m,n]`: serial single-pass rank-1
/// accumulation. [`matmul_tn`]'s serial path is bit-identical to this;
/// the parallel path differs only by the fixed-chunk partial association.
pub fn matmul_tn_ref(a: &[f32], b: &[f32], m: usize, k: usize, n: usize) -> Vec<f32> {
    assert_eq!(a.len(), m * k);
    assert_eq!(b.len(), m * n);
    let mut out = vec![0f32; k * n];
    for r in 0..m {
        let ar = &a[r * k..(r + 1) * k];
        let br = &b[r * n..(r + 1) * n];
        for (i, &av) in ar.iter().enumerate() {
            let o = &mut out[i * n..(i + 1) * n];
            for (ov, &bv) in o.iter_mut().zip(br) {
                *ov += av * bv;
            }
        }
    }
    out
}

// ---------------------------------------------------------------------------
// Blocked matmul (A @ B) with packed B panels
// ---------------------------------------------------------------------------

/// Pack `b[k,n]` into `ceil(n/NR)` column panels of shape `[k, NR]`
/// (remainder lanes zero-padded): the microkernel streams one contiguous
/// panel per output tile instead of striding across all of B.
fn pack_b(b: &[f32], k: usize, n: usize, packed: &mut Vec<f32>) {
    let np = n.div_ceil(NR);
    packed.clear();
    packed.resize(np * k * NR, 0.0);
    for p in 0..np {
        let j0 = p * NR;
        let w = NR.min(n - j0);
        let strip = &mut packed[p * k * NR..(p + 1) * k * NR];
        for l in 0..k {
            strip[l * NR..l * NR + w].copy_from_slice(&b[l * n + j0..l * n + j0 + w]);
        }
    }
}

/// Blocked core shared by [`matmul_into`] / [`matmul_bias_into`]. The
/// `MR×NR` microkernel (`ops.mm_micro`) and the 1×NR skinny-row kernel
/// (`ops.mm_panel_row`) come from the active [`simd`] tier.
fn mm_blocked(a: &[f32], b: &[f32], bias: Option<&[f32]>, m: usize, k: usize, n: usize, out: &mut [f32]) {
    assert_eq!(a.len(), m * k);
    assert_eq!(b.len(), k * n);
    assert_eq!(out.len(), m * n);
    if let Some(bs) = bias {
        assert_eq!(bs.len(), n);
    }
    let ops = simd::ops();
    let np = n.div_ceil(NR);
    let mut packed = take_buf();
    pack_b(b, k, n, &mut packed);
    let pk: &[f32] = &packed;

    let store = |acc: &[f32; NR], j0: usize, w: usize, dst: &mut [f32]| match bias {
        Some(bs) => {
            for c in 0..w {
                dst[c] = acc[c] + bs[j0 + c];
            }
        }
        None => dst.copy_from_slice(&acc[..w]),
    };
    // one row block (`mr` rows of `out`) across every packed panel
    let block = |i0: usize, blk: &mut [f32]| {
        let mr = blk.len() / n;
        let mut acc = [[0f32; NR]; MR];
        for p in 0..np {
            let strip = &pk[p * k * NR..(p + 1) * k * NR];
            let j0 = p * NR;
            let w = NR.min(n - j0);
            (ops.mm_micro)(a, i0, mr, k, strip, &mut acc);
            for r in 0..mr {
                store(&acc[r], j0, w, &mut blk[r * n + j0..r * n + j0 + w]);
            }
        }
    };
    // 1×NR microkernel for the column-parallel (skinny-M) path
    let panel_row = |i: usize, p: usize, dst: &mut [f32]| {
        let strip = &pk[p * k * NR..(p + 1) * k * NR];
        let j0 = p * NR;
        let w = dst.len();
        let ar = &a[i * k..(i + 1) * k];
        let mut acc = [0f32; NR];
        (ops.mm_panel_row)(ar, strip, k, &mut acc);
        store(&acc, j0, w, dst);
    };

    if m * k * n < PAR_THRESHOLD {
        for (bi, blk) in out.chunks_mut(MR * n).enumerate() {
            block(bi * MR, blk);
        }
    } else if m >= 2 * MR {
        out.par_chunks_mut(MR * n).enumerate().for_each(|(bi, blk)| block(bi * MR, blk));
    } else {
        // few rows, many columns (decode-/head-shaped): parallelize over
        // column panels so single-row products still fan out
        for (i, row) in out.chunks_mut(n).enumerate() {
            row.par_chunks_mut(NR).enumerate().for_each(|(p, dst)| panel_row(i, p, dst));
        }
    }
    put_buf(packed);
}

/// `out[m,n] = a[m,k] @ b[k,n]` into a caller-provided buffer.
pub fn matmul_into(a: &[f32], b: &[f32], m: usize, k: usize, n: usize, out: &mut [f32]) {
    mm_blocked(a, b, None, m, k, n, out);
}

/// `out[m,n] = a[m,k] @ b[k,n] + bias[n]` — bias fused into the tile store.
pub fn matmul_bias_into(a: &[f32], b: &[f32], bias: &[f32], m: usize, k: usize, n: usize, out: &mut [f32]) {
    mm_blocked(a, b, Some(bias), m, k, n, out);
}

/// Allocating wrapper around [`matmul_into`].
pub fn matmul(a: &[f32], b: &[f32], m: usize, k: usize, n: usize) -> Vec<f32> {
    let mut out = vec![0f32; m * n];
    matmul_into(a, b, m, k, n, &mut out);
    out
}

// ---------------------------------------------------------------------------
// Blocked A @ Bᵀ (independent dot products)
// ---------------------------------------------------------------------------

/// `out[m,k] = a[m,n] @ b[k,n]ᵀ` into a caller-provided buffer — the
/// backward-through-weights product (`grad @ Wᵀ`). 4×4 tiles of dots
/// (`ops.nt_tile`): under the scalar tier 16 independent sequential
/// chains with the per-dot order of [`matmul_nt_ref`], hence
/// bit-identical; under AVX2 each dot uses the same fixed-lane FMA
/// association as the skinny-path `ops.nt_dot`.
pub fn matmul_nt_into(a: &[f32], b: &[f32], m: usize, n: usize, k: usize, out: &mut [f32]) {
    assert_eq!(a.len(), m * n);
    assert_eq!(b.len(), k * n);
    assert_eq!(out.len(), m * k);
    let ops = simd::ops();
    let tile = |i0: usize, j0: usize, mr: usize, jw: usize, blk: &mut [f32]| {
        let mut acc = [[0f32; NT_TILE]; NT_TILE];
        (ops.nt_tile)(a, b, n, i0, j0, mr, jw, &mut acc);
        for r in 0..mr {
            blk[r * k + j0..r * k + j0 + jw].copy_from_slice(&acc[r][..jw]);
        }
    };
    let block = |i0: usize, blk: &mut [f32]| {
        let mr = blk.len() / k;
        let mut j0 = 0;
        while j0 < k {
            let jw = NT_TILE.min(k - j0);
            tile(i0, j0, mr, jw, blk);
            j0 += jw;
        }
    };
    if m * n * k < PAR_THRESHOLD {
        for (bi, blk) in out.chunks_mut(NT_TILE * k).enumerate() {
            block(bi * NT_TILE, blk);
        }
    } else if m >= 2 * NT_TILE {
        out.par_chunks_mut(NT_TILE * k).enumerate().for_each(|(bi, blk)| block(bi * NT_TILE, blk));
    } else {
        // skinny M: parallelize over column tiles of each row
        for (i, row) in out.chunks_mut(k).enumerate() {
            row.par_chunks_mut(NT_TILE).enumerate().for_each(|(tj, dst)| {
                let j0 = tj * NT_TILE;
                let jw = dst.len();
                let ar = &a[i * n..(i + 1) * n];
                for c in 0..jw {
                    let br = &b[(j0 + c) * n..(j0 + c + 1) * n];
                    dst[c] = (ops.nt_dot)(ar, br);
                }
            });
        }
    }
}

/// Allocating wrapper around [`matmul_nt_into`].
pub fn matmul_nt(a: &[f32], b: &[f32], m: usize, n: usize, k: usize) -> Vec<f32> {
    let mut out = vec![0f32; m * k];
    matmul_nt_into(a, b, m, n, k, &mut out);
    out
}

// ---------------------------------------------------------------------------
// Aᵀ @ B with the fixed-chunk deterministic reduction
// ---------------------------------------------------------------------------

/// Rank-1 accumulation of rows `range` of `aᵀ @ b` into `out[k,n]`,
/// blocked over [`TN_JP`]-wide column panels so the partial stays cache
/// resident. Per output element the updates run in ascending-`r` order —
/// the same association as [`matmul_tn_ref`] restricted to `range`.
fn tn_accumulate(
    ops: &KernelOps,
    a: &[f32],
    b: &[f32],
    k: usize,
    n: usize,
    range: std::ops::Range<usize>,
    out: &mut [f32],
) {
    let mut jp = 0;
    while jp < n {
        let w = TN_JP.min(n - jp);
        for r in range.clone() {
            let ar = &a[r * k..(r + 1) * k];
            let br = &b[r * n + jp..r * n + jp + w];
            for (i, &av) in ar.iter().enumerate() {
                let o = &mut out[i * n + jp..i * n + jp + w];
                (ops.tn_axpy)(o, br, av);
            }
        }
        jp += w;
    }
}

/// `out[k,n] += a[m,k]ᵀ @ b[m,n]` — the weight-gradient product
/// (`xᵀ @ grad`), accumulating into the gradient buffer. The contraction
/// runs over `m`, so the parallel path splits it into [`REDUCE_CHUNKS`]
/// fixed ranges (private partials from the thread-local pool, summed into
/// `out` in chunk order) — deterministic for any pool size. Products
/// with too few contraction rows for the chunked reduction (skinny `m`:
/// a short token slice against a wide gradient) instead parallelize
/// over the `k` output rows, each accumulated in ascending-`r` order —
/// bit-identical to the serial pass, no silent serial fallback.
pub fn matmul_tn_acc(a: &[f32], b: &[f32], m: usize, k: usize, n: usize, out: &mut [f32]) {
    assert_eq!(a.len(), m * k);
    assert_eq!(b.len(), m * n);
    assert_eq!(out.len(), k * n);
    let ops = simd::ops();
    if m * k * n >= PAR_THRESHOLD && m >= 2 * REDUCE_CHUNKS {
        let chunk = m.div_ceil(REDUCE_CHUNKS);
        let kn = k * n;
        let mut partials = take_buf();
        partials.clear();
        partials.resize(REDUCE_CHUNKS * kn, 0.0);
        partials.par_chunks_mut(kn).enumerate().for_each(|(c, p)| {
            let lo = c * chunk;
            let hi = ((c + 1) * chunk).min(m);
            if lo < hi {
                tn_accumulate(ops, a, b, k, n, lo..hi, p);
            }
        });
        let pr: &[f32] = &partials;
        // per-row parallel reduce; chunk order is fixed, each output row
        // owned by one worker
        out.par_chunks_mut(n).enumerate().for_each(|(i, orow)| {
            for c in 0..REDUCE_CHUNKS {
                let p = &pr[c * kn + i * n..c * kn + i * n + n];
                for (o, &v) in orow.iter_mut().zip(p) {
                    *o += v;
                }
            }
        });
        put_buf(partials);
    } else if m * k * n >= PAR_THRESHOLD {
        // skinny m: each output row i = column i of a — one owner per
        // row, updates in the same ascending-r order as the serial pass
        out.par_chunks_mut(n).enumerate().for_each(|(i, orow)| {
            for r in 0..m {
                let av = a[r * k + i];
                let br = &b[r * n..(r + 1) * n];
                (ops.tn_axpy)(orow, br, av);
            }
        });
    } else {
        tn_accumulate(ops, a, b, k, n, 0..m, out);
    }
}

/// Allocating wrapper: `out[k,n] = a[m,k]ᵀ @ b[m,n]`.
pub fn matmul_tn(a: &[f32], b: &[f32], m: usize, k: usize, n: usize) -> Vec<f32> {
    let mut out = vec![0f32; k * n];
    matmul_tn_acc(a, b, m, k, n, &mut out);
    out
}

// ---------------------------------------------------------------------------
// Row-/element-parallel epilogues
// ---------------------------------------------------------------------------

/// Add `bias[n]` to every row of `x[rows,n]` in place (row-parallel; each
/// row owned by one worker, so bit-identical to the serial pass).
pub fn add_bias(x: &mut [f32], bias: &[f32]) {
    let n = bias.len();
    let row = |r: &mut [f32]| {
        for (o, &b) in r.iter_mut().zip(bias) {
            *o += b;
        }
    };
    if x.len() >= PAR_THRESHOLD {
        x.par_chunks_mut(n).for_each(row);
    } else {
        x.chunks_mut(n).for_each(row);
    }
}

/// Column sums of `g[rows,n]` added into `out[n]` — the bias gradient.
/// Parallel over column blocks: each column is owned by one worker and
/// summed in ascending row order, bit-identical to the serial loop.
pub fn colsum_into(g: &[f32], n: usize, out: &mut [f32]) {
    assert_eq!(out.len(), n);
    let rows = g.len() / n;
    if rows * n >= PAR_THRESHOLD && n >= 2 * COL_BLOCK {
        out.par_chunks_mut(COL_BLOCK).enumerate().for_each(|(bi, blk)| {
            let j0 = bi * COL_BLOCK;
            for r in 0..rows {
                let src = &g[r * n + j0..r * n + j0 + blk.len()];
                for (o, &v) in blk.iter_mut().zip(src) {
                    *o += v;
                }
            }
        });
    } else {
        for row in g.chunks(n) {
            for (o, &v) in out.iter_mut().zip(row) {
                *o += v;
            }
        }
    }
}

/// Elementwise add into the left operand (element-parallel).
pub fn add_into(dst: &mut [f32], src: &[f32]) {
    assert_eq!(dst.len(), src.len());
    if dst.len() >= PAR_THRESHOLD {
        dst.par_chunks_mut(ELEM_CHUNK).zip(src.par_chunks(ELEM_CHUNK)).for_each(|(d, s)| {
            for (o, &v) in d.iter_mut().zip(s) {
                *o += v;
            }
        });
    } else {
        for (d, &s) in dst.iter_mut().zip(src) {
            *d += s;
        }
    }
}

/// Per-row layernorm statistics: (mean, 1/sqrt(var + eps)) with the
/// population variance `jnp.var` uses.
pub struct LnStats {
    pub mean: Vec<f32>,
    pub rstd: Vec<f32>,
}

pub const LN_EPS: f32 = 1e-5;

#[inline]
fn ln_row(ops: &KernelOps, xr: &[f32], gamma: &[f32], beta: &[f32], yr: &mut [f32]) -> (f32, f32) {
    let n = xr.len();
    let mu = (ops.sum)(xr) / n as f32;
    let var = (ops.sq_dev_sum)(xr, mu) / n as f32;
    let rs = 1.0 / (var + LN_EPS).sqrt();
    for ((o, &xv), (&g, &b)) in yr.iter_mut().zip(xr).zip(gamma.iter().zip(beta)) {
        *o = (xv - mu) * rs * g + b;
    }
    (mu, rs)
}

/// `y = (x - mean) / sqrt(var + eps) * gamma + beta`, per row of
/// `x[rows,n]`, into caller-provided `y`/`mean`/`rstd` (row-parallel;
/// rows are independent, so bit-identical to the serial pass).
pub fn layernorm_into(
    x: &[f32],
    gamma: &[f32],
    beta: &[f32],
    n: usize,
    y: &mut [f32],
    mean: &mut [f32],
    rstd: &mut [f32],
) {
    let rows = x.len() / n;
    assert_eq!(y.len(), x.len());
    assert_eq!(mean.len(), rows);
    assert_eq!(rstd.len(), rows);
    let ops = simd::ops();
    if x.len() >= PAR_THRESHOLD {
        y.par_chunks_mut(n)
            .zip(mean.par_iter_mut().zip(rstd.par_iter_mut()))
            .enumerate()
            .for_each(|(r, (yr, (mu, rs)))| {
                let (m, s) = ln_row(ops, &x[r * n..(r + 1) * n], gamma, beta, yr);
                *mu = m;
                *rs = s;
            });
    } else {
        let stats = mean.iter_mut().zip(rstd.iter_mut());
        for ((r, yr), (mu, rs)) in y.chunks_mut(n).enumerate().zip(stats) {
            let (m, s) = ln_row(ops, &x[r * n..(r + 1) * n], gamma, beta, yr);
            *mu = m;
            *rs = s;
        }
    }
}

/// Allocating wrapper around [`layernorm_into`].
pub fn layernorm(x: &[f32], gamma: &[f32], beta: &[f32], n: usize) -> (Vec<f32>, LnStats) {
    let rows = x.len() / n;
    let mut y = vec![0f32; x.len()];
    let mut mean = vec![0f32; rows];
    let mut rstd = vec![0f32; rows];
    layernorm_into(x, gamma, beta, n, &mut y, &mut mean, &mut rstd);
    (y, LnStats { mean, rstd })
}

#[inline]
#[allow(clippy::too_many_arguments)]
fn ln_bwd_row(
    ops: &KernelOps,
    xr: &[f32],
    gyr: &[f32],
    mu: f32,
    rs: f32,
    gamma: &[f32],
    gxr: &mut [f32],
    gg: &mut [f32],
    gb: &mut [f32],
) {
    let n = xr.len();
    // dxhat = g_y * gamma; dx = rs*(dxhat - mean(dxhat) - xhat*mean(dxhat*xhat))
    let (sum_dxhat, sum_dxhat_xhat) = (ops.ln_bwd_sums)(xr, gyr, gamma, mu, rs, gg, gb);
    let m1 = sum_dxhat / n as f32;
    let m2 = sum_dxhat_xhat / n as f32;
    (ops.ln_bwd_gx)(xr, gyr, gamma, mu, rs, m1, m2, gxr);
}

/// VJP of [`layernorm`] into a caller-provided `g_x`; accumulates the
/// gamma/beta grads into `g_gamma`/`g_beta`. Rows (and their `g_x`) are
/// row-parallel; the cross-row gamma/beta reduction uses
/// [`REDUCE_CHUNKS`] fixed row ranges with pooled partials summed in
/// chunk order (thread-count independent).
#[allow(clippy::too_many_arguments)]
pub fn layernorm_bwd_into(
    x: &[f32],
    stats: &LnStats,
    gamma: &[f32],
    g_y: &[f32],
    n: usize,
    g_gamma: &mut [f32],
    g_beta: &mut [f32],
    g_x: &mut [f32],
) {
    let rows = x.len() / n;
    assert_eq!(g_x.len(), x.len());
    let ops = simd::ops();
    if x.len() >= PAR_THRESHOLD && rows >= 2 * REDUCE_CHUNKS {
        let chunk_rows = rows.div_ceil(REDUCE_CHUNKS);
        let mut partials = take_buf();
        partials.clear();
        partials.resize(REDUCE_CHUNKS * 2 * n, 0.0);
        g_x.par_chunks_mut(chunk_rows * n)
            .zip(partials.par_chunks_mut(2 * n))
            .enumerate()
            .for_each(|(c, (gx_chunk, part))| {
                let (gg, gb) = part.split_at_mut(n);
                let lo = c * chunk_rows;
                for (ri, gxr) in gx_chunk.chunks_mut(n).enumerate() {
                    let r = lo + ri;
                    ln_bwd_row(
                        ops,
                        &x[r * n..(r + 1) * n],
                        &g_y[r * n..(r + 1) * n],
                        stats.mean[r],
                        stats.rstd[r],
                        gamma,
                        gxr,
                        gg,
                        gb,
                    );
                }
            });
        for c in 0..REDUCE_CHUNKS {
            let part = &partials[c * 2 * n..(c + 1) * 2 * n];
            for (o, &v) in g_gamma.iter_mut().zip(&part[..n]) {
                *o += v;
            }
            for (o, &v) in g_beta.iter_mut().zip(&part[n..]) {
                *o += v;
            }
        }
        put_buf(partials);
    } else {
        for (r, gxr) in g_x.chunks_mut(n).enumerate() {
            ln_bwd_row(
                ops,
                &x[r * n..(r + 1) * n],
                &g_y[r * n..(r + 1) * n],
                stats.mean[r],
                stats.rstd[r],
                gamma,
                gxr,
                g_gamma,
                g_beta,
            );
        }
    }
}

/// Allocating wrapper around [`layernorm_bwd_into`].
pub fn layernorm_bwd(
    x: &[f32],
    stats: &LnStats,
    gamma: &[f32],
    g_y: &[f32],
    n: usize,
    g_gamma: &mut [f32],
    g_beta: &mut [f32],
) -> Vec<f32> {
    let mut g_x = vec![0f32; x.len()];
    layernorm_bwd_into(x, stats, gamma, g_y, n, g_gamma, g_beta, &mut g_x);
    g_x
}

/// Tanh-approximation GELU into a caller-provided buffer
/// (element-parallel: each element owned by one worker; [`ELEM_CHUNK`]
/// is a multiple of the 8-lane vector width, so chunking never shifts
/// which elements land in a vector tail).
pub fn gelu_into(x: &[f32], out: &mut [f32]) {
    assert_eq!(out.len(), x.len());
    let ops = simd::ops();
    if x.len() >= PAR_THRESHOLD {
        out.par_chunks_mut(ELEM_CHUNK)
            .zip(x.par_chunks(ELEM_CHUNK))
            .for_each(|(o, xs)| (ops.gelu)(xs, o));
    } else {
        (ops.gelu)(x, out);
    }
}

/// Allocating wrapper around [`gelu_into`] (model.py's `gelu`).
pub fn gelu(x: &[f32]) -> Vec<f32> {
    let mut out = vec![0f32; x.len()];
    gelu_into(x, &mut out);
    out
}

/// Fused GELU VJP: `g[i] *= gelu'(x[i])` in place — the
/// `gelu_grad(mpre) ⊙ g` product without the temporary.
pub fn gelu_grad_mul(x: &[f32], g: &mut [f32]) {
    assert_eq!(g.len(), x.len());
    let ops = simd::ops();
    if x.len() >= PAR_THRESHOLD {
        g.par_chunks_mut(ELEM_CHUNK)
            .zip(x.par_chunks(ELEM_CHUNK))
            .for_each(|(gs, xs)| (ops.gelu_grad_mul)(xs, gs));
    } else {
        (ops.gelu_grad_mul)(x, g);
    }
}

/// d gelu(x) / dx, elementwise (test/reference helper — always the
/// scalar-tier formula, so it can serve as the oracle for both tiers).
pub fn gelu_grad(x: &[f32]) -> Vec<f32> {
    x.iter().map(|&v| simd::scalar::gelu_grad_one(v)).collect()
}

#[cfg(test)]
mod tests {
    use super::simd::{tier_guard, Tier};
    use super::*;

    #[test]
    fn matmul_small_identity() {
        // [2,3] @ [3,2]
        let a = [1., 2., 3., 4., 5., 6.];
        let b = [7., 8., 9., 10., 11., 12.];
        let c = matmul(&a, &b, 2, 3, 2);
        assert_eq!(c, vec![58., 64., 139., 154.]);
    }

    #[test]
    fn matmul_variants_agree_with_explicit_transposes() {
        let m = 5;
        let k = 4;
        let n = 3;
        let a: Vec<f32> = (0..m * k).map(|i| (i as f32 * 0.37).sin()).collect();
        let b: Vec<f32> = (0..k * n).map(|i| (i as f32 * 0.23).cos()).collect();
        let c = matmul(&a, &b, m, k, n);
        // bᵀ laid out [n,k]; a @ (bᵀ)ᵀ via matmul_nt must equal c
        let mut bt = vec![0f32; n * k];
        for i in 0..k {
            for j in 0..n {
                bt[j * k + i] = b[i * n + j];
            }
        }
        let c2 = matmul_nt(&a, &bt, m, k, n);
        for (x, y) in c.iter().zip(&c2) {
            assert!((x - y).abs() < 1e-6);
        }
        // aᵀ laid out [k,m]; (aᵀ)ᵀ @ b via matmul_tn must equal c
        let mut at = vec![0f32; k * m];
        for i in 0..m {
            for j in 0..k {
                at[j * m + i] = a[i * k + j];
            }
        }
        let c3 = matmul_tn(&at, &b, k, m, n);
        for (x, y) in c.iter().zip(&c3) {
            assert!((x - y).abs() < 1e-6);
        }
    }

    #[test]
    fn blocked_matmul_bit_identical_to_ref() {
        // bit-identity to the refs is a scalar-tier contract
        let _g = tier_guard(Tier::Scalar);
        // spans the parallel row-block path and remainder tiles
        for (m, k, n) in [(65, 33, 50), (4, 8, 8), (1, 64, 1100), (7, 19, 23), (128, 32, 48)] {
            let a: Vec<f32> = (0..m * k).map(|i| ((i * 37) % 101) as f32 * 0.013 - 0.5).collect();
            let b: Vec<f32> = (0..k * n).map(|i| ((i * 53) % 97) as f32 * 0.021 - 1.0).collect();
            let blocked = matmul(&a, &b, m, k, n);
            let reference = matmul_ref(&a, &b, m, k, n);
            for (x, y) in blocked.iter().zip(&reference) {
                assert_eq!(x.to_bits(), y.to_bits(), "({m},{k},{n})");
            }
        }
    }

    #[test]
    fn matmul_bias_fusion_matches_separate_passes() {
        let _g = tier_guard(Tier::Scalar);
        let (m, k, n) = (9, 11, 13);
        let a: Vec<f32> = (0..m * k).map(|i| (i as f32 * 0.11).sin()).collect();
        let b: Vec<f32> = (0..k * n).map(|i| (i as f32 * 0.07).cos()).collect();
        let bias: Vec<f32> = (0..n).map(|i| i as f32 * 0.3 - 1.0).collect();
        let mut fused = vec![0f32; m * n];
        matmul_bias_into(&a, &b, &bias, m, k, n, &mut fused);
        let mut sep = matmul_ref(&a, &b, m, k, n);
        for row in sep.chunks_mut(n) {
            for (o, &bv) in row.iter_mut().zip(&bias) {
                *o += bv;
            }
        }
        for (x, y) in fused.iter().zip(&sep) {
            assert_eq!(x.to_bits(), y.to_bits());
        }
    }

    #[test]
    fn matmul_tn_parallel_matches_serial() {
        let _g = tier_guard(Tier::Scalar);
        // Force the parallel path and compare against the serial chunking.
        let m = 64;
        let k = 16;
        let n = 64; // 64*16*64 = 65536 ≥ PAR_THRESHOLD
        let a: Vec<f32> = (0..m * k).map(|i| ((i * 37) % 101) as f32 * 0.01).collect();
        let b: Vec<f32> = (0..m * n).map(|i| ((i * 53) % 97) as f32 * 0.02 - 0.5).collect();
        let par = matmul_tn(&a, &b, m, k, n);
        let mut serial = vec![0f32; k * n];
        // chunked in the same fixed order, single-threaded
        let chunk = m.div_ceil(8);
        for c in 0..8 {
            let mut p = vec![0f32; k * n];
            for r in c * chunk..((c + 1) * chunk).min(m) {
                for i in 0..k {
                    for j in 0..n {
                        p[i * n + j] += a[r * k + i] * b[r * n + j];
                    }
                }
            }
            for (o, v) in serial.iter_mut().zip(&p) {
                *o += v;
            }
        }
        for (x, y) in par.iter().zip(&serial) {
            assert_eq!(x.to_bits(), y.to_bits(), "nondeterministic reduction");
        }
    }

    #[test]
    fn matmul_tn_skinny_m_parallel_is_bit_identical_to_serial() {
        // 4·64·512 = 131072 ≥ PAR_THRESHOLD with m < 2·REDUCE_CHUNKS:
        // exercises the column-parallel skinny-m path. Per output element
        // both paths apply ascending-r single-rounded updates, so they
        // agree bit-for-bit under either tier — pin scalar so the oracle
        // (matmul_tn_ref) matches too.
        let _g = tier_guard(Tier::Scalar);
        let (m, k, n) = (4, 64, 512);
        let a: Vec<f32> = (0..m * k).map(|i| ((i * 37) % 101) as f32 * 0.013 - 0.5).collect();
        let b: Vec<f32> = (0..m * n).map(|i| ((i * 53) % 97) as f32 * 0.021 - 1.0).collect();
        let par = matmul_tn(&a, &b, m, k, n);
        let reference = matmul_tn_ref(&a, &b, m, k, n);
        for (x, y) in par.iter().zip(&reference) {
            assert_eq!(x.to_bits(), y.to_bits(), "skinny-m tn diverged from serial order");
        }
    }

    #[test]
    fn matmul_tn_acc_accumulates_into_existing_grads() {
        let (m, k, n) = (10, 5, 7);
        let a: Vec<f32> = (0..m * k).map(|i| (i as f32 * 0.3).sin()).collect();
        let b: Vec<f32> = (0..m * n).map(|i| (i as f32 * 0.2).cos()).collect();
        let mut acc: Vec<f32> = (0..k * n).map(|i| i as f32 * 0.1).collect();
        let before = acc.clone();
        matmul_tn_acc(&a, &b, m, k, n, &mut acc);
        let fresh = matmul_tn_ref(&a, &b, m, k, n);
        for i in 0..k * n {
            assert!((acc[i] - (before[i] + fresh[i])).abs() < 1e-5);
        }
    }

    #[test]
    fn layernorm_rows_are_normalized() {
        let n = 8;
        let x: Vec<f32> = (0..2 * n).map(|i| i as f32 * 0.5 - 3.0).collect();
        let gamma = vec![1.0; n];
        let beta = vec![0.0; n];
        let (y, _) = layernorm(&x, &gamma, &beta, n);
        for r in 0..2 {
            let row = &y[r * n..(r + 1) * n];
            let mu: f32 = row.iter().sum::<f32>() / n as f32;
            let var: f32 = row.iter().map(|&v| (v - mu) * (v - mu)).sum::<f32>() / n as f32;
            assert!(mu.abs() < 1e-5);
            assert!((var - 1.0).abs() < 1e-3);
        }
    }

    #[test]
    fn layernorm_bwd_matches_finite_difference() {
        let n = 6;
        let x: Vec<f32> = (0..n).map(|i| (i as f32 * 1.3).sin()).collect();
        let gamma: Vec<f32> = (0..n).map(|i| 1.0 + 0.1 * i as f32).collect();
        let beta: Vec<f32> = (0..n).map(|i| 0.05 * i as f32).collect();
        let g_y: Vec<f32> = (0..n).map(|i| (i as f32 * 0.7).cos()).collect();
        let loss = |xv: &[f32]| -> f32 {
            let (y, _) = layernorm(xv, &gamma, &beta, n);
            y.iter().zip(&g_y).map(|(a, b)| a * b).sum()
        };
        let (_, stats) = layernorm(&x, &gamma, &beta, n);
        let mut gg = vec![0f32; n];
        let mut gb = vec![0f32; n];
        let g_x = layernorm_bwd(&x, &stats, &gamma, &g_y, n, &mut gg, &mut gb);
        let eps = 1e-3f32;
        for i in 0..n {
            let mut xp = x.clone();
            xp[i] += eps;
            let mut xm = x.clone();
            xm[i] -= eps;
            let fd = (loss(&xp) - loss(&xm)) / (2.0 * eps);
            assert!(
                (fd - g_x[i]).abs() < 2e-2,
                "dx[{i}]: fd {fd} vs analytic {}",
                g_x[i]
            );
        }
        // beta grad is just g_y
        for i in 0..n {
            assert!((gb[i] - g_y[i]).abs() < 1e-6);
        }
    }

    #[test]
    fn gelu_grad_matches_finite_difference() {
        for &v in &[-2.0f32, -0.5, 0.0, 0.3, 1.7] {
            let eps = 1e-3;
            let fp = gelu(&[v + eps])[0];
            let fm = gelu(&[v - eps])[0];
            let fd = (fp - fm) / (2.0 * eps);
            let an = gelu_grad(&[v])[0];
            assert!((fd - an).abs() < 1e-3, "gelu'({v}): fd {fd} vs {an}");
        }
    }

    #[test]
    fn gelu_grad_mul_fuses_product() {
        let _g = tier_guard(Tier::Scalar);
        let x: Vec<f32> = (0..40).map(|i| (i as f32 * 0.17).sin() * 2.0).collect();
        let mut g: Vec<f32> = (0..40).map(|i| (i as f32 * 0.29).cos()).collect();
        let expect: Vec<f32> =
            g.iter().zip(gelu_grad(&x)).map(|(&gv, d)| gv * d).collect();
        gelu_grad_mul(&x, &mut g);
        for (a, b) in g.iter().zip(&expect) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
    }

    #[test]
    fn bias_helpers() {
        let mut x = vec![1.0, 2.0, 3.0, 4.0];
        add_bias(&mut x, &[10.0, 20.0]);
        assert_eq!(x, vec![11.0, 22.0, 13.0, 24.0]);
        let mut out = vec![0f32; 2];
        colsum_into(&x, 2, &mut out);
        assert_eq!(out, vec![24.0, 46.0]);
    }
}
