//! The paper's exact experimental grid (Table 1) plus the model zoo.
//!
//! | id | model     | N  | H     | L    | #GPUs | B   | #Data | #Pipe | #Op |
//! |----|-----------|----|-------|------|-------|-----|-------|-------|-----|
//! | 1  | GPT3-1B   | 24 | 2048  | 2048 | 192   | 128 | 8     | 24    | 1   |
//! | 2  | GPT3-1B   | 24 | 2048  | 2048 | 192   | 72  | 2     | 12    | 8   |
//! | 3  | GPT3-1B   | 24 | 2048  | 2048 | 192   | 72  | 1     | 24    | 8   |
//! | 4  | GPT3-13B  | 40 | 5120  | 2048 | 320   | 32  | 2     | 20    | 8   |
//! | 5  | GPT3-13B  | 40 | 5120  | 2048 | 320   | 32  | 1     | 40    | 8   |
//! | 6  | GPT3-44B  | 96 | 6144  | 2048 | 384   | 8   | 4     | 96    | 1   |
//! | 7  | GPT3-44B  | 96 | 6144  | 2048 | 384   | 8   | 2     | 24    | 8   |
//! | 8  | GPT3-44B  | 96 | 6144  | 2048 | 384   | 8   | 1     | 48    | 8   |
//! | 9  | GPT3-175B | 96 | 12288 | 2048 | 384   | 2   | 1     | 96    | 4   |
//! | 10 | GPT3-175B | 96 | 12288 | 2048 | 384   | 2   | 1     | 48    | 8   |

use super::{ClusterConfig, ModelConfig, ParallelConfig, Setting};

const GPT3_VOCAB: u32 = 50257;

/// GPT3-1B (paper Table 1; matches GPT-3 XL geometry).
pub fn gpt3_1b() -> ModelConfig {
    ModelConfig {
        name: "GPT3-1B".into(),
        num_layers: 24,
        hidden: 2048,
        num_heads: 16,
        seq_len: 2048,
        vocab: GPT3_VOCAB,
    }
}

/// GPT3-13B.
pub fn gpt3_13b() -> ModelConfig {
    ModelConfig {
        name: "GPT3-13B".into(),
        num_layers: 40,
        hidden: 5120,
        num_heads: 40,
        seq_len: 2048,
        vocab: GPT3_VOCAB,
    }
}

/// GPT3-44B — the paper's custom model: 175B layout with half the hidden size.
pub fn gpt3_44b() -> ModelConfig {
    ModelConfig {
        name: "GPT3-44B".into(),
        num_layers: 96,
        hidden: 6144,
        num_heads: 48,
        seq_len: 2048,
        vocab: GPT3_VOCAB,
    }
}

/// GPT3-175B — the largest GPT-3 (Brown et al., 2020).
pub fn gpt3_175b() -> ModelConfig {
    ModelConfig {
        name: "GPT3-175B".into(),
        num_layers: 96,
        hidden: 12288,
        num_heads: 96,
        seq_len: 2048,
        vocab: GPT3_VOCAB,
    }
}

pub fn model_by_name(name: &str) -> Option<ModelConfig> {
    match name.to_ascii_lowercase().as_str() {
        "gpt3-1b" | "1b" => Some(gpt3_1b()),
        "gpt3-13b" | "13b" => Some(gpt3_13b()),
        "gpt3-44b" | "44b" => Some(gpt3_44b()),
        "gpt3-175b" | "175b" => Some(gpt3_175b()),
        _ => None,
    }
}

fn cluster_for(total_gpus: u32) -> ClusterConfig {
    ClusterConfig {
        num_nodes: total_gpus / 8,
        ..ClusterConfig::default()
    }
}

fn setting_row(
    id: u32,
    model: ModelConfig,
    gpus: u32,
    batch: u32,
    data: u32,
    pipe: u32,
    op: u32,
) -> Setting {
    let s = Setting {
        id,
        model,
        cluster: cluster_for(gpus),
        parallel: ParallelConfig {
            batch_size: batch,
            data_parallel: data,
            pipeline_stages: pipe,
            op_parallel: op,
        },
    };
    debug_assert_eq!(s.parallel.total_gpus(), gpus, "row {id}");
    s
}

/// All ten Table 1 rows, in order.
pub fn table1() -> Vec<Setting> {
    vec![
        setting_row(1, gpt3_1b(), 192, 128, 8, 24, 1),
        setting_row(2, gpt3_1b(), 192, 72, 2, 12, 8),
        setting_row(3, gpt3_1b(), 192, 72, 1, 24, 8),
        setting_row(4, gpt3_13b(), 320, 32, 2, 20, 8),
        setting_row(5, gpt3_13b(), 320, 32, 1, 40, 8),
        setting_row(6, gpt3_44b(), 384, 8, 4, 96, 1),
        setting_row(7, gpt3_44b(), 384, 8, 2, 24, 8),
        setting_row(8, gpt3_44b(), 384, 8, 1, 48, 8),
        setting_row(9, gpt3_175b(), 384, 2, 1, 96, 4),
        setting_row(10, gpt3_175b(), 384, 2, 1, 48, 8),
    ]
}

/// Table 1 row by id (1-based, panics outside 1..=10).
pub fn setting(id: u32) -> Setting {
    table1()
        .into_iter()
        .find(|s| s.id == id)
        .unwrap_or_else(|| panic!("no Table 1 setting {id}"))
}

/// The Fig. 7 variants: setting (5) with longer sequences; the paper
/// shrinks B to fit memory (4096→8, 6144→4, 8192→2).
pub fn long_sequence_settings() -> Vec<(u32, Setting)> {
    let mut out = Vec::new();
    for (seq_len, batch) in [(2048u32, 32u32), (4096, 8), (6144, 4), (8192, 2)] {
        let mut s = setting(5);
        s.model.seq_len = seq_len;
        s.parallel.batch_size = batch;
        out.push((seq_len, s));
    }
    out
}
