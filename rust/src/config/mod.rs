//! Model / cluster / parallelism configuration.
//!
//! Mirrors the paper's experimental grid: a [`ModelConfig`] is a GPT-3
//! variant (Table 1 columns N, H, #Params, L), a [`ClusterConfig`] is the
//! AWS p3.16xlarge testbed shape, and a [`ParallelConfig`] is one Table 1
//! row (#GPUs, B, #Data, #Pipe, #Op). JSON load/save lets users define
//! their own; [`presets`] carries the paper's exact settings.

pub mod presets;

/// A GPT-style Transformer LM (decoder-only), paper §3.1.
#[derive(Debug, Clone, PartialEq)]
pub struct ModelConfig {
    pub name: String,
    /// Number of Transformer layers (Table 1 "N").
    pub num_layers: u32,
    /// Hidden state size (Table 1 "H").
    pub hidden: u32,
    /// Attention heads (paper follows GPT-3: head dim 128).
    pub num_heads: u32,
    /// Input sequence length (Table 1 "L").
    pub seq_len: u32,
    /// Vocabulary size (GPT-3 BPE).
    pub vocab: u32,
}

impl ModelConfig {
    /// Total parameter count: 12·N·H² transformer weights plus embeddings,
    /// the standard estimate the paper's "#Params" column uses.
    pub fn num_params(&self) -> u64 {
        let h = self.hidden as u64;
        let n = self.num_layers as u64;
        12 * n * h * h + (self.vocab as u64 + self.seq_len as u64) * h
    }

    /// Forward FLOPs per token for one layer, excluding the context-length
    /// dependent attention term: QKV (6H²) + proj (2H²) + FFN (16H²).
    pub fn layer_flops_per_token(&self) -> f64 {
        24.0 * (self.hidden as f64) * (self.hidden as f64)
    }

    /// Context-dependent attention FLOPs for a slice of `i` tokens whose
    /// context has `j` tokens: each query attends to (j + within-slice)
    /// keys → QKᵀ + PV ≈ 4·H·(j + i/2) per token.
    pub fn attn_ctx_flops(&self, i: f64, j: f64) -> f64 {
        4.0 * self.hidden as f64 * i * (j + i / 2.0)
    }
}

/// GPU device model (defaults shaped like a 16 GB V100 SXM2).
#[derive(Debug, Clone, PartialEq)]
pub struct GpuSpec {
    /// Peak mixed-precision throughput, TFLOP/s (V100 tensor cores: 125).
    pub peak_tflops: f64,
    /// Fraction of peak achievable on saturated transformer matmuls.
    pub efficiency: f64,
    /// Memory capacity in GiB.
    pub mem_gib: f64,
    /// Kernel-launch + framework overhead per layer invocation, ms. This is
    /// what makes the Fig. 3 curve flat below the saturation knee.
    pub launch_overhead_ms: f64,
    /// Tokens at which a single layer saturates the device for H = 2048
    /// (paper Fig. 3 measures ≈256 on V100); scaled by H²/op internally.
    pub saturation_tokens_h2048: f64,
}

impl Default for GpuSpec {
    fn default() -> Self {
        // efficiency / saturation / launch / p2p are the four constants
        // calibrated against the paper's Table 2 latencies by
        // `terapipe calibrate` (rms log-error 0.39 ⇒ typical ×1.5;
        // EXPERIMENTS.md §Calibration).
        GpuSpec {
            peak_tflops: 125.0,
            efficiency: 0.45,
            mem_gib: 16.0,
            launch_overhead_ms: 2.0,
            saturation_tokens_h2048: 128.0,
        }
    }
}

/// Cluster shape: the paper uses AWS p3.16xlarge (8×V100, NVLink inside a
/// node, 25 Gbps between nodes).
#[derive(Debug, Clone, PartialEq)]
pub struct ClusterConfig {
    pub gpus_per_node: u32,
    pub num_nodes: u32,
    /// Intra-node (NVLink) bandwidth per link, GB/s.
    pub intra_bw_gbps: f64,
    /// Inter-node network bandwidth, GB/s (25 Gbps ⇒ ~3.1 GB/s).
    pub inter_bw_gbps: f64,
    /// Point-to-point latency, ms.
    pub p2p_latency_ms: f64,
    pub gpu: GpuSpec,
}

impl Default for ClusterConfig {
    fn default() -> Self {
        ClusterConfig {
            gpus_per_node: 8,
            num_nodes: 48,
            intra_bw_gbps: 130.0,
            inter_bw_gbps: 3.1,
            p2p_latency_ms: 2.0,
            gpu: GpuSpec::default(),
        }
    }
}

impl ClusterConfig {
    pub fn total_gpus(&self) -> u32 {
        self.gpus_per_node * self.num_nodes
    }
}

/// One parallel-training setup — a row of Table 1.
#[derive(Debug, Clone, PartialEq)]
pub struct ParallelConfig {
    /// Minibatch size B (sequences).
    pub batch_size: u32,
    /// Data-parallel replicas (Table 1 "#Data").
    pub data_parallel: u32,
    /// Pipeline stages K (Table 1 "#Pipe").
    pub pipeline_stages: u32,
    /// GPUs doing Megatron-style operation partitioning per layer ("#Op").
    pub op_parallel: u32,
}

impl ParallelConfig {
    pub fn total_gpus(&self) -> u32 {
        self.data_parallel * self.pipeline_stages * self.op_parallel
    }
}

/// A full experimental setting: Table 1 row = model + cluster + parallelism.
#[derive(Debug, Clone, PartialEq)]
pub struct Setting {
    /// Table 1 row number, 1–10.
    pub id: u32,
    pub model: ModelConfig,
    pub cluster: ClusterConfig,
    pub parallel: ParallelConfig,
}

impl Setting {
    /// Layers per pipeline cell; the paper partitions uniformly so this
    /// must divide exactly.
    pub fn layers_per_stage(&self) -> u32 {
        assert_eq!(
            self.model.num_layers % self.parallel.pipeline_stages,
            0,
            "layers must divide evenly across pipeline stages"
        );
        self.model.num_layers / self.parallel.pipeline_stages
    }

    /// Sequences processed together per pipeline (B / #Data).
    pub fn batch_per_pipeline(&self) -> u32 {
        self.parallel.batch_size / self.parallel.data_parallel
    }

    pub fn validate(&self) -> Result<(), String> {
        if self.model.num_layers % self.parallel.pipeline_stages != 0 {
            return Err(format!(
                "setting {}: {} layers not divisible by {} stages",
                self.id, self.model.num_layers, self.parallel.pipeline_stages
            ));
        }
        if self.parallel.batch_size % self.parallel.data_parallel != 0 {
            return Err(format!(
                "setting {}: batch {} not divisible by #data {}",
                self.id, self.parallel.batch_size, self.parallel.data_parallel
            ));
        }
        if self.parallel.total_gpus() > self.cluster.total_gpus() {
            return Err(format!(
                "setting {}: needs {} GPUs, cluster has {}",
                self.id,
                self.parallel.total_gpus(),
                self.cluster.total_gpus()
            ));
        }
        if self.model.hidden % self.model.num_heads != 0 {
            return Err(format!("setting {}: hidden % heads != 0", self.id));
        }
        Ok(())
    }
}

// ---------------------------------------------------------------------------
// JSON (de)serialization — user-defined configs for the launcher
// ---------------------------------------------------------------------------

use crate::util::json::Json;

impl Setting {
    pub fn to_json(&self) -> Json {
        let m = &self.model;
        let c = &self.cluster;
        let p = &self.parallel;
        Json::obj(vec![
            ("id", self.id.into()),
            (
                "model",
                Json::obj(vec![
                    ("name", m.name.as_str().into()),
                    ("num_layers", m.num_layers.into()),
                    ("hidden", m.hidden.into()),
                    ("num_heads", m.num_heads.into()),
                    ("seq_len", m.seq_len.into()),
                    ("vocab", m.vocab.into()),
                ]),
            ),
            (
                "cluster",
                Json::obj(vec![
                    ("gpus_per_node", c.gpus_per_node.into()),
                    ("num_nodes", c.num_nodes.into()),
                    ("intra_bw_gbps", c.intra_bw_gbps.into()),
                    ("inter_bw_gbps", c.inter_bw_gbps.into()),
                    ("p2p_latency_ms", c.p2p_latency_ms.into()),
                    (
                        "gpu",
                        Json::obj(vec![
                            ("peak_tflops", c.gpu.peak_tflops.into()),
                            ("efficiency", c.gpu.efficiency.into()),
                            ("mem_gib", c.gpu.mem_gib.into()),
                            ("launch_overhead_ms", c.gpu.launch_overhead_ms.into()),
                            ("saturation_tokens_h2048", c.gpu.saturation_tokens_h2048.into()),
                        ]),
                    ),
                ]),
            ),
            (
                "parallel",
                Json::obj(vec![
                    ("batch_size", p.batch_size.into()),
                    ("data_parallel", p.data_parallel.into()),
                    ("pipeline_stages", p.pipeline_stages.into()),
                    ("op_parallel", p.op_parallel.into()),
                ]),
            ),
        ])
    }

    pub fn from_json(v: &Json) -> Result<Setting, String> {
        let u = |v: &Json, k: &str| -> Result<u32, String> {
            v.req(k)?.as_u32().ok_or_else(|| format!("'{k}' must be a number"))
        };
        let f = |v: &Json, k: &str| -> Result<f64, String> {
            v.req(k)?.as_f64().ok_or_else(|| format!("'{k}' must be a number"))
        };
        let m = v.req("model")?;
        let c = v.req("cluster")?;
        let g = c.req("gpu")?;
        let p = v.req("parallel")?;
        let s = Setting {
            id: u(v, "id")?,
            model: ModelConfig {
                name: m.req("name")?.as_str().ok_or("'name' must be a string")?.to_string(),
                num_layers: u(m, "num_layers")?,
                hidden: u(m, "hidden")?,
                num_heads: u(m, "num_heads")?,
                seq_len: u(m, "seq_len")?,
                vocab: u(m, "vocab")?,
            },
            cluster: ClusterConfig {
                gpus_per_node: u(c, "gpus_per_node")?,
                num_nodes: u(c, "num_nodes")?,
                intra_bw_gbps: f(c, "intra_bw_gbps")?,
                inter_bw_gbps: f(c, "inter_bw_gbps")?,
                p2p_latency_ms: f(c, "p2p_latency_ms")?,
                gpu: GpuSpec {
                    peak_tflops: f(g, "peak_tflops")?,
                    efficiency: f(g, "efficiency")?,
                    mem_gib: f(g, "mem_gib")?,
                    launch_overhead_ms: f(g, "launch_overhead_ms")?,
                    saturation_tokens_h2048: f(g, "saturation_tokens_h2048")?,
                },
            },
            parallel: ParallelConfig {
                batch_size: u(p, "batch_size")?,
                data_parallel: u(p, "data_parallel")?,
                pipeline_stages: u(p, "pipeline_stages")?,
                op_parallel: u(p, "op_parallel")?,
            },
        };
        Ok(s)
    }
}

/// Load a [`Setting`] from a JSON file (user-defined configs).
pub fn load_setting(path: &std::path::Path) -> anyhow::Result<Setting> {
    let text = std::fs::read_to_string(path)?;
    let v = Json::parse(&text).map_err(|e| anyhow::anyhow!("{}: {e}", path.display()))?;
    let s = Setting::from_json(&v).map_err(|e| anyhow::anyhow!("{}: {e}", path.display()))?;
    s.validate().map_err(|e| anyhow::anyhow!(e))?;
    Ok(s)
}

/// Serialize a [`Setting`] to JSON text (for `terapipe configs --dump`).
pub fn dump_setting(s: &Setting) -> String {
    s.to_json().to_string()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn param_counts_match_paper_names() {
        // Table 1: the model names encode the param counts.
        let b1 = presets::gpt3_1b();
        let b13 = presets::gpt3_13b();
        let b44 = presets::gpt3_44b();
        let b175 = presets::gpt3_175b();
        assert!((b1.num_params() as f64 / 1e9 - 1.2).abs() < 0.3, "{}", b1.num_params());
        assert!((b13.num_params() as f64 / 1e9 - 13.0).abs() < 1.0);
        assert!((b44.num_params() as f64 / 1e9 - 44.0).abs() < 2.0);
        assert!((b175.num_params() as f64 / 1e9 - 175.0).abs() < 5.0);
    }

    #[test]
    fn all_table1_settings_validate() {
        for s in presets::table1() {
            s.validate().unwrap();
        }
    }

    #[test]
    fn table1_has_ten_rows_with_paper_shapes() {
        let t = presets::table1();
        assert_eq!(t.len(), 10);
        // spot-check row 9: GPT3-175B, 384 GPUs, B=2, 96 stages, op=4
        let s9 = &t[8];
        assert_eq!(s9.id, 9);
        assert_eq!(s9.model.hidden, 12288);
        assert_eq!(s9.parallel.pipeline_stages, 96);
        assert_eq!(s9.parallel.op_parallel, 4);
        assert_eq!(s9.parallel.batch_size, 2);
        assert_eq!(s9.parallel.total_gpus(), 384);
    }

    #[test]
    fn json_roundtrip() {
        let s = presets::setting(5);
        let text = dump_setting(&s);
        let back = Setting::from_json(&crate::util::json::Json::parse(&text).unwrap()).unwrap();
        assert_eq!(s, back);
    }

    #[test]
    fn from_json_reports_missing_fields() {
        let v = crate::util::json::Json::parse(r#"{"id": 1}"#).unwrap();
        let err = Setting::from_json(&v).unwrap_err();
        assert!(err.contains("model"), "{err}");
    }

    #[test]
    fn layers_per_stage_divides() {
        let s = presets::setting(9);
        assert_eq!(s.layers_per_stage(), 1); // 96 layers / 96 stages
        let s = presets::setting(10);
        assert_eq!(s.layers_per_stage(), 2); // 96 / 48
    }

    #[test]
    fn invalid_settings_rejected() {
        let mut s = presets::setting(1);
        s.parallel.pipeline_stages = 7; // 24 % 7 != 0
        assert!(s.validate().is_err());
        let mut s = presets::setting(1);
        s.parallel.data_parallel = 3;
        assert!(s.validate().is_err());
        let mut s = presets::setting(1);
        s.cluster.num_nodes = 1;
        assert!(s.validate().is_err());
    }
}
