//! Closed-form wavefront evaluator for *regular* plans — the DAG class
//! token-level pipeline schedules actually produce.
//!
//! A plan is regular ([`is_regular`]) when:
//!
//! * there is no flush barrier and no per-stage memory cap (the two
//!   features that make dispatch order depend on global state), and
//! * every dependency points to a lower item id (one forward pass is a
//!   topological order), and
//! * on each stage, the items form a single dependency chain in id order:
//!   every item after the stage's first depends on the stage's previous
//!   item.
//!
//! Under those conditions the unit-capacity resource constraint is
//! subsumed by the dependency structure — each stage's execution order is
//! forced by its chain, priorities are irrelevant, and an item's start
//! time is exactly the max over its dependency finish times plus edge
//! delays. For the canonical K-stage × M-slice replay stream this is the
//! Eq. 5 wavefront recurrence
//!
//! ```text
//! c[s][i] = max(c[s-1][i] + delay, c[s][i-1]) + dur[s][i]
//! ```
//!
//! evaluated in O(K·M) with no event heap, no ready queues, and no
//! per-item scheduling state at all. The float operations are the same
//! `max`/`+` the discrete-event core performs in event order, so the two
//! engines agree to the bit on this class (`tests/sim_equivalence.rs`
//! pins ≤1e-9; in practice the makespans are identical).
//!
//! [`engine::simulate`](super::engine::simulate) runs the probe and
//! auto-selects this path; irregular plans fall back to the
//! discrete-event core.

use super::engine::bubble_frac;
use super::trace::Span;
use super::{Plan, SimResult};

/// Plan-shape probe: `true` iff `plan` is in the regular class the
/// closed-form evaluator handles exactly (see module docs). O(items +
/// edges); also rejects malformed shapes (non-dense ids, NaN/negative
/// durations or delays) so the caller can fall back to the engine whose
/// validation reports them.
pub fn is_regular(plan: &Plan) -> bool {
    if plan.stages == 0 || plan.flush_barrier || plan.mem_cap_parts.is_some() {
        return false;
    }
    // last item seen per stage (usize::MAX = none yet)
    let mut last: Vec<usize> = vec![usize::MAX; plan.stages];
    for (idx, it) in plan.items.iter().enumerate() {
        if it.id != idx || it.stage >= plan.stages || !(it.dur_ms >= 0.0) {
            return false;
        }
        let prev = last[it.stage];
        // the stage head needs no chain edge; everyone else must depend
        // on the stage's previous item so execution order is forced
        let mut chained = prev == usize::MAX;
        for &(d, del) in &it.deps {
            if d >= idx || !(del >= 0.0) {
                return false;
            }
            if d == prev {
                chained = true;
            }
        }
        if !chained {
            return false;
        }
        last[it.stage] = idx;
    }
    true
}

/// Evaluate a regular plan in closed form. Returns `Err` when the plan
/// is outside the regular class (the closed form would silently ignore
/// the resource/barrier/memory constraints there) — route those through
/// the discrete-event engine instead, or use the auto-selecting
/// [`super::engine::simulate`].
pub fn evaluate(plan: &Plan, collect_trace: bool) -> Result<SimResult, String> {
    if !is_regular(plan) {
        return Err(
            "plan is outside the wavefront's regular class (barrier/cap/irregular deps); \
             use the discrete-event engine"
                .into(),
        );
    }
    let mut fin = Vec::new();
    Ok(evaluate_into(plan, collect_trace, &mut fin))
}

/// [`evaluate`] with a caller-provided scratch buffer for the finish
/// times, so arena-backed callers replay with zero transient allocation
/// (beyond the returned result's own vectors).
pub(crate) fn evaluate_into(plan: &Plan, collect_trace: bool, fin: &mut Vec<f64>) -> SimResult {
    debug_assert!(is_regular(plan), "wavefront::evaluate on an irregular plan");
    let n = plan.items.len();
    let k = plan.stages;
    fin.clear();
    fin.resize(n, 0.0);
    let mut busy = vec![0.0f64; k];
    let mut trace: Vec<Span> = Vec::with_capacity(if collect_trace { n } else { 0 });
    for it in &plan.items {
        // start = max over deps of (finish + edge delay); the resource
        // constraint is implied by the chain dep (see module docs)
        let mut start = 0.0f64;
        for &(d, del) in &it.deps {
            start = start.max(fin[d] + del);
        }
        let end = start + it.dur_ms;
        fin[it.id] = end;
        busy[it.stage] += it.dur_ms;
        if collect_trace {
            trace.push(Span {
                stage: it.stage,
                start_ms: start,
                end_ms: end,
                phase: it.phase,
                part: it.part,
                slice: it.slice,
            });
        }
    }
    let makespan = fin.iter().copied().fold(0.0f64, f64::max);
    let total_busy: f64 = busy.iter().sum();
    trace.sort_by(|a, b| a.stage.cmp(&b.stage).then(a.start_ms.total_cmp(&b.start_ms)));
    SimResult {
        makespan_ms: makespan,
        bubble_fraction: bubble_frac(total_busy, k, makespan),
        busy_ms: busy,
        trace,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::{Item, Phase};

    fn item(id: usize, stage: usize, dur: f64, deps: Vec<(usize, f64)>) -> Item {
        Item {
            id,
            stage,
            phase: Phase::Fwd,
            part: 0,
            slice: id,
            dur_ms: dur,
            deps,
            priority: id as u64,
        }
    }

    /// The canonical replay stream — the shared builder, so these tests
    /// always validate the exact shape `planner::validate` replays.
    fn chain_plan(k: usize, t: &[f64]) -> Plan {
        crate::sim::schedule::stream_plan(t, k)
    }

    #[test]
    fn chain_plans_are_regular_and_match_eq5() {
        for t in [vec![1.0, 3.0], vec![2.0, 5.0, 1.0, 4.0], vec![1.0; 8]] {
            for k in [1usize, 2, 5] {
                let p = chain_plan(k, &t);
                assert!(is_regular(&p));
                let r = evaluate(&p, false).unwrap();
                let want: f64 = t.iter().sum::<f64>()
                    + (k as f64 - 1.0) * t.iter().copied().fold(f64::NEG_INFINITY, f64::max);
                assert!((r.makespan_ms - want).abs() < 1e-9, "k={k}: {} vs {want}", r.makespan_ms);
            }
        }
    }

    #[test]
    fn barrier_or_cap_is_irregular() {
        let mut p = chain_plan(2, &[1.0, 2.0]);
        p.flush_barrier = true;
        assert!(!is_regular(&p));
        p.flush_barrier = false;
        p.mem_cap_parts = Some(1);
        assert!(!is_regular(&p));
    }

    #[test]
    fn independent_items_on_one_stage_are_irregular() {
        // no chain edge between the two stage-0 items ⇒ dispatch order is
        // a scheduling decision, not a dependency — must route to the DES
        let items = vec![item(0, 0, 1.0, vec![]), item(1, 0, 1.0, vec![])];
        let p = Plan { stages: 1, items, mem_cap_parts: None, flush_barrier: false };
        assert!(!is_regular(&p));
        // the public evaluator refuses rather than silently dropping the
        // resource constraint (the closed form would report 1.0, not 2.0)
        let err = evaluate(&p, false).unwrap_err();
        assert!(err.contains("regular class"), "{err}");
    }

    #[test]
    fn backward_edge_is_irregular() {
        // dep on a higher id: a single forward pass is no longer a
        // topological order
        let items = vec![item(0, 0, 1.0, vec![(1, 0.0)]), item(1, 0, 1.0, vec![])];
        let p = Plan { stages: 1, items, mem_cap_parts: None, flush_barrier: false };
        assert!(!is_regular(&p));
    }

    #[test]
    fn extra_cross_stage_and_in_stage_edges_stay_regular() {
        // chain + a long-range cross-stage edge and an older in-stage
        // edge: order is still forced, longest path still exact
        let items = vec![
            item(0, 0, 1.0, vec![]),
            item(1, 0, 1.0, vec![(0, 0.0)]),
            item(2, 1, 1.0, vec![(0, 0.5)]),
            item(3, 1, 1.0, vec![(2, 0.0), (1, 0.25), (0, 3.0)]),
        ];
        let p = Plan { stages: 2, items, mem_cap_parts: None, flush_barrier: false };
        assert!(is_regular(&p));
        let r = evaluate(&p, true).unwrap();
        // item 3: max(fin2=2.5? fin0+3=4, fin1+0.25=2.25, fin2+0=2.5) + 1
        // fin0=1, fin1=2, fin2=1+0.5+1=2.5 ⇒ start3=4, fin3=5
        assert!((r.makespan_ms - 5.0).abs() < 1e-12, "{}", r.makespan_ms);
        assert_eq!(r.trace.len(), 4);
    }

    #[test]
    fn comm_delays_on_the_chain_edge_are_honoured() {
        let mut p = chain_plan(3, &[1.0, 1.0]);
        for it in &mut p.items {
            let id = it.id;
            for d in &mut it.deps {
                // cross-stage edges are at stride m=2 in the chain plan
                if id >= 2 && d.0 == id - 2 {
                    d.1 = 0.5;
                }
            }
        }
        assert!(is_regular(&p));
        let r = evaluate(&p, false).unwrap();
        // plain eq5 = 4.0, two cross-stage hops on the critical path add 1.0
        assert!((r.makespan_ms - 5.0).abs() < 1e-9, "{}", r.makespan_ms);
    }

    #[test]
    fn empty_plan_evaluates_to_zero_with_zero_bubble() {
        let p = Plan { stages: 2, items: vec![], mem_cap_parts: None, flush_barrier: false };
        assert!(is_regular(&p));
        let r = evaluate(&p, true).unwrap();
        assert_eq!(r.makespan_ms, 0.0);
        assert_eq!(r.bubble_fraction, 0.0);
        assert!(r.trace.is_empty());
    }
}
