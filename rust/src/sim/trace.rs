//! Timeline traces: the paper's Fig. 2 / Fig. 4 style visualizations as
//! ASCII (for the CLI) and Chrome trace-event JSON (for chrome://tracing).

use super::Phase;
use crate::util::json::Json;

/// One executed work item on the timeline.
#[derive(Debug, Clone)]
pub struct Span {
    pub stage: usize,
    pub start_ms: f64,
    pub end_ms: f64,
    pub phase: Phase,
    pub part: usize,
    pub slice: usize,
}

/// ASCII timeline, one row per stage (Fig. 2-style). `width` columns span
/// [0, makespan]. Forward slices print as digits (part index mod 10),
/// backward as letters, idle as '·'.
pub fn ascii(spans: &[Span], stages: usize, width: usize) -> String {
    let makespan = spans.iter().map(|s| s.end_ms).fold(0.0f64, f64::max);
    if makespan <= 0.0 || width == 0 {
        return String::new();
    }
    let mut rows = vec![vec!['·'; width]; stages];
    for s in spans {
        let a = ((s.start_ms / makespan) * width as f64).floor() as usize;
        let b = (((s.end_ms / makespan) * width as f64).ceil() as usize).min(width);
        let ch = match s.phase {
            Phase::Fwd => char::from_digit((s.part % 10) as u32, 10).unwrap(),
            Phase::Bwd => (b'a' + (s.part % 26) as u8) as char,
        };
        for c in a..b.max(a + 1).min(width) {
            rows[s.stage][c] = ch;
        }
    }
    let mut out = String::new();
    for (k, row) in rows.iter().enumerate() {
        out.push_str(&format!("stage {k:>2} |"));
        out.extend(row.iter());
        out.push_str("|\n");
    }
    out.push_str(&format!("          0 ms {:>width$.1} ms\n", makespan, width = width.saturating_sub(5)));
    out
}

/// Chrome trace-event JSON (load via chrome://tracing or Perfetto).
pub fn chrome_json(spans: &[Span]) -> String {
    let evs: Vec<Json> = spans
        .iter()
        .map(|s| {
            Json::obj(vec![
                (
                    "name",
                    format!(
                        "{}{}.{}",
                        if s.phase == Phase::Fwd { "F" } else { "B" },
                        s.part,
                        s.slice
                    )
                    .into(),
                ),
                ("cat", if s.phase == Phase::Fwd { "fwd" } else { "bwd" }.into()),
                ("ph", "X".into()),
                ("ts", (s.start_ms * 1000.0).into()),
                ("dur", ((s.end_ms - s.start_ms) * 1000.0).into()),
                ("pid", 0u32.into()),
                ("tid", s.stage.into()),
            ])
        })
        .collect();
    Json::Arr(evs).to_string()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn spans() -> Vec<Span> {
        vec![
            Span { stage: 0, start_ms: 0.0, end_ms: 1.0, phase: Phase::Fwd, part: 0, slice: 0 },
            Span { stage: 1, start_ms: 1.0, end_ms: 2.0, phase: Phase::Fwd, part: 0, slice: 0 },
            Span { stage: 1, start_ms: 2.0, end_ms: 4.0, phase: Phase::Bwd, part: 0, slice: 0 },
            Span { stage: 0, start_ms: 4.0, end_ms: 6.0, phase: Phase::Bwd, part: 0, slice: 0 },
        ]
    }

    #[test]
    fn ascii_has_one_row_per_stage_and_idle_gaps() {
        let a = ascii(&spans(), 2, 24);
        let lines: Vec<&str> = a.lines().collect();
        assert_eq!(lines.len(), 3);
        assert!(lines[0].starts_with("stage  0"));
        assert!(lines[0].contains('0')); // fwd part 0
        assert!(lines[0].contains('a')); // bwd part 0
        assert!(lines[1].contains('·')); // stage 1 idle at start
    }

    #[test]
    fn ascii_empty_input_is_empty() {
        assert_eq!(ascii(&[], 2, 10), "");
    }

    #[test]
    fn chrome_json_parses_and_counts() {
        let j = chrome_json(&spans());
        let v = Json::parse(&j).unwrap();
        let arr = v.as_arr().unwrap();
        assert_eq!(arr.len(), 4);
        assert_eq!(arr[0].get("ph").unwrap().as_str(), Some("X"));
        assert_eq!(arr[2].get("cat").unwrap().as_str(), Some("bwd"));
        assert_eq!(arr[2].get("tid").unwrap().as_usize(), Some(1));
    }
}
