//! Schedule builders: turn a [`JointScheme`] (or a GPipe baseline) into a
//! simulator [`Plan`] with full fwd+bwd dependency structure.
//!
//! Dependency structure (per batch part d with token slices s_1..s_M):
//!
//! * Fwd(k, d, i) ← Fwd(k-1, d, i)  [activation arrives, + comm delay]
//! * Fwd(k, d, i) ← Fwd(k, d, i-1)  [KV context of earlier slices]
//! * Bwd(K-1, d, i) ← Fwd(K-1, d, i) and all Fwd(K-1, d, >i) — the slice's
//!   K/V gradient contributions from later slices must exist; with the
//!   reverse-order backward the binding dep is Bwd(k, d, i+1)
//! * Bwd(k, d, i) ← Bwd(k+1, d, i)  [upstream grad, + comm delay]
//! * Bwd(k, d, i) ← Bwd(k, d, i+1)  [context-grad accumulators]
//!
//! Priorities realize the paper's execution order: forward slices in
//! stream order, backward in reverse stream order.

use super::{Item, Phase, Plan};
use crate::perfmodel::CostModel;
use crate::solver::JointScheme;

/// Per-phase slice costs. [`CostModel::t`] is fwd+bwd combined; the
/// simulator needs them apart.
pub trait PhaseCost {
    fn fwd_ms(&self, microbatch: u32, i: u32, j: u32) -> f64;
    fn bwd_ms(&self, microbatch: u32, i: u32, j: u32) -> f64;
    fn comm_ms(&self, microbatch: u32, i: u32) -> f64;
}

/// Adapter: any [`CostModel`] factory split by the standard bwd ≈ 2·fwd.
pub struct SplitCost<F> {
    pub model_for: F,
}

impl<F, M> PhaseCost for SplitCost<F>
where
    F: Fn(u32) -> M,
    M: CostModel,
{
    fn fwd_ms(&self, b: u32, i: u32, j: u32) -> f64 {
        (self.model_for)(b).t(i, j) / 3.0
    }
    fn bwd_ms(&self, b: u32, i: u32, j: u32) -> f64 {
        2.0 * (self.model_for)(b).t(i, j) / 3.0
    }
    fn comm_ms(&self, b: u32, i: u32) -> f64 {
        (self.model_for)(b).t_comm(i)
    }
}

/// Build the K-stage × M-slice *replay stream* for per-slice stage times
/// `durs`: every stage executes the slice stream in order — slice `i` on
/// stage `k` depends on slice `i` on stage `k-1` and slice `i-1` on stage
/// `k`, with no extra edge delay (Eq. 4's computation + transmission are
/// folded into the durations). This is the regime where Eq. 5 is exact —
/// the shape `planner::validate` replays, the solver-vs-sim differential
/// suite pins, and `benches/sim.rs` measures — and it is *regular*
/// (`wavefront::is_regular`), so it takes the closed-form path.
pub fn stream_plan(durs: &[f64], stages: usize) -> Plan {
    assert!(!durs.is_empty() && stages >= 1);
    let m = durs.len();
    let mut items = Vec::with_capacity(m * stages);
    for s in 0..stages {
        for (i, &d) in durs.iter().enumerate() {
            let mut deps = Vec::new();
            if s > 0 {
                deps.push(((s - 1) * m + i, 0.0));
            }
            if i > 0 {
                deps.push((s * m + i - 1, 0.0));
            }
            items.push(Item {
                id: s * m + i,
                stage: s,
                phase: Phase::Fwd,
                part: 0,
                slice: i,
                dur_ms: d,
                deps,
                priority: (s * m + i) as u64,
            });
        }
    }
    Plan { stages, items, mem_cap_parts: None, flush_barrier: false }
}

/// [`stream_plan`] with per-stage durations: `durs[s][i]` is slice `i`'s
/// time on stage `s` — the shape per-stage cost models
/// ([`crate::perfmodel::measure::StageModels`]) produce, where the first
/// stage carries the embedding and the last the LM head. Same dependency
/// structure as [`stream_plan`]; the wavefront recurrence is exact on
/// per-item durations, so the plan stays regular.
pub fn stream_plan_per_stage(durs: &[Vec<f64>]) -> Plan {
    let stages = durs.len();
    assert!(stages >= 1);
    let m = durs[0].len();
    assert!(m >= 1 && durs.iter().all(|d| d.len() == m), "ragged per-stage durations");
    let mut items = Vec::with_capacity(m * stages);
    for (s, stage_durs) in durs.iter().enumerate() {
        for (i, &d) in stage_durs.iter().enumerate() {
            let mut deps = Vec::new();
            if s > 0 {
                deps.push(((s - 1) * m + i, 0.0));
            }
            if i > 0 {
                deps.push((s * m + i - 1, 0.0));
            }
            items.push(Item {
                id: s * m + i,
                stage: s,
                phase: Phase::Fwd,
                part: 0,
                slice: i,
                dur_ms: d,
                deps,
                priority: (s * m + i) as u64,
            });
        }
    }
    Plan { stages, items, mem_cap_parts: None, flush_barrier: false }
}

/// [`stream_plan_per_stage`] with explicit cross-stage transmission
/// delays: `hop_ms[s][i]` rides on the edge from slice `i` on stage `s`
/// to slice `i` on stage `s+1` (so `hop_ms.len() == durs.len() - 1`).
/// Use this when comm time is modeled per link rather than folded into
/// the stage durations — e.g. fitting against a
/// [`crate::coordinator::VirtualTransport`] run where the injected link
/// latency is observable separately from compute. Edge delays keep the
/// plan regular (`wavefront::is_regular` accepts nonzero cross-stage
/// delays), so the closed-form recurrence still applies.
pub fn stream_plan_per_stage_comm(durs: &[Vec<f64>], hop_ms: &[Vec<f64>]) -> Plan {
    let stages = durs.len();
    assert!(stages >= 1);
    let m = durs[0].len();
    assert!(m >= 1 && durs.iter().all(|d| d.len() == m), "ragged per-stage durations");
    assert!(
        hop_ms.len() == stages - 1 && hop_ms.iter().all(|h| h.len() == m),
        "need one delay row per hop, one entry per slice"
    );
    let mut items = Vec::with_capacity(m * stages);
    for (s, stage_durs) in durs.iter().enumerate() {
        for (i, &d) in stage_durs.iter().enumerate() {
            let mut deps = Vec::new();
            if s > 0 {
                deps.push(((s - 1) * m + i, hop_ms[s - 1][i]));
            }
            if i > 0 {
                deps.push((s * m + i - 1, 0.0));
            }
            items.push(Item {
                id: s * m + i,
                stage: s,
                phase: Phase::Fwd,
                part: 0,
                slice: i,
                dur_ms: d,
                deps,
                priority: (s * m + i) as u64,
            });
        }
    }
    Plan { stages, items, mem_cap_parts: None, flush_barrier: false }
}

/// Build the simulator plan for a joint (batch, token) scheme on a
/// `stages`-deep pipeline.
pub fn build_plan<C: PhaseCost>(
    cost: &C,
    scheme: &JointScheme,
    stages: usize,
    mem_cap_parts: Option<u32>,
    flush_barrier: bool,
) -> Plan {
    let mut items: Vec<Item> = Vec::new();
    // ids: fwd items first (part-major, slice, stage), then bwd
    let fwd_id = |d: usize, i: usize, k: usize, counts: &[usize]| -> usize {
        // offset of part d = stages * (slices of parts < d)
        let prior: usize = counts[..d].iter().sum();
        (prior + i) * stages + k
    };
    let counts: Vec<usize> = scheme.parts.iter().map(|(_, s)| s.lens.len()).collect();
    let total_slices: usize = counts.iter().sum();
    let fwd_total = total_slices * stages;

    // forward items
    let mut prio = 0u64;
    for (d, (b, s)) in scheme.parts.iter().enumerate() {
        let mut ctx = 0u32;
        for (i, &l) in s.lens.iter().enumerate() {
            for k in 0..stages {
                let id = fwd_id(d, i, k, &counts);
                let mut deps = Vec::new();
                if k > 0 {
                    deps.push((fwd_id(d, i, k - 1, &counts), cost.comm_ms(*b, l)));
                }
                if i > 0 {
                    deps.push((fwd_id(d, i - 1, k, &counts), 0.0));
                }
                items.push(Item {
                    id,
                    stage: k,
                    phase: Phase::Fwd,
                    part: d,
                    slice: i,
                    dur_ms: cost.fwd_ms(*b, l, ctx),
                    deps,
                    priority: prio,
                });
                prio += 1;
            }
            ctx += l;
        }
    }
    items.sort_by_key(|i| i.id);

    // backward items: reverse stream order, reverse stage order
    let bwd_id = |d: usize, i: usize, k: usize, counts: &[usize]| -> usize {
        let prior: usize = counts[..d].iter().sum();
        fwd_total + (prior + i) * stages + k
    };
    let mut bwd_items = Vec::new();
    for (d, (b, s)) in scheme.parts.iter().enumerate() {
        let m = s.lens.len();
        let mut ctx_of: Vec<u32> = Vec::with_capacity(m);
        let mut acc = 0u32;
        for &l in &s.lens {
            ctx_of.push(acc);
            acc += l;
        }
        for i in (0..m).rev() {
            for k in (0..stages).rev() {
                let id = bwd_id(d, i, k, &counts);
                let mut deps = Vec::new();
                if k == stages - 1 {
                    // loss grad needs this slice's forward on the last stage
                    deps.push((fwd_id(d, i, k, &counts), 0.0));
                } else {
                    deps.push((bwd_id(d, i, k + 1, &counts), cost.comm_ms(*b, s.lens[i])));
                }
                if i + 1 < m {
                    // context-grad accumulation from the next slice
                    deps.push((bwd_id(d, i + 1, k, &counts), 0.0));
                }
                bwd_items.push(Item {
                    id,
                    stage: k,
                    phase: Phase::Bwd,
                    part: d,
                    slice: i,
                    dur_ms: cost.bwd_ms(*b, s.lens[i], ctx_of[i]),
                    deps,
                    // bwd runs after fwd priorities; reverse stream order
                    priority: prio + (m - 1 - i) as u64 * stages as u64 + (stages - 1 - k) as u64,
                });
            }
        }
        prio += (m * stages) as u64;
    }
    items.extend(bwd_items);
    items.sort_by_key(|i| i.id);
    for (idx, it) in items.iter().enumerate() {
        debug_assert_eq!(idx, it.id);
    }

    Plan {
        stages,
        items,
        mem_cap_parts,
        flush_barrier,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::engine::simulate;
    use crate::solver::{JointScheme, SliceScheme};

    /// constant-cost model: fwd 1 ms, bwd 2 ms, no comm
    struct Const;
    impl PhaseCost for Const {
        fn fwd_ms(&self, _b: u32, _i: u32, _j: u32) -> f64 {
            1.0
        }
        fn bwd_ms(&self, _b: u32, _i: u32, _j: u32) -> f64 {
            2.0
        }
        fn comm_ms(&self, _b: u32, _i: u32) -> f64 {
            0.0
        }
    }

    fn scheme(parts: Vec<Vec<u32>>) -> JointScheme {
        JointScheme {
            parts: parts
                .into_iter()
                .map(|lens| {
                    (
                        1u32,
                        SliceScheme {
                            lens,
                            total_ms: 0.0,
                            t_max_ms: 0.0,
                            latency_ms: 0.0,
                        },
                    )
                })
                .collect(),
            latency_ms: 0.0,
        }
    }

    #[test]
    fn stream_plan_is_regular_and_matches_eq5() {
        let durs = [1.0, 3.0, 2.0];
        let p = stream_plan(&durs, 4);
        assert!(crate::sim::wavefront::is_regular(&p));
        let r = simulate(&p).unwrap();
        // Σt + (K-1)·max t = 6 + 3·3
        assert!((r.makespan_ms - 15.0).abs() < 1e-9, "{}", r.makespan_ms);
    }

    #[test]
    fn per_stage_stream_plan_is_regular_and_uses_stage_durs() {
        let p = stream_plan_per_stage(&[vec![1.0, 1.0], vec![3.0, 3.0]]);
        assert!(crate::sim::wavefront::is_regular(&p));
        // F(0,0)@0-1, F(0,1)@1-2; F(1,0)@1-4, F(1,1)@4-7
        let r = simulate(&p).unwrap();
        assert!((r.makespan_ms - 7.0).abs() < 1e-9, "{}", r.makespan_ms);
        // uniform per-stage durations must agree with stream_plan exactly
        let durs = [1.0, 3.0, 2.0];
        let a = simulate(&stream_plan_per_stage(&[durs.to_vec(), durs.to_vec(), durs.to_vec()]))
            .unwrap();
        let b = simulate(&stream_plan(&durs, 3)).unwrap();
        assert_eq!(a.makespan_ms, b.makespan_ms);
    }

    #[test]
    fn comm_stream_plan_shifts_the_wavefront_by_the_hop_delay() {
        let durs = vec![vec![1.0, 1.0], vec![3.0, 3.0]];
        // Zero hop delays must reproduce stream_plan_per_stage exactly.
        let base = simulate(&stream_plan_per_stage(&durs)).unwrap();
        let zero = simulate(&stream_plan_per_stage_comm(&durs, &[vec![0.0, 0.0]])).unwrap();
        assert_eq!(base.makespan_ms, zero.makespan_ms);
        // A 5 ms hop on every slice: stage 1 is the bottleneck and its
        // first start shifts from t=1 to t=6, so makespan 7 → 12. Still
        // regular, so the closed form sees the same number.
        let p = stream_plan_per_stage_comm(&durs, &[vec![5.0, 5.0]]);
        assert!(crate::sim::wavefront::is_regular(&p));
        let r = simulate(&p).unwrap();
        assert!((r.makespan_ms - 12.0).abs() < 1e-9, "{}", r.makespan_ms);
        let wf = crate::sim::wavefront::evaluate(&p, false).unwrap();
        assert!((wf.makespan_ms - 12.0).abs() < 1e-9, "{}", wf.makespan_ms);
    }

    #[test]
    fn plan_has_fwd_and_bwd_for_every_slice_stage() {
        let p = build_plan(&Const, &scheme(vec![vec![8, 8], vec![16]]), 3, None, true);
        assert_eq!(p.items.len(), 2 * 3 * 3); // 3 slices × 3 stages × {f,b}
        let fwd = p.items.iter().filter(|i| i.phase == Phase::Fwd).count();
        assert_eq!(fwd, 9);
    }

    #[test]
    fn gpipe_like_single_part_makespan_known() {
        // M=1 part, 1 slice, K=2, fwd 1 bwd 2, flush: F0@0-1, F1@1-2,
        // B1@2-4, B0@4-6 ⇒ makespan 6
        let p = build_plan(&Const, &scheme(vec![vec![16]]), 2, None, true);
        let r = simulate(&p).unwrap();
        assert!((r.makespan_ms - 6.0).abs() < 1e-9, "{}", r.makespan_ms);
    }

    #[test]
    fn token_slicing_reduces_makespan_vs_single_slice() {
        // Fig. 2c vs 2b: same work, more slices ⇒ smaller bubbles. Use a
        // cost where slice time scales with length so total work is equal.
        struct Linear;
        impl PhaseCost for Linear {
            fn fwd_ms(&self, _b: u32, i: u32, _j: u32) -> f64 {
                i as f64 / 16.0
            }
            fn bwd_ms(&self, b: u32, i: u32, j: u32) -> f64 {
                2.0 * self.fwd_ms(b, i, j)
            }
            fn comm_ms(&self, _b: u32, _i: u32) -> f64 {
                0.0
            }
        }
        let k = 4;
        let single =
            simulate(&build_plan(&Linear, &scheme(vec![vec![64]]), k, None, true)).unwrap();
        let sliced =
            simulate(&build_plan(&Linear, &scheme(vec![vec![16; 4]]), k, None, true)).unwrap();
        assert!(
            sliced.makespan_ms < 0.6 * single.makespan_ms,
            "sliced {} vs single {}",
            sliced.makespan_ms,
            single.makespan_ms
        );
        assert!(sliced.bubble_fraction < single.bubble_fraction);
    }

    #[test]
    fn later_slices_cost_more_with_context_model() {
        struct Ctx;
        impl PhaseCost for Ctx {
            fn fwd_ms(&self, _b: u32, i: u32, j: u32) -> f64 {
                i as f64 / 16.0 + (i as f64 * j as f64) / 1024.0
            }
            fn bwd_ms(&self, b: u32, i: u32, j: u32) -> f64 {
                2.0 * self.fwd_ms(b, i, j)
            }
            fn comm_ms(&self, _b: u32, _i: u32) -> f64 {
                0.0
            }
        }
        let p = build_plan(&Ctx, &scheme(vec![vec![16, 16]]), 1, None, true);
        let first = p.items.iter().find(|i| i.slice == 0 && i.phase == Phase::Fwd).unwrap();
        let second = p.items.iter().find(|i| i.slice == 1 && i.phase == Phase::Fwd).unwrap();
        assert!(second.dur_ms > first.dur_ms);
    }

    #[test]
    fn memory_capped_plan_still_completes_without_barrier() {
        // Appendix A (c): cap 2 parts, 3 parts total, interleaved bwd.
        let p = build_plan(&Const, &scheme(vec![vec![8], vec![8], vec![8]]), 3, Some(2), false);
        let r = simulate(&p).unwrap();
        assert!(r.makespan_ms > 0.0);
    }

    #[test]
    fn appendix_a_terapipe_beats_capped_gpipe() {
        // Appendix A: 3 stages, memory cap 2 sequences. (b) microbatch GA
        // vs (c) TeraPipe splitting each sequence in two.
        let k = 3;
        struct Linear;
        impl PhaseCost for Linear {
            fn fwd_ms(&self, _b: u32, i: u32, _j: u32) -> f64 {
                i as f64
            }
            fn bwd_ms(&self, _b: u32, i: u32, _j: u32) -> f64 {
                2.0 * i as f64
            }
            fn comm_ms(&self, _b: u32, _i: u32) -> f64 {
                0.0
            }
        }
        let ga = simulate(&build_plan(
            &Linear,
            &scheme(vec![vec![2]; 6]),
            k,
            Some(2),
            false,
        ))
        .unwrap();
        let tp = simulate(&build_plan(
            &Linear,
            &scheme(vec![vec![1, 1]; 6]),
            k,
            Some(2),
            false,
        ))
        .unwrap();
        assert!(
            tp.makespan_ms < ga.makespan_ms,
            "terapipe {} vs GA {}",
            tp.makespan_ms,
            ga.makespan_ms
        );
    }

    #[test]
    fn comm_delays_appear_on_cross_stage_edges() {
        struct WithComm;
        impl PhaseCost for WithComm {
            fn fwd_ms(&self, _b: u32, _i: u32, _j: u32) -> f64 {
                1.0
            }
            fn bwd_ms(&self, _b: u32, _i: u32, _j: u32) -> f64 {
                1.0
            }
            fn comm_ms(&self, _b: u32, _i: u32) -> f64 {
                0.25
            }
        }
        let p = build_plan(&WithComm, &scheme(vec![vec![8]]), 2, None, true);
        // F0@0-1, F1@1.25-2.25, B1@2.25-3.25, B0@3.5-4.5
        let r = simulate(&p).unwrap();
        assert!((r.makespan_ms - 4.5).abs() < 1e-9, "{}", r.makespan_ms);
    }

    #[test]
    fn ids_are_dense_and_consistent() {
        let p = build_plan(&Const, &scheme(vec![vec![8, 8, 8], vec![8]]), 4, None, false);
        for (i, it) in p.items.iter().enumerate() {
            assert_eq!(i, it.id);
            for &(d, _) in &it.deps {
                assert!(d < p.items.len());
            }
        }
    }
}
