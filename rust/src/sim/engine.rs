//! The discrete-event core: executes a [`Plan`] and returns a
//! [`SimResult`].
//!
//! Each stage is a unit-capacity resource with a priority queue of ready
//! items. An item becomes *ready* when all dependencies have finished plus
//! their edge delays; it becomes *dispatchable* when its stage is idle,
//! the flush barrier (if any) allows its phase, and — for the first
//! forward slice of a batch part on that stage — an activation slot is
//! free. Backward completion of a part's last slice releases the slot
//! (Appendix A's memory constraint).
//!
//! Two implementations share this contract:
//!
//! * [`simulate_ref`] — the original engine, retained verbatim as the
//!   property-test oracle (repo style: every rewritten hot path keeps its
//!   reference implementation; see `solve_tokens_seq`,
//!   `solve_fixed_tmax_ref`).
//! * [`SimArena`] — the production core. All per-run buffers live in the
//!   arena and are reused across replays; dependency *and* dependent
//!   edges are CSR-flattened with the edge delay stored per edge (the
//!   reference does a linear `find` over the dependent's deps on every
//!   completion); completions re-dispatch only the finishing stage
//!   instead of all K (every other unblock path already has a pending
//!   event — see `dispatch` for the case analysis); the deferred-items
//!   scratch buffer is reused instead of allocated per dispatch; and
//!   trace collection is optional so validation replays skip [`Span`]
//!   bookkeeping entirely.
//!
//! The free functions [`simulate`] / [`simulate_opts`] are the public
//! entry points: they run a plan-shape probe ([`wavefront::is_regular`])
//! and route regular plans (per-stage chains, no barrier, no memory cap —
//! the class token-level pipeline schedules actually produce) to the
//! closed-form [`wavefront`] evaluator, everything else to a thread-local
//! [`SimArena`]. [`simulate_many`] fans independent replays across rayon
//! with one arena per worker.
//!
//! Equivalence is pinned by `tests/sim_equivalence.rs`: arena vs
//! reference is bit-identical (makespan, busy, trace) on randomized DAGs
//! including barriers, memory caps, edge delays and priority ties;
//! wavefront vs DES agrees within 1e-9 on regular plans.

use std::cell::RefCell;
use std::cmp::Reverse;
use std::collections::BinaryHeap;

use rayon::prelude::*;

use super::trace::Span;
use super::wavefront;
use super::{Phase, Plan, SimResult};

/// The paper's "pipeline bubble" share, guarded against the empty /
/// zero-makespan plans where the naive ratio is 0/0 (NaN): a plan with no
/// work has no bubbles.
#[inline]
pub(crate) fn bubble_frac(total_busy: f64, stages: usize, makespan: f64) -> f64 {
    if makespan <= 0.0 {
        0.0
    } else {
        1.0 - total_busy / (stages as f64 * makespan)
    }
}

// ---------------------------------------------------------------------------
// Public entry points (probe + thread-local arena)
// ---------------------------------------------------------------------------

thread_local! {
    static TL_ARENA: RefCell<SimArena> = RefCell::new(SimArena::new());
}

/// Simulate the plan (trace collection on). Regular plans take the
/// closed-form wavefront path, everything else the arena-backed
/// discrete-event core; both reuse a thread-local [`SimArena`] so repeated
/// calls on one thread allocate nothing beyond the returned result.
/// Returns an error on malformed input or deadlock (e.g. a memory cap
/// that can never be satisfied under a flush barrier — Appendix A's
/// failure mode) instead of looping forever.
pub fn simulate(plan: &Plan) -> Result<SimResult, String> {
    simulate_opts(plan, true)
}

/// [`simulate`] with trace collection optional: validation replays that
/// only need the makespan pass `collect_trace = false` and skip all
/// [`Span`] bookkeeping (the returned trace is empty).
pub fn simulate_opts(plan: &Plan, collect_trace: bool) -> Result<SimResult, String> {
    TL_ARENA.with(|a| a.borrow_mut().simulate(plan, collect_trace))
}

/// Replay many independent plans in parallel (one [`SimArena`] per rayon
/// worker, reused across the plans it processes). Results come back in
/// input order. This is the batched path behind `planner::validate` and
/// the solver-vs-sim differential suite.
pub fn simulate_many(plans: &[Plan], collect_trace: bool) -> Vec<Result<SimResult, String>> {
    plans
        .par_iter()
        .map_init(SimArena::new, |arena, p| arena.simulate(p, collect_trace))
        .collect()
}

/// Structural validation shared by both engines' entry points. The
/// reference engine `assert!`s; the production path returns `Err` so a
/// malformed plan (NaN duration, dangling dep, off-by-one stage) can
/// never panic the simulator — `planner::validate` runs inside a
/// long-lived service.
fn check_plan(plan: &Plan) -> Result<(), String> {
    if plan.stages == 0 {
        return Err("plan must have at least one stage".into());
    }
    let n = plan.items.len();
    for (idx, it) in plan.items.iter().enumerate() {
        if it.id != idx {
            return Err(format!("item ids must be dense and sorted: index {idx} holds id {}", it.id));
        }
        if it.stage >= plan.stages {
            return Err(format!("item {} on stage {} ≥ {}", it.id, it.stage, plan.stages));
        }
        if !(it.dur_ms >= 0.0) {
            return Err(format!("item {} has negative or non-finite duration {}", it.id, it.dur_ms));
        }
        for &(d, del) in &it.deps {
            if d >= n {
                return Err(format!("item {} depends on out-of-range id {d}", it.id));
            }
            if !(del >= 0.0) {
                return Err(format!("item {} has negative or non-finite edge delay {del}", it.id));
            }
        }
    }
    Ok(())
}

// ---------------------------------------------------------------------------
// Arena-backed discrete-event core
// ---------------------------------------------------------------------------

/// Event in the arena core's heap. Ordering matches the reference
/// engine's: time, then kind (0 = finish before 1 = wake at ties), then
/// item id — via `total_cmp`, so a NaN time can never panic the heap.
/// `stage` is deliberately not part of the order (same as the reference);
/// equal-time wakes on different stages commute because a dispatch only
/// touches its own stage's state. The heaps live in the arena —
/// `BinaryHeap::clear()` retains capacity, so reuse stays allocation-free.
#[derive(Clone, Copy, PartialEq)]
struct AEv {
    time: f64,
    kind: u8,
    stage: u32,
    item: u32,
}

impl Eq for AEv {}
impl PartialOrd for AEv {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}
impl Ord for AEv {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        self.time
            .total_cmp(&other.time)
            .then(self.kind.cmp(&other.kind))
            .then(self.item.cmp(&other.item))
    }
}

/// Reusable simulation arena: every per-run buffer is allocated once and
/// reused across replays, so steady-state replay does no heap allocation
/// beyond the returned [`SimResult`] (and, in trace mode, its spans).
///
/// Reuse protocol: one arena per thread (`&mut self` enforces exclusive
/// use); call [`SimArena::simulate`] — or [`SimArena::simulate_des`] to
/// bypass the wavefront probe — as many times as you like. Buffers grow to
/// the largest plan seen and stay there. The free functions
/// [`simulate`] / [`simulate_opts`] wrap a thread-local arena;
/// [`simulate_many`] builds one per rayon worker.
pub struct SimArena {
    // CSR dependents: for item i, `dept_edge[dept_off[i]..dept_off[i+1]]`
    // holds `(dependent id, edge delay)` — the delay is stored per edge so
    // a completion releases each dependent in O(1) (the reference engine
    // re-finds the delay with a linear scan of the dependent's deps).
    dept_off: Vec<u32>,
    dept_edge: Vec<(u32, f64)>,
    csr_cursor: Vec<u32>,
    // per-item
    missing: Vec<u32>,
    ready_time: Vec<f64>,
    finish: Vec<f64>,
    started: Vec<bool>,
    // per-stage
    idle_at: Vec<f64>,
    busy: Vec<f64>,
    fwd_left: Vec<u32>,
    used_slots: Vec<u32>,
    has_bwd: Vec<bool>,
    queues: Vec<BinaryHeap<Reverse<(u64, u32)>>>,
    // per (stage, part), stage-major
    holds: Vec<bool>,
    bwd_left: Vec<u32>,
    // event heap + dispatch scratch
    events: BinaryHeap<Reverse<AEv>>,
    deferred: Vec<(u64, u32)>,
}

impl Default for SimArena {
    fn default() -> Self {
        Self::new()
    }
}

impl SimArena {
    pub fn new() -> SimArena {
        SimArena {
            dept_off: Vec::new(),
            dept_edge: Vec::new(),
            csr_cursor: Vec::new(),
            missing: Vec::new(),
            ready_time: Vec::new(),
            finish: Vec::new(),
            started: Vec::new(),
            idle_at: Vec::new(),
            busy: Vec::new(),
            fwd_left: Vec::new(),
            used_slots: Vec::new(),
            has_bwd: Vec::new(),
            queues: Vec::new(),
            holds: Vec::new(),
            bwd_left: Vec::new(),
            events: BinaryHeap::new(),
            deferred: Vec::new(),
        }
    }

    /// Simulate `plan`, auto-selecting the engine: regular plans (see
    /// [`wavefront::is_regular`]) take the closed-form evaluator, the
    /// rest the discrete-event core. The probe runs first — it rejects
    /// every malformed shape `check_plan` would (non-dense ids, stage
    /// bounds, NaN/negative durations and delays), so the regular fast
    /// path pays exactly one O(items + edges) structural scan and
    /// irregular/malformed plans fall through to the DES entry, whose
    /// `check_plan` produces the descriptive error.
    pub fn simulate(&mut self, plan: &Plan, collect_trace: bool) -> Result<SimResult, String> {
        if wavefront::is_regular(plan) {
            // reuse the arena's finish buffer as the recurrence scratch
            return Ok(wavefront::evaluate_into(plan, collect_trace, &mut self.finish));
        }
        self.simulate_des(plan, collect_trace)
    }

    /// Simulate `plan` through the discrete-event core unconditionally
    /// (no wavefront probe) — the engine the equivalence suite compares
    /// bit-for-bit against [`simulate_ref`].
    pub fn simulate_des(&mut self, plan: &Plan, collect_trace: bool) -> Result<SimResult, String> {
        check_plan(plan)?;
        self.run_des(plan, collect_trace)
    }

    fn reset(&mut self, n: usize, k: usize, parts: usize) {
        self.dept_off.clear();
        self.dept_off.resize(n + 1, 0);
        self.csr_cursor.clear();
        self.missing.clear();
        self.missing.resize(n, 0);
        self.ready_time.clear();
        self.ready_time.resize(n, 0.0);
        self.finish.clear();
        self.finish.resize(n, f64::NAN);
        self.started.clear();
        self.started.resize(n, false);
        self.idle_at.clear();
        self.idle_at.resize(k, 0.0);
        self.busy.clear();
        self.busy.resize(k, 0.0);
        self.fwd_left.clear();
        self.fwd_left.resize(k, 0);
        self.used_slots.clear();
        self.used_slots.resize(k, 0);
        self.has_bwd.clear();
        self.has_bwd.resize(k, false);
        while self.queues.len() < k {
            self.queues.push(BinaryHeap::new());
        }
        for q in &mut self.queues {
            q.clear();
        }
        self.holds.clear();
        self.holds.resize(k * parts, false);
        self.bwd_left.clear();
        self.bwd_left.resize(k * parts, 0);
        self.events.clear();
        self.deferred.clear();
    }

    /// The event loop. Identical scheduling decisions to [`simulate_ref`]
    /// on tie-free plans — no two events at bit-identical times, which
    /// continuous durations guarantee and the equivalence suite pins
    /// bit-for-bit. (At exactly coincident instants the engines may
    /// resolve ties into different, equally legal schedules: the
    /// reference dispatches stages against stale same-instant state.
    /// See PERF.md §7.) Three structural differences, none of which
    /// change tie-free decisions:
    ///
    /// * a completion re-dispatches only its own stage. Every other way an
    ///   item can become dispatchable already has a pending event: stage
    ///   idle / barrier lift / memory-slot release all happen via a finish
    ///   on the item's own stage, and readiness pushes a wake at the
    ///   item's final `ready_time` the moment its last dep completes.
    /// * no wake is pushed when a dispatch defers a not-yet-ready item —
    ///   the readiness wake above is already in the heap (the reference
    ///   pushes a redundant duplicate on every scan).
    /// * the t=0 wakes are replaced by direct dispatch calls before the
    ///   loop (nothing can precede them in the heap).
    fn run_des(&mut self, plan: &Plan, collect_trace: bool) -> Result<SimResult, String> {
        let n = plan.items.len();
        let k = plan.stages;
        let parts = plan.items.iter().map(|i| i.part).max().map_or(0, |p| p + 1);
        self.reset(n, k, parts);

        // pass 1: per-item/per-stage counts, CSR edge counts
        for it in &plan.items {
            self.missing[it.id] = it.deps.len() as u32;
            for &(d, _) in &it.deps {
                self.dept_off[d + 1] += 1;
            }
            if it.phase == Phase::Fwd {
                self.fwd_left[it.stage] += 1;
            } else {
                self.bwd_left[it.stage * parts + it.part] += 1;
                self.has_bwd[it.stage] = true;
            }
        }
        for i in 0..n {
            self.dept_off[i + 1] += self.dept_off[i];
        }
        // pass 2: place edges
        let edges = self.dept_off[n] as usize;
        self.dept_edge.clear();
        self.dept_edge.resize(edges, (0, 0.0));
        self.csr_cursor.extend_from_slice(&self.dept_off[..n]);
        for it in &plan.items {
            for &(d, del) in &it.deps {
                let c = self.csr_cursor[d] as usize;
                self.dept_edge[c] = (it.id as u32, del);
                self.csr_cursor[d] += 1;
            }
        }

        let mut trace: Vec<Span> = Vec::with_capacity(if collect_trace { n } else { 0 });

        // items with no deps are ready at t=0; dispatch every stage once
        for it in &plan.items {
            if it.deps.is_empty() {
                self.queues[it.stage].push(Reverse((it.priority, it.id as u32)));
            }
        }
        for s in 0..k {
            self.dispatch(0.0, s, plan, parts, collect_trace, &mut trace);
        }

        let mut done = 0usize;
        while let Some(Reverse(ev)) = self.events.pop() {
            let now = ev.time;
            if ev.kind == 0 {
                // item finished
                let id = ev.item as usize;
                self.finish[id] = now;
                done += 1;
                let it = &plan.items[id];
                let s = it.stage;
                if it.phase == Phase::Fwd {
                    self.fwd_left[s] -= 1;
                } else {
                    let hp = s * parts + it.part;
                    self.bwd_left[hp] -= 1;
                    if self.bwd_left[hp] == 0 && self.holds[hp] {
                        self.holds[hp] = false;
                        self.used_slots[s] -= 1;
                    }
                }
                // release dependents (O(1) per edge via the CSR delay)
                let (a, b) = (self.dept_off[id] as usize, self.dept_off[id + 1] as usize);
                for e in a..b {
                    let (dep_id, delay) = self.dept_edge[e];
                    let di = dep_id as usize;
                    self.ready_time[di] = self.ready_time[di].max(now + delay);
                    self.missing[di] -= 1;
                    if self.missing[di] == 0 {
                        let ds = plan.items[di].stage;
                        self.queues[ds].push(Reverse((plan.items[di].priority, dep_id)));
                        self.events.push(Reverse(AEv {
                            time: self.ready_time[di].max(now),
                            kind: 1,
                            stage: ds as u32,
                            item: u32::MAX,
                        }));
                    }
                }
                // targeted wakeup: only the finishing stage can have
                // gained dispatchability from this completion
                self.dispatch(now, s, plan, parts, collect_trace, &mut trace);
            } else {
                self.dispatch(now, ev.stage as usize, plan, parts, collect_trace, &mut trace);
            }
        }

        if done != n {
            // unreachable items ⇒ same report as the reference engine
            return Err(format!(
                "deadlock: {done}/{n} items completed (memory cap {:?} with flush_barrier={} is unsatisfiable)",
                plan.mem_cap_parts, plan.flush_barrier
            ));
        }

        let makespan = self.finish[..n].iter().copied().fold(0.0f64, f64::max);
        let total_busy: f64 = self.busy[..k].iter().sum();
        trace.sort_by(|x, y| x.stage.cmp(&y.stage).then(x.start_ms.total_cmp(&y.start_ms)));
        Ok(SimResult {
            makespan_ms: makespan,
            bubble_fraction: bubble_frac(total_busy, k, makespan),
            busy_ms: self.busy[..k].to_vec(),
            trace,
        })
    }

    /// Dispatch as much as possible on stage `s` at `now`: scan the ready
    /// queue for the best dispatchable item, deferring blocked ones into
    /// the reused scratch buffer (the reference allocates a fresh `Vec`
    /// per call).
    fn dispatch(
        &mut self,
        now: f64,
        s: usize,
        plan: &Plan,
        parts: usize,
        collect_trace: bool,
        trace: &mut Vec<Span>,
    ) {
        if self.idle_at[s] > now {
            return;
        }
        debug_assert!(self.deferred.is_empty());
        let mut chosen: Option<u32> = None;
        while let Some(Reverse((prio, id))) = self.queues[s].pop() {
            let idu = id as usize;
            if self.started[idu] {
                continue;
            }
            let it = &plan.items[idu];
            let mut blocked = self.ready_time[idu] > now;
            if !blocked && plan.flush_barrier && it.phase == Phase::Bwd && self.fwd_left[s] > 0 {
                blocked = true; // barrier lifts when this stage's last fwd finishes
            }
            if !blocked && it.phase == Phase::Fwd && self.has_bwd[s] {
                if let Some(cap) = plan.mem_cap_parts {
                    if !self.holds[s * parts + it.part] && self.used_slots[s] >= cap {
                        blocked = true; // slot frees on a bwd completion here
                    }
                }
            }
            if blocked {
                // no wake push: a not-yet-ready item already has its
                // readiness wake in the heap (pushed when its last dep
                // finished), and barrier/memory blocks can only lift via a
                // finish on this stage, which re-dispatches it.
                self.deferred.push((prio, id));
            } else {
                chosen = Some(id);
                break;
            }
        }
        for i in 0..self.deferred.len() {
            let d = self.deferred[i];
            self.queues[s].push(Reverse(d));
        }
        self.deferred.clear();
        if let Some(id) = chosen {
            let idu = id as usize;
            let it = &plan.items[idu];
            if it.phase == Phase::Fwd && self.has_bwd[s] && plan.mem_cap_parts.is_some() {
                let hp = s * parts + it.part;
                if !self.holds[hp] {
                    self.holds[hp] = true;
                    self.used_slots[s] += 1;
                }
            }
            self.started[idu] = true;
            let end = now + it.dur_ms;
            self.idle_at[s] = end;
            self.busy[s] += it.dur_ms;
            if collect_trace {
                trace.push(Span {
                    stage: s,
                    start_ms: now,
                    end_ms: end,
                    phase: it.phase,
                    part: it.part,
                    slice: it.slice,
                });
            }
            self.events.push(Reverse(AEv { time: end, kind: 0, stage: s as u32, item: id }));
        }
    }
}

// ---------------------------------------------------------------------------
// Reference engine (oracle)
// ---------------------------------------------------------------------------

#[derive(Debug, PartialEq)]
struct Ev {
    time: f64,
    /// 0 = item finished, 1 = wake (retry dispatch) — finish first at ties.
    kind: u8,
    stage: usize,
    item: usize,
}

impl Eq for Ev {}
impl PartialOrd for Ev {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}
impl Ord for Ev {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        self.time
            .total_cmp(&other.time)
            .then(self.kind.cmp(&other.kind))
            .then(self.item.cmp(&other.item))
    }
}

/// The original discrete-event engine, retained as the property-test
/// oracle (`tests/sim_equivalence.rs` pins the arena core to it
/// bit-for-bit). Allocates every buffer per call and re-dispatches all K
/// stages on every completion — do not use on a hot path.
///
/// Returns an error on deadlock (e.g. a memory cap that can never be
/// satisfied under a flush barrier — Appendix A's failure mode) instead
/// of looping forever.
pub fn simulate_ref(plan: &Plan) -> Result<SimResult, String> {
    let n = plan.items.len();
    let k = plan.stages;
    assert!(k >= 1);
    for it in &plan.items {
        assert!(it.stage < k, "item {} on stage {} ≥ {}", it.id, it.stage, k);
        assert!(it.dur_ms >= 0.0);
    }

    // dependency bookkeeping
    let mut missing: Vec<usize> = plan.items.iter().map(|i| i.deps.len()).collect();
    let mut dependents: Vec<Vec<usize>> = vec![Vec::new(); n];
    for it in &plan.items {
        for &(d, _) in &it.deps {
            dependents[d].push(it.id);
        }
    }
    let mut ready_time: Vec<f64> = vec![0.0; n];
    let mut finish: Vec<f64> = vec![f64::NAN; n];
    let mut started: Vec<bool> = vec![false; n];

    // per-stage state
    let mut idle_at: Vec<f64> = vec![0.0; k];
    let mut busy: Vec<f64> = vec![0.0; k];
    // ready queue per stage: (priority, id), min-heap
    let mut queues: Vec<BinaryHeap<Reverse<(u64, usize)>>> =
        (0..k).map(|_| BinaryHeap::new()).collect();
    // flush barrier: remaining fwd items per stage
    let mut fwd_left: Vec<usize> = vec![0; k];
    for it in &plan.items {
        if it.phase == Phase::Fwd {
            fwd_left[it.stage] += 1;
        }
    }
    // memory slots: per stage, per part — acquired at first Fwd slice
    // dispatch, released after last Bwd slice finishes
    let parts = plan.items.iter().map(|i| i.part).max().map_or(0, |p| p + 1);
    let mut holds: Vec<Vec<bool>> = vec![vec![false; parts]; k];
    let mut used_slots: Vec<u32> = vec![0; k];
    let mut bwd_left_per_part: Vec<Vec<usize>> = vec![vec![0; parts]; k];
    let mut has_bwd_stage: Vec<bool> = vec![false; k];
    for it in &plan.items {
        if it.phase == Phase::Bwd {
            bwd_left_per_part[it.stage][it.part] += 1;
            has_bwd_stage[it.stage] = true;
        }
    }

    let mut events: BinaryHeap<Reverse<Ev>> = BinaryHeap::new();
    // items with no deps are ready at t=0
    for it in &plan.items {
        if it.deps.is_empty() {
            queues[it.stage].push(Reverse((it.priority, it.id)));
        }
    }
    for s in 0..k {
        events.push(Reverse(Ev { time: 0.0, kind: 1, stage: s, item: usize::MAX }));
    }

    let mut trace: Vec<Span> = Vec::with_capacity(n);
    let mut done = 0usize;

    // dispatch as much as possible on a stage at `now`; returns next
    // blocked-ready wake time if any
    let dispatch = |now: f64,
                    s: usize,
                    plan: &Plan,
                    queues: &mut Vec<BinaryHeap<Reverse<(u64, usize)>>>,
                    idle_at: &mut Vec<f64>,
                    busy: &mut Vec<f64>,
                    started: &mut Vec<bool>,
                    ready_time: &Vec<f64>,
                    fwd_left: &Vec<usize>,
                    holds: &mut Vec<Vec<bool>>,
                    used_slots: &mut Vec<u32>,
                    has_bwd_stage: &Vec<bool>,
                    events: &mut BinaryHeap<Reverse<Ev>>,
                    trace: &mut Vec<Span>|
     -> () {
        if idle_at[s] > now {
            return;
        }
        // scan the queue for the best dispatchable item; keep blocked ones
        let mut deferred: Vec<(u64, usize)> = Vec::new();
        let mut chosen: Option<usize> = None;
        while let Some(Reverse((prio, id))) = queues[s].pop() {
            let it = &plan.items[id];
            if started[id] {
                continue;
            }
            let mut blocked = false;
            let mut wake: Option<f64> = None;
            if ready_time[id] > now {
                blocked = true;
                wake = Some(ready_time[id]);
            }
            if !blocked && plan.flush_barrier && it.phase == Phase::Bwd && fwd_left[s] > 0 {
                blocked = true; // barrier lifts when last fwd finishes
            }
            if !blocked && it.phase == Phase::Fwd && has_bwd_stage[s] {
                if let Some(cap) = plan.mem_cap_parts {
                    if !holds[s][it.part] && used_slots[s] >= cap {
                        blocked = true; // slot frees on a bwd completion
                    }
                }
            }
            if blocked {
                deferred.push((prio, id));
                if let Some(w) = wake {
                    events.push(Reverse(Ev { time: w, kind: 1, stage: s, item: usize::MAX }));
                }
            } else {
                chosen = Some(id);
                break;
            }
        }
        for d in deferred {
            queues[s].push(Reverse(d));
        }
        if let Some(id) = chosen {
            let it = &plan.items[id];
            if it.phase == Phase::Fwd
                && has_bwd_stage[s]
                && plan.mem_cap_parts.is_some()
                && !holds[s][it.part]
            {
                holds[s][it.part] = true;
                used_slots[s] += 1;
            }
            started[id] = true;
            let end = now + it.dur_ms;
            idle_at[s] = end;
            busy[s] += it.dur_ms;
            trace.push(Span {
                stage: s,
                start_ms: now,
                end_ms: end,
                phase: it.phase,
                part: it.part,
                slice: it.slice,
            });
            events.push(Reverse(Ev { time: end, kind: 0, stage: s, item: id }));
        }
    };

    while let Some(Reverse(ev)) = events.pop() {
        let now = ev.time;
        if ev.kind == 0 {
            // item finished
            let id = ev.item;
            finish[id] = now;
            done += 1;
            let it = &plan.items[id];
            let s = it.stage;
            if it.phase == Phase::Fwd {
                fwd_left[s] -= 1;
            } else {
                bwd_left_per_part[s][it.part] -= 1;
                if bwd_left_per_part[s][it.part] == 0 && holds[s][it.part] {
                    holds[s][it.part] = false;
                    used_slots[s] -= 1;
                }
            }
            // release dependents
            for &dep_id in &dependents[id] {
                let delay = plan.items[dep_id]
                    .deps
                    .iter()
                    .find(|&&(d, _)| d == id)
                    .map(|&(_, del)| del)
                    .unwrap();
                ready_time[dep_id] = ready_time[dep_id].max(now + delay);
                missing[dep_id] -= 1;
                if missing[dep_id] == 0 {
                    let ds = plan.items[dep_id].stage;
                    queues[ds].push(Reverse((plan.items[dep_id].priority, dep_id)));
                    events.push(Reverse(Ev {
                        time: ready_time[dep_id].max(now),
                        kind: 1,
                        stage: ds,
                        item: usize::MAX,
                    }));
                }
            }
            // this stage is idle now; also re-try every stage that may have
            // been blocked on memory or the barrier (cheap: K is small)
            for st in 0..k {
                dispatch(
                    now, st, plan, &mut queues, &mut idle_at, &mut busy, &mut started,
                    &ready_time, &fwd_left, &mut holds, &mut used_slots, &has_bwd_stage,
                    &mut events, &mut trace,
                );
            }
        } else {
            dispatch(
                now, ev.stage, plan, &mut queues, &mut idle_at, &mut busy, &mut started,
                &ready_time, &fwd_left, &mut holds, &mut used_slots, &has_bwd_stage,
                &mut events, &mut trace,
            );
        }
    }

    if done != n {
        return Err(format!(
            "deadlock: {done}/{n} items completed (memory cap {:?} with flush_barrier={} is unsatisfiable)",
            plan.mem_cap_parts, plan.flush_barrier
        ));
    }

    let makespan = finish.iter().copied().fold(0.0f64, f64::max);
    let total_busy: f64 = busy.iter().sum();
    trace.sort_by(|a, b| a.stage.cmp(&b.stage).then(a.start_ms.total_cmp(&b.start_ms)));
    Ok(SimResult {
        makespan_ms: makespan,
        bubble_fraction: bubble_frac(total_busy, k, makespan),
        busy_ms: busy,
        trace,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::Item;

    fn item(
        id: usize,
        stage: usize,
        phase: Phase,
        part: usize,
        slice: usize,
        dur: f64,
        deps: Vec<(usize, f64)>,
    ) -> Item {
        Item { id, stage, phase, part, slice, dur_ms: dur, deps, priority: id as u64 }
    }

    /// fwd-only chain: K stages × M slices with slice costs `t`, uniform
    /// across stages ⇒ makespan must equal Eq. 5 exactly.
    fn chain_plan(k: usize, t: &[f64]) -> Plan {
        let m = t.len();
        let mut items = Vec::new();
        for s in 0..k {
            for i in 0..m {
                let mut deps = Vec::new();
                if s > 0 {
                    deps.push(((s - 1) * m + i, 0.0));
                }
                if i > 0 {
                    deps.push((s * m + i - 1, 0.0));
                }
                items.push(item(s * m + i, s, Phase::Fwd, 0, i, t[i], deps));
            }
        }
        Plan { stages: k, items, mem_cap_parts: None, flush_barrier: false }
    }

    #[test]
    fn forward_chain_matches_eq5() {
        for t in [vec![1.0, 3.0], vec![3.0, 1.0], vec![2.0, 5.0, 1.0, 4.0], vec![1.0; 8]] {
            for k in [1usize, 2, 3, 5] {
                let r = simulate(&chain_plan(k, &t)).unwrap();
                let want: f64 = t.iter().sum::<f64>()
                    + (k as f64 - 1.0) * t.iter().copied().fold(f64::NEG_INFINITY, f64::max);
                assert!(
                    (r.makespan_ms - want).abs() < 1e-9,
                    "k={k} t={t:?}: sim {} vs eq5 {want}",
                    r.makespan_ms
                );
            }
        }
    }

    #[test]
    fn uniform_split_nonuniform_time_has_bigger_bubbles() {
        // Fig. 4: same total work, the balanced split wins.
        let k = 4;
        let uneven = simulate(&chain_plan(k, &[1.0, 1.5, 2.0, 2.5])).unwrap();
        let even = simulate(&chain_plan(k, &[1.75; 4])).unwrap();
        assert!(even.makespan_ms < uneven.makespan_ms);
        assert!(even.bubble_fraction < uneven.bubble_fraction);
    }

    #[test]
    fn comm_delay_extends_makespan() {
        let p = chain_plan(3, &[1.0, 1.0]);
        // rebuild with explicit delays on cross-stage edges
        let mut items = p.items.clone();
        for it in &mut items {
            let my_stage = it.stage;
            for d in &mut it.deps {
                let dep_stage = d.0 / 2;
                if dep_stage != my_stage {
                    d.1 = 0.5;
                }
            }
        }
        let delayed = simulate(&Plan { items, ..p.clone() }).unwrap();
        let plain = simulate(&p).unwrap();
        // plain: Σt + (K-1)·max = 2 + 2·1 = 4
        assert!((plain.makespan_ms - 4.0).abs() < 1e-9, "{}", plain.makespan_ms);
        // each of the two cross-stage hops adds 0.5 on the critical path
        assert!((delayed.makespan_ms - 5.0).abs() < 1e-9, "{}", delayed.makespan_ms);
    }

    #[test]
    fn single_stage_is_serial_sum() {
        let r = simulate(&chain_plan(1, &[2.0, 3.0, 4.0])).unwrap();
        assert!((r.makespan_ms - 9.0).abs() < 1e-12);
        assert!(r.bubble_fraction.abs() < 1e-12);
    }

    #[test]
    fn flush_barrier_orders_bwd_after_all_fwd() {
        // 1 stage, one fwd part then its bwd + a second fwd part: with the
        // barrier, both fwds run before the first bwd.
        let items = vec![
            item(0, 0, Phase::Fwd, 0, 0, 1.0, vec![]),
            item(1, 0, Phase::Bwd, 0, 0, 1.0, vec![(0, 0.0)]),
            item(2, 0, Phase::Fwd, 1, 0, 1.0, vec![]),
        ];
        let plan = Plan {
            stages: 1,
            items: items.clone(),
            mem_cap_parts: None,
            flush_barrier: true,
        };
        let r = simulate(&plan).unwrap();
        let bwd_span = r.trace.iter().find(|s| s.phase == Phase::Bwd).unwrap();
        assert!((bwd_span.start_ms - 2.0).abs() < 1e-9, "bwd must wait for the flush");
        // without the barrier the bwd (ready at t=1, priority 1 < 2) runs first
        let r2 =
            simulate(&Plan { stages: 1, items, mem_cap_parts: None, flush_barrier: false })
                .unwrap();
        let bwd_span2 = r2.trace.iter().find(|s| s.phase == Phase::Bwd).unwrap();
        assert!((bwd_span2.start_ms - 1.0).abs() < 1e-9);
    }

    #[test]
    fn memory_cap_blocks_admission_until_bwd_frees() {
        // Appendix A (b): cap of 1 part ⇒ second part's fwd waits for the
        // first part's bwd to finish on that stage.
        let items = vec![
            item(0, 0, Phase::Fwd, 0, 0, 1.0, vec![]),
            item(1, 0, Phase::Bwd, 0, 0, 1.0, vec![(0, 0.0)]),
            item(2, 0, Phase::Fwd, 1, 0, 1.0, vec![]),
            item(3, 0, Phase::Bwd, 1, 0, 1.0, vec![(2, 0.0)]),
        ];
        let r =
            simulate(&Plan { stages: 1, items, mem_cap_parts: Some(1), flush_barrier: false })
                .unwrap();
        let f2 = r.trace.iter().find(|s| s.phase == Phase::Fwd && s.part == 1).unwrap();
        assert!(f2.start_ms >= 2.0 - 1e-9, "fwd(part 1) at {} must wait for bwd(part 0)", f2.start_ms);
    }

    #[test]
    fn impossible_cap_with_barrier_deadlocks_cleanly() {
        // barrier forces both fwds before any bwd, but cap 1 forbids the
        // second fwd before a bwd ⇒ deadlock, reported not spun.
        let items = vec![
            item(0, 0, Phase::Fwd, 0, 0, 1.0, vec![]),
            item(1, 0, Phase::Bwd, 0, 0, 1.0, vec![(0, 0.0)]),
            item(2, 0, Phase::Fwd, 1, 0, 1.0, vec![]),
            item(3, 0, Phase::Bwd, 1, 0, 1.0, vec![(2, 0.0)]),
        ];
        let plan =
            Plan { stages: 1, items, mem_cap_parts: Some(1), flush_barrier: true };
        let err = simulate(&plan).unwrap_err();
        assert!(err.contains("deadlock"));
        // oracle agrees
        assert!(simulate_ref(&plan).unwrap_err().contains("deadlock"));
    }

    #[test]
    fn busy_time_equals_item_durations() {
        let r = simulate(&chain_plan(3, &[1.0, 2.0])).unwrap();
        for b in &r.busy_ms {
            assert!((b - 3.0).abs() < 1e-12);
        }
        assert_eq!(r.trace.len(), 6);
    }

    #[test]
    fn priority_breaks_ties_among_ready_items() {
        // two independent fwd items on one stage: lower priority runs first
        let items = vec![
            Item {
                id: 0,
                stage: 0,
                phase: Phase::Fwd,
                part: 0,
                slice: 0,
                dur_ms: 1.0,
                deps: vec![],
                priority: 10,
            },
            Item {
                id: 1,
                stage: 0,
                phase: Phase::Fwd,
                part: 1,
                slice: 0,
                dur_ms: 1.0,
                deps: vec![],
                priority: 5,
            },
        ];
        let r =
            simulate(&Plan { stages: 1, items, mem_cap_parts: None, flush_barrier: false })
                .unwrap();
        assert_eq!(r.trace[0].part, 1);
    }

    // ---- fast-path / robustness pins (this PR) ----

    #[test]
    fn empty_plan_has_zero_makespan_and_zero_bubble() {
        // the naive bubble ratio is 0/0 here; the guard pins it to 0.0
        let r = simulate(&Plan {
            stages: 3,
            items: vec![],
            mem_cap_parts: None,
            flush_barrier: false,
        })
        .unwrap();
        assert_eq!(r.makespan_ms, 0.0);
        assert_eq!(r.bubble_fraction, 0.0);
        assert!(r.bubble_fraction.is_finite());
    }

    #[test]
    fn zero_duration_plan_has_zero_bubble_not_nan() {
        // all-zero durations ⇒ zero makespan through the DES path too
        // (the barrier forces the discrete-event engine)
        let items = vec![
            item(0, 0, Phase::Fwd, 0, 0, 0.0, vec![]),
            item(1, 0, Phase::Bwd, 0, 0, 0.0, vec![(0, 0.0)]),
        ];
        let plan = Plan { stages: 1, items, mem_cap_parts: None, flush_barrier: true };
        let r = simulate(&plan).unwrap();
        assert_eq!(r.makespan_ms, 0.0);
        assert_eq!(r.bubble_fraction, 0.0);
        let r = simulate_ref(&plan).unwrap();
        assert_eq!(r.bubble_fraction, 0.0);
    }

    #[test]
    fn nan_duration_is_an_error_not_a_panic() {
        let items = vec![item(0, 0, Phase::Fwd, 0, 0, f64::NAN, vec![])];
        let err = simulate(&Plan { stages: 1, items, mem_cap_parts: None, flush_barrier: false })
            .unwrap_err();
        assert!(err.contains("duration"), "{err}");
    }

    #[test]
    fn nan_edge_delay_is_an_error_not_a_panic() {
        let items = vec![
            item(0, 0, Phase::Fwd, 0, 0, 1.0, vec![]),
            item(1, 0, Phase::Fwd, 0, 1, 1.0, vec![(0, f64::NAN)]),
        ];
        let err = simulate(&Plan { stages: 1, items, mem_cap_parts: None, flush_barrier: false })
            .unwrap_err();
        assert!(err.contains("delay"), "{err}");
    }

    #[test]
    fn chain_plans_take_the_wavefront_path_and_agree_with_the_oracle() {
        let p = chain_plan(4, &[1.0, 2.5, 0.5]);
        assert!(wavefront::is_regular(&p));
        let fast = simulate(&p).unwrap();
        let oracle = simulate_ref(&p).unwrap();
        assert_eq!(fast.makespan_ms.to_bits(), oracle.makespan_ms.to_bits());
        assert_eq!(fast.busy_ms, oracle.busy_ms);
        assert_eq!(fast.trace.len(), oracle.trace.len());
    }

    #[test]
    fn arena_is_reusable_across_plans_of_different_shapes() {
        let mut arena = SimArena::new();
        let big = chain_plan(5, &[1.0, 2.0, 3.0, 4.0]);
        let small = chain_plan(2, &[1.0]);
        for p in [&big, &small, &big] {
            let a = arena.simulate_des(p, true).unwrap();
            let r = simulate_ref(p).unwrap();
            assert_eq!(a.makespan_ms.to_bits(), r.makespan_ms.to_bits());
        }
    }

    #[test]
    fn notrace_mode_returns_empty_trace_and_same_numbers() {
        let p = chain_plan(3, &[1.0, 2.0, 0.5]);
        let full = simulate_opts(&p, true).unwrap();
        let bare = simulate_opts(&p, false).unwrap();
        assert!(bare.trace.is_empty());
        assert!(!full.trace.is_empty());
        assert_eq!(full.makespan_ms.to_bits(), bare.makespan_ms.to_bits());
        assert_eq!(full.busy_ms, bare.busy_ms);
        assert_eq!(full.bubble_fraction.to_bits(), bare.bubble_fraction.to_bits());
    }

    #[test]
    fn simulate_many_matches_single_replays_in_order() {
        let plans = vec![
            chain_plan(2, &[1.0, 2.0]),
            chain_plan(4, &[0.5, 0.5, 3.0]),
            chain_plan(1, &[2.0]),
        ];
        let batched = simulate_many(&plans, false);
        for (p, b) in plans.iter().zip(&batched) {
            let single = simulate(p).unwrap();
            assert_eq!(
                single.makespan_ms.to_bits(),
                b.as_ref().unwrap().makespan_ms.to_bits()
            );
        }
    }
}
