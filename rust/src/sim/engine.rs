//! The discrete-event core: executes a [`Plan`] and returns a
//! [`SimResult`].
//!
//! Each stage is a unit-capacity resource with a priority queue of ready
//! items. An item becomes *ready* when all dependencies have finished plus
//! their edge delays; it becomes *dispatchable* when its stage is idle,
//! the flush barrier (if any) allows its phase, and — for the first
//! forward slice of a batch part on that stage — an activation slot is
//! free. Backward completion of a part's last slice releases the slot
//! (Appendix A's memory constraint).

use std::cmp::Reverse;
use std::collections::BinaryHeap;

use super::trace::Span;
use super::{Phase, Plan, SimResult};

#[derive(Debug, PartialEq)]
struct Ev {
    time: f64,
    /// 0 = item finished, 1 = wake (retry dispatch) — finish first at ties.
    kind: u8,
    stage: usize,
    item: usize,
}

impl Eq for Ev {}
impl PartialOrd for Ev {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}
impl Ord for Ev {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        self.time
            .partial_cmp(&other.time)
            .unwrap()
            .then(self.kind.cmp(&other.kind))
            .then(self.item.cmp(&other.item))
    }
}

/// Simulate the plan. Returns an error on deadlock (e.g. a memory cap that
/// can never be satisfied under a flush barrier — Appendix A's failure
/// mode) instead of looping forever.
pub fn simulate(plan: &Plan) -> Result<SimResult, String> {
    let n = plan.items.len();
    let k = plan.stages;
    assert!(k >= 1);
    for it in &plan.items {
        assert!(it.stage < k, "item {} on stage {} ≥ {}", it.id, it.stage, k);
        assert!(it.dur_ms >= 0.0);
    }

    // dependency bookkeeping
    let mut missing: Vec<usize> = plan.items.iter().map(|i| i.deps.len()).collect();
    let mut dependents: Vec<Vec<usize>> = vec![Vec::new(); n];
    for it in &plan.items {
        for &(d, _) in &it.deps {
            dependents[d].push(it.id);
        }
    }
    let mut ready_time: Vec<f64> = vec![0.0; n];
    let mut finish: Vec<f64> = vec![f64::NAN; n];
    let mut started: Vec<bool> = vec![false; n];

    // per-stage state
    let mut idle_at: Vec<f64> = vec![0.0; k];
    let mut busy: Vec<f64> = vec![0.0; k];
    // ready queue per stage: (priority, id), min-heap
    let mut queues: Vec<BinaryHeap<Reverse<(u64, usize)>>> =
        (0..k).map(|_| BinaryHeap::new()).collect();
    // flush barrier: remaining fwd items per stage
    let mut fwd_left: Vec<usize> = vec![0; k];
    for it in &plan.items {
        if it.phase == Phase::Fwd {
            fwd_left[it.stage] += 1;
        }
    }
    // memory slots: per stage, per part — acquired at first Fwd slice
    // dispatch, released after last Bwd slice finishes
    let parts = plan.items.iter().map(|i| i.part).max().map_or(0, |p| p + 1);
    let mut holds: Vec<Vec<bool>> = vec![vec![false; parts]; k];
    let mut used_slots: Vec<u32> = vec![0; k];
    let mut bwd_left_per_part: Vec<Vec<usize>> = vec![vec![0; parts]; k];
    let mut has_bwd_stage: Vec<bool> = vec![false; k];
    for it in &plan.items {
        if it.phase == Phase::Bwd {
            bwd_left_per_part[it.stage][it.part] += 1;
            has_bwd_stage[it.stage] = true;
        }
    }

    let mut events: BinaryHeap<Reverse<Ev>> = BinaryHeap::new();
    // items with no deps are ready at t=0
    for it in &plan.items {
        if it.deps.is_empty() {
            queues[it.stage].push(Reverse((it.priority, it.id)));
        }
    }
    for s in 0..k {
        events.push(Reverse(Ev { time: 0.0, kind: 1, stage: s, item: usize::MAX }));
    }

    let mut trace: Vec<Span> = Vec::with_capacity(n);
    let mut done = 0usize;

    // dispatch as much as possible on a stage at `now`; returns next
    // blocked-ready wake time if any
    let dispatch = |now: f64,
                    s: usize,
                    plan: &Plan,
                    queues: &mut Vec<BinaryHeap<Reverse<(u64, usize)>>>,
                    idle_at: &mut Vec<f64>,
                    busy: &mut Vec<f64>,
                    started: &mut Vec<bool>,
                    ready_time: &Vec<f64>,
                    fwd_left: &Vec<usize>,
                    holds: &mut Vec<Vec<bool>>,
                    used_slots: &mut Vec<u32>,
                    has_bwd_stage: &Vec<bool>,
                    events: &mut BinaryHeap<Reverse<Ev>>,
                    trace: &mut Vec<Span>|
     -> () {
        if idle_at[s] > now {
            return;
        }
        // scan the queue for the best dispatchable item; keep blocked ones
        let mut deferred: Vec<(u64, usize)> = Vec::new();
        let mut chosen: Option<usize> = None;
        while let Some(Reverse((prio, id))) = queues[s].pop() {
            let it = &plan.items[id];
            if started[id] {
                continue;
            }
            let mut blocked = false;
            let mut wake: Option<f64> = None;
            if ready_time[id] > now {
                blocked = true;
                wake = Some(ready_time[id]);
            }
            if !blocked && plan.flush_barrier && it.phase == Phase::Bwd && fwd_left[s] > 0 {
                blocked = true; // barrier lifts when last fwd finishes
            }
            if !blocked && it.phase == Phase::Fwd && has_bwd_stage[s] {
                if let Some(cap) = plan.mem_cap_parts {
                    if !holds[s][it.part] && used_slots[s] >= cap {
                        blocked = true; // slot frees on a bwd completion
                    }
                }
            }
            if blocked {
                deferred.push((prio, id));
                if let Some(w) = wake {
                    events.push(Reverse(Ev { time: w, kind: 1, stage: s, item: usize::MAX }));
                }
            } else {
                chosen = Some(id);
                break;
            }
        }
        for d in deferred {
            queues[s].push(Reverse(d));
        }
        if let Some(id) = chosen {
            let it = &plan.items[id];
            if it.phase == Phase::Fwd
                && has_bwd_stage[s]
                && plan.mem_cap_parts.is_some()
                && !holds[s][it.part]
            {
                holds[s][it.part] = true;
                used_slots[s] += 1;
            }
            started[id] = true;
            let end = now + it.dur_ms;
            idle_at[s] = end;
            busy[s] += it.dur_ms;
            trace.push(Span {
                stage: s,
                start_ms: now,
                end_ms: end,
                phase: it.phase,
                part: it.part,
                slice: it.slice,
            });
            events.push(Reverse(Ev { time: end, kind: 0, stage: s, item: id }));
        }
    };

    while let Some(Reverse(ev)) = events.pop() {
        let now = ev.time;
        if ev.kind == 0 {
            // item finished
            let id = ev.item;
            finish[id] = now;
            done += 1;
            let it = &plan.items[id];
            let s = it.stage;
            if it.phase == Phase::Fwd {
                fwd_left[s] -= 1;
            } else {
                bwd_left_per_part[s][it.part] -= 1;
                if bwd_left_per_part[s][it.part] == 0 && holds[s][it.part] {
                    holds[s][it.part] = false;
                    used_slots[s] -= 1;
                }
            }
            // release dependents
            for &dep_id in &dependents[id] {
                let delay = plan.items[dep_id]
                    .deps
                    .iter()
                    .find(|&&(d, _)| d == id)
                    .map(|&(_, del)| del)
                    .unwrap();
                ready_time[dep_id] = ready_time[dep_id].max(now + delay);
                missing[dep_id] -= 1;
                if missing[dep_id] == 0 {
                    let ds = plan.items[dep_id].stage;
                    queues[ds].push(Reverse((plan.items[dep_id].priority, dep_id)));
                    events.push(Reverse(Ev {
                        time: ready_time[dep_id].max(now),
                        kind: 1,
                        stage: ds,
                        item: usize::MAX,
                    }));
                }
            }
            // this stage is idle now; also re-try every stage that may have
            // been blocked on memory or the barrier (cheap: K is small)
            for st in 0..k {
                dispatch(
                    now, st, plan, &mut queues, &mut idle_at, &mut busy, &mut started,
                    &ready_time, &fwd_left, &mut holds, &mut used_slots, &has_bwd_stage,
                    &mut events, &mut trace,
                );
            }
        } else {
            dispatch(
                now, ev.stage, plan, &mut queues, &mut idle_at, &mut busy, &mut started,
                &ready_time, &fwd_left, &mut holds, &mut used_slots, &has_bwd_stage,
                &mut events, &mut trace,
            );
        }
    }

    if done != n {
        return Err(format!(
            "deadlock: {done}/{n} items completed (memory cap {:?} with flush_barrier={} is unsatisfiable)",
            plan.mem_cap_parts, plan.flush_barrier
        ));
    }

    let makespan = finish.iter().copied().fold(0.0f64, f64::max);
    let total_busy: f64 = busy.iter().sum();
    trace.sort_by(|a, b| (a.stage, a.start_ms).partial_cmp(&(b.stage, b.start_ms)).unwrap());
    Ok(SimResult {
        makespan_ms: makespan,
        bubble_fraction: 1.0 - total_busy / (k as f64 * makespan),
        busy_ms: busy,
        trace,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::Item;

    fn item(
        id: usize,
        stage: usize,
        phase: Phase,
        part: usize,
        slice: usize,
        dur: f64,
        deps: Vec<(usize, f64)>,
    ) -> Item {
        Item { id, stage, phase, part, slice, dur_ms: dur, deps, priority: id as u64 }
    }

    /// fwd-only chain: K stages × M slices with slice costs `t`, uniform
    /// across stages ⇒ makespan must equal Eq. 5 exactly.
    fn chain_plan(k: usize, t: &[f64]) -> Plan {
        let m = t.len();
        let mut items = Vec::new();
        for s in 0..k {
            for i in 0..m {
                let mut deps = Vec::new();
                if s > 0 {
                    deps.push(((s - 1) * m + i, 0.0));
                }
                if i > 0 {
                    deps.push((s * m + i - 1, 0.0));
                }
                items.push(item(s * m + i, s, Phase::Fwd, 0, i, t[i], deps));
            }
        }
        Plan { stages: k, items, mem_cap_parts: None, flush_barrier: false }
    }

    #[test]
    fn forward_chain_matches_eq5() {
        for t in [vec![1.0, 3.0], vec![3.0, 1.0], vec![2.0, 5.0, 1.0, 4.0], vec![1.0; 8]] {
            for k in [1usize, 2, 3, 5] {
                let r = simulate(&chain_plan(k, &t)).unwrap();
                let want: f64 = t.iter().sum::<f64>()
                    + (k as f64 - 1.0) * t.iter().copied().fold(f64::NEG_INFINITY, f64::max);
                assert!(
                    (r.makespan_ms - want).abs() < 1e-9,
                    "k={k} t={t:?}: sim {} vs eq5 {want}",
                    r.makespan_ms
                );
            }
        }
    }

    #[test]
    fn uniform_split_nonuniform_time_has_bigger_bubbles() {
        // Fig. 4: same total work, the balanced split wins.
        let k = 4;
        let uneven = simulate(&chain_plan(k, &[1.0, 1.5, 2.0, 2.5])).unwrap();
        let even = simulate(&chain_plan(k, &[1.75; 4])).unwrap();
        assert!(even.makespan_ms < uneven.makespan_ms);
        assert!(even.bubble_fraction < uneven.bubble_fraction);
    }

    #[test]
    fn comm_delay_extends_makespan() {
        let p = chain_plan(3, &[1.0, 1.0]);
        // rebuild with explicit delays on cross-stage edges
        let mut items = p.items.clone();
        for it in &mut items {
            let my_stage = it.stage;
            for d in &mut it.deps {
                let dep_stage = d.0 / 2;
                if dep_stage != my_stage {
                    d.1 = 0.5;
                }
            }
        }
        let delayed = simulate(&Plan { items, ..p.clone() }).unwrap();
        let plain = simulate(&p).unwrap();
        // plain: Σt + (K-1)·max = 2 + 2·1 = 4
        assert!((plain.makespan_ms - 4.0).abs() < 1e-9, "{}", plain.makespan_ms);
        // each of the two cross-stage hops adds 0.5 on the critical path
        assert!((delayed.makespan_ms - 5.0).abs() < 1e-9, "{}", delayed.makespan_ms);
    }

    #[test]
    fn single_stage_is_serial_sum() {
        let r = simulate(&chain_plan(1, &[2.0, 3.0, 4.0])).unwrap();
        assert!((r.makespan_ms - 9.0).abs() < 1e-12);
        assert!(r.bubble_fraction.abs() < 1e-12);
    }

    #[test]
    fn flush_barrier_orders_bwd_after_all_fwd() {
        // 1 stage, one fwd part then its bwd + a second fwd part: with the
        // barrier, both fwds run before the first bwd.
        let items = vec![
            item(0, 0, Phase::Fwd, 0, 0, 1.0, vec![]),
            item(1, 0, Phase::Bwd, 0, 0, 1.0, vec![(0, 0.0)]),
            item(2, 0, Phase::Fwd, 1, 0, 1.0, vec![]),
        ];
        let plan = Plan {
            stages: 1,
            items: items.clone(),
            mem_cap_parts: None,
            flush_barrier: true,
        };
        let r = simulate(&plan).unwrap();
        let bwd_span = r.trace.iter().find(|s| s.phase == Phase::Bwd).unwrap();
        assert!((bwd_span.start_ms - 2.0).abs() < 1e-9, "bwd must wait for the flush");
        // without the barrier the bwd (ready at t=1, priority 1 < 2) runs first
        let r2 =
            simulate(&Plan { stages: 1, items, mem_cap_parts: None, flush_barrier: false })
                .unwrap();
        let bwd_span2 = r2.trace.iter().find(|s| s.phase == Phase::Bwd).unwrap();
        assert!((bwd_span2.start_ms - 1.0).abs() < 1e-9);
    }

    #[test]
    fn memory_cap_blocks_admission_until_bwd_frees() {
        // Appendix A (b): cap of 1 part ⇒ second part's fwd waits for the
        // first part's bwd to finish on that stage.
        let items = vec![
            item(0, 0, Phase::Fwd, 0, 0, 1.0, vec![]),
            item(1, 0, Phase::Bwd, 0, 0, 1.0, vec![(0, 0.0)]),
            item(2, 0, Phase::Fwd, 1, 0, 1.0, vec![]),
            item(3, 0, Phase::Bwd, 1, 0, 1.0, vec![(2, 0.0)]),
        ];
        let r =
            simulate(&Plan { stages: 1, items, mem_cap_parts: Some(1), flush_barrier: false })
                .unwrap();
        let f2 = r.trace.iter().find(|s| s.phase == Phase::Fwd && s.part == 1).unwrap();
        assert!(f2.start_ms >= 2.0 - 1e-9, "fwd(part 1) at {} must wait for bwd(part 0)", f2.start_ms);
    }

    #[test]
    fn impossible_cap_with_barrier_deadlocks_cleanly() {
        // barrier forces both fwds before any bwd, but cap 1 forbids the
        // second fwd before a bwd ⇒ deadlock, reported not spun.
        let items = vec![
            item(0, 0, Phase::Fwd, 0, 0, 1.0, vec![]),
            item(1, 0, Phase::Bwd, 0, 0, 1.0, vec![(0, 0.0)]),
            item(2, 0, Phase::Fwd, 1, 0, 1.0, vec![]),
            item(3, 0, Phase::Bwd, 1, 0, 1.0, vec![(2, 0.0)]),
        ];
        let err =
            simulate(&Plan { stages: 1, items, mem_cap_parts: Some(1), flush_barrier: true })
                .unwrap_err();
        assert!(err.contains("deadlock"));
    }

    #[test]
    fn busy_time_equals_item_durations() {
        let r = simulate(&chain_plan(3, &[1.0, 2.0])).unwrap();
        for b in &r.busy_ms {
            assert!((b - 3.0).abs() < 1e-12);
        }
        assert_eq!(r.trace.len(), 6);
    }

    #[test]
    fn priority_breaks_ties_among_ready_items() {
        // two independent fwd items on one stage: lower priority runs first
        let items = vec![
            Item {
                id: 0,
                stage: 0,
                phase: Phase::Fwd,
                part: 0,
                slice: 0,
                dur_ms: 1.0,
                deps: vec![],
                priority: 10,
            },
            Item {
                id: 1,
                stage: 0,
                phase: Phase::Fwd,
                part: 1,
                slice: 0,
                dur_ms: 1.0,
                deps: vec![],
                priority: 5,
            },
        ];
        let r =
            simulate(&Plan { stages: 1, items, mem_cap_parts: None, flush_barrier: false })
                .unwrap();
        assert_eq!(r.trace[0].part, 1);
    }
}
