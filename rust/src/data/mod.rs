//! Training data for the end-to-end example: a byte-level tokenizer, a
//! synthetic structured corpus, and a deterministic batcher.
//!
//! The paper trains on standard LM corpora we don't ship; per the
//! substitution rule (DESIGN.md §2) we generate a small synthetic corpus
//! with real sequential structure (Markov-ish template text) so the loss
//! curve in EXPERIMENTS.md reflects actual learning, plus support for any
//! user-supplied text file.

use crate::util::Rng;

/// Byte-level tokenizer: token = byte, vocab 256. What GPT-2's BPE falls
/// back to; exactly reproducible in the python oracle.
pub struct ByteTokenizer;

impl ByteTokenizer {
    pub const VOCAB: u32 = 256;

    pub fn encode(text: &str) -> Vec<u32> {
        text.as_bytes().iter().map(|&b| b as u32).collect()
    }

    pub fn decode(tokens: &[u32]) -> String {
        let bytes: Vec<u8> = tokens.iter().map(|&t| (t & 0xFF) as u8).collect();
        String::from_utf8_lossy(&bytes).into_owned()
    }
}

/// Deterministic synthetic corpus with learnable structure: sentences
/// drawn from templated grammar over a small word bank. A bigram-aware
/// model reaches substantially lower loss than uniform — that gap is what
/// the e2e loss curve demonstrates.
pub fn synthetic_corpus(bytes: usize, seed: u64) -> String {
    const SUBJECTS: &[&str] = &["the pipeline", "a token", "the model", "one stage", "the slice", "a gradient"];
    const VERBS: &[&str] = &["flows through", "depends on", "waits for", "feeds", "updates", "follows"];
    const OBJECTS: &[&str] = &["the next stage", "its context", "the previous tokens", "the buffer", "the schedule", "the optimizer"];
    const TAILS: &[&str] = &["quickly", "in order", "without bubbles", "every step", "as planned", "again"];

    let mut rng = Rng::new(seed);
    let mut out = String::with_capacity(bytes + 64);
    while out.len() < bytes {
        let s = SUBJECTS[rng.below(SUBJECTS.len() as u32) as usize];
        let v = VERBS[rng.below(VERBS.len() as u32) as usize];
        let o = OBJECTS[rng.below(OBJECTS.len() as u32) as usize];
        out.push_str(s);
        out.push(' ');
        out.push_str(v);
        out.push(' ');
        out.push_str(o);
        if rng.below(2) == 0 {
            out.push(' ');
            out.push_str(TAILS[rng.below(TAILS.len() as u32) as usize]);
        }
        out.push_str(". ");
    }
    out.truncate(bytes);
    out
}

/// A (tokens, targets) training batch: `tokens[b][t]`'s target is the next
/// byte. Both are `batch × seq_len`, row-major flattened for the runtime.
#[derive(Debug, Clone)]
pub struct Batch {
    pub tokens: Vec<i32>,
    pub targets: Vec<i32>,
    pub batch: usize,
    pub seq_len: usize,
}

/// Deterministic batcher over an encoded corpus: samples `batch` windows
/// of `seq_len + 1` bytes per step.
pub struct Batcher {
    corpus: Vec<u32>,
    batch: usize,
    seq_len: usize,
    rng: Rng,
}

impl Batcher {
    pub fn new(text: &str, batch: usize, seq_len: usize, seed: u64) -> Self {
        let corpus = ByteTokenizer::encode(text);
        assert!(
            corpus.len() > seq_len + 1,
            "corpus ({} bytes) shorter than seq_len {}",
            corpus.len(),
            seq_len
        );
        Batcher {
            corpus,
            batch,
            seq_len,
            rng: Rng::new(seed),
        }
    }

    pub fn next_batch(&mut self) -> Batch {
        let mut tokens = Vec::with_capacity(self.batch * self.seq_len);
        let mut targets = Vec::with_capacity(self.batch * self.seq_len);
        let span = (self.corpus.len() - self.seq_len - 1) as u32;
        for _ in 0..self.batch {
            let start = self.rng.below(span) as usize;
            for t in 0..self.seq_len {
                tokens.push(self.corpus[start + t] as i32);
                targets.push(self.corpus[start + t + 1] as i32);
            }
        }
        Batch {
            tokens,
            targets,
            batch: self.batch,
            seq_len: self.seq_len,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tokenizer_roundtrip_ascii() {
        let text = "terapipe slices tokens";
        let toks = ByteTokenizer::encode(text);
        assert_eq!(ByteTokenizer::decode(&toks), text);
        assert!(toks.iter().all(|&t| t < ByteTokenizer::VOCAB));
    }

    #[test]
    fn corpus_deterministic_and_sized() {
        let a = synthetic_corpus(4096, 7);
        let b = synthetic_corpus(4096, 7);
        let c = synthetic_corpus(4096, 8);
        assert_eq!(a, b);
        assert_ne!(a, c);
        assert_eq!(a.len(), 4096);
        assert!(a.contains("the pipeline"));
    }

    #[test]
    fn batcher_shapes_and_next_byte_targets() {
        let text = synthetic_corpus(8192, 1);
        let mut b = Batcher::new(&text, 4, 32, 9);
        let batch = b.next_batch();
        assert_eq!(batch.tokens.len(), 4 * 32);
        assert_eq!(batch.targets.len(), 4 * 32);
        // target[t] == token[t+1] within each row
        for row in 0..4 {
            for t in 0..31 {
                assert_eq!(batch.targets[row * 32 + t], batch.tokens[row * 32 + t + 1]);
            }
        }
    }

    #[test]
    fn batcher_deterministic_per_seed() {
        let text = synthetic_corpus(8192, 1);
        let mut b1 = Batcher::new(&text, 2, 16, 5);
        let mut b2 = Batcher::new(&text, 2, 16, 5);
        assert_eq!(b1.next_batch().tokens, b2.next_batch().tokens);
    }

    #[test]
    #[should_panic(expected = "shorter than seq_len")]
    fn batcher_rejects_tiny_corpus() {
        Batcher::new("tiny", 1, 128, 0);
    }

    #[test]
    fn tokens_in_vocab_range() {
        let text = synthetic_corpus(2048, 3);
        let mut b = Batcher::new(&text, 2, 64, 0);
        let batch = b.next_batch();
        assert!(batch.tokens.iter().all(|&t| (0..256).contains(&t)));
    }
}
