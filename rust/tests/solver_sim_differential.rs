//! Solver-vs-simulator differential harness: every solver variant's
//! *predicted* Eq. 5 latency must equal the discrete-event simulator's
//! makespan when its plan is replayed as a pipeline.
//!
//! The replay regime is the one where Eq. 5 is exact (and where the sim
//! suite already pins `forward_chain_matches_eq5`): every stage runs the
//! same stream of slice stage-times `t_i = t(l_i, ctx_i) + t_comm(l_i)`
//! (Eq. 4's computation + transmission folded into the item duration, no
//! extra edge delay), so the simulated makespan is
//! `Σ t_i + (K-1)·max t_i` — independently re-deriving the objective the
//! DPs optimize. A solver that mis-reports `latency_ms` (stale totals,
//! double-counted bubble, budget-vs-achieved `t_max` confusion) diverges
//! from the replay and fails here within 1e-9.

use terapipe::perfmodel::CostModel;
use terapipe::sim::engine::simulate;
use terapipe::sim::{Item, Phase, Plan};
use terapipe::solver::bucketed::solve_tokens_bucketed;
use terapipe::solver::dp::solve_tokens;
use terapipe::solver::joint::{solve_joint, solve_joint_exact, JointOpts};
use terapipe::solver::uniform::uniform_scheme;
use terapipe::solver::JointScheme;
use terapipe::util::prop;

/// Random affine-with-context cost model drawn per case (same family as
/// the other solver property suites; kept at ms scale so the 1e-9
/// absolute tolerance is ~1e4 ulps of slack).
#[derive(Clone)]
struct RandModel {
    over: f64,
    lin: f64,
    ctx: f64,
    comm: f64,
    scale: f64,
    b: u32,
}

impl CostModel for RandModel {
    fn t(&self, i: u32, j: u32) -> f64 {
        let f = 1.0 + self.scale * (self.b as f64 - 1.0);
        f * (self.over + self.lin * i as f64 + self.ctx * i as f64 * j as f64)
    }
    fn t_comm(&self, _i: u32) -> f64 {
        self.comm * self.b as f64
    }
}

fn random_model(g: &mut prop::Gen) -> RandModel {
    RandModel {
        over: g.float(0.01, 2.0),
        lin: g.float(0.001, 0.1),
        ctx: g.float(0.0, 3e-4),
        comm: g.float(0.0, 0.3),
        scale: g.float(0.1, 1.0),
        b: 1,
    }
}

/// Replay a stream of per-slice stage times through the discrete-event
/// engine: a K-stage pipeline where every stage executes the same slice
/// stream in order (slice i on stage k depends on slice i on stage k-1
/// and slice i-1 on stage k). Returns the simulated makespan.
fn replay_stream(durs: &[f64], stages: usize) -> f64 {
    assert!(!durs.is_empty() && stages >= 1);
    let m = durs.len();
    let mut items = Vec::with_capacity(m * stages);
    for s in 0..stages {
        for (i, &d) in durs.iter().enumerate() {
            let mut deps = Vec::new();
            if s > 0 {
                deps.push(((s - 1) * m + i, 0.0));
            }
            if i > 0 {
                deps.push((s * m + i - 1, 0.0));
            }
            items.push(Item {
                id: s * m + i,
                stage: s,
                phase: Phase::Fwd,
                part: 0,
                slice: i,
                dur_ms: d,
                deps,
                priority: (s * m + i) as u64,
            });
        }
    }
    simulate(&Plan {
        stages,
        items,
        mem_cap_parts: None,
        flush_barrier: false,
    })
    .expect("replay plan has no cap/barrier, cannot deadlock")
    .makespan_ms
}

/// Slice stage times of a single-part token scheme under `model`.
fn stream_of_lens<M: CostModel>(model: &M, lens: &[u32]) -> Vec<f64> {
    let mut ctx = 0u32;
    let mut durs = Vec::with_capacity(lens.len());
    for &l in lens {
        durs.push(model.t(l, ctx) + model.t_comm(l));
        ctx += l;
    }
    durs
}

/// Concatenated slice stream of a joint plan, in execution order, each
/// part under its own microbatch model.
fn stream_of_joint<M: CostModel>(model_for: &dyn Fn(u32) -> M, plan: &JointScheme) -> Vec<f64> {
    let mut durs = Vec::new();
    for (b, scheme) in &plan.parts {
        durs.extend(stream_of_lens(&model_for(*b), &scheme.lens));
    }
    durs
}

/// (a) Token DP (§3.3): the solver's reported latency equals the replayed
/// pipeline makespan of its scheme.
#[test]
fn prop_dp_solver_matches_simulated_replay() {
    prop::run_cases(60, |g| {
        let m = random_model(g);
        let gran = *g.choose(&[8u32, 16, 32]);
        let l = g.int(2, 14) * gran;
        let k = g.int(1, 16);
        let eps = *g.choose(&[0.0f64, 0.1]);
        let (scheme, _) = solve_tokens(&m, l, k, gran, eps);
        let sim = replay_stream(&stream_of_lens(&m, &scheme.lens), k as usize);
        assert!(
            (sim - scheme.latency_ms).abs() < 1e-9,
            "case {}: dp predicted {} vs simulated {sim}",
            g.case,
            scheme.latency_ms
        );
    });
}

/// (b) Uniform baseline: same contract for every slice count.
#[test]
fn prop_uniform_scheme_matches_simulated_replay() {
    prop::run_cases(60, |g| {
        let m = random_model(g);
        let gran = 8u32;
        let l = g.int(2, 16) * gran;
        let k = g.int(1, 12);
        let n = g.int(1, l / gran);
        let u = uniform_scheme(&m, l, k, n, gran);
        let sim = replay_stream(&stream_of_lens(&m, &u.lens), k as usize);
        assert!(
            (sim - u.latency_ms).abs() < 1e-9,
            "case {}: uniform predicted {} vs simulated {sim}",
            g.case,
            u.latency_ms
        );
    });
}

/// (c) Bucketed DP: when the bucket set can compose the sequence, its
/// reported latency replays exactly too.
#[test]
fn prop_bucketed_solver_matches_simulated_replay() {
    prop::run_cases(60, |g| {
        let m = random_model(g);
        let l = g.int(2, 12) * 16;
        let k = g.int(1, 12);
        let buckets = [16u32, 32, 64];
        if let Some((scheme, _)) = solve_tokens_bucketed(&m, l, k, &buckets, 0.0) {
            let sim = replay_stream(&stream_of_lens(&m, &scheme.lens), k as usize);
            assert!(
                (sim - scheme.latency_ms).abs() < 1e-9,
                "case {}: bucketed predicted {} vs simulated {sim}",
                g.case,
                scheme.latency_ms
            );
        }
    });
}

/// (d) Joint solvers (§3.4): both the exact global-t_max search and the
/// corrected two-phase reduction replay to their reported latency. This is
/// the test that catches a double-counted bubble term — a plan whose
/// reported latency charges (K-1)·t_max once per part simulates strictly
/// faster than predicted.
#[test]
fn prop_joint_solvers_match_simulated_replay() {
    prop::run_cases(40, |g| {
        let base = random_model(g);
        let gran = *g.choose(&[8u32, 16]);
        let l = g.int(2, 10) * gran;
        let k = g.int(1, 12);
        let batch = g.int(1, 5);
        let b_cap = g.int(1, 3).min(batch);
        let eps = *g.choose(&[0.0f64, 0.1]);
        let opts = JointOpts {
            granularity: gran,
            eps_ms: eps,
            max_microbatch: Some(b_cap),
        };
        let mk = |b: u32| RandModel { b, ..base.clone() };

        let exact = solve_joint_exact(&mk, batch, l, k, &opts);
        let sim = replay_stream(&stream_of_joint(&mk, &exact), k as usize);
        assert!(
            (sim - exact.latency_ms).abs() < 1e-9,
            "case {}: joint-exact predicted {} vs simulated {sim}",
            g.case,
            exact.latency_ms
        );

        let reduction = solve_joint(&mk, batch, l, k, &opts);
        let sim = replay_stream(&stream_of_joint(&mk, &reduction), k as usize);
        assert!(
            (sim - reduction.latency_ms).abs() < 1e-9,
            "case {}: joint-reduction predicted {} vs simulated {sim}",
            g.case,
            reduction.latency_ms
        );
    });
}
