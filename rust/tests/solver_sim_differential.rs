//! Solver-vs-simulator differential harness: every solver variant's
//! *predicted* Eq. 5 latency must equal the discrete-event simulator's
//! makespan when its plan is replayed as a pipeline.
//!
//! The replay regime is the one where Eq. 5 is exact (and where the sim
//! suite already pins `forward_chain_matches_eq5`): every stage runs the
//! same stream of slice stage-times `t_i = t(l_i, ctx_i) + t_comm(l_i)`
//! (Eq. 4's computation + transmission folded into the item duration, no
//! extra edge delay), so the simulated makespan is
//! `Σ t_i + (K-1)·max t_i` — independently re-deriving the objective the
//! DPs optimize. A solver that mis-reports `latency_ms` (stale totals,
//! double-counted bubble, budget-vs-achieved `t_max` confusion) diverges
//! from the replay and fails here within 1e-9.
//!
//! Replays run on the batched fast path: each property test first solves
//! all of its cases (collecting one replay [`Plan`] per prediction), then
//! fans the whole batch through `sim::engine::simulate_many` with trace
//! collection off — regular replay plans take the closed-form wavefront
//! evaluator, and the fan-out reuses one `SimArena` per rayon worker.
//! `prop::run_cases` still reports the failing solve case; replay
//! divergences carry the case id through [`ReplayCase`].

use terapipe::perfmodel::CostModel;
use terapipe::sim::engine::simulate_many;
use terapipe::sim::schedule::stream_plan;
use terapipe::sim::Plan;
use terapipe::solver::bucketed::solve_tokens_bucketed;
use terapipe::solver::dp::solve_tokens;
use terapipe::solver::joint::{solve_joint, solve_joint_exact, JointOpts};
use terapipe::solver::uniform::uniform_scheme;
use terapipe::solver::JointScheme;
use terapipe::util::prop;

/// Random affine-with-context cost model drawn per case (same family as
/// the other solver property suites; kept at ms scale so the 1e-9
/// absolute tolerance is ~1e4 ulps of slack).
#[derive(Clone)]
struct RandModel {
    over: f64,
    lin: f64,
    ctx: f64,
    comm: f64,
    scale: f64,
    b: u32,
}

impl CostModel for RandModel {
    fn t(&self, i: u32, j: u32) -> f64 {
        let f = 1.0 + self.scale * (self.b as f64 - 1.0);
        f * (self.over + self.lin * i as f64 + self.ctx * i as f64 * j as f64)
    }
    fn t_comm(&self, _i: u32) -> f64 {
        self.comm * self.b as f64
    }
}

fn random_model(g: &mut prop::Gen) -> RandModel {
    RandModel {
        over: g.float(0.01, 2.0),
        lin: g.float(0.001, 0.1),
        ctx: g.float(0.0, 3e-4),
        comm: g.float(0.0, 0.3),
        scale: g.float(0.1, 1.0),
        b: 1,
    }
}

/// One solver prediction awaiting its batched replay.
struct ReplayCase {
    case: u64,
    label: &'static str,
    predicted_ms: f64,
}

/// Fan the collected plans through `simulate_many` (no trace) and check
/// every simulated makespan against its solver's prediction.
fn assert_replays(cases: &[ReplayCase], plans: &[Plan]) {
    assert_eq!(cases.len(), plans.len());
    let sims = simulate_many(plans, false);
    for (c, r) in cases.iter().zip(sims) {
        let sim = r
            .unwrap_or_else(|e| panic!("case {}: {} replay failed to simulate: {e}", c.case, c.label))
            .makespan_ms;
        assert!(
            (sim - c.predicted_ms).abs() < 1e-9,
            "case {}: {} predicted {} vs simulated {sim}",
            c.case,
            c.label,
            c.predicted_ms
        );
    }
}

/// Slice stage times of a single-part token scheme under `model`.
fn stream_of_lens<M: CostModel>(model: &M, lens: &[u32]) -> Vec<f64> {
    let mut ctx = 0u32;
    let mut durs = Vec::with_capacity(lens.len());
    for &l in lens {
        durs.push(model.t(l, ctx) + model.t_comm(l));
        ctx += l;
    }
    durs
}

/// Concatenated slice stream of a joint plan, in execution order, each
/// part under its own microbatch model.
fn stream_of_joint<M: CostModel>(model_for: &dyn Fn(u32) -> M, plan: &JointScheme) -> Vec<f64> {
    let mut durs = Vec::new();
    for (b, scheme) in &plan.parts {
        durs.extend(stream_of_lens(&model_for(*b), &scheme.lens));
    }
    durs
}

/// (a) Token DP (§3.3): the solver's reported latency equals the replayed
/// pipeline makespan of its scheme.
#[test]
fn prop_dp_solver_matches_simulated_replay() {
    let mut cases = Vec::new();
    let mut plans = Vec::new();
    prop::run_cases(60, |g| {
        let m = random_model(g);
        let gran = *g.choose(&[8u32, 16, 32]);
        let l = g.int(2, 14) * gran;
        let k = g.int(1, 16);
        let eps = *g.choose(&[0.0f64, 0.1]);
        let (scheme, _) = solve_tokens(&m, l, k, gran, eps);
        cases.push(ReplayCase { case: g.case, label: "dp", predicted_ms: scheme.latency_ms });
        plans.push(stream_plan(&stream_of_lens(&m, &scheme.lens), k as usize));
    });
    assert_replays(&cases, &plans);
}

/// (b) Uniform baseline: same contract for every slice count.
#[test]
fn prop_uniform_scheme_matches_simulated_replay() {
    let mut cases = Vec::new();
    let mut plans = Vec::new();
    prop::run_cases(60, |g| {
        let m = random_model(g);
        let gran = 8u32;
        let l = g.int(2, 16) * gran;
        let k = g.int(1, 12);
        let n = g.int(1, l / gran);
        let u = uniform_scheme(&m, l, k, n, gran);
        cases.push(ReplayCase { case: g.case, label: "uniform", predicted_ms: u.latency_ms });
        plans.push(stream_plan(&stream_of_lens(&m, &u.lens), k as usize));
    });
    assert_replays(&cases, &plans);
}

/// (c) Bucketed DP: when the bucket set can compose the sequence, its
/// reported latency replays exactly too.
#[test]
fn prop_bucketed_solver_matches_simulated_replay() {
    let mut cases = Vec::new();
    let mut plans = Vec::new();
    prop::run_cases(60, |g| {
        let m = random_model(g);
        let l = g.int(2, 12) * 16;
        let k = g.int(1, 12);
        let buckets = [16u32, 32, 64];
        if let Some((scheme, _)) = solve_tokens_bucketed(&m, l, k, &buckets, 0.0) {
            cases.push(ReplayCase {
                case: g.case,
                label: "bucketed",
                predicted_ms: scheme.latency_ms,
            });
            plans.push(stream_plan(&stream_of_lens(&m, &scheme.lens), k as usize));
        }
    });
    assert_replays(&cases, &plans);
}

/// (d) Joint solvers (§3.4): both the exact global-t_max search and the
/// corrected two-phase reduction replay to their reported latency. This is
/// the test that catches a double-counted bubble term — a plan whose
/// reported latency charges (K-1)·t_max once per part simulates strictly
/// faster than predicted.
#[test]
fn prop_joint_solvers_match_simulated_replay() {
    let mut cases = Vec::new();
    let mut plans = Vec::new();
    prop::run_cases(40, |g| {
        let base = random_model(g);
        let gran = *g.choose(&[8u32, 16]);
        let l = g.int(2, 10) * gran;
        let k = g.int(1, 12);
        let batch = g.int(1, 5);
        let b_cap = g.int(1, 3).min(batch);
        let eps = *g.choose(&[0.0f64, 0.1]);
        let opts = JointOpts {
            granularity: gran,
            eps_ms: eps,
            max_microbatch: Some(b_cap),
        };
        let mk = |b: u32| RandModel { b, ..base.clone() };

        let exact = solve_joint_exact(&mk, batch, l, k, &opts);
        cases.push(ReplayCase {
            case: g.case,
            label: "joint-exact",
            predicted_ms: exact.latency_ms,
        });
        plans.push(stream_plan(&stream_of_joint(&mk, &exact), k as usize));

        let reduction = solve_joint(&mk, batch, l, k, &opts);
        cases.push(ReplayCase {
            case: g.case,
            label: "joint-reduction",
            predicted_ms: reduction.latency_ms,
        });
        plans.push(stream_plan(&stream_of_joint(&mk, &reduction), k as usize));
    });
    assert_replays(&cases, &plans);
}
