//! Integration tests over the threaded pipeline on the **native CPU
//! backend** — they run in the default build, no artifacts, no PJRT.
//!
//! The load-bearing test is `pipelined_training_is_slicing_invariant`:
//! the paper's synchronous-training claim means the *schedule* must not
//! change the math — any token slicing, pipelined across stages, must
//! produce the same losses as any other. (The gradient-level version of
//! the claim — sliced backward bit-matching the unsliced oracle before
//! the optimizer — is pinned in `tests/backend_equivalence.rs`; loss
//! curves after Adam tolerate slightly more because near-zero gradients
//! make the first bias-corrected step sign-like.)
//!
//! Also here: the drift-gated replan loop (ROADMAP "planner on the real
//! runtime") — live samples routed through `planner::drift::DriftDetector`
//! so drift-free steps trigger **zero** re-solves.

use std::collections::HashMap;
use std::path::PathBuf;

use terapipe::backend::{BackendSpec, NativeSpec};
use terapipe::coordinator::{TrainConfig, Trainer};
use terapipe::data::{synthetic_corpus, Batcher};
use terapipe::perfmodel::{CostModel, ScaledModel};
use terapipe::planner::drift::DriftConfig;
use terapipe::runtime::manifest::ModelDims;

fn tiny_spec() -> NativeSpec {
    NativeSpec::new(
        ModelDims {
            vocab: 256, // byte-level corpus
            hidden: 16,
            num_heads: 2,
            layers_per_stage: 1,
            num_stages: 2,
            seq_len: 16,
            batch: 2,
            block_ctx: 4,
            seed: 3,
        },
        4,
    )
}

fn cfg_for(slicing: Vec<usize>, steps: usize, microbatches: usize) -> TrainConfig {
    TrainConfig {
        slicing,
        microbatches,
        steps,
        lr: 1e-2,
        seed: 42,
        ..Default::default()
    }
}

fn run_training(slicing: Vec<usize>, steps: usize, microbatches: usize) -> Vec<f64> {
    let mut t = Trainer::with_spec(tiny_spec(), cfg_for(slicing, steps, microbatches)).unwrap();
    let m = t.model.clone();
    let corpus = synthetic_corpus(1 << 13, 7);
    let mut batcher = Batcher::new(&corpus, m.batch, m.seq_len, 42);
    let reports = t.train(|| batcher.next_batch(), |_| {}).unwrap();
    reports.iter().map(|r| r.loss).collect()
}

/// The paper's central correctness claim, end to end on the real threaded
/// pipeline: losses are identical (fp32 tolerance) whatever the slicing.
/// This is a multi-stage, multi-slice pipelined step matching the
/// unsliced oracle (slicing `[L]`) in the default build.
#[test]
fn pipelined_training_is_slicing_invariant() {
    let unsliced = run_training(vec![16], 3, 1);
    let sliced = run_training(vec![8, 4, 4], 3, 1);
    let uniform = run_training(vec![4, 4, 4, 4], 3, 1);
    for (a, b) in unsliced.iter().zip(&sliced) {
        assert!((a - b).abs() < 1e-3, "unsliced {a} vs sliced {b}");
    }
    for (a, b) in unsliced.iter().zip(&uniform) {
        assert!((a - b).abs() < 1e-3, "unsliced {a} vs uniform {b}");
    }
}

/// Gradient accumulation across microbatches composes with slicing.
#[test]
fn microbatched_training_is_slicing_invariant() {
    let a = run_training(vec![16], 2, 2);
    let b = run_training(vec![8, 8], 2, 2);
    for (x, y) in a.iter().zip(&b) {
        assert!((x - y).abs() < 1e-3, "{x} vs {y}");
    }
}

/// Smoke: loss decreases on the synthetic corpus within a few steps —
/// gradients point downhill through the whole pipelined stack.
#[test]
fn pipelined_training_reduces_loss() {
    let losses = run_training(vec![8, 8], 8, 1);
    let first = losses[0];
    let last = *losses.last().unwrap();
    assert!(
        last < first - 0.05,
        "loss did not decrease: {first} -> {last} ({losses:?})"
    );
    // byte-level LM starts near ln(256) ≈ 5.55
    assert!(first > 3.0 && first < 7.0, "implausible initial loss {first}");
}

/// Config validation surfaces bad slicings before any thread spawns.
#[test]
fn trainer_rejects_invalid_slicing() {
    // 5 + 11 = 16 but neither is a granularity-4 bucket
    assert!(Trainer::with_spec(tiny_spec(), cfg_for(vec![5, 11], 1, 1)).is_err());
    // buckets, but wrong sum
    assert!(Trainer::with_spec(tiny_spec(), cfg_for(vec![8, 4], 1, 1)).is_err());
    assert!(Trainer::with_spec(tiny_spec(), cfg_for(vec![], 1, 1)).is_err());
}

/// Checkpoint → resume reproduces the exact training trajectory: train 2
/// steps, save; a fresh trainer resumed from the checkpoint continues
/// with the same losses a 4-step uninterrupted run sees at steps 3–4.
#[test]
fn checkpoint_resume_continues_trajectory() {
    let corpus = synthetic_corpus(1 << 13, 7);
    let m = tiny_spec().model();

    // uninterrupted 4-step reference
    let mut t = Trainer::with_spec(tiny_spec(), cfg_for(vec![8, 8], 4, 1)).unwrap();
    let mut b = Batcher::new(&corpus, m.batch, m.seq_len, 42);
    let full: Vec<f64> = t
        .train(|| b.next_batch(), |_| {})
        .unwrap()
        .iter()
        .map(|r| r.loss)
        .collect();
    drop(t);

    // 2 steps → checkpoint
    let ckpt = tempdir("resume");
    let mut t1 = Trainer::with_spec(tiny_spec(), cfg_for(vec![8, 8], 2, 1)).unwrap();
    let mut b1 = Batcher::new(&corpus, m.batch, m.seq_len, 42);
    t1.train(|| b1.next_batch(), |_| {}).unwrap();
    t1.save_checkpoint(&ckpt).unwrap();
    drop(t1);

    // resume for 2 more steps, feeding the same batch stream continuation
    let mut t2 =
        Trainer::with_spec_resume(tiny_spec(), cfg_for(vec![8, 8], 2, 1), Some(ckpt.clone()))
            .unwrap();
    let mut b2 = Batcher::new(&corpus, m.batch, m.seq_len, 42);
    b2.next_batch();
    b2.next_batch(); // skip the two consumed batches
    let resumed: Vec<f64> = t2
        .train(|| b2.next_batch(), |_| {})
        .unwrap()
        .iter()
        .map(|r| r.loss)
        .collect();

    // Full state (params + Adam moments + step counter) is checkpointed
    // and the native backend is deterministic, so the resumed trajectory
    // is exact to fp32 noise.
    assert!((resumed[0] - full[2]).abs() < 1e-6, "{} vs {}", resumed[0], full[2]);
    assert!((resumed[1] - full[3]).abs() < 1e-6, "{} vs {}", resumed[1], full[3]);
    let _ = std::fs::remove_dir_all(&ckpt);
}

/// Timing collection: with `trace` on, every (stage, slice) reports one
/// Fwd and one Bwd sample per step, and the forward-sweep makespan is
/// recorded.
#[test]
fn trace_collects_per_slice_timings() {
    let mut cfg = cfg_for(vec![8, 4, 4], 2, 1);
    cfg.trace = true;
    let mut t = Trainer::with_spec(tiny_spec(), cfg).unwrap();
    let m = t.model.clone();
    let corpus = synthetic_corpus(1 << 13, 7);
    let mut batcher = Batcher::new(&corpus, m.batch, m.seq_len, 42);
    let reports = t.train(|| batcher.next_batch(), |_| {}).unwrap();
    // 2 stages × 3 slices × 2 phases from the final step
    assert_eq!(t.last_timings().len(), 12, "{:?}", t.last_timings());
    assert!(t.last_timings().iter().all(|s| s.ms >= 0.0));
    assert!(reports.iter().all(|r| r.fwd_ms > 0.0 && r.fwd_ms <= r.wall_ms));
}

// ---------------------------------------------------------------------------
// Drift-gated replanning (ROADMAP: "planner on the real runtime")
// ---------------------------------------------------------------------------

/// Cost model tabulated from observed samples: median ms per (i, j).
struct MedianModel(HashMap<(u32, u32), f64>);

impl MedianModel {
    /// Warm up the real pipeline for a few steps and tabulate the
    /// observed stage-0 fwd+bwd latency per (slice len, context len).
    fn from_warmup(slicing: Vec<usize>, steps: usize) -> MedianModel {
        let mut cfg = cfg_for(slicing, steps, 1);
        cfg.trace = true;
        let mut t = Trainer::with_spec(tiny_spec(), cfg).unwrap();
        let m = t.model.clone();
        let corpus = synthetic_corpus(1 << 13, 7);
        let mut batcher = Batcher::new(&corpus, m.batch, m.seq_len, 42);
        let mut samples: HashMap<(u32, u32), Vec<f64>> = HashMap::new();
        for _ in 0..steps {
            let batches: Vec<_> = (0..1).map(|_| batcher.next_batch()).collect();
            t.step(&batches).unwrap();
            let timings = t.last_timings().to_vec();
            for f in timings.iter().filter(|s| {
                s.stage == 0 && s.phase == terapipe::coordinator::TimedPhase::Fwd
            }) {
                let bwd = timings
                    .iter()
                    .find(|s| {
                        s.stage == 0
                            && s.phase == terapipe::coordinator::TimedPhase::Bwd
                            && s.mb == f.mb
                            && s.slice == f.slice
                    })
                    .map(|s| s.ms)
                    .unwrap_or(0.0);
                samples
                    .entry((f.len as u32, f.off as u32))
                    .or_default()
                    .push(f.ms + bwd);
            }
        }
        let med = samples
            .into_iter()
            .map(|(k, mut v)| {
                v.sort_by(|a, b| a.partial_cmp(b).unwrap());
                (k, v[v.len() / 2])
            })
            .collect();
        MedianModel(med)
    }
}

impl CostModel for MedianModel {
    fn t(&self, i: u32, j: u32) -> f64 {
        *self.0.get(&(i, j)).expect("sample for every (i, j) the slicing produces")
    }
}

/// Drift-free execution must trigger **zero** re-solves: the live samples
/// agree with the solved-against model, so every cadence check lands on
/// `Stable` and the re-measure/re-solve is never paid.
#[test]
fn drift_free_steps_trigger_zero_resolves() {
    let model = MedianModel::from_warmup(vec![8, 4, 4], 3);
    let mut cfg = cfg_for(vec![8, 4, 4], 6, 1);
    cfg.replan_every = Some(2);
    let mut t = Trainer::with_spec(tiny_spec(), cfg).unwrap();
    let m = t.model.clone();
    let corpus = synthetic_corpus(1 << 13, 7);
    let mut batcher = Batcher::new(&corpus, m.batch, m.seq_len, 42);
    let mut resolve_calls = 0usize;
    let (_, report) = t
        .train_with_drift_replan(
            || batcher.next_batch(),
            |_| {},
            model,
            // generous threshold: scheduler noise on a shared box must not
            // masquerade as drift (mean rel err ≤ 1.0 ⇒ within 2×)
            DriftConfig { window: 6, rel_threshold: 1.0 },
            |_, _| {
                resolve_calls += 1;
                None
            },
        )
        .unwrap();
    assert_eq!(report.resolves, 0, "{report:?}");
    assert_eq!(resolve_calls, 0);
    assert!(report.stable_checks >= 1, "{report:?}");
    assert!(report.samples_seen >= 6, "{report:?}");
}

/// A genuinely wrong solved-against model (8× too fast) must be caught by
/// the window verdict and pay exactly the gated re-solve path.
#[test]
fn drifted_model_triggers_resolve() {
    let model = MedianModel::from_warmup(vec![8, 4, 4], 3);
    let wrong = ScaledModel { inner: model, compute: 0.125, comm: 0.125 };
    // 4 steps with cadence 2 ⇒ exactly one full-window cadence check
    let mut cfg = cfg_for(vec![8, 4, 4], 4, 1);
    cfg.replan_every = Some(2);
    let mut t = Trainer::with_spec(tiny_spec(), cfg).unwrap();
    let m = t.model.clone();
    let corpus = synthetic_corpus(1 << 13, 7);
    let mut batcher = Batcher::new(&corpus, m.batch, m.seq_len, 42);
    let mut resolve_calls = 0usize;
    let (_, report) = t
        .train_with_drift_replan(
            || batcher.next_batch(),
            |_| {},
            wrong,
            DriftConfig { window: 6, rel_threshold: 1.0 },
            |_, factor| {
                resolve_calls += 1;
                assert!(factor > 2.0, "fitted rescale factor {factor} should be ≈8");
                Some(vec![4, 4, 4, 4]) // adopt a valid new slicing
            },
        )
        .unwrap();
    assert!(report.resolves >= 1, "{report:?}");
    assert_eq!(resolve_calls, report.resolves);
    // the returned slicing was adopted
    assert_eq!(t.config().slicing, vec![4, 4, 4, 4]);
}

fn tempdir(tag: &str) -> PathBuf {
    let d = std::env::temp_dir().join(format!("terapipe-{tag}-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&d);
    std::fs::create_dir_all(&d).unwrap();
    d
}
