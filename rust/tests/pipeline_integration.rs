//! Integration tests over the real AOT artifacts (skipped with a notice
//! if `make artifacts` hasn't run).
//!
//! The load-bearing test is `pipelined_training_is_slicing_invariant`: the
//! paper's synchronous-training claim means the *schedule* must not change
//! the math — any token slicing, pipelined across stages, must produce the
//! same losses and the same updated parameters as any other.
//!
//! The whole file is compiled only with the `pjrt` feature (the PJRT
//! runtime binds the `xla` crate, which the default build omits).
#![cfg(feature = "pjrt")]

use std::path::PathBuf;

use terapipe::coordinator::{Trainer, TrainConfig};
use terapipe::data::{synthetic_corpus, Batcher};
use terapipe::runtime::tensor::HostTensor;
use terapipe::runtime::{stage_exe_names, StageRuntime};

fn artifacts() -> Option<PathBuf> {
    let d = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if d.join("manifest.json").exists() {
        Some(d)
    } else {
        eprintln!("SKIP: artifacts not built (run `make artifacts`)");
        None
    }
}

/// Runtime-level: composing bucketed slices with KV-context writes equals
/// one full-length slice — the token-dimension dependency structure,
/// exercised through the actual PJRT executables and the rust KV
/// bookkeeping (no python anywhere).
#[test]
fn slice_composition_matches_full_forward() {
    let Some(dir) = artifacts() else { return };
    let rt = StageRuntime::load(&dir, &stage_exe_names(0, 2, &[32, 64, 128])).unwrap();
    let m = rt.manifest.model.clone();
    assert_eq!(m.seq_len, 128, "test assumes default artifact geometry");
    let params = rt.manifest.load_init(&rt.manifest.init_stages[0]).unwrap();

    // deterministic pseudo-random input activation
    let n = m.batch * m.seq_len * m.hidden;
    let h_full: Vec<f32> = (0..n).map(|i| ((i * 2654435761 % 1000) as f32 / 500.0) - 1.0).collect();

    // full pass: one slice of length L, empty context
    let kv = HostTensor::zeros_f32(&m.kv_shape());
    let mut inputs: Vec<HostTensor> = params.clone();
    inputs.push(HostTensor::f32(&[m.batch, 128, m.hidden], h_full.clone()));
    inputs.push(kv.clone());
    inputs.push(kv.clone());
    inputs.push(HostTensor::scalar_i32(0));
    let full = rt.run("stage_fwd_s128", &inputs).unwrap().remove(0);

    // sliced pass: 64 + 32 + 32 with growing context
    let mut k_ctx = HostTensor::zeros_f32(&m.kv_shape());
    let mut v_ctx = HostTensor::zeros_f32(&m.kv_shape());
    let mut outs: Vec<HostTensor> = Vec::new();
    let mut off = 0usize;
    for len in [64usize, 32, 32] {
        let mut h = vec![0f32; m.batch * len * m.hidden];
        for b in 0..m.batch {
            let src = (b * m.seq_len + off) * m.hidden;
            let dst = b * len * m.hidden;
            h[dst..dst + len * m.hidden].copy_from_slice(&h_full[src..src + len * m.hidden]);
        }
        let mut inputs: Vec<HostTensor> = params.clone();
        inputs.push(HostTensor::f32(&[m.batch, len, m.hidden], h));
        inputs.push(k_ctx.clone());
        inputs.push(v_ctx.clone());
        inputs.push(HostTensor::scalar_i32(off as i32));
        let mut out = rt.run(&format!("stage_fwd_s{len}"), &inputs).unwrap();
        let v_new = out.pop().unwrap();
        let k_new = out.pop().unwrap();
        let h_out = out.pop().unwrap();
        k_ctx.write_at_axis(2, off, &k_new);
        v_ctx.write_at_axis(2, off, &v_new);
        outs.push(h_out);
        off += len;
    }

    // compare per-row slices against the full output
    let full_data = full.as_f32();
    let mut max_err = 0f32;
    let mut off = 0usize;
    for (h_out, len) in outs.iter().zip([64usize, 32, 32]) {
        let d = h_out.as_f32();
        for b in 0..m.batch {
            for t in 0..len {
                for c in 0..m.hidden {
                    let got = d[(b * len + t) * m.hidden + c];
                    let want = full_data[(b * m.seq_len + off + t) * m.hidden + c];
                    max_err = max_err.max((got - want).abs());
                }
            }
        }
        off += len;
    }
    assert!(max_err < 2e-4, "slice composition diverged: max err {max_err}");
}

fn run_training(slicing: Vec<usize>, steps: usize, microbatches: usize) -> Vec<f64> {
    let dir = artifacts().unwrap();
    let cfg = TrainConfig {
        slicing,
        microbatches,
        steps,
        lr: 1e-3,
        seed: 42,
        replan_every: None,
    };
    let mut t = Trainer::new(&dir, cfg).unwrap();
    let m = t.manifest.model.clone();
    let corpus = synthetic_corpus(1 << 15, 7);
    let mut batcher = Batcher::new(&corpus, m.batch, m.seq_len, 42);
    let reports = t.train(|| batcher.next_batch(), |_| {}).unwrap();
    reports.iter().map(|r| r.loss).collect()
}

/// The paper's central correctness claim, end to end on the real threaded
/// pipeline: losses are identical (fp32 tolerance) whatever the slicing.
#[test]
fn pipelined_training_is_slicing_invariant() {
    if artifacts().is_none() {
        return;
    }
    let unsliced = run_training(vec![128], 3, 1);
    let sliced = run_training(vec![64, 32, 16, 16], 3, 1);
    let uniform = run_training(vec![32, 32, 32, 32], 3, 1);
    for (a, b) in unsliced.iter().zip(&sliced) {
        assert!((a - b).abs() < 5e-4, "unsliced {a} vs sliced {b}");
    }
    for (a, b) in unsliced.iter().zip(&uniform) {
        assert!((a - b).abs() < 5e-4, "unsliced {a} vs uniform {b}");
    }
}

/// Gradient accumulation across microbatches composes with slicing.
#[test]
fn microbatched_training_is_slicing_invariant() {
    if artifacts().is_none() {
        return;
    }
    let a = run_training(vec![128], 2, 2);
    let b = run_training(vec![64, 64], 2, 2);
    for (x, y) in a.iter().zip(&b) {
        assert!((x - y).abs() < 5e-4, "{x} vs {y}");
    }
}

/// Smoke: loss decreases on the synthetic corpus within a few steps —
/// gradients point downhill through the whole pipelined stack.
#[test]
fn pipelined_training_reduces_loss() {
    if artifacts().is_none() {
        return;
    }
    let losses = run_training(vec![64, 64], 8, 1);
    let first = losses[0];
    let last = *losses.last().unwrap();
    assert!(
        last < first - 0.05,
        "loss did not decrease: {first} -> {last} ({losses:?})"
    );
    // byte-level LM starts near ln(256) ≈ 5.55
    assert!(first > 3.0 && first < 7.0, "implausible initial loss {first}");
}

/// Config validation surfaces bad slicings before any thread spawns.
#[test]
fn trainer_rejects_invalid_slicing() {
    let Some(dir) = artifacts() else { return };
    let bad = TrainConfig {
        slicing: vec![100, 28],
        microbatches: 1,
        steps: 1,
        lr: 1e-3,
        seed: 0,
        replan_every: None,
    };
    assert!(Trainer::new(&dir, bad).is_err());
}

/// Checkpoint → resume reproduces the exact training trajectory: train 2
/// steps, save; fresh trainer resumed from the checkpoint continues with
/// the same losses a 4-step uninterrupted run sees at steps 3–4.
#[test]
fn checkpoint_resume_continues_trajectory() {
    let Some(dir) = artifacts() else { return };
    let corpus = synthetic_corpus(1 << 15, 7);
    let mk_cfg = |steps: usize| TrainConfig {
        slicing: vec![64, 64],
        microbatches: 1,
        steps,
        lr: 1e-3,
        seed: 42,
        replan_every: None,
    };

    // uninterrupted 4-step reference
    let mut t = Trainer::new(&dir, mk_cfg(4)).unwrap();
    let m = t.manifest.model.clone();
    let mut b = Batcher::new(&corpus, m.batch, m.seq_len, 42);
    let full: Vec<f64> = t
        .train(|| b.next_batch(), |_| {})
        .unwrap()
        .iter()
        .map(|r| r.loss)
        .collect();
    drop(t);

    // 2 steps → checkpoint
    let ckpt = tempdir();
    let mut t1 = Trainer::new(&dir, mk_cfg(2)).unwrap();
    let mut b1 = Batcher::new(&corpus, m.batch, m.seq_len, 42);
    t1.train(|| b1.next_batch(), |_| {}).unwrap();
    t1.save_checkpoint(&ckpt).unwrap();
    drop(t1);

    // resume for 2 more steps, feeding the same batch stream continuation
    let mut t2 = Trainer::new_with_resume(&dir, mk_cfg(2), Some(ckpt.clone())).unwrap();
    let mut b2 = Batcher::new(&corpus, m.batch, m.seq_len, 42);
    b2.next_batch();
    b2.next_batch(); // skip the two consumed batches
    let resumed: Vec<f64> = t2
        .train(|| b2.next_batch(), |_| {})
        .unwrap()
        .iter()
        .map(|r| r.loss)
        .collect();

    // Full state (params + Adam moments + step counter) is checkpointed,
    // so the resumed trajectory is exact to fp32 noise.
    assert!((resumed[0] - full[2]).abs() < 1e-6, "{} vs {}", resumed[0], full[2]);
    assert!((resumed[1] - full[3]).abs() < 1e-6, "{} vs {}", resumed[1], full[3]);
    let _ = std::fs::remove_dir_all(&ckpt);
}

fn tempdir() -> PathBuf {
    let d = std::env::temp_dir().join(format!("terapipe-ckpt-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&d);
    std::fs::create_dir_all(&d).unwrap();
    d
}
