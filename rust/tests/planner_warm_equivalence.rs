//! Warm-start equivalence suite: the planner's warm-started, cache-served
//! re-solve must be **bit-identical** — plan and latency, `==` on every
//! f64, no tolerance — to a cold `solve_tokens` call over a freshly
//! densified model at the same cluster state, across randomized sequences
//! of cluster deltas (K changes, bandwidth rescales, per-stage slowdowns,
//! drift-sample batches).
//!
//! This is the contract that makes the online planner safe to trust: no
//! matter how the service arrived at a state — which deltas, in which
//! order, which tables were cached or rescaled, which hints seeded the
//! enumeration — its proposed plan is *exactly* the one a from-scratch
//! solver would produce. The acceptance criterion's 1e-9 sim replay rides
//! on top (`prop_emitted_plans_replay_through_the_simulator`).

use terapipe::perfmodel::{CostModel, ScaledModel};
use terapipe::planner::drift::LatencySample;
use terapipe::planner::{validate, Planner, PlannerConfig, ReplanTrigger};
use terapipe::solver::dp::solve_tokens;
use terapipe::util::prop;

/// Random affine-with-context cost model drawn per case (same family as
/// the solver equivalence suites).
#[derive(Clone)]
struct RandModel {
    over: f64,
    lin: f64,
    ctx: f64,
    comm: f64,
}
impl CostModel for RandModel {
    fn t(&self, i: u32, j: u32) -> f64 {
        self.over + self.lin * i as f64 + self.ctx * i as f64 * j as f64
    }
    fn t_comm(&self, _i: u32) -> f64 {
        self.comm
    }
}

fn random_model(g: &mut prop::Gen) -> RandModel {
    RandModel {
        over: g.float(0.01, 2.0),
        lin: g.float(0.001, 0.1),
        ctx: g.float(0.0, 3e-4),
        comm: g.float(0.0, 0.3),
    }
}

struct Instance {
    model: RandModel,
    seq_len: u32,
    gran: u32,
    eps: f64,
}

fn random_instance(g: &mut prop::Gen) -> Instance {
    let model = random_model(g);
    let gran = *g.choose(&[8u32, 16, 32]);
    Instance {
        model,
        seq_len: g.int(2, 16) * gran,
        gran,
        eps: *g.choose(&[0.0f64, 0.1]),
    }
}

fn planner_for(inst: &Instance, stages: u32, hysteresis: f64) -> Planner<RandModel> {
    Planner::new(
        "rand",
        inst.model.clone(),
        inst.seq_len,
        stages,
        PlannerConfig {
            granularity: inst.gran,
            eps_ms: inst.eps,
            hysteresis_rel: hysteresis,
            ..Default::default()
        },
    )
}

/// Cold reference at an arbitrary cluster state: fresh densification of
/// the scaled model (the exact table the planner's rescale path promises
/// to reproduce bit-for-bit), cold enumeration.
fn cold_solve(
    inst: &Instance,
    stages: u32,
    compute: f64,
    comm: f64,
) -> terapipe::solver::SliceScheme {
    let scaled = ScaledModel { inner: inst.model.clone(), compute, comm };
    let (scheme, _) = solve_tokens(&scaled, inst.seq_len, stages, inst.gran, inst.eps);
    scheme
}

/// (a) The core contract: 120 randomized delta sequences, every decision
/// bit-identical to the cold solve at that state.
#[test]
fn prop_warm_planner_bit_identical_to_cold_across_delta_sequences() {
    prop::run_cases(120, |g| {
        let inst = random_instance(g);
        let mut stages = g.int(1, 24);
        let mut p = planner_for(&inst, stages, 0.02);

        // initial solve
        let got = p.plan().clone();
        let want = cold_solve(&inst, stages, 1.0, 1.0);
        assert_eq!(got.lens, want.lens, "case {} initial", g.case);
        assert!(got.latency_ms == want.latency_ms, "case {} initial", g.case);

        // 3–8 random deltas
        let deltas = g.int(3, 8);
        for step in 0..deltas {
            let d = match g.int(0, 2) {
                0 => {
                    stages = g.int(1, 24);
                    p.on_stages_change(stages)
                }
                1 => p.on_bandwidth_change(g.float(0.25, 4.0)),
                _ => p.on_slowdown(g.float(0.5, 2.0)),
            };
            let (compute, comm) = p.scales();
            let want = cold_solve(&inst, stages, compute, comm);
            assert_eq!(
                d.scheme.lens, want.lens,
                "case {} delta {step} (K={stages}, c={compute}, m={comm})",
                g.case
            );
            assert!(
                d.scheme.total_ms == want.total_ms
                    && d.scheme.t_max_ms == want.t_max_ms
                    && d.scheme.latency_ms == want.latency_ms,
                "case {} delta {step}: warm {:?} vs cold {:?}",
                g.case,
                d.scheme,
                want
            );
            assert!(d.warm.is_some(), "every re-solve after the first is warm");
        }
    });
}

/// (b) Drift path: samples from an undisclosed uniform slowdown trip the
/// detector; the resulting decision is still bit-identical to a cold
/// solve at the fitted scale.
#[test]
fn prop_drift_replans_are_bit_identical_to_cold() {
    prop::run_cases(40, |g| {
        let inst = random_instance(g);
        let stages = g.int(2, 16);
        let mut p = planner_for(&inst, stages, 0.02);
        p.plan();

        let factor = g.float(1.2, 2.0);
        let truth = ScaledModel { inner: inst.model.clone(), compute: factor, comm: factor };
        let n_units = inst.seq_len / inst.gran;
        let mut decision = None;
        for k in 0..64u32 {
            let iu = 1 + (k % n_units.min(6));
            let ju = k % (n_units - iu + 1);
            let (i, j) = (iu * inst.gran, ju * inst.gran);
            let ms = truth.t(i, j) + truth.t_comm(i);
            if let Some(d) = p.on_sample(LatencySample { i, j, ms }) {
                decision = Some(d);
                break;
            }
        }
        let d = decision.expect("a ≥20% uniform slowdown must trip the detector");
        assert_eq!(d.trigger, ReplanTrigger::Drift);
        let (compute, comm) = p.scales();
        let want = cold_solve(&inst, stages, compute, comm);
        assert_eq!(d.scheme.lens, want.lens, "case {}", g.case);
        assert!(d.scheme.latency_ms == want.latency_ms, "case {}", g.case);
    });
}

/// (c) The acceptance criterion's validation leg: every decision's
/// predicted Eq. 5 latency replays through the simulator within 1e-9 at
/// its own cluster state. Replay plans are built per decision (baking in
/// the model snapshot) and fanned through the batched no-trace path.
#[test]
fn prop_emitted_plans_replay_through_the_simulator() {
    prop::run_cases(60, |g| {
        let inst = random_instance(g);
        let mut p = planner_for(&inst, g.int(1, 16), 0.02);
        let first = p.plan().clone();
        let mut plans = vec![validate::replay_plan(&p.current_model(), &first.lens, p.stages())];
        let mut preds = vec![first.latency_ms];
        // factor ranges kept moderate so the cumulative scale never
        // inflates absolute latencies to where f64 accumulation noise
        // could brush the 1e-9 acceptance tolerance
        for _step in 0..g.int(2, 5) {
            let d = match g.int(0, 2) {
                0 => p.on_stages_change(g.int(1, 16)),
                1 => p.on_bandwidth_change(g.float(0.5, 2.0)),
                _ => p.on_slowdown(g.float(0.6, 1.6)),
            };
            plans.push(validate::replay_plan(&p.current_model(), &d.scheme.lens, d.stages));
            preds.push(d.scheme.latency_ms);
        }
        validate::validate_plans(&plans, &preds, 1e-9)
            .unwrap_or_else(|e| panic!("case {}: {e}", g.case));
    });
}

/// (d) Cache behaviour along a delta sequence: exactly one densification
/// per instance, scale-only deltas served by rescales, repeated states by
/// hits.
#[test]
fn cache_serves_repeat_states_without_rebuilding() {
    let mut g = prop::Gen::new(5);
    let inst = random_instance(&mut g);
    let mut p = planner_for(&inst, 8, 0.02);
    p.plan();
    p.on_slowdown(1.5);
    p.on_stages_change(4); // same scales: hits the 1.5 rescale
    p.on_slowdown(1.0 / 1.5); // back to... a *new* cumulative factor bits-wise
    let cs = p.cache_stats();
    assert_eq!(cs.base_misses, 1, "one densification ever: {cs:?}");
    assert!(cs.rescales >= 1, "{cs:?}");
    assert!(cs.scaled_hits >= 1, "{cs:?}");
}

/// (e) Hysteresis: with an sky-high threshold the active plan never
/// churns, yet every decision still reports the cold-identical fresh
/// solve.
#[test]
fn hysteresis_keeps_active_plan_but_decisions_stay_exact() {
    let mut g = prop::Gen::new(9);
    let inst = random_instance(&mut g);
    let mut p = planner_for(&inst, 12, f64::INFINITY);
    let initial = p.plan().clone();
    for factor in [1.5, 0.5, 2.0] {
        let d = p.on_slowdown(factor);
        assert!(!d.switched);
        let (compute, comm) = p.scales();
        let want = cold_solve(&inst, 12, compute, comm);
        assert_eq!(d.scheme.lens, want.lens);
        assert!(d.scheme.latency_ms == want.latency_ms);
    }
    assert_eq!(p.plan().lens, initial.lens, "active plan must not churn");
}
