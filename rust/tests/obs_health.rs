//! The pipeline health observatory, pinned end to end:
//!
//! 1. **Kill-switch run**: a crash-stopped stage walks
//!    Healthy → Suspect → Unhealthy on the driver's health timeline
//!    *before* the recv deadline fails the step — while the surviving
//!    stage's heartbeats keep it Healthy — and the flight recorder's
//!    postmortem bundle parses back (Perfetto trace included) and names
//!    the killed stage.
//! 2. **Heartbeats are invisible to collect loops**: a healthy
//!    heartbeat-enabled run trains normally (no "unexpected message"),
//!    reports all-healthy per-step verdicts, and records no transitions.
//! 3. **Anomaly attribution properties** on the public detector API:
//!    stationary streams never alarm; a planted 4× compute straggler is
//!    named with the right stage; a planted 10 ms link delay is named
//!    comm degradation with the right link.

use std::time::{Duration, Instant};

use terapipe::backend::NativeSpec;
use terapipe::coordinator::transport::NetConfig;
use terapipe::coordinator::{TrainConfig, Trainer, VirtualTransport};
use terapipe::data::{synthetic_corpus, Batch, Batcher};
use terapipe::obs::anomaly::{AnomalyDetector, Cause};
use terapipe::obs::flight::{plan_fingerprint, DumpContext, FlightRecorder};
use terapipe::obs::health::HealthState;
use terapipe::runtime::manifest::ModelDims;
use terapipe::util::json::Json;
use terapipe::util::Rng;

const STAGES: usize = 2;

fn spec() -> NativeSpec {
    NativeSpec::new(
        ModelDims {
            vocab: 64,
            hidden: 32,
            num_heads: 4,
            layers_per_stage: 1,
            num_stages: STAGES,
            seq_len: 32,
            batch: 2,
            block_ctx: 8,
            seed: 9,
        },
        4,
    )
}

fn one_batch(m: &ModelDims) -> Vec<Batch> {
    let corpus = synthetic_corpus(1 << 13, 7);
    let mut b = Batcher::new(&corpus, m.batch, m.seq_len, 17);
    vec![b.next_batch()]
}

// ---------------------------------------------------------------------
// 1. Kill-switch: Suspect → Unhealthy on the timeline + postmortem bundle
// ---------------------------------------------------------------------

#[test]
fn killed_stage_walks_the_timeline_and_the_postmortem_bundle_parses() {
    terapipe::obs::set_enabled(true);

    // Stage 1's inbox delivers exactly its two step-1 forwards, then the
    // Update delivery crash-stops it: the whole step-1 data flow
    // completes deterministically, death lands on the update ack.
    let net = NetConfig::seeded(0).with_kill_after(1, 2);
    let vt = VirtualTransport::new(net);
    let cfg = TrainConfig {
        slicing: vec![16, 16],
        steps: 1,
        seed: 17,
        trace: true,
        // 4 probe sub-intervals of 400 ms: three silent probes take the
        // dead stage to Unhealthy before the deadline fails the step.
        recv_timeout_ms: Some(1600),
        heartbeat_ms: Some(50),
        ..Default::default()
    };
    let mut t = Trainer::with_spec_transport(spec(), cfg, &vt).unwrap();
    let m = t.model.clone();
    let batches = one_batch(&m);

    let t0 = Instant::now();
    let msg = format!("{:#}", t.step(&batches).unwrap_err());
    assert!(msg.contains("update"), "death should land on the update ack: {msg}");
    assert!(t0.elapsed() < Duration::from_secs(20), "not prompt: {:?}", t0.elapsed());

    // ---- the timeline names the killed stage, and only it ----
    let tl = t.health_timeline();
    let s1: Vec<_> = tl.for_stage(1).into_iter().map(|tr| (tr.from, tr.to)).collect();
    assert_eq!(
        s1,
        vec![
            (HealthState::Healthy, HealthState::Suspect),
            (HealthState::Suspect, HealthState::Unhealthy),
        ],
        "stage 1 must walk Suspect → Unhealthy: {tl:?}"
    );
    assert!(
        tl.for_stage(0).is_empty(),
        "heartbeats must keep the surviving stage Healthy: {tl:?}"
    );
    assert_eq!(t.health().codes(), vec![0, 2]);

    // ---- delivery-evidence bridge: the transport's owner drains ----
    // per-link samples into the attributor's comm windows
    let deliveries = vt.take_deliveries();
    assert!(!deliveries.is_empty(), "a completed step must leave delivery samples");
    t.observe_deliveries(&deliveries);

    // ---- flight recorder: record what we have, dump, parse back ----
    let flush = terapipe::obs::flush();
    let mut flight = FlightRecorder::new(4);
    flight.set_fingerprint(plan_fingerprint(&t.config().slicing, &[STAGES as u64]));
    flight.record_step(1, f64::NAN, 0.0, &flush.spans, flush.dropped, &t.health().codes(), &[]);

    let mut reg = terapipe::obs::MetricsRegistry::new();
    terapipe::obs::health::health_metrics(&mut reg, t.health());
    let metrics_text = reg.render();
    let final_health = t.health().codes();
    let ctx = DumpContext {
        reason: &format!("training failed: {msg}"),
        slicing: &t.config().slicing,
        stages: STAGES,
        metrics_text: &metrics_text,
        timeline: t.health_timeline(),
        final_health: &final_health,
        predicted: &[],
    };
    let dir = std::env::temp_dir().join(format!("terapipe-postmortem-{}", std::process::id()));
    let files = flight.dump(&dir, &ctx).unwrap();
    assert_eq!(
        files,
        vec!["trace.json", "metrics.prom", "health.json", "report.txt", "manifest.json"]
    );

    // the Perfetto trace parses back and carries real spans
    let trace = Json::parse(&std::fs::read_to_string(dir.join("trace.json")).unwrap()).unwrap();
    let events = trace.get("traceEvents").and_then(|e| e.as_arr()).expect("traceEvents array");
    assert!(!events.is_empty(), "a traced kill run must retain spans");

    // health.json names the killed stage as unhealthy
    let health = Json::parse(&std::fs::read_to_string(dir.join("health.json")).unwrap()).unwrap();
    let timeline = health.get("timeline").and_then(|v| v.as_arr()).expect("timeline array");
    assert!(
        timeline.iter().any(|e| {
            e.get("stage").and_then(|s| s.as_f64()) == Some(1.0)
                && e.get("to").and_then(|s| s.as_str()) == Some("unhealthy")
        }),
        "health.json must name stage 1 unhealthy: {health:?}"
    );
    let finals = health.get("final").and_then(|v| v.as_arr()).unwrap();
    assert_eq!(finals.iter().map(|c| c.as_f64().unwrap() as u8).collect::<Vec<_>>(), vec![0, 2]);

    // the human report carries the transition list and the metrics
    // snapshot carries the health gauges
    let report = std::fs::read_to_string(dir.join("report.txt")).unwrap();
    assert!(report.contains("stage 1: suspect -> unhealthy (miss)"), "report:\n{report}");
    let prom = std::fs::read_to_string(dir.join("metrics.prom")).unwrap();
    assert!(prom.contains("terapipe_stage_health"), "metrics:\n{prom}");

    let _ = std::fs::remove_dir_all(&dir);
}

// ---------------------------------------------------------------------
// 2. Heartbeats never leak into collect loops; healthy runs stay healthy
// ---------------------------------------------------------------------

#[test]
fn heartbeat_run_trains_cleanly_and_reports_all_healthy() {
    let cfg = TrainConfig {
        slicing: vec![16, 16],
        steps: 2,
        seed: 17,
        heartbeat_ms: Some(20),
        recv_timeout_ms: Some(30_000),
        ..Default::default()
    };
    let mut t = Trainer::with_spec(spec(), cfg).unwrap();
    let m = t.model.clone();
    let corpus = synthetic_corpus(1 << 13, 7);
    let mut batcher = Batcher::new(&corpus, m.batch, m.seq_len, 17);
    let mut healths: Vec<Vec<u8>> = Vec::new();
    let reports = t
        .train(|| batcher.next_batch(), |r| healths.push(r.stage_health.clone()))
        .expect("heartbeats must be consumed, not surfaced as 'unexpected message'");
    assert_eq!(reports.len(), 2);
    assert!(
        healths.iter().all(|h| h == &vec![0u8; STAGES]),
        "healthy run must report all-healthy: {healths:?}"
    );
    assert!(t.health_timeline().entries.is_empty(), "{:?}", t.health_timeline());
    assert!(t.take_anomalies().is_empty());
}

// ---------------------------------------------------------------------
// 3. Anomaly attribution properties (public detector API)
// ---------------------------------------------------------------------

#[test]
fn stationary_streams_never_alarm() {
    let mut det = AnomalyDetector::new();
    let mut rng = Rng::new(11);
    for step in 1..=60u64 {
        for stage in 0..4usize {
            for slice in 0..4u32 {
                // stable per-stage level + small noise
                let ms = 5.0 + 0.3 * stage as f64 + 0.2 * rng.f64();
                det.observe_slice(stage, slice, 0, ms);
            }
        }
        for link in 0..3usize {
            det.observe_link(link, 0.5 + 0.05 * rng.f64());
        }
        let hits = det.end_step(step);
        assert!(hits.is_empty(), "false alarm at step {step}: {hits:?}");
    }
}

#[test]
fn planted_compute_straggler_is_named_with_stage_and_factor() {
    let mut det = AnomalyDetector::new();
    let mut rng = Rng::new(7);
    let mut caught = Vec::new();
    for step in 1..=40u64 {
        for stage in 0..4usize {
            for slice in 0..4u32 {
                let base = 4.0 + 0.1 * rng.f64();
                let ms = if stage == 2 && step > 20 { 4.0 * base } else { base };
                det.observe_slice(stage, slice, 0, ms);
            }
        }
        caught.extend(det.end_step(step));
    }
    assert!(!caught.is_empty(), "a 4x straggler must be detected");
    assert!(caught.iter().all(|d| d.step > 20), "no detections before the plant: {caught:?}");
    for d in &caught {
        match d.cause {
            Cause::ComputeStraggler { stage, factor } => {
                assert_eq!(stage, 2, "wrong stage: {d:?}");
                assert!((3.0..5.5).contains(&factor), "factor should be ~4: {d:?}");
            }
            other => panic!("expected a compute straggler, got {other:?}"),
        }
    }
}

#[test]
fn planted_link_delay_is_named_comm_degradation() {
    let mut det = AnomalyDetector::new();
    let mut rng = Rng::new(13);
    let mut caught = Vec::new();
    for step in 1..=40u64 {
        // healthy compute throughout: the only plant is on link 1
        for stage in 0..3usize {
            for slice in 0..4u32 {
                det.observe_slice(stage, slice, 0, 4.0 + 0.1 * rng.f64());
            }
        }
        for link in 0..3usize {
            for _ in 0..4 {
                let base = 0.5 + 0.05 * rng.f64();
                let ms = if link == 1 && step > 20 { 10.0 } else { base };
                det.observe_link(link, ms);
            }
        }
        caught.extend(det.end_step(step));
    }
    assert!(!caught.is_empty(), "a 10 ms link delay must be detected");
    for d in &caught {
        match d.cause {
            Cause::CommDegradation { link, factor } => {
                assert_eq!(link, 1, "wrong link: {d:?}");
                assert!(factor > 5.0, "factor should reflect ~20x delay: {d:?}");
            }
            other => panic!("expected comm degradation, got {other:?}"),
        }
    }
}
