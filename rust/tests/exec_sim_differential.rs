//! The §3.5 contract, end to end on the native backend: execute real
//! pipelined training steps with trace enabled, feed the measured
//! per-slice timings into `perfmodel` (the Eq. 9 measure → fit path), and
//! assert that `sim::wavefront` on the **fitted** model predicts the
//! **executed** forward-sweep makespan.
//!
//! Fits are **per stage**: stage 0's samples include the embedding,
//! the last stage's include the head loss, so each stage gets its own
//! Eq. 9 model and the wavefront replays per-stage durations
//! (`stream_plan_per_stage`). That — plus the blocked kernels making the
//! cell latency far less noise-dominated — is what lets the tolerance sit
//! at 35 % (down from the pre-per-stage 60 %): the residual slack covers
//! OS scheduler noise on shared CI boxes and channel dispatch overhead,
//! while the property pinned is that measure → fit → wavefront lands in
//! the same regime as the real execution (what the planner's decisions
//! ride on), not perf reproducibility at simulator precision.
//! `TERAPIPE_EXEC_STRICT=1` tightens to 20 % for quiet local machines.

use std::collections::HashMap;

use terapipe::backend::NativeSpec;
use terapipe::coordinator::{TimedPhase, TrainConfig, Trainer};
use terapipe::data::{synthetic_corpus, Batcher};
use terapipe::perfmodel::measure::Measurements;
use terapipe::perfmodel::{measure, CostModel};
use terapipe::runtime::manifest::ModelDims;
use terapipe::sim::schedule::stream_plan_per_stage;
use terapipe::sim::wavefront;

const GRAN: usize = 4;
const STAGES: usize = 2;

fn spec() -> NativeSpec {
    NativeSpec::new(
        ModelDims {
            vocab: 64,
            hidden: 32,
            num_heads: 4,
            layers_per_stage: 1,
            num_stages: STAGES,
            seq_len: 32,
            batch: 2,
            block_ctx: 8,
            seed: 9,
        },
        GRAN,
    )
}

/// One traced run: returns the per-(stage, i, j) forward samples and the
/// executed forward-sweep makespans of the non-warmup steps.
fn traced_run(slicing: &[usize], steps: usize) -> (Vec<(usize, u32, u32, f64)>, Vec<f64>) {
    let cfg = TrainConfig {
        slicing: slicing.to_vec(),
        steps,
        trace: true,
        seed: 17,
        ..Default::default()
    };
    let mut t = Trainer::with_spec(spec(), cfg).unwrap();
    let m = t.model.clone();
    let corpus = synthetic_corpus(1 << 13, 7);
    let mut batcher = Batcher::new(&corpus, m.batch, m.seq_len, 17);
    let mut samples = Vec::new();
    let mut fwd_makespans = Vec::new();
    for step in 0..steps {
        let batches: Vec<_> = (0..1).map(|_| batcher.next_batch()).collect();
        let fwd_ms = t.step(&batches).unwrap().fwd_ms;
        if step == 0 {
            continue; // warmup: cold caches, lazy thread spin-up
        }
        fwd_makespans.push(fwd_ms);
        for s in t.last_timings() {
            if s.phase == TimedPhase::Fwd {
                samples.push((s.stage, s.len as u32, s.off as u32, s.ms));
            }
        }
    }
    (samples, fwd_makespans)
}

fn median(mut v: Vec<f64>) -> f64 {
    assert!(!v.is_empty());
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    v[v.len() / 2]
}

#[test]
fn wavefront_on_fitted_model_predicts_executed_makespan() {
    let strict = std::env::var("TERAPIPE_EXEC_STRICT").is_ok();
    let tol = if strict { 0.20 } else { 0.35 };
    let slicings: [&[usize]; 3] = [&[8, 8, 8, 8], &[16, 16], &[4, 4, 8, 16]];
    let steps = 5;

    // ---- execute with trace, pooling samples across slicings so each
    // stage's fit sees enough (i, j) variety to be well-posed ----
    let mut all: Vec<HashMap<(u32, u32), Vec<f64>>> = vec![HashMap::new(); STAGES];
    let mut executed: Vec<f64> = Vec::new();
    for sl in slicings {
        let (samples, makespans) = traced_run(sl, steps);
        for (stage, i, j, ms) in samples {
            all[stage].entry((i, j)).or_default().push(ms);
        }
        executed.push(median(makespans));
    }

    // ---- per-stage measure → fit (stage 0 carries the embedding, the
    // last stage the head, so their latency laws differ) ----
    let mut fits = Vec::with_capacity(STAGES);
    for stage_samples in &all {
        let mut base = Vec::new();
        let mut ctx_samples = Vec::new();
        for (&(i, j), v) in stage_samples {
            let ms = median(v.clone());
            if j == 0 {
                base.push((i, ms));
            } else {
                ctx_samples.push((i, j, ms));
            }
        }
        assert!(base.len() >= 3, "base curve too thin: {base:?}");
        assert!(ctx_samples.len() >= 4, "ctx samples too thin: {ctx_samples:?}");
        let meas = Measurements {
            granularity: GRAN as u32,
            base,
            ctx_samples,
            repeats: (steps - 1) as u32,
        };
        fits.push(measure::fit(&meas, spec().model.seq_len as u32).unwrap());
    }

    // ---- wavefront-predict each executed schedule from the fits ----
    for (sl, exec_ms) in slicings.iter().zip(&executed) {
        let mut durs: Vec<Vec<f64>> = Vec::with_capacity(STAGES);
        for fitted in &fits {
            let mut stage_durs = Vec::with_capacity(sl.len());
            let mut off = 0u32;
            for &len in sl.iter() {
                stage_durs.push(fitted.t(len as u32, off));
                off += len as u32;
            }
            durs.push(stage_durs);
        }
        let plan = stream_plan_per_stage(&durs);
        assert!(wavefront::is_regular(&plan), "replay stream must be regular");
        let predicted = wavefront::evaluate(&plan, false).unwrap().makespan_ms;
        assert!(predicted > 0.0);
        let rel = (predicted - exec_ms).abs() / exec_ms;
        assert!(
            rel < tol,
            "slicing {sl:?}: wavefront predicts {predicted:.3} ms, executed {exec_ms:.3} ms (rel {rel:.2} ≥ {tol})"
        );
    }
}
