//! The §3.5 contract, end to end on the native backend: execute real
//! pipelined training steps with trace enabled, feed the measured
//! per-slice timings into `perfmodel` (the Eq. 9 measure → fit path), and
//! assert that `sim::wavefront` on the **fitted** model predicts the
//! **executed** forward-sweep makespan.
//!
//! Stated tolerance: 60 % relative. The fitted model is a single cell's
//! bilinear law, while the executed pipeline mixes stage roles (embedding
//! on stage 0, LM head on the last), OS scheduler noise on shared CI
//! boxes, and channel dispatch overhead — the contract being pinned is
//! that measure → fit → wavefront lands in the same regime as the real
//! execution (the property the planner's decisions ride on), not perf
//! reproducibility at simulator precision. `TERAPIPE_EXEC_STRICT=1`
//! tightens to 30 % for quiet local machines.

use std::collections::HashMap;

use terapipe::backend::NativeSpec;
use terapipe::coordinator::{TimedPhase, TrainConfig, Trainer};
use terapipe::data::{synthetic_corpus, Batcher};
use terapipe::perfmodel::measure::Measurements;
use terapipe::perfmodel::{measure, CostModel};
use terapipe::runtime::manifest::ModelDims;
use terapipe::sim::schedule::stream_plan;
use terapipe::sim::wavefront;

const GRAN: usize = 4;

fn spec() -> NativeSpec {
    NativeSpec::new(
        ModelDims {
            vocab: 64,
            hidden: 32,
            num_heads: 4,
            layers_per_stage: 1,
            num_stages: 2,
            seq_len: 32,
            batch: 2,
            block_ctx: 8,
            seed: 9,
        },
        GRAN,
    )
}

/// One traced run: returns the per-(i, j) forward samples (all stages)
/// and the executed forward-sweep makespans of the non-warmup steps.
fn traced_run(slicing: &[usize], steps: usize) -> (Vec<(u32, u32, f64)>, Vec<f64>) {
    let cfg = TrainConfig {
        slicing: slicing.to_vec(),
        steps,
        trace: true,
        seed: 17,
        ..Default::default()
    };
    let mut t = Trainer::with_spec(spec(), cfg).unwrap();
    let m = t.model.clone();
    let corpus = synthetic_corpus(1 << 13, 7);
    let mut batcher = Batcher::new(&corpus, m.batch, m.seq_len, 17);
    let mut samples = Vec::new();
    let mut fwd_makespans = Vec::new();
    for step in 0..steps {
        let batches: Vec<_> = (0..1).map(|_| batcher.next_batch()).collect();
        let (_, _, fwd_ms) = t.step(step, &batches).unwrap();
        if step == 0 {
            continue; // warmup: cold caches, lazy thread spin-up
        }
        fwd_makespans.push(fwd_ms);
        for s in t.last_timings() {
            if s.phase == TimedPhase::Fwd {
                samples.push((s.len as u32, s.off as u32, s.ms));
            }
        }
    }
    (samples, fwd_makespans)
}

fn median(mut v: Vec<f64>) -> f64 {
    assert!(!v.is_empty());
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    v[v.len() / 2]
}

#[test]
fn wavefront_on_fitted_model_predicts_executed_makespan() {
    let strict = std::env::var("TERAPIPE_EXEC_STRICT").is_ok();
    let tol = if strict { 0.30 } else { 0.60 };
    let slicings: [&[usize]; 3] = [&[8, 8, 8, 8], &[16, 16], &[4, 4, 8, 16]];
    let steps = 5;

    // ---- execute with trace, pooling samples across slicings so the
    // fit sees enough (i, j) variety to be well-posed ----
    let mut all: HashMap<(u32, u32), Vec<f64>> = HashMap::new();
    let mut executed: Vec<f64> = Vec::new();
    for sl in slicings {
        let (samples, makespans) = traced_run(sl, steps);
        for (i, j, ms) in samples {
            all.entry((i, j)).or_default().push(ms);
        }
        executed.push(median(makespans));
    }

    // ---- feed the measured per-slice timings into perfmodel ----
    let mut base = Vec::new();
    let mut ctx_samples = Vec::new();
    for (&(i, j), v) in &all {
        let ms = median(v.clone());
        if j == 0 {
            base.push((i, ms));
        } else {
            ctx_samples.push((i, j, ms));
        }
    }
    assert!(base.len() >= 3, "base curve too thin: {base:?}");
    assert!(ctx_samples.len() >= 4, "ctx samples too thin: {ctx_samples:?}");
    let meas = Measurements {
        granularity: GRAN as u32,
        base,
        ctx_samples,
        repeats: (steps - 1) as u32,
    };
    let fitted = measure::fit(&meas, spec().model.seq_len as u32).unwrap();

    // ---- wavefront-predict each executed schedule from the fit ----
    let stages = spec().model.num_stages;
    for (sl, exec_ms) in slicings.iter().zip(&executed) {
        let mut durs = Vec::with_capacity(sl.len());
        let mut off = 0u32;
        for &len in sl.iter() {
            durs.push(fitted.t(len as u32, off));
            off += len as u32;
        }
        let plan = stream_plan(&durs, stages);
        assert!(wavefront::is_regular(&plan), "replay stream must be regular");
        let predicted = wavefront::evaluate(&plan, false).unwrap().makespan_ms;
        assert!(predicted > 0.0);
        let rel = (predicted - exec_ms).abs() / exec_ms;
        assert!(
            rel < tol,
            "slicing {sl:?}: wavefront predicts {predicted:.3} ms, executed {exec_ms:.3} ms (rel {rel:.2} ≥ {tol})"
        );
    }
}
