//! Property suite for the blocked kernel layer (`backend::math`).
//!
//! Two contracts are pinned here, both load-bearing for the measure →
//! plan → execute loop:
//!
//! 1. **Blocked ≡ naive.** The cache-blocked/packed matmul family must
//!    agree with the simple reference loops (`*_ref`) — *bit for bit* for
//!    `matmul`/`matmul_nt` (each output element is accumulated in the
//!    same strictly ascending contraction order with one accumulator, and
//!    Rust does not contract mul+add into FMA), within tolerance for
//!    `matmul_tn`'s chunk-reduced parallel path — on randomized shapes
//!    including remainder tiles (M, K, N not multiples of the block
//!    sizes).
//! 2. **Thread-count independence.** Every kernel with a parallel path
//!    returns bit-identical results under rayon pools of 1, 2 and 8
//!    threads — the determinism contract `backend/README.md` documents.

use terapipe::backend::math::{
    add_bias, add_into, colsum_into, gelu, gelu_grad_mul, layernorm, layernorm_bwd, matmul,
    matmul_nt, matmul_nt_ref, matmul_ref, matmul_tn, matmul_tn_ref,
};

/// SplitMix64 → f32 in [-1, 1): deterministic test data.
fn rnd(n: usize, seed: u64) -> Vec<f32> {
    let mut s = seed.wrapping_mul(0x9E37_79B9_7F4A_7C15).wrapping_add(1);
    (0..n)
        .map(|_| {
            s = s.wrapping_add(0x9E37_79B9_7F4A_7C15);
            let mut z = s;
            z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
            z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
            z ^= z >> 31;
            (z >> 40) as f32 / (1u64 << 23) as f32 - 1.0
        })
        .collect()
}

fn bits(v: &[f32]) -> Vec<u32> {
    v.iter().map(|x| x.to_bits()).collect()
}

/// Random dims in [1, 96] — small enough to stay fast, large enough to
/// cross MR/NR tile boundaries with remainders in every position.
fn random_shapes(count: usize, seed: u64) -> Vec<(usize, usize, usize)> {
    let dims = rnd(3 * count, seed);
    (0..count)
        .map(|i| {
            let d = |x: f32| ((x + 1.0) * 47.5) as usize + 1;
            (d(dims[3 * i]), d(dims[3 * i + 1]), d(dims[3 * i + 2]))
        })
        .collect()
}

#[test]
fn blocked_matmul_matches_ref_bit_for_bit() {
    // hand-picked remainder/edge shapes + serial and both parallel paths
    let mut shapes = vec![
        (1, 1, 1),
        (3, 5, 2),
        (13, 7, 9),
        (65, 33, 50),
        (4, 8, 8),
        (130, 70, 90),  // row-block parallel (work ≥ PAR_THRESHOLD, m ≥ 2·MR)
        (1, 520, 260),  // skinny-M parallel: column tiles
        (3, 260, 120),  // skinny-M parallel with remainder rows
    ];
    shapes.extend(random_shapes(16, 42));
    for (m, k, n) in shapes {
        let a = rnd(m * k, 1);
        let b = rnd(k * n, 2);
        assert_eq!(
            bits(&matmul(&a, &b, m, k, n)),
            bits(&matmul_ref(&a, &b, m, k, n)),
            "matmul ({m},{k},{n})"
        );
    }
}

#[test]
fn blocked_matmul_nt_matches_ref_bit_for_bit() {
    let mut shapes = vec![
        (1, 1, 1),
        (5, 3, 2),
        (13, 9, 7),
        (65, 50, 33),
        (130, 90, 70),
        (1, 520, 260),
    ];
    shapes.extend(random_shapes(16, 43));
    for (m, n, k) in shapes {
        let a = rnd(m * n, 3);
        let b = rnd(k * n, 4);
        assert_eq!(
            bits(&matmul_nt(&a, &b, m, n, k)),
            bits(&matmul_nt_ref(&a, &b, m, n, k)),
            "matmul_nt ({m},{n},{k})"
        );
    }
}

#[test]
fn matmul_tn_serial_bitwise_parallel_within_tolerance() {
    // below the parallel threshold the panel-blocked accumulation keeps
    // the reference's per-element ascending-r association: bit-identical
    for (m, k, n) in [(9usize, 7usize, 13usize), (33, 17, 29), (4, 8, 8)] {
        let a = rnd(m * k, 5);
        let b = rnd(m * n, 6);
        assert_eq!(
            bits(&matmul_tn(&a, &b, m, k, n)),
            bits(&matmul_tn_ref(&a, &b, m, k, n)),
            "matmul_tn serial ({m},{k},{n})"
        );
    }
    // the parallel path reduces over fixed row chunks — a different (but
    // deterministic) association, so compare to the ref with tolerance
    let (m, k, n) = (160, 40, 48);
    let a = rnd(m * k, 7);
    let b = rnd(m * n, 8);
    let got = matmul_tn(&a, &b, m, k, n);
    let want = matmul_tn_ref(&a, &b, m, k, n);
    for (i, (x, y)) in got.iter().zip(&want).enumerate() {
        assert!((x - y).abs() < 1e-3, "matmul_tn parallel [{i}]: {x} vs {y}");
    }
}

/// Run every kernel with a parallel path on above-threshold shapes and
/// return the output bit patterns.
fn run_all_parallel_kernels() -> Vec<Vec<u32>> {
    let mut outs = Vec::new();
    // matmul: row-block parallel + skinny-M column-tile parallel
    let a = rnd(130 * 70, 10);
    let b = rnd(70 * 90, 11);
    outs.push(bits(&matmul(&a, &b, 130, 70, 90)));
    let a1 = rnd(520, 12);
    let b1 = rnd(520 * 260, 13);
    outs.push(bits(&matmul(&a1, &b1, 1, 520, 260)));
    // matmul_nt, both paths
    let a2 = rnd(130 * 90, 14);
    let b2 = rnd(70 * 90, 15);
    outs.push(bits(&matmul_nt(&a2, &b2, 130, 90, 70)));
    let a3 = rnd(260, 30);
    let b3 = rnd(520 * 260, 31);
    outs.push(bits(&matmul_nt(&a3, &b3, 1, 260, 520)));
    // matmul_tn (chunked cross-row reduction)
    let a4 = rnd(160 * 40, 16);
    let b4 = rnd(160 * 48, 17);
    outs.push(bits(&matmul_tn(&a4, &b4, 160, 40, 48)));
    // add_bias
    let mut x = rnd(1024 * 128, 18);
    let bias = rnd(128, 19);
    add_bias(&mut x, &bias);
    outs.push(bits(&x));
    // colsum (column-block parallel)
    let g = rnd(512 * 256, 20);
    let mut cs = vec![0f32; 256];
    colsum_into(&g, 256, &mut cs);
    outs.push(bits(&cs));
    // add_into (element-chunk parallel)
    let mut d = rnd(1 << 17, 21);
    let s = rnd(1 << 17, 22);
    add_into(&mut d, &s);
    outs.push(bits(&d));
    // layernorm fwd + bwd (row-parallel; bwd has the chunked reduction)
    let xl = rnd(1024 * 128, 23);
    let gm = rnd(128, 24);
    let bt = rnd(128, 25);
    let (y, stats) = layernorm(&xl, &gm, &bt, 128);
    outs.push(bits(&y));
    let gy = rnd(1024 * 128, 26);
    let mut gg = vec![0f32; 128];
    let mut gb = vec![0f32; 128];
    let gx = layernorm_bwd(&xl, &stats, &gm, &gy, 128, &mut gg, &mut gb);
    outs.push(bits(&gx));
    outs.push(bits(&gg));
    outs.push(bits(&gb));
    // gelu fwd + fused grad-multiply
    let xg = rnd(1 << 17, 27);
    outs.push(bits(&gelu(&xg)));
    let mut gmu = rnd(1 << 17, 28);
    gelu_grad_mul(&xg, &mut gmu);
    outs.push(bits(&gmu));
    outs
}

#[test]
fn every_parallel_kernel_is_bit_identical_across_thread_counts() {
    let run = |threads: usize| -> Vec<Vec<u32>> {
        rayon::ThreadPoolBuilder::new()
            .num_threads(threads)
            .build()
            .unwrap()
            .install(run_all_parallel_kernels)
    };
    let baseline = run(1);
    for threads in [2usize, 8] {
        let got = run(threads);
        assert_eq!(baseline.len(), got.len());
        for (i, (a, b)) in baseline.iter().zip(&got).enumerate() {
            assert_eq!(a, b, "kernel output #{i} differs between 1 and {threads} threads");
        }
    }
}
