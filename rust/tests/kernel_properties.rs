//! Property suite for the blocked kernel layer (`backend::math`) and the
//! runtime-dispatched SIMD tier (`backend::simd`).
//!
//! Three contracts are pinned here, all load-bearing for the measure →
//! plan → execute loop:
//!
//! 1. **Scalar tier ≡ naive, bit for bit.** Under the scalar dispatch
//!    tier the cache-blocked/packed matmul family must agree with the
//!    simple reference loops (`*_ref`) — *bit for bit* for
//!    `matmul`/`matmul_nt` (each output element is accumulated in the
//!    same strictly ascending contraction order with one accumulator,
//!    and Rust does not contract mul+add into FMA), within tolerance for
//!    `matmul_tn`'s chunk-reduced parallel path — on randomized shapes
//!    including remainder tiles (M, K, N not multiples of the block
//!    sizes). These tests pin the tier with `tier_guard(Tier::Scalar)`
//!    so they hold on AVX2 hosts too.
//! 2. **SIMD tier ≡ scalar tier, within stated tolerances.** The
//!    AVX2+FMA tier reassociates reductions (8-lane trees) and contracts
//!    mul+add into single-rounded FMAs, so it is pinned against the
//!    scalar tier with one tolerance per kernel family (documented on
//!    each test) on remainder-heavy shapes where vector tails are
//!    exercised. Skipped with a printed notice on hosts without
//!    AVX2+FMA.
//! 3. **Thread-count independence.** Every kernel with a parallel path
//!    returns bit-identical results under rayon pools of 1, 2 and 8
//!    threads — under *both* tiers: each element's floating-point
//!    association is a pure function of its position, never of the
//!    worker that computed it (`backend/README.md`).

use terapipe::backend::math::{
    add_bias, add_into, colsum_into, gelu, gelu_grad_mul, layernorm, layernorm_bwd, matmul,
    matmul_nt, matmul_nt_ref, matmul_ref, matmul_tn, matmul_tn_ref,
};
use terapipe::backend::simd::{set_tier, simd_available, tier_guard, Tier};

/// SplitMix64 → f32 in [-1, 1): deterministic test data.
fn rnd(n: usize, seed: u64) -> Vec<f32> {
    let mut s = seed.wrapping_mul(0x9E37_79B9_7F4A_7C15).wrapping_add(1);
    (0..n)
        .map(|_| {
            s = s.wrapping_add(0x9E37_79B9_7F4A_7C15);
            let mut z = s;
            z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
            z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
            z ^= z >> 31;
            (z >> 40) as f32 / (1u64 << 23) as f32 - 1.0
        })
        .collect()
}

fn bits(v: &[f32]) -> Vec<u32> {
    v.iter().map(|x| x.to_bits()).collect()
}

/// Mixed absolute/relative bound: `|x − y| ≤ tol · max(1, |x|, |y|)`.
fn assert_close(got: &[f32], want: &[f32], tol: f32, label: &str) {
    assert_eq!(got.len(), want.len(), "{label}: length mismatch");
    for (i, (x, y)) in got.iter().zip(want).enumerate() {
        let bound = tol * x.abs().max(y.abs()).max(1.0);
        assert!((x - y).abs() <= bound, "{label}[{i}]: {x} vs {y} (tol {tol})");
    }
}

/// Random dims in [1, 96] — small enough to stay fast, large enough to
/// cross MR/NR tile boundaries with remainders in every position.
fn random_shapes(count: usize, seed: u64) -> Vec<(usize, usize, usize)> {
    let dims = rnd(3 * count, seed);
    (0..count)
        .map(|i| {
            let d = |x: f32| ((x + 1.0) * 47.5) as usize + 1;
            (d(dims[3 * i]), d(dims[3 * i + 1]), d(dims[3 * i + 2]))
        })
        .collect()
}

#[test]
fn blocked_matmul_matches_ref_bit_for_bit() {
    // the bit-identity contract is a scalar-tier property
    let _tier = tier_guard(Tier::Scalar);
    // hand-picked remainder/edge shapes + serial and both parallel paths
    let mut shapes = vec![
        (1, 1, 1),
        (3, 5, 2),
        (13, 7, 9),
        (65, 33, 50),
        (4, 8, 8),
        (130, 70, 90),  // row-block parallel (work ≥ PAR_THRESHOLD, m ≥ 2·MR)
        (1, 520, 260),  // skinny-M parallel: column tiles
        (3, 260, 120),  // skinny-M parallel with remainder rows
    ];
    shapes.extend(random_shapes(16, 42));
    for (m, k, n) in shapes {
        let a = rnd(m * k, 1);
        let b = rnd(k * n, 2);
        assert_eq!(
            bits(&matmul(&a, &b, m, k, n)),
            bits(&matmul_ref(&a, &b, m, k, n)),
            "matmul ({m},{k},{n})"
        );
    }
}

#[test]
fn blocked_matmul_nt_matches_ref_bit_for_bit() {
    let _tier = tier_guard(Tier::Scalar);
    let mut shapes = vec![
        (1, 1, 1),
        (5, 3, 2),
        (13, 9, 7),
        (65, 50, 33),
        (130, 90, 70),
        (1, 520, 260),
    ];
    shapes.extend(random_shapes(16, 43));
    for (m, n, k) in shapes {
        let a = rnd(m * n, 3);
        let b = rnd(k * n, 4);
        assert_eq!(
            bits(&matmul_nt(&a, &b, m, n, k)),
            bits(&matmul_nt_ref(&a, &b, m, n, k)),
            "matmul_nt ({m},{n},{k})"
        );
    }
}

#[test]
fn matmul_tn_serial_bitwise_parallel_within_tolerance() {
    let _tier = tier_guard(Tier::Scalar);
    // below the parallel threshold the panel-blocked accumulation keeps
    // the reference's per-element ascending-r association: bit-identical
    for (m, k, n) in [(9usize, 7usize, 13usize), (33, 17, 29), (4, 8, 8)] {
        let a = rnd(m * k, 5);
        let b = rnd(m * n, 6);
        assert_eq!(
            bits(&matmul_tn(&a, &b, m, k, n)),
            bits(&matmul_tn_ref(&a, &b, m, k, n)),
            "matmul_tn serial ({m},{k},{n})"
        );
    }
    // the parallel path reduces over fixed row chunks — a different (but
    // deterministic) association, so compare to the ref with tolerance
    let (m, k, n) = (160, 40, 48);
    let a = rnd(m * k, 7);
    let b = rnd(m * n, 8);
    let got = matmul_tn(&a, &b, m, k, n);
    let want = matmul_tn_ref(&a, &b, m, k, n);
    for (i, (x, y)) in got.iter().zip(&want).enumerate() {
        assert!((x - y).abs() < 1e-3, "matmul_tn parallel [{i}]: {x} vs {y}");
    }
}

/// SIMD tier vs scalar tier for the matmul families, on remainder-heavy
/// shapes (no dimension a multiple of MR=4 / NR=8, so every kernel runs
/// its vector tail).
///
/// Tolerance: **1e-4** mixed abs/rel. FMA contraction plus the 8-lane
/// reduction tree reassociate a K-deep dot product; with K ≤ 521 and
/// inputs in [-1, 1) the observed divergence is well under 1e-5, so 1e-4
/// leaves an order of magnitude of slack without masking real bugs.
#[test]
fn simd_matmul_family_matches_scalar_within_tolerance() {
    if !simd_available() {
        eprintln!("note: host lacks AVX2+FMA, skipping simd-vs-scalar matmul differential");
        return;
    }
    let _tier = tier_guard(Tier::Scalar);
    let shapes = [
        (13usize, 9usize, 31usize),
        (5, 23, 17),
        (1, 1, 1),
        (130, 71, 89),
        (1, 521, 259),
        (3, 261, 121),
    ];
    for &(m, k, n) in &shapes {
        let a = rnd(m * k, 50);
        let b = rnd(k * n, 51);
        let c = rnd(m * n, 52);
        set_tier(Tier::Scalar);
        let mm_s = matmul(&a, &b, m, k, n);
        let nt_s = matmul_nt(&c, &b, m, n, k);
        let tn_s = matmul_tn(&a, &c, m, k, n);
        set_tier(Tier::Avx2);
        let mm_v = matmul(&a, &b, m, k, n);
        let nt_v = matmul_nt(&c, &b, m, n, k);
        let tn_v = matmul_tn(&a, &c, m, k, n);
        set_tier(Tier::Scalar);
        assert_close(&mm_v, &mm_s, 1e-4, &format!("matmul ({m},{k},{n})"));
        assert_close(&nt_v, &nt_s, 1e-4, &format!("matmul_nt ({m},{n},{k})"));
        assert_close(&tn_v, &tn_s, 1e-4, &format!("matmul_tn ({m},{k},{n})"));
    }
}

/// SIMD tier vs scalar tier for LayerNorm fwd/bwd and GELU fwd/grad on
/// row lengths with 8-lane remainders.
///
/// Tolerance: **1e-5** mixed abs/rel for all four. The LayerNorm moments
/// and backward sums are single-row reductions (d = 131 here); the GELU
/// paths additionally go through the vector exp polynomial, whose
/// worst-case relative error against `f32::exp` is ≈ 4e-6 at the clamp
/// edges and ≈ 1e-7 over the GELU operating range.
#[test]
fn simd_elementwise_family_matches_scalar_within_tolerance() {
    if !simd_available() {
        eprintln!("note: host lacks AVX2+FMA, skipping simd-vs-scalar elementwise differential");
        return;
    }
    let _tier = tier_guard(Tier::Scalar);
    let (rows, d) = (9usize, 131usize);
    let x = rnd(rows * d, 60);
    let gm = rnd(d, 61);
    let bt = rnd(d, 62);
    let gy = rnd(rows * d, 63);
    let xe = rnd(1003, 64);
    let gp0 = rnd(1003, 65);

    set_tier(Tier::Scalar);
    let (y_s, st_s) = layernorm(&x, &gm, &bt, d);
    let mut gg_s = vec![0f32; d];
    let mut gb_s = vec![0f32; d];
    let gx_s = layernorm_bwd(&x, &st_s, &gm, &gy, d, &mut gg_s, &mut gb_s);
    let ge_s = gelu(&xe);
    let mut gp_s = gp0.clone();
    gelu_grad_mul(&xe, &mut gp_s);

    set_tier(Tier::Avx2);
    let (y_v, st_v) = layernorm(&x, &gm, &bt, d);
    let mut gg_v = vec![0f32; d];
    let mut gb_v = vec![0f32; d];
    let gx_v = layernorm_bwd(&x, &st_v, &gm, &gy, d, &mut gg_v, &mut gb_v);
    let ge_v = gelu(&xe);
    let mut gp_v = gp0.clone();
    gelu_grad_mul(&xe, &mut gp_v);
    set_tier(Tier::Scalar);

    assert_close(&y_v, &y_s, 1e-5, "layernorm fwd");
    assert_close(&gx_v, &gx_s, 1e-5, "layernorm bwd gx");
    assert_close(&gg_v, &gg_s, 1e-5, "layernorm bwd gamma grad");
    assert_close(&gb_v, &gb_s, 1e-5, "layernorm bwd beta grad");
    assert_close(&ge_v, &ge_s, 1e-5, "gelu fwd");
    assert_close(&gp_v, &gp_s, 1e-5, "gelu grad-mul");
}

/// The cell-level hot loops (softmax row ops, fused Adam) dispatch below
/// the public math API, so pin the two tier implementations against each
/// other directly, on lengths with 8-lane remainders.
///
/// Tolerances per op: `row_max` is **bit-exact** (max is invariant under
/// reassociation on finite data); `exp_sum_sub` / `exp_norm_sub` go
/// through the vector exp polynomial — **1e-5**; `adam_chunk` only
/// reassociates the FMA-contracted moment updates — **1e-5**.
#[cfg(target_arch = "x86_64")]
#[test]
fn simd_cell_kernels_match_scalar_within_tolerance() {
    use terapipe::backend::simd::{avx2, scalar};
    if !simd_available() {
        eprintln!("note: host lacks AVX2+FMA, skipping simd-vs-scalar cell kernel differential");
        return;
    }
    for len in [1usize, 7, 64, 257, 1003] {
        let row = rnd(len, 70);
        let mx_s = scalar::row_max(&row);
        let mx_v = avx2::row_max(&row);
        assert_eq!(mx_s.to_bits(), mx_v.to_bits(), "row_max len {len}");

        let z_s = scalar::exp_sum_sub(&row, mx_s);
        let z_v = avx2::exp_sum_sub(&row, mx_v);
        assert!(
            (z_s - z_v).abs() <= 1e-5 * z_s.abs().max(1.0),
            "exp_sum_sub len {len}: {z_s} vs {z_v}"
        );

        let mut r_s = row.clone();
        let mut r_v = row.clone();
        let n_s = scalar::exp_norm_sub(&mut r_s, mx_s);
        let n_v = avx2::exp_norm_sub(&mut r_v, mx_v);
        assert!(
            (n_s - n_v).abs() <= 1e-5 * n_s.abs().max(1.0),
            "exp_norm_sub sum len {len}: {n_s} vs {n_v}"
        );
        assert_close(&r_v, &r_s, 1e-5, &format!("exp_norm_sub row len {len}"));

        // fused Adam from identical initial state, step-1 bias corrections
        let g = rnd(len, 71);
        let mut p_s = rnd(len, 72);
        let mut p_v = p_s.clone();
        let mut m_s = vec![0.01f32; len];
        let mut m_v = m_s.clone();
        let mut v_s = vec![0.02f32; len];
        let mut v_v = v_s.clone();
        scalar::adam_chunk(&mut p_s, &g, &mut m_s, &mut v_s, 1e-3, 0.1, 0.001);
        avx2::adam_chunk(&mut p_v, &g, &mut m_v, &mut v_v, 1e-3, 0.1, 0.001);
        assert_close(&p_v, &p_s, 1e-5, &format!("adam params len {len}"));
        assert_close(&m_v, &m_s, 1e-5, &format!("adam m len {len}"));
        assert_close(&v_v, &v_s, 1e-5, &format!("adam v len {len}"));
    }
}

/// Run every kernel with a parallel path on above-threshold shapes and
/// return the output bit patterns.
fn run_all_parallel_kernels() -> Vec<Vec<u32>> {
    let mut outs = Vec::new();
    // matmul: row-block parallel + skinny-M column-tile parallel
    let a = rnd(130 * 70, 10);
    let b = rnd(70 * 90, 11);
    outs.push(bits(&matmul(&a, &b, 130, 70, 90)));
    let a1 = rnd(520, 12);
    let b1 = rnd(520 * 260, 13);
    outs.push(bits(&matmul(&a1, &b1, 1, 520, 260)));
    // matmul_nt, both paths
    let a2 = rnd(130 * 90, 14);
    let b2 = rnd(70 * 90, 15);
    outs.push(bits(&matmul_nt(&a2, &b2, 130, 90, 70)));
    let a3 = rnd(260, 30);
    let b3 = rnd(520 * 260, 31);
    outs.push(bits(&matmul_nt(&a3, &b3, 1, 260, 520)));
    // matmul_tn (chunked cross-row reduction)
    let a4 = rnd(160 * 40, 16);
    let b4 = rnd(160 * 48, 17);
    outs.push(bits(&matmul_tn(&a4, &b4, 160, 40, 48)));
    // matmul_tn skinny-m (column-panel parallel, k output rows)
    let a5 = rnd(200 * 4, 32);
    let b5 = rnd(200 * 96, 33);
    outs.push(bits(&matmul_tn(&a5, &b5, 200, 4, 96)));
    // add_bias
    let mut x = rnd(1024 * 128, 18);
    let bias = rnd(128, 19);
    add_bias(&mut x, &bias);
    outs.push(bits(&x));
    // colsum (column-block parallel)
    let g = rnd(512 * 256, 20);
    let mut cs = vec![0f32; 256];
    colsum_into(&g, 256, &mut cs);
    outs.push(bits(&cs));
    // add_into (element-chunk parallel)
    let mut d = rnd(1 << 17, 21);
    let s = rnd(1 << 17, 22);
    add_into(&mut d, &s);
    outs.push(bits(&d));
    // layernorm fwd + bwd (row-parallel; bwd has the chunked reduction)
    let xl = rnd(1024 * 128, 23);
    let gm = rnd(128, 24);
    let bt = rnd(128, 25);
    let (y, stats) = layernorm(&xl, &gm, &bt, 128);
    outs.push(bits(&y));
    let gy = rnd(1024 * 128, 26);
    let mut gg = vec![0f32; 128];
    let mut gb = vec![0f32; 128];
    let gx = layernorm_bwd(&xl, &stats, &gm, &gy, 128, &mut gg, &mut gb);
    outs.push(bits(&gx));
    outs.push(bits(&gg));
    outs.push(bits(&gb));
    // gelu fwd + fused grad-multiply
    let xg = rnd(1 << 17, 27);
    outs.push(bits(&gelu(&xg)));
    let mut gmu = rnd(1 << 17, 28);
    gelu_grad_mul(&xg, &mut gmu);
    outs.push(bits(&gmu));
    outs
}

#[test]
fn every_parallel_kernel_is_bit_identical_across_thread_counts() {
    let run = |threads: usize| -> Vec<Vec<u32>> {
        rayon::ThreadPoolBuilder::new()
            .num_threads(threads)
            .build()
            .unwrap()
            .install(run_all_parallel_kernels)
    };
    // Pool invariance must hold under both tiers: ownership of each
    // output element — and hence its association — depends only on its
    // position, never on which worker computed it.
    let mut tiers = vec![Tier::Scalar];
    if simd_available() {
        tiers.push(Tier::Avx2);
    } else {
        eprintln!("note: host lacks AVX2+FMA, checking pool invariance under the scalar tier only");
    }
    for tier in tiers {
        let _g = tier_guard(tier);
        let baseline = run(1);
        for threads in [2usize, 8] {
            let got = run(threads);
            assert_eq!(baseline.len(), got.len());
            for (i, (a, b)) in baseline.iter().zip(&got).enumerate() {
                assert_eq!(
                    a, b,
                    "kernel output #{i} differs between 1 and {threads} threads ({tier:?} tier)"
                );
            }
        }
    }
}
