//! Cross-module property tests: the DP solver, the Eq. 5 closed form and
//! the discrete-event simulator must agree wherever the paper's math says
//! they do. Uses the in-tree deterministic property harness
//! (`terapipe::util::prop`).

use terapipe::config::presets;
use terapipe::perfmodel::analytic::AnalyticModel;
use terapipe::perfmodel::{pipeline_latency, CostModel, TableCostModel};
use terapipe::sim::engine::simulate;
use terapipe::sim::schedule::{build_plan, PhaseCost};
use terapipe::sim::{Item, Phase, Plan};
use terapipe::solver::dp::{solve_fixed_tmax, solve_tokens, solve_tokens_seq};
use terapipe::solver::joint::{evaluate_joint_with, solve_joint_exact, JointOpts};
use terapipe::solver::uniform::uniform_scheme;
use terapipe::solver::{JointScheme, SliceScheme};
use terapipe::util::prop;

/// Random affine-with-context cost model drawn per case.
fn random_model(g: &mut prop::Gen) -> impl CostModel + Clone {
    #[derive(Clone)]
    struct M {
        over: f64,
        lin: f64,
        ctx: f64,
        comm: f64,
    }
    impl CostModel for M {
        fn t(&self, i: u32, j: u32) -> f64 {
            self.over + self.lin * i as f64 + self.ctx * i as f64 * j as f64
        }
        fn t_comm(&self, _i: u32) -> f64 {
            self.comm
        }
    }
    M {
        over: g.float(0.01, 2.0),
        lin: g.float(0.001, 0.1),
        ctx: g.float(0.0, 3e-4),
        comm: g.float(0.0, 0.3),
    }
}

/// The DP's reported latency must equal the independent Eq. 5 evaluation
/// of its scheme, and no random slicing may beat it.
#[test]
fn prop_dp_latency_consistent_and_unbeaten_by_random_schemes() {
    prop::run_cases(60, |g| {
        let m = random_model(g);
        let units = g.int(4, 16);
        let gran = *g.choose(&[8u32, 16, 32]);
        let l = units * gran;
        let k = g.int(1, 24);
        let (scheme, _) = solve_tokens(&m, l, k, gran, 0.0);
        assert_eq!(scheme.seq_len(), l);

        let eval = pipeline_latency(&m, &scheme.lens, k);
        assert!(
            (eval - scheme.latency_ms).abs() < 1e-9,
            "reported {} vs eval {eval}",
            scheme.latency_ms
        );

        // the parallel engine and the sequential reference agree here too
        // (the dedicated bit-identity suite is solver_parallel_equivalence)
        let (seq_scheme, _) = solve_tokens_seq(&m, l, k, gran, 0.0);
        assert_eq!(scheme.lens, seq_scheme.lens);

        for _ in 0..50 {
            let lens = g.composition(l, gran);
            let lat = pipeline_latency(&m, &lens, k);
            assert!(
                scheme.latency_ms <= lat + 1e-9,
                "DP {} beaten by {:?} = {lat}",
                scheme.latency_ms,
                lens
            );
        }
    });
}

/// Algorithm 1 feasibility: every slice in a fixed-t_max solution respects
/// the budget, and tightening t_max never lowers the total.
#[test]
fn prop_fixed_tmax_feasible_and_monotone() {
    prop::run_cases(60, |g| {
        let m = random_model(g);
        let gran = 8u32;
        let l = g.int(4, 20) * gran;
        let table = TableCostModel::build(&m, l, gran);
        let tmax_hi = m.t(l, 0) + m.t_comm(l) + 1.0;
        let tmax_lo = tmax_hi * g.float(0.3, 0.9);

        let hi = solve_fixed_tmax(&table, tmax_hi).expect("whole-sequence slice fits");
        if let Some(lo) = solve_fixed_tmax(&table, tmax_lo) {
            assert!(lo.total_ms >= hi.total_ms - 1e-9, "tighter budget, lower total");
            let mut ctx = 0usize;
            for &u in &lo.lens_units {
                assert!(table.at(u, ctx) + table.comm_at(u) <= tmax_lo + 1e-9);
                ctx += u;
            }
        }
    });
}

/// Fwd-only simulation of any slicing equals the Eq. 5 closed form
/// (uniform per-stage costs — the regime where Eq. 5 is exact).
#[test]
fn prop_sim_forward_matches_eq5_closed_form() {
    prop::run_cases(60, |g| {
        let m = random_model(g);
        let gran = 8u32;
        let l = g.int(2, 12) * gran;
        let k = g.int(1, 10) as usize;
        let lens = g.composition(l, gran);

        // forward-only items on a K-stage chain
        let mut items = Vec::new();
        let mcount = lens.len();
        let mut ctx = vec![0u32; mcount];
        let mut acc = 0;
        for (i, &len) in lens.iter().enumerate() {
            ctx[i] = acc;
            acc += len;
        }
        for s in 0..k {
            for (i, &len) in lens.iter().enumerate() {
                let mut deps = Vec::new();
                if s > 0 {
                    deps.push(((s - 1) * mcount + i, m.t_comm(len)));
                }
                if i > 0 {
                    deps.push((s * mcount + i - 1, 0.0));
                }
                items.push(Item {
                    id: s * mcount + i,
                    stage: s,
                    phase: Phase::Fwd,
                    part: 0,
                    slice: i,
                    dur_ms: m.t(len, ctx[i]),
                    deps,
                    priority: (s * mcount + i) as u64,
                });
            }
        }
        let r = simulate(&Plan {
            stages: k,
            items,
            mem_cap_parts: None,
            flush_barrier: false,
        })
        .unwrap();

        // Eq. 5 with comm folded differently: the sim pays comm on edges
        // (pipeline fill), so compare against the no-comm closed form when
        // comm = 0; otherwise just require sim ≥ closed form.
        let closed = {
            let mut total = 0.0;
            let mut tmax = f64::NEG_INFINITY;
            let mut c = 0u32;
            for &len in &lens {
                let t = m.t(len, c);
                total += t;
                tmax = tmax.max(t);
                c += len;
            }
            total + (k as f64 - 1.0) * tmax
        };
        if m.t_comm(8) == 0.0 {
            assert!((r.makespan_ms - closed).abs() < 1e-6, "sim {} vs eq5 {closed}", r.makespan_ms);
        } else {
            assert!(r.makespan_ms >= closed - 1e-9);
        }
    });
}

/// The exact joint solver's plan always covers the batch, and its reported
/// latency is never worse than the trivial GPipe plan's Eq. 5 evaluation.
#[test]
fn prop_joint_exact_covers_batch_and_beats_gpipe_eval() {
    prop::run_cases(25, |g| {
        let setting = presets::setting(*g.choose(&[5u32, 7, 8, 9]));
        let base = AnalyticModel::from_setting(&setting, 1);
        let batch = g.int(1, 8);
        let k = g.int(2, 48);
        let opts = JointOpts {
            granularity: 128,
            eps_ms: 0.5,
            max_microbatch: Some(4),
        };
        let j = solve_joint_exact(|b| base.with_microbatch(b), batch, 2048, k, &opts);
        assert_eq!(j.batch(), batch);
        for (_, s) in &j.parts {
            assert_eq!(s.seq_len(), 2048);
            assert!(s.lens.iter().all(|&l| l % 128 == 0));
        }
        // trivial plan: every sequence unsliced
        let trivial: Vec<(u32, SliceScheme)> = (0..batch)
            .map(|_| {
                (
                    1u32,
                    SliceScheme {
                        lens: vec![2048],
                        total_ms: 0.0,
                        t_max_ms: 0.0,
                        latency_ms: 0.0,
                    },
                )
            })
            .collect();
        let trivial_eval = evaluate_joint_with(&|b| base.with_microbatch(b), &trivial, k);
        assert!(
            j.latency_ms <= trivial_eval + 1e-6,
            "joint {} vs trivial {trivial_eval}",
            j.latency_ms
        );
    });
}

/// Memory-capped simulation is never faster than uncapped, and caps ≥
/// #parts change nothing (Appendix A boundary conditions).
#[test]
fn prop_memory_cap_monotone() {
    struct Unit;
    impl PhaseCost for Unit {
        fn fwd_ms(&self, _b: u32, i: u32, _j: u32) -> f64 {
            i as f64
        }
        fn bwd_ms(&self, _b: u32, i: u32, _j: u32) -> f64 {
            2.0 * i as f64
        }
        fn comm_ms(&self, _b: u32, _i: u32) -> f64 {
            0.0
        }
    }
    prop::run_cases(40, |g| {
        let parts = g.int(2, 6);
        let slices = g.int(1, 3);
        let k = g.int(2, 5) as usize;
        let scheme = JointScheme {
            parts: (0..parts)
                .map(|_| {
                    (
                        1u32,
                        SliceScheme {
                            lens: vec![4; slices as usize],
                            total_ms: 0.0,
                            t_max_ms: 0.0,
                            latency_ms: 0.0,
                        },
                    )
                })
                .collect(),
            latency_ms: 0.0,
        };
        let free = simulate(&build_plan(&Unit, &scheme, k, None, false)).unwrap();
        let ample = simulate(&build_plan(&Unit, &scheme, k, Some(parts), false)).unwrap();
        let tight = simulate(&build_plan(&Unit, &scheme, k, Some(1), false)).unwrap();
        assert!((free.makespan_ms - ample.makespan_ms).abs() < 1e-9);
        assert!(tight.makespan_ms >= free.makespan_ms - 1e-9);
    });
}

/// Uniform baseline self-consistency: scheme latency equals the closed
/// form on random instances; the DP never loses to it.
#[test]
fn prop_uniform_eval_matches_closed_form_and_dp_wins() {
    prop::run_cases(40, |g| {
        let m = random_model(g);
        let gran = 8u32;
        let l = g.int(4, 16) * gran;
        let k = g.int(2, 16);
        let n = g.int(1, l / gran);
        let u = uniform_scheme(&m, l, k, n, gran);
        let eval = pipeline_latency(&m, &u.lens, k);
        assert!((eval - u.latency_ms).abs() < 1e-9);

        let (dp, _) = solve_tokens(&m, l, k, gran, 0.0);
        assert!(dp.latency_ms <= u.latency_ms + 1e-9);
    });
}
